# Convenience targets. `make artifacts` is what the runtime error
# messages and docs refer to: it AOT-exports the JAX models to HLO
# text + metadata (requires JAX; see DESIGN.md §Substitutions).

artifacts:
	cd python/compile && python aot.py --out ../../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Machine-readable perf record: engine throughput + SC-backend pool
# sweep in BENCH_sc.json, sorter-level Mbit/s in BENCH_bsn.json, and
# datapath/SI costs plus the faulted-vs-clean/guarded engine overhead
# in BENCH_datapath.json (all tracked across PRs; CI uploads them as
# the `bench-json` artifact with BENCH_QUICK=1).
bench-json:
	BENCH_JSON=BENCH_sc.json cargo bench --bench sc_serve
	BENCH_JSON=BENCH_bsn.json cargo bench --bench bsn
	BENCH_JSON=BENCH_datapath.json cargo bench --bench datapath

.PHONY: artifacts build test bench bench-json
