# Convenience targets. `make artifacts` is what the runtime error
# messages and docs refer to: it AOT-exports the JAX models to HLO
# text + metadata (requires JAX; see DESIGN.md §Substitutions).

artifacts:
	cd python/compile && python aot.py --out ../../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

# Machine-readable perf record: engine throughput + SC-backend pool
# sweep, written to BENCH_sc.json (tracked across PRs).
bench-json:
	BENCH_JSON=BENCH_sc.json cargo bench --bench sc_serve

.PHONY: artifacts build test bench bench-json
