# Convenience targets. `make artifacts` is what the runtime error
# messages and docs refer to: it AOT-exports the JAX models to HLO
# text + metadata (requires JAX; see DESIGN.md §Substitutions).

artifacts:
	cd python/compile && python aot.py --out ../../artifacts

build:
	cargo build --release

test:
	cargo test -q

bench:
	cargo bench

.PHONY: artifacts build test bench
