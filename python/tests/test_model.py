"""L2 model tests: shapes, quantization semantics, train/eval parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M
from compile import train as T


@pytest.fixture(scope="module")
def tnn_setup():
    cfg = M.tnn()
    params = M.init_params(cfg, jax.random.PRNGKey(0))
    return cfg, params


@pytest.fixture(scope="module")
def scnet_setup():
    cfg = M.scnet(10)
    params = M.init_params(cfg, jax.random.PRNGKey(1))
    return cfg, params


def test_param_names_match_init(tnn_setup, scnet_setup):
    for cfg, params in (tnn_setup, scnet_setup):
        assert set(cfg.param_names()) == set(params.keys())


def test_scnet_has_residual_taps():
    cfg = M.scnet(10)
    names = cfg.param_names()
    assert "conv0.alpha_res" in names
    assert "conv1.alpha_res" not in names
    assert names[0] == "input.alpha"
    assert names[-1] == "fc.w"


def test_forward_train_shapes(scnet_setup):
    cfg, params = scnet_setup
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 3, 32, 32))
    logits = M.forward_train(cfg, params, x, M.QuantKnobs.of())
    assert logits.shape == (4, 10)
    assert np.all(np.isfinite(np.asarray(logits)))


def test_forward_eval_shapes(scnet_setup):
    cfg, params = scnet_setup
    x = jax.random.normal(jax.random.PRNGKey(3), (2, 3, 32, 32))
    logits = M.forward_eval(cfg, params, x, M.QuantKnobs.of())
    assert logits.shape == (2, 10)
    # Serving-path logits are integer-valued (ternary fc on codes).
    a = np.asarray(logits)
    np.testing.assert_array_equal(a, np.round(a))


def test_fp_knobs_bypass_quantization(scnet_setup):
    cfg, params = scnet_setup
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 3, 32, 32))
    fp = M.QuantKnobs.of(act_fp=1.0, w_fp=1.0, res_fp=1.0)
    q = M.QuantKnobs.of()
    lf = M.forward_train(cfg, params, x, fp)
    lq = M.forward_train(cfg, params, x, q)
    # FP and quantized paths must differ (quantization does something).
    assert not np.allclose(np.asarray(lf), np.asarray(lq))


def test_fq_act_ste_grads():
    # Gradient flows through the STE (non-zero), and alpha receives a
    # gradient via the LSQ formulation.
    def f(x, a):
        return jnp.sum(M.fq_act(x, a, 4.0, 0.0) ** 2)

    x = jnp.asarray([0.3, -1.2, 2.7])
    gx, ga = jax.grad(f, argnums=(0, 1))(x, jnp.asarray(0.5))
    assert np.any(np.asarray(gx) != 0.0)
    assert np.asarray(ga) != 0.0


def test_ternarize_values():
    w = jnp.asarray([0.9, -0.8, 0.05, -0.1, 0.4])
    out = np.asarray(M.ternarize(w, jnp.asarray(0.0)))
    alpha = np.mean(np.abs(np.asarray(w)))
    np.testing.assert_allclose(out, np.asarray([1, -1, 0, 0, 1]) * alpha, rtol=1e-6)


def test_train_step_reduces_loss(tnn_setup):
    cfg, _ = tnn_setup
    params = M.init_params(cfg, jax.random.PRNGKey(5))
    moms = {k: jnp.zeros_like(v) for k, v in params.items()}
    key = jax.random.PRNGKey(6)
    # A tiny separable task: class = sign pattern of a fixed direction.
    x = jax.random.normal(key, (32, 1, 28, 28))
    y = (x.mean(axis=(1, 2, 3)) > 0).astype(jnp.int32)
    knobs = M.QuantKnobs.of(act_bsl=8)
    step = jax.jit(
        lambda p, m, x, y: M.sgd_momentum_step(cfg, p, m, x, y, 0.05, knobs)
    )
    first = None
    last = None
    for i in range(30):
        params, moms, loss = step(params, moms, x, y)
        if first is None:
            first = float(loss)
        last = float(loss)
    assert last < first, f"loss did not decrease: {first} -> {last}"


def test_flat_pack_roundtrip(scnet_setup):
    cfg, params = scnet_setup
    flat = T.pack(cfg, params)
    back = T.unpack(cfg, flat)
    for k in params:
        np.testing.assert_array_equal(np.asarray(params[k]), np.asarray(back[k]))


def test_flat_train_step_signature(tnn_setup):
    cfg, _ = tnn_setup
    params = M.init_params(cfg, jax.random.PRNGKey(7))
    n = len(cfg.param_names())
    flat_p = T.pack(cfg, params)
    flat_m = [jnp.zeros_like(t) for t in flat_p]
    x = jax.random.normal(jax.random.PRNGKey(8), (8, 1, 28, 28))
    y = jnp.zeros((8,), jnp.int32)
    knobs = M.QuantKnobs.of()
    fn = T.make_train_step(cfg)
    out = fn(*flat_p, *flat_m, x, y, jnp.asarray(0.01), *knobs.flat())
    assert len(out) == 2 * n + 1
    assert out[-1].shape == ()


def test_eval_train_path_matches_forward_train(scnet_setup):
    cfg, params = scnet_setup
    x = jax.random.normal(jax.random.PRNGKey(9), (2, 3, 32, 32))
    knobs = M.QuantKnobs.of()
    fn = T.make_eval_train_path(cfg)
    flat = T.pack(cfg, params)
    (logits,) = fn(*flat, x, *knobs.flat())
    want = M.forward_train(cfg, params, x, knobs)
    np.testing.assert_allclose(np.asarray(logits), np.asarray(want), rtol=1e-5)
