"""L1 kernel correctness: Pallas vs pure-jnp oracle.

The CORE correctness signal of the compile path: `sc_qmatmul` (Pallas,
interpret mode) must match `sc_qmatmul_ref` bit-exactly over a
hypothesis sweep of shapes and quantization parameters.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import fused_activation, im2col_ref, sc_qmatmul_ref
from compile.kernels.sc_matmul import sc_qmatmul, vmem_bytes


def _rand_case(rng, p, k, o, act_half=1, res=True):
    x = rng.integers(-act_half, act_half + 1, size=(p, k)).astype(np.float32)
    w = rng.integers(-1, 2, size=(k, o)).astype(np.float32)
    gamma = rng.uniform(0.5, 2.0, size=(o,)).astype(np.float32)
    beta = rng.uniform(-2.0, 2.0, size=(o,)).astype(np.float32)
    r = (
        rng.integers(-8, 9, size=(p, o)).astype(np.float32)
        if res
        else np.zeros((p, o), np.float32)
    )
    return x, w, gamma, beta, r


def _run_both(x, w, gamma, beta, r, aa, ar, ao, half, bm=32):
    got = sc_qmatmul(x, w, gamma, beta, r, aa, ar, ao, half, bm=bm)
    want = sc_qmatmul_ref(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(gamma), jnp.asarray(beta),
        jnp.asarray(r), aa, ar, ao, half,
    )
    return np.asarray(got), np.asarray(want)


def test_kernel_matches_ref_basic():
    rng = np.random.default_rng(0)
    x, w, gamma, beta, r = _rand_case(rng, 96, 18, 8)
    got, want = _run_both(x, w, gamma, beta, r, 0.03, 0.12, 0.5, 1.0)
    np.testing.assert_array_equal(got, want)


def test_kernel_no_residual():
    rng = np.random.default_rng(1)
    x, w, gamma, beta, r = _rand_case(rng, 50, 27, 16, res=False)
    got, want = _run_both(x, w, gamma, beta, r, 0.05, 0.0, 0.25, 8.0)
    np.testing.assert_array_equal(got, want)


def test_kernel_row_padding():
    # P not a multiple of bm exercises the padding path.
    rng = np.random.default_rng(2)
    x, w, gamma, beta, r = _rand_case(rng, 33, 9, 4)
    got, want = _run_both(x, w, gamma, beta, r, 0.1, 0.1, 0.5, 8.0, bm=32)
    assert got.shape == (33, 4)
    np.testing.assert_array_equal(got, want)


@settings(max_examples=25, deadline=None)
@given(
    p=st.integers(1, 80),
    k=st.integers(1, 64),
    o=st.integers(1, 24),
    act_half=st.sampled_from([1, 2, 4, 8]),
    out_half=st.sampled_from([1.0, 2.0, 8.0]),
    seed=st.integers(0, 2**31 - 1),
)
def test_kernel_matches_ref_sweep(p, k, o, act_half, out_half, seed):
    rng = np.random.default_rng(seed)
    x, w, gamma, beta, r = _rand_case(rng, p, k, o, act_half=act_half)
    aa = float(rng.uniform(0.01, 0.2))
    ar = float(rng.uniform(0.0, 0.3))
    ao = float(rng.uniform(0.1, 1.0))
    got, want = _run_both(x, w, gamma, beta, r, aa, ar, ao, float(out_half))
    np.testing.assert_array_equal(got, want)


def test_outputs_are_integer_codes_in_range():
    rng = np.random.default_rng(3)
    x, w, gamma, beta, r = _rand_case(rng, 64, 36, 8)
    got, _ = _run_both(x, w, gamma, beta, r, 0.02, 0.05, 0.3, 8.0)
    assert np.all(got == np.round(got)), "outputs must be integer codes"
    assert got.min() >= -8 and got.max() <= 8
    # BN-ReLU output is non-negative before quantization.
    assert got.min() >= 0 or np.all(got[got < 0] == 0)


def test_fused_activation_eq1():
    # Eq 1: gamma(x - beta) above beta, 0 below.
    acc = jnp.asarray([[-1.0, 0.0, 1.0, 3.0]])
    out = fused_activation(acc, 2.0, 1.0, 0.5, 8.0)
    np.testing.assert_array_equal(np.asarray(out), [[0.0, 0.0, 0.0, 8.0]])


def test_im2col_matches_conv():
    # im2col + matmul == lax.conv for random cases.
    rng = np.random.default_rng(4)
    x = rng.normal(size=(3, 8, 8)).astype(np.float32)
    w = rng.normal(size=(5, 3, 3, 3)).astype(np.float32)
    cols, oh, ow = im2col_ref(jnp.asarray(x), 3, 2, 1)
    wmat = w.reshape(5, 27).T
    got = (cols @ wmat).reshape(oh, ow, 5).transpose(2, 0, 1)
    want = jax.lax.conv_general_dilated(
        jnp.asarray(x)[None], jnp.asarray(w), (2, 2), [(1, 1), (1, 1)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )[0]
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_vmem_budget_largest_layer():
    # Largest scnet layer (K=576, O=64) at bm=128 must fit VMEM with
    # double-buffering headroom (DESIGN.md §Perf).
    assert vmem_bytes(128, 576, 64) < 4 * 1024 * 1024


@pytest.mark.parametrize("bm", [8, 32, 128])
def test_block_size_invariance(bm):
    rng = np.random.default_rng(5)
    x, w, gamma, beta, r = _rand_case(rng, 70, 12, 6)
    a = sc_qmatmul(x, w, gamma, beta, r, 0.1, 0.1, 0.4, 8.0, bm=bm)
    b = sc_qmatmul_ref(
        jnp.asarray(x), jnp.asarray(w), jnp.asarray(gamma), jnp.asarray(beta),
        jnp.asarray(r), 0.1, 0.1, 0.4, 8.0,
    )
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
