"""AOT export tests: HLO text artifacts + metadata round-trip."""

import os
import subprocess
import sys

import numpy as np
import pytest

from compile import aot
from compile import model as M


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.export_model(M.tnn(), str(out))
    return str(out)


def test_artifacts_exist(exported):
    for suffix in ("train", "eval", "evalq"):
        p = os.path.join(exported, f"tnn_{suffix}.hlo.txt")
        assert os.path.exists(p), p
        text = open(p).read()
        assert text.startswith("HloModule"), "must be HLO text, not proto"
        assert "ENTRY" in text


def test_meta_header_and_params(exported):
    lines = open(os.path.join(exported, "tnn_meta.txt")).read().splitlines()
    head = lines[0].split()
    assert head[0] == "model" and head[1] == "tnn"
    n_params = int(head[head.index("params") + 1])
    p_lines = [l for l in lines if l.startswith("P ")]
    init_lines = [l for l in lines if l.startswith("INIT ")]
    assert len(p_lines) == n_params
    assert len(init_lines) == n_params
    names = [l.split()[1] for l in p_lines]
    assert names == M.tnn().param_names()


def test_init_values_roundtrip(exported):
    # INIT hex blobs decode to the same values init_params produces.
    import jax

    params = M.init_params(M.tnn(), jax.random.PRNGKey(0))
    lines = open(os.path.join(exported, "tnn_meta.txt")).read().splitlines()
    for l in lines:
        if not l.startswith("INIT "):
            continue
        _, name, hexs = l.split()
        got = np.frombuffer(bytes.fromhex(hexs), dtype="<f4")
        want = np.ravel(np.asarray(params[name], np.float32))
        np.testing.assert_array_equal(got, want, err_msg=name)


def test_hlo_parameter_count_matches_meta(exported):
    # The train HLO has 2*n_params + 9 entry parameters (params, moms,
    # x, y, lr, 6 knobs).
    n = len(M.tnn().param_names())
    text = open(os.path.join(exported, "tnn_train.hlo.txt")).read()
    # Nested computations (reducers, fusions) declare their own
    # parameters and are printed before ENTRY — count only the entry's.
    entry = text[text.index("ENTRY "):]
    n_args = entry.count("parameter(")
    assert n_args == 2 * n + 9, f"{n_args} != {2 * n + 9}"


def test_cli_runs(tmp_path):
    env = dict(os.environ)
    r = subprocess.run(
        [sys.executable, "-m", "compile.aot", "--out", str(tmp_path), "--models", "tnn"],
        capture_output=True,
        text=True,
        env=env,
        cwd=os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    )
    assert r.returncode == 0, r.stderr
    assert os.path.exists(tmp_path / "tnn_meta.txt")
