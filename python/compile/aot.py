"""AOT export: lower the L2 model to HLO text for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the xla
crate's xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text
parser reassigns ids, so text round-trips cleanly. See
/opt/xla-example/gen_hlo.py.

Artifacts written to ``artifacts/`` (``make artifacts``):

    <model>_train.hlo.txt      flat SGD+momentum step
    <model>_eval.hlo.txt       serving path (Pallas kernel inside)
    <model>_evalq.hlo.txt      fake-quant eval path (FP ablations)
    <model>_calib.hlo.txt      activation-statistics pass (QAT re-seating)
    <model>_meta.txt           flat input/output metadata + init values

Meta format (line-oriented, parsed by rust/src/runtime/meta.rs):

    model <name> classes <k> input <c> <h> <w> batch <b> params <n>
    P <name> <dtype> <d0,d1,...>        one line per parameter
    IN <role> <dtype> <dims>            extra inputs in order
    INIT <name> <base64-less hex f32 little-endian...>
"""

import argparse
import os
import sys

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M
from . import train as T

# Fixed batch size baked into the exported HLO (the Rust batcher pads).
BATCH = 32


def to_hlo_text(lowered) -> str:
    """StableHLO MLIR -> XlaComputation -> HLO text (see module docs)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def export_model(cfg: M.ModelCfg, outdir: str, seed: int = 0) -> None:
    """Export train/eval HLOs and metadata for one model config."""
    names = cfg.param_names()
    params = M.init_params(cfg, jax.random.PRNGKey(seed))
    pspecs = [spec(params[n].shape) for n in names]
    c, h, w = cfg.input
    x_spec = spec((BATCH, c, h, w))
    y_spec = spec((BATCH,), jnp.int32)
    scalar = spec(())
    knob_specs = [scalar] * 6

    train_args = pspecs + pspecs + [x_spec, y_spec, scalar] + knob_specs
    eval_args = pspecs + [x_spec] + knob_specs

    train_fn = T.make_train_step(cfg)
    eval_fn = T.make_eval_step(cfg)
    evalq_fn = T.make_eval_train_path(cfg)
    calib_fn = T.make_calib(cfg)
    calib_args = pspecs + [x_spec]

    jobs = [
        (f"{cfg.name}_train", train_fn, train_args),
        (f"{cfg.name}_eval", eval_fn, eval_args),
        (f"{cfg.name}_evalq", evalq_fn, eval_args),
        (f"{cfg.name}_calib", calib_fn, calib_args),
    ]
    for name, fn, args in jobs:
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        path = os.path.join(outdir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {path} ({len(text)} chars)")

    meta_path = os.path.join(outdir, f"{cfg.name}_meta.txt")
    with open(meta_path, "w") as f:
        f.write(
            f"model {cfg.name} classes {cfg.num_classes} "
            f"input {c} {h} {w} batch {BATCH} params {len(names)}\n"
        )
        for n in names:
            dims = ",".join(str(d) for d in params[n].shape)
            f.write(f"P {n} f32 {dims}\n")
        # Initial parameter values (hex-encoded f32 LE) so the Rust
        # trainer starts from the same init as python.
        for n in names:
            flat = jnp.ravel(params[n]).astype(jnp.float32)
            hexs = bytes(flat.tobytes()).hex()
            f.write(f"INIT {n} {hexs}\n")
    print(f"wrote {meta_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="output directory")
    ap.add_argument(
        "--models",
        default="tnn,scnet10,scnet20",
        help="comma-separated model list",
    )
    args = ap.parse_args()
    outdir = args.out
    os.makedirs(outdir, exist_ok=True)
    for m in args.models.split(","):
        m = m.strip()
        if m == "tnn":
            cfg = M.tnn()
        elif m.startswith("scnet"):
            cfg = M.scnet(int(m[len("scnet"):] or "10"))
        else:
            print(f"unknown model {m}", file=sys.stderr)
            sys.exit(1)
        export_model(cfg, outdir)


if __name__ == "__main__":
    main()
