"""Flat-signature train/eval functions for AOT export.

The Rust coordinator drives training by executing the exported
``train_step`` HLO in a loop (Python never runs at runtime), so the
JAX functions here take and return *flat lists of arrays* in the
deterministic order of ``ModelCfg.param_names()`` — the same order the
Rust side reads from the metadata file.

Signatures (all f32 unless noted):

``train_step``:
  inputs:  params..., moms..., x[B,C,H,W], y[B] (i32), lr,
           act_half, act_fp, w_fp, res_half, res_fp, res_on
  outputs: new_params..., new_moms..., loss

``eval_step``:
  inputs:  params..., x[B,C,H,W],
           act_half, act_fp, w_fp, res_half, res_fp, res_on
  outputs: logits[B, num_classes]

``eval_step`` runs the **serving path** (integer codes through the
Pallas kernel); ``train_step`` runs the fake-quant QAT path.
"""

from typing import List

import jax.numpy as jnp

from . import model as M


def pack(cfg: M.ModelCfg, params: dict) -> List[jnp.ndarray]:
    """Dict -> flat list in export order."""
    return [params[n] for n in cfg.param_names()]


def unpack(cfg: M.ModelCfg, flat) -> dict:
    """Flat list -> dict."""
    names = cfg.param_names()
    assert len(flat) == len(names), f"{len(flat)} != {len(names)}"
    return dict(zip(names, flat))


def make_train_step(cfg: M.ModelCfg):
    """Build the flat train-step function for `cfg`."""
    n = len(cfg.param_names())

    def train_step(*args):
        params = unpack(cfg, args[:n])
        moms = unpack(cfg, args[n : 2 * n])
        x, y, lr = args[2 * n], args[2 * n + 1], args[2 * n + 2]
        knobs = M.QuantKnobs.unflat(args[2 * n + 3 : 2 * n + 9])
        new_p, new_m, loss = M.sgd_momentum_step(cfg, params, moms, x, y, lr, knobs)
        return tuple(pack(cfg, new_p)) + tuple(pack(cfg, new_m)) + (loss,)

    return train_step


def make_eval_step(cfg: M.ModelCfg):
    """Build the flat eval-step (serving) function for `cfg`."""
    n = len(cfg.param_names())

    def eval_step(*args):
        params = unpack(cfg, args[:n])
        x = args[n]
        knobs = M.QuantKnobs.unflat(args[n + 1 : n + 7])
        return (M.forward_eval(cfg, params, x, knobs),)

    return eval_step


def make_eval_train_path(cfg: M.ModelCfg):
    """Flat eval using the *training* (fake-quant) path — used for the
    ablation accuracy rows where the float/FP configurations cannot run
    on the integer serving path."""
    n = len(cfg.param_names())

    def eval_step(*args):
        params = unpack(cfg, args[:n])
        x = args[n]
        knobs = M.QuantKnobs.unflat(args[n + 1 : n + 7])
        return (M.forward_train(cfg, params, x, knobs),)

    return eval_step


def make_calib(cfg: M.ModelCfg):
    """Flat calibration pass: float forward returning per-layer
    activation statistics used to re-seat the quantization scales
    between the float warm-up and the QAT phase.

    inputs:  params..., x[B,C,H,W]
    outputs: stats[1 + n_convs] — mean |input| followed by the mean
             absolute post-activation value of every conv layer.
    """
    n = len(cfg.param_names())

    def calib(*args):
        params = unpack(cfg, args[:n])
        x = args[n]
        stats = [jnp.mean(jnp.abs(x))]
        res = None
        for i, c in enumerate(cfg.convs):
            w = params[f"conv{i}.w"]
            y = M.conv_nchw(x, w, c.stride, c.pad)
            if c.res_in and res is not None:
                y = y + res
            if c.bn:
                g = params[f"conv{i}.gamma"][None, :, None, None]
                b = params[f"conv{i}.beta"][None, :, None, None]
                y = g * (y - b)
            if c.relu:
                y = jnp.maximum(y, 0.0)
            if c.res_out:
                res = y
            stats.append(jnp.mean(jnp.abs(y)))
            x = y
        return (jnp.stack(stats),)

    return calib
