"""L2: the SC-friendly model (paper §III) in JAX.

Mirrors the Rust model substrate (`rust/src/nn/model.rs`) exactly:

* same topologies (``tnn``, ``scnet``), same parameter names and order;
* same quantization rules — ternary weights at ``alpha_w = mean|w|``,
  thermometer activations at trained per-layer ``alpha_out``, and the
  **high-precision residual tap** (BSL 16) of Fig 6b;
* the BN-ReLU fusion of Eq 1 (``BN(x) = gamma·(x - beta)``).

Two forward paths:

* :func:`forward_train` — float fake-quant (LSQ-style STE) for QAT; all
  quantization knobs are *traced scalars*, so one exported HLO serves
  every ablation row (Table III, Fig 2, Fig 8, Table IV).
* :func:`forward_eval` — the serving path: integer codes end-to-end,
  with every conv running through the L1 Pallas kernel
  (`kernels/sc_matmul.py`).
"""

import dataclasses
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from .kernels import ref as kref
from .kernels.sc_matmul import sc_qmatmul

# Residual tap BSL (paper §III: 16b residual).
RES_BSL = 16


@dataclasses.dataclass(frozen=True)
class ConvCfg:
    """One conv layer (mirror of Rust `LayerCfg::Conv`)."""

    cin: int
    cout: int
    k: int
    stride: int
    pad: int
    bn: bool
    relu: bool
    res_in: bool
    res_out: bool


@dataclasses.dataclass(frozen=True)
class ModelCfg:
    """Model topology (mirror of Rust `ModelCfg`)."""

    name: str
    input: Tuple[int, int, int]
    convs: Tuple[ConvCfg, ...]
    num_classes: int

    def param_names(self) -> List[str]:
        """Parameter names in export order (must match Rust)."""
        names = ["input.alpha"]
        for i, c in enumerate(self.convs):
            names.append(f"conv{i}.w")
            if c.bn:
                names.append(f"conv{i}.gamma")
                names.append(f"conv{i}.beta")
            names.append(f"conv{i}.alpha_out")
            if c.res_out:
                names.append(f"conv{i}.alpha_res")
        names.append("fc.w")
        return names


def tnn() -> ModelCfg:
    """The §II ternary CNN for SynthDigits (28×28×1)."""
    conv = lambda cin, cout, s: ConvCfg(cin, cout, 3, s, 1, False, True, False, False)
    return ModelCfg(
        name="tnn",
        input=(1, 28, 28),
        convs=(conv(1, 8, 2), conv(8, 16, 2), conv(16, 32, 2)),
        num_classes=10,
    )


def scnet(num_classes: int = 10) -> ModelCfg:
    """The §III SC-friendly residual network for SynthCIFAR (32×32×3)."""
    c = ConvCfg
    return ModelCfg(
        name=f"scnet{num_classes}",
        input=(3, 32, 32),
        convs=(
            c(3, 16, 3, 1, 1, True, True, False, True),
            c(16, 16, 3, 1, 1, True, True, True, False),
            c(16, 32, 3, 2, 1, True, True, False, True),
            c(32, 32, 3, 1, 1, True, True, True, False),
            c(32, 64, 3, 2, 1, True, True, False, True),
            c(64, 64, 3, 1, 1, True, True, True, False),
        ),
        num_classes=num_classes,
    )


def init_params(cfg: ModelCfg, key) -> Dict[str, jnp.ndarray]:
    """He-style init matching Rust `ModelParams::init` conventions."""
    params: Dict[str, jnp.ndarray] = {"input.alpha": jnp.asarray([0.5], jnp.float32)}
    for i, c in enumerate(cfg.convs):
        key, sub = jax.random.split(key)
        fan_in = c.k * c.k * c.cin
        std = (2.0 / fan_in) ** 0.5
        params[f"conv{i}.w"] = std * jax.random.normal(
            sub, (c.cout, c.cin, c.k, c.k), jnp.float32
        )
        if c.bn:
            params[f"conv{i}.gamma"] = jnp.ones((c.cout,), jnp.float32)
            params[f"conv{i}.beta"] = jnp.zeros((c.cout,), jnp.float32)
        params[f"conv{i}.alpha_out"] = jnp.asarray([0.5], jnp.float32)
        if c.res_out:
            params[f"conv{i}.alpha_res"] = jnp.asarray([0.125], jnp.float32)
    key, sub = jax.random.split(key)
    hid = cfg.convs[-1].cout
    params["fc.w"] = (2.0 / hid) ** 0.5 * jax.random.normal(
        sub, (cfg.num_classes, hid), jnp.float32
    )
    return params


@dataclasses.dataclass(frozen=True)
class QuantKnobs:
    """Traced quantization configuration (one HLO serves all ablations).

    ``*_fp`` flags are 0/1 floats: 1 selects the float (un-quantized)
    path. ``res_on`` gates the residual adds entirely.
    """

    act_half: jnp.ndarray
    act_fp: jnp.ndarray
    w_fp: jnp.ndarray
    res_half: jnp.ndarray
    res_fp: jnp.ndarray
    res_on: jnp.ndarray

    @staticmethod
    def of(act_bsl=2, act_fp=0.0, w_fp=0.0, res_bsl=RES_BSL, res_fp=0.0, res_on=1.0):
        """Concrete knobs (for tests / default tracing)."""
        return QuantKnobs(
            act_half=jnp.asarray(act_bsl / 2, jnp.float32),
            act_fp=jnp.asarray(act_fp, jnp.float32),
            w_fp=jnp.asarray(w_fp, jnp.float32),
            res_half=jnp.asarray(res_bsl / 2, jnp.float32),
            res_fp=jnp.asarray(res_fp, jnp.float32),
            res_on=jnp.asarray(res_on, jnp.float32),
        )

    def flat(self):
        """Scalars in export order."""
        return [self.act_half, self.act_fp, self.w_fp, self.res_half, self.res_fp, self.res_on]

    @staticmethod
    def unflat(vals):
        """Rebuild from export order."""
        return QuantKnobs(*vals)


def fq_act(x, alpha, half, fp_flag):
    """LSQ-style fake-quant with STE; `fp_flag=1` bypasses."""
    alpha = jnp.maximum(alpha, 1e-8)
    xa = x / alpha
    xc = jnp.clip(xa, -half, half)
    xr = xc + lax.stop_gradient(jnp.round(xc) - xc)
    return fp_flag * x + (1.0 - fp_flag) * xr * alpha


def ternarize(w, fp_flag):
    """Ternary fake-quant at ``alpha_w = mean|w|`` (Rust rule)."""
    alpha = jnp.maximum(jnp.mean(jnp.abs(w)), 1e-8)
    wq = jnp.clip(jnp.round(w / alpha), -1.0, 1.0)
    q = w + lax.stop_gradient(wq * alpha - w)
    return fp_flag * w + (1.0 - fp_flag) * q


def w_alpha(w):
    """Weight scale (shared rule)."""
    return jnp.maximum(jnp.mean(jnp.abs(w)), 1e-8)


def conv_nchw(x, w, stride, pad):
    """Standard NCHW/OIHW convolution."""
    return lax.conv_general_dilated(
        x, w, (stride, stride), [(pad, pad), (pad, pad)],
        dimension_numbers=("NCHW", "OIHW", "NCHW"),
    )


def forward_train(cfg: ModelCfg, params, x, knobs: QuantKnobs):
    """QAT fake-quant forward; returns logits ``[B, num_classes]``."""
    a0 = params["input.alpha"][0]
    x = fq_act(x, a0, knobs.act_half, knobs.act_fp)
    res = None
    for i, c in enumerate(cfg.convs):
        w = ternarize(params[f"conv{i}.w"], knobs.w_fp)
        y = conv_nchw(x, w, c.stride, c.pad)
        if c.res_in and res is not None:
            y = y + knobs.res_on * res
        if c.bn:
            g = params[f"conv{i}.gamma"][None, :, None, None]
            b = params[f"conv{i}.beta"][None, :, None, None]
            y = g * (y - b)
        if c.relu:
            y = jnp.maximum(y, 0.0)
        if c.res_out:
            ar = params[f"conv{i}.alpha_res"][0]
            res = fq_act(y, ar, knobs.res_half, knobs.res_fp)
        ao = params[f"conv{i}.alpha_out"][0]
        x = fq_act(y, ao, knobs.act_half, knobs.act_fp)
    feat = jnp.mean(x, axis=(2, 3))
    wfc = ternarize(params["fc.w"], knobs.w_fp)
    return feat @ wfc.T


def forward_eval(cfg: ModelCfg, params, x, knobs: QuantKnobs):
    """Serving path: integer codes end-to-end through the Pallas kernel.

    Activations are integer-valued code tensors; each conv is an
    im2col + :func:`sc_qmatmul` call fusing BSN accumulation, residual
    and the Eq-1 SI activation, exactly as the silicon datapath.
    """
    b = x.shape[0]
    a_in = params["input.alpha"][0]
    q = jnp.clip(jnp.round(x / a_in), -knobs.act_half, knobs.act_half)
    res_q = None
    alpha_res_in = jnp.asarray(0.0, jnp.float32)
    alpha_in = a_in
    for i, c in enumerate(cfg.convs):
        w = params[f"conv{i}.w"]
        aw = w_alpha(w)
        wq = jnp.clip(jnp.round(w / aw), -1.0, 1.0)
        # [O, I, K, K] -> [I*K*K, O] to match im2col column order.
        wmat = wq.reshape(c.cout, c.cin * c.k * c.k).T
        cols = jax.vmap(lambda im: kref.im2col_ref(im, c.k, c.stride, c.pad)[0])(q)
        _, oh, ow = kref.im2col_ref(q[0], c.k, c.stride, c.pad)
        xmat = cols.reshape(b * oh * ow, c.cin * c.k * c.k)
        alpha_acc = alpha_in * aw
        if c.res_in and res_q is not None:
            # Residual codes are spatially aligned (stride-1 blocks).
            rmat = res_q.transpose(0, 2, 3, 1).reshape(b * oh * ow, c.cout)
            a_res = alpha_res_in * knobs.res_on
        else:
            rmat = jnp.zeros((b * oh * ow, c.cout), jnp.float32)
            a_res = jnp.asarray(0.0, jnp.float32)
        gamma = params.get(f"conv{i}.gamma", jnp.ones((c.cout,), jnp.float32))
        beta = params.get(f"conv{i}.beta", jnp.zeros((c.cout,), jnp.float32))
        ao = params[f"conv{i}.alpha_out"][0]
        out = sc_qmatmul(
            xmat, wmat, gamma, beta, rmat,
            alpha_acc, a_res, ao, knobs.act_half,
        )
        if c.res_out:
            ar = params[f"conv{i}.alpha_res"][0]
            acc_real = (xmat @ wmat) * alpha_acc + rmat * a_res
            res_flat = kref.fused_activation(
                acc_real, gamma[None, :], beta[None, :], ar, knobs.res_half
            )
            res_q = res_flat.reshape(b, oh, ow, c.cout).transpose(0, 3, 1, 2)
            alpha_res_in = ar
        q = out.reshape(b, oh, ow, c.cout).transpose(0, 3, 1, 2)
        alpha_in = ao
    feat = jnp.sum(q, axis=(2, 3))  # count-domain GAP (scale-free argmax)
    wfc = params["fc.w"]
    afc = w_alpha(wfc)
    wfcq = jnp.clip(jnp.round(wfc / afc), -1.0, 1.0)
    return feat @ wfcq.T


def loss_fn(cfg: ModelCfg, params, x, y, knobs: QuantKnobs):
    """Mean softmax cross-entropy."""
    logits = forward_train(cfg, params, x, knobs)
    logp = jax.nn.log_softmax(logits)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
    return nll


# Global gradient-norm clip: the paper's BN (Eq 1) is a pure affine
# transform with no variance normalization, so deep non-residual
# configurations can explode without it.
GRAD_CLIP = 5.0


def sgd_momentum_step(cfg: ModelCfg, params, moms, x, y, lr, knobs: QuantKnobs, mu=0.9):
    """One SGD+momentum step with global-norm clipping; returns
    (params, moms, loss)."""
    loss, grads = jax.value_and_grad(lambda p: loss_fn(cfg, p, x, y, knobs))(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g * g) for g in grads.values()) + 1e-12)
    scale = jnp.minimum(1.0, GRAD_CLIP / gnorm)
    new_p = {}
    new_m = {}
    for k in params:
        g = grads[k] * scale
        m = mu * moms[k] + g
        new_m[k] = m
        new_p[k] = params[k] - lr * m
    return new_p, new_m, loss
