"""L1 Pallas kernel: the SC datapath hot-spot as a fused tiled matmul.

One kernel implements what the silicon does with a multiplier array, a
bitonic sorting network and a selective interconnect (paper Fig 3/6):

    acc   = x_cols @ w              (ternary products + BSN accumulate)
    real  = acc*alpha_acc + r*alpha_res   (high-precision residual fuse)
    out_q = SI(real)                (BN-ReLU of Eq 1 + re-quantize)

Hardware adaptation (DESIGN.md §Hardware-Adaptation): the ASIC tiles by
output pixel; on TPU we tile for VMEM/MXU instead — the grid walks
``bm``-row blocks of the im2col matrix while the (small) weight tile
stays resident, expressing the HBM↔VMEM schedule with BlockSpecs. The
kernel runs with ``interpret=True`` (the CPU PJRT plugin cannot execute
Mosaic custom-calls); on a real TPU the same BlockSpecs map the inner
matmul onto the MXU.

VMEM budget at the default ``bm=128`` with K=576, O=64 (the largest
scnet layer): x tile 128·576·4 B = 288 KiB, w 576·64·4 B = 144 KiB,
out 32 KiB — comfortably under the ~16 MiB VMEM of a TPU core, with
headroom for double buffering. MXU utilization estimate: the inner
``128×576 @ 576×64`` matmul maps to 128×128 systolic passes at ≥50%
occupancy for O=64 (full for O=128).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import ref

# Default row-block size (output pixels per grid step).
DEFAULT_BM = 128


def _kernel(x_ref, w_ref, g_ref, b_ref, r_ref, s_ref, o_ref):
    """Fused block: matmul + residual + BN-ReLU + re-quantize.

    ``s_ref`` packs the four scalars
    ``[alpha_acc, alpha_res, alpha_out, out_half]`` as a (4,) vector
    (scalar-prefetch is TPU-specific; a tiny VMEM vector is portable
    across interpret/compile modes).
    """
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    alpha_acc = s_ref[0]
    alpha_res = s_ref[1]
    alpha_out = s_ref[2]
    out_half = s_ref[3]
    real = acc * alpha_acc + r_ref[...] * alpha_res
    gamma = g_ref[...][None, :]
    beta = b_ref[...][None, :]
    y = jnp.where(real >= beta, gamma * (real - beta), 0.0)
    o_ref[...] = jnp.clip(jnp.round(y / alpha_out), -out_half, out_half)


@functools.partial(jax.jit, static_argnames=("bm",))
def sc_qmatmul(
    x,
    w,
    gamma,
    beta,
    residual,
    alpha_acc,
    alpha_res,
    alpha_out,
    out_half,
    bm: int = DEFAULT_BM,
):
    """Pallas SC block matmul; semantics of :func:`ref.sc_qmatmul_ref`.

    Args:
      x: ``[P, K]`` quantized activations (integer-valued f32).
      w: ``[K, O]`` ternary weights.
      gamma, beta: ``[O]`` Eq-1 BN parameters.
      residual: ``[P, O]`` residual codes (zeros when unused).
      alpha_acc, alpha_res, alpha_out, out_half: scalars (traced).
      bm: static row-block size.

    Returns:
      ``[P, O]`` integer-valued quantized outputs (f32).
    """
    p, k = x.shape
    k2, o = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    # Pad rows to a multiple of bm (P = OH·OW is rarely aligned).
    bm = min(bm, max(p, 1))
    pad = (-p) % bm
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
        residual = jnp.pad(residual, ((0, pad), (0, 0)))
    pp = x.shape[0]
    scalars = jnp.stack(
        [
            jnp.asarray(alpha_acc, jnp.float32),
            jnp.asarray(alpha_res, jnp.float32),
            jnp.asarray(alpha_out, jnp.float32),
            jnp.asarray(out_half, jnp.float32),
        ]
    )
    grid = (pp // bm,)
    out = pl.pallas_call(
        _kernel,
        out_shape=jax.ShapeDtypeStruct((pp, o), jnp.float32),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, k), lambda i: (i, 0)),
            pl.BlockSpec((k, o), lambda i: (0, 0)),
            pl.BlockSpec((o,), lambda i: (0,)),
            pl.BlockSpec((o,), lambda i: (0,)),
            pl.BlockSpec((bm, o), lambda i: (i, 0)),
            pl.BlockSpec((4,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bm, o), lambda i: (i, 0)),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, w, gamma, beta, residual, scalars)
    return out[:p]


def vmem_bytes(bm: int, k: int, o: int) -> int:
    """Static VMEM footprint estimate of one grid step (f32)."""
    return 4 * (bm * k + k * o + 2 * o + bm * o + 4 + bm * o)
