"""Pure-jnp oracle for the L1 Pallas kernel (the correctness signal).

``sc_qmatmul_ref`` is the reference semantics of the SC datapath's
hot-spot: a quantized matmul (im2col'd conv) fused with the paper's
BN-ReLU activation (Eq 1), high-precision residual accumulation
(§III.C) and thermometer re-quantization — everything the BSN + SI
implement in hardware, expressed at tensor level.

The Pallas kernel in ``sc_matmul.py`` must match this function exactly
(pytest + hypothesis sweep shapes and parameters).
"""

import jax.numpy as jnp


def fused_activation(acc, gamma, beta, alpha_out, out_half):
    """BN-ReLU (paper Eq 1) + thermometer re-quantization.

    ``acc`` is the real-valued accumulation; returns integer-valued
    quantized outputs in ``[-out_half, out_half]`` (stored as f32, as
    the datapath's codes are).
    """
    y = jnp.where(acc >= beta, gamma * (acc - beta), 0.0)
    q = jnp.clip(jnp.round(y / alpha_out), -out_half, out_half)
    return q


def sc_qmatmul_ref(
    x,
    w,
    gamma,
    beta,
    residual,
    alpha_acc,
    alpha_res,
    alpha_out,
    out_half,
):
    """Reference SC block matmul.

    Args:
      x: ``[P, K]`` quantized activations (integer-valued f32).
      w: ``[K, O]`` ternary weights (values in {-1, 0, 1}, f32).
      gamma, beta: ``[O]`` BN parameters (Eq 1).
      residual: ``[P, O]`` quantized residual codes (integer-valued
        f32) or zeros when the layer has no residual input.
      alpha_acc: scalar — scale of one accumulated product
        (``alpha_in * alpha_w``).
      alpha_res: scalar — scale of the residual codes.
      alpha_out: scalar — output quantization scale.
      out_half: scalar — output clip range (``BSL/2``).

    Returns:
      ``[P, O]`` integer-valued quantized outputs.
    """
    acc = x @ w  # exact integer accumulation (the BSN)
    real = acc * alpha_acc + residual * alpha_res
    return fused_activation(real, gamma[None, :], beta[None, :], alpha_out, out_half)


def im2col_ref(x, k, stride, pad):
    """im2col for CHW input: returns ``[OH*OW, C*K*K]`` patches.

    Column ordering matches the Rust substrate (`nn/layers.rs`):
    channel-major, then kernel row, then kernel column.
    """
    c, h, w = x.shape
    xp = jnp.pad(x, ((0, 0), (pad, pad), (pad, pad)))
    oh = (h + 2 * pad - k) // stride + 1
    ow = (w + 2 * pad - k) // stride + 1
    cols = []
    for ky in range(k):
        for kx in range(k):
            patch = xp[:, ky : ky + oh * stride : stride, kx : kx + ow * stride : stride]
            cols.append(patch.reshape(c, oh * ow))
    # [k*k, c, P] -> [P, c, k*k]: channel-major then (ky, kx).
    stacked = jnp.stack(cols, axis=0)
    return jnp.transpose(stacked, (2, 1, 0)).reshape(oh * ow, c * k * k), oh, ow
