//! Fault-tolerance sweep (the Fig-5 scenario as a standalone tool).
//!
//! Trains the §II ternary CNN on SynthDigits via PJRT, freezes it into
//! the gate-level SC simulator and the binary baseline, and sweeps the
//! bit-error rate, printing accuracy-loss curves for both designs and
//! the average loss reduction (the paper reports ~70%).
//!
//! ```bash
//! cargo run --release --example fault_sweep [-- steps=400 images=100]
//! ```

use scnn::data::SynthDigits;
use scnn::fault::ber_sweep;
use scnn::nn::model::ModelCfg;
use scnn::nn::quant::QuantConfig;
use scnn::nn::sc_exec::Prepared;
use scnn::runtime::{trainer::Knobs, Runtime, Trainer};

fn arg(name: &str, default: usize) -> usize {
    std::env::args()
        .find_map(|a| a.strip_prefix(&format!("{name}=")).and_then(|s| s.parse().ok()))
        .unwrap_or(default)
}

fn main() -> scnn::Result<()> {
    let steps = arg("steps", 400);
    let images = arg("images", 100);
    let data = SynthDigits::new();
    let rt = Runtime::new("artifacts")?;
    let knobs = Knobs::quantized(2).with_res_bsl(None);
    let mut tr = Trainer::new(&rt, "tnn")?;
    println!("training tnn for {steps} steps...");
    tr.train_qat(&data, steps / 2, steps / 2, 0.1, knobs, |s, l| {
        if s % 100 == 0 {
            println!("  step {s:>4} loss {l:.3}");
        }
    })?;
    let soft = tr.accuracy(&data, 512, knobs, false)?;
    println!("soft accuracy {soft:.4}");

    let prep = Prepared::new(
        &ModelCfg::tnn(),
        &tr.to_model_params(),
        QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None },
    );
    let bers = [1e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2];
    let sweep = ber_sweep(&prep, &data, &bers, images, 2, 7);
    println!("\nSC-simulator soft accuracy {:.4}", sweep.soft_accuracy);
    println!("{:<10} {:>10} {:>10} {:>11} {:>11}", "BER", "SC acc", "bin acc", "SC loss", "bin loss");
    for p in &sweep.points {
        println!(
            "{:<10.0e} {:>10.4} {:>10.4} {:>11.4} {:>11.4}",
            p.ber, p.acc_sc, p.acc_binary, p.loss_sc, p.loss_binary
        );
    }
    println!(
        "\naverage accuracy-loss reduction (SC vs binary): {:.0}%  (paper: ~70%)",
        sweep.avg_loss_reduction() * 100.0
    );
    println!("fault_sweep OK");
    Ok(())
}
