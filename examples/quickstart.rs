//! Quickstart: the paper's datapath on one dot-product, then a whole
//! frozen network through the batched serving engine.
//!
//! Steps 1–4 walk one accumulation through the circuit blocks: encode
//! ternary activations/weights as thermometer codes, multiply with the
//! 5-gate cell (Fig 3a), accumulate through a gate-level bitonic
//! sorting network (Fig 3b), and apply a BN-fused ReLU via the
//! selective interconnect — checked against plain integer arithmetic.
//! Step 5 then runs the same mathematics at model scale on the serving
//! core: a frozen network forwarded batch-at-a-time by `nn::ScEngine`
//! (packed ternary GEMM panels + sharded engine threads), bit-identical
//! to the circuit-faithful `ScExecutor`.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use scnn::circuits::multiplier::TernaryMultiplier;
use scnn::circuits::si::{ActivationFn, SelectiveInterconnect};
use scnn::circuits::Bsn;
use scnn::coding::{Ternary, ThermCode};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::QuantConfig;
use scnn::nn::sc_exec::{Prepared, ScExecutor};
use scnn::nn::{ScEngine, Tensor};
use scnn::util::Rng;

fn main() {
    // A toy 8-wide accumulation: activations and ternary weights.
    let acts: [i64; 8] = [1, -1, 0, 1, 1, -1, 1, 0];
    let weights = [
        Ternary::Pos,
        Ternary::Neg,
        Ternary::Pos,
        Ternary::Pos,
        Ternary::Neg,
        Ternary::Neg,
        Ternary::Pos,
        Ternary::Zero,
    ];

    println!("== 1. encode (thermometer, BSL 2 — Table II) ==");
    let codes: Vec<ThermCode> = acts.iter().map(|&a| ThermCode::encode(a, 2)).collect();
    for (a, c) in acts.iter().zip(&codes) {
        println!("  {a:>2}  ->  {c}");
    }

    println!("\n== 2. multiply (5-gate ternary cells, Fig 3a) ==");
    let products: Vec<ThermCode> = codes
        .iter()
        .zip(&weights)
        .map(|(c, &w)| TernaryMultiplier::mult_therm(c, w))
        .collect();
    for ((c, w), p) in codes.iter().zip(&weights).zip(&products) {
        println!("  {c} x {w:>5?} = {p}  (q={})", p.decode());
    }

    println!("\n== 3. accumulate (gate-level bitonic sorting network, Fig 3b) ==");
    let bsn = Bsn::new(16);
    let concat = Bsn::concat(&products);
    let sorted = bsn.sort_gate_level(&concat);
    println!("  concat: {concat}");
    println!("  sorted: {sorted}");
    let acc = ThermCode::from_bits(sorted.clone());
    let expect: i64 = acts.iter().zip(&weights).map(|(&a, w)| a * w.to_i64()).sum();
    println!("  accumulated q = {} (integer check: {expect})", acc.decode());
    assert_eq!(acc.decode(), expect);

    println!("\n== 4. activate (BN-fused ReLU via selective interconnect, Eq 1) ==");
    let act = ActivationFn::BnRelu { gamma: 1.0, beta: 1.0, ratio: 1.0 };
    let si = SelectiveInterconnect::for_activation(&act, 16, 8);
    let out = si.apply_bits(&sorted);
    let out_code = ThermCode::from_bits(out);
    println!(
        "  SI taps {:?}",
        si.taps().iter().take(4).collect::<Vec<_>>()
    );
    println!("  output code: {out_code} -> q = {}", out_code.decode());
    let ideal = if expect as f64 >= 1.0 { expect - 1 } else { 0 };
    assert_eq!(out_code.decode(), ideal.clamp(-4, 4));

    println!("\n== 5. serve a frozen network (batched ScEngine, ternary GEMM + threads) ==");
    // Freeze a small ternary CNN at the paper's W2-A2 quant point and
    // forward a batch through the serving engine: zero-skipping packed
    // weight panels, count-table activations, batch rows sharded over
    // two scoped threads. Bit-identical to the circuit-faithful
    // per-image executor.
    let cfg = ModelCfg::tnn();
    let (ic, ih, iw) = cfg.input;
    let mut rng = Rng::new(42);
    let params = ModelParams::init(&cfg, &mut rng);
    let quant = QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None };
    let prep = std::sync::Arc::new(Prepared::new(&cfg, &params, quant));
    let mut engine = ScEngine::with_threads(prep.clone(), 2);
    let batch = 4usize;
    let il = engine.image_len();
    let cl = engine.classes();
    let images: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32).collect();
    let mut logits = vec![0i64; batch * cl];
    let t0 = std::time::Instant::now();
    engine.forward_batch_into(&images, &mut logits);
    let dt = t0.elapsed();
    let exec = ScExecutor::new(prep);
    for b in 0..batch {
        let img = Tensor::from_vec(&[ic, ih, iw], images[b * il..(b + 1) * il].to_vec());
        assert_eq!(
            &logits[b * cl..(b + 1) * cl],
            exec.forward(&img).as_slice(),
            "engine logits must be bit-identical to the executor (image {b})"
        );
        let pred = (0..cl).max_by_key(|&c| logits[b * cl + c]).unwrap();
        println!("  image {b}: class {pred}  logits[..4] {:?}", &logits[b * cl..b * cl + 4]);
    }
    println!(
        "  {batch} images in {:.2?} on {} engine threads — bit-identical to ScExecutor",
        dt,
        engine.threads()
    );

    println!("\n== 6. hardware cost (28-nm calibrated model) ==");
    let cost = bsn.cost();
    println!(
        "  16-bit BSN: {} comparators, {:.2} um2, {:.3} ns, ADP {:.2} um2*ns",
        bsn.comparator_count(),
        cost.area_um2,
        cost.delay_ns,
        cost.adp()
    );
    let big = Bsn::new(9216);
    let bc = big.cost();
    println!(
        "  3x3x512-conv BSN (9216b): {:.3e} um2, {:.2} ns  (Table V baseline)",
        bc.area_um2, bc.delay_ns
    );

    println!("\nquickstart OK");
}
