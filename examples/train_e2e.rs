//! End-to-end driver: proves all three layers compose.
//!
//! 1. Rust loads the AOT-exported `train_step` HLO (L2 JAX model with
//!    the L1 Pallas kernel inside) and trains the SC-friendly network
//!    on SynthCIFAR for several hundred steps via PJRT, logging the
//!    loss curve — Python never runs.
//! 2. The trained parameters are evaluated on the serving path (integer
//!    codes through the Pallas kernel), and
//! 3. frozen into the **bit-exact SC circuit simulator** (gate-level
//!    multipliers/BSN/SI semantics) and the binary baseline executor,
//!    whose fault-free logits must agree exactly.
//!
//! ```bash
//! cargo run --release --example train_e2e [-- steps=300]
//! ```
//!
//! Results are recorded in EXPERIMENTS.md §End-to-end.

use scnn::data::{Dataset, Split, SynthCifar};
use scnn::nn::binary_exec::BinaryExecutor;
use scnn::nn::model::ModelCfg;
use scnn::nn::quant::QuantConfig;
use scnn::nn::sc_exec::{Prepared, ScExecutor};
use scnn::runtime::{trainer::Knobs, Runtime, Trainer};

fn main() -> scnn::Result<()> {
    let steps: usize = std::env::args()
        .find_map(|a| a.strip_prefix("steps=").and_then(|s| s.parse().ok()))
        .unwrap_or(300);
    let data = SynthCifar::new(10);
    let rt = Runtime::new("artifacts")?;
    println!("PJRT platform: {}", rt.platform());

    let knobs = Knobs::quantized(2).with_res_bsl(Some(16)); // W2-A2-R16
    let mut tr = Trainer::new(&rt, "scnet10")?;
    println!(
        "training scnet10 (W2-A2-R16): {} params, batch {}, {steps} steps",
        tr.meta().total_elems(),
        tr.meta().batch
    );
    let t0 = std::time::Instant::now();
    // Two-phase QAT: float warm-up, activation-scale calibration, then
    // quantized fine-tuning (see Trainer::train_qat).
    let losses = tr.train_qat(&data, steps / 2, steps / 2, 0.05, knobs, |s, loss| {
        if s % 25 == 0 {
            println!("  step {s:>5}  loss {loss:.4}");
        }
    })?;
    let dt = t0.elapsed().as_secs_f64();
    println!(
        "loss {:.4} -> {:.4} in {dt:.1}s ({:.1} steps/s)",
        losses.first().unwrap(),
        losses.last().unwrap(),
        steps as f64 / dt
    );
    assert!(
        losses.last().unwrap() < losses.first().unwrap(),
        "training must reduce the loss"
    );

    // Serving path (Pallas kernel) vs fake-quant path.
    let acc_fake = tr.accuracy(&data, 512, knobs, false)?;
    let acc_serving = tr.accuracy(&data, 512, knobs, true)?;
    println!("test accuracy: fake-quant {acc_fake:.4}, serving/Pallas {acc_serving:.4}");

    // Freeze into the hardware simulators.
    let params = tr.to_model_params();
    let cfg = ModelCfg::scnet(10);
    let prep = Prepared::new(&cfg, &params, QuantConfig::w2a2r16());
    let sc = ScExecutor::new(prep.clone());
    let bin = BinaryExecutor::new(prep);
    let (images, labels) = data.batch(Split::Test, 0, 128);
    let t1 = std::time::Instant::now();
    let acc_sc = sc.accuracy(&images, &labels);
    let sim_dt = t1.elapsed().as_secs_f64();
    let acc_bin = bin.accuracy(&images, &labels);
    println!(
        "bit-exact SC simulator accuracy {acc_sc:.4} ({:.1} img/s); binary executor {acc_bin:.4}",
        128.0 / sim_dt
    );
    // Fault-free, the SC bitstream machinery and the binary integer
    // datapath compute the same network.
    for i in 0..16 {
        assert_eq!(
            sc.forward(&images[i]),
            bin.forward(&images[i]),
            "SC and binary executors must agree fault-free (image {i})"
        );
    }
    println!("SC == binary on 16/16 spot-checked images");
    println!("train_e2e OK");
    Ok(())
}
