//! Serving demo: the L3 coordinator pool batching concurrent requests
//! into any registered backend — `sc` (the native bit-exact SC engine,
//! no artifacts needed), `pjrt` (integer codes through the Pallas
//! kernel when AOT artifacts are present), `binary` (fixed-point
//! baseline), `synthetic` (fixed-latency toy), or `auto`.
//!
//! Spawns an optional warm-up training run (PJRT only), starts an
//! `N`-worker pool, fires requests from several client threads, and
//! reports throughput, latency percentiles, batch occupancy and the
//! per-worker breakdown.
//!
//! ```bash
//! cargo run --release --example serve [-- backend=sc requests=2048 clients=8 workers=4 threads=2]
//! cargo run --release --example serve -- net=1   # same demo over a loopback TCP socket
//! ```
//!
//! With `net=1` the pool sits behind the `coordinator::net` TCP
//! front-end on `127.0.0.1:0`: clients speak the length-prefixed
//! binary protocol over real sockets, and the demo finishes with a
//! Prometheus metrics scrape.

use std::sync::Arc;

use scnn::coordinator::{
    Backend, Coordinator, ModelRegistry, NetClient, NetServer, ServeConfig, TenantPolicy,
};
use scnn::data::{Dataset, Split, SynthCifar};
use scnn::runtime::{artifacts_ready, trainer::Knobs, Runtime, Trainer};

fn arg(name: &str, default: usize) -> usize {
    std::env::args()
        .find_map(|a| a.strip_prefix(&format!("{name}=")).and_then(|s| s.parse().ok()))
        .unwrap_or(default)
}

/// The `net=1` variant: same pool, reached over a loopback socket.
fn net_demo(
    backend: Backend,
    clients: usize,
    requests: usize,
    cfg: ServeConfig,
) -> scnn::Result<()> {
    let resolved = backend.resolve("artifacts", "scnet10");
    let registry = Arc::new(ModelRegistry::new(TenantPolicy::default()));
    let _ = registry.register_backend(resolved, cfg)?;
    let server = NetServer::bind("127.0.0.1:0", registry.clone())?;
    let addr = server.local_addr();
    println!("net front-end up on {addr} (backend {resolved})");
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for t in 0..clients {
        let n = requests / clients;
        handles.push(std::thread::spawn(move || -> scnn::Result<usize> {
            let mut client = NetClient::connect(addr)?.with_tenant(&format!("client-{t}"));
            let data = SynthCifar::new(10);
            let mut hits = 0;
            for i in 0..n {
                let (x, y) = data.sample(Split::Test, t * 1_000_000 + i);
                if client.classify("scnet10", &x.into_vec())? == y {
                    hits += 1;
                }
            }
            Ok(hits)
        }));
    }
    let mut hits = 0;
    for h in handles {
        hits += h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let served = (requests / clients) * clients;
    println!(
        "served {served} requests over TCP in {wall:.2}s -> {:.0} req/s (accuracy {:.3})",
        served as f64 / wall,
        hits as f64 / served as f64
    );
    let scrape = NetClient::connect(addr)?.metrics_text()?;
    let latency_lines: Vec<&str> =
        scrape.lines().filter(|l| l.starts_with("scnn_request_latency_quantile")).collect();
    println!("metrics scrape ({} lines):\n{}", scrape.lines().count(), latency_lines.join("\n"));
    server.shutdown();
    for (name, m) in registry.shutdown_all() {
        println!(
            "{name}: batches {}  occupancy {:.2}  p50 {:?}  p99 {:?}",
            m.batches, m.occupancy, m.p50, m.p99
        );
    }
    println!("serve OK");
    Ok(())
}

fn main() -> scnn::Result<()> {
    let clients = arg("clients", 8).max(1);
    let requests = arg("requests", 2048).max(clients);
    let workers = arg("workers", 4).max(1);
    // Intra-engine threads of the sc backend (each worker shards its
    // batch rows across this many scoped threads; bit-identical logits).
    let threads = arg("threads", 1).max(1);
    let warmup_steps = arg("warmup", 100);
    let backend = Backend::parse(
        &std::env::args()
            .find_map(|a| a.strip_prefix("backend=").map(str::to_string))
            .unwrap_or_else(|| "auto".into()),
    )?;
    let data = SynthCifar::new(10);
    let knobs = Knobs::quantized(2).with_res_bsl(Some(16));

    let mut cfg = ServeConfig::new("artifacts", "scnet10");
    cfg.knobs = knobs;
    cfg.workers = workers;
    cfg.threads = threads;
    if arg("net", 0) == 1 {
        return net_demo(backend, clients, requests, cfg);
    }
    let resolved = backend.resolve("artifacts", "scnet10");
    println!("backend: {resolved} (pass backend=sc for the native SC engine)");
    if resolved == Backend::Pjrt && artifacts_ready("artifacts", "scnet10") && warmup_steps > 0 {
        // Real serving path; warm-up training so the model is non-trivial.
        println!("warm-up: training {warmup_steps} steps...");
        let rt = Runtime::new("artifacts")?;
        let mut tr = Trainer::new(&rt, "scnet10")?;
        tr.train_qat(&data, warmup_steps / 2, warmup_steps / 2, 0.05, knobs, |_, _| {})?;
        cfg.params = Some(tr.params().to_vec());
    }
    let coord = Coordinator::start_backend(resolved, cfg)?;

    println!(
        "coordinator up; {} workers, {clients} clients x {} reqs",
        coord.workers(),
        requests / clients
    );
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for t in 0..clients {
        let client = coord.client();
        let n = requests / clients;
        handles.push(std::thread::spawn(move || -> scnn::Result<usize> {
            let data = SynthCifar::new(10);
            let mut hits = 0;
            for i in 0..n {
                let (x, y) = data.sample(Split::Test, t * 1_000_000 + i);
                if client.classify(x.into_vec())? == y {
                    hits += 1;
                }
            }
            Ok(hits)
        }));
    }
    let mut hits = 0;
    for h in handles {
        hits += h.join().unwrap()?;
    }
    let wall = t0.elapsed().as_secs_f64();
    let m = coord.shutdown();
    let served = (requests / clients) * clients;
    println!(
        "served {served} requests in {wall:.2}s -> {:.0} req/s (accuracy {:.3})",
        served as f64 / wall,
        hits as f64 / served as f64
    );
    println!(
        "batches {}  occupancy {:.2}  latency p50 {:?}  p99 {:?}  mean {:?}  peak in-flight {}",
        m.batches, m.occupancy, m.p50, m.p99, m.mean, m.inflight_peak
    );
    for w in &m.per_worker {
        println!("  worker {}: {} requests in {} batches", w.worker, w.requests, w.batches);
    }
    println!("serve OK");
    Ok(())
}
