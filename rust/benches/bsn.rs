//! BSN benchmarks — regenerates the Table V / Fig 9 performance axis
//! and measures the simulator's own throughput (§Perf L3 target:
//! ≥ 10^7 sorted bits/s gate-level).

use scnn::accel;
use scnn::circuits::Bsn;
use scnn::coding::BitVec;
use scnn::util::bench::Bench;
use scnn::util::Rng;

fn main() {
    let b = Bench::default();
    println!("== BSN gate-level sort throughput ==");
    let mut rng = Rng::new(1);
    for width in [256usize, 1024, 4608, 9216] {
        let bsn = Bsn::new(width);
        let mut bits = BitVec::zeros(width);
        for i in 0..width {
            bits.set(i, rng.gen_bool(0.5));
        }
        b.run(&format!("bsn/gate_sort/{width}"), width as u64, || {
            bsn.sort_gate_level(&bits)
        });
    }

    println!("\n== functional accumulate (count domain) ==");
    for width in [4608usize, 9216] {
        let counts: Vec<usize> = (0..width / 64).map(|i| (i * 7) % 64).collect();
        b.run(&format!("bsn/functional/{width}"), width as u64, || {
            counts.iter().sum::<usize>()
        });
    }

    println!("\n== approximate designs (Table V workloads) ==");
    for width in [2304usize, 4608, 9216] {
        let spatial = accel::design_spatial(width, 16);
        let m0 = spatial.stages()[0].m;
        let l0 = spatial.stages()[0].l;
        let counts: Vec<usize> = (0..m0).map(|i| (i * 13) % (l0 + 1)).collect();
        b.run(&format!("approx/spatial_counts/{width}"), m0 as u64, || {
            spatial.eval_counts(&counts)
        });
        let mut rng2 = Rng::new(7);
        b.run(&format!("approx/spatial_mse100/{width}"), 100, || {
            spatial.mse(0.5, 100, &mut rng2)
        });
    }

    println!("\n== cost model (used inside search loops) ==");
    for width in [4608usize, 9216] {
        b.run(&format!("cost/bsn_gate_count/{width}"), 1, || {
            Bsn::new(width).gate_count()
        });
    }

    println!("\n== fault-injected sort ==");
    let bsn = Bsn::new(1024);
    let mut bits = BitVec::zeros(1024);
    for i in 0..1024 {
        bits.set(i, rng.gen_bool(0.5));
    }
    let mut frng = Rng::new(3);
    b.run("bsn/faulty_sort/1024@1e-3", 1024, || {
        bsn.sort_with_faults(&bits, 1e-3, &mut frng)
    });
}
