//! BSN benchmarks — regenerates the Table V / Fig 9 performance axis
//! and measures the simulator's own throughput (§Perf L3 target:
//! ≥ 10^7 sorted bits/s gate-level; the packed u64 datapath clears it
//! by orders of magnitude).
//!
//! With `BENCH_JSON=<path>` (what `make bench-json` sets) sorter
//! throughput is also written as machine-readable JSON — in Mbit/s per
//! width, plus a scalar-vs-SIMD series for the raw packed-word kernels
//! (`bitvec/*`) — so sorter-level wins are tracked separately from the
//! end-to-end serving wins in `BENCH_sc.json`. `BENCH_QUICK=1` runs a
//! reduced configuration for CI.

use scnn::accel;
use scnn::circuits::Bsn;
use scnn::coding::BitVec;
use scnn::util::bench::{Bench, JsonReport};
use scnn::util::simd::Dispatch;
use scnn::util::Rng;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v != "0")
}

/// The raw packed-word kernels behind every `BitVec` bulk op, scalar
/// arm vs the dispatched table, in Mbit/s over a buffer the size of a
/// big BSN stream. Fixed `_scalar`/`_simd` entry names keep the JSON
/// series machine-comparable; equality of results is asserted inline.
fn bitvec_kernels(report: &mut JsonReport, b: &Bench, rng: &mut Rng) {
    let level = Dispatch::active().level().name();
    let sc = Dispatch::scalar();
    let act = Dispatch::active();
    println!("\n== BitVec word kernels scalar vs SIMD (dispatched level: {level}) ==");
    let words = if quick() { 1usize << 10 } else { 1 << 14 };
    let bits = (words * 64) as u64;
    let a: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let c: Vec<u64> = (0..words).map(|_| rng.next_u64()).collect();
    let mut dst = vec![0u64; words];
    assert_eq!(act.popcount(&a), sc.popcount(&a));
    assert_eq!(act.count_and(&a, &c), sc.count_and(&a, &c));
    for (arm, d) in [("scalar", sc), ("simd", act)] {
        let mp = b.run(&format!("bsn/bitvec/popcount_{arm}"), bits, || d.popcount(&a));
        let mc = b.run(&format!("bsn/bitvec/count_and_{arm}"), bits, || d.count_and(&a, &c));
        let ma = b.run(&format!("bsn/bitvec/and_{arm}"), bits, || {
            dst.copy_from_slice(&a);
            d.and_words(&mut dst, &c);
            dst[0]
        });
        let mf = b.run(&format!("bsn/bitvec/funnel_shr_{arm}"), bits, || {
            d.funnel_shr(&a, 17, &mut dst);
            dst[0]
        });
        let measures = [("popcount", mp), ("count_and", mc), ("and", ma), ("funnel_shr", mf)];
        for (kernel, m) in measures {
            report.add_scalar(
                &format!("bitvec/{kernel}_{arm}/throughput"),
                bits as f64 / m.median_s.max(1e-12) / 1e6,
                "Mbit/s",
            );
        }
    }
    act.funnel_shr(&a, 17, &mut dst);
    let mut want = vec![0u64; words];
    sc.funnel_shr(&a, 17, &mut want);
    assert_eq!(dst, want, "funnel_shr arms diverged");
}

fn main() {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    let mut report = JsonReport::new("bsn");
    println!("== BSN gate-level sort throughput (packed u64 datapath) ==");
    let mut rng = Rng::new(1);
    let widths: &[usize] =
        if quick() { &[256, 1024] } else { &[256, 1024, 4608, 9216] };
    let mut scratch: Vec<u64> = Vec::new();
    let mut sorted = BitVec::zeros(0);
    for &width in widths {
        let bsn = Bsn::new(width);
        let mut bits = BitVec::zeros(width);
        for i in 0..width {
            bits.set(i, rng.gen_bool(0.5));
        }
        let m = b.run(&format!("bsn/gate_sort/{width}"), width as u64, || {
            bsn.sort_gate_level_into(&bits, &mut scratch, &mut sorted)
        });
        report.add(&format!("gate_sort/{width}"), &m, width as u64);
        report.add_scalar(
            &format!("gate_sort/{width}/throughput"),
            width as f64 / m.median_s.max(1e-12) / 1e6,
            "Mbit/s",
        );
    }

    bitvec_kernels(&mut report, &b, &mut rng);

    println!("\n== functional accumulate (count domain) ==");
    for width in [4608usize, 9216] {
        let counts: Vec<usize> = (0..width / 64).map(|i| (i * 7) % 64).collect();
        let m = b.run(&format!("bsn/functional/{width}"), width as u64, || {
            counts.iter().sum::<usize>()
        });
        report.add(&format!("functional/{width}"), &m, width as u64);
    }

    if !quick() {
        println!("\n== approximate designs (Table V workloads) ==");
        for width in [2304usize, 4608, 9216] {
            let spatial = accel::design_spatial(width, 16);
            let m0 = spatial.stages()[0].m;
            let l0 = spatial.stages()[0].l;
            let counts: Vec<usize> = (0..m0).map(|i| (i * 13) % (l0 + 1)).collect();
            b.run(&format!("approx/spatial_counts/{width}"), m0 as u64, || {
                spatial.eval_counts(&counts)
            });
            let mut rng2 = Rng::new(7);
            b.run(&format!("approx/spatial_mse100/{width}"), 100, || {
                spatial.mse(0.5, 100, &mut rng2)
            });
        }

        println!("\n== cost model (used inside search loops) ==");
        for width in [4608usize, 9216] {
            b.run(&format!("cost/bsn_gate_count/{width}"), 1, || {
                Bsn::new(width).gate_count()
            });
        }
    }

    println!("\n== fault-injected sort (scalar path, reused scratch) ==");
    let bsn = Bsn::new(1024);
    let mut bits = BitVec::zeros(1024);
    for i in 0..1024 {
        bits.set(i, rng.gen_bool(0.5));
    }
    let mut frng = Rng::new(3);
    let mut lanes: Vec<bool> = Vec::new();
    let m = b.run("bsn/faulty_sort/1024@1e-3", 1024, || {
        bsn.sort_with_faults_into(&bits, 1e-3, &mut frng, &mut lanes, &mut sorted)
    });
    report.add("faulty_sort/1024@1e-3", &m, 1024);
    report.add_scalar(
        "faulty_sort/1024@1e-3/throughput",
        1024.0 / m.median_s.max(1e-12) / 1e6,
        "Mbit/s",
    );

    if let Ok(path) = std::env::var("BENCH_JSON") {
        report.write(&path).expect("write BENCH_JSON");
        println!("\nwrote {} entries to {path}", report.len());
    }
}
