//! Conv-datapath benchmarks: the Table IV / Fig 2 cost units, the SI
//! synthesis cost (a per-layer setup operation in the executors), and
//! the fault layer's serving overhead — the packed engine forwarding
//! clean vs under injected BER vs with the integrity guard armed.
//!
//! With `BENCH_JSON=<path>` (what `make bench-json` sets) the results
//! are written as machine-readable JSON (`BENCH_datapath.json` in CI),
//! so the faulted-vs-clean throughput ratio is tracked across PRs.
//! `BENCH_QUICK=1` selects the short CI configuration.

use std::sync::Arc;

use scnn::circuits::si::{ActivationFn, SelectiveInterconnect};
use scnn::circuits::{BsnKind, ConvDatapath, DatapathConfig};
use scnn::coding::Ternary;
use scnn::fault::guard::{DatapathGuard, GuardCounters};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::{Pruning, QuantConfig};
use scnn::nn::sc_exec::{FaultCfg, Prepared};
use scnn::nn::ScEngine;
use scnn::util::bench::{Bench, JsonReport};
use scnn::util::Rng;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v != "0")
}

fn datapath_eval(report: &mut JsonReport, b: &Bench, rng: &mut Rng) {
    println!("== datapath functional eval (one output pixel) ==");
    for (label, acc_width, act_bsl) in
        [("2-2", 576usize, 2usize), ("2-4", 576, 4), ("2-2-wide", 4608, 2)]
    {
        let dp = ConvDatapath::new(DatapathConfig {
            acc_width,
            act_bsl,
            residual_bsl: None,
            out_bsl: 16,
            bsn: BsnKind::Exact,
            activation: ActivationFn::Relu { ratio: 0.1 },
        });
        let half = (act_bsl / 2) as i64;
        let acts: Vec<i64> = (0..acc_width).map(|_| rng.gen_range_i64(-half, half)).collect();
        let ws: Vec<Ternary> =
            (0..acc_width).map(|_| Ternary::from_i64(rng.gen_range_i64(-1, 1))).collect();
        let m = b.run(&format!("datapath/eval/{label}"), acc_width as u64, || {
            dp.eval(&acts, &ws, None)
        });
        report.add(&format!("datapath/eval/{label}"), &m, acc_width as u64);
    }

    println!("\n== datapath cost roll-up (used by fig2/tab4 sweeps) ==");
    for act_bsl in [2usize, 4, 8, 16] {
        let m = b.run(&format!("datapath/cost/a{act_bsl}"), 1, || {
            ConvDatapath::new(DatapathConfig {
                acc_width: 4608,
                act_bsl,
                residual_bsl: None,
                out_bsl: 16,
                bsn: BsnKind::Exact,
                activation: ActivationFn::Relu { ratio: 0.1 },
            })
            .cost()
        });
        report.add(&format!("datapath/cost/a{act_bsl}"), &m, 0);
    }
}

fn si_series(report: &mut JsonReport, b: &Bench) {
    println!("\n== SI synthesis (per-channel, per-layer setup) ==");
    for in_w in [1152usize, 9216] {
        let m = b.run(&format!("si/synthesize/{in_w}->16"), in_w as u64, || {
            SelectiveInterconnect::for_activation(
                &ActivationFn::BnRelu { gamma: 1.2, beta: 3.0, ratio: 0.05 },
                in_w,
                16,
            )
        });
        report.add(&format!("si/synthesize/{in_w}->16"), &m, in_w as u64);
    }

    println!("\n== SI apply ==");
    let si = SelectiveInterconnect::for_activation(
        &ActivationFn::Relu { ratio: 0.05 },
        9216,
        16,
    );
    let m = b.run("si/apply_count/9216", 1, || si.apply_count(5000));
    report.add("si/apply_count/9216", &m, 1);
}

/// The integrity layer's serving cost: one engine forwarding the same
/// image clean, under injected BER (count-domain mask folding), and
/// with the datapath guard verifying every GEMM row block.
fn fault_overhead(report: &mut JsonReport, b: &Bench, rng: &mut Rng) {
    println!("\n== engine forward: clean vs faulted vs guarded (tnn, BSL 2) ==");
    let cfg = ModelCfg::tnn();
    let params = ModelParams::init(&cfg, &mut Rng::new(11));
    let prep = Arc::new(Prepared::new(
        &cfg,
        &params,
        QuantConfig {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
    ));
    let (c, h, w) = prep.cfg.input;
    let image: Vec<f32> = (0..c * h * w).map(|_| rng.normal() as f32 * 0.5).collect();
    let mut engine = ScEngine::new(prep);
    let cl = engine.classes();
    let mut logits = vec![0i64; cl];

    let clean = b.run("engine/forward/clean", 1, || {
        engine.forward_into(&image, &mut logits);
        logits[0]
    });
    report.add("engine/forward/clean", &clean, 1);

    for ber in [1e-3f64, 1e-2] {
        engine.set_fault(Some(FaultCfg { ber, seed: 7 }));
        let name = format!("engine/forward/faulted_ber={ber:.0e}");
        let m = b.run(&name, 1, || {
            engine.forward_into(&image, &mut logits);
            logits[0]
        });
        report.add(&name, &m, 1);
        if m.median_s > 0.0 {
            report.add_scalar(
                &format!("engine/forward/clean_over_faulted_ber={ber:.0e}"),
                clean.median_s / m.median_s,
                "x",
            );
        }
    }
    engine.set_fault(None);

    engine.set_guard(Some(Arc::new(DatapathGuard::new(Arc::new(GuardCounters::default())))));
    let guarded = b.run("engine/forward/guarded", 1, || {
        engine.forward_into(&image, &mut logits);
        logits[0]
    });
    report.add("engine/forward/guarded", &guarded, 1);
    if guarded.median_s > 0.0 {
        report.add_scalar(
            "engine/forward/clean_over_guarded",
            clean.median_s / guarded.median_s,
            "x",
        );
    }
}

fn main() {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    let mut report = JsonReport::new("datapath");
    let mut rng = Rng::new(5);
    datapath_eval(&mut report, &b, &mut rng);
    si_series(&mut report, &b);
    fault_overhead(&mut report, &b, &mut rng);
    if let Ok(path) = std::env::var("BENCH_JSON") {
        report.write(&path).expect("write BENCH_JSON");
        println!("\nwrote {} entries to {path}", report.len());
    } else {
        println!("\n(set BENCH_JSON=BENCH_datapath.json or run `make bench-json` for JSON output)");
    }
}
