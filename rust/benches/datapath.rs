//! Conv-datapath benchmarks: the Table IV / Fig 2 cost units plus the
//! SI synthesis cost (a per-layer setup operation in the executors).

use scnn::circuits::si::{ActivationFn, SelectiveInterconnect};
use scnn::circuits::{BsnKind, ConvDatapath, DatapathConfig};
use scnn::coding::Ternary;
use scnn::util::bench::Bench;
use scnn::util::Rng;

fn main() {
    let b = Bench::default();
    println!("== datapath functional eval (one output pixel) ==");
    let mut rng = Rng::new(5);
    for (label, acc_width, act_bsl) in
        [("2-2", 576usize, 2usize), ("2-4", 576, 4), ("2-2-wide", 4608, 2)]
    {
        let dp = ConvDatapath::new(DatapathConfig {
            acc_width,
            act_bsl,
            residual_bsl: None,
            out_bsl: 16,
            bsn: BsnKind::Exact,
            activation: ActivationFn::Relu { ratio: 0.1 },
        });
        let half = (act_bsl / 2) as i64;
        let acts: Vec<i64> = (0..acc_width).map(|_| rng.gen_range_i64(-half, half)).collect();
        let ws: Vec<Ternary> =
            (0..acc_width).map(|_| Ternary::from_i64(rng.gen_range_i64(-1, 1))).collect();
        b.run(&format!("datapath/eval/{label}"), acc_width as u64, || {
            dp.eval(&acts, &ws, None)
        });
    }

    println!("\n== datapath cost roll-up (used by fig2/tab4 sweeps) ==");
    for act_bsl in [2usize, 4, 8, 16] {
        b.run(&format!("datapath/cost/a{act_bsl}"), 1, || {
            ConvDatapath::new(DatapathConfig {
                acc_width: 4608,
                act_bsl,
                residual_bsl: None,
                out_bsl: 16,
                bsn: BsnKind::Exact,
                activation: ActivationFn::Relu { ratio: 0.1 },
            })
            .cost()
        });
    }

    println!("\n== SI synthesis (per-channel, per-layer setup) ==");
    for in_w in [1152usize, 9216] {
        b.run(&format!("si/synthesize/{in_w}->16"), in_w as u64, || {
            SelectiveInterconnect::for_activation(
                &ActivationFn::BnRelu { gamma: 1.2, beta: 3.0, ratio: 0.05 },
                in_w,
                16,
            )
        });
    }

    println!("\n== SI apply ==");
    let si = SelectiveInterconnect::for_activation(
        &ActivationFn::Relu { ratio: 0.05 },
        9216,
        16,
    );
    b.run("si/apply_count/9216", 1, || si.apply_count(5000));
}
