//! Native SC serving benchmarks (§Perf): the batched `ScEngine` vs the
//! per-image `ScExecutor`, and a worker-scaling sweep of the pool on
//! the **real SC model** (backend `sc`) instead of the synthetic
//! stand-in.
//!
//! With `BENCH_JSON=<path>` (what `make bench-json` sets) the results
//! are also written as machine-readable JSON so the perf trajectory is
//! tracked across PRs:
//!
//! ```bash
//! BENCH_JSON=BENCH_sc.json cargo bench --bench sc_serve
//! ```
//!
//! `BENCH_QUICK=1` selects the small synthetic/SC configuration CI
//! uses to keep the artifact-producing run short (fewer measurement
//! iterations, pool sweep capped at 2 workers).

use std::time::Instant;

use scnn::coordinator::{Backend, Coordinator, ServeConfig};
use scnn::data::{Dataset, Split, SynthCifar, SynthDigits};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::QuantConfig;
use scnn::nn::sc_engine::ScEngine;
use scnn::nn::sc_exec::{Prepared, ScExecutor};
use scnn::util::bench::{Bench, JsonReport};
use scnn::util::Rng;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v != "0")
}

fn engine_vs_executor(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    println!("== engine vs executor (bit-identical logits, same frozen model) ==");
    for (label, cfg, quant, img) in [
        (
            "tnn",
            ModelCfg::tnn(),
            QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None },
            SynthDigits::new().sample(Split::Test, 0).0,
        ),
        (
            "scnet10",
            ModelCfg::scnet(10),
            QuantConfig::w2a2r16(),
            SynthCifar::new(10).sample(Split::Test, 0).0,
        ),
    ] {
        let mut rng = Rng::new(11);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = std::sync::Arc::new(Prepared::new(&cfg, &params, quant));
        let exec = ScExecutor::new(prep.clone());
        let mut engine = ScEngine::new(prep);
        assert_eq!(engine.forward(&img), exec.forward(&img), "{label}: engines disagree");
        let me = b.run(&format!("sc_serve/executor/{label}_forward"), 1, || exec.forward(&img));
        let mg = b.run(&format!("sc_serve/engine/{label}_forward"), 1, || engine.forward(&img));
        let speedup = me.median_s / mg.median_s.max(1e-12);
        println!("   -> engine speedup over executor: {speedup:.2}x");
        report.add(&format!("executor/{label}_forward"), &me, 1);
        report.add(&format!("engine/{label}_forward"), &mg, 1);
        report.add_scalar(&format!("engine/{label}_speedup"), speedup, "x");
    }
}

fn pool_sweep_sc(report: &mut JsonReport) {
    println!("\n== worker-scaling sweep (backend sc, tnn, real SC model) ==");
    let mut n1 = 0.0f64;
    let mut n4 = 0.0f64;
    let sweep: &[usize] = if quick() { &[1, 2] } else { &[1, 2, 4, 8] };
    for &workers in sweep {
        let mut cfg = ServeConfig::new("artifacts", "tnn");
        cfg.workers = workers;
        cfg.batch = 8;
        cfg.queue_depth = 64;
        let coord = Coordinator::start_backend(Backend::Sc, cfg).expect("start sc pool");
        let clients = 4 * workers;
        let per_client = if quick() { 16usize } else { 64usize };
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..clients {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                let data = SynthDigits::new();
                for i in 0..per_client {
                    let (x, _) = data.sample(Split::Test, t * 10_000 + i);
                    client.infer(x.into_vec()).expect("infer");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let reqs_per_s = (clients * per_client) as f64 / wall;
        let m = coord.shutdown();
        println!(
            "sc_serve/pool/workers={workers}  {reqs_per_s:>8.0} req/s  occupancy {:.2}  \
             p50 {:?}  p99 {:?}",
            m.occupancy, m.p50, m.p99
        );
        report.add_scalar(&format!("pool/sc/workers={workers}"), reqs_per_s, "req/s");
        if workers == 1 {
            n1 = reqs_per_s;
        }
        if workers == *sweep.last().unwrap() {
            n4 = reqs_per_s;
        }
    }
    let top = sweep.last().unwrap();
    let speedup = n4 / n1.max(1.0);
    println!(
        "sc_serve/pool/speedup  N={top} vs N=1: {speedup:.2}x  ({})",
        if speedup > 1.0 { "scales" } else { "DOES NOT SCALE" }
    );
    report.add_scalar(&format!("pool/sc/speedup_n{top}_vs_n1"), speedup, "x");
}

fn main() {
    let mut report = JsonReport::new("sc_serve");
    engine_vs_executor(&mut report);
    pool_sweep_sc(&mut report);
    if let Ok(path) = std::env::var("BENCH_JSON") {
        report.write(&path).expect("write BENCH_JSON");
        println!("\nwrote {} entries to {path}", report.len());
    } else {
        println!("\n(set BENCH_JSON=BENCH_sc.json or run `make bench-json` for JSON output)");
    }
}
