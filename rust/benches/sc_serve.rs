//! Native SC serving benchmarks (§Perf): the packed GEMM kernels vs
//! the naive triple loop, the sparse (compressed-column) kernel vs
//! dense across activation densities, the engine across the
//! structured-pruning knob, the batched `ScEngine` vs the per-image
//! `ScExecutor`, the engine's imgs/s at N threads, a worker-scaling
//! sweep of the pool on the **real SC model** (backend `sc`) instead
//! of the synthetic stand-in, and a chaos-degradation series (goodput
//! + p99 of the supervised pool under injected worker panics).
//!
//! With `BENCH_JSON=<path>` (what `make bench-json` sets) the results
//! are also written as machine-readable JSON so the perf trajectory is
//! tracked across PRs:
//!
//! ```bash
//! BENCH_JSON=BENCH_sc.json cargo bench --bench sc_serve
//! ```
//!
//! `BENCH_QUICK=1` selects the small synthetic/SC configuration CI
//! uses to keep the artifact-producing run short (fewer measurement
//! iterations, pool sweep capped at 2 workers).

use std::time::{Duration, Instant};

use scnn::coordinator::{
    chaos_factory, Backend, ChaosSwitch, Coordinator, ExecutorSpec, PoolConfig, ServeConfig,
    SyntheticExecutor,
};
use scnn::data::{Dataset, Split, SynthCifar, SynthDigits};
use scnn::nn::gemm::{gemm_naive, I8Panel, SparseCols, TernaryPanel};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::{Pruning, QuantConfig};
use scnn::nn::sc_engine::ScEngine;
use scnn::nn::sc_exec::{Prepared, ScExecutor};
use scnn::util::bench::{Bench, JsonReport};
use scnn::util::simd::Dispatch;
use scnn::util::Rng;

fn quick() -> bool {
    std::env::var("BENCH_QUICK").is_ok_and(|v| v != "0")
}

/// The packed kernels against the naive triple loop, on conv-shaped
/// problems (rows = cout, k = accumulation width, n = output pixels).
/// Work item = one multiply-accumulate of the naive loop, so items/s
/// is MACs/s and the speedup scalars are directly comparable.
fn gemm_vs_naive(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    println!("== packed GEMM vs naive triple loop (ternary conv shapes) ==");
    // (label, cout, acc width, npix): tnn layer 2, scnet res block 2,
    // and a ragged shape exercising the block/microkernel edges.
    for (label, rows, k, n) in
        [("tnn_l2", 16usize, 72usize, 49usize), ("scnet_rb2", 32, 288, 256), ("ragged", 13, 37, 19)]
    {
        let mut rng = Rng::new(0xBEC + rows as u64);
        let w: Vec<i8> = (0..rows * k).map(|_| rng.gen_range_i64(-1, 1) as i8).collect();
        let cols: Vec<i32> = (0..n * k).map(|_| rng.gen_range_i64(-8, 9) as i32).collect();
        let macs = (rows * k * n) as u64;
        let mut out = vec![0i64; rows * n];
        let mn = b.run(&format!("sc_serve/gemm/naive/{label}"), macs, || {
            gemm_naive(&w, rows, k, &cols, n, &mut out);
            out[0]
        });
        let expect = out.clone();
        let ternary = TernaryPanel::pack(&w, rows, k);
        let mt = b.run(&format!("sc_serve/gemm/ternary/{label}"), macs, || {
            ternary.gemm_into(&cols, n, &mut out);
            out[0]
        });
        assert_eq!(out, expect, "{label}: ternary kernel disagrees with naive");
        let dense = I8Panel::pack(&w, rows, k);
        let md = b.run(&format!("sc_serve/gemm/dense/{label}"), macs, || {
            dense.gemm_into(&cols, n, &mut out);
            out[0]
        });
        assert_eq!(out, expect, "{label}: dense kernel disagrees with naive");
        report.add(&format!("gemm/naive/{label}"), &mn, macs);
        report.add(&format!("gemm/ternary/{label}"), &mt, macs);
        report.add(&format!("gemm/dense/{label}"), &md, macs);
        let st = mn.median_s / mt.median_s.max(1e-12);
        let sd = mn.median_s / md.median_s.max(1e-12);
        println!("   -> {label}: ternary {st:.2}x, dense {sd:.2}x over naive");
        report.add_scalar(&format!("gemm/ternary/{label}_speedup"), st, "x");
        report.add_scalar(&format!("gemm/dense/{label}_speedup"), sd, "x");
    }
}

/// The same packed kernels with the SIMD arm pinned off vs the
/// dispatched table — the MACs/s step the `util::simd` microkernels
/// buy on this machine. Entry names are fixed (`_scalar` / `_simd`) so
/// the JSON series stays machine-comparable; the dispatched level is
/// printed alongside. Outputs are asserted identical, which is the
/// whole point of exact i64 counts.
fn gemm_simd_vs_scalar(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    let level = Dispatch::active().level().name();
    let sc = Dispatch::scalar();
    println!("\n== packed GEMM scalar vs SIMD (dispatched level: {level}) ==");
    for (label, rows, k, n) in
        [("tnn_l2", 16usize, 72usize, 49usize), ("scnet_rb2", 32, 288, 256), ("ragged", 13, 37, 19)]
    {
        let mut rng = Rng::new(0x51D + rows as u64);
        let w: Vec<i8> = (0..rows * k).map(|_| rng.gen_range_i64(-1, 1) as i8).collect();
        let cols: Vec<i32> = (0..n * k).map(|_| rng.gen_range_i64(-8, 9) as i32).collect();
        let macs = (rows * k * n) as u64;
        let ternary = TernaryPanel::pack(&w, rows, k);
        let dense = I8Panel::pack(&w, rows, k);
        let mut out = vec![0i64; rows * n];
        let mts = b.run(&format!("sc_serve/gemm/ternary_scalar/{label}"), macs, || {
            ternary.gemm_into_with(sc, &cols, n, &mut out);
            out[0]
        });
        let expect = out.clone();
        let mtv = b.run(&format!("sc_serve/gemm/ternary_simd/{label}"), macs, || {
            ternary.gemm_into(&cols, n, &mut out);
            out[0]
        });
        assert_eq!(out, expect, "{label}: SIMD ternary kernel diverged from scalar");
        let mds = b.run(&format!("sc_serve/gemm/dense_scalar/{label}"), macs, || {
            dense.gemm_into_with(sc, &cols, n, &mut out);
            out[0]
        });
        let expect = out.clone();
        let mdv = b.run(&format!("sc_serve/gemm/dense_simd/{label}"), macs, || {
            dense.gemm_into(&cols, n, &mut out);
            out[0]
        });
        assert_eq!(out, expect, "{label}: SIMD dense kernel diverged from scalar");
        report.add(&format!("gemm/ternary_scalar/{label}"), &mts, macs);
        report.add(&format!("gemm/ternary_simd/{label}"), &mtv, macs);
        report.add(&format!("gemm/dense_scalar/{label}"), &mds, macs);
        report.add(&format!("gemm/dense_simd/{label}"), &mdv, macs);
        let st = mts.median_s / mtv.median_s.max(1e-12);
        let sd = mds.median_s / mdv.median_s.max(1e-12);
        println!("   -> {label}: ternary {st:.2}x, dense {sd:.2}x ({level} over scalar)");
        report.add_scalar(&format!("gemm/simd/{label}_ternary_speedup"), st, "x");
        report.add_scalar(&format!("gemm/simd/{label}_dense_speedup"), sd, "x");
    }
    let is_scalar = if level == "scalar" { 1.0 } else { 0.0 };
    report.add_scalar("gemm/simd/level_is_scalar", is_scalar, "bool");
}

/// Sparse (compressed-column) GEMM vs the dense ternary kernel across
/// activation densities. Work items are the *dense* MAC count
/// (rows·k·n) at every density, so the `gemm/sparse_{p}pct` MACs/s
/// series reads directly as effective throughput and must rise with
/// sparsity — the zero-skipping payoff. Outputs are asserted
/// bit-identical to the dense kernel at every point.
fn gemm_sparsity_sweep(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    println!("\n== sparse vs dense ternary GEMM across activation density (scnet_rb2) ==");
    let (rows, k, n) = (32usize, 288usize, 256usize);
    let mut rng = Rng::new(0x5AC5);
    let w: Vec<i8> = (0..rows * k).map(|_| rng.gen_range_i64(-1, 1) as i8).collect();
    let ternary = TernaryPanel::pack(&w, rows, k);
    let macs = (rows * k * n) as u64;
    let mut dense_rate = 0.0f64;
    for pct in [0u32, 25, 50, 75, 90] {
        let cols: Vec<i32> = (0..n * k)
            .map(|_| {
                if rng.gen_bool(pct as f64 / 100.0) {
                    0
                } else {
                    rng.gen_range_i64(-8, 9) as i32
                }
            })
            .collect();
        let mut expect = vec![0i64; rows * n];
        ternary.gemm_into(&cols, n, &mut expect);
        let sp = SparseCols::compress(&cols, n, k);
        let mut out = vec![0i64; rows * n];
        let m = b.run(&format!("sc_serve/gemm/sparse_{pct}pct"), macs, || {
            ternary.gemm_sparse_into(&sp, &mut out);
            out[0]
        });
        assert_eq!(out, expect, "{pct}% zeros: sparse kernel diverged from dense");
        let rate = macs as f64 / m.median_s.max(1e-12);
        if pct == 0 {
            let md = b.run("sc_serve/gemm/sparse_dense_ref", macs, || {
                ternary.gemm_into(&cols, n, &mut out);
                out[0]
            });
            dense_rate = macs as f64 / md.median_s.max(1e-12);
        }
        println!(
            "   -> {pct:>2}% zeros: {:.1}M effective MACs/s ({:.2}x dense-ref)",
            rate / 1e6,
            rate / dense_rate.max(1e-9)
        );
        report.add(&format!("gemm/sparse_{pct}pct"), &m, macs);
        report.add_scalar(
            &format!("gemm/sparse_{pct}pct_vs_dense"),
            rate / dense_rate.max(1e-9),
            "x",
        );
    }
}

/// Engine imgs/s across the structured-pruning knob: the end-to-end
/// payoff of freeze-time N:M weight sparsity through the zero-skipping
/// ternary panels (denser pruning → fewer packed weights → faster).
fn engine_pruning_sweep(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    println!("\n== engine forward vs structured weight pruning (tnn) ==");
    let cfg = ModelCfg::tnn();
    let mut rng = Rng::new(23);
    let params = ModelParams::init(&cfg, &mut rng);
    let img: Vec<f32> = {
        let (c, h, w) = cfg.input;
        (0..c * h * w).map(|_| rng.normal() as f32 * 0.5).collect()
    };
    let mut base_rate = 0.0f64;
    for (label, pruning) in [
        ("off", Pruning::Off),
        ("3of4", Pruning::Nm { n: 3, m: 4 }),
        ("2of4", Pruning::Nm { n: 2, m: 4 }),
        ("1of4", Pruning::Nm { n: 1, m: 4 }),
    ] {
        let prep = Prepared::new(
            &cfg,
            &params,
            QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None, pruning },
        );
        let mut engine = ScEngine::new(prep);
        let cl = engine.classes();
        let mut logits = vec![0i64; cl];
        let m = b.run(&format!("sc_serve/engine/prune_{label}"), 1, || {
            engine.forward_into(&img, &mut logits);
            logits[0]
        });
        let rate = 1.0 / m.median_s.max(1e-12);
        if label == "off" {
            base_rate = rate;
        }
        println!(
            "   -> prune {label}: {rate:.1} imgs/s ({:.2}x unpruned)",
            rate / base_rate.max(1e-9)
        );
        report.add_scalar(&format!("engine/prune_{label}"), rate, "imgs/s");
        report.add_scalar(
            &format!("engine/prune_{label}_speedup"),
            rate / base_rate.max(1e-9),
            "x",
        );
    }
}

/// Engine throughput at N intra-engine threads (imgs/s on a fixed
/// batch), with bit-identity asserted against the sequential engine.
fn engine_threads_sweep(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    println!("\n== engine batch forward at N threads (tnn, bit-identical logits) ==");
    let cfg = ModelCfg::tnn();
    let mut rng = Rng::new(19);
    let params = ModelParams::init(&cfg, &mut rng);
    let prep = std::sync::Arc::new(Prepared::new(
        &cfg,
        &params,
        QuantConfig {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
    ));
    let batch = if quick() { 8usize } else { 32usize };
    let mut seq = ScEngine::new(prep.clone());
    let il = seq.image_len();
    let cl = seq.classes();
    let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32).collect();
    let mut expect = vec![0i64; batch * cl];
    seq.forward_batch_into(&x, &mut expect);
    let mut t1 = 0.0f64;
    let mut t_top = 0.0f64;
    let sweep: &[usize] = if quick() { &[1, 2] } else { &[1, 2, 4] };
    for &threads in sweep {
        let mut engine = ScEngine::with_threads(prep.clone(), threads);
        let mut logits = vec![0i64; batch * cl];
        let m = b.run(
            &format!("sc_serve/engine/tnn_batch{batch}_threads={threads}"),
            batch as u64,
            || {
                engine.forward_batch_into(&x, &mut logits);
                logits[0]
            },
        );
        assert_eq!(logits, expect, "threads={threads}: logits diverged");
        let imgs_per_s = batch as f64 / m.median_s.max(1e-12);
        report.add_scalar(&format!("engine/tnn/threads={threads}"), imgs_per_s, "imgs/s");
        if threads == 1 {
            t1 = m.median_s;
        }
        t_top = m.median_s;
    }
    let top = *sweep.last().unwrap();
    let speedup = t1 / t_top.max(1e-12);
    println!("   -> thread scaling N={top} vs N=1 on batch {batch}: {speedup:.2}x");
    report.add_scalar(&format!("engine/tnn/thread_speedup_n{top}_vs_n1"), speedup, "x");
    // Single-request latency: a one-row batch takes the channel-block
    // sharding path, so --threads helps even without co-riders.
    for &threads in sweep {
        let mut engine = ScEngine::with_threads(prep.clone(), threads);
        let mut logits = vec![0i64; cl];
        let m = b.run(&format!("sc_serve/engine/tnn_batch1_threads={threads}"), 1, || {
            engine.forward_batch_into(&x[..il], &mut logits);
            logits[0]
        });
        assert_eq!(logits[..], expect[..cl], "batch1 threads={threads}: logits diverged");
        report.add_scalar(
            &format!("engine/tnn/batch1_threads={threads}"),
            1.0 / m.median_s.max(1e-12),
            "imgs/s",
        );
    }
}

fn engine_vs_executor(report: &mut JsonReport) {
    let b = if quick() { Bench::quick() } else { Bench::default() };
    println!("\n== engine vs executor (bit-identical logits, same frozen model) ==");
    for (label, cfg, quant, img) in [
        (
            "tnn",
            ModelCfg::tnn(),
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
            SynthDigits::new().sample(Split::Test, 0).0,
        ),
        (
            "scnet10",
            ModelCfg::scnet(10),
            QuantConfig::w2a2r16(),
            SynthCifar::new(10).sample(Split::Test, 0).0,
        ),
    ] {
        let mut rng = Rng::new(11);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = std::sync::Arc::new(Prepared::new(&cfg, &params, quant));
        let exec = ScExecutor::new(prep.clone());
        let mut engine = ScEngine::new(prep);
        assert_eq!(engine.forward(&img), exec.forward(&img), "{label}: engines disagree");
        let me = b.run(&format!("sc_serve/executor/{label}_forward"), 1, || exec.forward(&img));
        let mg = b.run(&format!("sc_serve/engine/{label}_forward"), 1, || engine.forward(&img));
        let speedup = me.median_s / mg.median_s.max(1e-12);
        println!("   -> engine speedup over executor: {speedup:.2}x");
        report.add(&format!("executor/{label}_forward"), &me, 1);
        report.add(&format!("engine/{label}_forward"), &mg, 1);
        report.add_scalar(&format!("engine/{label}_speedup"), speedup, "x");
    }
}

fn pool_sweep_sc(report: &mut JsonReport) {
    println!("\n== worker-scaling sweep (backend sc, tnn, real SC model) ==");
    let mut n1 = 0.0f64;
    let mut n4 = 0.0f64;
    let sweep: &[usize] = if quick() { &[1, 2] } else { &[1, 2, 4, 8] };
    for &workers in sweep {
        let mut cfg = ServeConfig::new("artifacts", "tnn");
        cfg.workers = workers;
        cfg.batch = 8;
        cfg.queue_depth = 64;
        let coord = Coordinator::start_backend(Backend::Sc, cfg).expect("start sc pool");
        let clients = 4 * workers;
        let per_client = if quick() { 16usize } else { 64usize };
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..clients {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                let data = SynthDigits::new();
                for i in 0..per_client {
                    let (x, _) = data.sample(Split::Test, t * 10_000 + i);
                    client.infer(x.into_vec()).expect("infer");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let reqs_per_s = (clients * per_client) as f64 / wall;
        let m = coord.shutdown();
        println!(
            "sc_serve/pool/workers={workers}  {reqs_per_s:>8.0} req/s  occupancy {:.2}  \
             p50 {:?}  p99 {:?}",
            m.occupancy, m.p50, m.p99
        );
        report.add_scalar(&format!("pool/sc/workers={workers}"), reqs_per_s, "req/s");
        if workers == 1 {
            n1 = reqs_per_s;
        }
        if workers == *sweep.last().unwrap() {
            n4 = reqs_per_s;
        }
    }
    let top = sweep.last().unwrap();
    let speedup = n4 / n1.max(1.0);
    println!(
        "sc_serve/pool/speedup  N={top} vs N=1: {speedup:.2}x  ({})",
        if speedup > 1.0 { "scales" } else { "DOES NOT SCALE" }
    );
    report.add_scalar(&format!("pool/sc/speedup_n{top}_vs_n1"), speedup, "x");
}

/// Degradation-under-chaos series: goodput (successfully answered
/// req/s) and p99 latency of a supervised pool while worker panics
/// are injected at increasing rates. The synthetic backend isolates
/// supervision overhead (panic → typed error → in-thread respawn)
/// from model compute, so the series tracks the fault-tolerance
/// layer's own cost.
fn chaos_degradation(report: &mut JsonReport) {
    println!("\n== degradation under injected worker panics (supervised pool) ==");
    let spec = ExecutorSpec { image_len: 64, batch: 8, classes: 10 };
    let rates: &[f64] = if quick() { &[0.0, 0.05] } else { &[0.0, 0.01, 0.05, 0.2] };
    let mut goodput0 = 0.0f64;
    for &rate in rates {
        let switch = ChaosSwitch::new(0.0);
        let factory = chaos_factory(
            SyntheticExecutor::factory(spec, Duration::from_micros(500)),
            switch.clone(),
            0xBAD,
        );
        let coord = Coordinator::start_with(
            factory,
            PoolConfig {
                workers: 2,
                queue_depth: 64,
                restart_budget: 1_000_000,
                ..PoolConfig::default()
            },
        )
        .expect("start supervised pool");
        switch.set_rate(rate);
        let clients = 4usize;
        let per_client = if quick() { 64usize } else { 256usize };
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..clients {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                let mut rng = Rng::new(0xD00D + t as u64);
                let mut ok = 0u64;
                for _ in 0..per_client {
                    let x: Vec<f32> = (0..spec.image_len).map(|_| rng.f64() as f32).collect();
                    if client.infer_within(x, Some(Duration::from_secs(5))).is_ok() {
                        ok += 1;
                    }
                }
                ok
            }));
        }
        let mut ok = 0u64;
        for h in handles {
            ok += h.join().expect("bench client");
        }
        let wall = t0.elapsed().as_secs_f64();
        let goodput = ok as f64 / wall.max(1e-9);
        switch.off();
        let m = coord.shutdown();
        let total = (clients * per_client) as u64;
        println!(
            "sc_serve/chaos/rate={rate}  goodput {goodput:>7.0} req/s  ok {ok}/{total}  \
             p99 {:?}  panics {}  respawns {}",
            m.p99, m.worker_panics, m.worker_respawns
        );
        report.add_scalar(&format!("chaos/goodput/rate={rate}"), goodput, "req/s");
        report.add_scalar(&format!("chaos/p99_ms/rate={rate}"), m.p99.as_secs_f64() * 1e3, "ms");
        if rate == 0.0 {
            goodput0 = goodput;
        } else {
            report.add_scalar(
                &format!("chaos/goodput_retained/rate={rate}"),
                goodput / goodput0.max(1e-9),
                "frac",
            );
        }
        assert!(ok > 0, "rate {rate}: supervised pool must keep serving");
    }
}

fn main() {
    let mut report = JsonReport::new("sc_serve");
    gemm_vs_naive(&mut report);
    gemm_simd_vs_scalar(&mut report);
    gemm_sparsity_sweep(&mut report);
    engine_pruning_sweep(&mut report);
    engine_vs_executor(&mut report);
    engine_threads_sweep(&mut report);
    pool_sweep_sc(&mut report);
    chaos_degradation(&mut report);
    if let Ok(path) = std::env::var("BENCH_JSON") {
        report.write(&path).expect("write BENCH_JSON");
        println!("\nwrote {} entries to {path}", report.len());
    } else {
        println!("\n(set BENCH_JSON=BENCH_sc.json or run `make bench-json` for JSON output)");
    }
}
