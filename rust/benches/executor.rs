//! Bit-exact SC executor benchmarks (§Perf L3 target: evaluate 1k
//! SynthCIFAR images in < 60 s → ≥ 16.7 img/s on the fast count path).

use std::sync::Arc;

use scnn::data::{Dataset, Split, SynthCifar, SynthDigits};
use scnn::nn::binary_exec::BinaryExecutor;
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::{Pruning, QuantConfig};
use scnn::nn::sc_exec::{FaultCfg, Prepared, ScExecutor};
use scnn::util::bench::Bench;
use scnn::util::Rng;

fn main() {
    let b = Bench::default();
    let mut rng = Rng::new(11);

    println!("== tnn (SynthDigits) forward ==");
    let cfg = ModelCfg::tnn();
    let params = ModelParams::init(&cfg, &mut rng);
    // One frozen model shared by all three executors (Arc refcount
    // bumps, no weight/SI-table copies).
    let prep = Arc::new(Prepared::new(
        &cfg,
        &params,
        QuantConfig {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
    ));
    let digits = SynthDigits::new();
    let (dimg, _) = digits.sample(Split::Test, 0);
    let sc = ScExecutor::new(prep.clone());
    b.run("exec/sc/tnn_forward", 1, || sc.forward(&dimg));
    let bin = BinaryExecutor::new(prep.clone());
    b.run("exec/binary/tnn_forward", 1, || bin.forward(&dimg));
    let faulty = ScExecutor::with_faults(prep, FaultCfg { ber: 1e-3, seed: 3 });
    b.run("exec/sc_faulty/tnn_forward", 1, || faulty.forward(&dimg));

    println!("\n== scnet10 (SynthCIFAR, residual) forward ==");
    let cfg = ModelCfg::scnet(10);
    let params = ModelParams::init(&cfg, &mut rng);
    let prep = Arc::new(Prepared::new(&cfg, &params, QuantConfig::w2a2r16()));
    let cifar = SynthCifar::new(10);
    let (cimg, _) = cifar.sample(Split::Test, 0);
    let sc = ScExecutor::new(prep.clone());
    let m = b.run("exec/sc/scnet_forward", 1, || sc.forward(&cimg));
    println!(
        "   -> {:.1} img/s ({:.0} img per 60 s; §Perf target >= 1000)",
        1.0 / m.median_s,
        60.0 / m.median_s
    );
    let bin = BinaryExecutor::new(prep.clone());
    b.run("exec/binary/scnet_forward", 1, || bin.forward(&cimg));

    println!("\n== executor setup (SI synthesis across layers) ==");
    b.run("exec/prepare/scnet", 1, || {
        Prepared::new(&cfg, &params, QuantConfig::w2a2r16())
    });

    println!("\n== dataset generation ==");
    b.run("data/synthcifar_sample", 1, || cifar.sample(Split::Train, 1234));
    b.run("data/synthdigits_sample", 1, || digits.sample(Split::Train, 1234));
}
