//! Coordinator / serving benchmarks: end-to-end request throughput and
//! latency through the dynamic batcher + PJRT serving path.
//!
//! Requires `make artifacts`; skips gracefully otherwise.

use std::time::Instant;

use scnn::coordinator::{BatchPolicy, Coordinator, ServeConfig};
use scnn::data::{Dataset, Split, SynthCifar};
use scnn::runtime::trainer::Knobs;

fn main() {
    if !std::path::Path::new("artifacts/scnet10_meta.txt").exists() {
        println!("coordinator bench skipped: run `make artifacts` first");
        return;
    }
    for (label, clients, max_wait_ms) in
        [("1-client", 1usize, 2u64), ("8-clients", 8, 2), ("32-clients", 32, 5)]
    {
        let mut cfg = ServeConfig::new("artifacts", "scnet10");
        cfg.knobs = Knobs::quantized(2).with_res_bsl(Some(16));
        cfg.policy = BatchPolicy { max_wait: std::time::Duration::from_millis(max_wait_ms) };
        let coord = Coordinator::start(cfg).expect("start coordinator");
        let requests_per_client = 192usize;
        let t0 = Instant::now();
        let mut handles = Vec::new();
        for t in 0..clients {
            let client = coord.client();
            handles.push(std::thread::spawn(move || {
                let data = SynthCifar::new(10);
                for i in 0..requests_per_client {
                    let (x, _) = data.sample(Split::Test, t * 10_000 + i);
                    client.infer(x.into_vec()).expect("infer");
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        let wall = t0.elapsed().as_secs_f64();
        let m = coord.shutdown();
        let total = clients * requests_per_client;
        println!(
            "coordinator/{label:<12} {total:>6} reqs in {wall:>6.2}s -> {:>7.0} req/s  \
             occupancy {:.2}  p50 {:?}  p99 {:?}",
            total as f64 / wall,
            m.occupancy,
            m.p50,
            m.p99
        );
    }
}
