//! Coordinator / serving benchmarks.
//!
//! Part 1 always runs: a **worker-scaling sweep** over the synthetic
//! backend, whose fixed per-batch latency models a busy fixed-batch
//! accelerator — sustained throughput must rise with the worker count
//! at saturation (N=4 > N=1). Part 2 (end-to-end PJRT serving path)
//! needs `make artifacts` and skips gracefully otherwise.

use std::time::{Duration, Instant};

use scnn::coordinator::{
    BatchPolicy, Coordinator, ExecutorSpec, PoolConfig, ServeConfig, SyntheticExecutor,
};
use scnn::data::{Dataset, Split, SynthCifar};
use scnn::runtime::{artifacts_ready, trainer::Knobs};

/// Drive a pool to saturation from `clients` blocking threads; returns
/// (req/s, final snapshot).
fn drive(
    coord: &Coordinator,
    clients: usize,
    requests_per_client: usize,
) -> (f64, scnn::coordinator::MetricsSnapshot) {
    let t0 = Instant::now();
    let mut handles = Vec::new();
    for t in 0..clients {
        let client = coord.client();
        handles.push(std::thread::spawn(move || {
            let data = SynthCifar::new(10);
            for i in 0..requests_per_client {
                let (x, _) = data.sample(Split::Test, t * 10_000 + i);
                client.infer(x.into_vec()).expect("infer");
            }
        }));
    }
    for h in handles {
        h.join().unwrap();
    }
    let wall = t0.elapsed().as_secs_f64();
    ((clients * requests_per_client) as f64 / wall, coord.metrics())
}

fn sweep_workers() {
    println!("== worker-scaling sweep (synthetic backend, 2 ms/batch accelerator) ==");
    let spec = ExecutorSpec { image_len: 3 * 32 * 32, batch: 8, classes: 10 };
    let mut throughput_n1 = 0.0f64;
    let mut throughput_n4 = 0.0f64;
    for workers in [1usize, 2, 4, 8] {
        let factory = SyntheticExecutor::factory(spec, Duration::from_millis(2));
        let pool = PoolConfig { workers, queue_depth: 64, ..PoolConfig::default() };
        let coord = Coordinator::start_with(factory, pool).expect("start pool");
        // Saturate: enough concurrent clients to keep every worker's
        // batch full (8 blocking clients per worker at batch 8).
        let (reqs_per_s, m) = drive(&coord, 8 * workers, 96);
        if workers == 1 {
            throughput_n1 = reqs_per_s;
        }
        if workers == 4 {
            throughput_n4 = reqs_per_s;
        }
        println!(
            "coordinator/sweep/workers={workers}  {:>8.0} req/s  occupancy {:.2}  \
             p50 {:?}  p99 {:?}  peak-inflight {}",
            reqs_per_s, m.occupancy, m.p50, m.p99, m.inflight_peak
        );
        coord.shutdown();
    }
    let speedup = throughput_n4 / throughput_n1.max(1.0);
    println!(
        "coordinator/sweep/speedup  N=4 vs N=1: {speedup:.2}x  ({})",
        if speedup > 1.0 { "scales" } else { "DOES NOT SCALE" }
    );
}

fn sweep_batch_policy() {
    println!("\n== batching policy (synthetic backend, 1 worker, light load) ==");
    let spec = ExecutorSpec { image_len: 3 * 32 * 32, batch: 8, classes: 10 };
    for (label, adaptive) in [("adaptive", true), ("fixed-wait", false)] {
        let factory = SyntheticExecutor::factory(spec, Duration::from_millis(2));
        let policy = BatchPolicy {
            max_wait: Duration::from_millis(5),
            adaptive,
            ..BatchPolicy::default()
        };
        let pool = PoolConfig { workers: 1, policy, queue_depth: 64, ..PoolConfig::default() };
        let coord = Coordinator::start_with(factory, pool).expect("start pool");
        // 2 clients against batch 8: occupancy is low, so the adaptive
        // policy should stop holding batches open and cut p50.
        let (reqs_per_s, m) = drive(&coord, 2, 96);
        println!(
            "coordinator/policy/{label:<10}  {:>8.0} req/s  occupancy {:.2}  p50 {:?}  p99 {:?}",
            reqs_per_s, m.occupancy, m.p50, m.p99
        );
        coord.shutdown();
    }
}

fn bench_pjrt() {
    if !artifacts_ready("artifacts", "scnet10") {
        println!("\ncoordinator/pjrt skipped: run `make artifacts` first");
        return;
    }
    println!("\n== end-to-end PJRT serving path ==");
    for (label, workers, clients) in
        [("w1/8-clients", 1usize, 8usize), ("w2/16-clients", 2, 16), ("w4/32-clients", 4, 32)]
    {
        let mut cfg = ServeConfig::new("artifacts", "scnet10");
        cfg.knobs = Knobs::quantized(2).with_res_bsl(Some(16));
        cfg.workers = workers;
        let coord = Coordinator::start(cfg).expect("start coordinator");
        let (reqs_per_s, m) = drive(&coord, clients, 192);
        println!(
            "coordinator/pjrt/{label:<14}  {:>7.0} req/s  occupancy {:.2}  p50 {:?}  p99 {:?}",
            reqs_per_s, m.occupancy, m.p50, m.p99
        );
        coord.shutdown();
    }
}

fn main() {
    sweep_workers();
    sweep_batch_policy();
    bench_pjrt();
}
