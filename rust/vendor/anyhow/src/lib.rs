//! Offline drop-in subset of the `anyhow` error-handling crate.
//!
//! The build environment has no crates.io access (DESIGN.md
//! §Substitutions), so this vendored shim provides exactly the surface
//! `scnn` uses, with the same semantics as upstream `anyhow`:
//!
//! * [`Error`] — a context-chain error type. `{}` displays the
//!   outermost message; `{:#}` displays the whole chain joined by
//!   `": "` (matching upstream's alternate formatting).
//! * [`Result<T>`] — `Result` defaulted to [`Error`].
//! * [`Context`] — `.context(..)` / `.with_context(..)` on `Result`
//!   and `Option`.
//! * [`anyhow!`], [`bail!`], [`ensure!`] — the construction macros.
//!
//! Like upstream, [`Error`] deliberately does **not** implement
//! `std::error::Error`, which is what allows the blanket
//! `From<E: std::error::Error>` conversion used by `?`.

use std::fmt;

/// A context-chain error. Index 0 of the chain is the outermost
/// (most recently attached) message; the last entry is the root cause.
pub struct Error {
    chain: Vec<String>,
}

impl Error {
    /// Construct from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Self {
        Self { chain: vec![message.to_string()] }
    }

    /// Prepend a context message (what `.context(..)` does).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Self {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The messages from outermost context to root cause.
    pub fn chain(&self) -> impl Iterator<Item = &str> {
        self.chain.iter().map(String::as_str)
    }

    /// The innermost (root-cause) message.
    pub fn root_cause(&self) -> &str {
        self.chain.last().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            f.write_str(&self.chain.join(": "))
        } else {
            f.write_str(self.chain.first().map(String::as_str).unwrap_or(""))
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.chain.first().map(String::as_str).unwrap_or(""))?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for (i, cause) in self.chain[1..].iter().enumerate() {
                write!(f, "\n    {i}: {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        let mut chain = vec![e.to_string()];
        let mut source = e.source();
        while let Some(s) = source {
            chain.push(s.to_string());
            source = s.source();
        }
        Self { chain }
    }
}

/// `Result` with [`Error`] as the default error type.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to errors (and convert `Option` to `Result`).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T>;

    /// Wrap the error with a lazily-evaluated context message.
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string or printable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Return early with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an [`Error`] when a condition is false.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return Err($crate::Error::msg(concat!(
                "condition failed: ",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            return Err($crate::anyhow!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Result<usize> {
        let n: usize = s.parse().context("parsing count")?;
        ensure!(n > 0, "count must be positive, got {n}");
        Ok(n)
    }

    #[test]
    fn context_chain_and_alternate_display() {
        let e = parse("x").unwrap_err();
        assert_eq!(format!("{e}"), "parsing count");
        let full = format!("{e:#}");
        assert!(full.starts_with("parsing count: "), "{full}");
        assert!(format!("{e:?}").contains("Caused by"), "{e:?}");
    }

    #[test]
    fn ensure_and_bail_format() {
        let e = parse("0").unwrap_err();
        assert_eq!(format!("{e}"), "count must be positive, got 0");
        fn fail() -> Result<()> {
            bail!("bad value {}", 7)
        }
        assert_eq!(format!("{}", fail().unwrap_err()), "bad value 7");
    }

    #[test]
    fn option_context_and_question_mark() {
        fn first(v: &[u8]) -> Result<u8> {
            let x = v.first().context("empty slice")?;
            Ok(*x)
        }
        assert_eq!(first(&[3]).unwrap(), 3);
        assert_eq!(format!("{}", first(&[]).unwrap_err()), "empty slice");
    }

    #[test]
    fn from_std_error_keeps_sources() {
        let io = std::io::Error::other("disk on fire");
        let e: Error = io.into();
        assert_eq!(e.root_cause(), "disk on fire");
        let e = e.context("loading artifact");
        assert_eq!(e.chain().count(), 2);
    }

    #[test]
    fn anyhow_macro_accepts_string_exprs() {
        let msg = String::from("already formatted");
        let e = anyhow!(msg.clone());
        assert_eq!(format!("{e}"), "already formatted");
    }
}
