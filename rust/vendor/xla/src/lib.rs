//! Offline API stub of the `xla` (PJRT) crate, version-matched to the
//! `xla_extension` 0.5.1 surface that `scnn::runtime` targets.
//!
//! The build environment has neither crates.io access nor the
//! `libxla_extension` native library (DESIGN.md §Substitutions), so
//! this vendored shim keeps the crate compiling and the non-PJRT 95%
//! of the test suite running:
//!
//! * [`Literal`] is **functional**: scalar/vec1/reshape/to_vec round
//!   trips behave like the real crate (host-side data only).
//! * Client construction succeeds (so `scnn info` and artifact probing
//!   work), but [`PjRtClient::compile`] and execution return
//!   "backend unavailable" errors pointing at the substitution note.
//!
//! Swapping the real backend in is a one-line `Cargo.toml` change
//! (point the `xla` dependency at the real crate); no `scnn` source
//! changes are required, which is the entire point of the stub.

use std::fmt;

/// Stub error type (the real crate's `Error` is also a display-able
/// enum; only the message matters to `scnn`, which wraps everything in
/// `anyhow` context).
#[derive(Clone, Debug)]
pub struct Error(String);

impl Error {
    fn unavailable(op: &str) -> Self {
        Self(format!(
            "{op} unavailable: scnn was built against the vendored `xla` API stub \
             (no PJRT native library in this environment; see DESIGN.md §Substitutions)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

/// Stub result type.
pub type Result<T> = std::result::Result<T, Error>;

/// Element storage of a [`Literal`].
#[doc(hidden)]
#[derive(Clone, Debug)]
pub enum Storage {
    /// 32-bit floats.
    F32(Vec<f32>),
    /// 32-bit signed integers.
    I32(Vec<i32>),
    /// A tuple of literals.
    Tuple(Vec<Literal>),
}

/// Element types a [`Literal`] can hold (mirror of the real crate's
/// native-type trait, restricted to what `scnn` uses).
pub trait NativeType: Copy + Sized {
    /// Human-readable dtype name for error messages.
    const NAME: &'static str;
    #[doc(hidden)]
    fn wrap(v: Vec<Self>) -> Storage;
    #[doc(hidden)]
    fn unwrap(s: &Storage) -> Option<Vec<Self>>;
}

impl NativeType for f32 {
    const NAME: &'static str = "f32";
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::F32(v)
    }
    fn unwrap(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::F32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

impl NativeType for i32 {
    const NAME: &'static str = "i32";
    fn wrap(v: Vec<Self>) -> Storage {
        Storage::I32(v)
    }
    fn unwrap(s: &Storage) -> Option<Vec<Self>> {
        match s {
            Storage::I32(v) => Some(v.clone()),
            _ => None,
        }
    }
}

/// A host-side tensor value (functional in the stub).
#[derive(Clone, Debug)]
pub struct Literal {
    storage: Storage,
    dims: Vec<i64>,
}

impl Literal {
    /// Rank-0 literal.
    pub fn scalar<T: NativeType>(v: T) -> Self {
        Self { storage: T::wrap(vec![v]), dims: vec![] }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(v: &[T]) -> Self {
        Self { storage: T::wrap(v.to_vec()), dims: vec![v.len() as i64] }
    }

    /// Reinterpret with new dimensions (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Self> {
        let n: i64 = dims.iter().product();
        let len = match &self.storage {
            Storage::F32(v) => v.len(),
            Storage::I32(v) => v.len(),
            Storage::Tuple(_) => {
                return Err(Error("cannot reshape a tuple literal".into()));
            }
        };
        if n.max(1) as usize != len.max(1) {
            return Err(Error(format!(
                "reshape {dims:?} incompatible with {len} elements"
            )));
        }
        Ok(Self { storage: self.storage.clone(), dims: dims.to_vec() })
    }

    /// Copy the elements out as a flat vector.
    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap(&self.storage)
            .ok_or_else(|| Error(format!("literal does not hold {} elements", T::NAME)))
    }

    /// First element (rank-agnostic).
    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        self.to_vec::<T>()?
            .first()
            .copied()
            .ok_or_else(|| Error("empty literal".into()))
    }

    /// Unpack a tuple literal into its components.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.storage {
            Storage::Tuple(v) => Ok(v),
            _ => Err(Error("literal is not a tuple".into())),
        }
    }

    /// Dimensions.
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (the stub only retains the source path).
#[derive(Clone, Debug)]
pub struct HloModuleProto {
    path: String,
}

impl HloModuleProto {
    /// Read an HLO **text** file. The stub verifies the file is
    /// readable and looks like HLO text, then records the path.
    pub fn from_text_file(path: &str) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error(format!("reading HLO text {path}: {e}")))?;
        if !text.contains("HloModule") {
            return Err(Error(format!("{path} does not look like HLO text")));
        }
        Ok(Self { path: path.to_string() })
    }
}

/// A computation handle compiled from an [`HloModuleProto`].
#[derive(Clone, Debug)]
pub struct XlaComputation {
    path: String,
}

impl XlaComputation {
    /// Wrap a parsed module.
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        Self { path: proto.path.clone() }
    }
}

/// PJRT client handle. Construction succeeds in the stub so that
/// diagnostics (`scnn info`) and metadata loading work without the
/// native library; only compile/execute are unavailable.
pub struct PjRtClient {
    platform: &'static str,
}

impl PjRtClient {
    /// CPU-backed client.
    pub fn cpu() -> Result<Self> {
        Ok(Self { platform: "cpu-stub (vendored xla shim; PJRT unavailable)" })
    }

    /// Platform name for diagnostics.
    pub fn platform_name(&self) -> String {
        self.platform.to_string()
    }

    /// Compile a computation — always fails in the stub.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable(&format!("compiling {}", comp.path)))
    }
}

/// A compiled executable handle (never constructed by the stub).
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute with the given arguments — unreachable in the stub
    /// (compile never succeeds), present for API compatibility.
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PJRT execute"))
    }
}

/// A device buffer handle (never constructed by the stub).
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Copy device memory back to a host [`Literal`].
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("device -> host transfer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let l = Literal::vec1(&[1.0f32, -2.5, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0]);
        assert_eq!(l.get_first_element::<f32>().unwrap(), 1.0);
        assert!(l.to_vec::<i32>().is_err());
    }

    #[test]
    fn literal_reshape_checks_element_count() {
        let l = Literal::vec1(&[0i32; 12]);
        let r = l.reshape(&[3, 4]).unwrap();
        assert_eq!(r.dims(), &[3, 4]);
        assert!(l.reshape(&[5, 5]).is_err());
    }

    #[test]
    fn scalar_literal() {
        let l = Literal::scalar(7i32);
        assert_eq!(l.dims().len(), 0);
        assert_eq!(l.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn compile_reports_stub() {
        let client = PjRtClient::cpu().unwrap();
        assert!(client.platform_name().contains("stub"));
        let comp = XlaComputation { path: "x.hlo.txt".into() };
        let err = client.compile(&comp).unwrap_err();
        assert!(err.to_string().contains("vendored `xla` API stub"), "{err}");
    }
}
