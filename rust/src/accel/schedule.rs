//! The flexible accelerator schedule: one physical spatial-temporal
//! datapath serving every conv layer of a network (paper §IV.C).
//!
//! The paper's flexibility claim: a single small BSN with runtime
//! control signals handles all accumulation widths; smaller layers need
//! fewer cycles, so average ADP drops 8.5× and datapath area 2.2× on
//! ResNet-18's four conv sizes, with per-layer reductions of 8.2–23.3×.

use crate::circuits::bsn::Bsn;
use crate::circuits::st_bsn::SpatialTemporalBsn;
use crate::cost::Cost;
use super::design_st;

/// Per-layer schedule entry.
#[derive(Clone, Debug)]
pub struct LayerSchedule {
    /// Accumulation width in bits.
    pub width_bits: usize,
    /// Cycles on the shared datapath (incl. merge).
    pub cycles: usize,
    /// ADP of the shared datapath for this layer (area × latency).
    pub adp_st: f64,
    /// ADP of the inflexible baseline for this layer: the monolithic
    /// exact BSN provisioned for the **largest** width (Fig 9b — a big
    /// BSN must serve small layers too).
    pub adp_exact: f64,
    /// Reduction factor.
    pub reduction: f64,
}

/// The shared-datapath schedule over a set of layer widths.
#[derive(Clone, Debug)]
pub struct Schedule {
    /// The shared physical accumulator (sized by `inner_bits`).
    pub inner_bits: usize,
    /// Per-layer entries.
    pub layers: Vec<LayerSchedule>,
    /// Area of the shared ST datapath (µm²) — one instance serves all.
    pub shared_area_um2: f64,
    /// Area of the inflexible alternative: the *largest* exact BSN
    /// (which the paper notes must be provisioned for the worst case,
    /// Fig 9b).
    pub monolithic_area_um2: f64,
}

impl Schedule {
    /// Build a schedule for `widths_bits` on a shared inner BSN of
    /// `inner_bits` (must divide every width).
    pub fn new(widths_bits: &[usize], inner_bits: usize) -> Self {
        let mut layers = Vec::with_capacity(widths_bits.len());
        let mut shared_area: f64 = 0.0;
        let monolithic_cost = Bsn::new(*widths_bits.iter().max().unwrap()).cost();
        for &w in widths_bits {
            let st = design_st(w, inner_bits.min(w), 16, 16);
            let c: Cost = st.total_cost();
            shared_area = shared_area.max(c.area_um2);
            layers.push(LayerSchedule {
                width_bits: w,
                cycles: st.total_cycles(),
                adp_st: c.adp(),
                adp_exact: monolithic_cost.adp(),
                reduction: monolithic_cost.adp() / c.adp(),
            });
        }
        let monolithic = Bsn::new(*widths_bits.iter().max().unwrap()).cost().area_um2;
        Self {
            inner_bits,
            layers,
            shared_area_um2: shared_area,
            monolithic_area_um2: monolithic,
        }
    }

    /// Average ADP reduction across layers (paper: 8.5× on ResNet-18).
    pub fn avg_adp_reduction(&self) -> f64 {
        self.layers.iter().map(|l| l.reduction).sum::<f64>() / self.layers.len() as f64
    }

    /// Datapath-area reduction of the shared design versus provisioning
    /// the monolithic worst-case BSN (paper: 2.2×).
    pub fn area_reduction(&self) -> f64 {
        self.monolithic_area_um2 / self.shared_area_um2
    }

    /// Reuse helper for tests/benches: the ST instance of one layer.
    pub fn st_for(&self, width_bits: usize) -> SpatialTemporalBsn {
        design_st(width_bits, self.inner_bits.min(width_bits), 16, 16)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::accel::RESNET18_ACC_WIDTHS;

    fn widths_bits() -> Vec<usize> {
        RESNET18_ACC_WIDTHS.iter().map(|w| w * 2).collect()
    }

    #[test]
    fn schedule_covers_all_layers() {
        let s = Schedule::new(&widths_bits(), 1152);
        assert_eq!(s.layers.len(), 4);
        // Cycle counts scale with width: 2, 3, 5, 9.
        let cycles: Vec<usize> = s.layers.iter().map(|l| l.cycles).collect();
        assert_eq!(cycles, vec![2, 3, 5, 9]);
    }

    #[test]
    fn every_layer_wins_vs_exact() {
        let s = Schedule::new(&widths_bits(), 1152);
        for l in &s.layers {
            assert!(
                l.reduction > 1.0,
                "width {} must beat the exact BSN (got {:.2}x)",
                l.width_bits,
                l.reduction
            );
        }
        assert!(s.avg_adp_reduction() > 2.0);
    }

    #[test]
    fn shared_area_smaller_than_monolithic() {
        let s = Schedule::new(&widths_bits(), 1152);
        assert!(
            s.area_reduction() > 1.5,
            "flexible datapath should be much smaller: {:.2}x",
            s.area_reduction()
        );
    }
}
