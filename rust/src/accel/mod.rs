//! Accelerator model: maps network layers onto BSN configurations and
//! rolls up per-layer hardware cost (paper §IV.C — Table V, Fig 13,
//! Fig 9).
//!
//! * [`design_spatial`] — heuristic generator over the parameterized
//!   BSN design space of Fig 10b (stage count, group sizes, clip &
//!   stride per stage), biased by the Gaussian-input observation of
//!   Fig 11 (clip ≈ l/4 at inner stages is safe).
//! * [`design_st`] — folds a wide accumulation onto one small spatial
//!   BSN (Fig 12).
//! * [`search_spatial`] — small design-space search: minimize ADP
//!   subject to an MSE budget (the ablation behind Table V's configs).
//! * [`schedule`] — the flexible accelerator: one physical
//!   spatial-temporal datapath serving every layer of a network with
//!   per-layer cycle counts (Fig 13's four conv sizes).

pub mod schedule;

use crate::circuits::approx_bsn::{ApproxBsn, ApproxStage, SubSample};
use crate::circuits::bsn::Bsn;
use crate::circuits::st_bsn::SpatialTemporalBsn;
use crate::cost::Cost;
use crate::util::Rng;

/// The four conv accumulation widths of ResNet-18's basic blocks
/// (3×3×{64,128,256,512} products) — the paper's Fig 13 x-axis.
pub const RESNET18_ACC_WIDTHS: [usize; 4] = [576, 1152, 2304, 4608];

/// Largest sub-BSN the spatial designer will instantiate as a leaf.
const MAX_LEAF: usize = 256;
/// Preferred group input size for inner stages.
const GROUP_TARGET: usize = 128;

/// Smallest divisor of `n` that is `>= lo` (falls back to `n`).
fn divisor_at_least(n: usize, lo: usize) -> usize {
    for m in lo..=n {
        if n % m == 0 {
            return m;
        }
    }
    n
}

/// Design a spatial approximate BSN for `width` input bits with an
/// `out_bsl`-bit output, via [`design_spatial_with`]'s default knobs:
/// inner stages clip a quarter of each sorted group and stride by 2
/// (truncated quantization, safe for near-Gaussian inputs — Fig 11);
/// the final stage strides down to exactly `out_bsl`.
pub fn design_spatial(width: usize, out_bsl: usize) -> ApproxBsn {
    design_spatial_with(width, out_bsl, 4, 2).expect("default spatial design must exist")
}

/// Final-stage sampler: largest power-of-two stride reaching exactly
/// `out_bsl` output bits with symmetric clipping.
fn final_sub(l: usize, out_bsl: usize) -> Option<SubSample> {
    let mut s = 1usize;
    while out_bsl * s * 2 <= l {
        s *= 2;
    }
    let kept = out_bsl * s;
    if kept > l || (l - kept) % 2 != 0 {
        return None;
    }
    Some(SubSample { clip: (l - kept) / 2, stride: s })
}

/// Inner-stage sampler for an `l`-bit group: clip `l/clip_div` bits per
/// end (rounded to keep the kept region stride-aligned and the output
/// BSL even — zero-centering).
fn inner_sub(l: usize, clip_div: usize, stride: usize) -> Option<SubSample> {
    let mut clip = l / clip_div;
    // Shrink the clip until the kept width is divisible by 2·stride so
    // the output BSL is even.
    while clip > 0 && (l - 2 * clip) % (2 * stride) != 0 {
        clip -= 1;
    }
    let kept = l - 2 * clip;
    if kept == 0 || kept % stride != 0 {
        return None;
    }
    let sub = SubSample { clip, stride };
    (sub.out_bsl(l) >= 2).then_some(sub)
}

/// Parameterized spatial designer over the Fig-10b space. Stages are
/// built in *block units* so widths always chain: after a stage of `m`
/// groups emitting `b` bits each, the next stage regroups whole blocks.
pub fn design_spatial_with(
    width: usize,
    out_bsl: usize,
    clip_div: usize,
    inner_stride: usize,
) -> Option<ApproxBsn> {
    assert!(width >= out_bsl, "width {width} too small for out_bsl {out_bsl}");
    if width <= MAX_LEAF {
        let sub = final_sub(width, out_bsl)?;
        return Some(ApproxBsn::new(vec![ApproxStage { m: 1, l: width, sub }]));
    }
    // Leaf stage: split into groups near GROUP_TARGET bits.
    let m0 = divisor_at_least(width, width.div_ceil(GROUP_TARGET));
    let l0 = width / m0;
    let sub0 = inner_sub(l0, clip_div, inner_stride)?;
    let mut stages = vec![ApproxStage { m: m0, l: l0, sub: sub0 }];
    let mut blocks = m0;
    let mut bsl = sub0.out_bsl(l0);
    while blocks > 1 {
        // Group as many whole blocks as fit under MAX_LEAF.
        let mut g = 1usize;
        for cand in (2..=blocks).rev() {
            if blocks % cand == 0 && cand * bsl <= MAX_LEAF {
                g = cand;
                break;
            }
        }
        if g == 1 {
            // No divisor fits; take the smallest divisor >= 2 even if it
            // exceeds MAX_LEAF (rare, still correct).
            g = divisor_at_least(blocks, 2);
        }
        let m = blocks / g;
        let l = g * bsl;
        let sub = if m == 1 {
            final_sub(l, out_bsl)?
        } else {
            inner_sub(l, clip_div, inner_stride)?
        };
        if m > 1 && m * sub.out_bsl(l) >= blocks * bsl {
            return None; // not shrinking — this knob setting is useless
        }
        stages.push(ApproxStage { m, l, sub });
        blocks = m;
        bsl = sub.out_bsl(l);
    }
    (bsl == out_bsl).then(|| ApproxBsn::new(stages))
}

/// Design a spatial-temporal BSN: a single `inner_width`-bit sub-BSN
/// (with sub-sampling to `partial_bsl`) reused over
/// `total_width / inner_width` cycles, plus a merge stage producing
/// `out_bsl` bits.
pub fn design_st(
    total_width: usize,
    inner_width: usize,
    partial_bsl: usize,
    out_bsl: usize,
) -> SpatialTemporalBsn {
    assert_eq!(total_width % inner_width, 0);
    let cycles = total_width / inner_width;
    // Inner: single-stage sort + clip/stride to partial_bsl.
    let mut s = 1usize;
    while partial_bsl * s * 2 <= inner_width {
        s *= 2;
    }
    let kept = partial_bsl * s;
    let clip = (inner_width - kept) / 2;
    let inner = ApproxBsn::new(vec![ApproxStage {
        m: 1,
        l: inner_width,
        sub: SubSample { clip, stride: s },
    }]);
    // Merge: cycles × partial_bsl bits down to out_bsl.
    let mw = cycles * partial_bsl;
    let ms = (mw / out_bsl).max(1);
    let mkept = out_bsl * ms;
    let msub = SubSample { clip: (mw - mkept) / 2, stride: ms };
    SpatialTemporalBsn::new(inner, total_width, msub)
}

/// One candidate from the spatial design-space search.
#[derive(Clone, Debug)]
pub struct SearchResult {
    /// The chosen configuration.
    pub config: ApproxBsn,
    /// Hardware cost.
    pub cost: Cost,
    /// Measured MSE (normalized, as in [`ApproxBsn::mse`]).
    pub mse: f64,
}

/// Grid-search the Fig-10b design space for `width` bits: vary the
/// final-stage stride aggressiveness and inner clip fraction; keep the
/// cheapest config whose MSE is within `mse_budget`.
pub fn search_spatial(
    width: usize,
    out_bsl: usize,
    mse_budget: f64,
    trials: usize,
    seed: u64,
) -> SearchResult {
    let mut rng = Rng::new(seed);
    let exact = ApproxBsn::exact(width);
    let mut best = SearchResult {
        cost: exact.cost(),
        mse: 0.0,
        config: exact,
    };
    for clip_div in [8usize, 6, 4, 3] {
        for stride in [1usize, 2, 4] {
            let cand = design_spatial_with(width, out_bsl, clip_div, stride);
            let Some(cand) = cand else { continue };
            let mse = cand.mse(0.5, trials, &mut rng);
            let cost = cand.cost();
            if mse <= mse_budget && cost.adp() < best.cost.adp() {
                best = SearchResult { config: cand, cost, mse };
            }
        }
    }
    best
}

/// Per-layer comparison of the three accumulator designs (Table V rows
/// for one layer; Fig 13 across layers).
#[derive(Clone, Debug)]
pub struct LayerProfile {
    /// Products accumulated (conv K·K·Cin).
    pub acc_products: usize,
    /// BSN input width in bits (products × act BSL).
    pub width_bits: usize,
    /// Exact baseline BSN.
    pub exact: Cost,
    /// Spatial approximate BSN.
    pub spatial: Cost,
    /// Spatial MSE.
    pub spatial_mse: f64,
    /// Spatial-temporal BSN (total, all cycles).
    pub st: Cost,
    /// ST throughput-normalized ADP (Table V footnote).
    pub st_adp_norm: f64,
    /// ST MSE.
    pub st_mse: f64,
    /// ST cycles.
    pub st_cycles: usize,
}

/// Profile one accumulation width at a given activation BSL.
pub fn profile_layer(
    acc_products: usize,
    act_bsl: usize,
    inner_width_bits: usize,
    mse_trials: usize,
    seed: u64,
) -> LayerProfile {
    let width_bits = acc_products * act_bsl;
    let mut rng = Rng::new(seed);
    let exact = Bsn::new(width_bits).cost();
    let spatial = design_spatial(width_bits, 16);
    let spatial_mse = spatial.mse(0.5, mse_trials, &mut rng);
    let st = design_st(width_bits, inner_width_bits.min(width_bits), 16, 16);
    let st_mse = st.mse(0.5, mse_trials, &mut rng);
    LayerProfile {
        acc_products,
        width_bits,
        exact,
        spatial: spatial.cost(),
        spatial_mse,
        st: st.total_cost(),
        st_adp_norm: st.adp_throughput_normalized(exact.delay_ns),
        st_mse,
        st_cycles: st.total_cycles(),
    }
}

/// Profile the four ResNet-18 conv sizes (Fig 13).
pub fn profile_resnet18(act_bsl: usize, mse_trials: usize, seed: u64) -> Vec<LayerProfile> {
    RESNET18_ACC_WIDTHS
        .iter()
        .map(|&wprod| {
            profile_layer(wprod, act_bsl, RESNET18_ACC_WIDTHS[0] * act_bsl, mse_trials, seed)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spatial_designs_are_valid_and_cheaper() {
        for w in [1152usize, 2304, 4608, 9216] {
            let d = design_spatial(w, 16);
            assert_eq!(d.in_width(), w);
            assert_eq!(d.out_bsl(), 16);
            let exact = Bsn::new(w).cost();
            assert!(
                d.cost().area_um2 < exact.area_um2,
                "w={w}: {} !< {}",
                d.cost().area_um2,
                exact.area_um2
            );
        }
    }

    #[test]
    fn spatial_mse_negligible_for_balanced_inputs() {
        let d = design_spatial(9216, 16);
        let mut rng = Rng::new(3);
        let mse = d.mse(0.5, 200, &mut rng);
        assert!(mse < 1e-2, "mse={mse}");
    }

    #[test]
    fn st_designs_cycle_counts() {
        // Fig 12's shape: 4608 bits on a 576-bit inner = 8 + 1 cycles.
        let st = design_st(4608, 576, 16, 16);
        assert_eq!(st.total_cycles(), 9);
        // Fig 13: same inner serves all four sizes with varying cycles.
        for (i, &w) in RESNET18_ACC_WIDTHS.iter().enumerate() {
            let st = design_st(w * 2, 1152, 16, 16);
            assert_eq!(st.data_cycles(), 1 << i);
        }
    }

    #[test]
    fn search_respects_budget() {
        let r = search_spatial(2304, 16, 1e-3, 100, 7);
        assert!(r.mse <= 1e-3);
        assert_eq!(r.config.in_width(), 2304);
    }

    #[test]
    fn profile_orders_match_paper() {
        // Table V's ordering: exact > spatial > ST(normalized) in ADP,
        // with ST cheapest in area.
        let p = profile_layer(4608, 2, 1152, 50, 11);
        assert!(p.spatial.adp() < p.exact.adp(), "spatial must beat exact");
        assert!(p.st.area_um2 < p.spatial.area_um2, "ST must be smallest in area");
        assert!(p.st_adp_norm < p.exact.adp(), "ST normalized ADP must beat exact");
        assert!(p.st_cycles > 1);
    }

    #[test]
    fn divisor_helper() {
        assert_eq!(divisor_at_least(9216, 18), 18);
        assert_eq!(divisor_at_least(100, 7), 10);
        assert_eq!(divisor_at_least(13, 5), 13);
    }
}
