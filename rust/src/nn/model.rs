//! Model configurations and parameter containers.
//!
//! Two networks reproduce the paper's benchmarks (DESIGN.md
//! §Substitutions):
//!
//! * [`ModelCfg::tnn`] — the §II ternary CNN (conv + ReLU only, no
//!   BN/residual), for the SynthDigits (MNIST-substitute) experiments.
//! * [`ModelCfg::scnet`] — the §III SC-friendly network: ternary-weight
//!   convs, low-BSL activations, per-channel BN fused into the SI
//!   (Eq 1), and the **high-precision residual** path (Fig 6b): each
//!   residual conv consumes a BSL-16 tap of its input alongside the
//!   low-BSL main code.
//!
//! The dataflow is code-to-code: a layer's SI output *is* the next
//! layer's input code (scales `alpha_out` are trained parameters,
//! exported from JAX). Nothing is ever de-quantized on the datapath —
//! exactly the end-to-end SC property the paper claims.
//!
//! Parameter naming matches `python/compile/aot.py`'s metadata export:
//! `conv{i}.w`, `conv{i}.gamma`, `conv{i}.beta`, `conv{i}.alpha_out`,
//! `conv{i}.alpha_res`, plus `input.alpha` and `fc.w`.

use super::layers::ConvShape;
use super::tensor::Tensor;

/// One layer of the model.
#[derive(Clone, Debug)]
pub enum LayerCfg {
    /// Ternary-weight convolution with optional fused BN-ReLU and
    /// residual ports.
    Conv {
        /// Shape.
        shape: ConvShape,
        /// Fuse per-channel BN (Eq 1) into the activation.
        bn: bool,
        /// ReLU (fused with BN when both set).
        relu: bool,
        /// Consume the high-precision residual tap of the input.
        res_in: bool,
        /// Produce a high-precision (BSL-16) residual tap of the output.
        res_out: bool,
    },
    /// Global average pooling (count-domain sum; scale-free for the
    /// classifier).
    GlobalAvgPool,
    /// Final ternary linear classifier.
    Linear {
        /// Input features.
        in_dim: usize,
        /// Classes.
        out_dim: usize,
    },
}

/// A full model configuration.
#[derive(Clone, Debug)]
pub struct ModelCfg {
    /// Model name (artifact prefix).
    pub name: String,
    /// Input (C, H, W).
    pub input: (usize, usize, usize),
    /// Layers in order.
    pub layers: Vec<LayerCfg>,
    /// Number of classes.
    pub num_classes: usize,
}

impl ModelCfg {
    /// §II ternary CNN for SynthDigits (28×28×1, 10 classes). Stride-2
    /// convs replace pooling so every layer is an SC datapath.
    pub fn tnn() -> Self {
        let conv = |cin, cout, stride| LayerCfg::Conv {
            shape: ConvShape { cin, cout, k: 3, stride, pad: 1 },
            bn: false,
            relu: true,
            res_in: false,
            res_out: false,
        };
        Self {
            name: "tnn".into(),
            input: (1, 28, 28),
            layers: vec![
                conv(1, 8, 2),   // 14x14, acc width 9
                conv(8, 16, 2),  // 7x7,  acc width 72
                conv(16, 32, 2), // 4x4,  acc width 144
                LayerCfg::GlobalAvgPool,
                LayerCfg::Linear { in_dim: 32, out_dim: 10 },
            ],
            num_classes: 10,
        }
    }

    /// §III SC-friendly residual network for SynthCIFAR (32×32×3).
    pub fn scnet(num_classes: usize) -> Self {
        let conv = |cin, cout, stride, res_in, res_out| LayerCfg::Conv {
            shape: ConvShape { cin, cout, k: 3, stride, pad: 1 },
            bn: true,
            relu: true,
            res_in,
            res_out,
        };
        Self {
            name: "scnet".into(),
            input: (3, 32, 32),
            layers: vec![
                conv(3, 16, 1, false, true),   // stem          32x32
                conv(16, 16, 1, true, false),  // res block 1   32x32, acc 144
                conv(16, 32, 2, false, true),  // transition    16x16
                conv(32, 32, 1, true, false),  // res block 2   16x16, acc 288
                conv(32, 64, 2, false, true),  // transition    8x8
                conv(64, 64, 1, true, false),  // res block 3   8x8,  acc 576
                LayerCfg::GlobalAvgPool,
                LayerCfg::Linear { in_dim: 64, out_dim: num_classes },
            ],
            num_classes,
        }
    }

    /// Conv layer indices (for naming).
    pub fn conv_indices(&self) -> Vec<usize> {
        self.layers
            .iter()
            .enumerate()
            .filter(|(_, l)| matches!(l, LayerCfg::Conv { .. }))
            .map(|(i, _)| i)
            .collect()
    }

    /// Parameter names in export order (must match aot.py).
    pub fn param_names(&self) -> Vec<String> {
        let mut names = vec!["input.alpha".to_string()];
        let mut ci = 0usize;
        for l in &self.layers {
            match l {
                LayerCfg::Conv { bn, res_out, .. } => {
                    names.push(format!("conv{ci}.w"));
                    if *bn {
                        names.push(format!("conv{ci}.gamma"));
                        names.push(format!("conv{ci}.beta"));
                    }
                    names.push(format!("conv{ci}.alpha_out"));
                    if *res_out {
                        names.push(format!("conv{ci}.alpha_res"));
                    }
                    ci += 1;
                }
                LayerCfg::Linear { .. } => names.push("fc.w".to_string()),
                LayerCfg::GlobalAvgPool => {}
            }
        }
        names
    }

    /// Total accumulation widths of all conv layers (drives the BSN
    /// sizing — Fig 9 / Fig 13).
    pub fn acc_widths(&self) -> Vec<usize> {
        self.layers
            .iter()
            .filter_map(|l| match l {
                LayerCfg::Conv { shape, .. } => Some(shape.acc_width()),
                _ => None,
            })
            .collect()
    }

    /// Rough parameter count.
    pub fn param_count(&self) -> usize {
        self.layers
            .iter()
            .map(|l| match l {
                LayerCfg::Conv { shape, bn, .. } => {
                    shape.cout * shape.cin * shape.k * shape.k
                        + if *bn { 2 * shape.cout } else { 0 }
                }
                LayerCfg::Linear { in_dim, out_dim } => in_dim * out_dim,
                LayerCfg::GlobalAvgPool => 0,
            })
            .sum()
    }
}

/// Named parameter store.
#[derive(Clone, Debug, Default)]
pub struct ModelParams {
    entries: Vec<(String, Tensor)>,
}

impl ModelParams {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Build from (name, tensor) pairs.
    pub fn from_pairs(pairs: Vec<(String, Tensor)>) -> Self {
        Self { entries: pairs }
    }

    /// Insert (replacing an existing entry of the same name).
    pub fn insert(&mut self, name: &str, t: Tensor) {
        if let Some(e) = self.entries.iter_mut().find(|(n, _)| n == name) {
            e.1 = t;
        } else {
            self.entries.push((name.to_string(), t));
        }
    }

    /// Look up by name.
    pub fn get(&self, name: &str) -> Option<&Tensor> {
        self.entries.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    /// Scalar parameter.
    pub fn scalar(&self, name: &str) -> Option<f32> {
        self.get(name).map(|t| t.data()[0])
    }

    /// All entries in insertion order.
    pub fn entries(&self) -> &[(String, Tensor)] {
        &self.entries
    }

    /// Initialize random parameters for a config (He-style for weights,
    /// 1/0 for BN, small positive alphas) — used by tests and the pure
    /// Rust fallback when no trained artifact is available.
    pub fn init(cfg: &ModelCfg, rng: &mut crate::util::Rng) -> Self {
        let mut p = Self::new();
        p.insert("input.alpha", Tensor::from_vec(&[1], vec![0.5]));
        let mut ci = 0usize;
        for l in &cfg.layers {
            match l {
                LayerCfg::Conv { shape, bn, res_out, .. } => {
                    let fan_in = shape.acc_width() as f64;
                    let std = (2.0 / fan_in).sqrt();
                    let n = shape.cout * shape.cin * shape.k * shape.k;
                    let w: Vec<f32> =
                        (0..n).map(|_| rng.normal_ms(0.0, std) as f32).collect();
                    p.insert(
                        &format!("conv{ci}.w"),
                        Tensor::from_vec(&[shape.cout, shape.cin, shape.k, shape.k], w),
                    );
                    if *bn {
                        p.insert(
                            &format!("conv{ci}.gamma"),
                            Tensor::from_vec(&[shape.cout], vec![1.0; shape.cout]),
                        );
                        p.insert(
                            &format!("conv{ci}.beta"),
                            Tensor::from_vec(&[shape.cout], vec![0.0; shape.cout]),
                        );
                    }
                    p.insert(&format!("conv{ci}.alpha_out"), Tensor::from_vec(&[1], vec![0.5]));
                    if *res_out {
                        p.insert(&format!("conv{ci}.alpha_res"), Tensor::from_vec(&[1], vec![0.125]));
                    }
                    ci += 1;
                }
                LayerCfg::Linear { in_dim, out_dim } => {
                    let std = (2.0 / *in_dim as f64).sqrt();
                    let w: Vec<f32> = (0..in_dim * out_dim)
                        .map(|_| rng.normal_ms(0.0, std) as f32)
                        .collect();
                    p.insert("fc.w", Tensor::from_vec(&[*out_dim, *in_dim], w));
                }
                LayerCfg::GlobalAvgPool => {}
            }
        }
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tnn_structure() {
        let m = ModelCfg::tnn();
        assert_eq!(m.acc_widths(), vec![9, 72, 144]);
        assert_eq!(m.num_classes, 10);
        assert!(m.param_count() > 1000);
    }

    #[test]
    fn scnet_structure() {
        let m = ModelCfg::scnet(10);
        assert_eq!(m.acc_widths(), vec![27, 144, 144, 288, 288, 576]);
        // Names include residual alphas only where res_out is set.
        let names = m.param_names();
        assert!(names.contains(&"conv0.alpha_res".to_string()));
        assert!(!names.contains(&"conv1.alpha_res".to_string()));
        assert!(names.contains(&"fc.w".to_string()));
        assert_eq!(names[0], "input.alpha");
    }

    #[test]
    fn params_init_covers_all_names() {
        let m = ModelCfg::scnet(10);
        let mut rng = crate::util::Rng::new(1);
        let p = ModelParams::init(&m, &mut rng);
        for n in m.param_names() {
            assert!(p.get(&n).is_some(), "missing {n}");
        }
    }

    #[test]
    fn insert_replaces() {
        let mut p = ModelParams::new();
        p.insert("a", Tensor::from_vec(&[1], vec![1.0]));
        p.insert("a", Tensor::from_vec(&[1], vec![2.0]));
        assert_eq!(p.scalar("a"), Some(2.0));
        assert_eq!(p.entries().len(), 1);
    }
}
