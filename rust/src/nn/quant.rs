//! Quantization rules shared with the JAX QAT model.
//!
//! These functions are the Rust mirror of the fake-quant operators in
//! `python/compile/model.py`; both sides must round identically so the
//! bit-exact SC executor evaluates exactly the trained network.
//!
//! * **Weights** (ternary, BSL 2): per-tensor scale `alpha_w = mean|w|`;
//!   `w_q = clamp(round(w / alpha_w), -1, 1)`.
//! * **Activations** (thermometer, BSL `L`): per-layer scale `alpha_a`
//!   (a trained parameter); `x_q = clamp(round(x / alpha_a), -L/2, L/2)`.
//! * **Residuals** — same rule at the residual BSL (§III.B's
//!   high-precision residual uses BSL 16 → range ±8).

use super::tensor::Tensor;
use crate::coding::Ternary;

/// Structured weight-pruning rule applied at freeze time, before panel
/// packing. Pruning happens on the *float* magnitudes (so a weight that
/// ternarizes to ±1 can still be pruned) and zeroes the ternary codes;
/// [`crate::nn::gemm::TernaryPanel`] then drops the zeros from its
/// index lists entirely, so pruned weights cost nothing at inference.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum Pruning {
    /// No pruning (dense freeze).
    #[default]
    Off,
    /// N:M semi-structured sparsity: in every aligned group of `m`
    /// consecutive weights along the reduction axis, keep the `n`
    /// largest-magnitude weights and zero the rest.
    Nm {
        /// Weights kept per group.
        n: usize,
        /// Group size along the reduction axis.
        m: usize,
    },
    /// Block pruning: zero every aligned block of `size` consecutive
    /// weights along the reduction axis whose mean float magnitude is
    /// below half the ternary scale (the same `round(w/alpha)` rule the
    /// element-wise ternarizer uses, applied at block granularity).
    Block {
        /// Block length along the reduction axis.
        size: usize,
    },
}

/// Quantization configuration of one network variant — the paper's
/// `W-A-R/BSL` triple (Table IV) plus the freeze-time pruning rule.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Activation BSL (2, 4, 8, 16) or `None` for float (ablations).
    pub act_bsl: Option<usize>,
    /// Ternary weights when true; float weights otherwise.
    pub weight_ternary: bool,
    /// Residual BSL; `None` = no residual path or float residual.
    pub residual_bsl: Option<usize>,
    /// Structured weight pruning applied when freezing ternary panels.
    pub pruning: Pruning,
}

impl QuantConfig {
    /// The paper's headline config: W2-A2-R16.
    pub fn w2a2r16() -> Self {
        Self {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: Some(16),
            pruning: Pruning::Off,
        }
    }

    /// Fully float baseline.
    pub fn float() -> Self {
        Self {
            act_bsl: None,
            weight_ternary: false,
            residual_bsl: None,
            pruning: Pruning::Off,
        }
    }
}

/// A ternarized weight tensor.
#[derive(Clone, Debug)]
pub struct TernaryTensor {
    /// Quantized values.
    pub values: Vec<i8>,
    /// Shape (O, I, Kh, Kw) for conv, (O, I) for linear.
    pub shape: Vec<usize>,
    /// Scale factor: `w ≈ alpha * w_q`.
    pub alpha: f32,
}

impl TernaryTensor {
    /// Ternarize with the shared rule.
    pub fn quantize(w: &Tensor) -> Self {
        let alpha = w.mean_abs().max(1e-8);
        let values = w
            .data()
            .iter()
            .map(|&x| (x / alpha).round().clamp(-1.0, 1.0) as i8)
            .collect();
        Self { values, shape: w.shape().to_vec(), alpha }
    }

    /// Ternarize, then apply structured [`Pruning`] along the reduction
    /// axis. `row_width` is the reduction length of one output row
    /// (`acc_width` for conv panels, in-features for linear) and must
    /// tile the tensor; groups and blocks are aligned within each row
    /// so pruning never straddles two output channels. Selection uses
    /// the *float* magnitudes (ties keep the earlier index), so a
    /// weight that survives ternarization can still be pruned away.
    pub fn quantize_pruned(w: &Tensor, row_width: usize, pruning: Pruning) -> Self {
        let mut t = Self::quantize(w);
        if pruning == Pruning::Off || row_width == 0 {
            return t;
        }
        assert_eq!(
            t.values.len() % row_width,
            0,
            "pruning row width {row_width} must tile {} weights",
            t.values.len()
        );
        let mags = w.data();
        match pruning {
            Pruning::Off => {}
            Pruning::Nm { n, m } => {
                assert!(1 <= n && n <= m, "invalid N:M pruning {n}:{m}");
                let mut order: Vec<usize> = Vec::with_capacity(m);
                for (r, row) in t.values.chunks_mut(row_width).enumerate() {
                    let rmags = &mags[r * row_width..(r + 1) * row_width];
                    for g in (0..row_width).step_by(m) {
                        let end = (g + m).min(row_width);
                        if end - g <= n {
                            continue; // tail group smaller than the keep budget
                        }
                        order.clear();
                        order.extend(g..end);
                        // Stable sort: equal magnitudes keep the earlier index.
                        order.sort_by(|&a, &b| rmags[b].abs().total_cmp(&rmags[a].abs()));
                        for &drop in &order[n..] {
                            row[drop] = 0;
                        }
                    }
                }
            }
            Pruning::Block { size } => {
                assert!(size >= 1, "block pruning needs size >= 1");
                // A block survives iff its mean float magnitude rounds
                // to a nonzero ternary code — the element-wise rule
                // `round(|w|/alpha) >= 1` lifted to block granularity.
                let cut = 0.5 * t.alpha;
                for (r, row) in t.values.chunks_mut(row_width).enumerate() {
                    let rmags = &mags[r * row_width..(r + 1) * row_width];
                    for b in (0..row_width).step_by(size) {
                        let end = (b + size).min(row_width);
                        let mean = rmags[b..end].iter().map(|v| v.abs()).sum::<f32>()
                            / (end - b) as f32;
                        if mean < cut {
                            row[b..end].fill(0);
                        }
                    }
                }
            }
        }
        t
    }

    /// As [`Ternary`] symbols.
    pub fn ternary(&self, i: usize) -> Ternary {
        Ternary::from_i64(self.values[i] as i64)
    }

    /// Dequantized view.
    pub fn dequantize(&self) -> Tensor {
        Tensor::from_vec(
            &self.shape,
            self.values.iter().map(|&v| v as f32 * self.alpha).collect(),
        )
    }
}

/// A thermometer-quantized activation tensor.
#[derive(Clone, Debug)]
pub struct QuantTensor {
    /// Quantized integer values in `[-bsl/2, bsl/2]`.
    pub values: Vec<i32>,
    /// Shape.
    pub shape: Vec<usize>,
    /// BSL.
    pub bsl: usize,
    /// Scale factor.
    pub alpha: f32,
}

impl QuantTensor {
    /// Quantize activations at scale `alpha` and the given BSL.
    pub fn quantize(x: &Tensor, alpha: f32, bsl: usize) -> Self {
        let half = (bsl / 2) as f32;
        let a = alpha.max(1e-8);
        let values = x
            .data()
            .iter()
            .map(|&v| (v / a).round().clamp(-half, half) as i32)
            .collect();
        Self { values, shape: x.shape().to_vec(), bsl, alpha: a }
    }

    /// Dequantized view.
    pub fn dequantize(&self) -> Tensor {
        Tensor::from_vec(
            &self.shape,
            self.values.iter().map(|&v| v as f32 * self.alpha).collect(),
        )
    }

    /// Quantization levels available (`bsl + 1`).
    pub fn levels(&self) -> usize {
        self.bsl + 1
    }
}

/// Fake-quant (quantize → dequantize) for activations — the exact STE
/// forward the JAX model uses.
pub fn fake_quant_act(x: &Tensor, alpha: f32, bsl: usize) -> Tensor {
    QuantTensor::quantize(x, alpha, bsl).dequantize()
}

/// Fake-quant for weights.
pub fn fake_quant_weight(w: &Tensor) -> Tensor {
    TernaryTensor::quantize(w).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ternarize_signs_and_zeros() {
        let w = Tensor::from_vec(&[5], vec![0.9, -0.8, 0.05, -0.1, 0.4]);
        let t = TernaryTensor::quantize(&w);
        // alpha = mean|w| = 0.45; round(w/0.45) -> 2,-2,0,0,1 clamped.
        assert_eq!(t.values, vec![1, -1, 0, 0, 1]);
        assert!((t.alpha - 0.45).abs() < 1e-6);
    }

    #[test]
    fn act_quant_ranges() {
        let x = Tensor::from_vec(&[5], vec![3.0, -3.0, 0.4, 1.1, -0.6]);
        let q = QuantTensor::quantize(&x, 1.0, 4);
        assert_eq!(q.values, vec![2, -2, 0, 1, -1]);
        assert_eq!(q.levels(), 5);
    }

    #[test]
    fn fake_quant_roundtrip_error_bounded() {
        let x = Tensor::from_vec(&[4], vec![0.3, -0.7, 1.4, -2.9]);
        let fq = fake_quant_act(&x, 0.5, 16);
        for (a, b) in x.data().iter().zip(fq.data()) {
            assert!((a - b).abs() <= 0.25 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn dequantize_roundtrip() {
        let w = Tensor::from_vec(&[3], vec![0.5, -0.5, 0.0]);
        let t = TernaryTensor::quantize(&w);
        let d = t.dequantize();
        assert_eq!(d.shape(), &[3]);
        for (orig, deq) in w.data().iter().zip(d.data()) {
            assert!((orig - deq).abs() <= t.alpha);
        }
    }

    #[test]
    fn headline_config() {
        let c = QuantConfig::w2a2r16();
        assert_eq!(c.act_bsl, Some(2));
        assert!(c.weight_ternary);
        assert_eq!(c.residual_bsl, Some(16));
        assert_eq!(c.pruning, Pruning::Off);
    }

    #[test]
    fn nm_pruning_keeps_the_n_largest_per_group() {
        // Two rows of width 8, 2:4 pruning: each aligned group of 4
        // keeps its two largest float magnitudes.
        let w = Tensor::from_vec(
            &[2, 8],
            vec![
                0.9, -0.8, 0.05, 0.7, /* | */ 0.1, 0.2, -0.3, 0.4, //
                0.5, 0.5, 0.5, 0.5, /* | */ -0.9, 0.0, 0.0, 0.9,
            ],
        );
        let dense = TernaryTensor::quantize(&w);
        let t = TernaryTensor::quantize_pruned(&w, 8, Pruning::Nm { n: 2, m: 4 });
        assert_eq!(t.alpha, dense.alpha, "pruning must not move the scale");
        // Row 0 group 0 keeps 0.9 and -0.8; group 1 keeps -0.3 and 0.4.
        assert_eq!(
            &t.values[..8],
            &[dense.values[0], dense.values[1], 0, 0, 0, 0, dense.values[6], dense.values[7]]
        );
        // Row 1 group 0 is a four-way tie: earlier indices win.
        assert_eq!(
            &t.values[8..],
            &[dense.values[8], dense.values[9], 0, 0, dense.values[12], 0, 0, dense.values[15]]
        );
        // n == m is a structural no-op.
        let same = TernaryTensor::quantize_pruned(&w, 8, Pruning::Nm { n: 4, m: 4 });
        assert_eq!(same.values, dense.values);
    }

    #[test]
    fn block_pruning_zeros_weak_blocks_only() {
        let w = Tensor::from_vec(
            &[1, 8],
            vec![0.9, 0.8, 0.9, 0.8, 0.01, 0.02, 0.01, 0.02],
        );
        let t = TernaryTensor::quantize_pruned(&w, 8, Pruning::Block { size: 4 });
        let dense = TernaryTensor::quantize(&w);
        assert_eq!(&t.values[..4], &dense.values[..4], "strong block survives");
        assert_eq!(&t.values[4..], &[0, 0, 0, 0], "weak block is dropped whole");
    }

    #[test]
    fn pruning_off_matches_plain_quantize() {
        let w = Tensor::from_vec(&[3, 4], (0..12).map(|i| (i as f32 - 6.0) * 0.1).collect());
        let a = TernaryTensor::quantize(&w);
        let b = TernaryTensor::quantize_pruned(&w, 4, Pruning::Off);
        assert_eq!(a.values, b.values);
        assert_eq!(a.alpha, b.alpha);
    }
}
