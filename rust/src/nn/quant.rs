//! Quantization rules shared with the JAX QAT model.
//!
//! These functions are the Rust mirror of the fake-quant operators in
//! `python/compile/model.py`; both sides must round identically so the
//! bit-exact SC executor evaluates exactly the trained network.
//!
//! * **Weights** (ternary, BSL 2): per-tensor scale `alpha_w = mean|w|`;
//!   `w_q = clamp(round(w / alpha_w), -1, 1)`.
//! * **Activations** (thermometer, BSL `L`): per-layer scale `alpha_a`
//!   (a trained parameter); `x_q = clamp(round(x / alpha_a), -L/2, L/2)`.
//! * **Residuals** — same rule at the residual BSL (§III.B's
//!   high-precision residual uses BSL 16 → range ±8).

use super::tensor::Tensor;
use crate::coding::Ternary;

/// Quantization configuration of one network variant — the paper's
/// `W-A-R/BSL` triple (Table IV).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct QuantConfig {
    /// Activation BSL (2, 4, 8, 16) or `None` for float (ablations).
    pub act_bsl: Option<usize>,
    /// Ternary weights when true; float weights otherwise.
    pub weight_ternary: bool,
    /// Residual BSL; `None` = no residual path or float residual.
    pub residual_bsl: Option<usize>,
}

impl QuantConfig {
    /// The paper's headline config: W2-A2-R16.
    pub fn w2a2r16() -> Self {
        Self { act_bsl: Some(2), weight_ternary: true, residual_bsl: Some(16) }
    }

    /// Fully float baseline.
    pub fn float() -> Self {
        Self { act_bsl: None, weight_ternary: false, residual_bsl: None }
    }
}

/// A ternarized weight tensor.
#[derive(Clone, Debug)]
pub struct TernaryTensor {
    /// Quantized values.
    pub values: Vec<i8>,
    /// Shape (O, I, Kh, Kw) for conv, (O, I) for linear.
    pub shape: Vec<usize>,
    /// Scale factor: `w ≈ alpha * w_q`.
    pub alpha: f32,
}

impl TernaryTensor {
    /// Ternarize with the shared rule.
    pub fn quantize(w: &Tensor) -> Self {
        let alpha = w.mean_abs().max(1e-8);
        let values = w
            .data()
            .iter()
            .map(|&x| (x / alpha).round().clamp(-1.0, 1.0) as i8)
            .collect();
        Self { values, shape: w.shape().to_vec(), alpha }
    }

    /// As [`Ternary`] symbols.
    pub fn ternary(&self, i: usize) -> Ternary {
        Ternary::from_i64(self.values[i] as i64)
    }

    /// Dequantized view.
    pub fn dequantize(&self) -> Tensor {
        Tensor::from_vec(
            &self.shape,
            self.values.iter().map(|&v| v as f32 * self.alpha).collect(),
        )
    }
}

/// A thermometer-quantized activation tensor.
#[derive(Clone, Debug)]
pub struct QuantTensor {
    /// Quantized integer values in `[-bsl/2, bsl/2]`.
    pub values: Vec<i32>,
    /// Shape.
    pub shape: Vec<usize>,
    /// BSL.
    pub bsl: usize,
    /// Scale factor.
    pub alpha: f32,
}

impl QuantTensor {
    /// Quantize activations at scale `alpha` and the given BSL.
    pub fn quantize(x: &Tensor, alpha: f32, bsl: usize) -> Self {
        let half = (bsl / 2) as f32;
        let a = alpha.max(1e-8);
        let values = x
            .data()
            .iter()
            .map(|&v| (v / a).round().clamp(-half, half) as i32)
            .collect();
        Self { values, shape: x.shape().to_vec(), bsl, alpha: a }
    }

    /// Dequantized view.
    pub fn dequantize(&self) -> Tensor {
        Tensor::from_vec(
            &self.shape,
            self.values.iter().map(|&v| v as f32 * self.alpha).collect(),
        )
    }

    /// Quantization levels available (`bsl + 1`).
    pub fn levels(&self) -> usize {
        self.bsl + 1
    }
}

/// Fake-quant (quantize → dequantize) for activations — the exact STE
/// forward the JAX model uses.
pub fn fake_quant_act(x: &Tensor, alpha: f32, bsl: usize) -> Tensor {
    QuantTensor::quantize(x, alpha, bsl).dequantize()
}

/// Fake-quant for weights.
pub fn fake_quant_weight(w: &Tensor) -> Tensor {
    TernaryTensor::quantize(w).dequantize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ternarize_signs_and_zeros() {
        let w = Tensor::from_vec(&[5], vec![0.9, -0.8, 0.05, -0.1, 0.4]);
        let t = TernaryTensor::quantize(&w);
        // alpha = mean|w| = 0.45; round(w/0.45) -> 2,-2,0,0,1 clamped.
        assert_eq!(t.values, vec![1, -1, 0, 0, 1]);
        assert!((t.alpha - 0.45).abs() < 1e-6);
    }

    #[test]
    fn act_quant_ranges() {
        let x = Tensor::from_vec(&[5], vec![3.0, -3.0, 0.4, 1.1, -0.6]);
        let q = QuantTensor::quantize(&x, 1.0, 4);
        assert_eq!(q.values, vec![2, -2, 0, 1, -1]);
        assert_eq!(q.levels(), 5);
    }

    #[test]
    fn fake_quant_roundtrip_error_bounded() {
        let x = Tensor::from_vec(&[4], vec![0.3, -0.7, 1.4, -2.9]);
        let fq = fake_quant_act(&x, 0.5, 16);
        for (a, b) in x.data().iter().zip(fq.data()) {
            assert!((a - b).abs() <= 0.25 + 1e-6, "{a} vs {b}");
        }
    }

    #[test]
    fn dequantize_roundtrip() {
        let w = Tensor::from_vec(&[3], vec![0.5, -0.5, 0.0]);
        let t = TernaryTensor::quantize(&w);
        let d = t.dequantize();
        assert_eq!(d.shape(), &[3]);
        for (orig, deq) in w.data().iter().zip(d.data()) {
            assert!((orig - deq).abs() <= t.alpha);
        }
    }

    #[test]
    fn headline_config() {
        let c = QuantConfig::w2a2r16();
        assert_eq!(c.act_bsl, Some(2));
        assert!(c.weight_ternary);
        assert_eq!(c.residual_bsl, Some(16));
    }
}
