//! Conventional binary (fixed-point / float) executors — the baselines
//! the paper compares against.
//!
//! Two roles:
//!
//! 1. **Reference semantics** ([`forward_float`]): float forward with
//!    optional fake-quantization, used for the Table III ablations
//!    (FP/2b weight × FP/2b activation) and as the oracle the SC
//!    executor is validated against.
//! 2. **Binary fault baseline** ([`BinaryExecutor`]): the same quantized
//!    network on a conventional two's-complement datapath where bit
//!    errors flip *weighted* bits — an MSB flip corrupts the result
//!    catastrophically, which is exactly why Fig 5 shows binary designs
//!    degrading much faster than SC at equal BER.

use std::sync::Arc;

use crate::fault::inject;
use crate::util::Rng;
use super::layers;
use super::model::{LayerCfg, ModelCfg, ModelParams};
use super::quant::{fake_quant_act, fake_quant_weight, QuantConfig};
use super::sc_exec::{CodeMap, FaultCfg, Prepared};
use super::tensor::Tensor;

/// Float reference forward with optional fake-quant (Table III / Fig 8
/// ablations). Residual taps follow the same high-precision rule as the
/// SC model.
pub fn forward_float(
    cfg: &ModelCfg,
    params: &ModelParams,
    quant: QuantConfig,
    image: &Tensor,
) -> Vec<f32> {
    let mut x = image.clone();
    // Input quantization (when activations are quantized).
    if let Some(bsl) = quant.act_bsl {
        let a = params.scalar("input.alpha").unwrap();
        x = fake_quant_act(&x, a, bsl);
    }
    let mut res: Option<Tensor> = None;
    let mut ci = 0usize;
    let mut gap: Option<Tensor> = None;
    for l in &cfg.layers {
        match l {
            LayerCfg::Conv { shape, bn, relu, res_in, res_out } => {
                let mut w = params.get(&format!("conv{ci}.w")).unwrap().clone();
                if quant.weight_ternary {
                    w = fake_quant_weight(&w);
                }
                let mut y = layers::conv2d(&x, &w, shape);
                if *res_in {
                    let r = res.as_ref().expect("residual tap missing");
                    assert_eq!(r.shape(), y.shape());
                    for (yv, rv) in y.data_mut().iter_mut().zip(r.data()) {
                        *yv += rv;
                    }
                }
                if *bn {
                    let g = params.get(&format!("conv{ci}.gamma")).unwrap().data();
                    let b = params.get(&format!("conv{ci}.beta")).unwrap().data();
                    y = layers::bn(&y, g, b);
                }
                if *relu {
                    y = layers::relu(&y);
                }
                if *res_out {
                    let mut tap = y.clone();
                    if let Some(rbsl) = quant.residual_bsl {
                        let a = params.scalar(&format!("conv{ci}.alpha_res")).unwrap();
                        tap = fake_quant_act(&tap, a, rbsl);
                    }
                    res = Some(tap);
                }
                if let Some(bsl) = quant.act_bsl {
                    let a = params.scalar(&format!("conv{ci}.alpha_out")).unwrap();
                    y = fake_quant_act(&y, a, bsl);
                }
                x = y;
                ci += 1;
            }
            LayerCfg::GlobalAvgPool => {
                gap = Some(layers::global_avgpool(&x));
            }
            LayerCfg::Linear { in_dim, out_dim } => {
                let input = gap.clone().unwrap_or_else(|| {
                    x.clone().reshape(&[x.len()])
                });
                assert_eq!(input.len(), *in_dim);
                let mut w = params.get("fc.w").unwrap().clone();
                if quant.weight_ternary {
                    w = fake_quant_weight(&w);
                }
                let _ = out_dim;
                return layers::linear(&input, &w).into_vec();
            }
        }
    }
    panic!("model has no classifier");
}

/// Accuracy of the float/fake-quant reference.
pub fn accuracy_float(
    cfg: &ModelCfg,
    params: &ModelParams,
    quant: QuantConfig,
    images: &[Tensor],
    labels: &[usize],
) -> f64 {
    let hits = images
        .iter()
        .zip(labels)
        .filter(|(im, &l)| {
            let logits = forward_float(cfg, params, quant, im);
            Tensor::from_vec(&[logits.len()], logits.clone()).argmax() == l
        })
        .count();
    hits as f64 / labels.len().max(1) as f64
}

/// Binary fixed-point executor over the same frozen network as the SC
/// executor, with faults injected into two's-complement words.
pub struct BinaryExecutor {
    prep: Arc<Prepared>,
    fault: Option<FaultCfg>,
}

impl BinaryExecutor {
    /// Fault-free. Accepts an owned [`Prepared`] or a shared
    /// `Arc<Prepared>` (pool workers share one frozen model).
    pub fn new(prep: impl Into<Arc<Prepared>>) -> Self {
        Self { prep: prep.into(), fault: None }
    }

    /// With word-level fault injection.
    pub fn with_faults(prep: impl Into<Arc<Prepared>>, fault: FaultCfg) -> Self {
        Self { prep: prep.into(), fault: Some(fault) }
    }

    /// The frozen network.
    pub fn prepared(&self) -> &Prepared {
        &self.prep
    }

    /// Forward one image → integer class scores. Fault-free, this is
    /// numerically identical to [`super::sc_exec::ScExecutor::forward`]
    /// (asserted in `rust/tests/sc_pipeline.rs`): the binary chip
    /// computes the same quantized network, just in binary words.
    /// Equivalent to [`BinaryExecutor::forward_with_tag`] at tag 0.
    pub fn forward(&self, image: &Tensor) -> Vec<i64> {
        self.forward_with_tag(image, 0)
    }

    /// Forward with an explicit image tag. The fault RNG is seeded from
    /// `(seed, tag)` ([`inject::image_seed`]), so each image's draws are
    /// independent of evaluation order — the reproducibility contract
    /// shared with the SC fault path.
    pub fn forward_with_tag(&self, image: &Tensor, tag: u64) -> Vec<i64> {
        let mut rng = self.fault.map(|f| Rng::new(inject::image_seed(f.seed, tag)));
        let act_bsl = self.prep.act_bsl();
        let half = (act_bsl / 2) as f32;
        let mut main = CodeMap {
            q: image
                .data()
                .iter()
                .map(|&v| (v / self.prep.input_alpha).round().clamp(-half, half) as i32)
                .collect(),
            dims: self.prep.cfg.input,
            bsl: act_bsl,
        };
        let mut res: Option<CodeMap> = None;
        let mut li = 0usize;
        let mut gap: Option<Vec<i64>> = None;
        // Integer im2col + GEMM count scratch reused across layers (no
        // per-layer float tensor round-trip, no per-layer allocation).
        let mut cols: Vec<i32> = Vec::new();
        let mut acc: Vec<i64> = Vec::new();
        for l in &self.prep.cfg.layers {
            match l {
                LayerCfg::Conv { .. } => {
                    let pc = &self.prep.convs[li];
                    let (m, r) =
                        self.conv_layer(pc, &main, res.as_ref(), rng.as_mut(), &mut cols, &mut acc);
                    main = m;
                    if r.is_some() {
                        res = r;
                    }
                    li += 1;
                }
                LayerCfg::GlobalAvgPool => {
                    let (c, h, w) = main.dims;
                    let mut sums = vec![0i64; c];
                    for ci in 0..c {
                        for p in 0..h * w {
                            sums[ci] += main.q[ci * h * w + p] as i64;
                        }
                    }
                    gap = Some(sums);
                }
                LayerCfg::Linear { in_dim, out_dim } => {
                    let x = gap
                        .clone()
                        .unwrap_or_else(|| main.q.iter().map(|&v| v as i64).collect());
                    assert_eq!(x.len(), *in_dim);
                    // Classifier through the dense packed panel (the
                    // binary family's GEMM format).
                    let fc = &self.prep.fc_panels.dense;
                    let logits: Vec<i64> =
                        (0..*out_dim).map(|o| fc.row_dot_i64(o, &x)).collect();
                    return logits;
                }
            }
        }
        panic!("model has no classifier");
    }

    fn conv_layer(
        &self,
        pc: &super::sc_exec::PreparedConv,
        main: &CodeMap,
        res: Option<&CodeMap>,
        mut rng: Option<&mut Rng>,
        cols: &mut Vec<i32>,
        acc: &mut Vec<i64>,
    ) -> (CodeMap, Option<CodeMap>) {
        let (cin, h, w) = main.dims;
        let acc_w = pc.shape.acc_width();
        let (oh, ow) = pc.shape.out_hw(h, w);
        let npix = oh * ow;
        cols.clear();
        cols.resize(npix * acc_w, 0);
        layers::im2col_i32_into(&main.q, (cin, h, w), &pc.shape, cols);
        // Accumulator word width for fault injection: enough for the
        // worst-case accumulation.
        let acc_bits = (64 - (pc.bsn_width as u64).leading_zeros()).max(8) as u32;
        let ber = self.fault.map(|f| f.ber).unwrap_or(0.0);

        // Fault-free accumulation is one dense i8-panel GEMM (the
        // 4×-wide microkernel over the panel packed at freeze time);
        // the word-fault path below must walk scalar words to inject
        // per-word flips in the same draw order as before.
        if rng.is_none() {
            // Grow-only scratch, never cleared: gemm_into overwrites
            // every element it hands out, so stale counts from another
            // layer never survive into a read.
            if acc.len() < pc.shape.cout * npix {
                acc.resize(pc.shape.cout * npix, 0);
            }
            pc.panels.dense.gemm_into(cols, npix, &mut acc[..pc.shape.cout * npix]);
        }

        let mut out_main = vec![0i32; pc.shape.cout * npix];
        let mut out_res = pc.si_res.as_ref().map(|_| vec![0i32; pc.shape.cout * npix]);
        let half = (main.bsl / 2) as i64;
        for co in 0..pc.shape.cout {
            let wrow = &pc.wq.values[co * acc_w..(co + 1) * acc_w];
            for p in 0..npix {
                let dot: i64 = if let Some(r) = rng.as_deref_mut() {
                    let xr = &cols[p * acc_w..(p + 1) * acc_w];
                    let mut s = 0i64;
                    for i in 0..acc_w {
                        // Activation word faults (sign + 3 magnitude bits).
                        let q = flip_word((xr[i] as i64).clamp(-half, half), 4, ber, r);
                        s += q * wrow[i] as i64;
                    }
                    s
                } else {
                    acc[co * npix + p]
                };
                // Count-domain offset identical to the SC path.
                let mut count = dot + (acc_w as i64) * half;
                if pc.res_in {
                    let rm = res.expect("residual map");
                    let rhalf = (rm.bsl / 2) as i64;
                    let rq = rm.q[co * oh * ow + p] as i64;
                    let rcount =
                        super::sc_exec::align_res_count((rq + rhalf) as usize, rm.bsl, pc.res_shift);
                    count += rcount as i64;
                }
                if let Some(r) = rng.as_deref_mut() {
                    // Accumulator word faults — the binary killer: a
                    // flipped MSB shifts the result by half the range.
                    count = flip_word(count, acc_bits, ber, r);
                }
                let count = count.clamp(0, pc.bsn_width as i64) as usize;
                let cmain = pc.si_main[co].apply_count(count);
                out_main[co * npix + p] =
                    cmain as i32 - (pc.si_main[co].out_bsl() / 2) as i32;
                if let Some(ref sis) = pc.si_res {
                    let cres = sis[co].apply_count(count);
                    out_res.as_mut().unwrap()[co * npix + p] =
                        cres as i32 - (sis[co].out_bsl() / 2) as i32;
                }
            }
        }
        let mm = CodeMap { q: out_main, dims: (pc.shape.cout, oh, ow), bsl: main.bsl };
        let rm = out_res.map(|q| CodeMap { q, dims: (pc.shape.cout, oh, ow), bsl: self.prep.res_bsl() });
        (mm, rm)
    }

    /// Predicted classes. Images are tagged by index, matching the SC
    /// executor's convention.
    pub fn predict(&self, images: &[Tensor]) -> Vec<usize> {
        images
            .iter()
            .enumerate()
            .map(|(i, im)| {
                let l = self.forward_with_tag(im, i as u64);
                l.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap()
            })
            .collect()
    }

    /// Accuracy.
    pub fn accuracy(&self, images: &[Tensor], labels: &[usize]) -> f64 {
        let preds = self.predict(images);
        preds.iter().zip(labels).filter(|(p, l)| p == l).count() as f64
            / labels.len().max(1) as f64
    }
}

/// Flip bits of a two's-complement word of `bits` width with per-bit
/// probability `ber`.
pub fn flip_word(v: i64, bits: u32, ber: f64, rng: &mut Rng) -> i64 {
    if ber <= 0.0 {
        return v;
    }
    let mut u = (v as u64) & ((1u64 << bits) - 1);
    for b in 0..bits {
        if rng.gen_bool(ber) {
            u ^= 1 << b;
        }
    }
    // Sign-extend back.
    let sign = 1u64 << (bits - 1);
    if u & sign != 0 {
        (u as i64) - (1i64 << bits)
    } else {
        u as i64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::ModelCfg;
    use crate::nn::quant::Pruning;
    use crate::nn::sc_exec::ScExecutor;

    #[test]
    fn float_forward_shapes() {
        let cfg = ModelCfg::scnet(10);
        let mut rng = Rng::new(2);
        let params = ModelParams::init(&cfg, &mut rng);
        let img = Tensor::from_vec(
            &[3, 32, 32],
            (0..3 * 32 * 32).map(|_| rng.normal() as f32 * 0.3).collect(),
        );
        let fp = forward_float(&cfg, &params, QuantConfig::float(), &img);
        assert_eq!(fp.len(), 10);
        let q = forward_float(&cfg, &params, QuantConfig::w2a2r16(), &img);
        assert_eq!(q.len(), 10);
    }

    #[test]
    fn binary_matches_sc_fault_free() {
        // The central parity check: identical logits from the SC
        // bitstream machinery and the binary integer datapath.
        let cfg = ModelCfg::scnet(10);
        let mut rng = Rng::new(4);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Prepared::new(&cfg, &params, QuantConfig::w2a2r16());
        let sc = ScExecutor::new(prep.clone());
        let bin = BinaryExecutor::new(prep);
        for s in 0..3 {
            let mut r2 = Rng::new(100 + s);
            let img = Tensor::from_vec(
                &[3, 32, 32],
                (0..3 * 32 * 32).map(|_| r2.normal() as f32 * 0.4).collect(),
            );
            assert_eq!(sc.forward(&img), bin.forward(&img), "seed {s}");
        }
    }

    #[test]
    fn flip_word_sign_extension() {
        let mut rng = Rng::new(1);
        // ber=0 identity.
        assert_eq!(flip_word(-5, 8, 0.0, &mut rng), -5);
        // ber=1 flips everything: ~v within the window.
        let v = flip_word(0, 4, 1.0, &mut rng);
        assert_eq!(v, -1); // 0b1111 sign-extended
    }

    #[test]
    fn faults_degrade_binary_more_than_sc() {
        // Micro version of Fig 5's claim at one BER point.
        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(6);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        );
        let clean = BinaryExecutor::new(prep.clone());
        let imgs: Vec<Tensor> = (0..24)
            .map(|i| {
                let mut r = Rng::new(1000 + i);
                Tensor::from_vec(&[1, 28, 28], (0..784).map(|_| r.normal() as f32).collect())
            })
            .collect();
        let labels = clean.predict(&imgs); // self-labels: measure drift
        let ber = 0.02;
        let sc_f = ScExecutor::with_faults(prep.clone(), FaultCfg { ber, seed: 9 });
        let bin_f = BinaryExecutor::with_faults(prep, FaultCfg { ber, seed: 9 });
        let acc_sc = sc_f.accuracy(&imgs, &labels);
        let acc_bin = bin_f.accuracy(&imgs, &labels);
        assert!(
            acc_sc >= acc_bin,
            "SC ({acc_sc}) should tolerate faults at least as well as binary ({acc_bin})"
        );
    }
}
