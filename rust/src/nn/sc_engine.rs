//! Batched, serving-grade SC inference engine.
//!
//! [`ScEngine`] evaluates the same frozen network as
//! [`super::sc_exec::ScExecutor`] — bit-identical logits, asserted in
//! `rust/tests/sc_serve.rs` — but is shaped for the request path
//! instead of offline experiments:
//!
//! * **Shared model.** The engine holds `Arc<Prepared>`, so a pool of
//!   workers shares one copy of the ternarized weights and SI tables
//!   instead of deep-cloning them per worker.
//! * **Pre-sized scratch arenas.** All intermediate state — im2col
//!   column buffers, ping-pong activation planes, residual planes and
//!   the GAP accumulator — is allocated once at construction from the
//!   model's static geometry and reused for every image. The
//!   steady-state forward path performs **no heap allocation**: the
//!   inner conv loop is integer dot products plus table lookups over
//!   caller-owned slices (the `*_into` discipline of
//!   [`crate::coding::thermometer`] and [`crate::circuits`]).
//! * **Synthesized count tables.** Per-channel selective interconnects
//!   and the residual re-scaling block are folded into lookup tables at
//!   construction ([`SelectiveInterconnect::count_table`],
//!   [`align_res_count`]), which is exact: both are pure monotone
//!   functions of the accumulated count. This is the same
//!   "deterministic coding makes everything a count function" property
//!   the paper builds on (DESIGN.md §Hardware-Adaptation: activations
//!   stay thermometer/ternary codes end-to-end, so a layer is fully
//!   described by its count-transfer function) — the engine just
//!   evaluates that function by indexed load instead of tap scan.
//!
//! The engine is the fault-free serving path; fault injection (Fig 5)
//! stays on [`super::sc_exec::ScExecutor`], which walks actual bit
//! streams — since `crate::coding::BitVec` packs those streams into
//! native `u64` words, no byte-per-bit (`Vec<bool>`) buffer exists
//! anywhere on a serving path, packed planes and integer count planes
//! only (DESIGN.md §Perf, "Packed representation"). Throughput floors
//! for both live in DESIGN.md §Perf and are tracked by
//! `rust/benches/sc_serve.rs` → `BENCH_sc.json`.

use std::sync::Arc;

use crate::circuits::si::SelectiveInterconnect;
use super::layers::im2col_i32_into;
use super::model::LayerCfg;
use super::sc_exec::{align_res_count, Prepared};
use super::tensor::Tensor;

/// Per-conv-layer execution plan: static geometry plus the synthesized
/// count tables, so the hot loop touches no model-construction code.
struct ConvPlan {
    /// Input plane dims (C, H, W).
    in_dims: (usize, usize, usize),
    /// Output spatial dims.
    oh: usize,
    ow: usize,
    /// Accumulation width (products per output pixel).
    acc_w: usize,
    /// Count-domain offset `acc_w · L/2` added to the dot product.
    base: i64,
    /// LUT row width: `bsn_width + 1` (one entry per possible count).
    lut_w: usize,
    /// Main SI transfer, channel-major `cout × lut_w`, already offset
    /// to signed codes: `lut[c] = apply_count(c) - out_bsl/2`.
    si_main_lut: Vec<i32>,
    /// Residual-tap SI transfer (layers with `res_out`).
    si_res_lut: Option<Vec<i32>>,
    /// Residual alignment `res count → aligned count` (§III.C), for
    /// layers with `res_in`. Indexed by `rq + res_bsl/2 ∈ 0..=res_bsl`.
    align_lut: Option<Vec<i64>>,
}

/// The batched SC inference engine. See the module docs.
pub struct ScEngine {
    prep: Arc<Prepared>,
    plans: Vec<ConvPlan>,
    /// im2col scratch, sized for the widest layer.
    cols: Vec<i32>,
    /// Ping-pong activation planes (input of the current layer lives in
    /// `plane_a`, its output is written to `plane_b`, then swapped).
    plane_a: Vec<i32>,
    plane_b: Vec<i32>,
    /// Ping-pong residual planes (read old tap, write new tap).
    res_a: Vec<i32>,
    res_b: Vec<i32>,
    /// Global-average-pool accumulator.
    gap: Vec<i64>,
}

impl ScEngine {
    /// Build an engine over a frozen network, pre-sizing every scratch
    /// arena from the model's static geometry and synthesizing the
    /// per-channel count tables.
    pub fn new(prep: impl Into<Arc<Prepared>>) -> Self {
        let prep: Arc<Prepared> = prep.into();
        let act_bsl = prep.act_bsl();
        let half = (act_bsl / 2) as i64;
        let res_bsl = prep.res_bsl();
        let mut dims = prep.cfg.input;
        let mut res_dims: Option<(usize, usize, usize)> = None;
        let mut plans = Vec::with_capacity(prep.convs.len());
        let mut max_cols = 0usize;
        let mut max_plane = dims.0 * dims.1 * dims.2;
        let mut max_res = 0usize;
        let mut max_ch = dims.0;
        let mut ci = 0usize;
        for l in &prep.cfg.layers {
            if let LayerCfg::Conv { shape, .. } = l {
                let pc = &prep.convs[ci];
                let (oh, ow) = shape.out_hw(dims.1, dims.2);
                let npix = oh * ow;
                let acc_w = shape.acc_width();
                let lut_w = pc.bsn_width + 1;
                let si_main_lut = flatten_si_luts(&pc.si_main, lut_w);
                let si_res_lut =
                    pc.si_res.as_ref().map(|sis| flatten_si_luts(sis, lut_w));
                let align_lut = if pc.res_in {
                    let rd = res_dims.expect("res_in conv without a residual producer");
                    assert_eq!(
                        rd,
                        (shape.cout, oh, ow),
                        "residual tap geometry must match the consuming conv output"
                    );
                    Some(
                        (0..=res_bsl)
                            .map(|c| align_res_count(c, res_bsl, pc.res_shift) as i64)
                            .collect(),
                    )
                } else {
                    None
                };
                plans.push(ConvPlan {
                    in_dims: dims,
                    oh,
                    ow,
                    acc_w,
                    base: acc_w as i64 * half,
                    lut_w,
                    si_main_lut,
                    si_res_lut,
                    align_lut,
                });
                max_cols = max_cols.max(npix * acc_w);
                dims = (shape.cout, oh, ow);
                max_plane = max_plane.max(dims.0 * dims.1 * dims.2);
                if pc.si_res.is_some() {
                    res_dims = Some(dims);
                    max_res = max_res.max(dims.0 * dims.1 * dims.2);
                }
                max_ch = max_ch.max(shape.cout);
                ci += 1;
            }
        }
        Self {
            prep,
            plans,
            cols: vec![0; max_cols],
            plane_a: vec![0; max_plane],
            plane_b: vec![0; max_plane],
            res_a: vec![0; max_res],
            res_b: vec![0; max_res],
            gap: vec![0; max_ch],
        }
    }

    /// The frozen network.
    pub fn prepared(&self) -> &Prepared {
        &self.prep
    }

    /// The shared handle to the frozen network.
    pub fn prepared_arc(&self) -> &Arc<Prepared> {
        &self.prep
    }

    /// Flattened image length (C·H·W).
    pub fn image_len(&self) -> usize {
        let (c, h, w) = self.prep.cfg.input;
        c * h * w
    }

    /// Logits per image.
    pub fn classes(&self) -> usize {
        self.prep.cfg.num_classes
    }

    /// Forward one flat CHW image into a caller-owned logits slice.
    /// Allocation-free in steady state; bit-identical to
    /// [`super::sc_exec::ScExecutor::forward`].
    pub fn forward_into(&mut self, image: &[f32], logits: &mut [i64]) {
        let Self { prep, plans, cols, plane_a, plane_b, res_a, res_b, gap } = self;
        let prep: &Prepared = &**prep;
        let (c0, h0, w0) = prep.cfg.input;
        let n0 = c0 * h0 * w0;
        assert_eq!(image.len(), n0, "image length mismatch");
        assert_eq!(logits.len(), prep.cfg.num_classes, "logits length mismatch");
        // Input encoding at the trained scale (same rule as ScExecutor).
        let halff = (prep.act_bsl() / 2) as f32;
        for (dst, &v) in plane_a[..n0].iter_mut().zip(image.iter()) {
            *dst = (v / prep.input_alpha).round().clamp(-halff, halff) as i32;
        }
        let rhalf = (prep.res_bsl() / 2) as i64;
        let mut dims = prep.cfg.input;
        let mut li = 0usize;
        let mut gap_len: Option<usize> = None;
        for l in &prep.cfg.layers {
            match l {
                LayerCfg::Conv { .. } => {
                    let pc = &prep.convs[li];
                    let plan = &plans[li];
                    let (cin, h, w) = plan.in_dims;
                    let npix = plan.oh * plan.ow;
                    let acc = plan.acc_w;
                    im2col_i32_into(
                        &plane_a[..cin * h * w],
                        (cin, h, w),
                        &pc.shape,
                        &mut cols[..npix * acc],
                    );
                    for co in 0..pc.shape.cout {
                        let wrow = &pc.wq.values[co * acc..(co + 1) * acc];
                        let main_lut =
                            &plan.si_main_lut[co * plan.lut_w..(co + 1) * plan.lut_w];
                        let res_lut = plan
                            .si_res_lut
                            .as_deref()
                            .map(|l| &l[co * plan.lut_w..(co + 1) * plan.lut_w]);
                        let res_in = plan
                            .align_lut
                            .as_deref()
                            .map(|lut| (lut, &res_a[co * npix..(co + 1) * npix]));
                        let out_row = &mut plane_b[co * npix..(co + 1) * npix];
                        for p in 0..npix {
                            let xr = &cols[p * acc..(p + 1) * acc];
                            // Product counts through TernaryMultiplier
                            // semantics: count(a·w) = a·w + L/2 per
                            // product, summed by the BSN (popcount).
                            let mut count = plan.base;
                            for (x, wv) in xr.iter().zip(wrow.iter()) {
                                count += *x as i64 * *wv as i64;
                            }
                            // Residual contribution (§III.C alignment).
                            if let Some((lut, rrow)) = res_in {
                                count += lut[(rrow[p] as i64 + rhalf) as usize];
                            }
                            let c = (count.max(0) as usize).min(plan.lut_w - 1);
                            out_row[p] = main_lut[c];
                            if let Some(rl) = res_lut {
                                res_b[co * npix + p] = rl[c];
                            }
                        }
                    }
                    std::mem::swap(plane_a, plane_b);
                    if pc.si_res.is_some() {
                        std::mem::swap(res_a, res_b);
                    }
                    dims = (pc.shape.cout, plan.oh, plan.ow);
                    li += 1;
                }
                LayerCfg::GlobalAvgPool => {
                    let (c, h, w) = dims;
                    for ch in 0..c {
                        let mut s = 0i64;
                        for &q in &plane_a[ch * h * w..(ch + 1) * h * w] {
                            s += q as i64;
                        }
                        gap[ch] = s;
                    }
                    gap_len = Some(c);
                }
                LayerCfg::Linear { in_dim, out_dim } => {
                    assert_eq!(*out_dim, logits.len());
                    let fc = &prep.fc.values;
                    if let Some(n) = gap_len {
                        assert_eq!(n, *in_dim);
                        for (o, out) in logits.iter_mut().enumerate() {
                            let mut s = 0i64;
                            for i in 0..*in_dim {
                                s += gap[i] * fc[o * in_dim + i] as i64;
                            }
                            *out = s;
                        }
                    } else {
                        let (c, h, w) = dims;
                        assert_eq!(c * h * w, *in_dim);
                        for (o, out) in logits.iter_mut().enumerate() {
                            let mut s = 0i64;
                            for i in 0..*in_dim {
                                s += plane_a[i] as i64 * fc[o * in_dim + i] as i64;
                            }
                            *out = s;
                        }
                    }
                    return;
                }
            }
        }
        panic!("model has no classifier layer");
    }

    /// Forward a flat batch (`batch · image_len` floats, NCHW) into a
    /// caller-owned `batch · classes` logits slice.
    pub fn forward_batch_into(&mut self, x: &[f32], logits: &mut [i64]) {
        let il = self.image_len();
        let cl = self.classes();
        assert!(il > 0 && x.len() % il == 0, "batch input length must be a multiple of image_len");
        let batch = x.len() / il;
        assert_eq!(logits.len(), batch * cl, "logits buffer length mismatch");
        for b in 0..batch {
            self.forward_into(&x[b * il..(b + 1) * il], &mut logits[b * cl..(b + 1) * cl]);
        }
    }

    /// Convenience single-image forward (allocates the result vector).
    pub fn forward(&mut self, image: &Tensor) -> Vec<i64> {
        let mut logits = vec![0i64; self.classes()];
        self.forward_into(image.data(), &mut logits);
        logits
    }

    /// Classify a batch; returns predicted classes.
    pub fn predict(&mut self, images: &[Tensor]) -> Vec<usize> {
        images
            .iter()
            .map(|im| {
                let l = self.forward(im);
                l.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap()
            })
            .collect()
    }
}

/// Flatten per-channel SI count tables into one channel-major LUT of
/// signed output codes.
fn flatten_si_luts(sis: &[SelectiveInterconnect], lut_w: usize) -> Vec<i32> {
    let mut lut = Vec::with_capacity(sis.len() * lut_w);
    for si in sis {
        let off = (si.out_bsl() / 2) as i32;
        let table = si.count_table();
        assert_eq!(table.len(), lut_w, "SI in_width must equal the layer's BSN width");
        lut.extend(table.into_iter().map(|v| v as i32 - off));
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{ModelCfg, ModelParams};
    use crate::nn::quant::QuantConfig;
    use crate::nn::sc_exec::ScExecutor;
    use crate::util::Rng;

    fn prep_for(cfg: &ModelCfg, quant: QuantConfig, seed: u64) -> Arc<Prepared> {
        let mut rng = Rng::new(seed);
        let params = ModelParams::init(cfg, &mut rng);
        Arc::new(Prepared::new(cfg, &params, quant))
    }

    #[test]
    fn engine_matches_executor_on_tnn() {
        let cfg = ModelCfg::tnn();
        for bsl in [2usize, 4, 8] {
            let prep = prep_for(
                &cfg,
                QuantConfig { act_bsl: Some(bsl), weight_ternary: true, residual_bsl: None },
                3,
            );
            let exec = ScExecutor::new(prep.clone());
            let mut engine = ScEngine::new(prep);
            let mut rng = Rng::new(41 + bsl as u64);
            for _ in 0..3 {
                let img = Tensor::from_vec(
                    &[1, 28, 28],
                    (0..784).map(|_| rng.normal() as f32).collect(),
                );
                assert_eq!(engine.forward(&img), exec.forward(&img), "bsl={bsl}");
            }
        }
    }

    #[test]
    fn engine_matches_executor_on_residual_scnet() {
        let cfg = ModelCfg::scnet(10);
        let prep = prep_for(&cfg, QuantConfig::w2a2r16(), 5);
        let exec = ScExecutor::new(prep.clone());
        let mut engine = ScEngine::new(prep);
        let mut rng = Rng::new(17);
        for _ in 0..2 {
            let img = Tensor::from_vec(
                &[3, 32, 32],
                (0..3 * 32 * 32).map(|_| rng.normal() as f32 * 0.5).collect(),
            );
            assert_eq!(engine.forward(&img), exec.forward(&img));
        }
    }

    #[test]
    fn batch_forward_equals_per_image() {
        let cfg = ModelCfg::tnn();
        let prep = prep_for(
            &cfg,
            QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None },
            9,
        );
        let mut engine = ScEngine::new(prep);
        let mut rng = Rng::new(23);
        let batch = 3usize;
        let il = engine.image_len();
        let cl = engine.classes();
        let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32).collect();
        let mut batched = vec![0i64; batch * cl];
        engine.forward_batch_into(&x, &mut batched);
        for b in 0..batch {
            let mut one = vec![0i64; cl];
            engine.forward_into(&x[b * il..(b + 1) * il], &mut one);
            assert_eq!(&batched[b * cl..(b + 1) * cl], one.as_slice(), "image {b}");
        }
    }

    #[test]
    fn engine_shares_the_prepared() {
        let cfg = ModelCfg::tnn();
        let prep = prep_for(
            &cfg,
            QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None },
            1,
        );
        let a = ScEngine::new(prep.clone());
        let b = ScEngine::new(prep.clone());
        assert!(Arc::ptr_eq(a.prepared_arc(), b.prepared_arc()));
    }
}
