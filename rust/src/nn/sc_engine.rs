//! Batched, serving-grade SC inference engine.
//!
//! [`ScEngine`] evaluates the same frozen network as
//! [`super::sc_exec::ScExecutor`] — bit-identical logits, asserted in
//! `rust/tests/sc_serve.rs` and `rust/tests/gemm.rs` — but is shaped
//! for the request path instead of offline experiments:
//!
//! * **Shared model.** The engine holds `Arc<Prepared>`, so a pool of
//!   workers shares one copy of the ternarized weights, packed GEMM
//!   panels and SI tables instead of deep-cloning them per worker.
//! * **Ternary GEMM core.** The accumulation stage of every conv layer
//!   is one cache-blocked call into [`super::gemm::TernaryPanel`] —
//!   zero-skipping add/sub index lists packed once at
//!   [`Prepared`] build time — instead of a naive per-(channel, pixel)
//!   scalar dot product (DESIGN.md §Perf, "Ternary GEMM + threading").
//! * **Sparsity-aware routing.** The im2col pass counts nonzeros as it
//!   fills (free — see [`im2col_i32_nnz_into`]); layers whose measured
//!   column density falls at or below
//!   [`SPARSE_DENSITY_CROSSOVER`](super::gemm::SPARSE_DENSITY_CROSSOVER)
//!   are re-compressed into a [`SparseCols`] panel and run through the
//!   zero-skipping `gemm_sparse_*` kernels. Both paths are exact i64
//!   count accumulation, so the routing decision never changes a logit
//!   — it only changes how fast the counts arrive (DESIGN.md §Perf,
//!   "Sparsity"). Attach an [`ScEngine::set_sparsity_counters`] sink to
//!   export measured density and sparse-path hit rate to serving
//!   metrics.
//! * **Pre-sized scratch arenas.** All intermediate state — im2col
//!   column buffers, the GEMM count plane, ping-pong activation planes,
//!   residual planes and the GAP accumulator — lives in
//!   per-thread [`EngineScratch`] arenas allocated once at construction
//!   from the model's static geometry and reused for every image. The
//!   steady-state forward path performs **no heap allocation**.
//! * **Synthesized count tables.** Per-channel selective interconnects
//!   and the residual re-scaling block are folded into lookup tables at
//!   construction ([`si::flatten_count_tables`], [`align_res_count`]),
//!   which is exact: both are pure monotone functions of the
//!   accumulated count. This is the same "deterministic coding makes
//!   everything a count function" property the paper builds on
//!   (DESIGN.md §Hardware-Adaptation) — the engine just evaluates that
//!   function by indexed load instead of tap scan.
//! * **Intra-engine threading.** [`ScEngine::forward_batch_into`]
//!   shards **batch rows × output-channel blocks** with
//!   `std::thread::scope` — no runtime, no extra deps. Rows split into
//!   contiguous chunks, one per scratch arena; threads left over on a
//!   narrow batch (down to one image using all of them) split each
//!   conv layer's channel blocks within their row, so the knob also
//!   cuts single-request latency. Because count
//!   accumulation is exact `i64` arithmetic and every (row,
//!   channel-block) work item writes a disjoint output slice, the
//!   sharding is order-safe: logits are **bit-identical** at every
//!   thread count (asserted in `rust/tests/gemm.rs`). The knob is
//!   plumbed through `ServeConfig::threads` / `scnn serve --threads N`.
//!   Trade-off: the channel-block path spawns its scoped threads per
//!   conv layer, so it pays thread-creation cost per layer per image —
//!   worth it on wide layers (scnet-class models), mostly overhead on
//!   tiny ones; the row path spawns once per batch.
//!
//! The engine also serves **under injected faults**
//! ([`ScEngine::set_fault`]): every circuit stage's bitflip mask
//! ([`crate::fault::inject`]) is folded into the count domain exactly —
//! a flip on a known stream lane changes the count by ±1, an SI tap on
//! a corrupted lane is re-evaluated from the mask — so faulted logits
//! are bit-identical to the stream-materializing
//! [`super::sc_exec::ScExecutor`] fault path at packed speed
//! (property-tested in `rust/tests/gemm.rs`, every thread count).
//! One deviation from the zero-allocation rule: the faulted path keeps
//! a few sparse mask vectors per `conv_block` call (they are `O(ber ·
//! width)` and reused across the block's pixels).
//!
//! With a [`DatapathGuard`] attached ([`ScEngine::set_guard`]), every
//! GEMM row block is checksum-verified and scalar-re-executed on
//! violation before its counts reach the SI tables — the serving
//! integrity layer behind `scnn serve --guard`.
//!
//! Throughput floors live in DESIGN.md §Perf and are tracked by
//! `rust/benches/sc_serve.rs` → `BENCH_sc.json`.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::circuits::si::{self, SelTap};
use crate::fault::guard::DatapathGuard;
use crate::fault::inject::{self, Stage};
use super::gemm::{column_sums, SparseCols, SPARSE_DENSITY_CROSSOVER};
use super::layers::im2col_i32_nnz_into;
use super::model::LayerCfg;
use super::sc_exec::{align_res_count, FaultCfg, Prepared, PreparedConv};
use super::tensor::Tensor;

/// Per-conv-layer execution plan: static geometry plus the synthesized
/// count tables, so the hot loop touches no model-construction code.
struct ConvPlan {
    /// Input plane dims (C, H, W).
    in_dims: (usize, usize, usize),
    /// Output spatial dims.
    oh: usize,
    ow: usize,
    /// Accumulation width (products per output pixel).
    acc_w: usize,
    /// Activation BSL `L` (per-product stream length) — the fault
    /// model's `Mult` stage spans `acc_w · L` lanes.
    act_bsl: usize,
    /// Count-domain offset `acc_w · L/2` added to the dot product.
    base: i64,
    /// LUT row width: `bsn_width + 1` (one entry per possible count).
    lut_w: usize,
    /// Main SI transfer, channel-major `cout × lut_w`, already offset
    /// to signed codes ([`si::flatten_count_tables`]).
    si_main_lut: Vec<i32>,
    /// Residual-tap SI transfer (layers with `res_out`).
    si_res_lut: Option<Vec<i32>>,
    /// Residual alignment `res count → aligned count` (§III.C), for
    /// layers with `res_in`. Indexed by `rq + res_bsl/2 ∈ 0..=res_bsl`.
    align_lut: Option<Vec<i64>>,
}

/// Static scratch geometry of one frozen network: the arena sizes every
/// [`EngineScratch`] is allocated from.
#[derive(Clone, Copy, Debug)]
struct ScratchSizes {
    cols: usize,
    acc: usize,
    plane: usize,
    res: usize,
    ch: usize,
}

/// One thread's complete working set: im2col columns, the GEMM count
/// plane, ping-pong activation/residual planes and the GAP accumulator.
/// Allocated once, reused for every image the thread forwards.
struct EngineScratch {
    /// im2col scratch, sized for the widest layer.
    cols: Vec<i32>,
    /// GEMM output counts (`cout × npix` i64), sized for the widest
    /// layer.
    acc: Vec<i64>,
    /// Ping-pong activation planes (input of the current layer lives in
    /// `plane_a`, its output is written to `plane_b`, then swapped).
    plane_a: Vec<i32>,
    plane_b: Vec<i32>,
    /// Ping-pong residual planes (read old tap, write new tap).
    res_a: Vec<i32>,
    res_b: Vec<i32>,
    /// Global-average-pool accumulator.
    gap: Vec<i64>,
    /// Per-layer im2col column sums — the guard's checksum vector.
    /// Grown on first guarded forward (empty when no guard runs).
    colsum: Vec<i64>,
    /// Per-pixel nonzero counts from the im2col pass (the density
    /// measurement driving the sparse-vs-dense routing).
    nnz: Vec<u32>,
    /// Compressed activation panel, refilled in place for each layer
    /// that routes sparse (allocations reused across images).
    sparse: SparseCols,
    /// Sparsity telemetry accumulated by this arena's forwards, folded
    /// into the shared [`SparsityCounters`] after each batch.
    stat: SparsityStat,
}

impl EngineScratch {
    fn new(s: &ScratchSizes) -> Self {
        Self {
            cols: vec![0; s.cols],
            acc: vec![0; s.acc],
            plane_a: vec![0; s.plane],
            plane_b: vec![0; s.plane],
            res_a: vec![0; s.res],
            res_b: vec![0; s.res],
            gap: vec![0; s.ch],
            colsum: Vec::new(),
            nnz: Vec::new(),
            sparse: SparseCols::new(),
            stat: SparsityStat::default(),
        }
    }
}

/// One arena's sparsity tally (plain integers — the hot loop never
/// touches an atomic; totals are folded into the shared
/// [`SparsityCounters`] once per batch).
#[derive(Clone, Copy, Debug, Default)]
struct SparsityStat {
    /// Conv-layer GEMMs executed.
    gemm: u64,
    /// Of those, how many routed through the sparse kernels.
    sparse: u64,
    /// Nonzero im2col entries observed.
    nnz: u64,
    /// Total im2col entries observed.
    elems: u64,
}

/// Shared activation-sparsity telemetry: how many conv-layer GEMMs ran,
/// how many of them took the sparse path, and the measured im2col
/// density behind those decisions. Pool workers clone one `Arc` (the
/// same pattern as [`crate::fault::guard::GuardCounters`]) so serving
/// metrics aggregate across the fleet; see
/// [`ScEngine::set_sparsity_counters`].
#[derive(Debug, Default)]
pub struct SparsityCounters {
    gemm_total: AtomicU64,
    sparse_gemm: AtomicU64,
    act_nnz: AtomicU64,
    act_elems: AtomicU64,
}

impl SparsityCounters {
    /// Conv-layer GEMM executions observed (dense + sparse).
    pub fn gemm_total(&self) -> u64 {
        self.gemm_total.load(Ordering::Relaxed)
    }

    /// GEMMs that routed through the sparse kernels.
    pub fn sparse_gemm(&self) -> u64 {
        self.sparse_gemm.load(Ordering::Relaxed)
    }

    /// Nonzero im2col activation entries observed.
    pub fn act_nnz(&self) -> u64 {
        self.act_nnz.load(Ordering::Relaxed)
    }

    /// Total im2col activation entries observed.
    pub fn act_elems(&self) -> u64 {
        self.act_elems.load(Ordering::Relaxed)
    }

    /// Measured activation density `nnz / elems` (1.0 before any
    /// forward has run).
    pub fn density(&self) -> f64 {
        let e = self.act_elems();
        if e == 0 {
            1.0
        } else {
            self.act_nnz() as f64 / e as f64
        }
    }

    fn fold(&self, s: &SparsityStat) {
        if s.gemm == 0 && s.elems == 0 {
            return;
        }
        self.gemm_total.fetch_add(s.gemm, Ordering::Relaxed);
        self.sparse_gemm.fetch_add(s.sparse, Ordering::Relaxed);
        self.act_nnz.fetch_add(s.nnz, Ordering::Relaxed);
        self.act_elems.fetch_add(s.elems, Ordering::Relaxed);
    }
}

/// The batched SC inference engine. See the module docs.
pub struct ScEngine {
    prep: Arc<Prepared>,
    plans: Vec<ConvPlan>,
    /// One scratch arena per shard thread (`scratch.len()` == the
    /// engine's thread knob; index 0 serves the sequential paths).
    scratch: Vec<EngineScratch>,
    /// Fault injection (Fig 5): when set, every forward applies the
    /// per-site stage masks in the count domain.
    fault: Option<FaultCfg>,
    /// Count-domain integrity guard; shared across every engine thread.
    guard: Option<Arc<DatapathGuard>>,
    /// Sparsity telemetry sink; shared across every engine thread.
    sparsity: Option<Arc<SparsityCounters>>,
}

impl ScEngine {
    /// Build a single-threaded engine over a frozen network. Equivalent
    /// to [`ScEngine::with_threads`]`(prep, 1)`.
    pub fn new(prep: impl Into<Arc<Prepared>>) -> Self {
        Self::with_threads(prep, 1)
    }

    /// Build an engine whose [`ScEngine::forward_batch_into`] shards
    /// batch rows across up to `threads` scoped threads, each owning
    /// one pre-sized scratch arena. `threads` is clamped to ≥ 1; memory
    /// scales linearly with it (one full arena set per thread). Logits
    /// are bit-identical at every thread count.
    pub fn with_threads(prep: impl Into<Arc<Prepared>>, threads: usize) -> Self {
        let prep: Arc<Prepared> = prep.into();
        let act_bsl = prep.act_bsl();
        let half = (act_bsl / 2) as i64;
        let res_bsl = prep.res_bsl();
        let mut dims = prep.cfg.input;
        let mut res_dims: Option<(usize, usize, usize)> = None;
        let mut plans = Vec::with_capacity(prep.convs.len());
        let mut sizes = ScratchSizes {
            cols: 0,
            acc: 0,
            plane: dims.0 * dims.1 * dims.2,
            res: 0,
            ch: dims.0,
        };
        let mut ci = 0usize;
        for l in &prep.cfg.layers {
            if let LayerCfg::Conv { shape, .. } = l {
                let pc = &prep.convs[ci];
                let (oh, ow) = shape.out_hw(dims.1, dims.2);
                let npix = oh * ow;
                let acc_w = shape.acc_width();
                let lut_w = pc.bsn_width + 1;
                let si_main_lut = si::flatten_count_tables(&pc.si_main, lut_w);
                let si_res_lut =
                    pc.si_res.as_ref().map(|sis| si::flatten_count_tables(sis, lut_w));
                let align_lut = if pc.res_in {
                    let rd = res_dims.expect("res_in conv without a residual producer");
                    assert_eq!(
                        rd,
                        (shape.cout, oh, ow),
                        "residual tap geometry must match the consuming conv output"
                    );
                    Some(
                        (0..=res_bsl)
                            .map(|c| align_res_count(c, res_bsl, pc.res_shift) as i64)
                            .collect(),
                    )
                } else {
                    None
                };
                plans.push(ConvPlan {
                    in_dims: dims,
                    oh,
                    ow,
                    acc_w,
                    act_bsl,
                    base: acc_w as i64 * half,
                    lut_w,
                    si_main_lut,
                    si_res_lut,
                    align_lut,
                });
                sizes.cols = sizes.cols.max(npix * acc_w);
                sizes.acc = sizes.acc.max(shape.cout * npix);
                dims = (shape.cout, oh, ow);
                sizes.plane = sizes.plane.max(dims.0 * dims.1 * dims.2);
                if pc.si_res.is_some() {
                    res_dims = Some(dims);
                    sizes.res = sizes.res.max(dims.0 * dims.1 * dims.2);
                }
                sizes.ch = sizes.ch.max(shape.cout);
                ci += 1;
            }
        }
        let scratch = (0..threads.max(1)).map(|_| EngineScratch::new(&sizes)).collect();
        Self { prep, plans, scratch, fault: None, guard: None, sparsity: None }
    }

    /// Set (or clear) fault injection for subsequent forwards. With the
    /// same `FaultCfg` and image tags, the engine's faulted logits are
    /// bit-identical to [`super::sc_exec::ScExecutor::with_faults`].
    pub fn set_fault(&mut self, fault: Option<FaultCfg>) {
        self.fault = fault;
    }

    /// The active fault configuration.
    pub fn fault(&self) -> Option<FaultCfg> {
        self.fault
    }

    /// Attach (or detach) a count-domain integrity guard. The guard is
    /// shared — pool workers pass clones of one `Arc` so detection /
    /// recovery counters aggregate across the fleet.
    pub fn set_guard(&mut self, guard: Option<Arc<DatapathGuard>>) {
        self.guard = guard;
    }

    /// Attach (or detach) a sparsity telemetry sink. Like the guard,
    /// the counters are shared: pool workers pass clones of one `Arc`
    /// so density and sparse-path hit rate aggregate across the fleet.
    /// The hot loop tallies into plain per-arena integers; the shared
    /// atomics are touched once per batch.
    pub fn set_sparsity_counters(&mut self, counters: Option<Arc<SparsityCounters>>) {
        self.sparsity = counters;
    }

    /// The frozen network.
    pub fn prepared(&self) -> &Prepared {
        &self.prep
    }

    /// The shared handle to the frozen network.
    pub fn prepared_arc(&self) -> &Arc<Prepared> {
        &self.prep
    }

    /// The thread knob: how many scratch arenas / scoped threads
    /// [`ScEngine::forward_batch_into`] shards batch rows across.
    pub fn threads(&self) -> usize {
        self.scratch.len()
    }

    /// Flattened image length (C·H·W).
    pub fn image_len(&self) -> usize {
        let (c, h, w) = self.prep.cfg.input;
        c * h * w
    }

    /// Logits per image.
    pub fn classes(&self) -> usize {
        self.prep.cfg.num_classes
    }

    /// Forward one flat CHW image into a caller-owned logits slice.
    /// Allocation-free in steady state; bit-identical to
    /// [`super::sc_exec::ScExecutor::forward`]. On an engine with a
    /// thread knob > 1, each conv layer's output-channel blocks are
    /// computed by scoped threads (still bit-identical — the single
    /// request latency win). Under fault injection the image carries
    /// tag 0 — use [`ScEngine::forward_into_tagged`] to give each image
    /// its own fault identity.
    pub fn forward_into(&mut self, image: &[f32], logits: &mut [i64]) {
        self.forward_into_tagged(image, 0, logits);
    }

    /// Forward one image whose fault masks are derived from `tag`
    /// (canonically the image's index; inert without a `FaultCfg`).
    /// Same tag, same `FaultCfg` ⇒ same masks as
    /// [`super::sc_exec::ScExecutor::forward_with_tag`], at any thread
    /// count.
    pub fn forward_into_tagged(&mut self, image: &[f32], tag: u64, logits: &mut [i64]) {
        let Self { prep, plans, scratch, fault, guard, sparsity } = self;
        let threads = scratch.len();
        forward_one(
            prep,
            plans,
            &mut scratch[0],
            image,
            logits,
            threads,
            *fault,
            tag,
            guard.as_deref(),
        );
        if let Some(ctr) = sparsity.as_deref() {
            ctr.fold(&scratch[0].stat);
        }
        scratch[0].stat = SparsityStat::default();
    }

    /// Forward a flat batch (`batch · image_len` floats, NCHW) into a
    /// caller-owned `batch · classes` logits slice.
    ///
    /// With a thread knob > 1 ([`ScEngine::with_threads`]) the work is
    /// sharded over **batch rows × output-channel blocks**: rows split
    /// into contiguous chunks, one per scoped thread (each in its own
    /// scratch arena, spawned once per batch), and any threads left
    /// over when the batch is narrower than the knob — down to a
    /// single-row batch using all of them — are spent inside each row
    /// on its conv layers' output-channel blocks, so the knob also
    /// cuts latency when co-riders are scarce. Exact i64 count
    /// accumulation and disjoint output slices make both dimensions
    /// order-safe: the logits are bit-identical to the sequential path
    /// at every thread count.
    /// Under fault injection, row `b` of the batch carries image tag
    /// `b` — the same convention as [`ScExecutor::predict`] — so logits
    /// are independent of how the batch is sharded.
    ///
    /// [`ScExecutor::predict`]: super::sc_exec::ScExecutor::predict
    pub fn forward_batch_into(&mut self, x: &[f32], logits: &mut [i64]) {
        let il = self.image_len();
        let cl = self.classes();
        assert!(il > 0 && x.len() % il == 0, "batch input length must be a multiple of image_len");
        let batch = x.len() / il;
        assert_eq!(logits.len(), batch * cl, "logits buffer length mismatch");
        let Self { prep, plans, scratch, fault, guard, sparsity } = self;
        let prep: &Prepared = prep;
        let plans: &[ConvPlan] = plans;
        let fault = *fault;
        let guard = guard.as_deref();
        let nt = scratch.len().min(batch);
        if nt <= 1 {
            // Sequential engine — or a single row, where the only
            // parallelism available is inside the row: spend the
            // threads on its conv layers' output-channel blocks.
            let intra = if batch == 1 { scratch.len() } else { 1 };
            let s = &mut scratch[0];
            for (b, (xrow, lrow)) in
                x.chunks_exact(il).zip(logits.chunks_exact_mut(cl)).enumerate()
            {
                forward_one(prep, plans, s, xrow, lrow, intra, fault, b as u64, guard);
            }
            if let Some(ctr) = sparsity.as_deref() {
                ctr.fold(&s.stat);
            }
            s.stat = SparsityStat::default();
            return;
        }
        // Contiguous row chunks, one scoped thread per scratch arena —
        // row sharding spawns once per batch, so it is the primary
        // dimension whenever more than one row exists. Threads left
        // over when the batch is narrower than the knob (batch < len)
        // are spent *inside* each row thread, on its conv layers'
        // output-channel blocks — channel-block sharding only touches
        // that thread's own arena, so the dimensions compose freely.
        let intra = (scratch.len() / nt).max(1);
        let per = batch.div_ceil(nt);
        std::thread::scope(|sc| {
            let mut xs = x;
            let mut ls = &mut logits[..];
            let mut row0 = 0usize;
            for s in scratch[..nt].iter_mut() {
                let take = per.min(xs.len() / il);
                if take == 0 {
                    break;
                }
                let (xa, xrest) = xs.split_at(take * il);
                let (la, lrest) = std::mem::take(&mut ls).split_at_mut(take * cl);
                xs = xrest;
                ls = lrest;
                let base = row0;
                row0 += take;
                sc.spawn(move || {
                    for (k, (xrow, lrow)) in
                        xa.chunks_exact(il).zip(la.chunks_exact_mut(cl)).enumerate()
                    {
                        let tag = (base + k) as u64;
                        forward_one(prep, plans, s, xrow, lrow, intra, fault, tag, guard);
                    }
                });
            }
        });
        if let Some(ctr) = sparsity.as_deref() {
            for s in scratch[..nt].iter() {
                ctr.fold(&s.stat);
            }
        }
        for s in scratch[..nt].iter_mut() {
            s.stat = SparsityStat::default();
        }
    }

    /// Convenience single-image forward (allocates the result vector).
    pub fn forward(&mut self, image: &Tensor) -> Vec<i64> {
        let mut logits = vec![0i64; self.classes()];
        self.forward_into(image.data(), &mut logits);
        logits
    }

    /// Classify a batch; returns predicted classes. Images are tagged
    /// by index — the shared fault-reproducibility convention.
    pub fn predict(&mut self, images: &[Tensor]) -> Vec<usize> {
        let cl = self.classes();
        let mut logits = vec![0i64; cl];
        images
            .iter()
            .enumerate()
            .map(|(i, im)| {
                self.forward_into_tagged(im.data(), i as u64, &mut logits);
                logits.iter().enumerate().max_by_key(|(_, &v)| v).map(|(i, _)| i).unwrap()
            })
            .collect()
    }
}

/// Per-layer fault/guard context handed down to [`conv_block`]: the
/// coordinates that key the site-derived masks plus the shared guard
/// and its per-layer checksum vector. `Copy` so scoped channel-block
/// threads can each take one.
#[derive(Clone, Copy)]
struct BlockCtx<'a> {
    /// Conv layer index (fault-site coordinate).
    li: usize,
    /// Image tag (fault-site coordinate).
    tag: u64,
    fault: Option<FaultCfg>,
    guard: Option<&'a DatapathGuard>,
    /// im2col column sums of this layer (empty when no guard runs).
    colsum: &'a [i64],
    /// Compressed activation panel when this layer's measured density
    /// cleared the crossover — the GEMM routes sparse; `None` = dense.
    sparse: Option<&'a SparseCols>,
}

/// One full image through the frozen network, entirely inside one
/// scratch arena — the unit of work the batch sharding distributes.
#[allow(clippy::too_many_arguments)]
fn forward_one(
    prep: &Prepared,
    plans: &[ConvPlan],
    s: &mut EngineScratch,
    image: &[f32],
    logits: &mut [i64],
    threads: usize,
    fault: Option<FaultCfg>,
    tag: u64,
    guard: Option<&DatapathGuard>,
) {
    let EngineScratch {
        cols,
        acc,
        plane_a,
        plane_b,
        res_a,
        res_b,
        gap,
        colsum,
        nnz,
        sparse,
        stat,
    } = s;
    let (c0, h0, w0) = prep.cfg.input;
    let n0 = c0 * h0 * w0;
    assert_eq!(image.len(), n0, "image length mismatch");
    assert_eq!(logits.len(), prep.cfg.num_classes, "logits length mismatch");
    // Input encoding at the trained scale (same rule as ScExecutor).
    let halff = (prep.act_bsl() / 2) as f32;
    for (dst, &v) in plane_a[..n0].iter_mut().zip(image.iter()) {
        *dst = (v / prep.input_alpha).round().clamp(-halff, halff) as i32;
    }
    let rhalf = (prep.res_bsl() / 2) as i64;
    let mut dims = prep.cfg.input;
    let mut li = 0usize;
    let mut gap_len: Option<usize> = None;
    for l in &prep.cfg.layers {
        match l {
            LayerCfg::Conv { .. } => {
                let pc = &prep.convs[li];
                let plan = &plans[li];
                let (cin, h, w) = plan.in_dims;
                let npix = plan.oh * plan.ow;
                let acc_w = plan.acc_w;
                let cout = pc.shape.cout;
                im2col_i32_nnz_into(
                    &plane_a[..cin * h * w],
                    (cin, h, w),
                    &pc.shape,
                    &mut cols[..npix * acc_w],
                    nnz,
                );
                let cols_s = &cols[..npix * acc_w];
                // The guard's checksum oracle: per-k column sums of the
                // im2col matrix, computed once per layer (`row · colsum`
                // must equal the row's count sum, by GEMM linearity).
                if guard.is_some() {
                    column_sums(cols_s, acc_w, colsum);
                } else {
                    colsum.clear();
                }
                // Sparse-vs-dense routing from the measured density.
                // Both kernels are exact i64 accumulation, so this only
                // decides speed — never a count.
                let layer_nnz: u64 = nnz.iter().map(|&v| v as u64).sum();
                let elems = (npix * acc_w) as u64;
                let route_sparse =
                    elems > 0 && (layer_nnz as f64) <= SPARSE_DENSITY_CROSSOVER * elems as f64;
                if route_sparse {
                    sparse.fill_from(cols_s, npix, acc_w);
                }
                stat.gemm += 1;
                stat.sparse += route_sparse as u64;
                stat.nnz += layer_nnz;
                stat.elems += elems;
                let ctx = BlockCtx {
                    li,
                    tag,
                    fault,
                    guard,
                    colsum: &colsum[..],
                    sparse: route_sparse.then_some(&*sparse),
                };
                let counts = &mut acc[..cout * npix];
                let out_plane = &mut plane_b[..cout * npix];
                // Residual planes are empty slices on layers without
                // the corresponding tap — conv_block keys off length.
                let res_src: &[i32] =
                    if plan.align_lut.is_some() { &res_a[..cout * npix] } else { &[] };
                let res_plane: &mut [i32] =
                    if pc.si_res.is_some() { &mut res_b[..cout * npix] } else { &mut [] };
                let nb = threads.min(cout).max(1);
                if nb <= 1 {
                    conv_block(
                        pc, plan, rhalf, cols_s, res_src, 0, counts, out_plane, res_plane, ctx,
                    );
                } else {
                    // Output-channel-block sharding: each scoped thread
                    // owns a disjoint channel range (GEMM rows + count
                    // LUTs), reading the shared im2col/residual planes.
                    let per = cout.div_ceil(nb);
                    std::thread::scope(|sc| {
                        let mut counts = counts;
                        let mut out_plane = out_plane;
                        let mut res_plane = res_plane;
                        let mut r0 = 0usize;
                        while r0 < cout {
                            let rows = per.min(cout - r0);
                            let (cc, crest) =
                                std::mem::take(&mut counts).split_at_mut(rows * npix);
                            counts = crest;
                            let (oc, orest) =
                                std::mem::take(&mut out_plane).split_at_mut(rows * npix);
                            out_plane = orest;
                            let rlen = if res_plane.is_empty() { 0 } else { rows * npix };
                            let (rc, rrest) = std::mem::take(&mut res_plane).split_at_mut(rlen);
                            res_plane = rrest;
                            sc.spawn(move || {
                                conv_block(pc, plan, rhalf, cols_s, res_src, r0, cc, oc, rc, ctx);
                            });
                            r0 += rows;
                        }
                    });
                }
                std::mem::swap(plane_a, plane_b);
                if pc.si_res.is_some() {
                    std::mem::swap(res_a, res_b);
                }
                dims = (pc.shape.cout, plan.oh, plan.ow);
                li += 1;
            }
            LayerCfg::GlobalAvgPool => {
                let (c, h, w) = dims;
                for ch in 0..c {
                    let mut sum = 0i64;
                    for &q in &plane_a[ch * h * w..(ch + 1) * h * w] {
                        sum += q as i64;
                    }
                    gap[ch] = sum;
                }
                gap_len = Some(c);
            }
            LayerCfg::Linear { in_dim, out_dim } => {
                assert_eq!(*out_dim, logits.len());
                // Classifier through the shared ternary panel (zero
                // weights skipped, adds/subs only).
                let fc = &prep.fc_panels.ternary;
                if let Some(n) = gap_len {
                    assert_eq!(n, *in_dim);
                    for (o, out) in logits.iter_mut().enumerate() {
                        *out = fc.row_dot_i64(o, &gap[..*in_dim]);
                    }
                } else {
                    let (c, h, w) = dims;
                    assert_eq!(c * h * w, *in_dim);
                    for (o, out) in logits.iter_mut().enumerate() {
                        *out = fc.row_dot(o, &plane_a[..*in_dim]);
                    }
                }
                return;
            }
        }
    }
    panic!("model has no classifier layer");
}

/// One output-channel block of one conv layer — the sharding work
/// unit: GEMM the panel rows `r0..r0+rows` over the shared im2col
/// matrix, verify them when a guard is attached, then push the counts
/// through the per-channel SI/residual LUTs (or the faulted
/// count-domain algebra). `counts`/`out` are the block's disjoint
/// `rows × npix` chunks; `res_src` is the full residual input plane
/// (empty when the layer consumes none) and `res_out` the block's
/// residual-tap chunk (empty when the layer produces none).
#[allow(clippy::too_many_arguments)]
fn conv_block(
    pc: &PreparedConv,
    plan: &ConvPlan,
    rhalf: i64,
    cols: &[i32],
    res_src: &[i32],
    r0: usize,
    counts: &mut [i64],
    out: &mut [i32],
    res_out: &mut [i32],
    ctx: BlockCtx<'_>,
) {
    let npix = plan.oh * plan.ow;
    let rows = counts.len() / npix.max(1);
    // Sparse or dense per the layer's routing decision — identical
    // exact-i64 counts either way, so everything downstream (guard,
    // faults, SI LUTs) is oblivious to which kernel ran.
    match ctx.sparse {
        Some(sp) => pc.panels.ternary.gemm_sparse_rows_into(r0, r0 + rows, sp, counts),
        None => pc.panels.ternary.gemm_rows_into(r0, r0 + rows, cols, npix, counts),
    }
    // Guard the GEMM counts before anything downstream consumes them.
    // Faults model the *circuit* stages and are folded in afterwards;
    // the guard protects the accumulation itself.
    if let Some(g) = ctx.guard {
        g.verify_rows(&pc.panels.ternary, r0, rows, cols, npix, ctx.colsum, plan.base, counts);
    }
    if let Some(fc) = ctx.fault {
        conv_block_faulted(pc, plan, rhalf, cols, res_src, r0, counts, out, res_out, ctx, fc);
        return;
    }
    for l in 0..rows {
        let co = r0 + l;
        let arow = &counts[l * npix..(l + 1) * npix];
        let main_lut = &plan.si_main_lut[co * plan.lut_w..(co + 1) * plan.lut_w];
        let res_lut = plan
            .si_res_lut
            .as_deref()
            .map(|t| &t[co * plan.lut_w..(co + 1) * plan.lut_w]);
        let res_in = plan
            .align_lut
            .as_deref()
            .map(|lut| (lut, &res_src[co * npix..(co + 1) * npix]));
        let out_row = &mut out[l * npix..(l + 1) * npix];
        for p in 0..npix {
            // Product counts through TernaryMultiplier semantics:
            // count(a·w) = a·w + L/2 per product, summed by the BSN —
            // i.e. the GEMM dot plus the constant offset `acc_w · L/2`.
            let mut count = plan.base + arow[p];
            // Residual contribution (§III.C alignment).
            if let Some((lut, rrow)) = res_in {
                count += lut[(rrow[p] as i64 + rhalf) as usize];
            }
            let c = (count.max(0) as usize).min(plan.lut_w - 1);
            out_row[p] = main_lut[c];
            if let Some(rl) = res_lut {
                res_out[l * npix + p] = rl[c];
            }
        }
    }
}

/// The faulted variant of [`conv_block`]'s SI loop: every circuit
/// stage's site-derived bitflip mask ([`crate::fault::inject`]) is
/// folded into the count domain *exactly*, without materializing a
/// single bit stream:
///
/// * **Mult** — each product stream is a canonical ones-prefix of
///   count `w·x + L/2`, so a flip at concatenated lane `g` (product
///   `g/L`, offset `g%L`) is −1 below the prefix, +1 above it.
/// * **Rescale** — the aligned residual stream is a canonical prefix
///   over `res_bits` lanes; [`inject::prefix_flip_delta`] gives the
///   popcount delta in one binary search.
/// * **Bsn** — one shared mask corrupts the sorted stream feeding both
///   SIs. A flip at lane `g` moves every tap reading `g`; that tap
///   multiplicity is the count-table difference `lut[g+1] − lut[g]`.
/// * **SiMain / SiRes** — output-lane flips re-evaluate the flipped
///   tap against the *corrupted* sorted stream
///   (`(c > q) XOR bsn_mask[q]`) to decide the ±1.
///
/// Bit-identical to the stream-materializing `ScExecutor` fault path
/// (property-tested in `rust/tests/gemm.rs`). The sparse mask vectors
/// live per call — the one deviation from the engine's zero-allocation
/// steady state, sized `O(ber · stage width)`.
#[allow(clippy::too_many_arguments)]
fn conv_block_faulted(
    pc: &PreparedConv,
    plan: &ConvPlan,
    rhalf: i64,
    cols: &[i32],
    res_src: &[i32],
    r0: usize,
    counts: &[i64],
    out: &mut [i32],
    res_out: &mut [i32],
    ctx: BlockCtx<'_>,
    fc: FaultCfg,
) {
    let npix = plan.oh * plan.ow;
    let rows = counts.len() / npix.max(1);
    let acc_w = plan.acc_w;
    let bsl = plan.act_bsl;
    let half = (bsl / 2) as i64;
    // Sparse stage masks, reused across the block's (channel, pixel)
    // sites.
    let (mut m_mult, mut m_res, mut m_bsn, mut m_si) =
        (Vec::new(), Vec::new(), Vec::new(), Vec::new());
    for l in 0..rows {
        let co = r0 + l;
        let wrow = &pc.wq.values[co * acc_w..(co + 1) * acc_w];
        let arow = &counts[l * npix..(l + 1) * npix];
        let main_lut = &plan.si_main_lut[co * plan.lut_w..(co + 1) * plan.lut_w];
        let res_lut = plan
            .si_res_lut
            .as_deref()
            .map(|t| &t[co * plan.lut_w..(co + 1) * plan.lut_w]);
        let res_in = plan
            .align_lut
            .as_deref()
            .map(|lut| (lut, &res_src[co * npix..(co + 1) * npix]));
        let main_taps = pc.si_main[co].taps();
        let res_taps = pc.si_res.as_ref().map(|sis| sis[co].taps());
        for p in 0..npix {
            let mut rng = inject::site_rng(fc.seed, ctx.tag, ctx.li, co, p, Stage::Mult);
            inject::fill_mask(&mut rng, fc.ber, acc_w * bsl, &mut m_mult);
            let mut count = plan.base + arow[p];
            let xrow = &cols[p * acc_w..(p + 1) * acc_w];
            for &g in &m_mult {
                let g = g as usize;
                let prefix = wrow[g / bsl] as i64 * xrow[g / bsl] as i64 + half;
                count += if ((g % bsl) as i64) < prefix { -1 } else { 1 };
            }
            if let Some((lut, rrow)) = res_in {
                let aligned = lut[(rrow[p] as i64 + rhalf) as usize];
                let mut rng = inject::site_rng(fc.seed, ctx.tag, ctx.li, co, p, Stage::Rescale);
                inject::fill_mask(&mut rng, fc.ber, pc.res_bits, &mut m_res);
                count += aligned + inject::prefix_flip_delta(&m_res, aligned as usize);
            }
            let c = (count.max(0) as usize).min(plan.lut_w - 1);
            let mut rng = inject::site_rng(fc.seed, ctx.tag, ctx.li, co, p, Stage::Bsn);
            inject::fill_mask(&mut rng, fc.ber, pc.bsn_width, &mut m_bsn);
            let si_rng = inject::site_rng(fc.seed, ctx.tag, ctx.li, co, p, Stage::SiMain);
            out[l * npix + p] = si_out_faulty(main_lut, main_taps, c, &m_bsn, fc, si_rng, &mut m_si);
            if let (Some(rl), Some(rt)) = (res_lut, res_taps) {
                let si_rng = inject::site_rng(fc.seed, ctx.tag, ctx.li, co, p, Stage::SiRes);
                res_out[l * npix + p] = si_out_faulty(rl, rt, c, &m_bsn, fc, si_rng, &mut m_si);
            }
        }
    }
}

/// One SI output under the shared BSN-lane mask plus its own
/// output-lane mask, in the count domain. `lut` is the channel's
/// signed count table (`lut[c]` = signed code on a clean sorted stream
/// of count `c`), `taps` its tap configuration over the same stream.
fn si_out_faulty(
    lut: &[i32],
    taps: &[SelTap],
    c: usize,
    m_bsn: &[u32],
    fc: FaultCfg,
    mut rng: crate::util::Rng,
    m_si: &mut Vec<u32>,
) -> i32 {
    let mut v = lut[c] as i64;
    for &g in m_bsn {
        let g = g as usize;
        let mult = (lut[g + 1] - lut[g]) as i64;
        v += if g < c { -mult } else { mult };
    }
    inject::fill_mask(&mut rng, fc.ber, taps.len(), m_si);
    for &j in m_si.iter() {
        let bit = match taps[j as usize] {
            SelTap::Zero => false,
            SelTap::One => true,
            SelTap::Bit(q) => (c > q) != inject::contains(m_bsn, q),
        };
        v += if bit { -1 } else { 1 };
    }
    v as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{ModelCfg, ModelParams};
    use crate::nn::quant::{Pruning, QuantConfig};
    use crate::nn::sc_exec::ScExecutor;
    use crate::util::Rng;

    fn prep_for(cfg: &ModelCfg, quant: QuantConfig, seed: u64) -> Arc<Prepared> {
        let mut rng = Rng::new(seed);
        let params = ModelParams::init(cfg, &mut rng);
        Arc::new(Prepared::new(cfg, &params, quant))
    }

    #[test]
    fn engine_matches_executor_on_tnn() {
        let cfg = ModelCfg::tnn();
        for bsl in [2usize, 4, 8] {
            let prep = prep_for(
                &cfg,
                QuantConfig {
                    act_bsl: Some(bsl),
                    weight_ternary: true,
                    residual_bsl: None,
                    pruning: Pruning::Off,
                },
                3,
            );
            let exec = ScExecutor::new(prep.clone());
            let mut engine = ScEngine::new(prep);
            let mut rng = Rng::new(41 + bsl as u64);
            for _ in 0..3 {
                let img = Tensor::from_vec(
                    &[1, 28, 28],
                    (0..784).map(|_| rng.normal() as f32).collect(),
                );
                assert_eq!(engine.forward(&img), exec.forward(&img), "bsl={bsl}");
            }
        }
    }

    #[test]
    fn engine_matches_executor_on_residual_scnet() {
        let cfg = ModelCfg::scnet(10);
        let prep = prep_for(&cfg, QuantConfig::w2a2r16(), 5);
        let exec = ScExecutor::new(prep.clone());
        let mut engine = ScEngine::new(prep);
        let mut rng = Rng::new(17);
        for _ in 0..2 {
            let img = Tensor::from_vec(
                &[3, 32, 32],
                (0..3 * 32 * 32).map(|_| rng.normal() as f32 * 0.5).collect(),
            );
            assert_eq!(engine.forward(&img), exec.forward(&img));
        }
    }

    #[test]
    fn batch_forward_equals_per_image() {
        let cfg = ModelCfg::tnn();
        let prep = prep_for(
            &cfg,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
            9,
        );
        let mut engine = ScEngine::new(prep);
        let mut rng = Rng::new(23);
        let batch = 3usize;
        let il = engine.image_len();
        let cl = engine.classes();
        let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32).collect();
        let mut batched = vec![0i64; batch * cl];
        engine.forward_batch_into(&x, &mut batched);
        for b in 0..batch {
            let mut one = vec![0i64; cl];
            engine.forward_into(&x[b * il..(b + 1) * il], &mut one);
            assert_eq!(&batched[b * cl..(b + 1) * cl], one.as_slice(), "image {b}");
        }
    }

    #[test]
    fn threaded_batch_is_bit_identical() {
        let cfg = ModelCfg::tnn();
        let prep = prep_for(
            &cfg,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
            31,
        );
        let mut seq = ScEngine::new(prep.clone());
        let mut rng = Rng::new(37);
        let batch = 5usize;
        let il = seq.image_len();
        let cl = seq.classes();
        let x: Vec<f32> = (0..batch * il).map(|_| rng.normal() as f32).collect();
        let mut expect = vec![0i64; batch * cl];
        seq.forward_batch_into(&x, &mut expect);
        // More threads than rows, equal, and fewer — all bit-identical.
        for threads in [2usize, 3, 5, 8] {
            let mut thr = ScEngine::with_threads(prep.clone(), threads);
            assert_eq!(thr.threads(), threads);
            let mut got = vec![0i64; batch * cl];
            thr.forward_batch_into(&x, &mut got);
            assert_eq!(got, expect, "threads={threads}");
        }
    }

    #[test]
    fn threaded_single_image_is_bit_identical() {
        // batch < threads takes the output-channel-block path; so does
        // forward_into on a threaded engine. Both model families.
        for (cfg, quant, shape) in [
            (
                ModelCfg::tnn(),
                QuantConfig {
                    act_bsl: Some(2),
                    weight_ternary: true,
                    residual_bsl: None,
                    pruning: Pruning::Off,
                },
                vec![1usize, 28, 28],
            ),
            (ModelCfg::scnet(10), QuantConfig::w2a2r16(), vec![3, 32, 32]),
        ] {
            let prep = prep_for(&cfg, quant, 43);
            let mut seq = ScEngine::new(prep.clone());
            let mut par = ScEngine::with_threads(prep, 4);
            let mut rng = Rng::new(51);
            let n: usize = shape.iter().product();
            for _ in 0..2 {
                let img =
                    Tensor::from_vec(&shape, (0..n).map(|_| rng.normal() as f32 * 0.5).collect());
                assert_eq!(par.forward(&img), seq.forward(&img), "{}", cfg.name);
            }
        }
    }

    #[test]
    fn sparse_routing_engages_and_stays_bit_identical() {
        let cfg = ModelCfg::tnn();
        let prep = prep_for(
            &cfg,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
            3,
        );
        let exec = ScExecutor::new(prep.clone());
        let mut engine = ScEngine::new(prep);
        let ctr = Arc::new(SparsityCounters::default());
        engine.set_sparsity_counters(Some(ctr.clone()));
        assert_eq!(ctr.density(), 1.0, "no forwards yet");
        // A mostly-zero image keeps every layer below the crossover, so
        // the sparse kernels carry the whole network; logits must still
        // match the stream-semantics executor exactly.
        let mut rng = Rng::new(61);
        let img = Tensor::from_vec(
            &[1, 28, 28],
            (0..784)
                .map(|i| if i % 19 == 0 { rng.normal() as f32 * 2.0 } else { 0.0 })
                .collect(),
        );
        assert_eq!(engine.forward(&img), exec.forward(&img));
        assert!(ctr.gemm_total() > 0);
        assert!(ctr.sparse_gemm() > 0, "sparse path must engage on a sparse image");
        assert!(ctr.density() < 1.0);
        assert!(ctr.act_nnz() <= ctr.act_elems());
        // Telemetry accumulates per forward and is schedule-independent.
        let before = ctr.gemm_total();
        assert_eq!(engine.forward(&img), exec.forward(&img));
        assert_eq!(ctr.gemm_total(), 2 * before);
    }

    #[test]
    fn engine_shares_the_prepared() {
        let cfg = ModelCfg::tnn();
        let prep = prep_for(
            &cfg,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
            1,
        );
        let a = ScEngine::new(prep.clone());
        let b = ScEngine::new(prep.clone());
        assert!(Arc::ptr_eq(a.prepared_arc(), b.prepared_arc()));
    }
}
