//! Integer GEMM core for the count-domain accumulation stage.
//!
//! Every executor in this crate reduces a conv layer to the same shape
//! of work: an im2col matrix of quantized activation codes (`npix × K`
//! i32 rows) against a panel of low-precision weight rows (`cout × K`),
//! accumulated exactly in `i64` counts. PR 3 made the *bit-level*
//! stages word-parallel, which moved the serving hot path into these
//! dot products — previously naive per-(channel, pixel) scalar loops.
//!
//! This module is the one implementation they all share, with the
//! weight panels packed **once at [`super::sc_exec::Prepared`] build
//! time** into the two formats the two model families want:
//!
//! * [`TernaryPanel`] — for the SC family, whose weights are ternary
//!   (`{-1, 0, +1}` after [`super::quant::TernaryTensor::quantize`]).
//!   Each weight row is split into a `+1` index list and a `−1` index
//!   list; **zeros are skipped entirely** (no load, no multiply) and
//!   the surviving terms are pure adds/subtracts — the paper's own
//!   argument that ternary weights make the accumulator multiplier-free
//!   applies to the simulator too. A typical ternarized row is ~²⁄₃
//!   non-zero, so this also cuts memory traffic by a third before any
//!   arithmetic win.
//! * [`I8Panel`] — for the binary/quantized family: a dense row-major
//!   `i8` panel walked by a 4×-wide unrolled microkernel (four pixel
//!   columns per pass, one weight load feeding four accumulators).
//!
//! The ternary kernel is **cache-blocked**: its output is produced in
//! [`BLOCK_CO`]-row channel blocks, and within a block the im2col row
//! of one pixel (a few KiB) is reused across every channel before
//! moving on, so the activation row stays in L1 while the much larger
//! index panel streams. The dense kernel's reuse lever is its
//! microkernel instead (one weight load feeds four pixel columns).
//! Accumulation is exact `i64` integer arithmetic
//! — no ordering, no rounding — which is what lets the threaded engine
//! shard output blocks freely and still produce **bit-identical**
//! logits (asserted in `rust/tests/gemm.rs`).
//!
//! Both panel kernels route their inner dots through the
//! runtime-dispatched SIMD table ([`Dispatch`]): the dense microkernel
//! becomes a widened 8-lane AVX2 multiply-accumulate and the ternary
//! index lists a gathered accumulate, while `SCNN_NO_SIMD=1` (or the
//! `_with` variants taking [`Dispatch::scalar`]) pins the original
//! scalar loops. Exact i64 accumulation makes every arm bit-identical
//! — `ScEngine`, `ScExecutor`, `BinaryExecutor` and the classifier
//! arms inherit the vector paths with zero call-site changes.
//!
//! [`gemm_naive`] is the reference triple loop the packed kernels are
//! property-tested against; `rust/benches/sc_serve.rs` tracks the
//! packed-vs-naive ratio in `BENCH_sc.json` (DESIGN.md §Perf,
//! "Ternary GEMM + threading").

use crate::util::simd::Dispatch;

/// Output-channel block width of the cache-blocked kernels. Eight i64
/// accumulator lanes per activation-row pass: small enough to live in
/// registers, large enough to amortize the activation-row loads.
pub const BLOCK_CO: usize = 8;

/// Activation-density crossover of the sparse GEMM path: a layer whose
/// measured im2col density (`nnz / (npix·k)`) is at or below this
/// routes through [`SparseCols`] + `gemm_sparse_*`; denser layers stay
/// on the dense kernels, whose contiguous loads win once most entries
/// are nonzero anyway. The threshold only picks *which* exact-i64
/// kernel runs — both produce identical counts — so it can be tuned
/// freely without any accuracy consequence.
pub const SPARSE_DENSITY_CROSSOVER: f64 = 0.5;

/// CSR-style compressed im2col panel: per output pixel (one GEMM
/// column) the nonzero activation codes and their positions within the
/// `k`-wide accumulation. ReLU-quantized activations are mostly zeros
/// at low BSL, and a zero contributes nothing to an exact integer
/// count — so the sparse kernels skip them outright instead of
/// streaming them. Column index lists are ascending by construction,
/// which is what the gathered [`Dispatch::sparse_i8_dot`] arm and the
/// merge-intersection of [`TernaryPanel::gemm_sparse_into`] rely on.
#[derive(Clone, Debug, Default)]
pub struct SparseCols {
    n: usize,
    k: usize,
    /// Concatenated per-column nonzero values.
    vals: Vec<i32>,
    /// Positions of `vals` within their column (`< k`, ascending per
    /// column).
    idx: Vec<u32>,
    /// Column starts into `vals`/`idx` (`n + 1` entries).
    off: Vec<u32>,
}

impl SparseCols {
    /// An empty panel (fill later with [`SparseCols::fill_from`]).
    pub fn new() -> Self {
        Self::default()
    }

    /// Compress an `n × k` row-major im2col matrix (one row per output
    /// pixel, matching the dense kernels' `cols` layout).
    pub fn compress(cols: &[i32], n: usize, k: usize) -> Self {
        let mut s = Self::new();
        s.fill_from(cols, n, k);
        s
    }

    /// Re-fill from a dense im2col matrix, reusing the allocations —
    /// the zero-alloc steady state of the engine's per-layer scratch.
    pub fn fill_from(&mut self, cols: &[i32], n: usize, k: usize) {
        assert_eq!(cols.len(), n * k, "SparseCols::fill_from: cols size mismatch");
        assert!(k <= u32::MAX as usize, "SparseCols::fill_from: row width exceeds u32 indices");
        self.n = n;
        self.k = k;
        self.vals.clear();
        self.idx.clear();
        self.off.clear();
        self.off.push(0);
        if k == 0 {
            self.off.resize(n + 1, 0);
            return;
        }
        for col in cols.chunks_exact(k) {
            for (i, &v) in col.iter().enumerate() {
                if v != 0 {
                    self.vals.push(v);
                    self.idx.push(i as u32);
                }
            }
            self.off.push(self.vals.len() as u32);
        }
    }

    /// Number of columns (output pixels).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Column height (accumulation width).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Total nonzero entries across all columns.
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Fraction of entries that are nonzero; `1.0` for an empty shape
    /// (nothing to skip, so it reads as dense).
    pub fn density(&self) -> f64 {
        let total = self.n * self.k;
        if total == 0 {
            return 1.0;
        }
        self.vals.len() as f64 / total as f64
    }

    /// One column's `(values, positions)` pair.
    #[inline]
    pub fn col(&self, p: usize) -> (&[i32], &[u32]) {
        let lo = self.off[p] as usize;
        let hi = self.off[p + 1] as usize;
        (&self.vals[lo..hi], &self.idx[lo..hi])
    }
}

/// Reference GEMM: `out[r·n + p] = Σ_i w[r·k + i] · cols[p·k + i]`,
/// the naive triple loop every packed kernel must reproduce exactly.
/// `w` is `rows × k` row-major, `cols` is `n × k` row-major (one im2col
/// row per output pixel), `out` is `rows × n` row-major.
pub fn gemm_naive(w: &[i8], rows: usize, k: usize, cols: &[i32], n: usize, out: &mut [i64]) {
    assert_eq!(w.len(), rows * k, "gemm_naive: weight panel size mismatch");
    assert_eq!(cols.len(), n * k, "gemm_naive: activation matrix size mismatch");
    assert_eq!(out.len(), rows * n, "gemm_naive: output size mismatch");
    for r in 0..rows {
        let wrow = &w[r * k..(r + 1) * k];
        for p in 0..n {
            let x = &cols[p * k..(p + 1) * k];
            let mut s = 0i64;
            for i in 0..k {
                s += x[i] as i64 * wrow[i] as i64;
            }
            out[r * n + p] = s;
        }
    }
}

/// Column-sum vector of an im2col activation matrix:
/// `out[i] = Σ_p cols[p·k + i]`. This is the checksum basis of the
/// integrity guard ([`crate::fault::guard::DatapathGuard`]): by
/// linearity, the counts of GEMM row `r` must sum to
/// `row_dot_i64(r, out)`, so one `O(k)` dot verifies `npix` counts.
pub fn column_sums(cols: &[i32], k: usize, out: &mut Vec<i64>) {
    assert!(k > 0 && cols.len() % k == 0, "column_sums: cols not a multiple of k");
    out.clear();
    out.resize(k, 0);
    for col in cols.chunks_exact(k) {
        for (o, &v) in out.iter_mut().zip(col) {
            *o += v as i64;
        }
    }
}

/// Ternary weight panel packed as per-row `+1` / `−1` index lists
/// (CSR-like; zeros dropped at pack time). The multiplication
/// disappears: a row dot is `Σ x[plus] − Σ x[minus]`.
#[derive(Clone, Debug)]
pub struct TernaryPanel {
    rows: usize,
    k: usize,
    /// Concatenated per-row index lists: for row `r`,
    /// `idx[off[r]..mid[r]]` are the `+1` positions and
    /// `idx[mid[r]..off[r+1]]` the `−1` positions.
    idx: Vec<u32>,
    /// Row starts into `idx` (`rows + 1` entries).
    off: Vec<u32>,
    /// Per-row boundary between the `+1` and `−1` lists.
    mid: Vec<u32>,
}

impl TernaryPanel {
    /// Pack a `rows × k` row-major ternary panel. Panics when a value
    /// is outside `{-1, 0, +1}` — those rows belong in an [`I8Panel`].
    pub fn pack(values: &[i8], rows: usize, k: usize) -> Self {
        assert_eq!(values.len(), rows * k, "TernaryPanel::pack: panel size mismatch");
        assert!(k <= u32::MAX as usize, "TernaryPanel::pack: row width exceeds u32 indices");
        let mut idx = Vec::new();
        let mut off = Vec::with_capacity(rows + 1);
        let mut mid = Vec::with_capacity(rows);
        off.push(0u32);
        for r in 0..rows {
            let wrow = &values[r * k..(r + 1) * k];
            for (i, &v) in wrow.iter().enumerate() {
                if v == 1 {
                    idx.push(i as u32);
                } else {
                    assert!(
                        v == 0 || v == -1,
                        "TernaryPanel::pack: non-ternary weight {v} at row {r}, col {i}"
                    );
                }
            }
            mid.push(idx.len() as u32);
            for (i, &v) in wrow.iter().enumerate() {
                if v == -1 {
                    idx.push(i as u32);
                }
            }
            off.push(idx.len() as u32);
        }
        Self { rows, k, idx, off, mid }
    }

    /// Number of weight rows (output channels).
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row width (accumulation width / reduction dimension).
    pub fn k(&self) -> usize {
        self.k
    }

    /// Non-zero weights surviving the pack (the work the kernel does;
    /// `k·rows − nnz` multiplies were skipped outright).
    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// The `+1` and `−1` index lists of one row.
    #[inline]
    fn row_lists(&self, r: usize) -> (&[u32], &[u32]) {
        let lo = self.off[r] as usize;
        let mi = self.mid[r] as usize;
        let hi = self.off[r + 1] as usize;
        (&self.idx[lo..mi], &self.idx[mi..hi])
    }

    /// Dot of row `r` with one im2col row (`k` i32 codes): adds and
    /// subtracts only, zero weights never touched — a gathered
    /// accumulate on the SIMD arms.
    #[inline]
    pub fn row_dot(&self, r: usize, x: &[i32]) -> i64 {
        self.row_dot_with(Dispatch::active(), r, x)
    }

    /// [`TernaryPanel::row_dot`] through an explicit kernel table —
    /// [`Dispatch::scalar`] pins the reference arm (benches, property
    /// tests); the active table is what [`TernaryPanel::row_dot`] uses.
    #[inline]
    pub fn row_dot_with(&self, d: &Dispatch, r: usize, x: &[i32]) -> i64 {
        assert_eq!(x.len(), self.k, "TernaryPanel::row_dot: activation row width");
        let (plus, minus) = self.row_lists(r);
        // SAFETY: pack() stores only column indices < k, and x.len()
        // == k was just asserted, so every gathered index is in bounds.
        unsafe { d.gather_sub_i32(plus, minus, x) }
    }

    /// [`TernaryPanel::row_dot`] over `i64` inputs — the classifier
    /// path, where the GAP accumulator is already 64-bit.
    #[inline]
    pub fn row_dot_i64(&self, r: usize, x: &[i64]) -> i64 {
        self.row_dot_i64_with(Dispatch::active(), r, x)
    }

    /// [`TernaryPanel::row_dot_i64`] through an explicit kernel table.
    #[inline]
    pub fn row_dot_i64_with(&self, d: &Dispatch, r: usize, x: &[i64]) -> i64 {
        assert_eq!(x.len(), self.k, "TernaryPanel::row_dot_i64: activation row width");
        let (plus, minus) = self.row_lists(r);
        // SAFETY: pack() stores only column indices < k == x.len().
        unsafe { d.gather_sub_i64(plus, minus, x) }
    }

    /// Cache-blocked GEMM: `out[r·n + p] = row_dot(r, cols row p)`.
    /// Bit-identical to [`gemm_naive`] on ternary panels (exact i64
    /// accumulation; property-tested). Within each [`BLOCK_CO`]-row
    /// channel block the kernel walks pixels in the outer loop, so one
    /// im2col row is loaded once and consumed by the whole block.
    pub fn gemm_into(&self, cols: &[i32], n: usize, out: &mut [i64]) {
        self.gemm_rows_into(0, self.rows, cols, n, out);
    }

    /// [`TernaryPanel::gemm_into`] through an explicit kernel table.
    pub fn gemm_into_with(&self, d: &Dispatch, cols: &[i32], n: usize, out: &mut [i64]) {
        self.gemm_rows_into_with(d, 0, self.rows, cols, n, out);
    }

    /// [`TernaryPanel::gemm_into`] restricted to weight rows
    /// `r0..r1`, writing into a `(r1−r0) × n` chunk — the work unit of
    /// the engine's output-channel-block sharding (each thread owns a
    /// disjoint row range, so the full result is assembled without
    /// synchronization and stays bit-identical to the full-panel call).
    pub fn gemm_rows_into(&self, r0: usize, r1: usize, cols: &[i32], n: usize, out: &mut [i64]) {
        self.gemm_rows_into_with(Dispatch::active(), r0, r1, cols, n, out);
    }

    /// [`TernaryPanel::gemm_rows_into`] through an explicit kernel
    /// table.
    pub fn gemm_rows_into_with(
        &self,
        d: &Dispatch,
        r0: usize,
        r1: usize,
        cols: &[i32],
        n: usize,
        out: &mut [i64],
    ) {
        assert!(r0 <= r1 && r1 <= self.rows, "TernaryPanel::gemm_rows_into: row range");
        assert_eq!(cols.len(), n * self.k, "TernaryPanel::gemm_rows_into: cols size mismatch");
        assert_eq!(out.len(), (r1 - r0) * n, "TernaryPanel::gemm_rows_into: out size mismatch");
        if self.k == 0 {
            out.fill(0);
            return;
        }
        for b0 in (r0..r1).step_by(BLOCK_CO) {
            let b1 = (b0 + BLOCK_CO).min(r1);
            for (p, x) in cols.chunks_exact(self.k).enumerate() {
                for r in b0..b1 {
                    out[(r - r0) * n + p] = self.row_dot_with(d, r, x);
                }
            }
        }
    }

    /// Sparse-activation GEMM: like [`TernaryPanel::gemm_into`] but
    /// over a compressed [`SparseCols`] panel, intersecting each row's
    /// `+1`/`−1` index lists with each column's nonzero positions —
    /// `O(nnz_w + nnz_x)` per dot instead of touching all `k` slots.
    /// Exact i64 accumulation over the same surviving terms, so the
    /// counts are bit-identical to the dense path.
    pub fn gemm_sparse_into(&self, sp: &SparseCols, out: &mut [i64]) {
        self.gemm_sparse_rows_into(0, self.rows, sp, out);
    }

    /// [`TernaryPanel::gemm_sparse_into`] through an explicit kernel
    /// table.
    pub fn gemm_sparse_into_with(&self, d: &Dispatch, sp: &SparseCols, out: &mut [i64]) {
        self.gemm_sparse_rows_into_with(d, 0, self.rows, sp, out);
    }

    /// [`TernaryPanel::gemm_sparse_into`] restricted to weight rows
    /// `r0..r1` — the sparse twin of [`TernaryPanel::gemm_rows_into`],
    /// sharing its output layout so the engine's channel-block
    /// sharding can route either path per layer.
    pub fn gemm_sparse_rows_into(&self, r0: usize, r1: usize, sp: &SparseCols, out: &mut [i64]) {
        self.gemm_sparse_rows_into_with(Dispatch::active(), r0, r1, sp, out);
    }

    /// [`TernaryPanel::gemm_sparse_rows_into`] through an explicit
    /// kernel table.
    pub fn gemm_sparse_rows_into_with(
        &self,
        d: &Dispatch,
        r0: usize,
        r1: usize,
        sp: &SparseCols,
        out: &mut [i64],
    ) {
        assert!(r0 <= r1 && r1 <= self.rows, "TernaryPanel::gemm_sparse_rows_into: row range");
        assert_eq!(sp.k(), self.k, "TernaryPanel::gemm_sparse_rows_into: column height");
        assert_eq!(
            out.len(),
            (r1 - r0) * sp.n(),
            "TernaryPanel::gemm_sparse_rows_into: out size mismatch"
        );
        let n = sp.n();
        if self.k == 0 {
            out.fill(0);
            return;
        }
        for b0 in (r0..r1).step_by(BLOCK_CO) {
            let b1 = (b0 + BLOCK_CO).min(r1);
            for p in 0..n {
                let (vals, idx) = sp.col(p);
                if idx.len() == self.k {
                    // Fully-dense column: its positions are exactly
                    // 0..k, so `vals` *is* the dense column — take the
                    // gathered dense kernel instead of intersecting.
                    for r in b0..b1 {
                        out[(r - r0) * n + p] = self.row_dot_with(d, r, vals);
                    }
                } else {
                    for r in b0..b1 {
                        let (plus, minus) = self.row_lists(r);
                        out[(r - r0) * n + p] =
                            intersect_sum(plus, vals, idx) - intersect_sum(minus, vals, idx);
                    }
                }
            }
        }
    }
}

/// `Σ vals[j]` over the positions where the sorted weight-index `list`
/// and the sorted nonzero-position list `idx` intersect — the
/// two-pointer merge at the heart of the ternary sparse dot. Both
/// lists are strictly ascending (pack order for weights, column order
/// for activations), so one linear pass finds every surviving term.
#[inline]
fn intersect_sum(list: &[u32], vals: &[i32], idx: &[u32]) -> i64 {
    let (mut a, mut b) = (0usize, 0usize);
    let mut s = 0i64;
    while a < list.len() && b < idx.len() {
        let (la, ib) = (list[a], idx[b]);
        if la == ib {
            s += vals[b] as i64;
            a += 1;
            b += 1;
        } else if la < ib {
            a += 1;
        } else {
            b += 1;
        }
    }
    s
}

/// Dense low-bit weight panel (row-major `i8`) with a 4×-wide unrolled
/// microkernel: four pixel columns advance together, so each weight
/// byte is loaded once and feeds four independent i64 accumulators.
#[derive(Clone, Debug)]
pub struct I8Panel {
    rows: usize,
    k: usize,
    data: Vec<i8>,
}

impl I8Panel {
    /// Pack a `rows × k` row-major `i8` panel (any i8 values — the
    /// quantized/binary family is not restricted to ternary).
    pub fn pack(values: &[i8], rows: usize, k: usize) -> Self {
        assert_eq!(values.len(), rows * k, "I8Panel::pack: panel size mismatch");
        Self { rows, k, data: values.to_vec() }
    }

    /// Number of weight rows.
    pub fn rows(&self) -> usize {
        self.rows
    }

    /// Row width.
    pub fn k(&self) -> usize {
        self.k
    }

    /// One packed weight row.
    #[inline]
    pub fn row(&self, r: usize) -> &[i8] {
        &self.data[r * self.k..(r + 1) * self.k]
    }

    /// Dot of row `r` with one activation row — the widened
    /// multiply-accumulate kernel on the SIMD arms.
    #[inline]
    pub fn row_dot(&self, r: usize, x: &[i32]) -> i64 {
        self.row_dot_with(Dispatch::active(), r, x)
    }

    /// [`I8Panel::row_dot`] through an explicit kernel table.
    #[inline]
    pub fn row_dot_with(&self, d: &Dispatch, r: usize, x: &[i32]) -> i64 {
        assert_eq!(x.len(), self.k, "I8Panel::row_dot: activation row width");
        d.i8_dot(self.row(r), x)
    }

    /// [`I8Panel::row_dot`] over `i64` inputs (classifier path). Stays
    /// on the scalar loop: the classifier calls it once per class per
    /// image, far off the hot path, and an i64×i64 lane product has no
    /// AVX2 win.
    #[inline]
    pub fn row_dot_i64(&self, r: usize, x: &[i64]) -> i64 {
        debug_assert_eq!(x.len(), self.k);
        let mut s = 0i64;
        for (&xv, &wv) in x.iter().zip(self.row(r)) {
            s += xv * wv as i64;
        }
        s
    }

    /// GEMM via the 4×-wide microkernel; bit-identical to
    /// [`gemm_naive`] (exact i64 accumulation, property-tested). The
    /// dense panel's reuse lever is the microkernel itself — each
    /// weight byte loaded once for four pixel columns — so rows are
    /// walked flat (channel blocking buys nothing here; it belongs to
    /// the ternary kernel's gather pattern).
    pub fn gemm_into(&self, cols: &[i32], n: usize, out: &mut [i64]) {
        self.gemm_into_with(Dispatch::active(), cols, n, out);
    }

    /// [`I8Panel::gemm_into`] through an explicit kernel table.
    pub fn gemm_into_with(&self, d: &Dispatch, cols: &[i32], n: usize, out: &mut [i64]) {
        assert_eq!(cols.len(), n * self.k, "I8Panel::gemm_into: cols size mismatch");
        assert_eq!(out.len(), self.rows * n, "I8Panel::gemm_into: out size mismatch");
        let k = self.k;
        for r in 0..self.rows {
            let wrow = self.row(r);
            let orow = &mut out[r * n..(r + 1) * n];
            let mut p = 0usize;
            // Microkernel: 4 pixel columns per pass, one (widened)
            // weight load feeding 4 accumulators.
            while p + 4 <= n {
                let x = [
                    &cols[p * k..(p + 1) * k],
                    &cols[(p + 1) * k..(p + 2) * k],
                    &cols[(p + 2) * k..(p + 3) * k],
                    &cols[(p + 3) * k..(p + 4) * k],
                ];
                let acc = d.i8_dot4(wrow, x);
                orow[p..p + 4].copy_from_slice(&acc);
                p += 4;
            }
            // Ragged edge narrower than the microkernel.
            while p < n {
                orow[p] = self.row_dot_with(d, r, &cols[p * k..(p + 1) * k]);
                p += 1;
            }
        }
    }

    /// Sparse-activation GEMM over a compressed [`SparseCols`] panel:
    /// each dot touches only a column's nonzeros, reaching the dense
    /// weight row through [`Dispatch::sparse_i8_dot`] (gathered byte
    /// loads on the vector arms). Bit-identical to [`I8Panel::gemm_into`]
    /// — the skipped terms are exact zeros in an exact i64 sum.
    pub fn gemm_sparse_into(&self, sp: &SparseCols, out: &mut [i64]) {
        self.gemm_sparse_into_with(Dispatch::active(), sp, out);
    }

    /// [`I8Panel::gemm_sparse_into`] through an explicit kernel table.
    pub fn gemm_sparse_into_with(&self, d: &Dispatch, sp: &SparseCols, out: &mut [i64]) {
        assert_eq!(sp.k(), self.k, "I8Panel::gemm_sparse_into: column height");
        assert_eq!(out.len(), self.rows * sp.n(), "I8Panel::gemm_sparse_into: out size mismatch");
        let n = sp.n();
        for r in 0..self.rows {
            let wrow = self.row(r);
            let orow = &mut out[r * n..(r + 1) * n];
            for (p, o) in orow.iter_mut().enumerate() {
                let (vals, idx) = sp.col(p);
                *o = if idx.len() == self.k {
                    // Fully-dense column: positions are 0..k, so
                    // `vals` is the dense column — use the contiguous
                    // multiply-accumulate kernel.
                    d.i8_dot(wrow, vals)
                } else {
                    // SAFETY: SparseCols stores ascending positions
                    // < k == wrow.len().
                    unsafe { d.sparse_i8_dot(wrow, vals, idx) }
                };
            }
        }
    }
}

/// Both packings of one weight panel, built together at `Prepared`
/// freeze time: the SC family consumes [`WeightPanels::ternary`], the
/// binary/quantized family [`WeightPanels::dense`]. One pack call, one
/// source of truth for the panel geometry. Deliberate trade-off: one
/// frozen model carries both formats (plus the raw `wq.values` the
/// fault path walks) so any executor family can attach to the same
/// shared `Arc<Prepared>` without re-packing — a few extra bytes per
/// weight on models this size, paid once per freeze, never per worker.
#[derive(Clone, Debug)]
pub struct WeightPanels {
    /// Zero-skipping add/sub panel for the ternary family.
    pub ternary: TernaryPanel,
    /// Dense microkernel panel for the binary/quantized family.
    pub dense: I8Panel,
}

impl WeightPanels {
    /// Pack a `rows × k` row-major ternary panel both ways.
    pub fn pack(values: &[i8], rows: usize, k: usize) -> Self {
        Self {
            ternary: TernaryPanel::pack(values, rows, k),
            dense: I8Panel::pack(values, rows, k),
        }
    }
}

/// Unrolled f32 dot product for the float layers (`layers::linear`,
/// `layers::conv2d`). Single accumulator, strictly sequential adds —
/// **bit-identical** to the scalar loop it replaces (float summation
/// order is observable), just with the loop control amortized 4×.
#[inline]
pub fn dot_f32(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0f32;
    let mut ca = a.chunks_exact(4);
    let mut cb = b.chunks_exact(4);
    for (qa, qb) in ca.by_ref().zip(cb.by_ref()) {
        s += qa[0] * qb[0];
        s += qa[1] * qb[1];
        s += qa[2] * qb[2];
        s += qa[3] * qb[3];
    }
    for (x, y) in ca.remainder().iter().zip(cb.remainder()) {
        s += x * y;
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn random_panel(rng: &mut Rng, rows: usize, k: usize, ternary: bool) -> Vec<i8> {
        (0..rows * k)
            .map(|_| {
                if ternary {
                    rng.gen_range_i64(-1, 1) as i8
                } else {
                    rng.gen_range_i64(-128, 127) as i8
                }
            })
            .collect()
    }

    fn random_cols(rng: &mut Rng, n: usize, k: usize) -> Vec<i32> {
        (0..n * k).map(|_| rng.gen_range_i64(-8, 9) as i32).collect()
    }

    #[test]
    fn ternary_panel_matches_naive() {
        let mut rng = Rng::new(1);
        for &(rows, k, n) in
            &[(1usize, 1usize, 1usize), (3, 7, 5), (8, 9, 16), (17, 72, 49), (5, 144, 3)]
        {
            let w = random_panel(&mut rng, rows, k, true);
            let cols = random_cols(&mut rng, n, k);
            let mut expect = vec![0i64; rows * n];
            gemm_naive(&w, rows, k, &cols, n, &mut expect);
            let panel = TernaryPanel::pack(&w, rows, k);
            assert_eq!(panel.rows(), rows);
            assert_eq!(panel.k(), k);
            let mut got = vec![i64::MIN; rows * n];
            panel.gemm_into(&cols, n, &mut got);
            assert_eq!(got, expect, "rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn i8_panel_matches_naive_including_ragged_edges() {
        let mut rng = Rng::new(2);
        // n below, at, and above the 4-wide microkernel; rows straddling
        // BLOCK_CO.
        for &(rows, k, n) in &[(1usize, 3usize, 1usize), (2, 5, 3), (9, 8, 4), (11, 13, 7)] {
            let w = random_panel(&mut rng, rows, k, false);
            let cols = random_cols(&mut rng, n, k);
            let mut expect = vec![0i64; rows * n];
            gemm_naive(&w, rows, k, &cols, n, &mut expect);
            let panel = I8Panel::pack(&w, rows, k);
            let mut got = vec![i64::MIN; rows * n];
            panel.gemm_into(&cols, n, &mut got);
            assert_eq!(got, expect, "rows={rows} k={k} n={n}");
        }
    }

    #[test]
    fn ternary_pack_skips_zeros() {
        let w: Vec<i8> = vec![1, 0, -1, 0, 0, 1];
        let panel = TernaryPanel::pack(&w, 2, 3);
        assert_eq!(panel.nnz(), 3);
        assert_eq!(panel.row_dot(0, &[10, 20, 30]), 10 - 30);
        assert_eq!(panel.row_dot(1, &[4, 5, 6]), 6);
        assert_eq!(panel.row_dot_i64(0, &[10, 20, 30]), -20);
    }

    #[test]
    #[should_panic(expected = "non-ternary weight")]
    fn ternary_pack_rejects_wide_values() {
        TernaryPanel::pack(&[2], 1, 1);
    }

    #[test]
    fn i64_dots_match_i32_dots() {
        let mut rng = Rng::new(3);
        let w = random_panel(&mut rng, 4, 10, true);
        let x32 = random_cols(&mut rng, 1, 10);
        let x64: Vec<i64> = x32.iter().map(|&v| v as i64).collect();
        let tp = TernaryPanel::pack(&w, 4, 10);
        let dp = I8Panel::pack(&w, 4, 10);
        for r in 0..4 {
            assert_eq!(tp.row_dot(r, &x32), tp.row_dot_i64(r, &x64));
            assert_eq!(dp.row_dot(r, &x32), dp.row_dot_i64(r, &x64));
            assert_eq!(tp.row_dot(r, &x32), dp.row_dot(r, &x32));
        }
    }

    #[test]
    fn weight_panels_pack_both_families() {
        let w: Vec<i8> = vec![1, -1, 0, 0, 1, 1];
        let p = WeightPanels::pack(&w, 2, 3);
        assert_eq!(p.ternary.rows(), p.dense.rows());
        assert_eq!(p.ternary.row_dot(1, &[1, 2, 3]), p.dense.row_dot(1, &[1, 2, 3]));
    }

    fn sparse_cols(rng: &mut Rng, n: usize, k: usize, zero_p: f64) -> Vec<i32> {
        (0..n * k)
            .map(|_| {
                if rng.gen_bool(zero_p) {
                    0
                } else {
                    rng.gen_range_i64(-8, 9) as i32
                }
            })
            .collect()
    }

    #[test]
    fn sparse_cols_roundtrip_and_density() {
        let cols = vec![0, 3, 0, -2, 0, 0, 7, 0, 1, 0, 0, 0];
        let sp = SparseCols::compress(&cols, 3, 4);
        assert_eq!((sp.n(), sp.k(), sp.nnz()), (3, 4, 4));
        assert!((sp.density() - 4.0 / 12.0).abs() < 1e-12);
        assert_eq!(sp.col(0), (&[3, -2][..], &[1u32, 3][..]));
        assert_eq!(sp.col(1), (&[7][..], &[2u32][..]));
        assert_eq!(sp.col(2), (&[1][..], &[0u32][..]));
        // fill_from reuses the panel across shapes.
        let mut sp = sp;
        sp.fill_from(&[5, 0], 1, 2);
        assert_eq!((sp.n(), sp.nnz()), (1, 1));
        assert_eq!(SparseCols::compress(&[], 3, 0).density(), 1.0);
    }

    #[test]
    fn sparse_gemm_matches_naive_both_panels() {
        let mut rng = Rng::new(6);
        for &(rows, k, n) in
            &[(1usize, 1usize, 1usize), (3, 7, 5), (8, 9, 16), (17, 72, 49), (5, 144, 3)]
        {
            for zero_p in [0.0, 0.5, 0.9, 1.0] {
                let cols = sparse_cols(&mut rng, n, k, zero_p);
                let sp = SparseCols::compress(&cols, n, k);
                for ternary in [true, false] {
                    let w = random_panel(&mut rng, rows, k, ternary);
                    let mut expect = vec![0i64; rows * n];
                    gemm_naive(&w, rows, k, &cols, n, &mut expect);
                    let mut got = vec![i64::MIN; rows * n];
                    if ternary {
                        TernaryPanel::pack(&w, rows, k).gemm_sparse_into(&sp, &mut got);
                    } else {
                        I8Panel::pack(&w, rows, k).gemm_sparse_into(&sp, &mut got);
                    }
                    assert_eq!(
                        got, expect,
                        "ternary={ternary} rows={rows} k={k} n={n} zero_p={zero_p}"
                    );
                }
            }
        }
    }

    #[test]
    fn sparse_row_ranges_assemble_the_full_result() {
        let mut rng = Rng::new(7);
        let (rows, k, n) = (11usize, 23usize, 9usize);
        let w = random_panel(&mut rng, rows, k, true);
        let cols = sparse_cols(&mut rng, n, k, 0.6);
        let sp = SparseCols::compress(&cols, n, k);
        let panel = TernaryPanel::pack(&w, rows, k);
        let mut full = vec![0i64; rows * n];
        panel.gemm_sparse_into(&sp, &mut full);
        let mut sharded = vec![i64::MIN; rows * n];
        for (r0, r1) in [(0usize, 4usize), (4, 5), (5, 11)] {
            panel.gemm_sparse_rows_into(r0, r1, &sp, &mut sharded[r0 * n..r1 * n]);
        }
        assert_eq!(sharded, full);
    }

    #[test]
    fn dot_f32_matches_scalar_order() {
        let mut rng = Rng::new(4);
        for len in [0usize, 1, 3, 4, 7, 8, 129] {
            let a: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..len).map(|_| rng.normal() as f32).collect();
            let mut s = 0.0f32;
            for i in 0..len {
                s += a[i] * b[i];
            }
            // Identical summation order -> identical bits.
            assert_eq!(dot_f32(&a, &b).to_bits(), s.to_bits(), "len={len}");
        }
    }
}
