//! Minimal dense f32 tensor (CHW layout for images).

/// A dense row-major f32 tensor.
#[derive(Clone, Debug, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl Tensor {
    /// All-zero tensor.
    pub fn zeros(shape: &[usize]) -> Self {
        let n: usize = shape.iter().product();
        Self { shape: shape.to_vec(), data: vec![0.0; n] }
    }

    /// Wrap existing data.
    pub fn from_vec(shape: &[usize], data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len());
        Self { shape: shape.to_vec(), data }
    }

    /// Shape.
    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    /// Total elements.
    pub fn len(&self) -> usize {
        self.data.len()
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Raw data.
    pub fn data(&self) -> &[f32] {
        &self.data
    }

    /// Mutable raw data.
    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    /// Consume into the raw vector.
    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    /// 3-D (C,H,W) accessor.
    pub fn at3(&self, c: usize, h: usize, w: usize) -> f32 {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(c * self.shape[1] + h) * self.shape[2] + w]
    }

    /// 3-D (C,H,W) setter.
    pub fn set3(&mut self, c: usize, h: usize, w: usize, v: f32) {
        debug_assert_eq!(self.shape.len(), 3);
        self.data[(c * self.shape[1] + h) * self.shape[2] + w] = v;
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: &[usize]) -> Self {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape.to_vec();
        self
    }

    /// Element-wise map.
    pub fn map(mut self, f: impl Fn(f32) -> f32) -> Self {
        for v in &mut self.data {
            *v = f(*v);
        }
        self
    }

    /// Index of the maximum element (argmax over the flat data).
    pub fn argmax(&self) -> usize {
        self.data
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0)
    }

    /// Mean of absolute values.
    pub fn mean_abs(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().map(|v| v.abs()).sum::<f32>() / self.data.len() as f32
    }

    /// Maximum absolute value.
    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, v| m.max(v.abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zeros_and_shape() {
        let t = Tensor::zeros(&[2, 3, 4]);
        assert_eq!(t.len(), 24);
        assert_eq!(t.shape(), &[2, 3, 4]);
    }

    #[test]
    fn at3_row_major() {
        let mut t = Tensor::zeros(&[2, 2, 2]);
        t.set3(1, 0, 1, 5.0);
        assert_eq!(t.at3(1, 0, 1), 5.0);
        assert_eq!(t.data()[5], 5.0);
    }

    #[test]
    fn argmax_and_stats() {
        let t = Tensor::from_vec(&[4], vec![-3.0, 1.0, 2.0, -0.5]);
        assert_eq!(t.argmax(), 2);
        assert!((t.mean_abs() - 1.625).abs() < 1e-6);
        assert_eq!(t.max_abs(), 3.0);
    }

    #[test]
    fn reshape_and_map() {
        let t = Tensor::from_vec(&[4], vec![1.0, 2.0, 3.0, 4.0])
            .reshape(&[2, 2])
            .map(|x| x * 2.0);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.data(), &[2.0, 4.0, 6.0, 8.0]);
    }
}
