//! Layer primitives: im2col convolution, linear, pooling, BN folding.
//!
//! Convolutions are expressed through im2col so that one output pixel is
//! exactly one accumulation of width `K·K·C_in` — the unit the paper's
//! datapath (multiplier array → BSN → SI) processes, and the width that
//! drives the BSN cost model (Fig 9, Fig 13).

use super::gemm::{self, I8Panel};
use super::tensor::Tensor;

/// Static shape of a conv layer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ConvShape {
    /// Input channels.
    pub cin: usize,
    /// Output channels.
    pub cout: usize,
    /// Square kernel size.
    pub k: usize,
    /// Stride.
    pub stride: usize,
    /// Symmetric zero padding.
    pub pad: usize,
}

impl ConvShape {
    /// Accumulation width (products per output pixel) — the paper's
    /// "accumulation width".
    pub fn acc_width(&self) -> usize {
        self.k * self.k * self.cin
    }

    /// Output spatial size for an input of `h × w`.
    pub fn out_hw(&self, h: usize, w: usize) -> (usize, usize) {
        (
            (h + 2 * self.pad - self.k) / self.stride + 1,
            (w + 2 * self.pad - self.k) / self.stride + 1,
        )
    }
}

/// im2col: unfold a CHW image into rows of length `k·k·cin`, one row per
/// output pixel (row-major over output h, w). Padding contributes zeros.
pub fn im2col(x: &Tensor, cs: &ConvShape) -> (Vec<f32>, usize, usize) {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert_eq!(c, cs.cin);
    let (oh, ow) = cs.out_hw(h, w);
    let cols = cs.acc_width();
    let mut out = vec![0.0f32; oh * ow * cols];
    for oy in 0..oh {
        for ox in 0..ow {
            let row = (oy * ow + ox) * cols;
            let mut idx = 0;
            for ci in 0..c {
                for ky in 0..cs.k {
                    for kx in 0..cs.k {
                        let iy = (oy * cs.stride + ky) as isize - cs.pad as isize;
                        let ix = (ox * cs.stride + kx) as isize - cs.pad as isize;
                        if iy >= 0 && ix >= 0 && (iy as usize) < h && (ix as usize) < w {
                            out[row + idx] = x.at3(ci, iy as usize, ix as usize);
                        }
                        idx += 1;
                    }
                }
            }
        }
    }
    (out, oh, ow)
}

/// Integer im2col into a caller-owned buffer — the zero-allocation
/// entry point of the batched [`crate::nn::sc_engine::ScEngine`].
/// Unfolds a CHW plane of quantized codes into rows of length
/// `k·k·cin`, one row per output pixel; padding contributes zeros.
/// `out` must be exactly `oh·ow·acc_width` long; every element is
/// written (no stale data survives). Semantically identical to
/// [`im2col`] on integer-valued tensors.
///
/// The packing loop works a kernel row at a time: for each
/// `(pixel, ci, ky)` the `k` taps over `kx` are contiguous both in the
/// input row and in the output row, so the copy is `fill` for the
/// padded flanks plus one `copy_from_slice` for the valid span —
/// no per-element index arithmetic or bounds checks survive in the
/// inner loop.
pub fn im2col_i32_into(
    x: &[i32],
    (c, h, w): (usize, usize, usize),
    cs: &ConvShape,
    out: &mut [i32],
) -> (usize, usize) {
    im2col_i32_impl(x, (c, h, w), cs, out, None)
}

/// [`im2col_i32_into`] plus per-pixel nonzero counts: `nnz` is cleared
/// and receives one entry per output pixel (the GEMM column's nnz over
/// its `acc_width` entries). The counts fall out of the same fill pass
/// that already touches every element, so the sparse-GEMM crossover
/// heuristic in [`crate::nn::sc_engine::ScEngine`] gets its density
/// measurement for free. The unfolded `out` buffer is identical to
/// [`im2col_i32_into`]'s.
pub fn im2col_i32_nnz_into(
    x: &[i32],
    (c, h, w): (usize, usize, usize),
    cs: &ConvShape,
    out: &mut [i32],
    nnz: &mut Vec<u32>,
) -> (usize, usize) {
    im2col_i32_impl(x, (c, h, w), cs, out, Some(nnz))
}

/// Shared body of the two integer im2col entry points. Before the fill
/// loop one pass over the input marks every all-zero `(ci, iy)` input
/// row; those kernel rows short-circuit to a single `fill(0)` (no flank
/// arithmetic, no copy) — ReLU-sparse feature maps hit this constantly.
fn im2col_i32_impl(
    x: &[i32],
    (c, h, w): (usize, usize, usize),
    cs: &ConvShape,
    out: &mut [i32],
    mut nnz: Option<&mut Vec<u32>>,
) -> (usize, usize) {
    assert_eq!(c, cs.cin);
    assert_eq!(x.len(), c * h * w);
    let (oh, ow) = cs.out_hw(h, w);
    let cols = cs.acc_width();
    assert_eq!(out.len(), oh * ow * cols, "im2col_i32_into: buffer size mismatch");
    if let Some(n) = nnz.as_deref_mut() {
        n.clear();
        n.reserve(oh * ow);
    }
    let k = cs.k;
    // Per-(ci, iy) all-zero flags, one pass over the input.
    let mut row_zero = vec![false; c * h];
    if w > 0 {
        for (flag, irow) in row_zero.iter_mut().zip(x.chunks_exact(w)) {
            *flag = irow.iter().all(|&v| v == 0);
        }
    }
    let mut rows = out.chunks_exact_mut(cols.max(1));
    for oy in 0..oh {
        for ox in 0..ow {
            let row = rows.next().expect("output row per pixel");
            // Leftmost input column of this pixel's receptive field.
            let x0 = (ox * cs.stride) as isize - cs.pad as isize;
            // Valid kx span: 0 <= x0 + kx < w.
            let lo = (-x0).clamp(0, k as isize) as usize;
            let hi = (w as isize - x0).clamp(0, k as isize) as usize;
            let mut seg = row.chunks_exact_mut(k);
            let mut count = 0u32;
            for ci in 0..c {
                let plane = &x[ci * h * w..(ci + 1) * h * w];
                for ky in 0..k {
                    let dst = seg.next().expect("k-wide segment per (ci, ky)");
                    let iy = (oy * cs.stride + ky) as isize - cs.pad as isize;
                    if iy < 0 || iy >= h as isize || lo >= hi {
                        dst.fill(0);
                        continue;
                    }
                    let iy = iy as usize;
                    if row_zero[ci * h + iy] {
                        dst.fill(0);
                        continue;
                    }
                    dst[..lo].fill(0);
                    dst[hi..].fill(0);
                    let src_at = iy * w + (x0 + lo as isize) as usize;
                    let src = &plane[src_at..src_at + (hi - lo)];
                    dst[lo..hi].copy_from_slice(src);
                    if nnz.is_some() {
                        count += src.iter().filter(|&&v| v != 0).count() as u32;
                    }
                }
            }
            if let Some(n) = nnz.as_deref_mut() {
                n.push(count);
            }
        }
    }
    (oh, ow)
}

/// Float conv2d via im2col (the reference semantics both executors are
/// checked against). Weights are (O, I, K, K) row-major.
pub fn conv2d(x: &Tensor, w: &Tensor, cs: &ConvShape) -> Tensor {
    let (cols, oh, ow) = im2col(x, cs);
    let acc = cs.acc_width();
    assert_eq!(w.shape(), &[cs.cout, cs.cin, cs.k, cs.k]);
    let mut out = Tensor::zeros(&[cs.cout, oh, ow]);
    for co in 0..cs.cout {
        let wrow = &w.data()[co * acc..(co + 1) * acc];
        for p in 0..oh * ow {
            // Unrolled dot with sequential summation order (bit-exact
            // vs the scalar loop — this is the reference semantics).
            out.data_mut()[co * oh * ow + p] =
                gemm::dot_f32(&cols[p * acc..(p + 1) * acc], wrow);
        }
    }
    out
}

/// Integer conv2d on pre-quantized values: `x_q` (len = cin·h·w),
/// low-bit `w_q` (len = cout·acc). Returns per-pixel integer sums.
/// Routed through [`crate::nn::gemm`]: integer im2col (no float
/// round-trip) followed by the dense i8-panel GEMM; exact i64
/// accumulation, so the result is identical to the naive triple loop.
pub fn conv2d_int(
    x_q: &[i32],
    (cin, h, w): (usize, usize, usize),
    w_q: &[i8],
    cs: &ConvShape,
) -> (Vec<i64>, usize, usize) {
    assert_eq!(x_q.len(), cin * h * w);
    let (oh, ow) = cs.out_hw(h, w);
    let acc = cs.acc_width();
    let npix = oh * ow;
    let mut cols = vec![0i32; npix * acc];
    im2col_i32_into(x_q, (cin, h, w), cs, &mut cols);
    let mut out = vec![0i64; cs.cout * npix];
    I8Panel::pack(w_q, cs.cout, acc).gemm_into(&cols, npix, &mut out);
    (out, oh, ow)
}

/// 2×2 average pooling (stride 2) on CHW.
pub fn avgpool2(x: &Tensor) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let (oh, ow) = (h / 2, w / 2);
    let mut out = Tensor::zeros(&[c, oh, ow]);
    for ci in 0..c {
        for oy in 0..oh {
            for ox in 0..ow {
                let s = x.at3(ci, 2 * oy, 2 * ox)
                    + x.at3(ci, 2 * oy, 2 * ox + 1)
                    + x.at3(ci, 2 * oy + 1, 2 * ox)
                    + x.at3(ci, 2 * oy + 1, 2 * ox + 1);
                out.set3(ci, oy, ox, s / 4.0);
            }
        }
    }
    out
}

/// Global average pooling: CHW → C.
pub fn global_avgpool(x: &Tensor) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    let mut out = Tensor::zeros(&[c]);
    for ci in 0..c {
        let mut s = 0.0;
        for y in 0..h {
            for xx in 0..w {
                s += x.at3(ci, y, xx);
            }
        }
        out.data_mut()[ci] = s / (h * w) as f32;
    }
    out
}

/// Linear layer: `y = W x` with W of shape (O, I). One
/// [`gemm::dot_f32`] per output row (sequential summation order — the
/// reference semantics are unchanged).
pub fn linear(x: &Tensor, w: &Tensor) -> Tensor {
    let i = x.len();
    let o = w.shape()[0];
    assert_eq!(w.shape()[1], i);
    let mut out = Tensor::zeros(&[o]);
    for oo in 0..o {
        out.data_mut()[oo] = gemm::dot_f32(&w.data()[oo * i..(oo + 1) * i], x.data());
    }
    out
}

/// The paper's BN form (Eq 1): `BN(x) = γ(x - β)` per channel, fused
/// with ReLU downstream. Applies to CHW.
pub fn bn(x: &Tensor, gamma: &[f32], beta: &[f32]) -> Tensor {
    let (c, h, w) = (x.shape()[0], x.shape()[1], x.shape()[2]);
    assert_eq!(gamma.len(), c);
    assert_eq!(beta.len(), c);
    let mut out = x.clone();
    for ci in 0..c {
        for y in 0..h {
            for xx in 0..w {
                out.set3(ci, y, xx, gamma[ci] * (x.at3(ci, y, xx) - beta[ci]));
            }
        }
    }
    out
}

/// ReLU.
pub fn relu(x: &Tensor) -> Tensor {
    x.clone().map(|v| v.max(0.0))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_identity_kernel() {
        // 1x1 kernel with weight 1 reproduces the input.
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let w = Tensor::from_vec(&[1, 1, 1, 1], vec![1.0]);
        let cs = ConvShape { cin: 1, cout: 1, k: 1, stride: 1, pad: 0 };
        let y = conv2d(&x, &w, &cs);
        assert_eq!(y.data(), x.data());
    }

    #[test]
    fn conv_3x3_known_value() {
        // All-ones 3x3 input and kernel, no pad: sum = 9.
        let x = Tensor::from_vec(&[1, 3, 3], vec![1.0; 9]);
        let w = Tensor::from_vec(&[1, 1, 3, 3], vec![1.0; 9]);
        let cs = ConvShape { cin: 1, cout: 1, k: 3, stride: 1, pad: 0 };
        let y = conv2d(&x, &w, &cs);
        assert_eq!(y.shape(), &[1, 1, 1]);
        assert_eq!(y.data()[0], 9.0);
    }

    #[test]
    fn conv_padding_shapes() {
        let cs = ConvShape { cin: 3, cout: 8, k: 3, stride: 1, pad: 1 };
        assert_eq!(cs.out_hw(32, 32), (32, 32));
        assert_eq!(cs.acc_width(), 27);
        let cs2 = ConvShape { cin: 3, cout: 8, k: 3, stride: 2, pad: 1 };
        assert_eq!(cs2.out_hw(32, 32), (16, 16));
    }

    #[test]
    fn conv_int_matches_float_on_integers() {
        let cs = ConvShape { cin: 2, cout: 3, k: 3, stride: 1, pad: 1 };
        let xq: Vec<i32> = (0..2 * 5 * 5).map(|i| (i as i32 % 5) - 2).collect();
        let wq: Vec<i8> = (0..3 * 18).map(|i| ((i as i32 % 3) - 1) as i8).collect();
        let (yi, oh, ow) = conv2d_int(&xq, (2, 5, 5), &wq, &cs);
        let xf = Tensor::from_vec(&[2, 5, 5], xq.iter().map(|&v| v as f32).collect());
        let wf = Tensor::from_vec(&[3, 2, 3, 3], wq.iter().map(|&v| v as f32).collect());
        let yf = conv2d(&xf, &wf, &cs);
        assert_eq!((oh, ow), (5, 5));
        for (a, b) in yi.iter().zip(yf.data()) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn im2col_i32_into_matches_float_im2col() {
        let cs = ConvShape { cin: 2, cout: 1, k: 3, stride: 2, pad: 1 };
        let (c, h, w) = (2usize, 5usize, 4usize);
        let xq: Vec<i32> = (0..c * h * w).map(|i| (i as i32 % 7) - 3).collect();
        let xf = Tensor::from_vec(&[c, h, w], xq.iter().map(|&v| v as f32).collect());
        let (cols_f, oh, ow) = im2col(&xf, &cs);
        let mut cols_i = vec![99i32; oh * ow * cs.acc_width()];
        let (oh2, ow2) = im2col_i32_into(&xq, (c, h, w), &cs, &mut cols_i);
        assert_eq!((oh, ow), (oh2, ow2));
        for (a, b) in cols_i.iter().zip(&cols_f) {
            assert_eq!(*a as f32, *b);
        }
    }

    #[test]
    fn im2col_nnz_counts_match_buffer_and_zero_rows_short_circuit() {
        let cs = ConvShape { cin: 2, cout: 1, k: 3, stride: 1, pad: 1 };
        let (c, h, w) = (2usize, 5usize, 4usize);
        // Zero out whole input rows so the short-circuit path runs, and
        // sprinkle zeros inside live rows so counting is non-trivial.
        let xq: Vec<i32> = (0..c * h * w)
            .map(|i| {
                let (iy, v) = ((i / w) % h, (i as i32 % 5) - 2);
                if iy == 1 || iy == 3 {
                    0
                } else {
                    v
                }
            })
            .collect();
        let mut dense = vec![99i32; 5 * 4 * cs.acc_width()];
        let (oh, ow) = im2col_i32_into(&xq, (c, h, w), &cs, &mut dense);
        let mut counted = vec![77i32; dense.len()];
        let mut nnz = vec![123u32; 3];
        let (oh2, ow2) = im2col_i32_nnz_into(&xq, (c, h, w), &cs, &mut counted, &mut nnz);
        assert_eq!((oh, ow), (oh2, ow2));
        assert_eq!(dense, counted, "nnz variant must fill the same buffer");
        assert_eq!(nnz.len(), oh * ow, "one count per output pixel, stale entries cleared");
        let acc = cs.acc_width();
        for (p, &n) in nnz.iter().enumerate() {
            let expect =
                dense[p * acc..(p + 1) * acc].iter().filter(|&&v| v != 0).count() as u32;
            assert_eq!(n, expect, "pixel {p}");
        }
        // All-zero input: every count is zero and the buffer is zeroed.
        let zeros = vec![0i32; c * h * w];
        let mut buf = vec![55i32; dense.len()];
        im2col_i32_nnz_into(&zeros, (c, h, w), &cs, &mut buf, &mut nnz);
        assert!(buf.iter().all(|&v| v == 0));
        assert!(nnz.iter().all(|&n| n == 0));
    }

    #[test]
    fn pooling() {
        let x = Tensor::from_vec(&[1, 2, 2], vec![1.0, 3.0, 5.0, 7.0]);
        let y = avgpool2(&x);
        assert_eq!(y.data(), &[4.0]);
        let g = global_avgpool(&x);
        assert_eq!(g.data(), &[4.0]);
    }

    #[test]
    fn bn_eq1_form() {
        let x = Tensor::from_vec(&[1, 1, 2], vec![3.0, 5.0]);
        let y = bn(&x, &[2.0], &[1.0]);
        assert_eq!(y.data(), &[4.0, 8.0]);
    }

    #[test]
    fn linear_matvec() {
        let x = Tensor::from_vec(&[2], vec![1.0, 2.0]);
        let w = Tensor::from_vec(&[2, 2], vec![1.0, 0.0, 0.0, 1.0]);
        assert_eq!(linear(&x, &w).data(), &[1.0, 2.0]);
    }
}
