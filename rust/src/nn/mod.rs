//! Neural-network substrate: tensors, layers, quantization, model
//! configurations, and the two executors the paper compares —
//! the **bit-exact SC executor** (runs the quantized network through the
//! circuit simulators of [`crate::circuits`]) and the **binary integer
//! baseline** (a conventional fixed-point datapath) — plus the batched
//! **serving engine** ([`sc_engine::ScEngine`]): the same frozen network
//! as the SC executor, bit-identical logits, but with pre-sized scratch
//! arenas and synthesized count tables so the steady-state request path
//! allocates nothing. Every count-domain accumulation site routes
//! through the shared [`gemm`] core: weight panels packed once at
//! freeze time into zero-skipping ternary index lists (SC family) and
//! dense i8 microkernel panels (binary family), cache-blocked by
//! output-channel block (DESIGN.md §Perf "Ternary GEMM + threading").
//!
//! The quantization semantics here *must* match `python/compile/model.py`
//! exactly: the JAX side trains with fake-quant straight-through
//! estimators, and the Rust side re-quantizes the trained weights with
//! the same rules so that the SC simulation evaluates the very network
//! that was trained (verified end-to-end in `rust/tests/sc_pipeline.rs`).

pub mod binary_exec;
pub mod gemm;
pub mod layers;
pub mod model;
pub mod quant;
pub mod sc_engine;
pub mod sc_exec;
pub mod tensor;

pub use model::{LayerCfg, ModelCfg};
pub use quant::{Pruning, QuantConfig};
pub use sc_engine::{ScEngine, SparsityCounters};
pub use tensor::Tensor;
