//! Bit-exact SC executor: runs a quantized network through the paper's
//! circuit blocks.
//!
//! [`Prepared`] freezes a trained [`ModelParams`] into hardware form:
//! ternarized weights, per-channel selective interconnects (BN-ReLU
//! fused, Eq 1), residual alignment shifts (powers of two, §III.C) and
//! per-layer BSN widths. [`ScExecutor::forward`] then evaluates images
//! code-to-code:
//!
//! * activations are thermometer codes (counts) at each layer's trained
//!   scale — nothing is de-quantized between layers;
//! * products go through [`TernaryMultiplier`] semantics (proven equal
//!   to the 5-gate cell), accumulation through BSN popcount semantics
//!   (proven equal to the gate-level sorter), activation through SI tap
//!   semantics (proven equal to bit selection on the sorted stream);
//! * with a [`FaultCfg`], every circuit stage's output lanes take
//!   bitflip faults at rate `ber` — the Fig 5 experiment — applied as
//!   word-level masks to actual [`ThermCode`] bit vectors
//!   ([`crate::fault::inject`]). Masks are derived per
//!   `(image, layer, channel, pixel, stage)` site, so the packed
//!   count-domain [`super::sc_engine::ScEngine`] reproduces this
//!   stream-materializing path bit-for-bit (property-tested in
//!   `rust/tests/gemm.rs`); use [`ScExecutor::forward_with_tag`] to
//!   pin an image's fault identity.

use std::sync::Arc;

use crate::circuits::multiplier::TernaryMultiplier;
use crate::circuits::rescale::RescaleBlock;
use crate::circuits::si::{ActivationFn, SelectiveInterconnect};
use crate::coding::{BitVec, Ternary, ThermCode};
use crate::fault::inject::{self, Stage};
use crate::util::Rng;
use super::gemm::WeightPanels;
use super::layers::{im2col_i32_into, ConvShape};
use super::model::{LayerCfg, ModelCfg, ModelParams};
use super::quant::{Pruning, QuantConfig, TernaryTensor};
use super::tensor::Tensor;

/// Fault-injection configuration (Fig 5).
///
/// The seed anchors the per-site mask derivation of
/// [`crate::fault::inject`]: two executors (or the packed engine) with
/// the same `FaultCfg` and image tag draw identical faults at every
/// circuit stage, independent of evaluation order or threading.
#[derive(Clone, Copy, Debug)]
pub struct FaultCfg {
    /// Per-bit flip probability on every SC bitstream.
    pub ber: f64,
    /// RNG seed.
    pub seed: u64,
}

/// A conv layer frozen into hardware form.
#[derive(Clone, Debug)]
pub struct PreparedConv {
    /// Geometry.
    pub shape: ConvShape,
    /// Ternarized weights.
    pub wq: TernaryTensor,
    /// `wq` packed once into the GEMM panel formats: zero-skipping
    /// ternary index lists (SC family) and the dense i8 microkernel
    /// panel (binary family). Every accumulation site routes through
    /// these ([`crate::nn::gemm`]).
    pub panels: WeightPanels,
    /// Scale of the accumulated products (`alpha_in · alpha_w`).
    pub alpha_acc: f32,
    /// Output scale (trained).
    pub alpha_out: f32,
    /// Residual-tap output scale (when `res_out`).
    pub alpha_res_out: Option<f32>,
    /// Power-of-two shift aligning the incoming residual to
    /// `alpha_acc` (§III.C): `res count scale ×2^shift`.
    pub res_shift: i32,
    /// Per-channel SI for the main (low-BSL) output.
    pub si_main: Vec<SelectiveInterconnect>,
    /// Per-channel SI for the residual (BSL-16) tap.
    pub si_res: Option<Vec<SelectiveInterconnect>>,
    /// Total BSN input width in bits.
    pub bsn_width: usize,
    /// Width of the aligned residual stream out of the rescale block
    /// (0 when `!res_in`) — the `Rescale` fault-stage width.
    pub res_bits: usize,
    /// Whether this layer consumes a residual.
    pub res_in: bool,
}

/// The frozen network.
#[derive(Clone, Debug)]
pub struct Prepared {
    /// Source configuration.
    pub cfg: ModelCfg,
    /// Quantization variant.
    pub quant: QuantConfig,
    /// Input quantization scale.
    pub input_alpha: f32,
    /// Frozen conv layers (in network order).
    pub convs: Vec<PreparedConv>,
    /// Ternarized classifier.
    pub fc: TernaryTensor,
    /// Classifier weights packed into the GEMM panel formats.
    pub fc_panels: WeightPanels,
}

/// Residual BSL used by the high-precision tap.
pub const RES_BSL: usize = 16;

impl Prepared {
    /// Freeze a trained parameter set. `quant.act_bsl` must be set (the
    /// SC datapath is always quantized). `quant.pruning` is applied
    /// here, before panel packing, so pruned weights never enter the
    /// ternary index lists — the sparse weight structure costs nothing
    /// at inference (and the fault path, which walks `wq.values`
    /// directly, sees the identical pruned codes).
    pub fn new(cfg: &ModelCfg, params: &ModelParams, quant: QuantConfig) -> Self {
        let act_bsl = quant.act_bsl.expect("SC executor requires quantized activations");
        let res_bsl = quant.residual_bsl.unwrap_or(RES_BSL);
        let mut convs = Vec::new();
        let mut alpha_in = params.scalar("input.alpha").expect("input.alpha");
        let mut alpha_res_in: Option<f32> = None;
        let mut ci = 0usize;
        for l in &cfg.layers {
            match l {
                LayerCfg::Conv { shape, bn, relu, res_in, res_out } => {
                    let w = params.get(&format!("conv{ci}.w")).expect("conv weight");
                    let wq =
                        TernaryTensor::quantize_pruned(w, shape.acc_width(), quant.pruning);
                    let alpha_acc = alpha_in * wq.alpha;
                    let alpha_out =
                        params.scalar(&format!("conv{ci}.alpha_out")).expect("alpha_out");
                    let alpha_res_out = if *res_out {
                        Some(params.scalar(&format!("conv{ci}.alpha_res")).expect("alpha_res"))
                    } else {
                        None
                    };
                    // Residual alignment: the incoming residual code (at
                    // alpha_res_in) is scaled by 2^shift so that
                    // alpha_res_in / 2^shift ≈ alpha_acc; i.e. its count
                    // is replicated (shift>0) or divided (shift<0).
                    let res_shift = if *res_in {
                        let ar = alpha_res_in.expect("res_in layer without a residual tap");
                        (ar / alpha_acc).log2().round() as i32
                    } else {
                        0
                    };
                    let res_bits = if *res_in {
                        if res_shift >= 0 {
                            res_bsl << res_shift
                        } else {
                            res_bsl // divided in place, BSL constant (§III.C)
                        }
                    } else {
                        0
                    };
                    let bsn_width = shape.acc_width() * act_bsl + res_bits;
                    let (gamma, beta) = if *bn {
                        (
                            params.get(&format!("conv{ci}.gamma")).expect("gamma").data().to_vec(),
                            params.get(&format!("conv{ci}.beta")).expect("beta").data().to_vec(),
                        )
                    } else {
                        (vec![1.0; shape.cout], vec![0.0; shape.cout])
                    };
                    let mk_si = |alpha_tgt: f32, out_bsl: usize| -> Vec<SelectiveInterconnect> {
                        (0..shape.cout)
                            .map(|c| {
                                let act = if *relu {
                                    ActivationFn::BnRelu {
                                        gamma: gamma[c] as f64,
                                        beta: beta[c] as f64 / alpha_acc as f64,
                                        ratio: alpha_acc as f64 / alpha_tgt as f64,
                                    }
                                } else {
                                    ActivationFn::Relu { ratio: alpha_acc as f64 / alpha_tgt as f64 }
                                };
                                SelectiveInterconnect::for_activation(&act, bsn_width, out_bsl)
                            })
                            .collect()
                    };
                    let si_main = mk_si(alpha_out, act_bsl);
                    let si_res = alpha_res_out.map(|a| mk_si(a, res_bsl));
                    // Pack the weight panels once, here at freeze time:
                    // the serving hot loops never re-walk raw weights.
                    let panels = WeightPanels::pack(&wq.values, shape.cout, shape.acc_width());
                    convs.push(PreparedConv {
                        shape: *shape,
                        wq,
                        panels,
                        alpha_acc,
                        alpha_out,
                        alpha_res_out,
                        res_shift,
                        si_main,
                        si_res,
                        bsn_width,
                        res_bits,
                        res_in: *res_in,
                    });
                    alpha_in = alpha_out;
                    alpha_res_in = alpha_res_out.or(alpha_res_in);
                    ci += 1;
                }
                LayerCfg::Linear { .. } => {}
                LayerCfg::GlobalAvgPool => {}
            }
        }
        let fc_w = params.get("fc.w").expect("fc.w");
        let fc = TernaryTensor::quantize_pruned(fc_w, fc_w.shape()[1], quant.pruning);
        let fc_panels = WeightPanels::pack(&fc.values, fc.shape[0], fc.shape[1]);
        Self {
            cfg: cfg.clone(),
            quant,
            input_alpha: params.scalar("input.alpha").unwrap(),
            convs,
            fc,
            fc_panels,
        }
    }

    /// Activation BSL.
    pub fn act_bsl(&self) -> usize {
        self.quant.act_bsl.unwrap()
    }

    /// Residual BSL.
    pub fn res_bsl(&self) -> usize {
        self.quant.residual_bsl.unwrap_or(RES_BSL)
    }
}

/// Quantized activation map flowing between layers: integer codes plus
/// geometry.
#[derive(Clone, Debug)]
pub struct CodeMap {
    /// Quantized values `q ∈ [-bsl/2, bsl/2]`, CHW order.
    pub q: Vec<i32>,
    /// (C, H, W).
    pub dims: (usize, usize, usize),
    /// BSL of the codes.
    pub bsl: usize,
}

/// The SC executor.
///
/// Holds the frozen model behind an [`Arc`] so that any number of
/// executors (e.g. one per pool worker) share a single `Prepared`
/// instead of deep-cloning the weights and SI tables per worker.
pub struct ScExecutor {
    prep: Arc<Prepared>,
    fault: Option<FaultCfg>,
}

impl ScExecutor {
    /// New fault-free executor. Accepts either an owned [`Prepared`]
    /// (wrapped on the spot) or a shared `Arc<Prepared>` (no copy).
    pub fn new(prep: impl Into<Arc<Prepared>>) -> Self {
        Self { prep: prep.into(), fault: None }
    }

    /// With fault injection.
    pub fn with_faults(prep: impl Into<Arc<Prepared>>, fault: FaultCfg) -> Self {
        Self { prep: prep.into(), fault: Some(fault) }
    }

    /// The frozen network.
    pub fn prepared(&self) -> &Prepared {
        &self.prep
    }

    /// The shared handle to the frozen network.
    pub fn prepared_arc(&self) -> &Arc<Prepared> {
        &self.prep
    }

    /// Forward one CHW image; returns per-class integer scores.
    /// Under fault injection the image carries tag 0 — use
    /// [`Self::forward_with_tag`] to give each image of a batch or
    /// sweep its own fault identity.
    pub fn forward(&self, image: &Tensor) -> Vec<i64> {
        self.forward_with_tag(image, 0)
    }

    /// Forward one CHW image whose fault masks are derived from `tag`
    /// (canonically the image's index). Fault-free, the tag is inert.
    pub fn forward_with_tag(&self, image: &Tensor, tag: u64) -> Vec<i64> {
        let act_bsl = self.prep.act_bsl();
        // Input encoding.
        let half = (act_bsl / 2) as f32;
        let mut main = CodeMap {
            q: image
                .data()
                .iter()
                .map(|&v| (v / self.prep.input_alpha).round().clamp(-half, half) as i32)
                .collect(),
            dims: self.prep.cfg.input,
            bsl: act_bsl,
        };
        let mut res: Option<CodeMap> = None;
        // First residual tap comes from the input itself when the first
        // res_in layer appears before any res_out: our configs always
        // emit res_out first, so `res` starts empty.
        let mut li = 0usize;
        let mut gap: Option<Vec<i64>> = None;
        // Scratch reused across layers: the integer im2col buffer, the
        // GEMM count plane and (under fault injection) the bitstream
        // work codes, so neither path allocates per product or pixel.
        let mut cols: Vec<i32> = Vec::new();
        let mut acc: Vec<i64> = Vec::new();
        let mut scratch = FaultScratch::new();
        for l in &self.prep.cfg.layers {
            match l {
                LayerCfg::Conv { .. } => {
                    let pc = &self.prep.convs[li];
                    let (m, r) = self.conv_layer(
                        pc,
                        li,
                        tag,
                        &main,
                        res.as_ref(),
                        &mut cols,
                        &mut acc,
                        &mut scratch,
                    );
                    main = m;
                    if r.is_some() {
                        res = r;
                    }
                    li += 1;
                }
                LayerCfg::GlobalAvgPool => {
                    let (c, h, w) = main.dims;
                    let mut sums = vec![0i64; c];
                    for ci in 0..c {
                        for p in 0..h * w {
                            sums[ci] += main.q[ci * h * w + p] as i64;
                        }
                    }
                    gap = Some(sums);
                }
                LayerCfg::Linear { in_dim, out_dim } => {
                    let x = gap.clone().unwrap_or_else(|| {
                        main.q.iter().map(|&v| v as i64).collect()
                    });
                    assert_eq!(x.len(), *in_dim);
                    // Classifier through the packed ternary panel:
                    // zero weights skipped, adds/subs only.
                    let fc = &self.prep.fc_panels.ternary;
                    let logits: Vec<i64> =
                        (0..*out_dim).map(|o| fc.row_dot_i64(o, &x)).collect();
                    return logits;
                }
            }
        }
        panic!("model has no classifier layer");
    }

    /// Classify a batch; returns predicted classes. Each image is
    /// tagged with its index, so faults are per-image reproducible
    /// regardless of how the batch is split or ordered.
    pub fn predict(&self, images: &[Tensor]) -> Vec<usize> {
        images
            .iter()
            .enumerate()
            .map(|(i, im)| {
                let l = self.forward_with_tag(im, i as u64);
                l.iter()
                    .enumerate()
                    .max_by_key(|(_, &v)| v)
                    .map(|(i, _)| i)
                    .unwrap()
            })
            .collect()
    }

    /// Accuracy over a labelled set.
    pub fn accuracy(&self, images: &[Tensor], labels: &[usize]) -> f64 {
        let preds = self.predict(images);
        let hits = preds.iter().zip(labels).filter(|(p, l)| p == l).count();
        hits as f64 / labels.len().max(1) as f64
    }

    /// One conv layer in the count domain (or bit domain under faults).
    ///
    /// The fault path is the **gate-level reference** of the fault
    /// model: it materializes each stage's real bit stream, XORs the
    /// site-derived mask in, and counts — the packed engine's
    /// count-domain algebra is property-tested equal to this, end to
    /// end.
    #[allow(clippy::too_many_arguments)]
    fn conv_layer(
        &self,
        pc: &PreparedConv,
        li: usize,
        tag: u64,
        main: &CodeMap,
        res: Option<&CodeMap>,
        cols: &mut Vec<i32>,
        acc: &mut Vec<i64>,
        scratch: &mut FaultScratch,
    ) -> (CodeMap, Option<CodeMap>) {
        let act_bsl = main.bsl;
        let (cin, h, w) = main.dims;
        assert_eq!(cin, pc.shape.cin);
        // Integer im2col straight over the quantized codes, into the
        // caller's reusable buffer.
        let acc_w = pc.shape.acc_width();
        let (oh, ow) = pc.shape.out_hw(h, w);
        let npix = oh * ow;
        cols.clear();
        cols.resize(npix * acc_w, 0);
        im2col_i32_into(&main.q, (cin, h, w), &pc.shape, cols);
        let half = (act_bsl / 2) as i64;
        let base = acc_w as i64 * half;

        // Fault-free accumulation is one cache-blocked ternary GEMM
        // over the panels packed at freeze time: count(a·w) = a·w + L/2
        // per product (TernaryMultiplier semantics, proven equal to the
        // code path in unit tests), so the layer's counts are the GEMM
        // dot plus the constant offset `acc_w · L/2`.
        let fault = self.fault;
        if fault.is_none() {
            // Grow-only scratch, never cleared: gemm_into overwrites
            // every element it hands out, so stale counts from another
            // layer never survive into a read.
            if acc.len() < pc.shape.cout * npix {
                acc.resize(pc.shape.cout * npix, 0);
            }
            pc.panels.ternary.gemm_into(cols, npix, &mut acc[..pc.shape.cout * npix]);
        }

        let mut out_main = vec![0i32; pc.shape.cout * npix];
        let mut out_res = pc
            .si_res
            .as_ref()
            .map(|_| vec![0i32; pc.shape.cout * npix]);

        for co in 0..pc.shape.cout {
            let wrow = &pc.wq.values[co * acc_w..(co + 1) * acc_w];
            for p in 0..npix {
                // Product counts through the ternary multiplier.
                let mut count: i64 = if let Some(fc) = fault {
                    // Mult stage: one mask over the acc_w·L concatenated
                    // product streams; each product's slice lands at bit
                    // g − i·L. Streams run through the reusable scratch
                    // codes (no per-product allocation).
                    let xr = &cols[p * acc_w..(p + 1) * acc_w];
                    let mut r = inject::site_rng(fc.seed, tag, li, co, p, Stage::Mult);
                    inject::fill_mask(&mut r, fc.ber, acc_w * act_bsl, &mut scratch.mask);
                    let mut c = 0i64;
                    for i in 0..acc_w {
                        ThermCode::encode_into(xr[i] as i64, act_bsl, &mut scratch.enc);
                        TernaryMultiplier::mult_bits_into(
                            scratch.enc.bits(),
                            Ternary::from_i64(wrow[i] as i64),
                            scratch.prod.bits_mut(),
                        );
                        inject::apply_mask_range(
                            &scratch.mask,
                            i * act_bsl,
                            (i + 1) * act_bsl,
                            scratch.prod.bits_mut(),
                        );
                        c += scratch.prod.count() as i64;
                    }
                    c
                } else {
                    base + acc[co * npix + p]
                };
                // Residual contribution (§III.C alignment).
                if pc.res_in {
                    let rm = res.expect("residual map required");
                    let rhalf = (rm.bsl / 2) as i64;
                    let rq = rm.q[co_res_index(rm, co, p, oh, ow)] as i64;
                    let rcount = (rq + rhalf) as usize;
                    let mut aligned = align_res_count(rcount, rm.bsl, pc.res_shift);
                    if let Some(fc) = fault {
                        // Rescale stage: faults on the aligned residual
                        // stream (canonical prefix of `aligned` ones over
                        // `res_bits` lanes).
                        let mut r = inject::site_rng(fc.seed, tag, li, co, p, Stage::Rescale);
                        inject::fill_mask(&mut r, fc.ber, pc.res_bits, &mut scratch.mask);
                        ThermCode::from_count_into(aligned, pc.res_bits, &mut scratch.sorted);
                        inject::apply_mask(&scratch.mask, scratch.sorted.bits_mut());
                        aligned = scratch.sorted.count();
                    }
                    count += aligned as i64;
                }
                let c_bsn = (count.max(0) as usize).min(pc.bsn_width);
                // SI taps over the BSN's sorted stream.
                let (cmain, cres) = if let Some(fc) = fault {
                    // Bsn stage: ONE corrupted sorted stream feeds both
                    // SIs (they tap the same physical lanes).
                    let mut r = inject::site_rng(fc.seed, tag, li, co, p, Stage::Bsn);
                    inject::fill_mask(&mut r, fc.ber, pc.bsn_width, &mut scratch.mask);
                    ThermCode::from_count_into(c_bsn, pc.bsn_width, &mut scratch.sorted);
                    inject::apply_mask(&scratch.mask, scratch.sorted.bits_mut());
                    let cmain = apply_si_faulty(
                        &pc.si_main[co],
                        &scratch.sorted,
                        fc,
                        inject::site_rng(fc.seed, tag, li, co, p, Stage::SiMain),
                        &mut scratch.mask2,
                        &mut scratch.out_bits,
                    );
                    let cres = pc.si_res.as_ref().map(|sis| {
                        apply_si_faulty(
                            &sis[co],
                            &scratch.sorted,
                            fc,
                            inject::site_rng(fc.seed, tag, li, co, p, Stage::SiRes),
                            &mut scratch.mask2,
                            &mut scratch.out_bits,
                        )
                    });
                    (cmain, cres)
                } else {
                    (
                        pc.si_main[co].apply_count(c_bsn),
                        pc.si_res.as_ref().map(|sis| sis[co].apply_count(c_bsn)),
                    )
                };
                out_main[co * npix + p] =
                    cmain as i32 - (pc.si_main[co].out_bsl() / 2) as i32;
                if let Some(cres) = cres {
                    let sis = pc.si_res.as_ref().expect("cres implies si_res");
                    out_res.as_mut().unwrap()[co * npix + p] =
                        cres as i32 - (sis[co].out_bsl() / 2) as i32;
                }
            }
        }
        let main_map = CodeMap { q: out_main, dims: (pc.shape.cout, oh, ow), bsl: act_bsl };
        let res_map = out_res.map(|q| CodeMap {
            q,
            dims: (pc.shape.cout, oh, ow),
            bsl: self.prep.res_bsl(),
        });
        (main_map, res_map)
    }
}

/// Residual maps are spatially aligned with the conv output (residual
/// layers are stride-1, cin == cout).
fn co_res_index(rm: &CodeMap, co: usize, p: usize, oh: usize, ow: usize) -> usize {
    let (_, h, w) = rm.dims;
    debug_assert_eq!((h, w), (oh, ow), "residual must match conv output size");
    co * h * w + p
}

/// Align a residual count by a power-of-two shift, with the exact
/// semantics of the re-scaling block: replication for `shift > 0`,
/// `⌈c/2⌉ + pad` selection cycles for `shift < 0`.
pub fn align_res_count(count: usize, bsl: usize, shift: i32) -> usize {
    if shift >= 0 {
        count << shift
    } else {
        let block = RescaleBlock::new(bsl.max(16).min(16));
        let mut code = ThermCode::from_count(count.min(bsl), bsl);
        code = block.div_pow2(&code, (-shift) as u32);
        code.count()
    }
}

/// Flip each bit of a code with probability `ber` — the dense
/// Bernoulli sampler kept for targeted robustness tests; the
/// executors' fault path draws sparse masks via
/// [`crate::fault::inject::fill_mask`] instead.
pub fn flip_bits(code: &mut ThermCode, ber: f64, rng: &mut Rng) {
    if ber <= 0.0 {
        return;
    }
    let l = code.bsl();
    let bits = code.bits_mut();
    for i in 0..l {
        if rng.gen_bool(ber) {
            bits.flip(i);
        }
    }
}

/// Reusable bitstream work area for the fault-injection path: the
/// encoded activation, the multiplier product, the reconstructed
/// sorted (or aligned-residual) stream, the SI tap-output lanes, and
/// two mask index buffers. All reset in place each use — the faulted
/// forward allocates nothing per product or pixel.
struct FaultScratch {
    enc: ThermCode,
    prod: ThermCode,
    sorted: ThermCode,
    out_bits: BitVec,
    mask: Vec<u32>,
    mask2: Vec<u32>,
}

impl FaultScratch {
    fn new() -> Self {
        Self {
            enc: ThermCode::from_count(0, 2),
            prod: ThermCode::from_count(0, 2),
            sorted: ThermCode::from_count(0, 2),
            out_bits: BitVec::zeros(0),
            mask: Vec::new(),
            mask2: Vec::new(),
        }
    }
}

/// SI application on the (already corrupted) sorted stream, with
/// output-lane faults: materialize the tap outputs, XOR the SI-stage
/// mask in, and count.
fn apply_si_faulty(
    si: &SelectiveInterconnect,
    sorted: &ThermCode,
    fc: FaultCfg,
    mut rng: Rng,
    mask: &mut Vec<u32>,
    out_bits: &mut BitVec,
) -> usize {
    inject::fill_mask(&mut rng, fc.ber, si.out_bsl(), mask);
    si.apply_bits_into(sorted.bits(), out_bits);
    inject::apply_mask(mask, out_bits);
    out_bits.popcount()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::nn::model::{ModelCfg, ModelParams};

    fn tiny_prep(act_bsl: usize) -> Prepared {
        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(3);
        let params = ModelParams::init(&cfg, &mut rng);
        Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(act_bsl),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        )
    }

    #[test]
    fn pruned_freeze_drops_weights_and_still_classifies() {
        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(3);
        let params = ModelParams::init(&cfg, &mut rng);
        let dense = tiny_prep(2);
        let quant = QuantConfig { pruning: Pruning::Nm { n: 1, m: 4 }, ..dense.quant };
        let pruned = Prepared::new(&cfg, &params, quant);
        let nnz = |p: &Prepared| {
            p.convs
                .iter()
                .flat_map(|c| c.wq.values.iter())
                .chain(p.fc.values.iter())
                .filter(|&&v| v != 0)
                .count()
        };
        assert!(nnz(&pruned) < nnz(&dense), "1:4 pruning must drop weights");
        // Panels are packed from the pruned codes, so the zero-skipping
        // index lists shrink too.
        let lists = |p: &Prepared| {
            p.convs.iter().map(|c| c.panels.ternary.nnz()).sum::<usize>()
        };
        assert!(lists(&pruned) < lists(&dense));
        let exec = ScExecutor::new(pruned);
        let img = Tensor::from_vec(
            &[1, 28, 28],
            (0..784).map(|_| rng.normal() as f32).collect(),
        );
        assert_eq!(exec.forward(&img).len(), 10);
    }

    #[test]
    fn forward_shapes_and_determinism() {
        let prep = tiny_prep(2);
        let exec = ScExecutor::new(prep);
        let mut rng = Rng::new(7);
        let img = Tensor::from_vec(
            &[1, 28, 28],
            (0..784).map(|_| rng.normal() as f32).collect(),
        );
        let a = exec.forward(&img);
        let b = exec.forward(&img);
        assert_eq!(a.len(), 10);
        assert_eq!(a, b, "fault-free forward must be deterministic");
    }

    #[test]
    fn executors_share_one_prepared() {
        let prep = std::sync::Arc::new(tiny_prep(2));
        let a = ScExecutor::new(prep.clone());
        let b = ScExecutor::new(prep.clone());
        assert!(std::sync::Arc::ptr_eq(a.prepared_arc(), b.prepared_arc()));
        assert!(std::sync::Arc::ptr_eq(a.prepared_arc(), &prep));
    }

    #[test]
    fn residual_network_runs() {
        let cfg = ModelCfg::scnet(10);
        let mut rng = Rng::new(5);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Prepared::new(&cfg, &params, QuantConfig::w2a2r16());
        let exec = ScExecutor::new(prep);
        let img = Tensor::from_vec(
            &[3, 32, 32],
            (0..3 * 32 * 32).map(|_| rng.normal() as f32 * 0.5).collect(),
        );
        let logits = exec.forward(&img);
        assert_eq!(logits.len(), 10);
    }

    #[test]
    fn faults_perturb_but_zero_ber_matches_clean() {
        // One frozen model shared by all three executors (no deep clones).
        let prep = std::sync::Arc::new(tiny_prep(2));
        let clean = ScExecutor::new(prep.clone());
        let faulty0 = ScExecutor::with_faults(prep.clone(), FaultCfg { ber: 0.0, seed: 1 });
        let mut rng = Rng::new(11);
        let img = Tensor::from_vec(
            &[1, 28, 28],
            (0..784).map(|_| rng.normal() as f32).collect(),
        );
        assert_eq!(clean.forward(&img), faulty0.forward(&img));
        // High BER produces different logits (overwhelmingly likely).
        let faulty = ScExecutor::with_faults(prep, FaultCfg { ber: 0.2, seed: 1 });
        assert_ne!(clean.forward(&img), faulty.forward(&img));
    }

    #[test]
    fn align_res_count_shift_semantics() {
        assert_eq!(align_res_count(5, 16, 0), 5);
        assert_eq!(align_res_count(5, 16, 2), 20);
        // One divide cycle: ceil(12/2) + 4 (pad '11110000') = 10.
        assert_eq!(align_res_count(12, 16, -1), 10);
    }

    #[test]
    fn accuracy_on_labels() {
        let prep = tiny_prep(2);
        let exec = ScExecutor::new(prep);
        let mut rng = Rng::new(13);
        let imgs: Vec<Tensor> = (0..4)
            .map(|_| {
                Tensor::from_vec(&[1, 28, 28], (0..784).map(|_| rng.normal() as f32).collect())
            })
            .collect();
        let preds = exec.predict(&imgs);
        let acc = exec.accuracy(&imgs, &preds);
        assert_eq!(acc, 1.0);
    }
}
