//! The bitonic sorting network (BSN) non-linear adder (paper §II.B,
//! Fig 3b).
//!
//! All product bitstreams are concatenated and sorted descending by a
//! Batcher bitonic network [13]; because thermometer decode depends only
//! on the popcount, the sorted output *is* the exact accumulation result
//! in thermometer coding — and feeding it to the selective interconnect
//! realizes the activation function exactly.
//!
//! Each comparator is one AND + one OR (`max = a ∨ b`, `min = a ∧ b`),
//! so for `n = 2^k` inputs the network has exactly `n·k(k+1)/4`
//! comparators in `k(k+1)/2` stages — the super-linear growth that
//! motivates §IV (Fig 9).
//!
//! Three views of the same circuit:
//! * [`Bsn::sort_gate_level`] — compare-exchange simulation, bit-exact,
//!   supports per-wire fault injection;
//! * [`Bsn::accumulate`] — functional popcount model (property-tested
//!   equal to the gate-level view);
//! * [`Bsn::gate_count`] — exact combinatorics for the cost model.

use crate::coding::{BitVec, ThermCode};
use crate::cost::{cost_of, Cost};
use crate::gates::{GateCount, GateKind};
use crate::util::Rng;

/// A bitonic sorting network over `width` bit-inputs (padded internally
/// to the next power of two with 0s, which sort to the tail and leave
/// the thermometer semantics untouched).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bsn {
    /// Requested input width in bits.
    width: usize,
    /// Padded power-of-two width.
    padded: usize,
}

impl Bsn {
    /// Build a BSN for `width` input bits.
    pub fn new(width: usize) -> Self {
        assert!(width >= 1, "BSN width must be >= 1");
        Self { width, padded: width.next_power_of_two() }
    }

    /// Requested width.
    pub fn width(&self) -> usize {
        self.width
    }

    /// Internal power-of-two width.
    pub fn padded_width(&self) -> usize {
        self.padded
    }

    /// Number of comparators after constant-pruning synthesis: the
    /// padded network has `n·k(k+1)/4` comparators for `n = 2^k`, but a
    /// comparator whose lanes are fed (directly or transitively) by
    /// padding constants reduces to wires. We model pruning by counting
    /// only compare-exchanges whose both lanes lie in the live region —
    /// the standard const-propagation estimate, exact for powers of two.
    pub fn comparator_count(&self) -> u64 {
        let n = self.padded;
        let w = self.width;
        if n == w {
            let k = (n as u64).trailing_zeros() as u64;
            return n as u64 * k * (k + 1) / 4;
        }
        // Closed form per stage parameter j (a power of two): the live
        // pairs are (i, i + j) with bit j of i clear and i + j < w, so
        // their number is #{i < w - j : bit_j(i) = 0}
        //             = floor((w-j) / 2j)·j + min((w-j) mod 2j, j).
        let live_pairs = |j: usize| -> u64 {
            if w <= j {
                return 0;
            }
            let x = (w - j) as u64;
            let j = j as u64;
            (x / (2 * j)) * j + (x % (2 * j)).min(j)
        };
        let mut count = 0u64;
        let mut k = 2usize;
        while k <= n {
            let mut j = k / 2;
            while j >= 1 {
                count += live_pairs(j);
                j /= 2;
            }
            k *= 2;
        }
        count
    }

    /// Comparator stages on the critical path: `k(k+1)/2`.
    pub fn depth_stages(&self) -> u64 {
        let k = (self.padded as u64).trailing_zeros() as u64;
        k * (k + 1) / 2
    }

    /// Exact gate composition: one AND + one OR per comparator.
    pub fn gate_count(&self) -> GateCount {
        let c = self.comparator_count();
        let mut g = GateCount::new();
        g.add(GateKind::And2, c);
        g.add(GateKind::Or2, c);
        g.depth = self.depth_stages() as f64;
        g
    }

    /// Physical cost.
    pub fn cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }

    /// Gate-level descending sort (1s first). Bit-exact simulation of
    /// the compare-exchange network via the packed word-parallel fast
    /// path; the returned vector has the *requested* width (padding
    /// stripped).
    pub fn sort_gate_level(&self, bits: &BitVec) -> BitVec {
        self.sort_packed(bits)
    }

    /// Buffer-reuse variant of [`Bsn::sort_gate_level`] (fault-free
    /// fast path only): sorts into `out`, using `scratch` as the
    /// word-parallel work area. Both buffers are overwritten and reuse
    /// their allocations, so a steady-state serving loop sorts without
    /// touching the heap.
    pub fn sort_gate_level_into(&self, bits: &BitVec, scratch: &mut Vec<u64>, out: &mut BitVec) {
        self.sort_packed_into(bits, scratch, out);
    }

    /// Gate-level sort with per-comparator-output fault injection: each
    /// of the two output wires of every comparator flips with
    /// probability `ber`. Used by the Fig-5 fault-tolerance experiment.
    pub fn sort_with_faults(&self, bits: &BitVec, ber: f64, rng: &mut Rng) -> BitVec {
        let mut scratch = Vec::new();
        let mut out = BitVec::zeros(0);
        self.sort_with_faults_into(bits, ber, rng, &mut scratch, &mut out);
        out
    }

    /// Buffer-reuse variant of [`Bsn::sort_with_faults`]: `scratch` is
    /// the scalar lane buffer and `out` the result, both overwritten in
    /// place so a BER sweep re-sorting thousands of streams stops
    /// thrashing the allocator.
    pub fn sort_with_faults_into(
        &self,
        bits: &BitVec,
        ber: f64,
        rng: &mut Rng,
        scratch: &mut Vec<bool>,
        out: &mut BitVec,
    ) {
        let mut flip = || rng.gen_bool(ber);
        self.sort_scalar_into(bits, &mut flip, scratch, out);
    }

    /// Scalar (lane-per-bool) compare-exchange network with a fault
    /// closure sampled once per comparator output wire, in network
    /// order. The packed fast path is property-tested equal to this
    /// with a never-firing closure.
    fn sort_scalar_into<F: FnMut() -> bool>(
        &self,
        bits: &BitVec,
        fault: &mut F,
        v: &mut Vec<bool>,
        out: &mut BitVec,
    ) {
        assert_eq!(bits.len(), self.width, "BSN input width mismatch");
        let n = self.padded;
        v.clear();
        v.resize(n, false);
        for (dst, b) in v.iter_mut().zip(bits.iter()) {
            *dst = b;
        }

        // Batcher's bitonic sort, descending (ones first).
        let mut k = 2usize;
        while k <= n {
            let mut j = k / 2;
            while j >= 1 {
                for i in 0..n {
                    let l = i ^ j;
                    if l > i {
                        let descending = i & k == 0;
                        let (a, b) = (v[i], v[l]);
                        // Comparator: OR on the "greater" lane, AND on
                        // the "lesser" lane.
                        let (mut hi, mut lo) = (a || b, a && b);
                        if fault() {
                            hi = !hi;
                        }
                        if fault() {
                            lo = !lo;
                        }
                        if descending {
                            v[i] = hi;
                            v[l] = lo;
                        } else {
                            v[i] = lo;
                            v[l] = hi;
                        }
                    }
                }
                j /= 2;
            }
            k *= 2;
        }
        out.reset(self.width);
        for i in 0..self.width {
            if v[i] {
                out.set(i, true);
            }
        }
    }

    /// Bit-sliced (64-way word-parallel) bitonic sort — the fault-free
    /// fast path of [`Bsn::sort_gate_level`]. Compare-exchange of a
    /// whole word of independent pairs is two bitwise ops (`a|b`,
    /// `a&b`), so the network runs at ~64 comparators per instruction.
    /// Property-tested equal to the scalar compare-exchange network.
    fn sort_packed(&self, bits: &BitVec) -> BitVec {
        let mut scratch = Vec::new();
        let mut out = BitVec::zeros(0);
        self.sort_packed_into(bits, &mut scratch, &mut out);
        out
    }

    /// Packed sort into caller-owned buffers (see
    /// [`Bsn::sort_gate_level_into`]). Since [`BitVec`] stores packed
    /// `u64` words natively, entry and exit are word memcpys — no
    /// per-bit transpose on either side of the network.
    fn sort_packed_into(&self, bits: &BitVec, v: &mut Vec<u64>, out: &mut BitVec) {
        assert_eq!(bits.len(), self.width, "BSN input width mismatch");
        let n = self.padded;
        let words = n.div_ceil(64);
        v.clear();
        v.resize(words, 0u64);
        let src = bits.as_words();
        v[..src.len()].copy_from_slice(src);
        let mut k = 2usize;
        while k <= n {
            let mut j = k / 2;
            while j >= 1 {
                if j >= 64 {
                    // Word-aligned pairs: word wi pairs with word
                    // wi + j/64; direction constant per word (k > 64).
                    let jw = j / 64;
                    for wi in 0..words {
                        let li = wi ^ jw;
                        if li > wi {
                            let (a, b) = (v[wi], v[li]);
                            let (hi, lo) = (a | b, a & b);
                            // descending iff (bit index & k) == 0; for
                            // word-aligned blocks this is per-word.
                            if (wi * 64) & k == 0 {
                                v[wi] = hi;
                                v[li] = lo;
                            } else {
                                v[wi] = lo;
                                v[li] = hi;
                            }
                        }
                    }
                } else {
                    // In-word pairs at stride j: mask of "low" lanes
                    // (bit j of the in-word index clear), replicated.
                    let m = Self::low_lane_mask(j);
                    // Direction mask: 1 where the pair is descending
                    // (index & k == 0). For k >= 64 it's constant per
                    // word; below, a repeating 2k pattern.
                    for (wi, w) in v.iter_mut().enumerate() {
                        let a = *w & m;
                        let b = (*w >> j) & m;
                        let or_ = a | b;
                        let and_ = a & b;
                        let desc = (or_ & m) | ((and_ & m) << j);
                        let asc = (and_ & m) | ((or_ & m) << j);
                        let dmask = Self::desc_mask(wi, k);
                        *w = (desc & dmask) | (asc & !dmask);
                    }
                }
                j /= 2;
            }
            k *= 2;
        }
        out.load_words(v, self.width);
    }

    /// Mask selecting in-word lanes whose bit `j` of the index is 0
    /// (the "low" element of each stride-`j` pair), for `j < 64`.
    fn low_lane_mask(j: usize) -> u64 {
        // Repeating pattern: j ones, j zeros.
        let mut m = 0u64;
        let mut i = 0;
        while i < 64 {
            if (i / j) % 2 == 0 {
                m |= 1 << i;
            }
            i += 1;
        }
        m
    }

    /// Mask of bit positions in word `wi` whose global index `i`
    /// satisfies `i & k == 0` (descending blocks), for any `k`.
    fn desc_mask(wi: usize, k: usize) -> u64 {
        if k >= 64 {
            return if (wi * 64) & k == 0 { u64::MAX } else { 0 };
        }
        let mut m = 0u64;
        for i in 0..64 {
            if (wi * 64 + i) & k == 0 {
                m |= 1 << i;
            }
        }
        m
    }

    /// Functional accumulation: concatenate the product codes, "sort"
    /// (popcount), and return the thermometer sum over the full width.
    /// Exactly equals the gate-level path (see property tests).
    pub fn accumulate(&self, products: &[ThermCode]) -> ThermCode {
        let total: usize = products.iter().map(|p| p.count()).sum();
        let w: usize = products.iter().map(|p| p.bsl()).sum();
        assert_eq!(w, self.width, "BSN width mismatch: got {w} bits, expected {}", self.width);
        ThermCode::from_count(total, self.width)
    }

    /// Gate composition of a **bitonic merge tree** combining `blocks`
    /// already-sorted blocks of `block_bsl` bits each. Stage `i` merges
    /// pairs of sorted sequences of `block_bsl·2^i` bits with a bitonic
    /// merger (depth `log2(n)`, `n·log2(n)/2` comparators) — far
    /// cheaper than a full sort, and exactly what the inner stages of
    /// the progressive (approximate) BSN need, since sub-sampled
    /// outputs of sorted groups are themselves sorted.
    pub fn merge_tree_gate_count(blocks: usize, block_bsl: usize) -> GateCount {
        let mut g = GateCount::new();
        if blocks <= 1 {
            return g;
        }
        let levels = (blocks as f64).log2().ceil() as u32;
        let mut remaining = blocks as u64;
        let mut size = block_bsl as u64;
        for _ in 0..levels {
            let pairs = remaining / 2;
            let merged = 2 * size;
            let n = merged.next_power_of_two();
            let k = n.trailing_zeros() as u64;
            // One bitonic merger: n/2 comparators per stage, k stages.
            let comps = pairs * n / 2 * k;
            g.add(GateKind::And2, comps);
            g.add(GateKind::Or2, comps);
            g.depth += k as f64;
            remaining = remaining.div_ceil(2);
            size = merged;
        }
        g
    }

    /// Convenience: concatenate product bit-streams for the gate-level
    /// path.
    pub fn concat(products: &[ThermCode]) -> BitVec {
        let mut out = BitVec::zeros(0);
        Self::concat_into(products, &mut out);
        out
    }

    /// Buffer-reuse variant of [`Bsn::concat`]: overwrites `out`,
    /// reusing its allocation.
    pub fn concat_into(products: &[ThermCode], out: &mut BitVec) {
        out.reset(0);
        for p in products {
            out.extend_from(p.bits());
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coding::Ternary;

    #[test]
    fn sorts_descending_small() {
        let bsn = Bsn::new(8);
        let out = bsn.sort_gate_level(&BitVec::from_str01("01010110"));
        assert_eq!(out.to_str01(), "11110000");
    }

    #[test]
    fn sort_preserves_popcount_and_is_thermometer() {
        let mut rng = Rng::new(7);
        for width in [1usize, 2, 3, 5, 8, 13, 16, 31, 64, 100] {
            let bsn = Bsn::new(width);
            for _ in 0..20 {
                let mut b = BitVec::zeros(width);
                for i in 0..width {
                    b.set(i, rng.gen_bool(0.5));
                }
                let sorted = bsn.sort_gate_level(&b);
                assert_eq!(sorted.len(), width);
                assert_eq!(sorted.popcount(), b.popcount());
                assert!(sorted.is_thermometer(), "{} -> {}", b, sorted);
            }
        }
    }

    #[test]
    fn gate_level_equals_functional_accumulate() {
        let mut rng = Rng::new(21);
        for n_products in [1usize, 4, 9, 16] {
            for bsl in [2usize, 4, 8] {
                let products: Vec<ThermCode> = (0..n_products)
                    .map(|_| {
                        let (lo, hi) = ThermCode::range(bsl);
                        ThermCode::encode(rng.gen_range_i64(lo, hi), bsl)
                    })
                    .collect();
                let bsn = Bsn::new(n_products * bsl);
                let functional = bsn.accumulate(&products);
                let gate = bsn.sort_gate_level(&Bsn::concat(&products));
                assert_eq!(gate.popcount(), functional.count());
                // Accumulated value equals the integer sum of products.
                let sum: i64 = products.iter().map(|p| p.decode()).sum();
                assert_eq!(functional.decode(), sum);
            }
        }
    }

    #[test]
    fn accumulate_ternary_products_exact() {
        // 2-bit products a*w summed by the BSN must equal the integer
        // dot product — the end-to-end §II claim at micro scale.
        let acts = [1i64, -1, 0, 1, -1, 0, 1, 1];
        let ws = [Ternary::Pos, Ternary::Pos, Ternary::Neg, Ternary::Neg,
                  Ternary::Zero, Ternary::Pos, Ternary::Pos, Ternary::Neg];
        let products: Vec<ThermCode> = acts
            .iter()
            .zip(ws)
            .map(|(&a, w)| {
                crate::circuits::multiplier::TernaryMultiplier::mult_therm(
                    &ThermCode::encode(a, 2),
                    w,
                )
            })
            .collect();
        let bsn = Bsn::new(16);
        let acc = bsn.accumulate(&products);
        let expect: i64 = acts.iter().zip(ws).map(|(&a, w)| a * w.to_i64()).sum();
        assert_eq!(acc.decode(), expect);
    }

    #[test]
    fn comparator_combinatorics() {
        // n = 2^k -> n k(k+1)/4 comparators, k(k+1)/2 stages.
        let bsn = Bsn::new(16); // k = 4
        assert_eq!(bsn.comparator_count(), 16 * 4 * 5 / 4);
        assert_eq!(bsn.depth_stages(), 10);
        let bsn2 = Bsn::new(1024); // k = 10
        assert_eq!(bsn2.comparator_count(), 1024 * 10 * 11 / 4);
        assert_eq!(bsn2.depth_stages(), 55);
    }

    #[test]
    fn padded_width() {
        assert_eq!(Bsn::new(9216).padded_width(), 16384);
        assert_eq!(Bsn::new(1024).padded_width(), 1024);
    }

    #[test]
    fn table5_calibration_anchor() {
        // The 3x3x512 conv: 4608 products x 2-bit = 9216 bits.
        let bsn = Bsn::new(9216);
        let c = bsn.cost();
        // Calibrated to Table V baseline: 2.95e5 um^2, 4.33 ns.
        assert!((c.area_um2 / 2.95e5 - 1.0).abs() < 0.02, "area {}", c.area_um2);
        assert!((c.delay_ns / 4.33 - 1.0).abs() < 0.02, "delay {}", c.delay_ns);
    }

    #[test]
    fn packed_sort_equals_scalar() {
        // The word-parallel fast path must match the scalar network
        // exactly for every width class and density.
        let mut rng = Rng::new(99);
        for width in [1usize, 7, 63, 64, 65, 127, 128, 200, 511, 1024] {
            let bsn = Bsn::new(width);
            for density in [0.1, 0.5, 0.9] {
                for _ in 0..5 {
                    let mut b = BitVec::zeros(width);
                    for i in 0..width {
                        b.set(i, rng.gen_bool(density));
                    }
                    let packed = bsn.sort_gate_level(&b);
                    // Scalar path: force the fault machinery with a
                    // never-firing injector.
                    let mut never = || false;
                    let mut lanes = Vec::new();
                    let mut scalar = BitVec::zeros(0);
                    bsn.sort_scalar_into(&b, &mut never, &mut lanes, &mut scalar);
                    assert_eq!(packed, scalar, "width={width} in={b}");
                }
            }
        }
    }

    #[test]
    fn sort_into_reuses_buffers_and_matches() {
        let mut rng = Rng::new(5);
        let mut scratch = Vec::new();
        let mut out = BitVec::zeros(0);
        for width in [3usize, 17, 64, 129] {
            let bsn = Bsn::new(width);
            for _ in 0..4 {
                let mut b = BitVec::zeros(width);
                for i in 0..width {
                    b.set(i, rng.gen_bool(0.5));
                }
                bsn.sort_gate_level_into(&b, &mut scratch, &mut out);
                assert_eq!(out, bsn.sort_gate_level(&b), "width={width}");
                // Concat round-trips through the reuse path too.
                let codes =
                    [ThermCode::from_count(1, 2), ThermCode::from_count(2, 2)];
                let mut cat = BitVec::zeros(0);
                Bsn::concat_into(&codes, &mut cat);
                assert_eq!(cat, Bsn::concat(&codes));
            }
        }
    }

    #[test]
    fn faults_into_matches_allocating_path() {
        // Same seed -> identical draw order -> identical faulty output,
        // with the scratch buffers reused across calls.
        let bsn = Bsn::new(100);
        let mut setup = Rng::new(13);
        let mut b = BitVec::zeros(100);
        for i in 0..100 {
            b.set(i, setup.gen_bool(0.5));
        }
        let mut lanes = Vec::new();
        let mut out = BitVec::zeros(0);
        for ber in [0.0, 1e-3, 0.05] {
            let mut r1 = Rng::new(77);
            let mut r2 = Rng::new(77);
            let alloc = bsn.sort_with_faults(&b, ber, &mut r1);
            bsn.sort_with_faults_into(&b, ber, &mut r2, &mut lanes, &mut out);
            assert_eq!(alloc, out, "ber={ber}");
        }
    }

    #[test]
    fn zero_ber_faults_equals_clean() {
        let mut rng = Rng::new(3);
        let bsn = Bsn::new(32);
        let mut b = BitVec::zeros(32);
        for i in 0..32 {
            b.set(i, rng.gen_bool(0.4));
        }
        let clean = bsn.sort_gate_level(&b);
        let faulty = bsn.sort_with_faults(&b, 0.0, &mut rng);
        assert_eq!(clean, faulty);
    }

    #[test]
    fn fault_injection_bounded_impact() {
        // With small BER the popcount error should be small relative to
        // width — SC's graceful degradation (Fig 5's mechanism).
        let mut rng = Rng::new(11);
        let bsn = Bsn::new(256);
        let mut b = BitVec::zeros(256);
        for i in 0..256 {
            b.set(i, rng.gen_bool(0.5));
        }
        let clean = bsn.sort_gate_level(&b).popcount() as i64;
        let mut max_err = 0i64;
        for _ in 0..10 {
            let f = bsn.sort_with_faults(&b, 1e-3, &mut rng).popcount() as i64;
            max_err = max_err.max((f - clean).abs());
        }
        assert!(max_err <= 16, "max_err={max_err}");
    }
}
