//! The **spatial-temporal** BSN (paper §IV.B, Fig 12).
//!
//! Because the approximate BSN's output BSL is much shorter than its
//! input, a wide accumulation can be *folded in time*: one small
//! spatial BSN is reused over multiple cycles, each cycle producing a
//! short partial-sum code that is latched; a final merge cycle sorts the
//! concatenated partials. Fig 12's example: a 576-bit BSN reused over
//! 9 cycles (8 data + 1 merge) handles a 4608-bit accumulation.
//!
//! The approximation level (partial-sum BSL) and the reuse count are
//! runtime control signals, which is what makes one physical datapath
//! serve every layer of the network (Fig 13).

use crate::coding::BitVec;
use crate::cost::{cost_of, Cost};
use crate::gates::{GateCount, GateKind};
use crate::util::Rng;
use super::approx_bsn::{ApproxBsn, SubSample};
use super::bsn::Bsn;

/// A spatial-temporal BSN: `inner` handles `inner.in_width()` bits per
/// cycle; `data_cycles` cycles of input are latched and merged by a
/// final merge BSN + sampler.
#[derive(Clone, Debug)]
pub struct SpatialTemporalBsn {
    inner: ApproxBsn,
    data_cycles: usize,
    merge_sub: SubSample,
}

impl SpatialTemporalBsn {
    /// Fold a `total_width`-bit accumulation onto `inner`. The merge
    /// stage sorts `data_cycles × inner.out_bsl()` partial bits and
    /// sub-samples them with `merge_sub`.
    pub fn new(inner: ApproxBsn, total_width: usize, merge_sub: SubSample) -> Self {
        let w0 = inner.in_width();
        assert!(total_width >= w0, "total width smaller than the inner BSN");
        assert_eq!(
            total_width % w0,
            0,
            "total width {total_width} must be a multiple of the inner width {w0}"
        );
        let data_cycles = total_width / w0;
        // Validate the merge sampler against the merge width.
        let _ = merge_sub.out_bsl(data_cycles * inner.out_bsl());
        Self { inner, data_cycles, merge_sub }
    }

    /// The per-cycle spatial network.
    pub fn inner(&self) -> &ApproxBsn {
        &self.inner
    }

    /// Data cycles (excluding the merge cycle).
    pub fn data_cycles(&self) -> usize {
        self.data_cycles
    }

    /// Total cycles including the final merge — Fig 12's "9 cycles".
    pub fn total_cycles(&self) -> usize {
        self.data_cycles + 1
    }

    /// Total accumulated width in bits.
    pub fn total_width(&self) -> usize {
        self.data_cycles * self.inner.in_width()
    }

    /// Width of the merge BSN.
    pub fn merge_width(&self) -> usize {
        self.data_cycles * self.inner.out_bsl()
    }

    /// Final output BSL.
    pub fn out_bsl(&self) -> usize {
        self.merge_sub.out_bsl(self.merge_width())
    }

    /// Combined scale divisor (inner strides × merge stride).
    pub fn scale_divisor(&self) -> usize {
        self.inner.scale_divisor() * self.merge_sub.stride
    }

    /// Count-domain evaluation: `counts` holds the per-leaf-group
    /// popcounts for **all** cycles, i.e. `data_cycles × m_0` entries in
    /// cycle order.
    pub fn eval_counts(&self, counts: &[usize]) -> usize {
        let m0 = self.inner.stages()[0].m;
        assert_eq!(counts.len(), self.data_cycles * m0);
        let merged: usize = counts
            .chunks(m0)
            .map(|cycle| self.inner.eval_counts(cycle))
            .sum();
        self.merge_sub.apply_count(merged, self.merge_width())
    }

    /// Bit-level evaluation over the full input stream (cycle-major).
    /// Per-cycle chunk extraction is a word-parallel range copy; the
    /// merge sorts packed words end to end.
    pub fn eval_bits(&self, input: &BitVec) -> BitVec {
        assert_eq!(input.len(), self.total_width());
        let w0 = self.inner.in_width();
        let mut partials = BitVec::zeros(0);
        let mut chunk = BitVec::zeros(0);
        for c in 0..self.data_cycles {
            chunk.copy_range_from(input, c * w0, w0);
            partials.extend_from(&self.inner.eval_bits(&chunk));
        }
        let merge = Bsn::new(self.merge_width());
        let sorted = merge.sort_gate_level(&partials);
        self.merge_sub.apply_bits(&sorted)
    }

    /// Exact reference value at the output scale.
    pub fn exact_scaled_value(&self, counts: &[usize]) -> f64 {
        let total: usize = counts.iter().sum();
        let q = total as f64 - self.total_width() as f64 / 2.0;
        q / self.scale_divisor() as f64
    }

    /// Approximate decoded value at the output scale.
    pub fn approx_value(&self, counts: &[usize]) -> f64 {
        self.eval_counts(counts) as f64 - self.out_bsl() as f64 / 2.0
    }

    /// Gate composition: inner network + partial-sum registers + merge
    /// BSN + merge sampler + the control counter.
    pub fn gate_count(&self) -> GateCount {
        let inner = self.inner.gate_count();
        // Partials are sorted codes; the merge cycle is a merge tree,
        // not a full sort.
        let merge = Bsn::merge_tree_gate_count(self.data_cycles, self.inner.out_bsl());
        let mut regs = GateCount::new();
        regs.add(GateKind::Dff, self.merge_width() as u64);
        let mut sample = GateCount::new();
        sample.add(GateKind::Mux2, self.out_bsl() as u64);
        let mut ctrl = GateCount::new();
        ctrl.add(GateKind::Dff, 8);
        ctrl.add(GateKind::And2, 16);
        // Area of everything; critical path per cycle is the max of the
        // inner network and the merge network (they run in different
        // cycles on the same clock).
        let mut g = inner
            .parallel(&merge)
            .parallel(&sample)
            .series(&regs)
            .series(&ctrl);
        g.depth = inner.depth.max(merge.depth + sample.depth) + GateKind::Dff.delay_eq();
        g
    }

    /// Per-cycle physical cost (area is total; delay/energy are for one
    /// cycle).
    pub fn cycle_cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }

    /// End-to-end cost for one full accumulation: area unchanged, delay
    /// and energy over all cycles.
    pub fn total_cost(&self) -> Cost {
        self.cycle_cost().over_cycles(self.total_cycles() as u64)
    }

    /// Throughput-normalized ADP against a reference latency (Table V's
    /// footnote: the spatial-temporal design is charged the replication
    /// needed to match the single-cycle design's throughput).
    pub fn adp_throughput_normalized(&self, ref_delay_ns: f64) -> f64 {
        let c = self.cycle_cost();
        let latency = c.delay_ns * self.total_cycles() as f64;
        let replicas = (latency / ref_delay_ns).ceil();
        c.area_um2 * replicas * c.delay_ns
    }

    /// MSE versus the exact accumulation over Bernoulli(p) inputs,
    /// normalized like [`ApproxBsn::mse`].
    pub fn mse(&self, p_one: f64, trials: usize, rng: &mut Rng) -> f64 {
        let m0 = self.inner.stages()[0].m;
        let l0 = self.inner.stages()[0].l;
        let groups = self.data_cycles * m0;
        let mut se = 0.0;
        for _ in 0..trials {
            let counts: Vec<usize> = (0..groups)
                .map(|_| (0..l0).filter(|_| rng.gen_bool(p_one)).count())
                .collect();
            let exact = self.exact_scaled_value(&counts);
            let approx = self.approx_value(&counts);
            let norm = self.total_width() as f64 / (2.0 * self.scale_divisor() as f64);
            se += ((approx - exact) / norm).powi(2);
        }
        se / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::circuits::approx_bsn::ApproxStage;

    /// Fig 12's example at full scale: 576-bit inner BSN, 4608-bit
    /// accumulation, 8 data cycles + 1 merge = 9 cycles.
    fn fig12() -> SpatialTemporalBsn {
        let inner = ApproxBsn::new(vec![ApproxStage {
            m: 1,
            l: 576,
            sub: SubSample { clip: 224, stride: 8 },
        }]);
        SpatialTemporalBsn::new(inner, 4608, SubSample { clip: 56, stride: 1 })
    }

    #[test]
    fn fig12_is_nine_cycles() {
        let st = fig12();
        assert_eq!(st.data_cycles(), 8);
        assert_eq!(st.total_cycles(), 9);
        assert_eq!(st.total_width(), 4608);
        assert_eq!(st.inner().in_width(), 576);
    }

    fn small() -> SpatialTemporalBsn {
        // 32-bit inner, 128-bit total, 4 data cycles + merge.
        let inner = ApproxBsn::new(vec![ApproxStage {
            m: 1,
            l: 32,
            sub: SubSample { clip: 8, stride: 2 },
        }]);
        SpatialTemporalBsn::new(inner, 128, SubSample { clip: 8, stride: 1 })
    }

    #[test]
    fn counts_equals_bits() {
        let st = small();
        let mut rng = Rng::new(17);
        for _ in 0..20 {
            let mut bits = BitVec::zeros(128);
            for i in 0..128 {
                bits.set(i, rng.gen_bool(0.5));
            }
            let counts: Vec<usize> = (0..4)
                .map(|c| (0..32).filter(|&i| bits.get(c * 32 + i)).count())
                .collect();
            assert_eq!(st.eval_bits(&bits).popcount(), st.eval_counts(&counts));
        }
    }

    #[test]
    fn balanced_inputs_low_error() {
        let st = small();
        let mut rng = Rng::new(23);
        let mse = st.mse(0.5, 500, &mut rng);
        assert!(mse < 2e-2, "mse={mse}");
    }

    #[test]
    fn st_area_much_smaller_than_flat_bsn() {
        let st = fig12();
        let flat = Bsn::new(4608);
        let a_st = st.cycle_cost().area_um2;
        let a_flat = flat.cost().area_um2;
        assert!(
            a_st < a_flat / 5.0,
            "ST area {a_st} vs flat {a_flat} — folding must shrink area"
        );
    }

    #[test]
    fn total_cost_scales_delay_by_cycles() {
        let st = small();
        let c1 = st.cycle_cost();
        let ct = st.total_cost();
        assert_eq!(ct.area_um2, c1.area_um2);
        assert!((ct.delay_ns - c1.delay_ns * 5.0).abs() < 1e-9);
    }

    #[test]
    fn throughput_normalization_charges_replicas() {
        let st = fig12();
        let raw_adp = st.cycle_cost().adp();
        let norm = st.adp_throughput_normalized(4.33);
        assert!(norm > raw_adp, "normalization must charge replicas");
    }

    #[test]
    #[should_panic(expected = "multiple")]
    fn non_divisible_width_rejected() {
        let inner = ApproxBsn::exact(100);
        SpatialTemporalBsn::new(inner, 250, SubSample::IDENTITY);
    }
}
