//! The selective interconnect (SI) activation block (paper §II.B,
//! Fig 3b; BN-fusion in §III.C, Fig 7).
//!
//! Because the BSN output is fully sorted, bit `p` of the sorted stream
//! equals `1` iff the accumulated count `c > p`. Selecting bits of the
//! sorted stream therefore realizes **any monotone non-decreasing step
//! function** of the accumulation, deterministically: output bit `j`
//! taps sorted bit `sel[j]`, giving `out_count(c) = #{j : c > sel[j]}`.
//!
//! This module synthesizes the tap configuration for the paper's
//! activation functions:
//!
//! * plain ReLU (with re-scaling between input and output alphas),
//! * the BN-fused ReLU of Eq 1: `f(x) = γ(x-β)` for `x ≥ β`, else 0,
//! * quantized tanh (for the Fig 1 / Fig 10a accuracy comparisons),
//! * the two-step function of Fig 3b,
//! * arbitrary user closures (checked for monotonicity).

use crate::coding::{BitVec, ThermCode};
use crate::cost::{cost_of, Cost};
use crate::gates::{GateCount, GateKind};

/// One output tap of the SI: a constant or a sorted-stream bit index.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SelTap {
    /// Constant 0 (count never reaches the threshold).
    Zero,
    /// Constant 1 (threshold 0 — always on).
    One,
    /// Tap sorted bit `p` (1 iff count > p).
    Bit(usize),
}

/// The paper's activation functions, as synthesis recipes.
#[derive(Clone, Debug)]
pub enum ActivationFn {
    /// Identity (pure accumulation, re-quantized to the output BSL).
    Identity,
    /// `max(0, x)` with input/output scale ratio `r = alpha_in/alpha_out`.
    Relu {
        /// Scale ratio applied before output quantization.
        ratio: f64,
    },
    /// BN-fused ReLU (Eq 1): `γ(x-β)` for `x ≥ β`, else 0, in units of
    /// the input quantization step (`x = q_in`, output re-quantized).
    BnRelu {
        /// BN scale `γ > 0`.
        gamma: f64,
        /// BN shift `β` in input-quant units.
        beta: f64,
        /// Input-to-output scale ratio.
        ratio: f64,
    },
    /// `tanh(gain · q_in) · (L_out/2)` — the FSM comparison target.
    Tanh {
        /// Input gain (absorbs alpha_in).
        gain: f64,
    },
    /// The two-step function of Fig 3b: thresholds in count domain.
    TwoStep {
        /// Count thresholds (sorted); output count = #{t <= c}.
        t1: usize,
        /// Second threshold.
        t2: usize,
    },
}

impl ActivationFn {
    /// Evaluate as a count-domain function: accumulated count
    /// `c ∈ [0, in_width]` to output count `∈ [0, out_bsl]`.
    pub fn eval_count(&self, c: usize, in_width: usize, out_bsl: usize) -> usize {
        let half_in = in_width as f64 / 2.0;
        let half_out = out_bsl as f64 / 2.0;
        let q = c as f64 - half_in;
        let out_q = match self {
            ActivationFn::Identity => q,
            ActivationFn::Relu { ratio } => q.max(0.0) * ratio,
            ActivationFn::BnRelu { gamma, beta, ratio } => {
                if q >= *beta {
                    gamma * (q - beta) * ratio
                } else {
                    0.0
                }
            }
            ActivationFn::Tanh { gain } => (gain * q).tanh() * half_out,
            ActivationFn::TwoStep { t1, t2 } => {
                return (c >= *t1) as usize + (c >= *t2) as usize;
            }
        };
        (out_q.round().clamp(-half_out, half_out) + half_out) as usize
    }
}

/// A synthesized selective interconnect.
#[derive(Clone, Debug)]
pub struct SelectiveInterconnect {
    taps: Vec<SelTap>,
    in_width: usize,
}

impl SelectiveInterconnect {
    /// Synthesize taps for a monotone count function `f(c)` mapping
    /// `0..=in_width` to `0..=out_bsl`. Panics if `f` is not monotone
    /// non-decreasing or exceeds the output range — non-monotone
    /// functions are not realizable by bit selection (the paper's SI has
    /// the same restriction).
    pub fn synthesize(
        f: impl Fn(usize) -> usize,
        in_width: usize,
        out_bsl: usize,
    ) -> Self {
        let mut prev = 0usize;
        let mut values = Vec::with_capacity(in_width + 1);
        for c in 0..=in_width {
            let v = f(c);
            assert!(v <= out_bsl, "SI target out of range: f({c}) = {v} > {out_bsl}");
            assert!(v >= prev, "SI target not monotone at c={c}: {v} < {prev}");
            values.push(v);
            prev = v;
        }
        let taps = (0..out_bsl)
            .map(|j| {
                // Smallest count c with f(c) >= j+1.
                match values.iter().position(|&v| v >= j + 1) {
                    None => SelTap::Zero,
                    Some(0) => SelTap::One,
                    Some(t) => SelTap::Bit(t - 1),
                }
            })
            .collect();
        Self { taps, in_width }
    }

    /// Synthesize one of the named activation functions.
    pub fn for_activation(act: &ActivationFn, in_width: usize, out_bsl: usize) -> Self {
        Self::synthesize(|c| act.eval_count(c, in_width, out_bsl), in_width, out_bsl)
    }

    /// Output BSL.
    pub fn out_bsl(&self) -> usize {
        self.taps.len()
    }

    /// Input width.
    pub fn in_width(&self) -> usize {
        self.in_width
    }

    /// The tap configuration.
    pub fn taps(&self) -> &[SelTap] {
        &self.taps
    }

    /// Functional application in the count domain (the exact semantics
    /// of tapping a perfectly sorted stream).
    pub fn apply_count(&self, count: usize) -> usize {
        self.taps
            .iter()
            .filter(|t| match t {
                SelTap::Zero => false,
                SelTap::One => true,
                SelTap::Bit(p) => count > *p,
            })
            .count()
    }

    /// Bit-level application on an actual (possibly fault-corrupted)
    /// sorted stream.
    pub fn apply_bits(&self, sorted: &BitVec) -> BitVec {
        let mut out = BitVec::zeros(0);
        self.apply_bits_into(sorted, &mut out);
        out
    }

    /// Buffer-reuse variant of [`SelectiveInterconnect::apply_bits`]:
    /// overwrites `out`, reusing its allocation. The tap gather
    /// assembles whole output words directly from the packed sorted
    /// stream instead of setting bits one at a time.
    pub fn apply_bits_into(&self, sorted: &BitVec, out: &mut BitVec) {
        assert_eq!(sorted.len(), self.in_width);
        out.reset(self.taps.len());
        let words = out.as_mut_words();
        let mut acc = 0u64;
        let mut wi = 0usize;
        for (j, t) in self.taps.iter().enumerate() {
            let v = match t {
                SelTap::Zero => false,
                SelTap::One => true,
                SelTap::Bit(p) => sorted.get(*p),
            };
            if v {
                acc |= 1 << (j % 64);
            }
            if j % 64 == 63 {
                words[wi] = acc;
                wi += 1;
                acc = 0;
            }
        }
        if self.taps.len() % 64 != 0 {
            words[wi] = acc;
        }
    }

    /// Fused tap + popcount: the number of 1s
    /// [`SelectiveInterconnect::apply_bits`] would produce, without
    /// assembling the output vector. The fault path only needs the
    /// tapped *count* (it re-encodes from it), so this drops the whole
    /// temp-buffer write/read pass — the same fusion
    /// [`crate::coding::BitVec::count_and`] provides for word-aligned
    /// AND+popcount taps.
    pub fn apply_bits_count(&self, sorted: &BitVec) -> usize {
        assert_eq!(sorted.len(), self.in_width);
        self.taps
            .iter()
            .filter(|t| match t {
                SelTap::Zero => false,
                SelTap::One => true,
                SelTap::Bit(p) => sorted.get(*p),
            })
            .count()
    }

    /// The full count-transfer table `count ↦ apply_count(count)` for
    /// `count ∈ 0..=in_width` — what a serving engine precomputes once
    /// per channel so the steady-state inner loop is a single indexed
    /// load instead of a tap scan.
    pub fn count_table(&self) -> Vec<usize> {
        (0..=self.in_width).map(|c| self.apply_count(c)).collect()
    }

    /// [`SelectiveInterconnect::count_table`] shifted to **signed**
    /// output codes: entry `c` is `apply_count(c) - out_bsl/2`, i.e.
    /// exactly the value a serving engine stores in an activation
    /// plane. One synthesis entry point for every LUT consumer.
    pub fn signed_count_table(&self) -> Vec<i32> {
        let off = (self.out_bsl() / 2) as i32;
        self.count_table().into_iter().map(|v| v as i32 - off).collect()
    }

    /// Apply to a thermometer accumulation result.
    pub fn apply(&self, acc: &ThermCode) -> ThermCode {
        assert_eq!(acc.bsl(), self.in_width);
        ThermCode::from_count(self.apply_count(acc.count()), self.taps.len())
    }

    /// Gate composition: the SI is a configurable routing network [14];
    /// we model one `log2(in_width)`-deep mux path per output bit.
    pub fn gate_count(&self) -> GateCount {
        let depth = (self.in_width.max(2) as f64).log2().ceil();
        let mut g = GateCount::new();
        g.add(GateKind::Mux2, self.taps.len() as u64 * depth as u64);
        g.depth = depth * GateKind::Mux2.delay_eq();
        g
    }

    /// Physical cost.
    pub fn cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }
}

/// Flatten per-channel signed count tables into one channel-major LUT
/// of `sis.len() × lut_w` entries — the layout serving engines index as
/// `lut[channel · lut_w + count]`. `lut_w` must equal every channel's
/// `in_width + 1` (one entry per possible accumulated count); the
/// mismatch assert catches SI banks synthesized at the wrong BSN width.
pub fn flatten_count_tables(sis: &[SelectiveInterconnect], lut_w: usize) -> Vec<i32> {
    let mut lut = Vec::with_capacity(sis.len() * lut_w);
    for si in sis {
        let table = si.signed_count_table();
        assert_eq!(table.len(), lut_w, "SI in_width must equal the layer's BSN width");
        lut.extend(table);
    }
    lut
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn two_step_example_fig3b() {
        // Fig 3b: SI taps the 3rd and 6th sorted bits -> out bit j = 1
        // iff count > {2, 5}. TwoStep{t1:3, t2:6} == count >= 3, >= 6.
        let si = SelectiveInterconnect::for_activation(
            &ActivationFn::TwoStep { t1: 3, t2: 6 },
            8,
            2,
        );
        assert_eq!(si.taps(), &[SelTap::Bit(2), SelTap::Bit(5)]);
        assert_eq!(si.apply_count(2), 0);
        assert_eq!(si.apply_count(3), 1);
        assert_eq!(si.apply_count(5), 1);
        assert_eq!(si.apply_count(6), 2);
        assert_eq!(si.apply_count(8), 2);
    }

    #[test]
    fn synthesis_matches_target_everywhere() {
        // Whatever monotone f we ask for, apply_count must reproduce it
        // exactly at every possible count.
        let in_w = 64;
        let out = 16;
        let act = ActivationFn::Relu { ratio: 0.25 };
        let si = SelectiveInterconnect::for_activation(&act, in_w, out);
        for c in 0..=in_w {
            assert_eq!(
                si.apply_count(c),
                act.eval_count(c, in_w, out),
                "c={c}"
            );
        }
    }

    #[test]
    fn bn_relu_matches_eq1() {
        // Eq 1: gamma(x - beta) above beta, 0 below; monotone for gamma>0.
        let in_w = 32;
        let out = 16;
        let act = ActivationFn::BnRelu { gamma: 1.5, beta: 2.0, ratio: 0.5 };
        let si = SelectiveInterconnect::for_activation(&act, in_w, out);
        for c in 0..=in_w {
            let q = c as f64 - 16.0;
            let expect = if q >= 2.0 { (1.5 * (q - 2.0) * 0.5).round().min(8.0) } else { 0.0 };
            let got = si.apply_count(c) as f64 - 8.0;
            assert_eq!(got, expect, "c={c}");
        }
    }

    #[test]
    fn tanh_is_realizable_and_saturates() {
        let si = SelectiveInterconnect::for_activation(
            &ActivationFn::Tanh { gain: 0.25 },
            64,
            16,
        );
        assert_eq!(si.apply_count(0), 0); // tanh(-8) ~ -1 -> count 0
        assert_eq!(si.apply_count(64), 16);
        assert_eq!(si.apply_count(32), 8); // tanh(0) = 0 -> center
    }

    #[test]
    #[should_panic(expected = "not monotone")]
    fn non_monotone_rejected() {
        SelectiveInterconnect::synthesize(|c| if c == 3 { 5 } else { 0 }, 8, 8);
    }

    #[test]
    fn bits_path_equals_count_path_on_sorted() {
        let act = ActivationFn::Relu { ratio: 1.0 };
        let si = SelectiveInterconnect::for_activation(&act, 16, 16);
        for c in 0..=16usize {
            let sorted = ThermCode::from_count(c, 16);
            let bits = si.apply_bits(sorted.bits());
            assert_eq!(bits.popcount(), si.apply_count(c));
            assert!(bits.is_thermometer());
        }
    }

    #[test]
    fn apply_bits_into_and_count_table_match() {
        let act = ActivationFn::BnRelu { gamma: 1.25, beta: -1.0, ratio: 0.5 };
        let si = SelectiveInterconnect::for_activation(&act, 24, 8);
        let table = si.count_table();
        assert_eq!(table.len(), 25);
        let mut out = BitVec::zeros(0);
        for c in 0..=24usize {
            assert_eq!(table[c], si.apply_count(c));
            let sorted = ThermCode::from_count(c, 24);
            si.apply_bits_into(sorted.bits(), &mut out);
            assert_eq!(out, si.apply_bits(sorted.bits()));
            // Fused tap+count path agrees with the materialized one.
            assert_eq!(si.apply_bits_count(sorted.bits()), out.popcount());
        }
    }

    #[test]
    fn signed_table_and_flattening() {
        let a = SelectiveInterconnect::for_activation(&ActivationFn::Relu { ratio: 0.5 }, 12, 4);
        let b = SelectiveInterconnect::for_activation(
            &ActivationFn::BnRelu { gamma: 2.0, beta: 1.0, ratio: 0.25 },
            12,
            4,
        );
        let st = a.signed_count_table();
        for c in 0..=12usize {
            assert_eq!(st[c], a.apply_count(c) as i32 - 2, "c={c}");
        }
        let flat = flatten_count_tables(&[a.clone(), b.clone()], 13);
        assert_eq!(flat.len(), 2 * 13);
        assert_eq!(&flat[..13], a.signed_count_table().as_slice());
        assert_eq!(&flat[13..], b.signed_count_table().as_slice());
    }

    #[test]
    fn identity_is_requantization() {
        let si = SelectiveInterconnect::for_activation(&ActivationFn::Identity, 16, 16);
        for c in 0..=16 {
            assert_eq!(si.apply_count(c), c);
        }
    }

    #[test]
    fn si_cost_is_small_vs_bsn() {
        let si = SelectiveInterconnect::for_activation(
            &ActivationFn::Relu { ratio: 1.0 },
            9216,
            16,
        );
        let bsn = crate::circuits::Bsn::new(9216);
        assert!(si.cost().area_um2 < bsn.cost().area_um2 / 100.0);
    }
}
