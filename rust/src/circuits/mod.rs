//! The paper's SC circuit blocks, gate-accurate where the paper is
//! gate-accurate and functionally exact everywhere.
//!
//! * [`multiplier`] — the 5-gate ternary SC multiplier (Fig 3a) and its
//!   generalization to `L`-bit thermometer activations.
//! * [`bsn`] — the exact bitonic sorting network non-linear adder
//!   (Fig 3b): gate-level compare-exchange simulation, functional
//!   popcount model (property-tested equivalent), and exact Batcher
//!   combinatorics for the cost model.
//! * [`si`] — the selective interconnect: synthesis of arbitrary
//!   monotone step activation functions (ReLU, quantized tanh, two-step,
//!   BN-fused ReLU of Eq 1 / Fig 7) as bit-selections from the sorted
//!   stream.
//! * [`fsm`] — the FSM-based *stochastic* activation baselines the paper
//!   compares against in Fig 1 (Stanh, FSM-ReLU).
//! * [`rescale`] — the residual re-scaling block (§III.C): ×2^N by
//!   buffer replication, ÷2^N by 1-of-2 selection with the paper's
//!   `11110000` zero-padding.
//! * [`approx_bsn`] — the approximate **spatial** BSN (§IV.B): staged
//!   sub-BSNs with clip-and-stride sub-sampling (truncated
//!   quantization).
//! * [`st_bsn`] — the **spatial-temporal** BSN (Fig 12): multi-cycle
//!   reuse of one small BSN with a final merge stage.
//! * [`datapath`] — the full SC conv datapath: multiplier array + BSN +
//!   SI (+ residual path), with cost roll-up. This is the unit Table IV,
//!   Table V and Fig 13 measure.

pub mod approx_bsn;
pub mod bsn;
pub mod datapath;
pub mod fsm;
pub mod multiplier;
pub mod rescale;
pub mod si;
pub mod st_bsn;

pub use approx_bsn::{ApproxBsn, ApproxStage, SubSample};
pub use bsn::Bsn;
pub use datapath::{BsnKind, ConvDatapath, DatapathConfig};
pub use multiplier::TernaryMultiplier;
pub use rescale::RescaleBlock;
pub use si::{ActivationFn, SelectiveInterconnect};
pub use st_bsn::SpatialTemporalBsn;
