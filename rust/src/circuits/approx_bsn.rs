//! The approximate **spatial** sorting network (paper §IV.B, Fig 10b).
//!
//! The exact BSN's cost grows super-linearly with accumulation width
//! (Fig 9a), yet the SI consumes only a handful of output bits — a large
//! precision gap (Fig 10a). The paper exploits it with *progressive
//! sorting and sub-sampling*: the network is split into `N` stages; in
//! stage `i` there are `m_i` sub-BSNs, each sorting `l_i` bits, followed
//! by a **sub-sampling block** implementing truncated quantization: clip
//! `c_i` bits at each end of the sorted stream and keep 1 bit of every
//! `s_i` of the remainder.
//!
//! Because the accumulated distribution is near-Gaussian with small
//! variance (inputs come from many multipliers — Fig 11), aggressive
//! clipping costs almost nothing, and striding divides the downstream
//! width (and the represented scale) by `s_i`.

use crate::coding::BitVec;
use crate::cost::{cost_of, Cost};
use crate::gates::{GateCount, GateKind};
use crate::util::Rng;
use super::bsn::Bsn;

/// A clip-and-stride sub-sampling block on an `l`-bit sorted stream.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct SubSample {
    /// Bits clipped at *each* end of the sorted stream.
    pub clip: usize,
    /// Keep one bit of every `stride` remaining bits.
    pub stride: usize,
}

impl SubSample {
    /// Identity sampling.
    pub const IDENTITY: SubSample = SubSample { clip: 0, stride: 1 };

    /// Output BSL for an `l`-bit input.
    pub fn out_bsl(&self, l: usize) -> usize {
        assert!(2 * self.clip < l, "clip {} too large for l={l}", self.clip);
        let kept = l - 2 * self.clip;
        assert!(
            kept % self.stride == 0,
            "stride {} must divide kept width {kept}",
            self.stride
        );
        kept / self.stride
    }

    /// Sampled positions: the **middle** bit of each stride group,
    /// `p_j = clip + j·stride + stride/2` — tapping the centre bit
    /// instead of the last realizes round-to-nearest quantization in
    /// pure wiring, avoiding the `-stride/2` systematic bias a
    /// last-bit tap (floor) would accumulate across stages.
    pub fn positions(&self, l: usize) -> Vec<usize> {
        (0..self.out_bsl(l))
            .map(|j| self.clip + j * self.stride + self.stride / 2)
            .collect()
    }

    /// Count-domain application: input count `k` of `l` bits maps to
    /// `#{j : p_j < k}` over the tapped positions (round-to-nearest
    /// with saturation at the clip boundaries).
    pub fn apply_count(&self, k: usize, l: usize) -> usize {
        let out = self.out_bsl(l);
        let base = self.clip + self.stride / 2;
        if k <= base {
            return 0;
        }
        ((k - base - 1) / self.stride + 1).min(out)
    }

    /// Bit-level application on an actual sorted stream.
    pub fn apply_bits(&self, sorted: &BitVec) -> BitVec {
        let mut out = BitVec::zeros(0);
        self.apply_bits_into(sorted, &mut out);
        out
    }

    /// Buffer-reuse variant of [`SubSample::apply_bits`]: overwrites
    /// `out` (reusing its allocation), assembling whole output words
    /// from the packed sorted stream.
    pub fn apply_bits_into(&self, sorted: &BitVec, out: &mut BitVec) {
        let l = sorted.len();
        let n = self.out_bsl(l);
        out.reset(n);
        let words = out.as_mut_words();
        let mut acc = 0u64;
        let mut wi = 0usize;
        for j in 0..n {
            let p = self.clip + j * self.stride + self.stride / 2;
            if sorted.get(p) {
                acc |= 1 << (j % 64);
            }
            if j % 64 == 63 {
                words[wi] = acc;
                wi += 1;
                acc = 0;
            }
        }
        if n % 64 != 0 {
            words[wi] = acc;
        }
    }
}

/// One stage of the parameterized BSN: `m` sub-BSNs of `l`-bit inputs,
/// each followed by the same sub-sampling block.
#[derive(Clone, Copy, Debug)]
pub struct ApproxStage {
    /// Number of parallel sub-BSNs.
    pub m: usize,
    /// Input BSL per sub-BSN.
    pub l: usize,
    /// The truncated-quantization sampler.
    pub sub: SubSample,
}

impl ApproxStage {
    /// Input width of the stage.
    pub fn in_width(&self) -> usize {
        self.m * self.l
    }

    /// Output width of the stage.
    pub fn out_width(&self) -> usize {
        self.m * self.sub.out_bsl(self.l)
    }
}

/// The full approximate spatial BSN: a pipeline of [`ApproxStage`]s.
///
/// The *scale divisor* is the product of all strides: the final count
/// represents the exact accumulation divided by that factor (with
/// clipping saturation) — downstream SI synthesis must fold it into its
/// input scale.
#[derive(Clone, Debug)]
pub struct ApproxBsn {
    stages: Vec<ApproxStage>,
}

impl ApproxBsn {
    /// Build from stages; validates that widths chain and the final
    /// stage has `m == 1`.
    pub fn new(stages: Vec<ApproxStage>) -> Self {
        assert!(!stages.is_empty());
        for w in stages.windows(2) {
            assert_eq!(
                w[0].out_width(),
                w[1].in_width(),
                "stage widths must chain: {} -> {}",
                w[0].out_width(),
                w[1].in_width()
            );
        }
        assert_eq!(stages.last().unwrap().m, 1, "final stage must merge to one BSN");
        Self { stages }
    }

    /// The exact (single-stage, no sampling) BSN as a degenerate config.
    pub fn exact(width: usize) -> Self {
        Self::new(vec![ApproxStage { m: 1, l: width, sub: SubSample::IDENTITY }])
    }

    /// Stages.
    pub fn stages(&self) -> &[ApproxStage] {
        &self.stages
    }

    /// Total input width in bits.
    pub fn in_width(&self) -> usize {
        self.stages[0].in_width()
    }

    /// Final output BSL.
    pub fn out_bsl(&self) -> usize {
        let s = self.stages.last().unwrap();
        s.sub.out_bsl(s.l)
    }

    /// Product of all strides — the factor by which the represented
    /// scale was divided.
    pub fn scale_divisor(&self) -> usize {
        self.stages.iter().map(|s| s.sub.stride).product()
    }

    /// Count-domain evaluation from per-leaf-group counts. `counts[i]`
    /// is the popcount of the `i`-th `l_0`-bit input group of stage 0
    /// (`counts.len() == m_0`). Returns the final output count.
    ///
    /// Sorting a concatenation of groups merges their popcounts, so a
    /// stage's group count is the sum of the child counts feeding it —
    /// this is the exact functional semantics of the bit-level network
    /// (property-tested against [`ApproxBsn::eval_bits`]).
    pub fn eval_counts(&self, counts: &[usize]) -> usize {
        assert_eq!(counts.len(), self.stages[0].m);
        let mut cur: Vec<usize> = counts.to_vec();
        let mut cur_bsl = self.stages[0].l;
        for (i, st) in self.stages.iter().enumerate() {
            if i > 0 {
                // Regroup: each of the m_i groups of l_i bits is made of
                // l_i / cur_bsl child blocks.
                assert_eq!(st.l % cur_bsl, 0);
                let per = st.l / cur_bsl;
                assert_eq!(cur.len(), st.m * per);
                cur = cur.chunks(per).map(|c| c.iter().sum()).collect();
            }
            cur = cur.iter().map(|&k| st.sub.apply_count(k, st.l)).collect();
            cur_bsl = st.sub.out_bsl(st.l);
        }
        debug_assert_eq!(cur.len(), 1);
        cur[0]
    }

    /// Bit-level evaluation: actually sorts every sub-BSN and samples
    /// bits. Exact circuit semantics (used for verification). Group
    /// extraction, sorting and sampling all stay in the packed word
    /// domain — the only per-bit work left is the sampler's tap gather.
    pub fn eval_bits(&self, input: &BitVec) -> BitVec {
        assert_eq!(input.len(), self.in_width());
        let mut cur = input.clone();
        let mut next = BitVec::zeros(0);
        let mut grp = BitVec::zeros(0);
        let mut sorted = BitVec::zeros(0);
        let mut sampled = BitVec::zeros(0);
        let mut scratch: Vec<u64> = Vec::new();
        for st in &self.stages {
            next.reset(0);
            let bsn = Bsn::new(st.l);
            for g in 0..st.m {
                grp.copy_range_from(&cur, g * st.l, st.l);
                bsn.sort_gate_level_into(&grp, &mut scratch, &mut sorted);
                st.sub.apply_bits_into(&sorted, &mut sampled);
                next.extend_from(&sampled);
            }
            std::mem::swap(&mut cur, &mut next);
        }
        cur
    }

    /// Exact reference: the un-approximated result re-expressed at the
    /// output scale, `(k_total - W/2) / divisor` (real-valued).
    pub fn exact_scaled_value(&self, counts: &[usize]) -> f64 {
        let total: usize = counts.iter().sum();
        let q = total as f64 - self.in_width() as f64 / 2.0;
        q / self.scale_divisor() as f64
    }

    /// Decoded approximate value at the output scale.
    pub fn approx_value(&self, counts: &[usize]) -> f64 {
        self.eval_counts(counts) as f64 - self.out_bsl() as f64 / 2.0
    }

    /// Gate composition: stage 0 fully sorts its (unsorted) groups;
    /// every later stage only **merges** already-sorted sub-sampled
    /// blocks, so it uses a bitonic merge tree (see
    /// [`Bsn::merge_tree_gate_count`]) — this is what makes progressive
    /// sorting cheaper *and* shallower than one monolithic sort.
    pub fn gate_count(&self) -> GateCount {
        let mut total = GateCount::new();
        let mut child_bsl = 0usize;
        for (i, st) in self.stages.iter().enumerate() {
            let stage_net = if i == 0 {
                Bsn::new(st.l).gate_count().replicate(st.m as u64)
            } else {
                Bsn::merge_tree_gate_count(st.l / child_bsl, child_bsl)
                    .replicate(st.m as u64)
            };
            let mut sample = GateCount::new();
            sample.add(GateKind::Mux2, (st.m * st.sub.out_bsl(st.l)) as u64);
            sample.depth = GateKind::Mux2.delay_eq();
            total = total.series(&stage_net.series(&sample));
            child_bsl = st.sub.out_bsl(st.l);
        }
        total
    }

    /// Physical cost.
    pub fn cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }

    /// Mean-squared error versus the exact accumulation, evaluated over
    /// random near-Gaussian inputs (each input bit Bernoulli(p)), in
    /// units of the *output* quantization step, normalized by the output
    /// range — comparable across configurations (Table V, Fig 13).
    pub fn mse(&self, p_one: f64, trials: usize, rng: &mut Rng) -> f64 {
        let m0 = self.stages[0].m;
        let l0 = self.stages[0].l;
        let mut se = 0.0;
        for _ in 0..trials {
            let counts: Vec<usize> = (0..m0)
                .map(|_| (0..l0).filter(|_| rng.gen_bool(p_one)).count())
                .collect();
            let exact = self.exact_scaled_value(&counts);
            let approx = self.approx_value(&counts);
            let norm = self.in_width() as f64 / (2.0 * self.scale_divisor() as f64);
            se += ((approx - exact) / norm).powi(2);
        }
        se / trials as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn subsample_identity() {
        let s = SubSample::IDENTITY;
        assert_eq!(s.out_bsl(16), 16);
        for k in 0..=16 {
            assert_eq!(s.apply_count(k, 16), k);
        }
    }

    #[test]
    fn subsample_clip_and_stride() {
        let s = SubSample { clip: 4, stride: 2 };
        assert_eq!(s.out_bsl(16), 4);
        assert_eq!(s.positions(16), vec![5, 7, 9, 11]);
        assert_eq!(s.apply_count(0, 16), 0);
        assert_eq!(s.apply_count(4, 16), 0); // fully clipped
        assert_eq!(s.apply_count(6, 16), 1);
        assert_eq!(s.apply_count(12, 16), 4);
        assert_eq!(s.apply_count(16, 16), 4); // saturates
    }

    #[test]
    fn subsample_bits_equals_counts_on_sorted() {
        let s = SubSample { clip: 2, stride: 2 };
        for k in 0..=16usize {
            let sorted = crate::coding::ThermCode::from_count(k, 16);
            let bits = s.apply_bits(sorted.bits());
            assert_eq!(bits.popcount(), s.apply_count(k, 16), "k={k}");
        }
    }

    fn two_stage() -> ApproxBsn {
        // 4 groups of 16 bits -> sample to 8 each -> one 32-bit merge ->
        // 16-bit output.
        ApproxBsn::new(vec![
            ApproxStage { m: 4, l: 16, sub: SubSample { clip: 0, stride: 2 } },
            ApproxStage { m: 1, l: 32, sub: SubSample { clip: 8, stride: 1 } },
        ])
    }

    #[test]
    fn widths_chain_and_scale() {
        let a = two_stage();
        assert_eq!(a.in_width(), 64);
        assert_eq!(a.out_bsl(), 16);
        assert_eq!(a.scale_divisor(), 2);
    }

    #[test]
    fn counts_path_equals_bits_path() {
        let a = two_stage();
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let mut bits = BitVec::zeros(64);
            for i in 0..64 {
                bits.set(i, rng.gen_bool(0.5));
            }
            let counts: Vec<usize> = (0..4)
                .map(|g| (0..16).filter(|&i| bits.get(g * 16 + i)).count())
                .collect();
            assert_eq!(
                a.eval_bits(&bits).popcount(),
                a.eval_counts(&counts),
                "bits={bits}"
            );
        }
    }

    #[test]
    fn exact_config_is_exact() {
        let a = ApproxBsn::exact(64);
        let counts = vec![40usize];
        assert_eq!(a.eval_counts(&counts), 40);
        assert_eq!(a.approx_value(&counts), a.exact_scaled_value(&counts));
    }

    #[test]
    fn near_gaussian_inputs_small_error() {
        // With balanced inputs the accumulated count concentrates near
        // the center; clipping tails costs little (Fig 11's argument).
        let a = two_stage();
        let mut rng = Rng::new(9);
        let mse = a.mse(0.5, 500, &mut rng);
        assert!(mse < 1e-2, "mse={mse}");
    }

    #[test]
    fn approx_is_cheaper_than_exact() {
        let approx = ApproxBsn::new(vec![
            ApproxStage { m: 16, l: 64, sub: SubSample { clip: 16, stride: 2 } },
            ApproxStage { m: 1, l: 256, sub: SubSample { clip: 96, stride: 4 } },
        ]);
        let exact = Bsn::new(1024);
        assert!(approx.cost().area_um2 < exact.cost().area_um2);
        assert_eq!(approx.in_width(), 1024);
    }

    #[test]
    #[should_panic(expected = "must chain")]
    fn bad_chaining_rejected() {
        ApproxBsn::new(vec![
            ApproxStage { m: 2, l: 16, sub: SubSample::IDENTITY },
            ApproxStage { m: 1, l: 16, sub: SubSample::IDENTITY },
        ]);
    }
}
