//! FSM-based stochastic activation baselines (paper Fig 1, refs
//! [6]–[9]).
//!
//! The designs the paper argues *against*: serial finite-state machines
//! over stochastic bipolar bitstreams. They are inherently inaccurate —
//! the FSM consumes the stream serially, its output depends on bit
//! order, and the stochastic input itself fluctuates — which is exactly
//! what Fig 1 plots. We implement the two classic cells:
//!
//! * [`StanhFsm`] — Brown & Card's `Stanh(K, x) ≈ tanh(K/2 · x)`
//!   saturating up/down counter.
//! * [`ReluFsm`] — the FSM-based ReLU of [9]: tracks the running sign of
//!   the accumulated input and passes the input bit when positive,
//!   emitting the bipolar-zero pattern (alternating bits) otherwise.

use crate::coding::stochastic::{bipolar_decode, Sng};
use crate::coding::BitVec;
use crate::cost::{cost_of, Cost};
use crate::gates::{GateCount, GateKind};

/// Saturating up/down counter FSM implementing stochastic tanh.
#[derive(Clone, Debug)]
pub struct StanhFsm {
    states: u32,
    state: u32,
}

impl StanhFsm {
    /// `states` must be even; approximates `tanh(states/2 · x)`.
    pub fn new(states: u32) -> Self {
        assert!(states >= 2 && states % 2 == 0);
        Self { states, state: states / 2 }
    }

    /// Reset to the central state.
    pub fn reset(&mut self) {
        self.state = self.states / 2;
    }

    /// Process one input bit, produce one output bit.
    pub fn step(&mut self, bit: bool) -> bool {
        if bit {
            self.state = (self.state + 1).min(self.states - 1);
        } else {
            self.state = self.state.saturating_sub(1);
        }
        self.state >= self.states / 2
    }

    /// Run over a whole stream.
    pub fn run(&mut self, input: &BitVec) -> BitVec {
        let mut out = BitVec::zeros(input.len());
        for i in 0..input.len() {
            out.set(i, self.step(input.get(i)));
        }
        out
    }

    /// Gate cost: a `log2(K)`-bit saturating counter + comparator.
    pub fn gate_count(&self) -> GateCount {
        let bits = (self.states as f64).log2().ceil() as u64;
        let mut g = GateCount::new();
        g.add(GateKind::Dff, bits);
        g.add(GateKind::Xor2, bits); // increment/decrement logic
        g.add(GateKind::And2, 2 * bits);
        g.add(GateKind::Or2, bits);
        g.depth = bits as f64 + 2.0;
        g
    }

    /// Physical cost.
    pub fn cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }
}

/// FSM-based ReLU cell after [9]: a saturating counter tracks the
/// running estimate of the input sign; when the estimate is positive the
/// input bit passes through, otherwise the cell emits alternating bits
/// (bipolar zero).
#[derive(Clone, Debug)]
pub struct ReluFsm {
    states: u32,
    state: u32,
    phase: bool,
}

impl ReluFsm {
    /// Create with `states` counter states (even).
    pub fn new(states: u32) -> Self {
        assert!(states >= 2 && states % 2 == 0);
        Self { states, state: states / 2, phase: false }
    }

    /// Reset state and output phase.
    pub fn reset(&mut self) {
        self.state = self.states / 2;
        self.phase = false;
    }

    /// Process one bit.
    pub fn step(&mut self, bit: bool) -> bool {
        if bit {
            self.state = (self.state + 1).min(self.states - 1);
        } else {
            self.state = self.state.saturating_sub(1);
        }
        if self.state >= self.states / 2 {
            bit
        } else {
            self.phase = !self.phase;
            self.phase
        }
    }

    /// Run over a stream.
    pub fn run(&mut self, input: &BitVec) -> BitVec {
        let mut out = BitVec::zeros(input.len());
        for i in 0..input.len() {
            out.set(i, self.step(input.get(i)));
        }
        out
    }

    /// Gate cost (counter + mux + toggle).
    pub fn gate_count(&self) -> GateCount {
        let bits = (self.states as f64).log2().ceil() as u64;
        let mut g = GateCount::new();
        g.add(GateKind::Dff, bits + 1);
        g.add(GateKind::Xor2, bits);
        g.add(GateKind::And2, 2 * bits);
        g.add(GateKind::Or2, bits);
        g.add(GateKind::Mux2, 1);
        g.depth = bits as f64 + 2.0;
        g
    }

    /// Physical cost.
    pub fn cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }
}

/// Evaluate an FSM activation over a sweep of input values: for each
/// `x`, encode a stochastic bipolar stream of length `bsl`, run the FSM,
/// decode the output. Returns `(x, y)` pairs — the raw material of
/// Fig 1.
pub fn transfer_curve<F>(
    mut make_fsm: F,
    xs: &[f64],
    bsl: usize,
    seed: u16,
) -> Vec<(f64, f64)>
where
    F: FnMut() -> Box<dyn FnMut(&BitVec) -> BitVec>,
{
    let mut out = Vec::with_capacity(xs.len());
    for (i, &x) in xs.iter().enumerate() {
        let mut sng = Sng::new(seed.wrapping_add(i as u16).max(1));
        let stream = sng.bipolar(x, bsl);
        let mut fsm = make_fsm();
        let y = bipolar_decode(&fsm(&stream));
        out.push((x, y));
    }
    out
}

/// Mean-squared error of a transfer curve against an exact function.
pub fn curve_mse(curve: &[(f64, f64)], exact: impl Fn(f64) -> f64) -> f64 {
    let n = curve.len().max(1) as f64;
    curve.iter().map(|&(x, y)| (y - exact(x)).powi(2)).sum::<f64>() / n
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sweep() -> Vec<f64> {
        (0..41).map(|i| -1.0 + i as f64 * 0.05).collect()
    }

    #[test]
    fn stanh_tracks_tanh_loosely() {
        // The FSM approximates tanh(K/2 x) but with visible error at
        // moderate BSL — that inaccuracy IS the paper's Fig 1 point.
        let xs = sweep();
        let curve = transfer_curve(
            || {
                let mut f = StanhFsm::new(8);
                Box::new(move |b: &BitVec| {
                    f.reset();
                    f.run(b)
                })
            },
            &xs,
            1024,
            0x5A5A,
        );
        let mse = curve_mse(&curve, |x| (4.0 * x).tanh());
        assert!(mse < 0.05, "mse={mse}");
        // And it is *not* exact even at 1024 bits.
        assert!(mse > 1e-6, "FSM should not be exact, mse={mse}");
    }

    #[test]
    fn stanh_saturates_at_extremes() {
        let mut f = StanhFsm::new(8);
        let ones = BitVec::from_bits(&vec![true; 256]);
        let y = bipolar_decode(&f.run(&ones));
        assert!(y > 0.9);
        f.reset();
        let zeros = BitVec::zeros(256);
        let y = bipolar_decode(&f.run(&zeros));
        assert!(y < -0.9);
    }

    #[test]
    fn relu_fsm_shape() {
        // Positive inputs roughly identity, negative inputs near zero —
        // with substantial error at short BSL (Fig 1b).
        let xs = sweep();
        let curve = transfer_curve(
            || {
                let mut f = ReluFsm::new(16);
                Box::new(move |b: &BitVec| {
                    f.reset();
                    f.run(b)
                })
            },
            &xs,
            1024,
            0x1357,
        );
        let mse = curve_mse(&curve, |x| x.max(0.0));
        assert!(mse < 0.1, "mse={mse}");
        // Error grows as BSL shrinks — the latency/accuracy trade-off.
        let short = transfer_curve(
            || {
                let mut f = ReluFsm::new(16);
                Box::new(move |b: &BitVec| {
                    f.reset();
                    f.run(b)
                })
            },
            &xs,
            32,
            0x1357,
        );
        let mse_short = curve_mse(&short, |x| x.max(0.0));
        assert!(mse_short > mse, "short={mse_short} long={mse}");
    }

    #[test]
    fn fsm_output_depends_on_bit_order() {
        // The serial FSM is order-sensitive: a sorted stream and a
        // shuffled stream with the same popcount give different outputs
        // — the root cause of FSM inaccuracy (§II.A).
        let mut f1 = StanhFsm::new(8);
        let mut f2 = StanhFsm::new(8);
        let a = BitVec::from_str01("1111000011110000");
        let b = BitVec::from_str01("1010101010101010");
        let ya = f1.run(&a).popcount();
        let yb = f2.run(&b).popcount();
        assert_ne!(ya, yb);
    }

    #[test]
    fn fsm_cost_is_tiny() {
        let c = StanhFsm::new(16).cost();
        assert!(c.area_um2 < 50.0);
    }
}
