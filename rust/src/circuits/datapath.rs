//! The complete SC convolution datapath (paper Figs 3/6; measured in
//! Fig 2, Table IV, Table V, Fig 13).
//!
//! One output pixel of a conv layer with accumulation width `N`
//! (= `K·K·C_in` products) is computed by:
//!
//! ```text
//!  N × [ternary multiplier]  ──┐
//!                              ├─→ [BSN variant] ─→ [SI] ─→ activation
//!  residual ─→ [re-scale] ────┘        (exact / spatial / spatial-temporal)
//! ```
//!
//! The BSN variant is the paper's §II→§IV progression; everything else
//! is shared. [`ConvDatapath::cost`] rolls up area/delay/energy, and
//! [`ConvDatapath::eval_counts`] gives the exact functional output used
//! by the bit-exact network executor.

use crate::coding::{Ternary, ThermCode};
use crate::cost::{cost_of, Cost};
use super::approx_bsn::ApproxBsn;
use super::bsn::Bsn;
use super::multiplier::TernaryMultiplier;
use super::rescale::RescaleBlock;
use super::si::{ActivationFn, SelectiveInterconnect};
use super::st_bsn::SpatialTemporalBsn;

/// Which accumulator implements the non-linear adder.
#[derive(Clone, Debug)]
pub enum BsnKind {
    /// §II: one exact bitonic network over all bits.
    Exact,
    /// §IV.B: approximate spatial BSN.
    Spatial(ApproxBsn),
    /// §IV.B: spatial-temporal folding.
    SpatialTemporal(SpatialTemporalBsn),
}

/// Static configuration of a conv datapath.
#[derive(Clone, Debug)]
pub struct DatapathConfig {
    /// Number of products accumulated (K·K·C_in).
    pub acc_width: usize,
    /// Activation BSL (weights are always ternary / BSL 2).
    pub act_bsl: usize,
    /// Residual BSL; `None` disables the residual path (§II model).
    pub residual_bsl: Option<usize>,
    /// Output BSL after the SI.
    pub out_bsl: usize,
    /// The accumulator variant.
    pub bsn: BsnKind,
    /// The activation realized by the SI.
    pub activation: ActivationFn,
}

/// An instantiated datapath with its synthesized SI.
#[derive(Clone, Debug)]
pub struct ConvDatapath {
    cfg: DatapathConfig,
    si: SelectiveInterconnect,
    /// Width in bits entering the accumulator (products + residual).
    acc_bits: usize,
}

impl ConvDatapath {
    /// Build and synthesize. Panics if the BSN variant's width does not
    /// match `acc_width·act_bsl (+ residual_bsl)`.
    pub fn new(cfg: DatapathConfig) -> Self {
        let acc_bits = cfg.acc_width * cfg.act_bsl + cfg.residual_bsl.unwrap_or(0);
        let (si_in, divisor) = match &cfg.bsn {
            BsnKind::Exact => (acc_bits, 1usize),
            BsnKind::Spatial(a) => {
                assert_eq!(a.in_width(), acc_bits, "spatial BSN width mismatch");
                (a.out_bsl(), a.scale_divisor())
            }
            BsnKind::SpatialTemporal(st) => {
                assert_eq!(st.total_width(), acc_bits, "ST BSN width mismatch");
                (st.out_bsl(), st.scale_divisor())
            }
        };
        // The SI sees counts at the (possibly divided) accumulator
        // scale; fold the divisor into the activation's input step so
        // the synthesized transfer function is unchanged.
        let act = Self::rescaled_activation(&cfg.activation, divisor as f64);
        let si = SelectiveInterconnect::for_activation(&act, si_in, cfg.out_bsl);
        Self { cfg, si, acc_bits }
    }

    fn rescaled_activation(act: &ActivationFn, divisor: f64) -> ActivationFn {
        match act {
            ActivationFn::Identity => ActivationFn::Identity,
            ActivationFn::Relu { ratio } => ActivationFn::Relu { ratio: ratio * divisor },
            ActivationFn::BnRelu { gamma, beta, ratio } => ActivationFn::BnRelu {
                gamma: *gamma,
                beta: beta / divisor,
                ratio: ratio * divisor,
            },
            ActivationFn::Tanh { gain } => ActivationFn::Tanh { gain: gain * divisor },
            ActivationFn::TwoStep { t1, t2 } => ActivationFn::TwoStep {
                t1: (*t1 as f64 / divisor).round() as usize,
                t2: (*t2 as f64 / divisor).round() as usize,
            },
        }
    }

    /// Configuration.
    pub fn config(&self) -> &DatapathConfig {
        &self.cfg
    }

    /// The synthesized SI.
    pub fn si(&self) -> &SelectiveInterconnect {
        &self.si
    }

    /// Functional evaluation: activations (quantized, in
    /// `[-act_bsl/2, act_bsl/2]`), ternary weights, optional residual
    /// count at residual BSL. Returns the output [`ThermCode`].
    pub fn eval(
        &self,
        acts: &[i64],
        weights: &[Ternary],
        residual: Option<&ThermCode>,
    ) -> ThermCode {
        assert_eq!(acts.len(), self.cfg.acc_width);
        assert_eq!(weights.len(), self.cfg.acc_width);
        let l = self.cfg.act_bsl;
        let mut counts: Vec<usize> = acts
            .iter()
            .zip(weights)
            .map(|(&a, &w)| {
                TernaryMultiplier::mult_therm(&ThermCode::encode(a, l), w).count()
            })
            .collect();
        match (self.cfg.residual_bsl, residual) {
            (Some(rb), Some(r)) => {
                assert_eq!(r.bsl(), rb);
                counts.push(r.count());
            }
            (None, None) => {}
            _ => panic!("residual presence must match the configuration"),
        }
        let out_count = self.accumulate_activate(&counts);
        ThermCode::from_count(out_count, self.cfg.out_bsl)
    }

    /// Count-domain core: accumulate per-product counts through the BSN
    /// variant and apply the SI.
    pub fn accumulate_activate(&self, product_counts: &[usize]) -> usize {
        let acc_count = match &self.cfg.bsn {
            BsnKind::Exact => product_counts.iter().sum(),
            BsnKind::Spatial(a) => {
                let grouped = Self::regroup(product_counts, a.stages()[0].m, self.per_product_bits());
                a.eval_counts(&grouped)
            }
            BsnKind::SpatialTemporal(st) => {
                let m0 = st.inner().stages()[0].m * st.data_cycles();
                let grouped = Self::regroup(product_counts, m0, self.per_product_bits());
                st.eval_counts(&grouped)
            }
        };
        self.si.apply_count(acc_count)
    }

    /// Bits contributed per product-slot (the residual slot is appended
    /// with its own BSL, folded into the last group).
    fn per_product_bits(&self) -> usize {
        self.cfg.act_bsl
    }

    /// Regroup flat per-product counts into `m0` leaf groups of equal
    /// bit width. The residual (if present) rides in the final group;
    /// widths were validated at construction.
    fn regroup(counts: &[usize], m0: usize, _bits_each: usize) -> Vec<usize> {
        let per = counts.len().div_ceil(m0);
        let mut out = vec![0usize; m0];
        for (i, &c) in counts.iter().enumerate() {
            out[(i / per).min(m0 - 1)] += c;
        }
        out
    }

    /// Full cost roll-up: multipliers ∥ (residual re-scale) → BSN → SI.
    pub fn cost(&self) -> Cost {
        let mult = cost_of(
            &TernaryMultiplier::gate_count_lbit(self.cfg.act_bsl)
                .replicate(self.cfg.acc_width as u64),
        );
        let resc = self
            .cfg
            .residual_bsl
            .map(|b| RescaleBlock::new(b.max(16).min(16)).cost())
            .unwrap_or_default();
        let front = mult.parallel(&resc);
        let acc = match &self.cfg.bsn {
            BsnKind::Exact => Bsn::new(self.acc_bits).cost(),
            BsnKind::Spatial(a) => a.cost(),
            BsnKind::SpatialTemporal(st) => st.total_cost(),
        };
        front.series(&acc).series(&self.si.cost())
    }

    /// Accumulator-only cost (what Table V isolates).
    pub fn bsn_cost(&self) -> Cost {
        match &self.cfg.bsn {
            BsnKind::Exact => Bsn::new(self.acc_bits).cost(),
            BsnKind::Spatial(a) => a.cost(),
            BsnKind::SpatialTemporal(st) => st.total_cost(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn relu_path(acc_width: usize, act_bsl: usize, out_bsl: usize) -> ConvDatapath {
        ConvDatapath::new(DatapathConfig {
            acc_width,
            act_bsl,
            residual_bsl: None,
            out_bsl,
            bsn: BsnKind::Exact,
            activation: ActivationFn::Relu { ratio: 1.0 },
        })
    }

    #[test]
    fn exact_path_matches_integer_relu() {
        let mut rng = Rng::new(31);
        let dp = relu_path(9, 2, 16);
        for _ in 0..100 {
            let acts: Vec<i64> = (0..9).map(|_| rng.gen_range_i64(-1, 1)).collect();
            let ws: Vec<Ternary> =
                (0..9).map(|_| Ternary::from_i64(rng.gen_range_i64(-1, 1))).collect();
            let out = dp.eval(&acts, &ws, None);
            let dot: i64 = acts.iter().zip(&ws).map(|(&a, w)| a * w.to_i64()).sum();
            assert_eq!(out.decode(), dot.max(0).min(8), "acts={acts:?}");
        }
    }

    #[test]
    fn residual_adds_into_accumulation() {
        let dp = ConvDatapath::new(DatapathConfig {
            acc_width: 4,
            act_bsl: 2,
            residual_bsl: Some(16),
            out_bsl: 16,
            bsn: BsnKind::Exact,
            activation: ActivationFn::Identity,
        });
        let acts = vec![1i64, 1, -1, 0];
        let ws = vec![Ternary::Pos, Ternary::Pos, Ternary::Pos, Ternary::Pos];
        let res = ThermCode::encode(5, 16);
        let out = dp.eval(&acts, &ws, Some(&res));
        // dot = 1, residual = 5, total q = 6; Identity keeps q (the
        // 24-bit accumulation saturates at the +-8 output range).
        assert_eq!(out.decode(), 6);
    }

    #[test]
    fn spatial_variant_close_to_exact() {
        let mut rng = Rng::new(41);
        let spatial = ApproxBsn::new(vec![
            crate::circuits::ApproxStage {
                m: 8,
                l: 16,
                sub: crate::circuits::SubSample { clip: 0, stride: 1 },
            },
            crate::circuits::ApproxStage {
                m: 1,
                l: 128,
                sub: crate::circuits::SubSample { clip: 32, stride: 1 },
            },
        ]);
        let dp_exact = relu_path(64, 2, 16);
        let dp_approx = ConvDatapath::new(DatapathConfig {
            acc_width: 64,
            act_bsl: 2,
            residual_bsl: None,
            out_bsl: 16,
            bsn: BsnKind::Spatial(spatial),
            activation: ActivationFn::Relu { ratio: 1.0 },
        });
        let mut max_err = 0i64;
        for _ in 0..50 {
            let acts: Vec<i64> = (0..64).map(|_| rng.gen_range_i64(-1, 1)).collect();
            let ws: Vec<Ternary> =
                (0..64).map(|_| Ternary::from_i64(rng.gen_range_i64(-1, 1))).collect();
            let e = dp_exact.eval(&acts, &ws, None).decode();
            let a = dp_approx.eval(&acts, &ws, None).decode();
            max_err = max_err.max((e - a).abs());
        }
        // Clipping at ±32 of a 128-bit accumulation of balanced ternary
        // products almost never saturates.
        assert!(max_err <= 1, "max_err={max_err}");
    }

    #[test]
    fn cost_dominated_by_bsn_for_wide_acc() {
        let dp = relu_path(4608, 2, 16);
        let total = dp.cost();
        let bsn = dp.bsn_cost();
        assert!(bsn.area_um2 / total.area_um2 > 0.5);
    }

    #[test]
    fn wider_act_bsl_costs_more() {
        let c2 = relu_path(256, 2, 16).cost();
        let c8 = relu_path(256, 8, 16).cost();
        assert!(c8.adp() > 2.0 * c2.adp(), "Fig 2's efficiency overhead");
    }
}
