//! The residual re-scaling block (paper §III.C).
//!
//! The high-precision residual and the convolution output carry
//! different trained scale factors `alpha`; before they can be
//! accumulated in one BSN their alphas must match. The paper aligns them
//! by powers of two:
//!
//! * **multiply by 2^N** — replicate the residual bitstream `2^N` times
//!   in the buffer (popcount, and hence the decoded value, scales by
//!   `2^N`);
//! * **divide by 2^N** — per cycle, select 1 out of every 2 bits and
//!   append the 8-bit pattern `11110000` (which decodes to 0) to keep
//!   the BSL constant; repeat for `N` cycles.
//!
//! The division step is cycle-accurate here, including the exact padding
//! pattern, and is exact for even counts (odd counts floor — the same
//! truncation the hardware exhibits).

use crate::coding::{BitVec, ThermCode};
use crate::cost::{cost_of, Cost};
use crate::gates::{GateCount, GateKind};

/// The paper's padding pattern appended per division cycle (decodes to
/// zero: 4 ones in 8 bits).
pub const DIV_PAD: &str = "11110000";

/// Cycle-accurate residual re-scaling block for BSL-16 residuals (the
/// configuration of Table IV's `2-2-16`).
#[derive(Clone, Debug)]
pub struct RescaleBlock {
    bsl: usize,
}

impl RescaleBlock {
    /// Create for a given residual BSL. Division requires `bsl == 16`
    /// (8 selected bits + the 8-bit pad), the paper's configuration;
    /// multiplication works for any BSL.
    pub fn new(bsl: usize) -> Self {
        assert!(bsl >= 2 && bsl % 2 == 0);
        Self { bsl }
    }

    /// Residual BSL.
    pub fn bsl(&self) -> usize {
        self.bsl
    }

    /// Multiply by `2^n`: replicate the stream `2^n` times. Output BSL
    /// is `bsl · 2^n`; decoded value scales exactly by `2^n`.
    pub fn mul_pow2(&self, code: &ThermCode, n: u32) -> ThermCode {
        let mut out = ThermCode::from_bits(BitVec::zeros(0));
        self.mul_pow2_into(code, n, &mut out);
        out
    }

    /// Buffer-reuse variant of [`RescaleBlock::mul_pow2`]: overwrites
    /// `out`, reusing its allocation (the double-buffer register file
    /// the hardware block actually has).
    pub fn mul_pow2_into(&self, code: &ThermCode, n: u32, out: &mut ThermCode) {
        assert_eq!(code.bsl(), self.bsl);
        let reps = 1usize << n;
        let bits = out.bits_mut();
        bits.reset(0);
        for _ in 0..reps {
            bits.extend_from(code.bits());
        }
    }

    /// One division-by-2 cycle: select 1 of every 2 bits (even indices
    /// of the *sorted* stream, so the selected popcount is `ceil(c/2)`),
    /// then append `11110000` to restore the BSL. Requires BSL 16.
    pub fn div2_cycle(&self, code: &ThermCode) -> ThermCode {
        let mut out = ThermCode::from_bits(BitVec::zeros(0));
        self.div2_cycle_into(code, &mut out);
        out
    }

    /// Buffer-reuse variant of [`RescaleBlock::div2_cycle`]. `out` must
    /// not alias `code` (the hardware uses the second buffer of its
    /// double-buffered register file).
    pub fn div2_cycle_into(&self, code: &ThermCode, out: &mut ThermCode) {
        assert_eq!(self.bsl, 16, "the paper's divider pads 8 bits; BSL must be 16");
        assert_eq!(code.bsl(), 16);
        // Select every other bit (even lanes of the sorted stream keep
        // ceil(count/2) ones) with the dispatched even-bit compress
        // (SWAR scalar, `pext` on BMI2 hardware) of the one 16-lane
        // word — bits past lane 15 are zero by the tail invariant, so
        // the 64-lane compress reduces to the 16-lane one — then append
        // the pad pattern as a constant: DIV_PAD = "11110000" occupies
        // lanes 8..11 -> 0x0f00.
        let w = code.bits().as_words()[0];
        let x = crate::util::simd::Dispatch::active().compress_even(w);
        let bits = out.bits_mut();
        bits.reset(16);
        bits.as_mut_words()[0] = x | 0x0f00;
    }

    /// Divide by `2^n`: `n` division cycles.
    pub fn div_pow2(&self, code: &ThermCode, n: u32) -> ThermCode {
        let mut c = code.clone();
        let mut scratch = ThermCode::from_bits(BitVec::zeros(0));
        for _ in 0..n {
            self.div2_cycle_into(&c, &mut scratch);
            std::mem::swap(&mut c, &mut scratch);
        }
        c
    }

    /// Align a residual with scale `2^res_log2` to a target scale
    /// `2^tgt_log2`: multiplies or divides as needed and reports the
    /// number of cycles spent (division is `N` cycles; multiplication is
    /// a buffer copy, 1 cycle).
    pub fn align(
        &self,
        code: &ThermCode,
        res_log2: i32,
        tgt_log2: i32,
    ) -> (ThermCode, u32) {
        // Value = alpha * q with alpha = 2^res_log2. To express the same
        // value at alpha' = 2^tgt_log2 the count must scale by
        // 2^(res_log2 - tgt_log2).
        let shift = res_log2 - tgt_log2;
        if shift >= 0 {
            (self.mul_pow2(code, shift as u32), 1)
        } else {
            let n = (-shift) as u32;
            (self.div_pow2(code, n), n)
        }
    }

    /// Gate cost: a BSL-wide register file (double buffer) plus the
    /// select/append muxing.
    pub fn gate_count(&self) -> GateCount {
        let l = self.bsl as u64;
        let mut g = GateCount::new();
        g.add(GateKind::Dff, 2 * l);
        g.add(GateKind::Mux2, l);
        g.depth = 1.0 + GateKind::Mux2.delay_eq();
        g
    }

    /// Physical cost.
    pub fn cost(&self) -> Cost {
        cost_of(&self.gate_count())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_pattern_decodes_to_zero() {
        let pad = ThermCode::from_bits(BitVec::from_str01(DIV_PAD));
        assert_eq!(pad.decode(), 0);
    }

    #[test]
    fn mul_pow2_scales_value() {
        let r = RescaleBlock::new(16);
        for q in -8i64..=8 {
            let c = ThermCode::encode(q, 16);
            for n in 0..3u32 {
                let m = r.mul_pow2(&c, n);
                assert_eq!(m.decode(), q << n, "q={q} n={n}");
                assert_eq!(m.bsl(), 16 << n);
            }
        }
    }

    #[test]
    fn div2_exact_for_even_counts() {
        let r = RescaleBlock::new(16);
        for q in (-8i64..=8).filter(|q| q % 2 == 0) {
            let c = ThermCode::encode(q, 16);
            let d = r.div2_cycle(&c);
            assert_eq!(d.bsl(), 16);
            assert_eq!(d.decode(), q / 2, "q={q}");
        }
    }

    #[test]
    fn div2_truncates_odd_counts_by_at_most_one_level() {
        let r = RescaleBlock::new(16);
        for q in -8i64..=8 {
            let c = ThermCode::encode(q, 16);
            let d = r.div2_cycle(&c);
            let err = (d.decode() as f64 - q as f64 / 2.0).abs();
            assert!(err <= 0.5, "q={q} err={err}");
        }
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let r = RescaleBlock::new(16);
        let mut out = ThermCode::from_count(0, 16);
        for q in -8i64..=8 {
            let c = ThermCode::encode(q, 16);
            for n in 0..3u32 {
                r.mul_pow2_into(&c, n, &mut out);
                assert_eq!(out, r.mul_pow2(&c, n), "mul q={q} n={n}");
            }
            r.div2_cycle_into(&c, &mut out);
            assert_eq!(out, r.div2_cycle(&c), "div q={q}");
        }
    }

    #[test]
    fn div_pow2_multi_cycle() {
        let r = RescaleBlock::new(16);
        let c = ThermCode::encode(8, 16);
        assert_eq!(r.div_pow2(&c, 2).decode(), 2);
        assert_eq!(r.div_pow2(&c, 3).decode(), 1);
    }

    #[test]
    fn align_reports_cycles() {
        let r = RescaleBlock::new(16);
        let c = ThermCode::encode(4, 16);
        // Residual at alpha=2^0, conv at 2^-2: count must scale by 4.
        let (up, cyc) = r.align(&c, 0, -2);
        assert_eq!(cyc, 1);
        assert_eq!(up.decode(), 16);
        // Residual at 2^0, conv at 2^2: divide by 4 over 2 cycles.
        let (down, cyc) = r.align(&c, 0, 2);
        assert_eq!(cyc, 2);
        assert_eq!(down.decode(), 1);
        assert_eq!(down.bsl(), 16);
    }
}
