//! L3 inference coordinator: request queue → dynamic batcher → PJRT
//! worker.
//!
//! The paper's contribution is the accelerator itself, so the
//! coordinator is the thin-but-real serving layer around it: clients
//! submit single images, the batcher coalesces them into the fixed
//! batch the AOT-compiled executable expects (padding the tail), a
//! worker thread executes the serving-path HLO (integer codes through
//! the Pallas kernel), and per-request latency / batch-occupancy
//! metrics are tracked. No async runtime is available offline, so the
//! design is the classic thread + channel dynamic batcher (the same
//! shape as vLLM's router).

pub mod batcher;
pub mod metrics;

pub use batcher::{BatchPolicy, Coordinator, InferenceClient, ServeConfig};
pub use metrics::{MetricsSnapshot, ServerMetrics};
