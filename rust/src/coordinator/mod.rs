//! L3 inference coordinator: sharded request queue → adaptive dynamic
//! batcher → a pool of executor-owning workers.
//!
//! The paper's contribution is the accelerator itself, so the
//! coordinator is the serving layer that keeps the datapath fed:
//! clients submit single images, the pool coalesces them into the
//! fixed batches the AOT-compiled executable expects (padding the
//! tail), `N` worker threads execute the serving-path HLO (integer
//! codes through the Pallas kernel), and per-request latency /
//! batch-occupancy / shedding metrics are tracked per worker and
//! aggregated. No async runtime is available offline, so the design is
//! the classic thread + bounded-channel dynamic batcher (the same
//! shape as vLLM's router), sharded one queue per worker.
//!
//! Layering (see `docs/SERVING.md` for every knob and field):
//!
//! * [`backend`] — the unified [`Backend`] registry
//!   (`auto|pjrt|synthetic|sc|binary`): one name → one
//!   [`ExecutorFactory`], shared by the CLI, examples and benches.
//! * [`executor`] — the backend seam: [`BatchExecutor`] +
//!   [`ExecutorFactory`] (PJRT handles are not `Send`, so each worker
//!   builds its own backend in-thread), with [`PjrtExecutor`] for the
//!   AOT serving path, [`ScBatchExecutor`] for the native bit-exact SC
//!   engine, [`BinaryBatchExecutor`] for the fixed-point baseline and
//!   [`SyntheticExecutor`] for tests/benches.
//! * [`batcher`] — the pool: [`Coordinator`], [`InferenceClient`],
//!   [`BatchPolicy`] (adaptive hold time), [`OverloadPolicy`]
//!   (backpressure vs load shedding), [`ServeConfig`]/[`PoolConfig`].
//! * [`metrics`] — [`ServerMetrics`] per worker, aggregated into one
//!   [`MetricsSnapshot`] with fixed-bucket latency histograms and a
//!   Prometheus text exposition.
//! * [`registry`] — [`ModelRegistry`]: several named models behind one
//!   front-end (hot add/swap, per-model pools) plus per-tenant
//!   admission control ([`TenantPolicy`], [`Priority`]).
//! * [`net`] — the TCP front-end: a length-prefixed binary protocol
//!   over `std::net` ([`NetServer`], [`NetClient`], [`FrameReader`]),
//!   one acceptor thread + per-connection reader threads feeding the
//!   registry's pools.
//! * [`chaos`] — fault injection for tests/benches: [`ChaosSwitch`] +
//!   [`chaos_factory`] crash workers at a configurable rate, plus
//!   byte-level connection chaos helpers.
//!
//! Fault tolerance runs through every layer: workers are supervised
//! (`catch_unwind` + respawn up to [`PoolConfig::restart_budget`],
//! panics surfaced as [`WORKER_PANIC_ERROR`]), requests carry
//! deadlines end-to-end (shed as [`DEADLINE_EXPIRED_ERROR`], checked
//! at dequeue and batch admission), and [`NetClient`] never hangs
//! (timeouts + [`RetryPolicy`] with jittered backoff on idempotent
//! calls). See `docs/SERVING.md` §Failure model.

pub mod backend;
pub mod batcher;
pub mod chaos;
pub mod executor;
pub mod metrics;
pub mod net;
pub mod registry;

pub use backend::Backend;
pub use batcher::{
    is_deadline_error, is_shed_error, is_worker_panic_error, BatchPolicy, Coordinator,
    InferenceClient, OverloadPolicy, PoolConfig, ServeConfig, DEADLINE_EXPIRED_ERROR,
    DEFAULT_RESTART_BUDGET, SHED_ERROR, WORKER_PANIC_ERROR,
};
pub use chaos::{chaos_factory, ChaosSwitch, CHAOS_PANIC};
pub use executor::{
    BatchExecutor, BinaryBatchExecutor, ExecutorFactory, ExecutorSpec, PjrtExecutor,
    ScBatchExecutor, SyntheticExecutor,
};
pub use metrics::{
    prometheus_text, LatencyHistogram, MetricsSnapshot, PoolCounters, ServerMetrics, WorkerCounts,
};
pub use net::{
    is_timeout_error, Frame, FrameReader, InferRequest, InferResponse, NetClient, NetServer,
    RetryPolicy, Status, TIMEOUT_ERROR,
};
pub use registry::{ModelEntry, ModelRegistry, Priority, TenantCounters, TenantPolicy};
