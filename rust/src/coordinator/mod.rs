//! L3 inference coordinator: sharded request queue → adaptive dynamic
//! batcher → a pool of executor-owning workers.
//!
//! The paper's contribution is the accelerator itself, so the
//! coordinator is the serving layer that keeps the datapath fed:
//! clients submit single images, the pool coalesces them into the
//! fixed batches the AOT-compiled executable expects (padding the
//! tail), `N` worker threads execute the serving-path HLO (integer
//! codes through the Pallas kernel), and per-request latency /
//! batch-occupancy / shedding metrics are tracked per worker and
//! aggregated. No async runtime is available offline, so the design is
//! the classic thread + bounded-channel dynamic batcher (the same
//! shape as vLLM's router), sharded one queue per worker.
//!
//! Layering (see `docs/SERVING.md` for every knob and field):
//!
//! * [`backend`] — the unified [`Backend`] registry
//!   (`auto|pjrt|synthetic|sc|binary`): one name → one
//!   [`ExecutorFactory`], shared by the CLI, examples and benches.
//! * [`executor`] — the backend seam: [`BatchExecutor`] +
//!   [`ExecutorFactory`] (PJRT handles are not `Send`, so each worker
//!   builds its own backend in-thread), with [`PjrtExecutor`] for the
//!   AOT serving path, [`ScBatchExecutor`] for the native bit-exact SC
//!   engine, [`BinaryBatchExecutor`] for the fixed-point baseline and
//!   [`SyntheticExecutor`] for tests/benches.
//! * [`batcher`] — the pool: [`Coordinator`], [`InferenceClient`],
//!   [`BatchPolicy`] (adaptive hold time), [`OverloadPolicy`]
//!   (backpressure vs load shedding), [`ServeConfig`]/[`PoolConfig`].
//! * [`metrics`] — [`ServerMetrics`] per worker, aggregated into one
//!   [`MetricsSnapshot`] with fixed-bucket latency histograms and a
//!   Prometheus text exposition.
//! * [`registry`] — [`ModelRegistry`]: several named models behind one
//!   front-end (hot add/swap, per-model pools) plus per-tenant
//!   admission control ([`TenantPolicy`], [`Priority`]).
//! * [`net`] — the TCP front-end: a length-prefixed binary protocol
//!   over `std::net` ([`NetServer`], [`NetClient`], [`FrameReader`]),
//!   one acceptor thread + per-connection reader threads feeding the
//!   registry's pools.

pub mod backend;
pub mod batcher;
pub mod executor;
pub mod metrics;
pub mod net;
pub mod registry;

pub use backend::Backend;
pub use batcher::{
    is_shed_error, BatchPolicy, Coordinator, InferenceClient, OverloadPolicy, PoolConfig,
    ServeConfig, SHED_ERROR,
};
pub use executor::{
    BatchExecutor, BinaryBatchExecutor, ExecutorFactory, ExecutorSpec, PjrtExecutor,
    ScBatchExecutor, SyntheticExecutor,
};
pub use metrics::{prometheus_text, LatencyHistogram, MetricsSnapshot, ServerMetrics, WorkerCounts};
pub use net::{Frame, FrameReader, InferRequest, InferResponse, NetClient, NetServer, Status};
pub use registry::{ModelEntry, ModelRegistry, Priority, TenantCounters, TenantPolicy};
