//! Multi-worker inference pool with adaptive batching and
//! backpressure.
//!
//! Requests (single images) enter a **sharded queue**: one bounded
//! channel per worker, round-robin on submit with overflow spilling to
//! the next shard. Each worker thread owns its own [`BatchExecutor`]
//! (built in-thread via an [`ExecutorFactory`], because PJRT handles
//! are not `Send`), drains its shard into the executor's fixed batch —
//! padding the tail with zeros — executes once, and fans the logits
//! back out. Per-worker [`ServerMetrics`] aggregate into one
//! [`super::MetricsSnapshot`].
//!
//! Batching is **adaptive**: a worker tracks an EWMA of its batch
//! occupancy and scales the hold time between [`BatchPolicy::min_wait`]
//! (light traffic → don't add latency waiting for co-riders that are
//! not coming) and [`BatchPolicy::max_wait`] (heavy traffic → amortize
//! the fixed batch cost; under load the batch fills long before the
//! deadline anyway).
//!
//! Backpressure is explicit: every shard channel is bounded by
//! [`ServeConfig::queue_depth`]. When all shards are full the
//! [`OverloadPolicy`] decides between blocking the client
//! ([`OverloadPolicy::Block`]) and shedding the request with an error
//! ([`OverloadPolicy::Shed`]).
//!
//! Shutdown is graceful: [`Coordinator::shutdown`] signals stop,
//! workers drain every queued request into final batches, and the call
//! joins them before returning the last snapshot.
//!
//! Faults are supervised: the executor call of every batch runs under
//! [`std::panic::catch_unwind`], so a panicking model fails exactly
//! the requests of that batch — each with a typed
//! [`WORKER_PANIC_ERROR`] instead of a hung client — and the worker
//! rebuilds its executor from the shared [`ExecutorFactory`] up to
//! [`PoolConfig::restart_budget`] respawns before giving up its
//! shard. A worker that dies for good leaves the pool degraded
//! ([`Coordinator::healthy`] turns false) but still serving on the
//! surviving shards.
//!
//! Deadlines are enforced pool-side: a request may carry one
//! ([`InferenceClient::infer_within`]), and workers check it at
//! dequeue and again at batch admission, shedding expired work with a
//! typed [`DEADLINE_EXPIRED_ERROR`] and a distinct
//! `deadline_expired` counter rather than spending executor time on
//! an answer nobody is waiting for.

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::fault::guard::GuardCounters;
use crate::nn::SparsityCounters;
use crate::runtime::trainer::Knobs;
use crate::Result;
use anyhow::Context;

use super::backend::Backend;
use super::executor::{BatchExecutor, ExecutorFactory, ExecutorSpec};
use super::metrics::ServerMetrics;

/// What to do with a request when every shard queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Block the submitting client until its shard has room
    /// (backpressure propagates to the caller).
    Block,
    /// Fail fast: return an error to the client and count the request
    /// in [`super::MetricsSnapshot::shed`].
    Shed,
}

/// Batching policy of each pool worker.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max time to hold an open batch after its first request.
    pub max_wait: Duration,
    /// Hold time floor used when traffic is light (adaptive mode).
    pub min_wait: Duration,
    /// Scale the hold time with observed batch occupancy; `false`
    /// always holds for `max_wait`.
    pub adaptive: bool,
    /// Behavior when every shard queue is full.
    pub overload: OverloadPolicy,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            min_wait: Duration::from_micros(250),
            adaptive: true,
            overload: OverloadPolicy::Block,
        }
    }
}

impl BatchPolicy {
    /// The hold time for the next batch given the worker's occupancy
    /// EWMA in `[0, 1]`: interpolates `min_wait..=max_wait` when
    /// [`BatchPolicy::adaptive`], else returns `max_wait`.
    pub fn effective_wait(&self, occupancy_ewma: f64) -> Duration {
        if !self.adaptive {
            return self.max_wait;
        }
        let lo = self.min_wait.min(self.max_wait);
        lo + (self.max_wait - lo).mul_f64(occupancy_ewma.clamp(0.0, 1.0))
    }
}

struct Request {
    x: Vec<f32>,
    t0: Instant,
    /// Absolute point after which the pool sheds instead of executes
    /// (`None` = wait forever, the pre-deadline behavior).
    deadline: Option<Instant>,
    resp: mpsc::SyncSender<Result<Vec<f32>>>,
}

impl Request {
    /// True once the request's deadline (if any) has passed.
    fn expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now > d)
    }
}

/// State shared by the coordinator, its clients and its workers.
struct Shared {
    stop: AtomicBool,
    shed: AtomicU64,
    rr: AtomicUsize,
    inflight: AtomicUsize,
    inflight_peak: AtomicUsize,
    /// Executor panics caught by worker supervision.
    worker_panics: AtomicU64,
    /// Executors rebuilt after a caught panic.
    worker_respawns: AtomicU64,
    /// Requests shed because their deadline passed while queued.
    deadline_expired: AtomicU64,
    /// Worker threads currently serving their shard.
    live_workers: AtomicUsize,
}

impl Shared {
    fn new() -> Self {
        Self {
            stop: AtomicBool::new(false),
            shed: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicUsize::new(0),
            worker_panics: AtomicU64::new(0),
            worker_respawns: AtomicU64::new(0),
            deadline_expired: AtomicU64::new(0),
            live_workers: AtomicUsize::new(0),
        }
    }

    /// Bump the in-flight gauge before the request becomes visible to
    /// a worker; returns the observed level for [`Shared::note_admitted`].
    fn note_submitting(&self) -> usize {
        self.inflight.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Publish the peak only for requests that were actually admitted,
    /// so a burst of shed attempts cannot inflate `inflight_peak`.
    fn note_admitted(&self, observed: usize) {
        self.inflight_peak.fetch_max(observed, Ordering::Relaxed);
    }

    fn note_done(&self, n: usize) {
        self.inflight.fetch_sub(n, Ordering::Relaxed);
    }
}

/// Client handle: submit images, receive logits. Cheap to clone; any
/// number of threads may hold one.
#[derive(Clone)]
pub struct InferenceClient {
    shards: Vec<mpsc::SyncSender<Request>>,
    shared: Arc<Shared>,
    overload: OverloadPolicy,
    image_len: usize,
    classes: usize,
}

impl InferenceClient {
    /// Blocking inference of one image (CHW flat). Returns logits.
    ///
    /// ```
    /// use std::time::Duration;
    /// use scnn::coordinator::{Coordinator, ExecutorSpec, PoolConfig, SyntheticExecutor};
    ///
    /// # fn main() -> scnn::Result<()> {
    /// let spec = ExecutorSpec { image_len: 4, batch: 2, classes: 3 };
    /// let factory = SyntheticExecutor::factory(spec, Duration::ZERO);
    /// let pool = PoolConfig { workers: 2, ..PoolConfig::default() };
    /// let coord = Coordinator::start_with(factory, pool)?;
    /// let logits = coord.client().infer(vec![0.25; 4])?;
    /// assert_eq!(logits.len(), 3);
    /// coord.shutdown();
    /// # Ok(())
    /// # }
    /// ```
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.infer_within(x, None)
    }

    /// Blocking inference with a deadline: after `timeout` the pool
    /// sheds the request (typed [`DEADLINE_EXPIRED_ERROR`], counted in
    /// [`super::MetricsSnapshot::deadline_expired`]) instead of
    /// executing it. `None` waits forever, like
    /// [`InferenceClient::infer`]. The call itself never outlives the
    /// deadline by more than a fixed grace period, even against a
    /// wedged pool.
    pub fn infer_within(&self, x: Vec<f32>, timeout: Option<Duration>) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.image_len, "image length mismatch");
        let deadline = timeout.map(|t| Instant::now() + t);
        let (tx, rx) = mpsc::sync_channel(1);
        self.submit(Request { x, t0: Instant::now(), deadline, resp: tx })?;
        let received = match deadline {
            None => rx.recv().map_err(|_| RecvTimeoutError::Disconnected),
            // Workers shed expired work themselves, so the verdict
            // (logits or the typed expiry) normally arrives promptly;
            // waiting a grace past the deadline only guards against a
            // wedged pool and keeps "no caller ever hangs" true
            // unconditionally.
            Some(d) => {
                rx.recv_timeout(d.saturating_duration_since(Instant::now()) + DEADLINE_GRACE)
            }
        };
        match received {
            Ok(result) => result,
            Err(RecvTimeoutError::Timeout) => {
                // Abandon the response channel; the worker still owns
                // the request and accounts for it (shed or executed)
                // when it gets there, so the gauge is not repaired
                // here.
                anyhow::bail!("{} (no verdict within deadline + grace)", DEADLINE_EXPIRED_ERROR);
            }
            Err(RecvTimeoutError::Disconnected) => {
                // The response channel died without an answer: the
                // request raced a shutdown past the worker's final
                // drain (or the worker died). Either way it is
                // terminally done — repair the gauge and report the
                // shutdown as such, honoring the drain invariant.
                self.shared.note_done(1);
                if self.shared.stop.load(Ordering::Relaxed) {
                    anyhow::bail!("coordinator stopped");
                }
                anyhow::bail!("coordinator dropped the request");
            }
        }
    }

    /// Classify one image (argmax over [`InferenceClient::infer`]).
    ///
    /// ```
    /// use std::time::Duration;
    /// use scnn::coordinator::{Coordinator, ExecutorSpec, PoolConfig, SyntheticExecutor};
    ///
    /// # fn main() -> scnn::Result<()> {
    /// let spec = ExecutorSpec { image_len: 4, batch: 2, classes: 3 };
    /// let factory = SyntheticExecutor::factory(spec, Duration::ZERO);
    /// let coord = Coordinator::start_with(factory, PoolConfig::default())?;
    /// let class = coord.client().classify(vec![1.0, 0.0, 0.5, 0.25])?;
    /// assert!(class < 3);
    /// # Ok(())
    /// # }
    /// ```
    pub fn classify(&self, x: Vec<f32>) -> Result<usize> {
        let logits = self.infer(x)?;
        Ok(logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0))
    }

    /// Number of classes served.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Flattened image length (C·H·W floats) one request must carry —
    /// the shape contract network front-ends validate before
    /// submitting.
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Number of pool workers behind this client.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Route one request: round-robin over the shards, spilling to the
    /// next shard when the preferred one is full; when every shard is
    /// full, apply the [`OverloadPolicy`].
    fn submit(&self, req: Request) -> Result<()> {
        if self.shared.stop.load(Ordering::Relaxed) {
            anyhow::bail!("coordinator stopped");
        }
        let n = self.shards.len();
        let start = self.shared.rr.fetch_add(1, Ordering::Relaxed) % n;
        // Count the request as in-flight *before* it becomes visible to
        // any worker: otherwise a fast worker could decrement first and
        // underflow the gauge. Undone on every rejection path below;
        // the peak is only published on successful admission.
        let observed = self.shared.note_submitting();
        let mut req = req;
        // A disconnected shard (dead worker) is skipped like a full
        // one: the pool degrades to the surviving workers and only
        // reports a stop once every shard is gone.
        let mut first_full: Option<usize> = None;
        for k in 0..n {
            let shard = (start + k) % n;
            match self.shards[shard].try_send(req) {
                Ok(()) => {
                    self.shared.note_admitted(observed);
                    return Ok(());
                }
                Err(TrySendError::Full(r)) => {
                    first_full.get_or_insert(shard);
                    req = r;
                }
                Err(TrySendError::Disconnected(r)) => req = r,
            }
        }
        let Some(full) = first_full else {
            self.shared.note_done(1);
            anyhow::bail!("coordinator stopped");
        };
        match self.overload {
            OverloadPolicy::Block => match self.shards[full].send(req) {
                Ok(()) => {
                    self.shared.note_admitted(observed);
                    Ok(())
                }
                Err(_) => {
                    self.shared.note_done(1);
                    anyhow::bail!("coordinator stopped");
                }
            },
            OverloadPolicy::Shed => {
                self.shared.note_done(1);
                self.shared.shed.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("{} ({n} shard queues full)", SHED_ERROR);
            }
        }
    }
}

/// Everything a pool worker needs to build its serving stack, for any
/// [`Backend`]. PJRT workers consume `artifacts`/`params`; the native
/// `sc`/`binary` backends freeze the model from `model`/`knobs`/`seed`
/// and batch at `batch`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Artifacts directory.
    pub artifacts: String,
    /// Model name (artifact prefix): `tnn`, `scnet10`, `scnet20`.
    pub model: String,
    /// Trained parameters to install in PJRT workers (None = exported
    /// init).
    pub params: Option<Vec<Vec<f32>>>,
    /// Quantization knobs for the serving path.
    pub knobs: Knobs,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Per-shard request queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Number of pool workers, each owning its executor.
    pub workers: usize,
    /// Deterministic init seed for the native `sc`/`binary` backends
    /// (the frozen model is a pure function of `(model, knobs, seed)`).
    pub seed: u64,
    /// Batch capacity of one native-backend execution.
    pub batch: usize,
    /// Intra-engine threads of one `sc`-backend execution: each worker
    /// shards batch rows × output-channel blocks across this many
    /// scoped threads inside `nn::ScEngine` (bit-identical logits at
    /// any value; single-row batches fall back to channel-block
    /// sharding so the threads still cut latency). Total serving
    /// threads scale as `workers × threads`.
    pub threads: usize,
    /// Executor respawns each worker may spend recovering from caught
    /// panics before it gives up its shard (see
    /// [`PoolConfig::restart_budget`]).
    pub restart_budget: usize,
    /// Attach the count-domain [`crate::fault::guard::DatapathGuard`]
    /// to the native `sc` backend: every GEMM row block is
    /// checksum-verified and scalar-re-executed on violation, with
    /// detections/recoveries reported through the pool metrics
    /// (`scnn serve --guard`). Other backends ignore it.
    pub guard: bool,
}

impl ServeConfig {
    /// Defaults for a model.
    pub fn new(artifacts: &str, model: &str) -> Self {
        Self {
            artifacts: artifacts.to_string(),
            model: model.to_string(),
            params: None,
            knobs: Knobs::quantized(2),
            policy: BatchPolicy::default(),
            queue_depth: 1024,
            workers: 1,
            seed: 42,
            batch: 8,
            threads: 1,
            restart_budget: DEFAULT_RESTART_BUDGET,
            guard: false,
        }
    }
}

/// Backend-agnostic pool sizing/policy (what [`ServeConfig`] reduces
/// to once the PJRT-specific fields became an [`ExecutorFactory`]).
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Number of worker threads (each with its own shard + executor).
    pub workers: usize,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Per-shard request queue depth (backpressure bound).
    pub queue_depth: usize,
    /// How many times one worker may rebuild its executor after a
    /// caught panic before giving up its shard. `0` means a single
    /// panic retires the worker; the pool keeps serving on whatever
    /// shards survive.
    pub restart_budget: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self {
            workers: 1,
            policy: BatchPolicy::default(),
            queue_depth: 1024,
            restart_budget: DEFAULT_RESTART_BUDGET,
        }
    }
}

/// How often an idle worker re-checks the stop flag.
const IDLE_POLL: Duration = Duration::from_millis(20);

/// Default [`PoolConfig::restart_budget`]: generous enough to ride
/// out a flaky model, small enough that a deterministically-crashing
/// one retires its workers instead of burning CPU on rebuilds.
pub const DEFAULT_RESTART_BUDGET: usize = 3;

/// How long past its deadline [`InferenceClient::infer_within`] waits
/// for the pool's verdict before abandoning the response channel.
/// Workers answer expired requests with the typed shed error as soon
/// as they reach them, so this bound only matters against a wedged
/// pool.
const DEADLINE_GRACE: Duration = Duration::from_secs(1);

/// Marker prefix of load-shedding rejections (see [`is_shed_error`]).
pub const SHED_ERROR: &str = "overloaded: request shed";

/// Marker prefix of requests failed by a supervised executor panic
/// (see [`is_worker_panic_error`]).
pub const WORKER_PANIC_ERROR: &str = "worker panicked: request failed";

/// Marker prefix of requests shed because their deadline passed (see
/// [`is_deadline_error`]).
pub const DEADLINE_EXPIRED_ERROR: &str = "deadline expired: request shed";

/// True when an [`InferenceClient::infer`]/`classify` error is a
/// load-shedding rejection ([`OverloadPolicy::Shed`]) rather than a
/// real failure. Callers should use this instead of matching error
/// text themselves.
pub fn is_shed_error(e: &anyhow::Error) -> bool {
    format!("{e}").starts_with(SHED_ERROR)
}

/// True when an error reports the supervised panic of the worker that
/// held the request. The request did not execute to completion;
/// retrying on another connection (or after the respawn) is safe.
pub fn is_worker_panic_error(e: &anyhow::Error) -> bool {
    format!("{e}").starts_with(WORKER_PANIC_ERROR)
}

/// True when an error reports a deadline-expired shed — the pool
/// never executed the request (distinct from overload sheds, see
/// [`is_shed_error`], and from admission sheds, which also use the
/// [`SHED_ERROR`] marker).
pub fn is_deadline_error(e: &anyhow::Error) -> bool {
    format!("{e}").starts_with(DEADLINE_EXPIRED_ERROR)
}

/// Why a worker's serve loop returned to its supervisor.
enum WorkerExit {
    /// Stop was signaled (drain done) or every sender disconnected.
    Clean,
    /// The executor panicked mid-batch; its state is suspect and must
    /// be rebuilt before serving again.
    Panicked,
}

/// Best-effort text of a caught panic payload (the `&str`/`String`
/// payloads `panic!` produces; anything else gets a placeholder).
fn panic_message(payload: &(dyn std::any::Any + Send)) -> &str {
    if let Some(s) = payload.downcast_ref::<&'static str>() {
        s
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.as_str()
    } else {
        "non-string panic payload"
    }
}

/// The running pool (owns the worker threads).
pub struct Coordinator {
    client: InferenceClient,
    workers: Vec<JoinHandle<()>>,
    metrics: Vec<Arc<ServerMetrics>>,
    shared: Arc<Shared>,
    batch: usize,
    /// Integrity counters of the datapath guard, when
    /// [`ServeConfig::guard`] armed one on the backend.
    guard: Option<Arc<GuardCounters>>,
    /// Activation-sparsity telemetry of the SC backend's sparse GEMM
    /// routing (always armed by [`Coordinator::start_backend`]; `None`
    /// for pools started straight from a factory).
    sparsity: Option<Arc<SparsityCounters>>,
}

impl Coordinator {
    /// Start a pool over a named [`Backend`] — the single entry point
    /// the CLI, examples and benches share. `Backend::Auto` resolves
    /// against the artifact store; every other backend is taken
    /// literally. Blocks until every worker has built its executor.
    pub fn start_backend(backend: Backend, cfg: ServeConfig) -> Result<Self> {
        let pool = PoolConfig {
            workers: cfg.workers,
            policy: cfg.policy,
            queue_depth: cfg.queue_depth,
            restart_budget: cfg.restart_budget,
        };
        let guard = cfg.guard.then(|| Arc::new(GuardCounters::default()));
        // Sparsity telemetry costs four relaxed atomic adds per batch,
        // so it is always armed; non-SC backends simply never tick it.
        let sparsity = Some(Arc::new(SparsityCounters::default()));
        let factory = backend.factory_with(cfg, guard.clone(), sparsity.clone())?;
        let mut coord = Self::start_with(factory, pool)?;
        coord.guard = guard;
        coord.sparsity = sparsity;
        Ok(coord)
    }

    /// Start a PJRT-backed pool; blocks until every worker has
    /// compiled its executables and is ready to serve (or any failed).
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        Self::start_backend(Backend::Pjrt, cfg)
    }

    /// Start with automatic backend selection: the PJRT serving path
    /// when the model's AOT artifacts exist, else the synthetic demo
    /// backend shaped `(image_len, classes)` (for callers whose model
    /// is not in the registry; registry models can just use
    /// [`Coordinator::start_backend`] with [`Backend::Auto`]).
    pub fn start_auto(cfg: ServeConfig, fallback: (usize, usize)) -> Result<Self> {
        if crate::runtime::artifacts_ready(&cfg.artifacts, &cfg.model) {
            Self::start(cfg)
        } else {
            let pool = PoolConfig {
                workers: cfg.workers,
                policy: cfg.policy,
                queue_depth: cfg.queue_depth,
                restart_budget: cfg.restart_budget,
            };
            let (image_len, classes) = fallback;
            Self::start_with(super::SyntheticExecutor::demo_factory(image_len, classes), pool)
        }
    }

    /// Start a pool over any executor backend. Blocks until every
    /// worker has built its executor; fails if any worker fails or if
    /// workers disagree on the [`ExecutorSpec`].
    pub fn start_with(factory: ExecutorFactory, pool: PoolConfig) -> Result<Self> {
        let n = pool.workers.max(1);
        let factory = Arc::new(factory);
        let shared = Arc::new(Shared::new());
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<ExecutorSpec>>(n);
        let mut shards = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        let mut metrics = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Request>(pool.queue_depth.max(1));
            shards.push(tx);
            let m = Arc::new(ServerMetrics::new());
            metrics.push(m.clone());
            let factory = factory.clone();
            let shared = shared.clone();
            let ready_tx = ready_tx.clone();
            let policy = pool.policy;
            let restart_budget = pool.restart_budget;
            let handle = std::thread::Builder::new()
                .name(format!("scnn-worker-{w}"))
                .spawn(move || match (factory.as_ref())(w) {
                    Ok(exec) => {
                        // Count the worker live *before* reporting
                        // ready, so `healthy()` is true the moment
                        // `start_with` returns.
                        shared.live_workers.fetch_add(1, Ordering::Relaxed);
                        let _ = ready_tx.send(Ok(exec.spec()));
                        drop(ready_tx);
                        Self::supervise(
                            w,
                            exec,
                            &factory,
                            policy,
                            restart_budget,
                            &rx,
                            &m,
                            &shared,
                        );
                        shared.live_workers.fetch_sub(1, Ordering::Relaxed);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                })
                .context("spawning pool worker thread")?;
            workers.push(handle);
        }
        drop(ready_tx);
        let mut spec: Option<ExecutorSpec> = None;
        for _ in 0..n {
            let s = ready_rx.recv().context("worker died during setup")??;
            match spec {
                None => spec = Some(s),
                Some(prev) => anyhow::ensure!(
                    prev == s,
                    "workers disagree on executor spec: {prev:?} vs {s:?}"
                ),
            }
        }
        let Some(spec) = spec else {
            anyhow::bail!("no worker reported ready");
        };
        let client = InferenceClient {
            shards,
            shared: shared.clone(),
            overload: pool.policy.overload,
            image_len: spec.image_len,
            classes: spec.classes,
        };
        Ok(Self {
            client,
            workers,
            metrics,
            shared,
            batch: spec.batch,
            guard: None,
            sparsity: None,
        })
    }

    /// Run one worker under supervision: serve until the loop exits
    /// cleanly, and after a caught panic rebuild the executor from the
    /// factory — up to `restart_budget` respawns — and keep serving
    /// the same shard. The shard receiver stays alive across respawns,
    /// so queued requests survive the executor they were queued
    /// behind; only budget exhaustion (or a failing factory)
    /// disconnects the shard, degrading the pool to its surviving
    /// workers.
    #[allow(clippy::too_many_arguments)]
    fn supervise(
        w: usize,
        mut exec: Box<dyn BatchExecutor>,
        factory: &ExecutorFactory,
        policy: BatchPolicy,
        restart_budget: usize,
        rx: &mpsc::Receiver<Request>,
        metrics: &ServerMetrics,
        shared: &Shared,
    ) {
        let mut respawns = 0usize;
        loop {
            // The catch_unwind around the whole loop is a backstop for
            // panics outside the executor call (which has its own,
            // per-batch catch in `execute_batch`): clients of requests
            // dropped mid-unwind see a closed channel, not a hang.
            let exit = catch_unwind(AssertUnwindSafe(|| {
                Self::worker_loop(exec.as_mut(), policy, rx, metrics, shared)
            }));
            match exit {
                Ok(WorkerExit::Clean) => break,
                Ok(WorkerExit::Panicked) | Err(_) => {
                    shared.worker_panics.fetch_add(1, Ordering::Relaxed);
                    if respawns >= restart_budget {
                        break;
                    }
                    // The unwound executor's state is suspect; rebuild
                    // from scratch. A factory that fails (or panics)
                    // retires the worker on the spot.
                    match catch_unwind(AssertUnwindSafe(|| (factory)(w))) {
                        Ok(Ok(fresh)) => {
                            exec = fresh;
                            respawns += 1;
                            shared.worker_respawns.fetch_add(1, Ordering::Relaxed);
                        }
                        Ok(Err(_)) | Err(_) => break,
                    }
                }
            }
        }
    }

    /// One worker: batch its shard queue into the executor until the
    /// pool stops (then drain) or every sender disappears.
    fn worker_loop(
        exec: &mut dyn BatchExecutor,
        policy: BatchPolicy,
        rx: &mpsc::Receiver<Request>,
        metrics: &ServerMetrics,
        shared: &Shared,
    ) -> WorkerExit {
        let spec = exec.spec();
        // Start pessimistic (assume load) so cold-start bursts batch well.
        let mut occupancy_ewma = 1.0f64;
        'serve: loop {
            // Block for the first request, re-checking stop while idle.
            let first = loop {
                match rx.recv_timeout(IDLE_POLL) {
                    // Dequeue-time deadline check: expired work is
                    // shed before it can seed (and hold open) a batch.
                    Ok(r) if r.expired(Instant::now()) => Self::shed_expired(r, shared),
                    Ok(r) => break r,
                    Err(RecvTimeoutError::Timeout) => {
                        if shared.stop.load(Ordering::Relaxed) {
                            break 'serve;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break 'serve,
                }
            };
            let mut pending = Vec::with_capacity(spec.batch);
            pending.push(first);
            // Drain whatever is already queued, free of charge.
            while pending.len() < spec.batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            // Hold the batch open for the adaptive wait.
            if pending.len() < spec.batch {
                let deadline = Instant::now() + policy.effective_wait(occupancy_ewma);
                while pending.len() < spec.batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(_) => break,
                    }
                }
            }
            occupancy_ewma = 0.8 * occupancy_ewma
                + 0.2 * (pending.len() as f64 / spec.batch.max(1) as f64);
            if !Self::execute_batch(exec, &spec, pending, metrics, shared) {
                return WorkerExit::Panicked;
            }
        }
        // Graceful drain: serve everything still queued, then exit.
        loop {
            let mut pending = Vec::with_capacity(spec.batch);
            while pending.len() < spec.batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            if pending.is_empty() {
                break;
            }
            if !Self::execute_batch(exec, &spec, pending, metrics, shared) {
                return WorkerExit::Panicked;
            }
        }
        WorkerExit::Clean
    }

    /// Answer one expired request with the typed deadline error.
    /// `deadline_expired` is the only counter that moves — never
    /// `shed` or `errors` — so operators can separate deadline sheds
    /// from overload sheds and executor failures exactly.
    fn shed_expired(r: Request, shared: &Shared) {
        let queued = r.t0.elapsed();
        let _ = r.resp.send(Err(anyhow::anyhow!(
            "{} (queued {:.1} ms)",
            DEADLINE_EXPIRED_ERROR,
            queued.as_secs_f64() * 1e3
        )));
        shared.deadline_expired.fetch_add(1, Ordering::Relaxed);
        shared.note_done(1);
    }

    /// Pad, execute (panic-supervised), fan out, record. Returns
    /// `false` when the executor panicked: every request of the batch
    /// has been answered with the typed [`WORKER_PANIC_ERROR`] and the
    /// caller must rebuild the executor before serving again.
    fn execute_batch(
        exec: &mut dyn BatchExecutor,
        spec: &ExecutorSpec,
        pending: Vec<Request>,
        metrics: &ServerMetrics,
        shared: &Shared,
    ) -> bool {
        // Batch-admission deadline check: work that expired while
        // queued behind earlier batches (or while this one was held
        // open) is shed, not executed.
        let now = Instant::now();
        let (pending, dead): (Vec<Request>, Vec<Request>) =
            pending.into_iter().partition(|r| !r.expired(now));
        for r in dead {
            Self::shed_expired(r, shared);
        }
        if pending.is_empty() {
            return true;
        }
        let filled = pending.len();
        let mut x = vec![0.0f32; spec.batch * spec.image_len];
        for (i, r) in pending.iter().enumerate() {
            x[i * spec.image_len..(i + 1) * spec.image_len].copy_from_slice(&r.x);
        }
        let result = match catch_unwind(AssertUnwindSafe(|| exec.run_batch(&x, filled))) {
            Ok(result) => result.and_then(|logits| {
                anyhow::ensure!(
                    logits.len() == spec.batch * spec.classes,
                    "executor returned {} logits, expected {}",
                    logits.len(),
                    spec.batch * spec.classes
                );
                Ok(logits)
            }),
            Err(payload) => {
                // The executor panicked mid-batch: fail exactly these
                // requests with the typed marker (clients holding them
                // get an error, not a dead channel) and report the
                // poisoned executor to the supervisor.
                let msg = panic_message(payload.as_ref());
                for r in pending {
                    let _ = r.resp.send(Err(anyhow::anyhow!("{}: {}", WORKER_PANIC_ERROR, msg)));
                }
                metrics.record_errors(filled as u64);
                shared.note_done(filled);
                return false;
            }
        };
        match result {
            Ok(logits) => {
                let mut latencies = Vec::with_capacity(filled);
                for (i, r) in pending.into_iter().enumerate() {
                    let row = logits[i * spec.classes..(i + 1) * spec.classes].to_vec();
                    latencies.push(r.t0.elapsed());
                    let _ = r.resp.send(Ok(row));
                }
                metrics.record_batch(&latencies, spec.batch);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending {
                    let _ = r.resp.send(Err(anyhow::anyhow!(msg.clone())));
                }
                metrics.record_errors(filled as u64);
            }
        }
        shared.note_done(filled);
        true
    }

    /// A cloneable client handle.
    pub fn client(&self) -> InferenceClient {
        self.client.clone()
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.metrics.len()
    }

    /// Workers currently serving their shard (a worker that exhausted
    /// its restart budget no longer counts).
    pub fn live_workers(&self) -> usize {
        self.shared.live_workers.load(Ordering::Relaxed)
    }

    /// True while the pool is fully staffed: not stopped and every
    /// worker thread still serving. A worker retired by restart-budget
    /// exhaustion leaves the pool degraded — still serving on the
    /// surviving shards, but unhealthy.
    pub fn healthy(&self) -> bool {
        !self.shared.stop.load(Ordering::Relaxed) && self.live_workers() == self.workers()
    }

    /// Aggregated metrics snapshot across all workers.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        ServerMetrics::aggregate(
            &self.metrics,
            self.batch,
            super::PoolCounters {
                shed: self.shared.shed.load(Ordering::Relaxed),
                inflight_peak: self.shared.inflight_peak.load(Ordering::Relaxed),
                worker_panics: self.shared.worker_panics.load(Ordering::Relaxed),
                worker_respawns: self.shared.worker_respawns.load(Ordering::Relaxed),
                deadline_expired: self.shared.deadline_expired.load(Ordering::Relaxed),
                live_workers: self.shared.live_workers.load(Ordering::Relaxed),
                integrity_detected: self.guard.as_ref().map_or(0, |g| g.detected()),
                integrity_recovered: self.guard.as_ref().map_or(0, |g| g.recovered()),
                sparse_gemm: self.sparsity.as_ref().map_or(0, |s| s.sparse_gemm()),
                gemm_total: self.sparsity.as_ref().map_or(0, |s| s.gemm_total()),
                act_nnz: self.sparsity.as_ref().map_or(0, |s| s.act_nnz()),
                act_elems: self.sparsity.as_ref().map_or(0, |s| s.act_elems()),
            },
        )
    }

    /// Graceful shutdown: reject new requests, drain everything
    /// already queued, join the workers, and return the final
    /// aggregated snapshot.
    pub fn shutdown(mut self) -> super::MetricsSnapshot {
        self.shared.stop.store(true, Ordering::Relaxed);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.metrics()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Signal stop but do not join: a client blocked on a response
        // must not deadlock against a Coordinator dropped on the same
        // thread. Workers drain and exit on their next idle poll.
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_wait_interpolates() {
        let p = BatchPolicy {
            max_wait: Duration::from_millis(10),
            min_wait: Duration::from_millis(1),
            adaptive: true,
            overload: OverloadPolicy::Block,
        };
        assert_eq!(p.effective_wait(0.0), Duration::from_millis(1));
        assert_eq!(p.effective_wait(1.0), Duration::from_millis(10));
        let mid = p.effective_wait(0.5);
        assert!(mid > Duration::from_millis(4) && mid < Duration::from_millis(7), "{mid:?}");
        // Out-of-range EWMA values clamp.
        assert_eq!(p.effective_wait(7.0), Duration::from_millis(10));
        assert_eq!(p.effective_wait(-1.0), Duration::from_millis(1));
    }

    #[test]
    fn non_adaptive_wait_is_max_wait() {
        let p = BatchPolicy { adaptive: false, ..BatchPolicy::default() };
        assert_eq!(p.effective_wait(0.0), p.max_wait);
    }

    #[test]
    fn default_policy_is_sane() {
        let p = BatchPolicy::default();
        assert!(p.min_wait <= p.max_wait);
        assert_eq!(p.overload, OverloadPolicy::Block);
        let cfg = ServeConfig::new("artifacts", "scnet10");
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.queue_depth, 1024);
        assert_eq!(cfg.restart_budget, DEFAULT_RESTART_BUDGET);
        assert!(!cfg.guard, "the integrity guard is opt-in");
        assert_eq!(PoolConfig::default().restart_budget, DEFAULT_RESTART_BUDGET);
    }

    #[test]
    fn error_markers_are_distinguishable() {
        let shed = anyhow::anyhow!("{} (4 shard queues full)", SHED_ERROR);
        let panic = anyhow::anyhow!("{}: boom", WORKER_PANIC_ERROR);
        let expired = anyhow::anyhow!("{} (queued 7.0 ms)", DEADLINE_EXPIRED_ERROR);
        assert!(is_shed_error(&shed) && !is_worker_panic_error(&shed) && !is_deadline_error(&shed));
        assert!(is_worker_panic_error(&panic) && !is_shed_error(&panic));
        assert!(is_deadline_error(&expired) && !is_shed_error(&expired));
        assert!(!is_deadline_error(&panic) && !is_worker_panic_error(&expired));
    }

    #[test]
    fn panic_message_extracts_common_payloads() {
        let s = catch_unwind(|| std::panic::panic_any("static str")).unwrap_err();
        assert_eq!(panic_message(s.as_ref()), "static str");
        let owned = catch_unwind(|| std::panic::panic_any("owned".to_string())).unwrap_err();
        assert_eq!(panic_message(owned.as_ref()), "owned");
        let odd = catch_unwind(|| std::panic::panic_any(42u32)).unwrap_err();
        assert_eq!(panic_message(odd.as_ref()), "non-string panic payload");
    }

    #[test]
    fn request_expiry_is_deadline_relative() {
        let (tx, _rx) = mpsc::sync_channel(1);
        let now = Instant::now();
        let r = Request { x: vec![], t0: now, deadline: None, resp: tx.clone() };
        assert!(!r.expired(now + Duration::from_secs(3600)));
        let r = Request { x: vec![], t0: now, deadline: Some(now), resp: tx };
        assert!(!r.expired(now), "a deadline is inclusive");
        assert!(r.expired(now + Duration::from_nanos(1)));
    }
}
