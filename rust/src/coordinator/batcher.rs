//! Multi-worker inference pool with adaptive batching and
//! backpressure.
//!
//! Requests (single images) enter a **sharded queue**: one bounded
//! channel per worker, round-robin on submit with overflow spilling to
//! the next shard. Each worker thread owns its own [`BatchExecutor`]
//! (built in-thread via an [`ExecutorFactory`], because PJRT handles
//! are not `Send`), drains its shard into the executor's fixed batch —
//! padding the tail with zeros — executes once, and fans the logits
//! back out. Per-worker [`ServerMetrics`] aggregate into one
//! [`super::MetricsSnapshot`].
//!
//! Batching is **adaptive**: a worker tracks an EWMA of its batch
//! occupancy and scales the hold time between [`BatchPolicy::min_wait`]
//! (light traffic → don't add latency waiting for co-riders that are
//! not coming) and [`BatchPolicy::max_wait`] (heavy traffic → amortize
//! the fixed batch cost; under load the batch fills long before the
//! deadline anyway).
//!
//! Backpressure is explicit: every shard channel is bounded by
//! [`ServeConfig::queue_depth`]. When all shards are full the
//! [`OverloadPolicy`] decides between blocking the client
//! ([`OverloadPolicy::Block`]) and shedding the request with an error
//! ([`OverloadPolicy::Shed`]).
//!
//! Shutdown is graceful: [`Coordinator::shutdown`] signals stop,
//! workers drain every queued request into final batches, and the call
//! joins them before returning the last snapshot.

use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::mpsc::{self, RecvTimeoutError, TrySendError};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::trainer::Knobs;
use crate::Result;
use anyhow::Context;

use super::backend::Backend;
use super::executor::{BatchExecutor, ExecutorFactory, ExecutorSpec};
use super::metrics::ServerMetrics;

/// What to do with a request when every shard queue is full.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum OverloadPolicy {
    /// Block the submitting client until its shard has room
    /// (backpressure propagates to the caller).
    Block,
    /// Fail fast: return an error to the client and count the request
    /// in [`super::MetricsSnapshot::shed`].
    Shed,
}

/// Batching policy of each pool worker.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max time to hold an open batch after its first request.
    pub max_wait: Duration,
    /// Hold time floor used when traffic is light (adaptive mode).
    pub min_wait: Duration,
    /// Scale the hold time with observed batch occupancy; `false`
    /// always holds for `max_wait`.
    pub adaptive: bool,
    /// Behavior when every shard queue is full.
    pub overload: OverloadPolicy,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self {
            max_wait: Duration::from_millis(5),
            min_wait: Duration::from_micros(250),
            adaptive: true,
            overload: OverloadPolicy::Block,
        }
    }
}

impl BatchPolicy {
    /// The hold time for the next batch given the worker's occupancy
    /// EWMA in `[0, 1]`: interpolates `min_wait..=max_wait` when
    /// [`BatchPolicy::adaptive`], else returns `max_wait`.
    pub fn effective_wait(&self, occupancy_ewma: f64) -> Duration {
        if !self.adaptive {
            return self.max_wait;
        }
        let lo = self.min_wait.min(self.max_wait);
        lo + (self.max_wait - lo).mul_f64(occupancy_ewma.clamp(0.0, 1.0))
    }
}

struct Request {
    x: Vec<f32>,
    t0: Instant,
    resp: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// State shared by the coordinator, its clients and its workers.
struct Shared {
    stop: AtomicBool,
    shed: AtomicU64,
    rr: AtomicUsize,
    inflight: AtomicUsize,
    inflight_peak: AtomicUsize,
}

impl Shared {
    fn new() -> Self {
        Self {
            stop: AtomicBool::new(false),
            shed: AtomicU64::new(0),
            rr: AtomicUsize::new(0),
            inflight: AtomicUsize::new(0),
            inflight_peak: AtomicUsize::new(0),
        }
    }

    /// Bump the in-flight gauge before the request becomes visible to
    /// a worker; returns the observed level for [`Shared::note_admitted`].
    fn note_submitting(&self) -> usize {
        self.inflight.fetch_add(1, Ordering::Relaxed) + 1
    }

    /// Publish the peak only for requests that were actually admitted,
    /// so a burst of shed attempts cannot inflate `inflight_peak`.
    fn note_admitted(&self, observed: usize) {
        self.inflight_peak.fetch_max(observed, Ordering::Relaxed);
    }

    fn note_done(&self, n: usize) {
        self.inflight.fetch_sub(n, Ordering::Relaxed);
    }
}

/// Client handle: submit images, receive logits. Cheap to clone; any
/// number of threads may hold one.
#[derive(Clone)]
pub struct InferenceClient {
    shards: Vec<mpsc::SyncSender<Request>>,
    shared: Arc<Shared>,
    overload: OverloadPolicy,
    image_len: usize,
    classes: usize,
}

impl InferenceClient {
    /// Blocking inference of one image (CHW flat). Returns logits.
    ///
    /// ```
    /// use std::time::Duration;
    /// use scnn::coordinator::{Coordinator, ExecutorSpec, PoolConfig, SyntheticExecutor};
    ///
    /// # fn main() -> scnn::Result<()> {
    /// let spec = ExecutorSpec { image_len: 4, batch: 2, classes: 3 };
    /// let factory = SyntheticExecutor::factory(spec, Duration::ZERO);
    /// let pool = PoolConfig { workers: 2, ..PoolConfig::default() };
    /// let coord = Coordinator::start_with(factory, pool)?;
    /// let logits = coord.client().infer(vec![0.25; 4])?;
    /// assert_eq!(logits.len(), 3);
    /// coord.shutdown();
    /// # Ok(())
    /// # }
    /// ```
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.image_len, "image length mismatch");
        let (tx, rx) = mpsc::sync_channel(1);
        self.submit(Request { x, t0: Instant::now(), resp: tx })?;
        match rx.recv() {
            Ok(result) => result,
            Err(_) => {
                // The response channel died without an answer: the
                // request raced a shutdown past the worker's final
                // drain (or the worker died). Either way it is
                // terminally done — repair the gauge and report the
                // shutdown as such, honoring the drain invariant.
                self.shared.note_done(1);
                if self.shared.stop.load(Ordering::Relaxed) {
                    anyhow::bail!("coordinator stopped");
                }
                anyhow::bail!("coordinator dropped the request");
            }
        }
    }

    /// Classify one image (argmax over [`InferenceClient::infer`]).
    ///
    /// ```
    /// use std::time::Duration;
    /// use scnn::coordinator::{Coordinator, ExecutorSpec, PoolConfig, SyntheticExecutor};
    ///
    /// # fn main() -> scnn::Result<()> {
    /// let spec = ExecutorSpec { image_len: 4, batch: 2, classes: 3 };
    /// let factory = SyntheticExecutor::factory(spec, Duration::ZERO);
    /// let coord = Coordinator::start_with(factory, PoolConfig::default())?;
    /// let class = coord.client().classify(vec![1.0, 0.0, 0.5, 0.25])?;
    /// assert!(class < 3);
    /// # Ok(())
    /// # }
    /// ```
    pub fn classify(&self, x: Vec<f32>) -> Result<usize> {
        let logits = self.infer(x)?;
        Ok(logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0))
    }

    /// Number of classes served.
    pub fn classes(&self) -> usize {
        self.classes
    }

    /// Flattened image length (C·H·W floats) one request must carry —
    /// the shape contract network front-ends validate before
    /// submitting.
    pub fn image_len(&self) -> usize {
        self.image_len
    }

    /// Number of pool workers behind this client.
    pub fn workers(&self) -> usize {
        self.shards.len()
    }

    /// Route one request: round-robin over the shards, spilling to the
    /// next shard when the preferred one is full; when every shard is
    /// full, apply the [`OverloadPolicy`].
    fn submit(&self, req: Request) -> Result<()> {
        if self.shared.stop.load(Ordering::Relaxed) {
            anyhow::bail!("coordinator stopped");
        }
        let n = self.shards.len();
        let start = self.shared.rr.fetch_add(1, Ordering::Relaxed) % n;
        // Count the request as in-flight *before* it becomes visible to
        // any worker: otherwise a fast worker could decrement first and
        // underflow the gauge. Undone on every rejection path below;
        // the peak is only published on successful admission.
        let observed = self.shared.note_submitting();
        let mut req = req;
        // A disconnected shard (dead worker) is skipped like a full
        // one: the pool degrades to the surviving workers and only
        // reports a stop once every shard is gone.
        let mut first_full: Option<usize> = None;
        for k in 0..n {
            let shard = (start + k) % n;
            match self.shards[shard].try_send(req) {
                Ok(()) => {
                    self.shared.note_admitted(observed);
                    return Ok(());
                }
                Err(TrySendError::Full(r)) => {
                    first_full.get_or_insert(shard);
                    req = r;
                }
                Err(TrySendError::Disconnected(r)) => req = r,
            }
        }
        let Some(full) = first_full else {
            self.shared.note_done(1);
            anyhow::bail!("coordinator stopped");
        };
        match self.overload {
            OverloadPolicy::Block => match self.shards[full].send(req) {
                Ok(()) => {
                    self.shared.note_admitted(observed);
                    Ok(())
                }
                Err(_) => {
                    self.shared.note_done(1);
                    anyhow::bail!("coordinator stopped");
                }
            },
            OverloadPolicy::Shed => {
                self.shared.note_done(1);
                self.shared.shed.fetch_add(1, Ordering::Relaxed);
                anyhow::bail!("{} ({n} shard queues full)", SHED_ERROR);
            }
        }
    }
}

/// Everything a pool worker needs to build its serving stack, for any
/// [`Backend`]. PJRT workers consume `artifacts`/`params`; the native
/// `sc`/`binary` backends freeze the model from `model`/`knobs`/`seed`
/// and batch at `batch`.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Artifacts directory.
    pub artifacts: String,
    /// Model name (artifact prefix): `tnn`, `scnet10`, `scnet20`.
    pub model: String,
    /// Trained parameters to install in PJRT workers (None = exported
    /// init).
    pub params: Option<Vec<Vec<f32>>>,
    /// Quantization knobs for the serving path.
    pub knobs: Knobs,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Per-shard request queue depth (backpressure bound).
    pub queue_depth: usize,
    /// Number of pool workers, each owning its executor.
    pub workers: usize,
    /// Deterministic init seed for the native `sc`/`binary` backends
    /// (the frozen model is a pure function of `(model, knobs, seed)`).
    pub seed: u64,
    /// Batch capacity of one native-backend execution.
    pub batch: usize,
    /// Intra-engine threads of one `sc`-backend execution: each worker
    /// shards batch rows × output-channel blocks across this many
    /// scoped threads inside `nn::ScEngine` (bit-identical logits at
    /// any value; single-row batches fall back to channel-block
    /// sharding so the threads still cut latency). Total serving
    /// threads scale as `workers × threads`.
    pub threads: usize,
}

impl ServeConfig {
    /// Defaults for a model.
    pub fn new(artifacts: &str, model: &str) -> Self {
        Self {
            artifacts: artifacts.to_string(),
            model: model.to_string(),
            params: None,
            knobs: Knobs::quantized(2),
            policy: BatchPolicy::default(),
            queue_depth: 1024,
            workers: 1,
            seed: 42,
            batch: 8,
            threads: 1,
        }
    }
}

/// Backend-agnostic pool sizing/policy (what [`ServeConfig`] reduces
/// to once the PJRT-specific fields became an [`ExecutorFactory`]).
#[derive(Clone, Copy, Debug)]
pub struct PoolConfig {
    /// Number of worker threads (each with its own shard + executor).
    pub workers: usize,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Per-shard request queue depth (backpressure bound).
    pub queue_depth: usize,
}

impl Default for PoolConfig {
    fn default() -> Self {
        Self { workers: 1, policy: BatchPolicy::default(), queue_depth: 1024 }
    }
}

/// How often an idle worker re-checks the stop flag.
const IDLE_POLL: Duration = Duration::from_millis(20);

/// Marker prefix of load-shedding rejections (see [`is_shed_error`]).
pub const SHED_ERROR: &str = "overloaded: request shed";

/// True when an [`InferenceClient::infer`]/`classify` error is a
/// load-shedding rejection ([`OverloadPolicy::Shed`]) rather than a
/// real failure. Callers should use this instead of matching error
/// text themselves.
pub fn is_shed_error(e: &anyhow::Error) -> bool {
    format!("{e}").starts_with(SHED_ERROR)
}

/// The running pool (owns the worker threads).
pub struct Coordinator {
    client: InferenceClient,
    workers: Vec<JoinHandle<()>>,
    metrics: Vec<Arc<ServerMetrics>>,
    shared: Arc<Shared>,
    batch: usize,
}

impl Coordinator {
    /// Start a pool over a named [`Backend`] — the single entry point
    /// the CLI, examples and benches share. `Backend::Auto` resolves
    /// against the artifact store; every other backend is taken
    /// literally. Blocks until every worker has built its executor.
    pub fn start_backend(backend: Backend, cfg: ServeConfig) -> Result<Self> {
        let pool =
            PoolConfig { workers: cfg.workers, policy: cfg.policy, queue_depth: cfg.queue_depth };
        let factory = backend.factory(cfg)?;
        Self::start_with(factory, pool)
    }

    /// Start a PJRT-backed pool; blocks until every worker has
    /// compiled its executables and is ready to serve (or any failed).
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        Self::start_backend(Backend::Pjrt, cfg)
    }

    /// Start with automatic backend selection: the PJRT serving path
    /// when the model's AOT artifacts exist, else the synthetic demo
    /// backend shaped `(image_len, classes)` (for callers whose model
    /// is not in the registry; registry models can just use
    /// [`Coordinator::start_backend`] with [`Backend::Auto`]).
    pub fn start_auto(cfg: ServeConfig, fallback: (usize, usize)) -> Result<Self> {
        if crate::runtime::artifacts_ready(&cfg.artifacts, &cfg.model) {
            Self::start(cfg)
        } else {
            let pool = PoolConfig {
                workers: cfg.workers,
                policy: cfg.policy,
                queue_depth: cfg.queue_depth,
            };
            let (image_len, classes) = fallback;
            Self::start_with(super::SyntheticExecutor::demo_factory(image_len, classes), pool)
        }
    }

    /// Start a pool over any executor backend. Blocks until every
    /// worker has built its executor; fails if any worker fails or if
    /// workers disagree on the [`ExecutorSpec`].
    pub fn start_with(factory: ExecutorFactory, pool: PoolConfig) -> Result<Self> {
        let n = pool.workers.max(1);
        let factory = Arc::new(factory);
        let shared = Arc::new(Shared::new());
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<ExecutorSpec>>(n);
        let mut shards = Vec::with_capacity(n);
        let mut workers = Vec::with_capacity(n);
        let mut metrics = Vec::with_capacity(n);
        for w in 0..n {
            let (tx, rx) = mpsc::sync_channel::<Request>(pool.queue_depth.max(1));
            shards.push(tx);
            let m = Arc::new(ServerMetrics::new());
            metrics.push(m.clone());
            let factory = factory.clone();
            let shared = shared.clone();
            let ready_tx = ready_tx.clone();
            let policy = pool.policy;
            let handle = std::thread::Builder::new()
                .name(format!("scnn-worker-{w}"))
                .spawn(move || match (factory.as_ref())(w) {
                    Ok(mut exec) => {
                        let _ = ready_tx.send(Ok(exec.spec()));
                        drop(ready_tx);
                        Self::worker_loop(exec.as_mut(), policy, &rx, &m, &shared);
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                })
                .context("spawning pool worker thread")?;
            workers.push(handle);
        }
        drop(ready_tx);
        let mut spec: Option<ExecutorSpec> = None;
        for _ in 0..n {
            let s = ready_rx.recv().context("worker died during setup")??;
            match spec {
                None => spec = Some(s),
                Some(prev) => anyhow::ensure!(
                    prev == s,
                    "workers disagree on executor spec: {prev:?} vs {s:?}"
                ),
            }
        }
        let spec = spec.expect("n >= 1 workers reported ready");
        let client = InferenceClient {
            shards,
            shared: shared.clone(),
            overload: pool.policy.overload,
            image_len: spec.image_len,
            classes: spec.classes,
        };
        Ok(Self { client, workers, metrics, shared, batch: spec.batch })
    }

    /// One worker: batch its shard queue into the executor until the
    /// pool stops (then drain) or every sender disappears.
    fn worker_loop(
        exec: &mut dyn BatchExecutor,
        policy: BatchPolicy,
        rx: &mpsc::Receiver<Request>,
        metrics: &ServerMetrics,
        shared: &Shared,
    ) {
        let spec = exec.spec();
        // Start pessimistic (assume load) so cold-start bursts batch well.
        let mut occupancy_ewma = 1.0f64;
        'serve: loop {
            // Block for the first request, re-checking stop while idle.
            let first = loop {
                match rx.recv_timeout(IDLE_POLL) {
                    Ok(r) => break r,
                    Err(RecvTimeoutError::Timeout) => {
                        if shared.stop.load(Ordering::Relaxed) {
                            break 'serve;
                        }
                    }
                    Err(RecvTimeoutError::Disconnected) => break 'serve,
                }
            };
            let mut pending = Vec::with_capacity(spec.batch);
            pending.push(first);
            // Drain whatever is already queued, free of charge.
            while pending.len() < spec.batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            // Hold the batch open for the adaptive wait.
            if pending.len() < spec.batch {
                let deadline = Instant::now() + policy.effective_wait(occupancy_ewma);
                while pending.len() < spec.batch {
                    let now = Instant::now();
                    if now >= deadline {
                        break;
                    }
                    match rx.recv_timeout(deadline - now) {
                        Ok(r) => pending.push(r),
                        Err(_) => break,
                    }
                }
            }
            occupancy_ewma = 0.8 * occupancy_ewma
                + 0.2 * (pending.len() as f64 / spec.batch.max(1) as f64);
            Self::execute_batch(exec, &spec, pending, metrics, shared);
        }
        // Graceful drain: serve everything still queued, then exit.
        loop {
            let mut pending = Vec::with_capacity(spec.batch);
            while pending.len() < spec.batch {
                match rx.try_recv() {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            if pending.is_empty() {
                break;
            }
            Self::execute_batch(exec, &spec, pending, metrics, shared);
        }
    }

    /// Pad, execute, fan out, record.
    fn execute_batch(
        exec: &mut dyn BatchExecutor,
        spec: &ExecutorSpec,
        pending: Vec<Request>,
        metrics: &ServerMetrics,
        shared: &Shared,
    ) {
        let filled = pending.len();
        let mut x = vec![0.0f32; spec.batch * spec.image_len];
        for (i, r) in pending.iter().enumerate() {
            x[i * spec.image_len..(i + 1) * spec.image_len].copy_from_slice(&r.x);
        }
        let result = exec.run_batch(&x, filled).and_then(|logits| {
            anyhow::ensure!(
                logits.len() == spec.batch * spec.classes,
                "executor returned {} logits, expected {}",
                logits.len(),
                spec.batch * spec.classes
            );
            Ok(logits)
        });
        match result {
            Ok(logits) => {
                let mut latencies = Vec::with_capacity(filled);
                for (i, r) in pending.into_iter().enumerate() {
                    let row = logits[i * spec.classes..(i + 1) * spec.classes].to_vec();
                    latencies.push(r.t0.elapsed());
                    let _ = r.resp.send(Ok(row));
                }
                metrics.record_batch(&latencies, spec.batch);
            }
            Err(e) => {
                let msg = format!("{e:#}");
                for r in pending {
                    let _ = r.resp.send(Err(anyhow::anyhow!(msg.clone())));
                }
                metrics.record_errors(filled as u64);
            }
        }
        shared.note_done(filled);
    }

    /// A cloneable client handle.
    pub fn client(&self) -> InferenceClient {
        self.client.clone()
    }

    /// Number of pool workers.
    pub fn workers(&self) -> usize {
        self.metrics.len()
    }

    /// Aggregated metrics snapshot across all workers.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        ServerMetrics::aggregate(
            &self.metrics,
            self.batch,
            self.shared.shed.load(Ordering::Relaxed),
            self.shared.inflight_peak.load(Ordering::Relaxed),
        )
    }

    /// Graceful shutdown: reject new requests, drain everything
    /// already queued, join the workers, and return the final
    /// aggregated snapshot.
    pub fn shutdown(mut self) -> super::MetricsSnapshot {
        self.shared.stop.store(true, Ordering::Relaxed);
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
        self.metrics()
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Signal stop but do not join: a client blocked on a response
        // must not deadlock against a Coordinator dropped on the same
        // thread. Workers drain and exit on their next idle poll.
        self.shared.stop.store(true, Ordering::Relaxed);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn adaptive_wait_interpolates() {
        let p = BatchPolicy {
            max_wait: Duration::from_millis(10),
            min_wait: Duration::from_millis(1),
            adaptive: true,
            overload: OverloadPolicy::Block,
        };
        assert_eq!(p.effective_wait(0.0), Duration::from_millis(1));
        assert_eq!(p.effective_wait(1.0), Duration::from_millis(10));
        let mid = p.effective_wait(0.5);
        assert!(mid > Duration::from_millis(4) && mid < Duration::from_millis(7), "{mid:?}");
        // Out-of-range EWMA values clamp.
        assert_eq!(p.effective_wait(7.0), Duration::from_millis(10));
        assert_eq!(p.effective_wait(-1.0), Duration::from_millis(1));
    }

    #[test]
    fn non_adaptive_wait_is_max_wait() {
        let p = BatchPolicy { adaptive: false, ..BatchPolicy::default() };
        assert_eq!(p.effective_wait(0.0), p.max_wait);
    }

    #[test]
    fn default_policy_is_sane() {
        let p = BatchPolicy::default();
        assert!(p.min_wait <= p.max_wait);
        assert_eq!(p.overload, OverloadPolicy::Block);
        let cfg = ServeConfig::new("artifacts", "scnet10");
        assert_eq!(cfg.workers, 1);
        assert_eq!(cfg.queue_depth, 1024);
    }
}
