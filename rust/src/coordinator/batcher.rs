//! Dynamic batcher + PJRT worker thread.
//!
//! Requests (single images) are coalesced into the fixed batch size of
//! the AOT-compiled executable: the worker drains the queue until the
//! batch is full or `max_wait` expires since the first request, pads
//! the tail with zeros, executes once, and fans the logits back out.
//!
//! PJRT handles are not `Send` (the `xla` crate wraps raw pointers in
//! `Rc`), so the worker thread owns its *own* [`Runtime`] and
//! [`Trainer`]; trained parameters cross the thread boundary as plain
//! `Vec<f32>` blobs and are installed with [`Trainer::set_params`].

use std::sync::mpsc;
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::runtime::{trainer::Knobs, Runtime, Trainer};
use crate::Result;
use anyhow::Context;

use super::metrics::ServerMetrics;

/// Batching policy.
#[derive(Clone, Copy, Debug)]
pub struct BatchPolicy {
    /// Max time to hold an open batch after its first request.
    pub max_wait: Duration,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        Self { max_wait: Duration::from_millis(5) }
    }
}

struct Request {
    x: Vec<f32>,
    t0: Instant,
    resp: mpsc::SyncSender<Result<Vec<f32>>>,
}

/// Client handle: submit images, receive logits. Cheap to clone.
#[derive(Clone)]
pub struct InferenceClient {
    tx: mpsc::SyncSender<Request>,
    image_len: usize,
    classes: usize,
}

impl InferenceClient {
    /// Blocking inference of one image (CHW flat). Returns logits.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        anyhow::ensure!(x.len() == self.image_len, "image length mismatch");
        let (tx, rx) = mpsc::sync_channel(1);
        self.tx
            .send(Request { x, t0: Instant::now(), resp: tx })
            .map_err(|_| anyhow::anyhow!("coordinator stopped"))?;
        rx.recv().context("coordinator dropped the request")?
    }

    /// Classify one image.
    pub fn classify(&self, x: Vec<f32>) -> Result<usize> {
        let logits = self.infer(x)?;
        Ok(logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0))
    }

    /// Number of classes served.
    pub fn classes(&self) -> usize {
        self.classes
    }
}

/// Everything the worker needs to build its own PJRT stack.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Artifacts directory.
    pub artifacts: String,
    /// Model name (artifact prefix).
    pub model: String,
    /// Trained parameters to install (None = exported init).
    pub params: Option<Vec<Vec<f32>>>,
    /// Quantization knobs for the serving path.
    pub knobs: Knobs,
    /// Batching policy.
    pub policy: BatchPolicy,
    /// Request queue depth (backpressure bound).
    pub queue_depth: usize,
}

impl ServeConfig {
    /// Defaults for a model.
    pub fn new(artifacts: &str, model: &str) -> Self {
        Self {
            artifacts: artifacts.to_string(),
            model: model.to_string(),
            params: None,
            knobs: Knobs::quantized(2),
            policy: BatchPolicy::default(),
            queue_depth: 1024,
        }
    }
}

/// The running coordinator (owns the worker thread).
pub struct Coordinator {
    client: InferenceClient,
    worker: Option<JoinHandle<()>>,
    metrics: Arc<ServerMetrics>,
    batch: usize,
}

impl Coordinator {
    /// Start a coordinator; blocks until the worker has compiled the
    /// executable and is ready to serve (or failed).
    pub fn start(cfg: ServeConfig) -> Result<Self> {
        let (tx, rx) = mpsc::sync_channel::<Request>(cfg.queue_depth);
        let (ready_tx, ready_rx) = mpsc::sync_channel::<Result<(usize, usize, usize)>>(1);
        let metrics = Arc::new(ServerMetrics::new());
        let metrics_w = metrics.clone();
        let worker = std::thread::Builder::new()
            .name("scnn-batcher".into())
            .spawn(move || {
                let setup = (|| -> Result<(Trainer, usize, usize, usize)> {
                    let rt = Runtime::new(&cfg.artifacts)?;
                    let mut tr = Trainer::new(&rt, &cfg.model)?;
                    if let Some(p) = cfg.params {
                        tr.set_params(p)?;
                    }
                    let (c, h, w) = tr.meta().input;
                    let (batch, classes) = (tr.meta().batch, tr.meta().classes);
                    Ok((tr, c * h * w, batch, classes))
                })();
                match setup {
                    Ok((tr, image_len, batch, classes)) => {
                        let _ = ready_tx.send(Ok((image_len, batch, classes)));
                        Self::worker_loop(
                            tr, cfg.knobs, cfg.policy, rx, metrics_w, image_len, batch, classes,
                        );
                    }
                    Err(e) => {
                        let _ = ready_tx.send(Err(e));
                    }
                }
            })
            .context("spawning batcher thread")?;
        let (image_len, batch, classes) =
            ready_rx.recv().context("worker died during setup")??;
        Ok(Self {
            client: InferenceClient { tx, image_len, classes },
            worker: Some(worker),
            metrics,
            batch,
        })
    }

    #[allow(clippy::too_many_arguments)]
    fn worker_loop(
        trainer: Trainer,
        knobs: Knobs,
        policy: BatchPolicy,
        rx: mpsc::Receiver<Request>,
        metrics: Arc<ServerMetrics>,
        image_len: usize,
        batch: usize,
        classes: usize,
    ) {
        loop {
            // Block for the first request of the batch.
            let first = match rx.recv() {
                Ok(r) => r,
                Err(_) => return, // all senders gone
            };
            let deadline = Instant::now() + policy.max_wait;
            let mut pending = vec![first];
            while pending.len() < batch {
                let now = Instant::now();
                if now >= deadline {
                    break;
                }
                match rx.recv_timeout(deadline - now) {
                    Ok(r) => pending.push(r),
                    Err(_) => break,
                }
            }
            // Assemble the padded batch.
            let mut x = vec![0.0f32; batch * image_len];
            for (i, r) in pending.iter().enumerate() {
                x[i * image_len..(i + 1) * image_len].copy_from_slice(&r.x);
            }
            match trainer.logits(&x, knobs, true) {
                Ok(logits) => {
                    let mut latencies = Vec::with_capacity(pending.len());
                    for (i, r) in pending.into_iter().enumerate() {
                        let row = logits[i * classes..(i + 1) * classes].to_vec();
                        latencies.push(r.t0.elapsed());
                        let _ = r.resp.send(Ok(row));
                    }
                    metrics.record_batch(&latencies, batch);
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    for r in pending {
                        let _ = r.resp.send(Err(anyhow::anyhow!(msg.clone())));
                    }
                }
            }
        }
    }

    /// A cloneable client handle.
    pub fn client(&self) -> InferenceClient {
        self.client.clone()
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> super::MetricsSnapshot {
        self.metrics.snapshot(self.batch)
    }

    /// Stop the coordinator: returns the final metrics snapshot. The
    /// worker thread exits once every [`InferenceClient`] clone is
    /// dropped (the channel closes); outstanding requests error out.
    pub fn shutdown(self) -> super::MetricsSnapshot {
        self.metrics.snapshot(self.batch)
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        // Dropping our senders closes the channel once all client
        // clones are gone; the worker then exits on its own. Joining
        // here could hang if a client outlives the coordinator, so the
        // thread is detached instead.
        self.worker.take();
    }
}
