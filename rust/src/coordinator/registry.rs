//! Multi-model registry + per-tenant admission control.
//!
//! A [`ModelRegistry`] holds several named serving pools side by side
//! — one [`Coordinator`] per model, all reachable through the network
//! front-end ([`super::net`]) by the model id carried in each frame.
//! Registration is **hot**: `register` on an existing name swaps the
//! pool atomically (new requests route to the new pool, the old pool
//! drains gracefully and its final [`MetricsSnapshot`] is returned),
//! so a model can be re-frozen with new knobs under live traffic.
//!
//! Admission is **per tenant**, layered *in front of* the per-model
//! [`OverloadPolicy`]: every request names a tenant and a
//! [`Priority`], and a tenant may only hold [`TenantPolicy`]-bounded
//! concurrent requests — lower priorities hit a lower bound first, so
//! one noisy tenant starts shedding its own low-priority traffic
//! before it can starve anyone else's. Whatever passes admission then
//! still faces the pool's own Block/Shed backpressure.
//!
//! [`Coordinator`]: super::Coordinator
//! [`OverloadPolicy`]: super::OverloadPolicy

use std::collections::HashMap;
use std::sync::{
    Arc, Mutex, MutexGuard, PoisonError, RwLock, RwLockReadGuard, RwLockWriteGuard,
};
use std::time::Duration;

use crate::Result;

/// Lock, recovering from poison: registry state (model map, tenant
/// counters) stays valid across a panic elsewhere — worker panics are
/// supervised and accounted separately, and a poisoned registry lock
/// must not take the whole front-end down with it.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

fn read<T>(l: &RwLock<T>) -> RwLockReadGuard<'_, T> {
    l.read().unwrap_or_else(PoisonError::into_inner)
}

fn write<T>(l: &RwLock<T>) -> RwLockWriteGuard<'_, T> {
    l.write().unwrap_or_else(PoisonError::into_inner)
}

use super::backend::Backend;
use super::batcher::{Coordinator, InferenceClient, ServeConfig};
use super::metrics::{self, MetricsSnapshot};

/// Request priority carried on the wire (one byte) and consumed by
/// tenant admission: lower priorities shed earlier under load.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum Priority {
    /// Interactive traffic: admitted up to the tenant's full quota.
    High,
    /// Default traffic: admitted up to 3/4 of the quota.
    Normal,
    /// Batch/backfill traffic: admitted up to 1/2 of the quota.
    Low,
}

impl Priority {
    /// Wire encoding (one byte).
    pub fn as_u8(self) -> u8 {
        match self {
            Priority::High => 0,
            Priority::Normal => 1,
            Priority::Low => 2,
        }
    }

    /// Decode the wire byte.
    pub fn from_u8(v: u8) -> Option<Priority> {
        match v {
            0 => Some(Priority::High),
            1 => Some(Priority::Normal),
            2 => Some(Priority::Low),
            _ => None,
        }
    }

    /// Parse a CLI flag value.
    pub fn parse(s: &str) -> Result<Priority> {
        match s {
            "high" => Ok(Priority::High),
            "normal" => Ok(Priority::Normal),
            "low" => Ok(Priority::Low),
            other => anyhow::bail!("unknown priority {other:?} (high|normal|low)"),
        }
    }

    /// The flag spelling.
    pub fn name(self) -> &'static str {
        match self {
            Priority::High => "high",
            Priority::Normal => "normal",
            Priority::Low => "low",
        }
    }
}

/// Per-tenant admission policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TenantPolicy {
    /// Maximum concurrent (admitted, unanswered) requests one tenant
    /// may hold at [`Priority::High`]; `0` disables admission control.
    pub max_inflight: usize,
}

impl Default for TenantPolicy {
    /// Admission control off: single-tenant serving stays unthrottled.
    fn default() -> Self {
        Self { max_inflight: 0 }
    }
}

impl TenantPolicy {
    /// The in-flight bound a request of priority `p` must stay under:
    /// the full quota for `High`, ⌈3/4⌉ for `Normal`, ⌈1/2⌉ for `Low`
    /// (so low-priority traffic sheds first while the quota is never
    /// rounded to zero).
    pub fn limit_for(&self, p: Priority) -> usize {
        if self.max_inflight == 0 {
            return usize::MAX;
        }
        match p {
            Priority::High => self.max_inflight,
            Priority::Normal => (self.max_inflight * 3).div_ceil(4),
            Priority::Low => self.max_inflight.div_ceil(2),
        }
    }
}

#[derive(Debug, Default)]
struct TenantState {
    inflight: usize,
    peak: usize,
    admitted: u64,
    shed: u64,
}

/// Point-in-time counters of one tenant, from
/// [`TenantAdmission::counters`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TenantCounters {
    /// Tenant id as carried on the wire.
    pub tenant: String,
    /// Requests admitted over the tenant's lifetime.
    pub admitted: u64,
    /// Requests shed by admission control (before reaching any pool).
    pub shed: u64,
    /// High-water mark of the tenant's concurrent requests.
    pub peak: usize,
    /// Currently admitted, unanswered requests.
    pub inflight: usize,
}

/// Shared per-tenant admission state (one per registry). Admission is
/// a short critical section over a tenant map; the returned
/// [`TenantGuard`] releases the slot on drop, so every exit path of a
/// request — response, executor error, panic unwind — gives the slot
/// back.
#[derive(Debug, Default)]
pub struct TenantAdmission {
    policy: TenantPolicy,
    tenants: Mutex<HashMap<String, TenantState>>,
}

impl TenantAdmission {
    /// New admission state under `policy`.
    pub fn new(policy: TenantPolicy) -> Self {
        Self { policy, tenants: Mutex::new(HashMap::new()) }
    }

    /// The configured policy.
    pub fn policy(&self) -> TenantPolicy {
        self.policy
    }

    /// Try to admit one request for `tenant` at priority `p`: `Some`
    /// holds the slot until the guard drops, `None` means the request
    /// must be shed (the tenant's shed counter is already bumped).
    pub fn try_admit(self: &Arc<Self>, tenant: &str, p: Priority) -> Option<TenantGuard> {
        let limit = self.policy.limit_for(p);
        let mut g = lock(&self.tenants);
        let state = g.entry(tenant.to_string()).or_default();
        if state.inflight >= limit {
            state.shed += 1;
            return None;
        }
        state.inflight += 1;
        state.peak = state.peak.max(state.inflight);
        state.admitted += 1;
        drop(g);
        Some(TenantGuard { admission: self.clone(), tenant: tenant.to_string() })
    }

    /// Counters of every tenant seen so far, sorted by tenant id.
    pub fn counters(&self) -> Vec<TenantCounters> {
        let g = lock(&self.tenants);
        let mut out: Vec<TenantCounters> = g
            .iter()
            .map(|(t, s)| TenantCounters {
                tenant: t.clone(),
                admitted: s.admitted,
                shed: s.shed,
                peak: s.peak,
                inflight: s.inflight,
            })
            .collect();
        out.sort_by(|a, b| a.tenant.cmp(&b.tenant));
        out
    }

    fn release(&self, tenant: &str) {
        let mut g = lock(&self.tenants);
        if let Some(state) = g.get_mut(tenant) {
            state.inflight = state.inflight.saturating_sub(1);
        }
    }
}

/// RAII admission slot: dropping it releases the tenant's in-flight
/// slot.
#[derive(Debug)]
pub struct TenantGuard {
    admission: Arc<TenantAdmission>,
    tenant: String,
}

impl Drop for TenantGuard {
    fn drop(&mut self) {
        self.admission.release(&self.tenant);
    }
}

/// One registered model: a name, a cheap-to-clone client, and the
/// owning [`Coordinator`] (taken out on shutdown/swap).
pub struct ModelEntry {
    name: String,
    client: InferenceClient,
    coord: Mutex<Option<Coordinator>>,
}

impl ModelEntry {
    fn new(name: &str, coord: Coordinator) -> Self {
        Self { name: name.to_string(), client: coord.client(), coord: Mutex::new(Some(coord)) }
    }

    /// The model id requests route by.
    pub fn name(&self) -> &str {
        &self.name
    }

    /// The pool's client handle (shape contract included).
    pub fn client(&self) -> &InferenceClient {
        &self.client
    }

    /// Blocking inference through the model's pool.
    pub fn infer(&self, x: Vec<f32>) -> Result<Vec<f32>> {
        self.client.infer(x)
    }

    /// Blocking inference with a deadline: the pool sheds the request
    /// (typed deadline error) instead of executing it once `timeout`
    /// passes; `None` waits forever. See
    /// [`InferenceClient::infer_within`].
    pub fn infer_within(&self, x: Vec<f32>, timeout: Option<Duration>) -> Result<Vec<f32>> {
        self.client.infer_within(x, timeout)
    }

    /// True while the model's pool is fully staffed (see
    /// [`Coordinator::healthy`]); false once any worker exhausted its
    /// restart budget, or after shutdown/swap took the pool away.
    pub fn healthy(&self) -> bool {
        lock(&self.coord).as_ref().map(Coordinator::healthy).unwrap_or(false)
    }

    /// Live metrics of the model's pool (`None` once shut down).
    pub fn metrics(&self) -> Option<MetricsSnapshot> {
        lock(&self.coord).as_ref().map(Coordinator::metrics)
    }

    /// Drain and join the pool, returning its final snapshot (`None`
    /// if it was already shut down).
    fn shutdown(&self) -> Option<MetricsSnapshot> {
        lock(&self.coord).take().map(Coordinator::shutdown)
    }
}

/// Named serving pools behind one front-end, with hot add/swap/remove
/// and shared per-tenant admission.
pub struct ModelRegistry {
    models: RwLock<HashMap<String, Arc<ModelEntry>>>,
    admission: Arc<TenantAdmission>,
}

impl ModelRegistry {
    /// New, empty registry under a tenant policy
    /// (`TenantPolicy::default()` disables admission control).
    pub fn new(policy: TenantPolicy) -> Self {
        Self {
            models: RwLock::new(HashMap::new()),
            admission: Arc::new(TenantAdmission::new(policy)),
        }
    }

    /// Register (or hot-swap) `name` to serve through `coord`. New
    /// lookups see the new pool immediately; when a pool is replaced,
    /// it is drained (in-flight requests complete) and its final
    /// snapshot returned.
    pub fn register(&self, name: &str, coord: Coordinator) -> Option<MetricsSnapshot> {
        let entry = Arc::new(ModelEntry::new(name, coord));
        let old = write(&self.models).insert(name.to_string(), entry);
        old.and_then(|e| e.shutdown())
    }

    /// Register (or hot-swap) a model by starting a pool over a named
    /// [`Backend`]; the registry name is [`ServeConfig::model`].
    pub fn register_backend(
        &self,
        backend: Backend,
        cfg: ServeConfig,
    ) -> Result<Option<MetricsSnapshot>> {
        let name = cfg.model.clone();
        let coord = Coordinator::start_backend(backend, cfg)?;
        Ok(self.register(&name, coord))
    }

    /// Look up a model by id.
    pub fn get(&self, name: &str) -> Option<Arc<ModelEntry>> {
        read(&self.models).get(name).cloned()
    }

    /// Registered model ids, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut out: Vec<String> = read(&self.models).keys().cloned().collect();
        out.sort();
        out
    }

    /// Number of registered models.
    pub fn len(&self) -> usize {
        read(&self.models).len()
    }

    /// True when no model is registered.
    pub fn is_empty(&self) -> bool {
        read(&self.models).is_empty()
    }

    /// Unregister `name`, draining its pool; returns the final
    /// snapshot if the model existed.
    pub fn remove(&self, name: &str) -> Option<MetricsSnapshot> {
        let old = write(&self.models).remove(name);
        old.and_then(|e| e.shutdown())
    }

    /// Drain and join every pool, returning `(name, final snapshot)`
    /// sorted by name. The registry is empty afterwards.
    pub fn shutdown_all(&self) -> Vec<(String, MetricsSnapshot)> {
        let entries: Vec<(String, Arc<ModelEntry>)> =
            write(&self.models).drain().collect();
        let mut out: Vec<(String, MetricsSnapshot)> = entries
            .into_iter()
            .filter_map(|(name, e)| e.shutdown().map(|s| (name, s)))
            .collect();
        out.sort_by(|a, b| a.0.cmp(&b.0));
        out
    }

    /// The shared tenant admission state.
    pub fn admission(&self) -> &Arc<TenantAdmission> {
        &self.admission
    }

    /// Prometheus text exposition over every live model (per-model
    /// counters, latency histogram, quantiles) plus per-tenant
    /// admission counters.
    pub fn prometheus(&self) -> String {
        let entries: Vec<(String, MetricsSnapshot)> = {
            let g = read(&self.models);
            let mut v: Vec<(String, MetricsSnapshot)> = g
                .iter()
                .filter_map(|(name, e)| e.metrics().map(|m| (name.clone(), m)))
                .collect();
            v.sort_by(|a, b| a.0.cmp(&b.0));
            v
        };
        let pairs: Vec<(&str, MetricsSnapshot)> =
            entries.iter().map(|(n, s)| (n.as_str(), s.clone())).collect();
        let mut out = metrics::prometheus_text(&pairs);
        let tenants = self.admission.counters();
        if !tenants.is_empty() {
            let label = |t: &str| {
                let esc = t.replace('\\', "\\\\").replace('"', "\\\"");
                format!("tenant=\"{esc}\"")
            };
            out.push_str("# HELP scnn_tenant_admitted_total Requests admitted per tenant.\n");
            out.push_str("# TYPE scnn_tenant_admitted_total counter\n");
            for t in &tenants {
                out.push_str(&format!(
                    "scnn_tenant_admitted_total{{{}}} {}\n",
                    label(&t.tenant),
                    t.admitted
                ));
            }
            out.push_str("# HELP scnn_tenant_shed_total Requests shed by tenant admission.\n");
            out.push_str("# TYPE scnn_tenant_shed_total counter\n");
            for t in &tenants {
                out.push_str(&format!(
                    "scnn_tenant_shed_total{{{}}} {}\n",
                    label(&t.tenant),
                    t.shed
                ));
            }
            out.push_str("# HELP scnn_tenant_inflight_peak Peak concurrent requests per tenant.\n");
            out.push_str("# TYPE scnn_tenant_inflight_peak gauge\n");
            for t in &tenants {
                out.push_str(&format!(
                    "scnn_tenant_inflight_peak{{{}}} {}\n",
                    label(&t.tenant),
                    t.peak
                ));
            }
        }
        out
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    use super::super::batcher::PoolConfig;
    use super::super::executor::{ExecutorSpec, SyntheticExecutor};

    const SPEC: ExecutorSpec = ExecutorSpec { image_len: 6, batch: 2, classes: 3 };

    fn pool() -> Coordinator {
        Coordinator::start_with(
            SyntheticExecutor::factory(SPEC, Duration::ZERO),
            PoolConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn priority_wire_roundtrip_and_parse() {
        for p in [Priority::High, Priority::Normal, Priority::Low] {
            assert_eq!(Priority::from_u8(p.as_u8()), Some(p));
            assert_eq!(Priority::parse(p.name()).unwrap(), p);
        }
        assert_eq!(Priority::from_u8(3), None);
        assert!(Priority::parse("urgent").is_err());
    }

    #[test]
    fn tenant_limits_scale_with_priority() {
        let p = TenantPolicy { max_inflight: 4 };
        assert_eq!(p.limit_for(Priority::High), 4);
        assert_eq!(p.limit_for(Priority::Normal), 3);
        assert_eq!(p.limit_for(Priority::Low), 2);
        // A quota of one admits every priority (ceil never rounds to 0).
        let one = TenantPolicy { max_inflight: 1 };
        assert_eq!(one.limit_for(Priority::Low), 1);
        // Zero disables admission control entirely.
        let off = TenantPolicy::default();
        assert_eq!(off.limit_for(Priority::High), usize::MAX);
    }

    #[test]
    fn admission_sheds_low_priority_first_and_releases_on_drop() {
        let adm = Arc::new(TenantAdmission::new(TenantPolicy { max_inflight: 4 }));
        let g1 = adm.try_admit("acme", Priority::Low).unwrap();
        let g2 = adm.try_admit("acme", Priority::Low).unwrap();
        // Low hits its 1/2 bound at 2 in-flight; Normal and High still fit.
        assert!(adm.try_admit("acme", Priority::Low).is_none());
        let g3 = adm.try_admit("acme", Priority::Normal).unwrap();
        assert!(adm.try_admit("acme", Priority::Normal).is_none());
        let g4 = adm.try_admit("acme", Priority::High).unwrap();
        assert!(adm.try_admit("acme", Priority::High).is_none());
        // Another tenant is unaffected by acme's saturation.
        let other = adm.try_admit("quiet", Priority::Low).unwrap();
        drop(other);
        // Releasing slots re-opens admission.
        drop(g4);
        assert!(adm.try_admit("acme", Priority::High).is_some());
        drop((g1, g2, g3));
        let c = adm.counters();
        assert_eq!(c.len(), 2);
        assert_eq!(c[0].tenant, "acme");
        assert_eq!(c[0].shed, 3);
        assert_eq!(c[0].peak, 4);
        assert_eq!(c[0].inflight, 1, "the re-admitted High guard is still alive");
        assert_eq!(c[1].tenant, "quiet");
        assert_eq!(c[1].shed, 0);
    }

    #[test]
    fn registry_registers_routes_and_hot_swaps() {
        let reg = ModelRegistry::new(TenantPolicy::default());
        assert!(reg.is_empty());
        assert!(reg.register("toy", pool()).is_none());
        assert_eq!(reg.names(), vec!["toy".to_string()]);
        let entry = reg.get("toy").expect("registered");
        assert!(entry.healthy(), "fresh pool is fully staffed");
        let logits = entry.infer(vec![0.5; SPEC.image_len]).unwrap();
        assert_eq!(logits.len(), SPEC.classes);
        let bounded = entry.infer_within(vec![0.5; SPEC.image_len], Some(Duration::from_secs(5)));
        assert_eq!(bounded.unwrap(), logits, "deadline path returns identical logits");
        assert!(reg.get("nope").is_none());
        // Hot swap: the old pool's final snapshot records its traffic.
        let old = reg.register("toy", pool()).expect("swap returns old snapshot");
        assert_eq!(old.requests, 1);
        // The swapped-in pool serves immediately.
        let entry = reg.get("toy").unwrap();
        assert_eq!(entry.infer(vec![0.25; SPEC.image_len]).unwrap().len(), SPEC.classes);
        assert_eq!(reg.len(), 1);
        let finals = reg.shutdown_all();
        assert_eq!(finals.len(), 1);
        assert_eq!(finals[0].1.requests, 1);
        assert!(reg.is_empty());
    }

    #[test]
    fn remove_drains_and_reports() {
        let reg = ModelRegistry::new(TenantPolicy::default());
        assert!(reg.register("a", pool()).is_none());
        reg.get("a").unwrap().infer(vec![0.0; SPEC.image_len]).unwrap();
        let snap = reg.remove("a").expect("existed");
        assert_eq!(snap.requests, 1);
        assert!(reg.remove("a").is_none());
    }

    #[test]
    fn prometheus_covers_models_and_tenants() {
        let reg = ModelRegistry::new(TenantPolicy { max_inflight: 1 });
        assert!(reg.register("toy", pool()).is_none());
        reg.get("toy").unwrap().infer(vec![0.1; SPEC.image_len]).unwrap();
        let g = reg.admission().try_admit("acme", Priority::High).unwrap();
        assert!(reg.admission().try_admit("acme", Priority::High).is_none());
        drop(g);
        let text = reg.prometheus();
        assert!(text.contains("scnn_requests_total{model=\"toy\"} 1"), "{text}");
        assert!(text.contains("scnn_tenant_admitted_total{tenant=\"acme\"} 1"), "{text}");
        assert!(text.contains("scnn_tenant_shed_total{tenant=\"acme\"} 1"), "{text}");
    }
}
