//! Unified backend registry for the inference pool.
//!
//! One enum names every way the coordinator can execute a batch, and
//! one function turns a name + [`ServeConfig`] into the
//! [`ExecutorFactory`] the pool consumes — replacing the per-backend
//! factory plumbing that used to be duplicated across `main.rs`,
//! `examples/serve.rs` and the benches.
//!
//! | Backend | Executor | Needs artifacts | What it serves |
//! |---------|----------|-----------------|----------------|
//! | `pjrt` | [`PjrtExecutor`] | yes | AOT-compiled serving HLO through PJRT |
//! | `sc` | [`ScBatchExecutor`] | no | the **native bit-exact SC model** via the batched [`crate::nn::ScEngine`] |
//! | `binary` | [`BinaryBatchExecutor`] | no | the binary fixed-point baseline over the same frozen network |
//! | `synthetic` | [`SyntheticExecutor`] | no | deterministic fixed-latency toy (tests/benches) |
//! | `auto` | — | — | resolves to `pjrt` when artifacts exist, else `synthetic` |
//!
//! The `sc` and `binary` backends freeze the model deterministically
//! from [`ServeConfig::seed`] ([`ModelParams::init`]) at the quant
//! point described by [`ServeConfig::knobs`], so a pool and a
//! single-threaded executor built from the same config are guaranteed
//! to serve the *same* network — the bit-identical-logits property
//! `rust/tests/sc_serve.rs` asserts.

use std::sync::Arc;

use crate::fault::guard::GuardCounters;
use crate::nn::gemm::BLOCK_CO;
use crate::nn::model::{ModelCfg, ModelParams};
use crate::nn::quant::{Pruning, QuantConfig};
use crate::nn::sc_exec::Prepared;
use crate::nn::SparsityCounters;
use crate::runtime::artifacts_ready;
use crate::runtime::trainer::Knobs;
use crate::util::Rng;
use crate::Result;

use super::batcher::ServeConfig;
use super::executor::{
    BinaryBatchExecutor, ExecutorFactory, PjrtExecutor, ScBatchExecutor, SyntheticExecutor,
};

/// Every executor backend the pool can run, by name.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Backend {
    /// Resolve at start time: `pjrt` when the model's AOT artifacts
    /// exist, else `synthetic`.
    Auto,
    /// AOT-compiled serving path through PJRT.
    Pjrt,
    /// Deterministic in-process toy model with fixed batch latency.
    Synthetic,
    /// Native bit-exact SC model through the batched engine.
    Sc,
    /// Binary fixed-point baseline over the same frozen network.
    Binary,
}

impl Backend {
    /// All selectable backends, in `--backend` help order.
    pub const ALL: [Backend; 5] =
        [Backend::Auto, Backend::Pjrt, Backend::Synthetic, Backend::Sc, Backend::Binary];

    /// Parse a `--backend` flag value.
    pub fn parse(s: &str) -> Result<Backend> {
        match s {
            "auto" => Ok(Backend::Auto),
            "pjrt" => Ok(Backend::Pjrt),
            "synthetic" => Ok(Backend::Synthetic),
            "sc" => Ok(Backend::Sc),
            "binary" => Ok(Backend::Binary),
            other => anyhow::bail!("unknown backend {other:?} (auto|pjrt|synthetic|sc|binary)"),
        }
    }

    /// The flag spelling.
    pub fn name(self) -> &'static str {
        match self {
            Backend::Auto => "auto",
            Backend::Pjrt => "pjrt",
            Backend::Synthetic => "synthetic",
            Backend::Sc => "sc",
            Backend::Binary => "binary",
        }
    }

    /// Resolve [`Backend::Auto`] against the artifact store; concrete
    /// backends return themselves.
    pub fn resolve(self, artifacts: &str, model: &str) -> Backend {
        match self {
            Backend::Auto => {
                if artifacts_ready(artifacts, model) {
                    Backend::Pjrt
                } else {
                    Backend::Synthetic
                }
            }
            b => b,
        }
    }

    /// Build the pool's [`ExecutorFactory`] for this backend from a
    /// [`ServeConfig`]. `Auto` is resolved first. Takes the config by
    /// value so the PJRT arm can *move* the (potentially large)
    /// trained-parameter blobs into the worker closure instead of
    /// deep-cloning them.
    pub fn factory(self, cfg: ServeConfig) -> Result<ExecutorFactory> {
        self.factory_with(cfg, None, None)
    }

    /// [`Backend::factory`] with an optional datapath-guard counter
    /// block (see [`ServeConfig::guard`]) and an optional sparsity
    /// telemetry sink. Only the `sc` backend has a count-domain
    /// datapath to guard or a sparse GEMM path to meter; the other
    /// backends ignore both.
    pub fn factory_with(
        self,
        cfg: ServeConfig,
        guard: Option<Arc<GuardCounters>>,
        sparsity: Option<Arc<SparsityCounters>>,
    ) -> Result<ExecutorFactory> {
        match self.resolve(&cfg.artifacts, &cfg.model) {
            Backend::Pjrt => {
                let ServeConfig { artifacts, model, params, knobs, .. } = cfg;
                Ok(Box::new(move |_worker| {
                    let exec = PjrtExecutor::new(&artifacts, &model, params.as_deref(), knobs)?;
                    Ok(Box::new(exec))
                }))
            }
            Backend::Synthetic => {
                let mc = model_cfg_for(&cfg.model)?;
                let (c, h, w) = mc.input;
                Ok(SyntheticExecutor::demo_factory(c * h * w, mc.num_classes))
            }
            Backend::Sc => Ok(ScBatchExecutor::factory_with(
                prepared_for(&cfg)?,
                cfg.batch,
                cfg.threads,
                guard,
                sparsity,
            )),
            Backend::Binary => Ok(BinaryBatchExecutor::factory(prepared_for(&cfg)?, cfg.batch)),
            Backend::Auto => unreachable!("resolve() never returns Auto"),
        }
    }
}

impl std::fmt::Display for Backend {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

/// Every model name the serving stack can freeze from pure Rust —
/// the universe `scnn serve --models all` expands to and the registry
/// front-end routes between.
pub const MODEL_NAMES: [&str; 3] = ["tnn", "scnet10", "scnet20"];

/// The pure-Rust model configuration behind an artifact name.
pub fn model_cfg_for(model: &str) -> Result<ModelCfg> {
    match model {
        "tnn" => Ok(ModelCfg::tnn()),
        "scnet10" => Ok(ModelCfg::scnet(10)),
        "scnet20" => Ok(ModelCfg::scnet(20)),
        other => anyhow::bail!("unknown model {other:?} (tnn|scnet10|scnet20)"),
    }
}

/// Map the serving [`Knobs`] onto the SC executor's [`QuantConfig`].
/// The SC datapath is always quantized, so float knobs are rejected —
/// and so are disabled (`res_on = 0`) or float residuals: the frozen
/// [`Prepared`] network always wires the residual taps its model
/// config declares (a `residual_bsl` of `None` silently means
/// "default BSL 16" there, not "off"), so accepting those knobs would
/// serve a different network than requested.
pub fn quant_from_knobs(k: &Knobs) -> Result<QuantConfig> {
    anyhow::ensure!(
        k.act_fp == 0.0 && k.w_fp == 0.0,
        "the SC/binary backends require quantized activations and ternary weights"
    );
    anyhow::ensure!(
        k.res_on != 0.0 && k.res_fp == 0.0,
        "the SC/binary backends cannot disable or float the residual path \
         (the frozen SC network always wires its residual taps); \
         use --res-bsl <B> or omit the flag"
    );
    let act_bsl = (k.act_half * 2.0).round() as usize;
    let residual_bsl = Some((k.res_half * 2.0).round() as usize);
    let pruning = pruning_from_knobs(k)?;
    Ok(QuantConfig { act_bsl: Some(act_bsl), weight_ternary: true, residual_bsl, pruning })
}

/// Validate and map the pruning knobs onto [`Pruning`]. Invalid
/// configurations — `N > M`, `N = 0`, a block size that does not divide
/// the GEMM channel tile [`BLOCK_CO`], or both schemes at once — are
/// typed errors here, not silently-dense panels.
pub fn pruning_from_knobs(k: &Knobs) -> Result<Pruning> {
    let (n, m, b) = (k.prune_n as usize, k.prune_m as usize, k.prune_block as usize);
    let nm_on = n != 0 || m != 0;
    let block_on = b != 0;
    anyhow::ensure!(
        !(nm_on && block_on),
        "--prune and --prune-block are mutually exclusive (pick one pruning scheme)"
    );
    if nm_on {
        anyhow::ensure!(
            1 <= n && n <= m,
            "invalid N:M pruning {n}:{m} — need 1 <= N <= M (e.g. --prune 2:4)"
        );
        return Ok(Pruning::Nm { n, m });
    }
    if block_on {
        anyhow::ensure!(
            BLOCK_CO % b == 0,
            "invalid pruning block size {b} — must divide the GEMM channel tile {BLOCK_CO}"
        );
        return Ok(Pruning::Block { size: b });
    }
    Ok(Pruning::Off)
}

/// Freeze the served model for the native backends: deterministic
/// parameters from [`ServeConfig::seed`], quantization from
/// [`ServeConfig::knobs`], shared behind one [`Arc`] by every worker.
pub fn prepared_for(cfg: &ServeConfig) -> Result<Arc<Prepared>> {
    let mc = model_cfg_for(&cfg.model)?;
    let quant = quant_from_knobs(&cfg.knobs)?;
    let mut rng = Rng::new(cfg.seed);
    let params = ModelParams::init(&mc, &mut rng);
    Ok(Arc::new(Prepared::new(&mc, &params, quant)))
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrips_all_names() {
        for b in Backend::ALL {
            assert_eq!(Backend::parse(b.name()).unwrap(), b);
            assert_eq!(format!("{b}"), b.name());
        }
        assert!(Backend::parse("tpu").is_err());
    }

    #[test]
    fn auto_resolves_to_synthetic_without_artifacts() {
        let b = Backend::Auto.resolve("definitely/not/a/dir", "scnet10");
        assert_eq!(b, Backend::Synthetic);
        assert_eq!(Backend::Sc.resolve("definitely/not/a/dir", "scnet10"), Backend::Sc);
    }

    #[test]
    fn knob_mapping_matches_paper_configs() {
        let q = quant_from_knobs(&Knobs::quantized(2).with_res_bsl(Some(16))).unwrap();
        assert_eq!(q, QuantConfig::w2a2r16());
        let q4 = quant_from_knobs(&Knobs::quantized(4)).unwrap();
        assert_eq!(q4.act_bsl, Some(4));
        assert_eq!(q4.residual_bsl, Some(16));
        assert!(quant_from_knobs(&Knobs::float()).is_err());
        // Disabled or float residuals are unrepresentable in the frozen
        // SC network and must be rejected, not silently served at R16.
        assert!(quant_from_knobs(&Knobs::quantized(2).with_res_bsl(None)).is_err());
        assert!(quant_from_knobs(&Knobs::quantized(2).with_float_res()).is_err());
    }

    #[test]
    fn pruning_knobs_validate_and_map() {
        let q = quant_from_knobs(&Knobs::quantized(2).with_pruning(2, 4)).unwrap();
        assert_eq!(q.pruning, Pruning::Nm { n: 2, m: 4 });
        let qb = quant_from_knobs(&Knobs::quantized(2).with_block_pruning(4)).unwrap();
        assert_eq!(qb.pruning, Pruning::Block { size: 4 });
        assert_eq!(quant_from_knobs(&Knobs::quantized(2)).unwrap().pruning, Pruning::Off);
        // Invalid configs are typed errors, not silently-dense panels.
        assert!(quant_from_knobs(&Knobs::quantized(2).with_pruning(4, 2)).is_err(), "N > M");
        assert!(quant_from_knobs(&Knobs::quantized(2).with_pruning(0, 4)).is_err(), "N = 0");
        assert!(
            quant_from_knobs(&Knobs::quantized(2).with_block_pruning(3)).is_err(),
            "3 does not divide the channel tile {BLOCK_CO}"
        );
        let mut both = Knobs::quantized(2).with_pruning(2, 4);
        both.prune_block = 4.0;
        assert!(quant_from_knobs(&both).is_err(), "two schemes at once");
    }

    #[test]
    fn prepared_for_is_deterministic_in_the_seed() {
        let mut cfg = ServeConfig::new("artifacts", "tnn");
        cfg.seed = 11;
        let a = prepared_for(&cfg).unwrap();
        let b = prepared_for(&cfg).unwrap();
        assert_eq!(a.convs.len(), b.convs.len());
        assert_eq!(a.fc.values, b.fc.values);
        assert_eq!(a.input_alpha, b.input_alpha);
    }

    #[test]
    fn model_names_const_matches_model_cfg_for() {
        for name in MODEL_NAMES {
            assert!(model_cfg_for(name).is_ok(), "{name} must be freezable");
        }
    }

    #[test]
    fn unknown_model_is_rejected() {
        assert!(model_cfg_for("resnet50").is_err());
        let mut cfg = ServeConfig::new("artifacts", "resnet50");
        cfg.seed = 1;
        assert!(prepared_for(&cfg).is_err());
    }
}
