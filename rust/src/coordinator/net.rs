//! Network serving front-end: a length-prefixed binary protocol over
//! `std::net` (std-only, no async runtime), putting a real wire in
//! front of the in-process coordinator pools.
//!
//! ## Wire protocol
//!
//! Every frame is `u32 LE length` + body; the body starts with a
//! fixed header (`magic u32 LE`, `version u8`, `kind u8`) followed by
//! kind-specific fields (all integers LE, all floats f32 LE):
//!
//! | kind | frame | body after header |
//! |------|-------|-------------------|
//! | 0 | infer request | `id u64`, `priority u8`, `deadline_ms u32` (v2+), `model_len u8`, `tenant_len u8`, model utf-8, tenant utf-8, `count u32`, `count × f32` |
//! | 1 | infer response | `id u64`, `status u8`, `count u32`, then `count × f32` logits (status 0) or `count` utf-8 message bytes |
//! | 2 | metrics request | `id u64` |
//! | 3 | metrics response | `id u64`, `count u32`, `count` utf-8 bytes (Prometheus text) |
//!
//! **Versioning**: the current version is [`VERSION`]; every version
//! down to [`MIN_VERSION`] still decodes. v2 added the per-request
//! `deadline_ms` field (`0` = no deadline) — a v1 frame simply has no
//! deadline, so old clients keep working with deadline = ∞. The
//! server stamps each reply with the version of the request it
//! answers, so a v1 client never sees a v2 frame (nor the v2-only
//! `Expired` status, which requires sending a deadline in the first
//! place).
//!
//! Frames longer than [`MAX_FRAME`] bytes, bad magic/version/kind,
//! non-utf-8 ids, or bodies whose declared lengths disagree with the
//! frame length are **malformed**: the server answers with a
//! `BadRequest` response (id 0 if the id never decoded) and closes
//! the connection — a corrupt byte stream cannot be resynchronized,
//! but it must never panic a server thread.
//!
//! ## Threading model
//!
//! [`NetServer::bind`] spawns one acceptor thread; each accepted
//! connection gets its own reader thread that decodes frames with a
//! [`FrameReader`] (robust to any `read()` fragmentation, down to one
//! byte at a time), serves each request *synchronously* through the
//! [`ModelRegistry`] — tenant admission first, then the model pool's
//! own Block/Shed policy — and writes the response back on the same
//! socket. [`NetServer::shutdown`] stops the acceptor, lets every
//! connection finish the frame it is serving (requests already
//! buffered are drained, in-flight responses are written), and joins
//! all threads before returning.
//!
//! ## Failure handling
//!
//! Connection-handle bookkeeping is bounded: finished reader threads
//! are reaped on every accept and by a periodic sweeper, so an
//! always-on server does not leak one [`JoinHandle`] per past
//! connection. [`NetClient`] never blocks forever: connects, reads
//! and writes all carry timeouts (a hung server surfaces as a typed
//! [`TIMEOUT_ERROR`]), per-request deadlines ride the v2 wire header
//! into the pool, and idempotent exchanges (infer/classify/metrics)
//! retry over a fresh connection with jittered exponential backoff
//! under a bounded [`RetryPolicy`].

use std::io::{ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use crate::util::Rng;
use crate::Result;
use anyhow::Context;

use super::batcher::{
    is_deadline_error, is_shed_error, DEADLINE_EXPIRED_ERROR, SHED_ERROR, WORKER_PANIC_ERROR,
};
use super::registry::{ModelRegistry, Priority};

/// Lock, recovering from poison (a panicking connection thread must
/// not wedge the acceptor's handle bookkeeping).
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Frame magic: `"SCNN"` read as a little-endian u32.
pub const MAGIC: u32 = u32::from_le_bytes(*b"SCNN");

/// Protocol version stamped on frames this build encodes by default.
/// v2 added the infer-request `deadline_ms` field.
pub const VERSION: u8 = 2;

/// Oldest protocol version this build still decodes (and can encode,
/// for replies to old peers).
pub const MIN_VERSION: u8 = 1;

/// Hard cap on one frame's body length (16 MiB): anything larger is
/// rejected as malformed before buffering, so a bogus length prefix
/// cannot make the server allocate unboundedly.
pub const MAX_FRAME: usize = 1 << 24;

const KIND_INFER: u8 = 0;
const KIND_RESPONSE: u8 = 1;
const KIND_METRICS: u8 = 2;
const KIND_METRICS_TEXT: u8 = 3;

/// How often a connection thread re-checks the stop flag while idle.
const READ_POLL: Duration = Duration::from_millis(50);

/// How often the server's sweeper thread reaps finished connection
/// handles (accept-time reaping covers busy servers; the sweeper
/// covers idle ones).
const REAP_INTERVAL: Duration = Duration::from_millis(250);

/// Default client connect timeout.
pub const CONNECT_TIMEOUT: Duration = Duration::from_secs(5);

/// Default client read (response-wait) timeout.
pub const READ_TIMEOUT: Duration = Duration::from_secs(30);

/// Default client write timeout.
pub const WRITE_TIMEOUT: Duration = Duration::from_secs(30);

/// Socket-level read slice the client polls at so it can enforce its
/// own response budget without hanging in the kernel.
const CLIENT_READ_SLICE: Duration = Duration::from_millis(50);

/// Extra slack the client waits past its own deadline before giving
/// up on the socket — lets the server's `deadline expired` response
/// arrive instead of a generic timeout.
const CLIENT_DEADLINE_GRACE: Duration = Duration::from_secs(1);

/// Marker prefix for client-side socket timeouts; test with
/// [`is_timeout_error`].
pub const TIMEOUT_ERROR: &str = "timed out: no response from server";

/// `true` when `e` is a client-side socket timeout.
pub fn is_timeout_error(e: &anyhow::Error) -> bool {
    format!("{e}").starts_with(TIMEOUT_ERROR)
}

/// Response status byte.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Status {
    /// Success; the payload is the logits row.
    Ok,
    /// Rejected by load shedding (pool overload or tenant admission).
    Shed,
    /// Malformed frame or wrong payload shape.
    BadRequest,
    /// The frame named a model id the registry does not hold.
    UnknownModel,
    /// Executor/internal failure.
    Error,
    /// The request's deadline passed before execution; the pool shed
    /// it (v2+ — only ever sent to peers that set a deadline).
    Expired,
}

impl Status {
    fn as_u8(self) -> u8 {
        match self {
            Status::Ok => 0,
            Status::Shed => 1,
            Status::BadRequest => 2,
            Status::UnknownModel => 3,
            Status::Error => 4,
            Status::Expired => 5,
        }
    }

    fn from_u8(v: u8) -> Option<Status> {
        match v {
            0 => Some(Status::Ok),
            1 => Some(Status::Shed),
            2 => Some(Status::BadRequest),
            3 => Some(Status::UnknownModel),
            4 => Some(Status::Error),
            5 => Some(Status::Expired),
            _ => None,
        }
    }
}

/// One inference request as carried on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct InferRequest {
    /// Client-chosen id, echoed in the response.
    pub id: u64,
    /// Admission priority (lower sheds first under tenant load).
    pub priority: Priority,
    /// Per-request deadline in milliseconds from server receipt; `0`
    /// means none (the v1 behavior — v1 frames decode to `0`).
    pub deadline_ms: u32,
    /// Model id to route by (≤ 255 bytes utf-8).
    pub model: String,
    /// Tenant id for admission accounting (≤ 255 bytes utf-8).
    pub tenant: String,
    /// Flattened image (C·H·W floats).
    pub payload: Vec<f32>,
}

/// One inference response as carried on the wire.
#[derive(Clone, Debug, PartialEq)]
pub struct InferResponse {
    /// Echo of the request id (0 when the request never decoded).
    pub id: u64,
    /// Outcome.
    pub status: Status,
    /// Logits (empty unless `status == Ok`).
    pub logits: Vec<f32>,
    /// Error message (empty when `status == Ok`).
    pub message: String,
}

impl InferResponse {
    /// Success response.
    pub fn ok(id: u64, logits: Vec<f32>) -> Self {
        Self { id, status: Status::Ok, logits, message: String::new() }
    }

    /// Failure response.
    pub fn fail(id: u64, status: Status, message: impl Into<String>) -> Self {
        Self { id, status, logits: Vec::new(), message: message.into() }
    }
}

/// Every frame the protocol speaks.
#[derive(Clone, Debug, PartialEq)]
pub enum Frame {
    /// Client → server inference request.
    Infer(InferRequest),
    /// Server → client inference response.
    Response(InferResponse),
    /// Client → server metrics scrape.
    MetricsRequest {
        /// Client-chosen id, echoed back.
        id: u64,
    },
    /// Server → client Prometheus text exposition.
    MetricsText {
        /// Echo of the request id.
        id: u64,
        /// Prometheus text-format payload.
        text: String,
    },
}

/// Serialize one frame (length prefix included) onto `out` at the
/// current [`VERSION`].
pub fn encode_frame(frame: &Frame, out: &mut Vec<u8>) -> Result<()> {
    encode_frame_v(frame, VERSION, out)
}

/// Serialize one frame at an explicit protocol version in
/// `MIN_VERSION..=VERSION` — the server answers every peer at the
/// version it spoke, so old clients never receive frames they cannot
/// decode.
pub fn encode_frame_v(frame: &Frame, version: u8, out: &mut Vec<u8>) -> Result<()> {
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "unsupported protocol version {version} (supported {MIN_VERSION}..={VERSION})"
    );
    let start = out.len();
    out.extend_from_slice(&[0u8; 4]); // length placeholder
    out.extend_from_slice(&MAGIC.to_le_bytes());
    out.push(version);
    match frame {
        Frame::Infer(r) => {
            anyhow::ensure!(r.model.len() <= u8::MAX as usize, "model id too long");
            anyhow::ensure!(r.tenant.len() <= u8::MAX as usize, "tenant id too long");
            out.push(KIND_INFER);
            out.extend_from_slice(&r.id.to_le_bytes());
            out.push(r.priority.as_u8());
            if version >= 2 {
                out.extend_from_slice(&r.deadline_ms.to_le_bytes());
            } else {
                // A v1 frame has nowhere to carry the deadline; encode
                // only deadline-free requests rather than dropping it
                // silently.
                anyhow::ensure!(r.deadline_ms == 0, "deadlines need protocol v2");
            }
            out.push(r.model.len() as u8);
            out.push(r.tenant.len() as u8);
            out.extend_from_slice(r.model.as_bytes());
            out.extend_from_slice(r.tenant.as_bytes());
            out.extend_from_slice(&(r.payload.len() as u32).to_le_bytes());
            for v in &r.payload {
                out.extend_from_slice(&v.to_le_bytes());
            }
        }
        Frame::Response(r) => {
            out.push(KIND_RESPONSE);
            out.extend_from_slice(&r.id.to_le_bytes());
            out.push(r.status.as_u8());
            if r.status == Status::Ok {
                out.extend_from_slice(&(r.logits.len() as u32).to_le_bytes());
                for v in &r.logits {
                    out.extend_from_slice(&v.to_le_bytes());
                }
            } else {
                out.extend_from_slice(&(r.message.len() as u32).to_le_bytes());
                out.extend_from_slice(r.message.as_bytes());
            }
        }
        Frame::MetricsRequest { id } => {
            out.push(KIND_METRICS);
            out.extend_from_slice(&id.to_le_bytes());
        }
        Frame::MetricsText { id, text } => {
            out.push(KIND_METRICS_TEXT);
            out.extend_from_slice(&id.to_le_bytes());
            out.extend_from_slice(&(text.len() as u32).to_le_bytes());
            out.extend_from_slice(text.as_bytes());
        }
    }
    let body_len = out.len() - start - 4;
    anyhow::ensure!(body_len <= MAX_FRAME, "frame body {body_len} bytes exceeds {MAX_FRAME}");
    out[start..start + 4].copy_from_slice(&(body_len as u32).to_le_bytes());
    Ok(())
}

/// Bounds-checked cursor over one frame body.
struct Cur<'a> {
    b: &'a [u8],
    p: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        anyhow::ensure!(self.b.len() - self.p >= n, "malformed frame: truncated body");
        let s = &self.b[self.p..self.p + n];
        self.p += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        let mut a = [0u8; 4];
        a.copy_from_slice(self.take(4)?);
        Ok(u32::from_le_bytes(a))
    }

    fn u64(&mut self) -> Result<u64> {
        let mut a = [0u8; 8];
        a.copy_from_slice(self.take(8)?);
        Ok(u64::from_le_bytes(a))
    }

    fn utf8(&mut self, n: usize) -> Result<String> {
        let bytes = self.take(n)?;
        String::from_utf8(bytes.to_vec()).map_err(|_| anyhow::anyhow!("malformed frame: bad utf-8"))
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = self.take(n.checked_mul(4).context("malformed frame: payload count")?)?;
        Ok(bytes
            .chunks_exact(4)
            .map(|c| {
                let mut a = [0u8; 4];
                a.copy_from_slice(c);
                f32::from_le_bytes(a)
            })
            .collect())
    }

    fn done(&self) -> Result<()> {
        anyhow::ensure!(self.p == self.b.len(), "malformed frame: trailing bytes");
        Ok(())
    }
}

/// Decode one frame body (the bytes after the length prefix),
/// discarding the version it was encoded at.
pub fn decode_body(body: &[u8]) -> Result<Frame> {
    decode_body_v(body).map(|(_, f)| f)
}

/// Decode one frame body, returning `(version, frame)`. Accepts any
/// version in `MIN_VERSION..=VERSION`; v1 infer frames carry no
/// deadline field and decode with `deadline_ms == 0` (no deadline).
pub fn decode_body_v(body: &[u8]) -> Result<(u8, Frame)> {
    let mut c = Cur { b: body, p: 0 };
    let magic = c.u32()?;
    anyhow::ensure!(magic == MAGIC, "malformed frame: bad magic {magic:#010x}");
    let version = c.u8()?;
    anyhow::ensure!(
        (MIN_VERSION..=VERSION).contains(&version),
        "malformed frame: version {version} (supported {MIN_VERSION}..={VERSION})"
    );
    let kind = c.u8()?;
    let frame = match kind {
        KIND_INFER => {
            let id = c.u64()?;
            let priority = Priority::from_u8(c.u8()?)
                .ok_or_else(|| anyhow::anyhow!("malformed frame: bad priority byte"))?;
            let deadline_ms = if version >= 2 { c.u32()? } else { 0 };
            let model_len = c.u8()? as usize;
            let tenant_len = c.u8()? as usize;
            let model = c.utf8(model_len)?;
            let tenant = c.utf8(tenant_len)?;
            let count = c.u32()? as usize;
            let payload = c.f32s(count)?;
            Frame::Infer(InferRequest { id, priority, deadline_ms, model, tenant, payload })
        }
        KIND_RESPONSE => {
            let id = c.u64()?;
            let status = Status::from_u8(c.u8()?)
                .ok_or_else(|| anyhow::anyhow!("malformed frame: bad status byte"))?;
            let count = c.u32()? as usize;
            if status == Status::Ok {
                let logits = c.f32s(count)?;
                Frame::Response(InferResponse { id, status, logits, message: String::new() })
            } else {
                let message = c.utf8(count)?;
                Frame::Response(InferResponse { id, status, logits: Vec::new(), message })
            }
        }
        KIND_METRICS => Frame::MetricsRequest { id: c.u64()? },
        KIND_METRICS_TEXT => {
            let id = c.u64()?;
            let count = c.u32()? as usize;
            let text = c.utf8(count)?;
            Frame::MetricsText { id, text }
        }
        other => anyhow::bail!("malformed frame: unknown kind {other}"),
    };
    c.done()?;
    Ok((version, frame))
}

/// Incremental frame decoder: feed arbitrary byte chunks (any
/// `read()` fragmentation, down to a 1-byte trickle), pull complete
/// frames out. Malformed input returns `Err` — the caller must treat
/// the stream as unrecoverable.
#[derive(Debug)]
pub struct FrameReader {
    buf: Vec<u8>,
    pos: usize,
    last_version: u8,
}

impl Default for FrameReader {
    fn default() -> Self {
        Self { buf: Vec::new(), pos: 0, last_version: VERSION }
    }
}

impl FrameReader {
    /// New, empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Protocol version of the most recently decoded frame (the
    /// current [`VERSION`] until a frame has been decoded). The server
    /// answers each peer at this version so v1 clients never receive
    /// v2 frames.
    pub fn last_version(&self) -> u8 {
        self.last_version
    }

    /// Append raw bytes from the transport.
    pub fn feed(&mut self, bytes: &[u8]) {
        if self.pos > 0 && self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        }
        self.buf.extend_from_slice(bytes);
    }

    /// Bytes buffered but not yet decoded (a partial frame, or frames
    /// not yet pulled).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// Try to decode the next complete frame: `Ok(None)` means more
    /// bytes are needed; `Err` means the stream is malformed (bad
    /// magic/version/kind, oversized declared length, inconsistent
    /// body) and must be dropped.
    pub fn try_next(&mut self) -> Result<Option<Frame>> {
        if self.buffered() < 4 {
            return Ok(None);
        }
        let mut len_bytes = [0u8; 4];
        len_bytes.copy_from_slice(&self.buf[self.pos..self.pos + 4]);
        let len = u32::from_le_bytes(len_bytes) as usize;
        anyhow::ensure!(len <= MAX_FRAME, "malformed frame: declared length {len} exceeds max");
        if self.buffered() < 4 + len {
            return Ok(None);
        }
        let decoded = decode_body_v(&self.buf[self.pos + 4..self.pos + 4 + len]);
        self.pos += 4 + len;
        if self.pos == self.buf.len() {
            self.buf.clear();
            self.pos = 0;
        } else if self.pos > (1 << 16) {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        decoded.map(|(version, frame)| {
            self.last_version = version;
            Some(frame)
        })
    }
}

/// State shared by the acceptor, the connection threads and the
/// server handle.
struct ServerShared {
    registry: Arc<ModelRegistry>,
    stop: AtomicBool,
    accepted: AtomicU64,
    active: AtomicUsize,
    malformed: AtomicU64,
    reaped: AtomicU64,
}

impl ServerShared {
    /// Prometheus text: registry (per-model + per-tenant) families
    /// plus the server's own connection counters.
    fn metrics_text(&self) -> String {
        let mut out = self.registry.prometheus();
        out.push_str("# HELP scnn_connections_accepted_total Connections accepted.\n");
        out.push_str("# TYPE scnn_connections_accepted_total counter\n");
        out.push_str(&format!(
            "scnn_connections_accepted_total {}\n",
            self.accepted.load(Ordering::Relaxed)
        ));
        out.push_str("# HELP scnn_connections_active Connections currently open.\n");
        out.push_str("# TYPE scnn_connections_active gauge\n");
        out.push_str(&format!("scnn_connections_active {}\n", self.active.load(Ordering::Relaxed)));
        out.push_str("# HELP scnn_frames_malformed_total Frames rejected as malformed.\n");
        out.push_str("# TYPE scnn_frames_malformed_total counter\n");
        out.push_str(&format!(
            "scnn_frames_malformed_total {}\n",
            self.malformed.load(Ordering::Relaxed)
        ));
        out.push_str("# HELP scnn_connections_reaped_total Finished connection handles reaped.\n");
        out.push_str("# TYPE scnn_connections_reaped_total counter\n");
        out.push_str(&format!(
            "scnn_connections_reaped_total {}\n",
            self.reaped.load(Ordering::Relaxed)
        ));
        out
    }
}

/// Drop (join) every finished connection handle in `conns`, crediting
/// the count to the server's reaped counter. Called on every accept
/// and by the periodic sweeper so the handle vector stays bounded by
/// the number of *live* connections, not the connection history.
fn reap_finished(conns: &Mutex<Vec<JoinHandle<()>>>, shared: &ServerShared) {
    let finished: Vec<JoinHandle<()>> = {
        let mut g = lock(conns);
        let mut done = Vec::new();
        let mut i = 0;
        while i < g.len() {
            if g[i].is_finished() {
                done.push(g.swap_remove(i));
            } else {
                i += 1;
            }
        }
        done
    };
    if !finished.is_empty() {
        shared.reaped.fetch_add(finished.len() as u64, Ordering::Relaxed);
        for h in finished {
            let _ = h.join(); // already finished: join is immediate
        }
    }
}

/// The running TCP front-end: one acceptor thread + one reader thread
/// per connection, all serving through a shared [`ModelRegistry`].
pub struct NetServer {
    shared: Arc<ServerShared>,
    local_addr: SocketAddr,
    acceptor: Option<JoinHandle<()>>,
    sweeper: Option<JoinHandle<()>>,
    conns: Arc<Mutex<Vec<JoinHandle<()>>>>,
}

impl NetServer {
    /// Bind `addr` (e.g. `"127.0.0.1:0"` for an ephemeral port) and
    /// start accepting connections against `registry`.
    pub fn bind(addr: &str, registry: Arc<ModelRegistry>) -> Result<NetServer> {
        let listener = TcpListener::bind(addr).with_context(|| format!("binding {addr}"))?;
        let local_addr = listener.local_addr().context("resolving bound address")?;
        let shared = Arc::new(ServerShared {
            registry,
            stop: AtomicBool::new(false),
            accepted: AtomicU64::new(0),
            active: AtomicUsize::new(0),
            malformed: AtomicU64::new(0),
            reaped: AtomicU64::new(0),
        });
        let conns: Arc<Mutex<Vec<JoinHandle<()>>>> = Arc::new(Mutex::new(Vec::new()));
        let acceptor = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("scnn-acceptor".into())
                .spawn(move || loop {
                    match listener.accept() {
                        Ok((stream, _peer)) => {
                            if shared.stop.load(Ordering::Relaxed) {
                                // The shutdown wake-up (or a raced
                                // late client): stop accepting.
                                break;
                            }
                            shared.accepted.fetch_add(1, Ordering::Relaxed);
                            reap_finished(&conns, &shared);
                            let shared = shared.clone();
                            let handle = std::thread::Builder::new()
                                .name("scnn-conn".into())
                                .spawn(move || {
                                    shared.active.fetch_add(1, Ordering::Relaxed);
                                    serve_connection(stream, &shared);
                                    shared.active.fetch_sub(1, Ordering::Relaxed);
                                });
                            match handle {
                                Ok(h) => lock(&conns).push(h),
                                Err(_) => break,
                            }
                        }
                        Err(_) => {
                            if shared.stop.load(Ordering::Relaxed) {
                                break;
                            }
                        }
                    }
                })
                .context("spawning acceptor thread")?
        };
        let sweeper = {
            let shared = shared.clone();
            let conns = conns.clone();
            std::thread::Builder::new()
                .name("scnn-reaper".into())
                .spawn(move || {
                    // Poll the stop flag more often than we sweep so
                    // shutdown never waits a full sweep interval.
                    let slice = Duration::from_millis(25);
                    let mut since_sweep = Duration::ZERO;
                    while !shared.stop.load(Ordering::Relaxed) {
                        std::thread::sleep(slice);
                        since_sweep += slice;
                        if since_sweep >= REAP_INTERVAL {
                            reap_finished(&conns, &shared);
                            since_sweep = Duration::ZERO;
                        }
                    }
                })
                .context("spawning reaper thread")?
        };
        Ok(NetServer {
            shared,
            local_addr,
            acceptor: Some(acceptor),
            sweeper: Some(sweeper),
            conns,
        })
    }

    /// The bound address (resolves `:0` to the ephemeral port).
    pub fn local_addr(&self) -> SocketAddr {
        self.local_addr
    }

    /// Connections accepted so far.
    pub fn connections_accepted(&self) -> u64 {
        self.shared.accepted.load(Ordering::Relaxed)
    }

    /// Connection handles currently tracked (live connections plus
    /// any finished ones not yet reaped) — bounded on long-lived
    /// servers, unlike the pre-reaping accept history.
    pub fn tracked_connections(&self) -> usize {
        lock(&self.conns).len()
    }

    /// Finished connection handles reaped so far.
    pub fn connections_reaped(&self) -> u64 {
        self.shared.reaped.load(Ordering::Relaxed)
    }

    /// The Prometheus exposition a metrics frame returns (registry
    /// families + server connection counters).
    pub fn prometheus(&self) -> String {
        self.shared.metrics_text()
    }

    /// Graceful shutdown: stop accepting, let every connection finish
    /// (buffered requests are served, in-flight responses written),
    /// and join all threads. Model pools are left running — shut the
    /// registry down separately ([`ModelRegistry::shutdown_all`]).
    pub fn shutdown(mut self) {
        self.stop_and_wake();
        if let Some(h) = self.acceptor.take() {
            let _ = h.join();
        }
        if let Some(h) = self.sweeper.take() {
            let _ = h.join();
        }
        let handles: Vec<JoinHandle<()>> = {
            let mut g = lock(&self.conns);
            g.drain(..).collect()
        };
        for h in handles {
            let _ = h.join();
        }
    }

    fn stop_and_wake(&self) {
        self.shared.stop.store(true, Ordering::Relaxed);
        // Wake the acceptor out of its blocking accept().
        let _ = TcpStream::connect(self.local_addr);
    }
}

impl Drop for NetServer {
    fn drop(&mut self) {
        // Signal stop without joining: connection threads observe the
        // flag at their next read poll (≤ 50 ms) and exit.
        self.stop_and_wake();
    }
}

/// One connection: decode frames, serve them in order, write replies
/// on the same socket. Returns when the peer closes, the stream
/// errors, a malformed frame arrives, or the server stops (after
/// draining every complete frame already received).
fn serve_connection(stream: TcpStream, shared: &Arc<ServerShared>) {
    let _ = stream.set_nodelay(true);
    let _ = stream.set_read_timeout(Some(READ_POLL));
    let mut stream = stream;
    let mut reader = FrameReader::new();
    let mut rbuf = [0u8; 8192];
    let mut wbuf = Vec::new();
    loop {
        if !serve_buffered(&mut stream, &mut reader, &mut wbuf, shared) {
            return;
        }
        let stopping = shared.stop.load(Ordering::Relaxed);
        match stream.read(&mut rbuf) {
            Ok(0) => return, // peer closed
            Ok(n) => reader.feed(&rbuf[..n]),
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                if stopping {
                    // Drain whatever arrived before the stop flag and
                    // close; responses for frames already received
                    // were written above.
                    serve_buffered(&mut stream, &mut reader, &mut wbuf, shared);
                    return;
                }
            }
            Err(_) => return,
        }
    }
}

/// Serve every complete frame currently buffered; `false` means the
/// connection must close (malformed input or a dead peer socket).
fn serve_buffered(
    stream: &mut TcpStream,
    reader: &mut FrameReader,
    wbuf: &mut Vec<u8>,
    shared: &Arc<ServerShared>,
) -> bool {
    loop {
        match reader.try_next() {
            Ok(Some(frame)) => {
                // Answer at the version the peer spoke: a v1 client
                // never receives a v2 frame.
                let version = reader.last_version();
                let reply = handle_frame(shared, frame);
                if write_frame(stream, wbuf, &reply, version).is_err() {
                    return false;
                }
            }
            Ok(None) => return true,
            Err(e) => {
                shared.malformed.fetch_add(1, Ordering::Relaxed);
                let reply = Frame::Response(InferResponse::fail(
                    0,
                    Status::BadRequest,
                    format!("{e:#}"),
                ));
                let _ = write_frame(stream, wbuf, &reply, reader.last_version());
                return false;
            }
        }
    }
}

fn write_frame(
    stream: &mut TcpStream,
    wbuf: &mut Vec<u8>,
    frame: &Frame,
    version: u8,
) -> Result<()> {
    wbuf.clear();
    encode_frame_v(frame, version, wbuf)?;
    stream.write_all(wbuf).context("writing frame")?;
    stream.flush().context("flushing frame")?;
    Ok(())
}

/// Serve one decoded frame.
fn handle_frame(shared: &Arc<ServerShared>, frame: Frame) -> Frame {
    match frame {
        Frame::Infer(req) => Frame::Response(handle_infer(shared, req)),
        Frame::MetricsRequest { id } => Frame::MetricsText { id, text: shared.metrics_text() },
        Frame::Response(r) => Frame::Response(InferResponse::fail(
            r.id,
            Status::BadRequest,
            "unexpected response frame from client",
        )),
        Frame::MetricsText { id, .. } => Frame::Response(InferResponse::fail(
            id,
            Status::BadRequest,
            "unexpected metrics-text frame from client",
        )),
    }
}

/// Route one inference request: registry lookup → shape check →
/// tenant admission → the model pool's own overload policy.
fn handle_infer(shared: &Arc<ServerShared>, req: InferRequest) -> InferResponse {
    let Some(entry) = shared.registry.get(&req.model) else {
        let known = shared.registry.names().join("|");
        let msg = format!("unknown model {:?} (registered: {known})", req.model);
        return InferResponse::fail(req.id, Status::UnknownModel, msg);
    };
    let want = entry.client().image_len();
    if req.payload.len() != want {
        let msg = format!("payload length {} != model image length {want}", req.payload.len());
        return InferResponse::fail(req.id, Status::BadRequest, msg);
    }
    let _guard = match shared.registry.admission().try_admit(&req.tenant, req.priority) {
        Some(g) => g,
        None => {
            let msg = format!("{SHED_ERROR} (tenant {:?} over quota)", req.tenant);
            return InferResponse::fail(req.id, Status::Shed, msg);
        }
    };
    // deadline_ms counts from server receipt of the frame; the queue
    // and the batcher check it at dequeue and at batch admission.
    let timeout = (req.deadline_ms > 0).then(|| Duration::from_millis(req.deadline_ms as u64));
    match entry.infer_within(req.payload, timeout) {
        Ok(logits) => InferResponse::ok(req.id, logits),
        Err(e) if is_shed_error(&e) => InferResponse::fail(req.id, Status::Shed, format!("{e:#}")),
        Err(e) if is_deadline_error(&e) => {
            InferResponse::fail(req.id, Status::Expired, format!("{e:#}"))
        }
        Err(e) => InferResponse::fail(req.id, Status::Error, format!("{e:#}")),
    }
}

/// Retry discipline for [`NetClient`]: up to `retries` *re*-attempts
/// after the first try, sleeping a jittered exponential backoff
/// (`backoff_base × 2^attempt`, capped at `backoff_max`, scaled by a
/// uniform factor in `[0.5, 1.0)`) between attempts. Only idempotent
/// exchanges retry — infer, classify and metrics scrapes — and only
/// through the retrying wrappers; [`NetClient::request`] stays
/// single-shot so tests can count shed responses exactly.
#[derive(Clone, Copy, Debug)]
pub struct RetryPolicy {
    /// Re-attempts after the first try (0 = never retry).
    pub retries: u32,
    /// Backoff before the first retry.
    pub backoff_base: Duration,
    /// Backoff ceiling.
    pub backoff_max: Duration,
}

impl Default for RetryPolicy {
    fn default() -> Self {
        Self {
            retries: 2,
            backoff_base: Duration::from_millis(25),
            backoff_max: Duration::from_secs(1),
        }
    }
}

impl RetryPolicy {
    /// The jittered sleep before retry number `attempt` (0-based).
    fn backoff(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = self
            .backoff_base
            .saturating_mul(1u32.checked_shl(attempt).unwrap_or(u32::MAX))
            .min(self.backoff_max);
        exp.mul_f64(0.5 + 0.5 * rng.f64())
    }
}

/// Blocking client for the wire protocol: one TCP connection, one
/// in-flight request at a time (`scnn client`, tests, examples).
///
/// Never hangs: connects, reads and writes all carry timeouts
/// (defaults [`CONNECT_TIMEOUT`] / [`READ_TIMEOUT`] /
/// [`WRITE_TIMEOUT`]), a hung server surfaces as [`TIMEOUT_ERROR`],
/// and a broken stream reconnects on the next attempt. Idempotent
/// calls ([`NetClient::infer`], [`NetClient::classify`],
/// [`NetClient::metrics_text`]) retry under the configured
/// [`RetryPolicy`].
pub struct NetClient {
    addrs: Vec<SocketAddr>,
    stream: Option<TcpStream>,
    reader: FrameReader,
    scratch: Vec<u8>,
    next_id: u64,
    tenant: String,
    priority: Priority,
    deadline: Option<Duration>,
    retry: RetryPolicy,
    connect_timeout: Duration,
    read_timeout: Duration,
    write_timeout: Duration,
    rng: Rng,
}

impl NetClient {
    /// Connect to a serving front-end (with [`CONNECT_TIMEOUT`]).
    pub fn connect<A: ToSocketAddrs>(addr: A) -> Result<Self> {
        let addrs: Vec<SocketAddr> =
            addr.to_socket_addrs().context("resolving scnn server address")?.collect();
        anyhow::ensure!(!addrs.is_empty(), "scnn server address resolved to nothing");
        // Seed backoff jitter from the process's hash randomness —
        // distinct clients must not retry in lockstep.
        use std::hash::{BuildHasher, Hasher};
        let seed = std::collections::hash_map::RandomState::new().build_hasher().finish();
        let mut client = Self {
            addrs,
            stream: None,
            reader: FrameReader::new(),
            scratch: Vec::new(),
            next_id: 1,
            tenant: "default".to_string(),
            priority: Priority::Normal,
            deadline: None,
            retry: RetryPolicy::default(),
            connect_timeout: CONNECT_TIMEOUT,
            read_timeout: READ_TIMEOUT,
            write_timeout: WRITE_TIMEOUT,
            rng: Rng::new(seed | 1),
        };
        client.ensure_connected()?;
        Ok(client)
    }

    /// Set the tenant id carried on every request.
    pub fn with_tenant(mut self, tenant: &str) -> Self {
        self.tenant = tenant.to_string();
        self
    }

    /// Set the priority carried on every request.
    pub fn with_priority(mut self, priority: Priority) -> Self {
        self.priority = priority;
        self
    }

    /// Set the per-request deadline carried on every infer request
    /// (`None` = no deadline). Sub-millisecond deadlines round up to
    /// 1 ms so they stay expressible on the wire.
    pub fn with_deadline(mut self, deadline: Option<Duration>) -> Self {
        self.deadline = deadline;
        self
    }

    /// Replace the retry policy.
    pub fn with_retry(mut self, retry: RetryPolicy) -> Self {
        self.retry = retry;
        self
    }

    /// Set just the retry budget, keeping default backoff.
    pub fn with_retries(mut self, retries: u32) -> Self {
        self.retry.retries = retries;
        self
    }

    /// Override the connect/read/write timeouts.
    pub fn with_timeouts(mut self, connect: Duration, read: Duration, write: Duration) -> Self {
        self.connect_timeout = connect;
        self.read_timeout = read;
        self.write_timeout = write;
        if let Some(s) = &self.stream {
            let _ = s.set_write_timeout(Some(self.write_timeout));
        }
        self
    }

    /// The deadline_ms wire field for the configured deadline.
    fn deadline_ms(&self) -> u32 {
        match self.deadline {
            None => 0,
            Some(d) => {
                let ms = d.as_millis().clamp(1, u32::MAX as u128);
                ms as u32
            }
        }
    }

    /// Send one inference request and wait for its response frame
    /// (status not interpreted — overload tests read `Status::Shed`
    /// counts exactly from here). Single-shot: no retries.
    pub fn request(&mut self, model: &str, x: &[f32]) -> Result<InferResponse> {
        self.request_once(model, x)
    }

    /// Blocking inference: `Ok(logits)` or an error (shed rejections
    /// satisfy [`is_shed_error`], deadline expiry [`is_deadline_error`],
    /// socket timeouts [`is_timeout_error`]). Retries transport
    /// failures under the client's [`RetryPolicy`] — inference is
    /// idempotent, so a response lost to a broken stream is safe to
    /// re-request.
    pub fn infer(&mut self, model: &str, x: &[f32]) -> Result<Vec<f32>> {
        let r = self.retrying(|c| c.request_once(model, x))?;
        match r.status {
            Status::Ok => Ok(r.logits),
            Status::Shed if r.message.starts_with(SHED_ERROR) => anyhow::bail!("{}", r.message),
            Status::Shed => anyhow::bail!("{SHED_ERROR}: {}", r.message),
            Status::Expired if r.message.starts_with(DEADLINE_EXPIRED_ERROR) => {
                anyhow::bail!("{}", r.message)
            }
            Status::Expired => anyhow::bail!("{DEADLINE_EXPIRED_ERROR}: {}", r.message),
            // Typed pool failures (e.g. the worker-panic marker) keep
            // their marker prefix across the wire.
            Status::Error if r.message.starts_with(WORKER_PANIC_ERROR) => {
                anyhow::bail!("{}", r.message)
            }
            s => anyhow::bail!("server rejected request ({s:?}): {}", r.message),
        }
    }

    /// Classify one image (argmax over [`NetClient::infer`]).
    pub fn classify(&mut self, model: &str, x: &[f32]) -> Result<usize> {
        let logits = self.infer(model, x)?;
        Ok(logits
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap_or(0))
    }

    /// Scrape the server's Prometheus text exposition (idempotent —
    /// retries under the client's [`RetryPolicy`]).
    pub fn metrics_text(&mut self) -> Result<String> {
        self.retrying(|c| c.metrics_once())
    }

    fn metrics_once(&mut self) -> Result<String> {
        let id = self.next_id;
        self.next_id += 1;
        self.send(&Frame::MetricsRequest { id })?;
        match self.read_frame()? {
            Frame::MetricsText { id: rid, text } => {
                anyhow::ensure!(rid == id, "metrics response id {rid} for request {id}");
                Ok(text)
            }
            Frame::Response(r) => anyhow::bail!("metrics scrape failed: {}", r.message),
            other => anyhow::bail!("unexpected frame from server: {other:?}"),
        }
    }

    fn request_once(&mut self, model: &str, x: &[f32]) -> Result<InferResponse> {
        let id = self.next_id;
        self.next_id += 1;
        let frame = Frame::Infer(InferRequest {
            id,
            priority: self.priority,
            deadline_ms: self.deadline_ms(),
            model: model.to_string(),
            tenant: self.tenant.clone(),
            payload: x.to_vec(),
        });
        self.send(&frame)?;
        match self.read_frame()? {
            Frame::Response(r) => {
                anyhow::ensure!(r.id == id || r.id == 0, "response id {} for request {id}", r.id);
                Ok(r)
            }
            other => anyhow::bail!("unexpected frame from server: {other:?}"),
        }
    }

    /// Run `op`, retrying transport failures (connect errors, broken
    /// streams, socket timeouts) up to the retry budget with jittered
    /// exponential backoff. Application-level rejections — shed,
    /// expired, bad request — come back as `Ok(response)` from
    /// `request_once` and are never retried here.
    fn retrying<T>(&mut self, mut op: impl FnMut(&mut Self) -> Result<T>) -> Result<T> {
        let mut attempt = 0u32;
        loop {
            match op(self) {
                Ok(v) => return Ok(v),
                Err(e) => {
                    if attempt >= self.retry.retries {
                        return Err(e);
                    }
                    let sleep = self.retry.backoff(attempt, &mut self.rng);
                    attempt += 1;
                    std::thread::sleep(sleep);
                }
            }
        }
    }

    /// Connect if not already connected (the send/read paths drop the
    /// stream on any transport error, so the next attempt redials).
    fn ensure_connected(&mut self) -> Result<()> {
        if self.stream.is_some() {
            return Ok(());
        }
        let mut last: Option<anyhow::Error> = None;
        for addr in &self.addrs {
            match TcpStream::connect_timeout(addr, self.connect_timeout) {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    // Short slices so read_frame can enforce its own
                    // budget; one write timeout covers a whole frame.
                    let _ = stream.set_read_timeout(Some(CLIENT_READ_SLICE));
                    let _ = stream.set_write_timeout(Some(self.write_timeout));
                    self.stream = Some(stream);
                    self.reader = FrameReader::new();
                    return Ok(());
                }
                Err(e) => last = Some(anyhow::Error::from(e)),
            }
        }
        match last {
            Some(e) => Err(e.context("connecting to scnn server")),
            None => anyhow::bail!("connecting to scnn server: no addresses"),
        }
    }

    fn disconnect(&mut self) {
        self.stream = None;
        self.reader = FrameReader::new();
    }

    fn send(&mut self, frame: &Frame) -> Result<()> {
        self.ensure_connected()?;
        self.scratch.clear();
        encode_frame(frame, &mut self.scratch)?;
        let Some(stream) = self.stream.as_mut() else {
            anyhow::bail!("not connected");
        };
        let sent = stream
            .write_all(&self.scratch)
            .and_then(|()| stream.flush())
            .context("writing to server");
        if sent.is_err() {
            self.disconnect();
        }
        sent
    }

    fn read_frame(&mut self) -> Result<Frame> {
        // Budget: the request deadline plus grace (so the server's
        // own `deadline expired` reply wins the race), else the
        // configured read timeout.
        let budget = match self.deadline {
            Some(d) => d + CLIENT_DEADLINE_GRACE,
            None => self.read_timeout,
        };
        let give_up = Instant::now() + budget;
        let mut buf = [0u8; 8192];
        loop {
            match self.reader.try_next() {
                Ok(Some(f)) => return Ok(f),
                Ok(None) => {}
                Err(e) => {
                    self.disconnect();
                    return Err(e);
                }
            }
            let Some(stream) = self.stream.as_mut() else {
                anyhow::bail!("not connected");
            };
            match stream.read(&mut buf) {
                Ok(0) => {
                    self.disconnect();
                    anyhow::bail!("server closed the connection");
                }
                Ok(n) => self.reader.feed(&buf[..n]),
                Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => {
                    if Instant::now() >= give_up {
                        self.disconnect();
                        anyhow::bail!("{TIMEOUT_ERROR} (waited {budget:?})");
                    }
                }
                Err(e) => {
                    self.disconnect();
                    return Err(e).context("reading from server");
                }
            }
        }
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    fn roundtrip(frame: Frame) -> Frame {
        let mut buf = Vec::new();
        encode_frame(&frame, &mut buf).unwrap();
        let mut r = FrameReader::new();
        r.feed(&buf);
        let out = r.try_next().unwrap().expect("one whole frame buffered");
        assert_eq!(r.buffered(), 0, "no residue after a clean frame");
        out
    }

    #[test]
    fn frames_roundtrip() {
        let req = Frame::Infer(InferRequest {
            id: 7,
            priority: Priority::Low,
            deadline_ms: 250,
            model: "scnet10".into(),
            tenant: "acme".into(),
            payload: vec![0.5, -1.25, 3.0],
        });
        assert_eq!(roundtrip(req.clone()), req);
        let ok = Frame::Response(InferResponse::ok(9, vec![1.0, 2.0]));
        assert_eq!(roundtrip(ok.clone()), ok);
        let fail = Frame::Response(InferResponse::fail(3, Status::Shed, "overloaded"));
        assert_eq!(roundtrip(fail.clone()), fail);
        let m = Frame::MetricsRequest { id: 11 };
        assert_eq!(roundtrip(m.clone()), m);
        let t = Frame::MetricsText { id: 11, text: "# HELP x\n".into() };
        assert_eq!(roundtrip(t.clone()), t);
    }

    #[test]
    fn reader_survives_one_byte_trickle_and_coalesced_frames() {
        let a = Frame::Infer(InferRequest {
            id: 1,
            priority: Priority::High,
            deadline_ms: 0,
            model: "m".into(),
            tenant: "".into(),
            payload: vec![0.25; 17],
        });
        let b = Frame::MetricsRequest { id: 2 };
        let mut bytes = Vec::new();
        encode_frame(&a, &mut bytes).unwrap();
        encode_frame(&b, &mut bytes).unwrap();
        // Trickle: one byte per feed, both frames must come out whole.
        let mut r = FrameReader::new();
        let mut got = Vec::new();
        for byte in &bytes {
            r.feed(std::slice::from_ref(byte));
            while let Some(f) = r.try_next().unwrap() {
                got.push(f);
            }
        }
        assert_eq!(got, vec![a.clone(), b.clone()]);
        // Coalesced: both frames in one feed.
        let mut r = FrameReader::new();
        r.feed(&bytes);
        assert_eq!(r.try_next().unwrap(), Some(a));
        assert_eq!(r.try_next().unwrap(), Some(b));
        assert_eq!(r.try_next().unwrap(), None);
    }

    #[test]
    fn bad_magic_version_kind_are_malformed() {
        let mut buf = Vec::new();
        encode_frame(&Frame::MetricsRequest { id: 1 }, &mut buf).unwrap();
        // Corrupt the magic.
        let mut bad = buf.clone();
        bad[4] ^= 0xFF;
        let mut r = FrameReader::new();
        r.feed(&bad);
        assert!(format!("{:#}", r.try_next().unwrap_err()).contains("bad magic"));
        // Corrupt the version.
        let mut bad = buf.clone();
        bad[8] = 99;
        let mut r = FrameReader::new();
        r.feed(&bad);
        assert!(format!("{:#}", r.try_next().unwrap_err()).contains("version"));
        // Corrupt the kind.
        let mut bad = buf.clone();
        bad[9] = 42;
        let mut r = FrameReader::new();
        r.feed(&bad);
        assert!(format!("{:#}", r.try_next().unwrap_err()).contains("unknown kind"));
    }

    #[test]
    fn truncated_and_oversized_frames_are_malformed() {
        // Body claims a payload longer than the frame carries.
        let mut buf = Vec::new();
        let req = Frame::Infer(InferRequest {
            id: 1,
            priority: Priority::Normal,
            deadline_ms: 0,
            model: "m".into(),
            tenant: "t".into(),
            payload: vec![1.0, 2.0],
        });
        encode_frame(&req, &mut buf).unwrap();
        let cut = buf.len() - 4; // drop one f32, keep the declared count
        let body_len = (cut - 4) as u32;
        let mut bad = buf[..cut].to_vec();
        bad[0..4].copy_from_slice(&body_len.to_le_bytes());
        let mut r = FrameReader::new();
        r.feed(&bad);
        let e = r.try_next().unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
        // Declared length over MAX_FRAME is rejected before buffering.
        let mut r = FrameReader::new();
        r.feed(&((MAX_FRAME as u32 + 1).to_le_bytes()));
        let e = r.try_next().unwrap_err();
        assert!(format!("{e:#}").contains("exceeds max"), "{e:#}");
        // Trailing junk after a valid body is malformed too.
        let mut padded = Vec::new();
        encode_frame(&Frame::MetricsRequest { id: 1 }, &mut padded).unwrap();
        let len = u32::from_le_bytes(padded[0..4].try_into().unwrap()) + 1;
        padded[0..4].copy_from_slice(&len.to_le_bytes());
        padded.push(0xAB);
        let mut r = FrameReader::new();
        r.feed(&padded);
        let e = r.try_next().unwrap_err();
        assert!(format!("{e:#}").contains("trailing"), "{e:#}");
    }

    #[test]
    fn long_ids_are_rejected_at_encode_time() {
        let req = Frame::Infer(InferRequest {
            id: 1,
            priority: Priority::Normal,
            deadline_ms: 0,
            model: "m".repeat(256),
            tenant: "t".into(),
            payload: vec![],
        });
        assert!(encode_frame(&req, &mut Vec::new()).is_err());
    }

    /// Hand-encode a v1 infer frame (no deadline field) the way a
    /// pre-deadline client would.
    fn encode_v1_infer(id: u64, model: &str, tenant: &str, payload: &[f32]) -> Vec<u8> {
        let mut out = vec![0u8; 4];
        out.extend_from_slice(&MAGIC.to_le_bytes());
        out.push(1); // version 1
        out.push(KIND_INFER);
        out.extend_from_slice(&id.to_le_bytes());
        out.push(Priority::Normal.as_u8());
        out.push(model.len() as u8);
        out.push(tenant.len() as u8);
        out.extend_from_slice(model.as_bytes());
        out.extend_from_slice(tenant.as_bytes());
        out.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        for v in payload {
            out.extend_from_slice(&v.to_le_bytes());
        }
        let body_len = (out.len() - 4) as u32;
        out[0..4].copy_from_slice(&body_len.to_le_bytes());
        out
    }

    #[test]
    fn v1_frames_decode_with_no_deadline() {
        let bytes = encode_v1_infer(42, "m", "t", &[1.0, 2.0]);
        let mut r = FrameReader::new();
        r.feed(&bytes);
        let frame = r.try_next().unwrap().expect("one whole v1 frame");
        assert_eq!(r.last_version(), 1);
        let Frame::Infer(req) = frame else { panic!("not an infer frame") };
        assert_eq!(req.id, 42);
        assert_eq!(req.deadline_ms, 0, "v1 has no deadline field: deadline = none");
        assert_eq!(req.payload, vec![1.0, 2.0]);
    }

    #[test]
    fn v1_encoding_roundtrips_and_rejects_deadlines() {
        let req = Frame::Infer(InferRequest {
            id: 5,
            priority: Priority::High,
            deadline_ms: 0,
            model: "m".into(),
            tenant: "t".into(),
            payload: vec![0.5],
        });
        let mut buf = Vec::new();
        encode_frame_v(&req, 1, &mut buf).unwrap();
        assert_eq!(buf, encode_v1_infer(5, "m", "t", &[0.5]));
        let mut r = FrameReader::new();
        r.feed(&buf);
        assert_eq!(r.try_next().unwrap(), Some(req));
        assert_eq!(r.last_version(), 1);
        // A deadline cannot ride a v1 frame.
        let with_deadline = Frame::Infer(InferRequest {
            id: 5,
            priority: Priority::High,
            deadline_ms: 10,
            model: "m".into(),
            tenant: "t".into(),
            payload: vec![0.5],
        });
        assert!(encode_frame_v(&with_deadline, 1, &mut Vec::new()).is_err());
        // Out-of-range versions are rejected at encode time.
        assert!(encode_frame_v(&req, 0, &mut Vec::new()).is_err());
        assert!(encode_frame_v(&req, VERSION + 1, &mut Vec::new()).is_err());
    }

    #[test]
    fn v1_priority_byte_is_still_priority_not_deadline() {
        // Regression guard on field order: in a v1 body the byte after
        // `id` is the priority, and the model length follows directly.
        let bytes = encode_v1_infer(1, "ab", "c", &[]);
        let frame = decode_body(&bytes[4..]).unwrap();
        let Frame::Infer(req) = frame else { panic!("not an infer frame") };
        assert_eq!(req.priority, Priority::Normal);
        assert_eq!(req.model, "ab");
        assert_eq!(req.tenant, "c");
    }

    #[test]
    fn retry_backoff_is_capped_and_jittered() {
        let policy = RetryPolicy {
            retries: 5,
            backoff_base: Duration::from_millis(100),
            backoff_max: Duration::from_millis(350),
        };
        let mut rng = Rng::new(7);
        for attempt in 0..10 {
            let d = policy.backoff(attempt, &mut rng);
            let cap = Duration::from_millis(350);
            let exp = Duration::from_millis(100)
                .saturating_mul(1u32.checked_shl(attempt).unwrap_or(u32::MAX))
                .min(cap);
            assert!(d <= exp, "jitter never exceeds the exponential step");
            assert!(d >= exp.mul_f64(0.5), "jitter keeps at least half the step");
        }
    }
}
