//! Chaos injection for the serving stack: deliberately break things
//! at configurable rates so the fault-tolerance layer (worker
//! supervision, deadlines, client retries) can be exercised by real
//! tests and benchmarks instead of trusted on faith.
//!
//! Like [`SyntheticExecutor`], this module is always compiled but is
//! test/bench infrastructure: nothing in the serving path depends on
//! it. The pieces:
//!
//! - [`ChaosSwitch`] — a shared, atomically updatable panic rate.
//! - [`chaos_factory`] — wraps any [`ExecutorFactory`] so each built
//!   executor panics inside `run_batch` with probability `rate` per
//!   batch (deterministic per worker: seed ⊕ worker index).
//! - Connection-chaos helpers ([`malformed_frame`], [`slow_writer`],
//!   [`drop_after`]) — byte-level misbehavior for socket tests:
//!   garbage frames, stalled writes, connections cut mid-frame.
//!
//! Injected panics carry the [`CHAOS_PANIC`] marker so a test can
//! tell a deliberate crash from a real bug escaping into the harness.

use std::io::Write;
use std::net::TcpStream;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use crate::util::Rng;
use crate::Result;

use super::executor::{BatchExecutor, ExecutorFactory, ExecutorSpec};

/// Panic-message prefix for injected worker panics.
pub const CHAOS_PANIC: &str = "chaos: injected worker panic";

/// A shared dial for the injected panic rate, adjustable while the
/// pool is running (the f64 rate is stored as its bit pattern in an
/// `AtomicU64`). Clone the [`Arc`] into the factory and keep one
/// handle in the test to turn injection on and off.
#[derive(Debug)]
pub struct ChaosSwitch {
    rate_bits: AtomicU64,
}

impl ChaosSwitch {
    /// New switch at `rate` (probability per batch, clamped to [0, 1]).
    pub fn new(rate: f64) -> Arc<Self> {
        let s = Arc::new(Self { rate_bits: AtomicU64::new(0) });
        s.set_rate(rate);
        s
    }

    /// Current panic probability per batch.
    pub fn rate(&self) -> f64 {
        f64::from_bits(self.rate_bits.load(Ordering::Relaxed))
    }

    /// Update the panic probability (clamped to [0, 1]); takes effect
    /// on the next batch of every worker sharing the switch.
    pub fn set_rate(&self, rate: f64) {
        self.rate_bits.store(rate.clamp(0.0, 1.0).to_bits(), Ordering::Relaxed);
    }

    /// Shorthand for `set_rate(0.0)`.
    pub fn off(&self) {
        self.set_rate(0.0);
    }
}

/// An executor wrapper that panics before delegating with the
/// probability its [`ChaosSwitch`] currently reads.
struct ChaosExecutor {
    inner: Box<dyn BatchExecutor>,
    switch: Arc<ChaosSwitch>,
    rng: Rng,
    batches: u64,
}

impl BatchExecutor for ChaosExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.inner.spec()
    }

    fn run_batch(&mut self, x: &[f32], filled: usize) -> Result<Vec<f32>> {
        self.batches += 1;
        let rate = self.switch.rate();
        if rate > 0.0 && self.rng.gen_bool(rate) {
            panic!("{CHAOS_PANIC} (batch {})", self.batches);
        }
        self.inner.run_batch(x, filled)
    }
}

/// Wrap `inner` so every executor it builds injects panics at the
/// switch's current rate. Each worker draws from its own
/// deterministic stream (`seed ⊕ worker index`), so a given (seed,
/// rate, traffic) combination crashes reproducibly. Respawned workers
/// keep advancing their stream — the factory hands out a freshly
/// seeded wrapper per *build*, counting builds per worker.
pub fn chaos_factory(
    inner: ExecutorFactory,
    switch: Arc<ChaosSwitch>,
    seed: u64,
) -> ExecutorFactory {
    // Per-worker build counter so a respawned worker's wrapper does
    // not replay the identical panic schedule of its predecessor.
    let builds = Arc::new(AtomicU64::new(0));
    Box::new(move |worker| {
        let exec = (inner)(worker)?;
        let build = builds.fetch_add(1, Ordering::Relaxed);
        let stream = seed ^ (worker as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ (build << 32);
        Ok(Box::new(ChaosExecutor {
            inner: exec,
            switch: switch.clone(),
            rng: Rng::new(stream | 1),
            batches: 0,
        }))
    })
}

/// Bytes that are *not* a valid frame: correct length prefix, corrupt
/// magic. Feeding these to a server must yield a `BadRequest`
/// response and a closed connection — never a crash.
pub fn malformed_frame() -> Vec<u8> {
    let body = [0xDEu8, 0xAD, 0xBE, 0xEF, 0x01, 0x00];
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_le_bytes());
    out.extend_from_slice(&body);
    out
}

/// Write `bytes` one byte at a time with `stall` between bytes — a
/// slow-reader/slow-writer stall. Returns early on the first socket
/// error (the peer may legitimately cut us off).
pub fn slow_writer(stream: &mut TcpStream, bytes: &[u8], stall: Duration) -> Result<()> {
    for b in bytes {
        if stream.write_all(std::slice::from_ref(b)).is_err() {
            anyhow::bail!("peer closed during slow write");
        }
        let _ = stream.flush();
        std::thread::sleep(stall);
    }
    Ok(())
}

/// Write only the first `n` bytes of `bytes` and drop the connection
/// (the stream is consumed and closed on return) — a client dying
/// mid-frame. The server must discard the partial frame without
/// wedging the connection slot.
pub fn drop_after(stream: TcpStream, bytes: &[u8], n: usize) {
    let mut stream = stream;
    let n = n.min(bytes.len());
    let _ = stream.write_all(&bytes[..n]);
    let _ = stream.flush();
    drop(stream);
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::coordinator::executor::SyntheticExecutor;

    const SPEC: ExecutorSpec = ExecutorSpec { image_len: 4, batch: 2, classes: 3 };

    #[test]
    fn switch_clamps_and_updates() {
        let s = ChaosSwitch::new(2.0);
        assert_eq!(s.rate(), 1.0);
        s.set_rate(-1.0);
        assert_eq!(s.rate(), 0.0);
        s.set_rate(0.25);
        assert_eq!(s.rate(), 0.25);
        s.off();
        assert_eq!(s.rate(), 0.0);
    }

    #[test]
    fn zero_rate_is_transparent() {
        let switch = ChaosSwitch::new(0.0);
        let factory =
            chaos_factory(SyntheticExecutor::factory(SPEC, Duration::ZERO), switch, 42);
        let mut exec = factory(0).unwrap();
        assert_eq!(exec.spec(), SPEC);
        let x = vec![0.5; SPEC.image_len * SPEC.batch];
        let oracle = SyntheticExecutor::factory(SPEC, Duration::ZERO)(0)
            .unwrap()
            .run_batch(&x, SPEC.batch)
            .unwrap();
        for _ in 0..64 {
            assert_eq!(exec.run_batch(&x, SPEC.batch).unwrap(), oracle);
        }
    }

    #[test]
    fn full_rate_panics_with_marker() {
        let switch = ChaosSwitch::new(1.0);
        let factory =
            chaos_factory(SyntheticExecutor::factory(SPEC, Duration::ZERO), switch, 42);
        let mut exec = factory(0).unwrap();
        let x = vec![0.0; SPEC.image_len * SPEC.batch];
        let payload = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let _ = exec.run_batch(&x, SPEC.batch);
        }))
        .expect_err("rate 1.0 must panic");
        let msg = payload
            .downcast_ref::<String>()
            .cloned()
            .unwrap_or_else(|| "non-string payload".into());
        assert!(msg.starts_with(CHAOS_PANIC), "{msg}");
    }

    #[test]
    fn respawned_builds_draw_distinct_streams() {
        let switch = ChaosSwitch::new(0.0);
        let factory =
            chaos_factory(SyntheticExecutor::factory(SPEC, Duration::ZERO), switch, 7);
        // Two builds for the same worker index must not share a seed
        // (the wrapper varies the stream by build count).
        let _ = factory(0).unwrap();
        let _ = factory(0).unwrap();
    }

    #[test]
    fn malformed_frame_is_length_consistent_but_bad() {
        let bytes = malformed_frame();
        let mut len = [0u8; 4];
        len.copy_from_slice(&bytes[0..4]);
        assert_eq!(u32::from_le_bytes(len) as usize, bytes.len() - 4);
        let mut r = crate::coordinator::net::FrameReader::new();
        r.feed(&bytes);
        assert!(r.try_next().is_err(), "must decode as malformed");
    }
}
