//! Serving metrics: request counts, latency percentiles, batch
//! occupancy — one [`ServerMetrics`] per pool worker, aggregated into
//! a single [`MetricsSnapshot`].

use std::sync::{Arc, Mutex};
use std::time::Duration;

/// Thread-safe metrics accumulator (one per pool worker).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    padded_slots: u64,
    errors: u64,
    latencies_us: Vec<u64>,
}

/// Per-worker counters inside a [`MetricsSnapshot`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerCounts {
    /// Worker index (matches the `scnn-worker-{i}` thread name).
    pub worker: usize,
    /// Requests this worker completed successfully.
    pub requests: u64,
    /// Batches this worker executed.
    pub batches: u64,
    /// Requests this worker failed (executor errors).
    pub errors: u64,
}

/// A point-in-time snapshot aggregated over the whole pool.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Completed requests (across all workers).
    pub requests: u64,
    /// Executed batches (across all workers).
    pub batches: u64,
    /// Mean batch occupancy in [0, 1].
    pub occupancy: f64,
    /// p50 request latency.
    pub p50: Duration,
    /// p99 request latency.
    pub p99: Duration,
    /// Mean request latency.
    pub mean: Duration,
    /// Requests that failed with an executor error.
    pub errors: u64,
    /// Requests rejected by load shedding ([`OverloadPolicy::Shed`]).
    ///
    /// [`OverloadPolicy::Shed`]: super::OverloadPolicy::Shed
    pub shed: u64,
    /// Number of pool workers aggregated into this snapshot.
    pub workers: usize,
    /// Peak number of requests queued/executing at once (high-water
    /// mark of the admission gauge).
    pub inflight_peak: usize,
    /// Per-worker breakdown, indexed by worker.
    pub per_worker: Vec<WorkerCounts>,
}

impl ServerMetrics {
    /// New, empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch: `filled` live requests with their
    /// end-to-end latencies, `capacity` total slots.
    pub fn record_batch(&self, latencies: &[Duration], capacity: usize) {
        let mut g = self.inner.lock().unwrap();
        g.requests += latencies.len() as u64;
        g.batches += 1;
        g.padded_slots += (capacity - latencies.len()) as u64;
        g.latencies_us
            .extend(latencies.iter().map(|d| d.as_micros() as u64));
    }

    /// Record `n` requests that failed with an executor error.
    pub fn record_errors(&self, n: u64) {
        self.inner.lock().unwrap().errors += n;
    }

    /// Single-worker snapshot (sorts latencies; intended for
    /// end-of-run reporting).
    pub fn snapshot(&self, capacity: usize) -> MetricsSnapshot {
        Self::merge([self].into_iter(), capacity, 0, 0)
    }

    /// Aggregate the per-worker accumulators of a pool into one
    /// snapshot. `shed` and `inflight_peak` come from the pool's
    /// shared admission state.
    pub fn aggregate(
        workers: &[Arc<ServerMetrics>],
        capacity: usize,
        shed: u64,
        inflight_peak: usize,
    ) -> MetricsSnapshot {
        Self::merge(workers.iter().map(Arc::as_ref), capacity, shed, inflight_peak)
    }

    fn merge<'a>(
        workers: impl Iterator<Item = &'a ServerMetrics>,
        capacity: usize,
        shed: u64,
        inflight_peak: usize,
    ) -> MetricsSnapshot {
        let mut latencies: Vec<u64> = Vec::new();
        let mut per_worker = Vec::new();
        let (mut requests, mut batches, mut padded, mut errors) = (0u64, 0u64, 0u64, 0u64);
        for (w, m) in workers.enumerate() {
            let g = m.inner.lock().unwrap();
            requests += g.requests;
            batches += g.batches;
            padded += g.padded_slots;
            errors += g.errors;
            latencies.extend_from_slice(&g.latencies_us);
            per_worker.push(WorkerCounts {
                worker: w,
                requests: g.requests,
                batches: g.batches,
                errors: g.errors,
            });
        }
        latencies.sort_unstable();
        let n = latencies.len();
        let pick = |q: f64| -> Duration {
            if n == 0 {
                return Duration::ZERO;
            }
            let idx = ((n as f64 - 1.0) * q).round() as usize;
            Duration::from_micros(latencies[idx])
        };
        let mean = if n == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(latencies.iter().sum::<u64>() / n as u64)
        };
        let slots = batches * capacity as u64;
        MetricsSnapshot {
            requests,
            batches,
            occupancy: if slots == 0 { 0.0 } else { 1.0 - padded as f64 / slots as f64 },
            p50: pick(0.5),
            p99: pick(0.99),
            mean,
            errors,
            shed,
            workers: per_worker.len(),
            inflight_peak,
            per_worker,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::new();
        m.record_batch(
            &[Duration::from_micros(100), Duration::from_micros(300)],
            4,
        );
        m.record_batch(&[Duration::from_micros(200)], 4);
        let s = m.snapshot(4);
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert!((s.occupancy - 3.0 / 8.0).abs() < 1e-9);
        assert_eq!(s.p50, Duration::from_micros(200));
        assert_eq!(s.mean, Duration::from_micros(200));
        assert_eq!(s.workers, 1);
        assert_eq!(s.errors, 0);
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn empty_snapshot() {
        let m = ServerMetrics::new();
        let s = m.snapshot(8);
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.per_worker.len(), 1);
    }

    #[test]
    fn aggregates_across_workers() {
        let a = Arc::new(ServerMetrics::new());
        let b = Arc::new(ServerMetrics::new());
        a.record_batch(&[Duration::from_micros(100); 4], 4);
        b.record_batch(&[Duration::from_micros(500)], 4);
        b.record_errors(2);
        let s = ServerMetrics::aggregate(&[a, b], 4, 3, 17);
        assert_eq!(s.requests, 5);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 2);
        assert_eq!(s.shed, 3);
        assert_eq!(s.workers, 2);
        assert_eq!(s.inflight_peak, 17);
        assert!((s.occupancy - 5.0 / 8.0).abs() < 1e-9);
        assert_eq!(s.p99, Duration::from_micros(500));
        assert_eq!(s.per_worker[0].requests, 4);
        assert_eq!(s.per_worker[1].requests, 1);
        assert_eq!(s.per_worker[1].errors, 2);
        // Latency pool is merged before percentiles: p50 of
        // [100,100,100,100,500] is 100µs.
        assert_eq!(s.p50, Duration::from_micros(100));
    }
}
