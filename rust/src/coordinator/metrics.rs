//! Serving metrics: request counts, latency percentiles, batch
//! occupancy — one [`ServerMetrics`] per pool worker, aggregated into
//! a single [`MetricsSnapshot`] — plus the fixed-bucket
//! [`LatencyHistogram`] behind the Prometheus text exposition
//! ([`prometheus_text`]) the network front-end serves.
//!
//! Memory is bounded by construction: every latency lands in the
//! histogram (constant size) and in a per-worker ring buffer of the
//! most recent [`LATENCY_WINDOW`] samples (exact percentiles over the
//! recent window), so a week of serving costs the same memory as a
//! minute. Counters and the histogram `_sum`/`_count` cover the whole
//! lifetime.

use std::sync::{Arc, Mutex, MutexGuard, PoisonError};
use std::time::Duration;

/// Lock a mutex, recovering the guard from a poisoned lock. Serving
/// metrics must survive a panicking thread elsewhere in the pool —
/// the supervisor accounts the panic; the counters (monotone u64s and
/// a histogram) are meaningful regardless of where the panic landed.
fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(PoisonError::into_inner)
}

/// Upper bounds (µs, inclusive) of the fixed latency buckets; one
/// implicit `+Inf` bucket follows. Spans 50 µs … 1 s, roughly
/// geometric — wide enough for the synthetic backend's
/// sub-millisecond batches and the SC engine's tens-of-ms forwards.
pub const LATENCY_BUCKET_BOUNDS_US: [u64; 14] = [
    50, 100, 250, 500, 1_000, 2_500, 5_000, 10_000, 25_000, 50_000, 100_000, 250_000, 500_000,
    1_000_000,
];

/// Number of histogram buckets, including the `+Inf` overflow bucket.
pub const LATENCY_BUCKETS: usize = LATENCY_BUCKET_BOUNDS_US.len() + 1;

/// Per-worker cap on the exact-percentile sample window. Latencies
/// beyond this many recent samples survive only in the histogram
/// (bucket-resolution percentiles, exact `_sum`/`_count`).
pub const LATENCY_WINDOW: usize = 4096;

/// Fixed-bucket cumulative latency histogram (Prometheus `histogram`
/// semantics: `buckets[i]` counts samples ≤ bound `i`, the last bucket
/// is `+Inf`, and `sum`/`count` are exact over the full lifetime).
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LatencyHistogram {
    counts: [u64; LATENCY_BUCKETS],
    sum_us: u64,
    count: u64,
}

impl LatencyHistogram {
    /// New, empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one latency sample.
    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_micros() as u64);
    }

    /// Record one latency sample in microseconds.
    pub fn record_us(&mut self, us: u64) {
        let idx = LATENCY_BUCKET_BOUNDS_US
            .iter()
            .position(|&b| us <= b)
            .unwrap_or(LATENCY_BUCKET_BOUNDS_US.len());
        self.counts[idx] += 1;
        self.sum_us += us;
        self.count += 1;
    }

    /// Fold another histogram into this one (bucket-wise sum).
    pub fn merge(&mut self, other: &LatencyHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.sum_us += other.sum_us;
        self.count += other.count;
    }

    /// Total samples recorded (the Prometheus `_count`).
    pub fn count(&self) -> u64 {
        self.count
    }

    /// Sum of all samples in microseconds (the Prometheus `_sum`,
    /// before the seconds conversion).
    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    /// Per-bucket (non-cumulative) counts, `+Inf` last.
    pub fn bucket_counts(&self) -> &[u64; LATENCY_BUCKETS] {
        &self.counts
    }

    /// Cumulative counts per bucket in bound order (`+Inf` last) —
    /// exactly the series a Prometheus `_bucket{le=...}` family wants.
    /// Monotone non-decreasing; the last entry equals
    /// [`LatencyHistogram::count`].
    pub fn cumulative(&self) -> [u64; LATENCY_BUCKETS] {
        let mut out = [0u64; LATENCY_BUCKETS];
        let mut acc = 0u64;
        for (o, c) in out.iter_mut().zip(self.counts.iter()) {
            acc += c;
            *o = acc;
        }
        out
    }

    /// Bucket-resolution quantile estimate: the upper bound of the
    /// first bucket whose cumulative count reaches `q` of the total
    /// (the `+Inf` bucket reports the largest finite bound). Zero when
    /// empty.
    pub fn quantile(&self, q: f64) -> Duration {
        if self.count == 0 {
            return Duration::ZERO;
        }
        let rank = (q.clamp(0.0, 1.0) * self.count as f64).ceil().max(1.0) as u64;
        let mut acc = 0u64;
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= rank {
                let bound = LATENCY_BUCKET_BOUNDS_US
                    .get(i)
                    .copied()
                    .unwrap_or(LATENCY_BUCKET_BOUNDS_US[LATENCY_BUCKET_BOUNDS_US.len() - 1]);
                return Duration::from_micros(bound);
            }
        }
        Duration::from_micros(LATENCY_BUCKET_BOUNDS_US[LATENCY_BUCKET_BOUNDS_US.len() - 1])
    }
}

/// Thread-safe metrics accumulator (one per pool worker).
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    padded_slots: u64,
    errors: u64,
    hist: LatencyHistogram,
    /// Ring buffer of the most recent latencies (µs), capacity
    /// [`LATENCY_WINDOW`]: exact percentiles without unbounded growth.
    recent_us: Vec<u64>,
    recent_next: usize,
}

impl Inner {
    fn push_latency(&mut self, us: u64) {
        self.hist.record_us(us);
        if self.recent_us.len() < LATENCY_WINDOW {
            self.recent_us.push(us);
        } else {
            self.recent_us[self.recent_next] = us;
        }
        self.recent_next = (self.recent_next + 1) % LATENCY_WINDOW;
    }
}

/// Per-worker counters inside a [`MetricsSnapshot`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct WorkerCounts {
    /// Worker index (matches the `scnn-worker-{i}` thread name).
    pub worker: usize,
    /// Requests this worker completed successfully.
    pub requests: u64,
    /// Batches this worker executed.
    pub batches: u64,
    /// Requests this worker failed (executor errors).
    pub errors: u64,
}

/// Pool-level counters merged into a [`MetricsSnapshot`]: they live
/// in the pool's shared admission/supervision state, not in any
/// per-worker accumulator.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct PoolCounters {
    /// Requests rejected by load shedding or tenant admission.
    pub shed: u64,
    /// High-water mark of admitted, unanswered requests.
    pub inflight_peak: usize,
    /// Executor panics caught by worker supervision.
    pub worker_panics: u64,
    /// Executors rebuilt after a caught panic.
    pub worker_respawns: u64,
    /// Requests shed because their deadline passed while queued.
    pub deadline_expired: u64,
    /// Worker threads currently serving their shard.
    pub live_workers: usize,
    /// GEMM rows that failed a datapath-guard integrity check
    /// (`ServeConfig.guard`).
    pub integrity_detected: u64,
    /// Guard-detected rows whose scalar re-execution restored a
    /// passing check.
    pub integrity_recovered: u64,
    /// Conv GEMM dispatches routed through the compressed sparse
    /// panel (subset of `gemm_total`).
    pub sparse_gemm: u64,
    /// Conv GEMM dispatches total (sparse + dense routes).
    pub gemm_total: u64,
    /// Non-zero activation entries seen by the im2col stage.
    pub act_nnz: u64,
    /// Total activation entries seen by the im2col stage
    /// (denominator for the density gauge).
    pub act_elems: u64,
}

/// A point-in-time snapshot aggregated over the whole pool.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Completed requests (across all workers).
    pub requests: u64,
    /// Executed batches (across all workers).
    pub batches: u64,
    /// Mean batch occupancy in [0, 1].
    pub occupancy: f64,
    /// p50 request latency (exact over the recent
    /// [`LATENCY_WINDOW`]-per-worker sample window).
    pub p50: Duration,
    /// p95 request latency (same window).
    pub p95: Duration,
    /// p99 request latency (same window).
    pub p99: Duration,
    /// Mean request latency (exact over the full lifetime, from the
    /// histogram `_sum`/`_count`).
    pub mean: Duration,
    /// Requests that failed with an executor error.
    pub errors: u64,
    /// Requests rejected by load shedding ([`OverloadPolicy::Shed`])
    /// or tenant admission control.
    ///
    /// [`OverloadPolicy::Shed`]: super::OverloadPolicy::Shed
    pub shed: u64,
    /// Number of pool workers aggregated into this snapshot.
    pub workers: usize,
    /// Peak number of requests queued/executing at once (high-water
    /// mark of the admission gauge).
    pub inflight_peak: usize,
    /// Executor panics caught by worker supervision (each failed one
    /// batch of requests with a typed error).
    pub worker_panics: u64,
    /// Executors rebuilt from the factory after a caught panic
    /// (bounded by the pool's restart budget).
    pub worker_respawns: u64,
    /// Requests shed because their deadline passed before execution
    /// (disjoint from `shed` and `errors`).
    pub deadline_expired: u64,
    /// Worker threads currently serving; less than `workers` once a
    /// worker exhausts its restart budget.
    pub live_workers: usize,
    /// GEMM rows that failed the datapath guard's count-domain
    /// integrity checks (zero when the guard is off).
    pub integrity_detected: u64,
    /// Guard-detected rows healed by scalar re-execution. Equal to
    /// `integrity_detected` while recovery holds its 100% contract.
    pub integrity_recovered: u64,
    /// Conv GEMM dispatches that took the sparse (compressed-panel)
    /// route; zero for non-SC backends.
    pub sparse_gemm: u64,
    /// Conv GEMM dispatches total, dense and sparse.
    pub gemm_total: u64,
    /// Measured activation density in [0, 1] over all im2col panels
    /// (non-zeros / total), 1.0 before any SC batch runs.
    pub activation_density: f64,
    /// Full-lifetime latency histogram (bucket-wise sum over workers).
    pub hist: LatencyHistogram,
    /// Per-worker breakdown, indexed by worker.
    pub per_worker: Vec<WorkerCounts>,
}

impl ServerMetrics {
    /// New, empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch: `filled` live requests with their
    /// end-to-end latencies, `capacity` total slots.
    pub fn record_batch(&self, latencies: &[Duration], capacity: usize) {
        let mut g = lock(&self.inner);
        g.requests += latencies.len() as u64;
        g.batches += 1;
        g.padded_slots += (capacity - latencies.len()) as u64;
        for d in latencies {
            g.push_latency(d.as_micros() as u64);
        }
    }

    /// Record `n` requests that failed with an executor error.
    pub fn record_errors(&self, n: u64) {
        lock(&self.inner).errors += n;
    }

    /// Number of latency samples currently held for exact percentiles
    /// — never exceeds [`LATENCY_WINDOW`] (the memory-cap invariant;
    /// older samples live on in the histogram only).
    pub fn latency_samples(&self) -> usize {
        lock(&self.inner).recent_us.len()
    }

    /// Single-worker snapshot (sorts the recent-latency window;
    /// intended for end-of-run reporting).
    pub fn snapshot(&self, capacity: usize) -> MetricsSnapshot {
        Self::merge(
            [self].into_iter(),
            capacity,
            PoolCounters { live_workers: 1, ..PoolCounters::default() },
        )
    }

    /// Aggregate the per-worker accumulators of a pool into one
    /// snapshot. The [`PoolCounters`] come from the pool's shared
    /// admission/supervision state.
    pub fn aggregate(
        workers: &[Arc<ServerMetrics>],
        capacity: usize,
        counters: PoolCounters,
    ) -> MetricsSnapshot {
        Self::merge(workers.iter().map(Arc::as_ref), capacity, counters)
    }

    fn merge<'a>(
        workers: impl Iterator<Item = &'a ServerMetrics>,
        capacity: usize,
        counters: PoolCounters,
    ) -> MetricsSnapshot {
        let mut recent: Vec<u64> = Vec::new();
        let mut hist = LatencyHistogram::new();
        let mut per_worker = Vec::new();
        let (mut requests, mut batches, mut padded, mut errors) = (0u64, 0u64, 0u64, 0u64);
        for (w, m) in workers.enumerate() {
            let g = lock(&m.inner);
            requests += g.requests;
            batches += g.batches;
            padded += g.padded_slots;
            errors += g.errors;
            hist.merge(&g.hist);
            recent.extend_from_slice(&g.recent_us);
            per_worker.push(WorkerCounts {
                worker: w,
                requests: g.requests,
                batches: g.batches,
                errors: g.errors,
            });
        }
        recent.sort_unstable();
        let n = recent.len();
        let pick = |q: f64| -> Duration {
            if n == 0 {
                return Duration::ZERO;
            }
            let idx = ((n as f64 - 1.0) * q).round() as usize;
            Duration::from_micros(recent[idx])
        };
        let mean = if hist.count() == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(hist.sum_us() / hist.count())
        };
        let slots = batches * capacity as u64;
        MetricsSnapshot {
            requests,
            batches,
            occupancy: if slots == 0 { 0.0 } else { 1.0 - padded as f64 / slots as f64 },
            p50: pick(0.5),
            p95: pick(0.95),
            p99: pick(0.99),
            mean,
            errors,
            shed: counters.shed,
            workers: per_worker.len(),
            inflight_peak: counters.inflight_peak,
            worker_panics: counters.worker_panics,
            worker_respawns: counters.worker_respawns,
            deadline_expired: counters.deadline_expired,
            live_workers: counters.live_workers,
            integrity_detected: counters.integrity_detected,
            integrity_recovered: counters.integrity_recovered,
            sparse_gemm: counters.sparse_gemm,
            gemm_total: counters.gemm_total,
            activation_density: if counters.act_elems == 0 {
                1.0
            } else {
                counters.act_nnz as f64 / counters.act_elems as f64
            },
            hist,
            per_worker,
        }
    }
}

/// Escape a Prometheus label value (backslash, quote, newline).
fn escape_label(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"").replace('\n', "\\n")
}

/// Format microseconds as seconds the way Prometheus bounds are
/// spelled (shortest float round-trip: `50 µs` → `0.00005`).
fn secs(us: u64) -> String {
    (us as f64 / 1e6).to_string()
}

/// Render one metric family: `# HELP` + `# TYPE` headers followed by
/// one sample per `(labels, value)` row.
fn family(out: &mut String, name: &str, kind: &str, help: &str, rows: &[(String, String)]) {
    if rows.is_empty() {
        return;
    }
    out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
    for (labels, value) in rows {
        out.push_str(&format!("{name}{{{labels}}} {value}\n"));
    }
}

/// Prometheus text exposition (text format 0.0.4) over a set of named
/// model snapshots: request/error/shed counters, occupancy and
/// in-flight gauges, the cumulative latency histogram
/// (`scnn_request_latency_seconds_bucket{le=...}` + `_sum`/`_count`),
/// and p50/p95/p99 quantile gauges per model.
pub fn prometheus_text(models: &[(&str, MetricsSnapshot)]) -> String {
    let mut out = String::new();
    let label = |m: &str| format!("model=\"{}\"", escape_label(m));
    let counter_rows = |f: &dyn Fn(&MetricsSnapshot) -> u64| -> Vec<(String, String)> {
        models.iter().map(|(m, s)| (label(m), f(s).to_string())).collect()
    };
    family(
        &mut out,
        "scnn_requests_total",
        "counter",
        "Requests completed successfully.",
        &counter_rows(&|s| s.requests),
    );
    family(
        &mut out,
        "scnn_request_errors_total",
        "counter",
        "Requests failed with an executor error.",
        &counter_rows(&|s| s.errors),
    );
    family(
        &mut out,
        "scnn_requests_shed_total",
        "counter",
        "Requests rejected by load shedding or tenant admission.",
        &counter_rows(&|s| s.shed),
    );
    family(
        &mut out,
        "scnn_batches_total",
        "counter",
        "Executor batch invocations.",
        &counter_rows(&|s| s.batches),
    );
    family(
        &mut out,
        "scnn_batch_occupancy",
        "gauge",
        "Mean live-slot fraction per executed batch.",
        &models.iter().map(|(m, s)| (label(m), s.occupancy.to_string())).collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "scnn_inflight_peak",
        "gauge",
        "High-water mark of admitted, unanswered requests.",
        &counter_rows(&|s| s.inflight_peak as u64),
    );
    family(
        &mut out,
        "scnn_worker_panics_total",
        "counter",
        "Executor panics caught by worker supervision.",
        &counter_rows(&|s| s.worker_panics),
    );
    family(
        &mut out,
        "scnn_worker_respawns_total",
        "counter",
        "Executors rebuilt after a caught panic.",
        &counter_rows(&|s| s.worker_respawns),
    );
    family(
        &mut out,
        "scnn_deadline_expired_total",
        "counter",
        "Requests shed because their deadline passed while queued.",
        &counter_rows(&|s| s.deadline_expired),
    );
    family(
        &mut out,
        "scnn_workers_live",
        "gauge",
        "Worker threads currently serving their shard.",
        &counter_rows(&|s| s.live_workers as u64),
    );
    family(
        &mut out,
        "scnn_integrity_faults_detected_total",
        "counter",
        "GEMM rows that failed a datapath-guard integrity check.",
        &counter_rows(&|s| s.integrity_detected),
    );
    family(
        &mut out,
        "scnn_integrity_recovered_total",
        "counter",
        "Guard-detected rows healed by scalar re-execution.",
        &counter_rows(&|s| s.integrity_recovered),
    );
    family(
        &mut out,
        "scnn_sparse_gemm_total",
        "counter",
        "Conv GEMM dispatches routed through the sparse panel.",
        &counter_rows(&|s| s.sparse_gemm),
    );
    family(
        &mut out,
        "scnn_gemm_total",
        "counter",
        "Conv GEMM dispatches, dense and sparse routes combined.",
        &counter_rows(&|s| s.gemm_total),
    );
    family(
        &mut out,
        "scnn_activation_density",
        "gauge",
        "Measured activation density over im2col panels (1.0 when idle).",
        &models
            .iter()
            .map(|(m, s)| (label(m), s.activation_density.to_string()))
            .collect::<Vec<_>>(),
    );
    // Histogram family: cumulative buckets, then _sum and _count.
    let mut rows = Vec::new();
    for (m, s) in models {
        let cum = s.hist.cumulative();
        for (i, &bound) in LATENCY_BUCKET_BOUNDS_US.iter().enumerate() {
            rows.push((format!("{},le=\"{}\"", label(m), secs(bound)), cum[i].to_string()));
        }
        rows.push((format!("{},le=\"+Inf\"", label(m)), cum[LATENCY_BUCKETS - 1].to_string()));
    }
    family(
        &mut out,
        "scnn_request_latency_seconds_bucket",
        "counter",
        "Cumulative request-latency distribution.",
        &rows,
    );
    family(
        &mut out,
        "scnn_request_latency_seconds_sum",
        "counter",
        "Sum of request latencies in seconds.",
        &models.iter().map(|(m, s)| (label(m), secs(s.hist.sum_us()))).collect::<Vec<_>>(),
    );
    family(
        &mut out,
        "scnn_request_latency_seconds_count",
        "counter",
        "Count of latency samples.",
        &counter_rows(&|s| s.hist.count()),
    );
    let mut qrows = Vec::new();
    for (m, s) in models {
        for (q, d) in [(0.5, s.p50), (0.95, s.p95), (0.99, s.p99)] {
            qrows.push((format!("{},quantile=\"{}\"", label(m), q), secs(d.as_micros() as u64)));
        }
    }
    family(
        &mut out,
        "scnn_request_latency_quantile_seconds",
        "gauge",
        "Exact latency quantiles over the recent sample window.",
        &qrows,
    );
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::new();
        m.record_batch(&[Duration::from_micros(100), Duration::from_micros(300)], 4);
        m.record_batch(&[Duration::from_micros(200)], 4);
        let s = m.snapshot(4);
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert!((s.occupancy - 3.0 / 8.0).abs() < 1e-9);
        assert_eq!(s.p50, Duration::from_micros(200));
        assert_eq!(s.mean, Duration::from_micros(200));
        assert_eq!(s.workers, 1);
        assert_eq!(s.errors, 0);
        assert_eq!(s.shed, 0);
    }

    #[test]
    fn empty_snapshot() {
        let m = ServerMetrics::new();
        let s = m.snapshot(8);
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99, Duration::ZERO);
        assert_eq!(s.per_worker.len(), 1);
        assert_eq!(s.hist.count(), 0);
        assert_eq!(s.hist.quantile(0.5), Duration::ZERO);
    }

    #[test]
    fn aggregates_across_workers() {
        let a = Arc::new(ServerMetrics::new());
        let b = Arc::new(ServerMetrics::new());
        a.record_batch(&[Duration::from_micros(100); 4], 4);
        b.record_batch(&[Duration::from_micros(500)], 4);
        b.record_errors(2);
        let counters = PoolCounters {
            shed: 3,
            inflight_peak: 17,
            worker_panics: 2,
            worker_respawns: 1,
            deadline_expired: 5,
            live_workers: 2,
            integrity_detected: 4,
            integrity_recovered: 4,
            sparse_gemm: 6,
            gemm_total: 9,
            act_nnz: 25,
            act_elems: 100,
        };
        let s = ServerMetrics::aggregate(&[a, b], 4, counters);
        assert_eq!(s.requests, 5);
        assert_eq!(s.batches, 2);
        assert_eq!(s.errors, 2);
        assert_eq!(s.shed, 3);
        assert_eq!(s.workers, 2);
        assert_eq!(s.inflight_peak, 17);
        assert_eq!(s.worker_panics, 2);
        assert_eq!(s.worker_respawns, 1);
        assert_eq!(s.deadline_expired, 5);
        assert_eq!(s.live_workers, 2);
        assert_eq!(s.integrity_detected, 4);
        assert_eq!(s.integrity_recovered, 4);
        assert_eq!(s.sparse_gemm, 6);
        assert_eq!(s.gemm_total, 9);
        assert!((s.activation_density - 0.25).abs() < 1e-12);
        assert!((s.occupancy - 5.0 / 8.0).abs() < 1e-9);
        assert_eq!(s.p99, Duration::from_micros(500));
        assert_eq!(s.per_worker[0].requests, 4);
        assert_eq!(s.per_worker[1].requests, 1);
        assert_eq!(s.per_worker[1].errors, 2);
        // Latency pool is merged before percentiles: p50 of
        // [100,100,100,100,500] is 100µs.
        assert_eq!(s.p50, Duration::from_micros(100));
        // The merged histogram agrees with the merged counters.
        assert_eq!(s.hist.count(), 5);
        assert_eq!(s.hist.sum_us(), 900);
    }

    #[test]
    fn histogram_buckets_are_cumulative_and_monotone() {
        let mut h = LatencyHistogram::new();
        for us in [10, 50, 51, 100, 2_000, 9_999, 2_000_000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.sum_us(), 10 + 50 + 51 + 100 + 2_000 + 9_999 + 2_000_000);
        let cum = h.cumulative();
        for w in cum.windows(2) {
            assert!(w[0] <= w[1], "cumulative counts must be monotone: {cum:?}");
        }
        assert_eq!(cum[LATENCY_BUCKETS - 1], h.count());
        // ≤ 50 µs: the 10 and 50 samples (bounds are inclusive).
        assert_eq!(cum[0], 2);
        // ≤ 100 µs adds 51 and 100.
        assert_eq!(cum[1], 4);
        // The 2 s sample lands only in +Inf.
        assert_eq!(cum[LATENCY_BUCKETS - 2], 6);
    }

    #[test]
    fn histogram_merge_and_quantile() {
        let mut a = LatencyHistogram::new();
        let mut b = LatencyHistogram::new();
        for _ in 0..9 {
            a.record_us(100);
        }
        b.record_us(400_000);
        a.merge(&b);
        assert_eq!(a.count(), 10);
        // p50 falls in the ≤100 µs bucket, p99 in the ≤500 ms bucket.
        assert_eq!(a.quantile(0.5), Duration::from_micros(100));
        assert_eq!(a.quantile(0.99), Duration::from_micros(500_000));
    }

    #[test]
    fn latency_window_is_capped() {
        let m = ServerMetrics::new();
        let total = LATENCY_WINDOW + 1_000;
        for i in 0..total {
            m.record_batch(&[Duration::from_micros(i as u64 + 1)], 1);
        }
        // The exact-percentile pool is capped; lifetime counters are not.
        assert_eq!(m.latency_samples(), LATENCY_WINDOW);
        let s = m.snapshot(1);
        assert_eq!(s.requests, total as u64);
        assert_eq!(s.hist.count(), total as u64);
        // The ring holds the *most recent* window: its minimum is the
        // first sample that was not overwritten.
        assert!(s.p50 >= Duration::from_micros(1_000));
        // Lifetime mean stays exact (sum of 1..=total over total).
        let sum: u64 = (1..=total as u64).sum();
        assert_eq!(s.mean, Duration::from_micros(sum / total as u64));
    }

    #[test]
    fn prometheus_exposition_is_consistent() {
        let m = ServerMetrics::new();
        m.record_batch(
            &[Duration::from_micros(80), Duration::from_micros(80), Duration::from_micros(30_000)],
            4,
        );
        let s = m.snapshot(4);
        let text = prometheus_text(&[("tnn", s.clone())]);
        // _count and _sum agree with the snapshot's histogram.
        assert!(text.contains(&format!(
            "scnn_request_latency_seconds_count{{model=\"tnn\"}} {}",
            s.hist.count()
        )));
        assert!(text.contains(&format!(
            "scnn_request_latency_seconds_sum{{model=\"tnn\"}} {}",
            s.hist.sum_us() as f64 / 1e6
        )));
        assert!(text.contains("scnn_requests_total{model=\"tnn\"} 3"));
        // Fault-tolerance families are always exposed, even at zero,
        // so dashboards can alert on the first panic ever.
        assert!(text.contains("scnn_worker_panics_total{model=\"tnn\"} 0"), "{text}");
        assert!(text.contains("scnn_worker_respawns_total{model=\"tnn\"} 0"), "{text}");
        assert!(text.contains("scnn_deadline_expired_total{model=\"tnn\"} 0"), "{text}");
        assert!(text.contains("scnn_workers_live{model=\"tnn\"} 1"), "{text}");
        assert!(text.contains("scnn_integrity_faults_detected_total{model=\"tnn\"} 0"), "{text}");
        assert!(text.contains("scnn_integrity_recovered_total{model=\"tnn\"} 0"), "{text}");
        // Sparsity families are exposed too; density idles at 1.
        assert!(text.contains("scnn_sparse_gemm_total{model=\"tnn\"} 0"), "{text}");
        assert!(text.contains("scnn_gemm_total{model=\"tnn\"} 0"), "{text}");
        assert!(text.contains("scnn_activation_density{model=\"tnn\"} 1"), "{text}");
        // Bucket series is cumulative: two samples ≤ 100 µs, all three
        // ≤ 50 ms and in +Inf.
        let bucket = |le: &str, n: u64| {
            format!("scnn_request_latency_seconds_bucket{{model=\"tnn\",le=\"{le}\"}} {n}")
        };
        assert!(text.contains(&bucket("0.0001", 2)), "{text}");
        assert!(text.contains(&bucket("0.05", 3)), "{text}");
        assert!(text.contains(&bucket("+Inf", 3)), "{text}");
        // Quantile gauges are present per model.
        let q50 = "scnn_request_latency_quantile_seconds{model=\"tnn\",quantile=\"0.5\"}";
        assert!(text.contains(q50), "{text}");
        // Every bucket line count is monotone in the order emitted.
        let mut last = 0u64;
        for line in text.lines().filter(|l| l.starts_with("scnn_request_latency_seconds_bucket")) {
            let v: u64 = line.rsplit(' ').next().unwrap().parse().unwrap();
            assert!(v >= last, "bucket series must be monotone: {text}");
            last = v;
        }
        // HELP/TYPE headers come exactly once per family.
        assert_eq!(text.matches("# TYPE scnn_requests_total counter").count(), 1);
    }

    #[test]
    fn prometheus_escapes_label_values() {
        let s = ServerMetrics::new().snapshot(1);
        let text = prometheus_text(&[("we\"ird\\name", s)]);
        assert!(text.contains("model=\"we\\\"ird\\\\name\""));
    }
}
