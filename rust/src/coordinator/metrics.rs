//! Serving metrics: request counts, latency percentiles, batch
//! occupancy.

use std::sync::Mutex;
use std::time::Duration;

/// Thread-safe metrics accumulator.
#[derive(Debug, Default)]
pub struct ServerMetrics {
    inner: Mutex<Inner>,
}

#[derive(Debug, Default)]
struct Inner {
    requests: u64,
    batches: u64,
    padded_slots: u64,
    latencies_us: Vec<u64>,
}

/// A point-in-time snapshot.
#[derive(Clone, Debug)]
pub struct MetricsSnapshot {
    /// Completed requests.
    pub requests: u64,
    /// Executed batches.
    pub batches: u64,
    /// Mean batch occupancy in [0, 1].
    pub occupancy: f64,
    /// p50 request latency.
    pub p50: Duration,
    /// p99 request latency.
    pub p99: Duration,
    /// Mean request latency.
    pub mean: Duration,
}

impl ServerMetrics {
    /// New, empty.
    pub fn new() -> Self {
        Self::default()
    }

    /// Record one executed batch: `filled` live requests with their
    /// end-to-end latencies, `capacity` total slots.
    pub fn record_batch(&self, latencies: &[Duration], capacity: usize) {
        let mut g = self.inner.lock().unwrap();
        g.requests += latencies.len() as u64;
        g.batches += 1;
        g.padded_slots += (capacity - latencies.len()) as u64;
        g.latencies_us
            .extend(latencies.iter().map(|d| d.as_micros() as u64));
    }

    /// Snapshot (sorts latencies; intended for end-of-run reporting).
    pub fn snapshot(&self, capacity: usize) -> MetricsSnapshot {
        let mut g = self.inner.lock().unwrap();
        g.latencies_us.sort_unstable();
        let n = g.latencies_us.len();
        let pick = |q: f64| -> Duration {
            if n == 0 {
                return Duration::ZERO;
            }
            let idx = ((n as f64 - 1.0) * q).round() as usize;
            Duration::from_micros(g.latencies_us[idx])
        };
        let mean = if n == 0 {
            Duration::ZERO
        } else {
            Duration::from_micros(g.latencies_us.iter().sum::<u64>() / n as u64)
        };
        let slots = g.batches * capacity as u64;
        MetricsSnapshot {
            requests: g.requests,
            batches: g.batches,
            occupancy: if slots == 0 {
                0.0
            } else {
                1.0 - g.padded_slots as f64 / slots as f64
            },
            p50: pick(0.5),
            p99: pick(0.99),
            mean,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn records_and_snapshots() {
        let m = ServerMetrics::new();
        m.record_batch(
            &[Duration::from_micros(100), Duration::from_micros(300)],
            4,
        );
        m.record_batch(&[Duration::from_micros(200)], 4);
        let s = m.snapshot(4);
        assert_eq!(s.requests, 3);
        assert_eq!(s.batches, 2);
        assert!((s.occupancy - 3.0 / 8.0).abs() < 1e-9);
        assert_eq!(s.p50, Duration::from_micros(200));
        assert_eq!(s.mean, Duration::from_micros(200));
    }

    #[test]
    fn empty_snapshot() {
        let m = ServerMetrics::new();
        let s = m.snapshot(8);
        assert_eq!(s.requests, 0);
        assert_eq!(s.p99, Duration::ZERO);
    }
}
