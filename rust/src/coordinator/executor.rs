//! Pluggable batch executors behind the inference pool.
//!
//! The pool in [`super::batcher`] is backend-agnostic: each worker
//! thread owns one [`BatchExecutor`], built *inside* that thread by an
//! [`ExecutorFactory`]. The factory indirection exists because PJRT
//! handles are not `Send` (the `xla` crate wraps raw pointers in
//! `Rc`): a [`Trainer`] can never cross a thread boundary, but a
//! closure that builds one can. It is also the seam the unified
//! [`super::Backend`] registry plugs into — a worker neither knows nor
//! cares whether its batches run on PJRT ([`PjrtExecutor`]), the native
//! bit-exact SC engine ([`ScBatchExecutor`]), the binary fixed-point
//! baseline ([`BinaryBatchExecutor`]), or the in-process synthetic
//! model used by tests and benches ([`SyntheticExecutor`]).
//!
//! `run_batch` takes `&mut self`: a worker exclusively owns its
//! executor, and the native SC engine reuses per-worker scratch arenas
//! across batches (the zero-allocation steady state).

use std::sync::Arc;
use std::time::Duration;

use crate::fault::guard::{DatapathGuard, GuardCounters};
use crate::nn::binary_exec::BinaryExecutor;
use crate::nn::sc_engine::{ScEngine, SparsityCounters};
use crate::nn::sc_exec::Prepared;
use crate::nn::tensor::Tensor;
use crate::runtime::{trainer::Knobs, Runtime, Trainer};
use crate::Result;

/// Fixed shape contract of one executor: every worker in a pool must
/// report the same spec (checked at startup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecutorSpec {
    /// Flattened image length (C·H·W floats per request).
    pub image_len: usize,
    /// Fixed batch capacity of one execution (AOT-compiled batch).
    pub batch: usize,
    /// Logits per request.
    pub classes: usize,
}

/// A batch-at-a-time inference engine owned by a single pool worker.
pub trait BatchExecutor {
    /// The executor's shape contract.
    fn spec(&self) -> ExecutorSpec;

    /// Run one padded batch of `spec().batch * spec().image_len`
    /// floats, returning `spec().batch * spec().classes` logits.
    /// `filled` is the number of live rows at the front of the batch
    /// (the rest is zero padding): backends with per-row cost compute
    /// only those rows and may return anything (canonically zeros) in
    /// the padded rows, which the pool never reads. Fixed-shape
    /// backends (AOT-compiled PJRT) are free to ignore it.
    /// Takes `&mut self` so stateful backends can reuse their scratch
    /// arenas across batches.
    fn run_batch(&mut self, x: &[f32], filled: usize) -> Result<Vec<f32>>;
}

/// Builds a worker's executor inside the worker thread. The argument
/// is the worker index (0-based), for logging or device placement.
pub type ExecutorFactory = Box<dyn Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync>;

/// PJRT-backed executor: the serving path (integer codes through the
/// Pallas kernel) of an AOT-exported model. Each instance owns its own
/// [`Runtime`] and [`Trainer`] because PJRT handles are not `Send`.
pub struct PjrtExecutor {
    trainer: Trainer,
    knobs: Knobs,
    spec: ExecutorSpec,
}

impl PjrtExecutor {
    /// Build a runtime, load the model's executables, and optionally
    /// install trained parameters.
    pub fn new(
        artifacts: &str,
        model: &str,
        params: Option<&[Vec<f32>]>,
        knobs: Knobs,
    ) -> Result<Self> {
        let rt = Runtime::new(artifacts)?;
        let mut trainer = Trainer::new(&rt, model)?;
        if let Some(p) = params {
            trainer.set_params(p.to_vec())?;
        }
        let (c, h, w) = trainer.meta().input;
        let spec = ExecutorSpec {
            image_len: c * h * w,
            batch: trainer.meta().batch,
            classes: trainer.meta().classes,
        };
        Ok(Self { trainer, knobs, spec })
    }
}

impl BatchExecutor for PjrtExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.spec
    }

    fn run_batch(&mut self, x: &[f32], _filled: usize) -> Result<Vec<f32>> {
        // The AOT executable has a fixed batch shape; padded rows cost
        // the same either way.
        self.trainer.logits(x, self.knobs, true)
    }
}

/// Native SC serving backend: the batched, bit-exact
/// [`ScEngine`] behind the pool — the paper's deterministic-coding
/// datapath served directly, no AOT artifacts required. All workers
/// share one frozen [`Prepared`] (`Arc`, including the packed GEMM
/// panels); each worker owns its own engine (scratch arenas are
/// per-worker state, one arena set per engine thread). With
/// `threads > 1` the engine shards each batch over rows × output-
/// channel blocks (rows when the batch is wide, channel blocks within
/// a row when it isn't) — logits stay bit-identical at any thread
/// count. Logits are the SC executor's integer class scores, converted
/// to `f32` losslessly for the wire format.
pub struct ScBatchExecutor {
    engine: ScEngine,
    spec: ExecutorSpec,
    logits: Vec<i64>,
}

impl ScBatchExecutor {
    /// Build over a shared frozen model, with a fixed per-execution
    /// batch capacity and intra-engine thread count (both clamped to
    /// ≥ 1).
    pub fn new(prep: Arc<Prepared>, batch: usize, threads: usize) -> Self {
        let engine = ScEngine::with_threads(prep, threads.max(1));
        let batch = batch.max(1);
        let spec = ExecutorSpec {
            image_len: engine.image_len(),
            batch,
            classes: engine.classes(),
        };
        Self { engine, spec, logits: vec![0i64; batch * spec.classes] }
    }

    /// Factory for [`super::Coordinator::start_with`]: every worker
    /// shares `prep`, each builds its own engine in-thread.
    pub fn factory(prep: Arc<Prepared>, batch: usize, threads: usize) -> ExecutorFactory {
        Self::factory_with(prep, batch, threads, None, None)
    }

    /// [`ScBatchExecutor::factory`] with the count-domain integrity
    /// guard armed and/or the sparsity telemetry sink attached: one
    /// [`DatapathGuard`] (shared `Arc`) checks every worker's GEMM row
    /// blocks, and one [`SparsityCounters`] block aggregates measured
    /// activation density and sparse-path hit rate across the fleet.
    pub fn factory_with(
        prep: Arc<Prepared>,
        batch: usize,
        threads: usize,
        guard: Option<Arc<GuardCounters>>,
        sparsity: Option<Arc<SparsityCounters>>,
    ) -> ExecutorFactory {
        let guard = guard.map(|c| Arc::new(DatapathGuard::new(c)));
        Box::new(move |_worker| {
            let mut exec = ScBatchExecutor::new(prep.clone(), batch, threads);
            exec.engine.set_guard(guard.clone());
            exec.engine.set_sparsity_counters(sparsity.clone());
            Ok(Box::new(exec))
        })
    }
}

impl BatchExecutor for ScBatchExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.spec
    }

    fn run_batch(&mut self, x: &[f32], filled: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == self.spec.batch * self.spec.image_len,
            "batch input length {} != {}",
            x.len(),
            self.spec.batch * self.spec.image_len
        );
        // Only the live rows are forwarded — a partial batch at light
        // load must not pay full-batch SC-model cost for zero padding.
        let filled = filled.min(self.spec.batch);
        self.engine.forward_batch_into(
            &x[..filled * self.spec.image_len],
            &mut self.logits[..filled * self.spec.classes],
        );
        for v in &mut self.logits[filled * self.spec.classes..] {
            *v = 0;
        }
        Ok(self.logits.iter().map(|&v| v as f32).collect())
    }
}

/// Binary fixed-point baseline behind the pool: the conventional
/// datapath over the same frozen network, for apples-to-apples serving
/// comparisons against [`ScBatchExecutor`]. Per-image path (the
/// baseline is not the optimized engine).
pub struct BinaryBatchExecutor {
    exec: BinaryExecutor,
    spec: ExecutorSpec,
}

impl BinaryBatchExecutor {
    /// Build over a shared frozen model.
    pub fn new(prep: Arc<Prepared>, batch: usize) -> Self {
        let (c, h, w) = prep.cfg.input;
        let spec = ExecutorSpec {
            image_len: c * h * w,
            batch: batch.max(1),
            classes: prep.cfg.num_classes,
        };
        Self { exec: BinaryExecutor::new(prep), spec }
    }

    /// Factory for [`super::Coordinator::start_with`].
    pub fn factory(prep: Arc<Prepared>, batch: usize) -> ExecutorFactory {
        Box::new(move |_worker| Ok(Box::new(BinaryBatchExecutor::new(prep.clone(), batch))))
    }
}

impl BatchExecutor for BinaryBatchExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.spec
    }

    fn run_batch(&mut self, x: &[f32], filled: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == self.spec.batch * self.spec.image_len,
            "batch input length {} != {}",
            x.len(),
            self.spec.batch * self.spec.image_len
        );
        let (c, h, w) = self.exec.prepared().cfg.input;
        let mut out = Vec::with_capacity(self.spec.batch * self.spec.classes);
        for b in 0..filled.min(self.spec.batch) {
            let img = Tensor::from_vec(
                &[c, h, w],
                x[b * self.spec.image_len..(b + 1) * self.spec.image_len].to_vec(),
            );
            out.extend(self.exec.forward(&img).into_iter().map(|v| v as f32));
        }
        out.resize(self.spec.batch * self.spec.classes, 0.0);
        Ok(out)
    }
}

/// Deterministic in-process model for tests and benchmarks: logits are
/// a pure function of each image (identical results for any worker
/// count), and each executed batch costs a fixed simulated latency,
/// like a busy fixed-batch accelerator. Because the cost is latency
/// (not host CPU), a worker-scaling sweep shows real scaling on any
/// host.
pub struct SyntheticExecutor {
    spec: ExecutorSpec,
    latency: Duration,
}

impl SyntheticExecutor {
    /// New executor with zero simulated latency.
    pub fn new(spec: ExecutorSpec) -> Self {
        Self { spec, latency: Duration::ZERO }
    }

    /// Set the simulated per-batch latency.
    pub fn with_latency(mut self, latency: Duration) -> Self {
        self.latency = latency;
        self
    }

    /// Convenience factory for [`super::Coordinator::start_with`].
    pub fn factory(spec: ExecutorSpec, latency: Duration) -> ExecutorFactory {
        Box::new(move |_worker| Ok(Box::new(SyntheticExecutor::new(spec).with_latency(latency))))
    }

    /// The demo-grade fallback the CLI and `examples/serve.rs` share
    /// when AOT artifacts are absent: batch 16, 2 ms simulated batch
    /// latency (a plausible small-accelerator operating point).
    pub fn demo_factory(image_len: usize, classes: usize) -> ExecutorFactory {
        Self::factory(
            ExecutorSpec { image_len, batch: 16, classes },
            Duration::from_millis(2),
        )
    }

    /// The reference logits for one image — exposed so tests can check
    /// pool responses against ground truth.
    pub fn reference_logits(&self, image: &[f32]) -> Vec<f32> {
        debug_assert_eq!(image.len(), self.spec.image_len);
        let mut out = Vec::with_capacity(self.spec.classes);
        for c in 0..self.spec.classes {
            // Class-dependent strided projection: cheap, deterministic,
            // and discriminative enough that argmax varies with input.
            let stride = c + 1;
            let mut acc = 0.0f32;
            let mut i = c % self.spec.image_len.max(1);
            while i < image.len() {
                acc += image[i] * (1.0 + (c as f32) * 0.125);
                i += stride;
            }
            out.push(acc / (image.len() as f32 / stride as f32).max(1.0));
        }
        out
    }
}

impl BatchExecutor for SyntheticExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.spec
    }

    fn run_batch(&mut self, x: &[f32], filled: usize) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == self.spec.batch * self.spec.image_len,
            "batch input length {} != {}",
            x.len(),
            self.spec.batch * self.spec.image_len
        );
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        let mut out = Vec::with_capacity(self.spec.batch * self.spec.classes);
        for b in 0..filled.min(self.spec.batch) {
            let image = &x[b * self.spec.image_len..(b + 1) * self.spec.image_len];
            out.extend(self.reference_logits(image));
        }
        out.resize(self.spec.batch * self.spec.classes, 0.0);
        Ok(out)
    }
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_shape_correct() {
        let spec = ExecutorSpec { image_len: 8, batch: 3, classes: 4 };
        let mut exec = SyntheticExecutor::new(spec);
        let x: Vec<f32> = (0..24).map(|i| i as f32 * 0.1).collect();
        let a = exec.run_batch(&x, 3).unwrap();
        let b = exec.run_batch(&x, 3).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        // Row 1 equals the reference logits of image 1.
        assert_eq!(&a[4..8], exec.reference_logits(&x[8..16]).as_slice());
        // Padded rows (filled < batch) come back zeroed, full length.
        let p = exec.run_batch(&x, 1).unwrap();
        assert_eq!(p.len(), 12);
        assert_eq!(&p[..4], &a[..4]);
        assert!(p[4..].iter().all(|&v| v == 0.0));
        // Input length is validated.
        assert!(exec.run_batch(&x[..23], 2).is_err());
    }

    #[test]
    fn synthetic_logits_vary_by_input() {
        let spec = ExecutorSpec { image_len: 16, batch: 1, classes: 10 };
        let exec = SyntheticExecutor::new(spec);
        let a = exec.reference_logits(&[0.5; 16]);
        let mut img = vec![0.5; 16];
        img[3] = -2.0;
        let b = exec.reference_logits(&img);
        assert_ne!(a, b);
    }

    #[test]
    fn sc_batch_executor_matches_sc_executor() {
        use crate::nn::model::{ModelCfg, ModelParams};
        use crate::nn::quant::{Pruning, QuantConfig};
        use crate::nn::sc_exec::ScExecutor;
        use crate::util::Rng;

        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(7);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Arc::new(Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        ));
        let mut be = ScBatchExecutor::new(prep.clone(), 2, 2);
        assert_eq!(be.spec(), ExecutorSpec { image_len: 784, batch: 2, classes: 10 });
        let x: Vec<f32> = (0..2 * 784).map(|_| rng.normal() as f32).collect();
        let logits = be.run_batch(&x, 2).unwrap();
        assert_eq!(logits.len(), 20);
        let exec = ScExecutor::new(prep);
        for b in 0..2 {
            let img = Tensor::from_vec(&[1, 28, 28], x[b * 784..(b + 1) * 784].to_vec());
            let expect: Vec<f32> = exec.forward(&img).into_iter().map(|v| v as f32).collect();
            assert_eq!(&logits[b * 10..(b + 1) * 10], expect.as_slice(), "row {b}");
        }
        // Partial batch: only the live row is computed, padding is zeroed.
        let partial = be.run_batch(&x, 1).unwrap();
        assert_eq!(&partial[..10], &logits[..10]);
        assert!(partial[10..].iter().all(|&v| v == 0.0));
        // Wrong batch length is rejected.
        assert!(be.run_batch(&x[..784], 1).is_err());
    }

    #[test]
    fn binary_batch_executor_matches_sc_on_clean_path() {
        use crate::nn::model::{ModelCfg, ModelParams};
        use crate::nn::quant::{Pruning, QuantConfig};
        use crate::util::Rng;

        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(8);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Arc::new(Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        ));
        let mut sc = ScBatchExecutor::new(prep.clone(), 1, 1);
        let mut bin = BinaryBatchExecutor::new(prep, 1);
        assert_eq!(sc.spec(), bin.spec());
        let x: Vec<f32> = (0..784).map(|_| rng.normal() as f32).collect();
        // Fault-free, the binary datapath computes the same network.
        assert_eq!(sc.run_batch(&x, 1).unwrap(), bin.run_batch(&x, 1).unwrap());
    }
}
