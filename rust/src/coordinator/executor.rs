//! Pluggable batch executors behind the inference pool.
//!
//! The pool in [`super::batcher`] is backend-agnostic: each worker
//! thread owns one [`BatchExecutor`], built *inside* that thread by an
//! [`ExecutorFactory`]. The factory indirection exists because PJRT
//! handles are not `Send` (the `xla` crate wraps raw pointers in
//! `Rc`): a [`Trainer`] can never cross a thread boundary, but a
//! closure that builds one can. It is also the seam every later
//! multi-backend PR plugs into — a worker neither knows nor cares
//! whether its batches run on PJRT, a future GPU backend, or the
//! in-process synthetic model used by tests and benches.

use std::time::Duration;

use crate::runtime::{trainer::Knobs, Runtime, Trainer};
use crate::Result;

/// Fixed shape contract of one executor: every worker in a pool must
/// report the same spec (checked at startup).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ExecutorSpec {
    /// Flattened image length (C·H·W floats per request).
    pub image_len: usize,
    /// Fixed batch capacity of one execution (AOT-compiled batch).
    pub batch: usize,
    /// Logits per request.
    pub classes: usize,
}

/// A batch-at-a-time inference engine owned by a single pool worker.
pub trait BatchExecutor {
    /// The executor's shape contract.
    fn spec(&self) -> ExecutorSpec;

    /// Run one padded batch of `spec().batch * spec().image_len`
    /// floats, returning `spec().batch * spec().classes` logits.
    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>>;
}

/// Builds a worker's executor inside the worker thread. The argument
/// is the worker index (0-based), for logging or device placement.
pub type ExecutorFactory = Box<dyn Fn(usize) -> Result<Box<dyn BatchExecutor>> + Send + Sync>;

/// PJRT-backed executor: the serving path (integer codes through the
/// Pallas kernel) of an AOT-exported model. Each instance owns its own
/// [`Runtime`] and [`Trainer`] because PJRT handles are not `Send`.
pub struct PjrtExecutor {
    trainer: Trainer,
    knobs: Knobs,
    spec: ExecutorSpec,
}

impl PjrtExecutor {
    /// Build a runtime, load the model's executables, and optionally
    /// install trained parameters.
    pub fn new(
        artifacts: &str,
        model: &str,
        params: Option<&[Vec<f32>]>,
        knobs: Knobs,
    ) -> Result<Self> {
        let rt = Runtime::new(artifacts)?;
        let mut trainer = Trainer::new(&rt, model)?;
        if let Some(p) = params {
            trainer.set_params(p.to_vec())?;
        }
        let (c, h, w) = trainer.meta().input;
        let spec = ExecutorSpec {
            image_len: c * h * w,
            batch: trainer.meta().batch,
            classes: trainer.meta().classes,
        };
        Ok(Self { trainer, knobs, spec })
    }
}

impl BatchExecutor for PjrtExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.spec
    }

    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        self.trainer.logits(x, self.knobs, true)
    }
}

/// Deterministic in-process model for tests and benchmarks: logits are
/// a pure function of each image (identical results for any worker
/// count), and each executed batch costs a fixed simulated latency,
/// like a busy fixed-batch accelerator. Because the cost is latency
/// (not host CPU), a worker-scaling sweep shows real scaling on any
/// host.
pub struct SyntheticExecutor {
    spec: ExecutorSpec,
    latency: Duration,
}

impl SyntheticExecutor {
    /// New executor with zero simulated latency.
    pub fn new(spec: ExecutorSpec) -> Self {
        Self { spec, latency: Duration::ZERO }
    }

    /// Set the simulated per-batch latency.
    pub fn with_latency(mut self, latency: Duration) -> Self {
        self.latency = latency;
        self
    }

    /// Convenience factory for [`super::Coordinator::start_with`].
    pub fn factory(spec: ExecutorSpec, latency: Duration) -> ExecutorFactory {
        Box::new(move |_worker| Ok(Box::new(SyntheticExecutor::new(spec).with_latency(latency))))
    }

    /// The demo-grade fallback the CLI and `examples/serve.rs` share
    /// when AOT artifacts are absent: batch 16, 2 ms simulated batch
    /// latency (a plausible small-accelerator operating point).
    pub fn demo_factory(image_len: usize, classes: usize) -> ExecutorFactory {
        Self::factory(
            ExecutorSpec { image_len, batch: 16, classes },
            Duration::from_millis(2),
        )
    }

    /// The reference logits for one image — exposed so tests can check
    /// pool responses against ground truth.
    pub fn reference_logits(&self, image: &[f32]) -> Vec<f32> {
        debug_assert_eq!(image.len(), self.spec.image_len);
        let mut out = Vec::with_capacity(self.spec.classes);
        for c in 0..self.spec.classes {
            // Class-dependent strided projection: cheap, deterministic,
            // and discriminative enough that argmax varies with input.
            let stride = c + 1;
            let mut acc = 0.0f32;
            let mut i = c % self.spec.image_len.max(1);
            while i < image.len() {
                acc += image[i] * (1.0 + (c as f32) * 0.125);
                i += stride;
            }
            out.push(acc / (image.len() as f32 / stride as f32).max(1.0));
        }
        out
    }
}

impl BatchExecutor for SyntheticExecutor {
    fn spec(&self) -> ExecutorSpec {
        self.spec
    }

    fn run_batch(&self, x: &[f32]) -> Result<Vec<f32>> {
        anyhow::ensure!(
            x.len() == self.spec.batch * self.spec.image_len,
            "batch input length {} != {}",
            x.len(),
            self.spec.batch * self.spec.image_len
        );
        if !self.latency.is_zero() {
            std::thread::sleep(self.latency);
        }
        let mut out = Vec::with_capacity(self.spec.batch * self.spec.classes);
        for b in 0..self.spec.batch {
            let image = &x[b * self.spec.image_len..(b + 1) * self.spec.image_len];
            out.extend(self.reference_logits(image));
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_is_deterministic_and_shape_correct() {
        let spec = ExecutorSpec { image_len: 8, batch: 3, classes: 4 };
        let exec = SyntheticExecutor::new(spec);
        let x: Vec<f32> = (0..24).map(|i| i as f32 * 0.1).collect();
        let a = exec.run_batch(&x).unwrap();
        let b = exec.run_batch(&x).unwrap();
        assert_eq!(a, b);
        assert_eq!(a.len(), 12);
        // Row 1 equals the reference logits of image 1.
        assert_eq!(&a[4..8], exec.reference_logits(&x[8..16]).as_slice());
        // Input length is validated.
        assert!(exec.run_batch(&x[..23]).is_err());
    }

    #[test]
    fn synthetic_logits_vary_by_input() {
        let spec = ExecutorSpec { image_len: 16, batch: 1, classes: 10 };
        let exec = SyntheticExecutor::new(spec);
        let a = exec.reference_logits(&[0.5; 16]);
        let mut img = vec![0.5; 16];
        img[3] = -2.0;
        let b = exec.reference_logits(&img);
        assert_ne!(a, b);
    }
}
