//! Deterministic synthetic datasets (DESIGN.md §Substitutions).
//!
//! The paper evaluates on MNIST (§II, Fig 5) and CIFAR-10/100 (§III);
//! neither is available in this environment, so we generate procedural
//! stand-ins that exercise the same code paths:
//!
//! * [`SynthDigits`] — 28×28 grayscale, 10 classes: a 7×5 bitmap font
//!   rendered with random shift, scale jitter and Gaussian noise. A
//!   small CNN separates it well, like MNIST.
//! * [`SynthCifar`] — 32×32×3, `k` classes: class-conditional oriented
//!   gratings + colored blobs + noise; harder than SynthDigits, and its
//!   accuracy ordering under quantization ablations mirrors CIFAR's.
//!
//! Both are deterministic: `(split, index)` fully determines a sample.

use crate::nn::tensor::Tensor;
use crate::util::Rng;

/// A labelled dataset generator.
pub trait Dataset: Send + Sync {
    /// Image shape (C, H, W).
    fn shape(&self) -> (usize, usize, usize);
    /// Number of classes.
    fn num_classes(&self) -> usize;
    /// Deterministically generate sample `idx` of the split.
    fn sample(&self, split: Split, idx: usize) -> (Tensor, usize);

    /// Generate a batch.
    fn batch(&self, split: Split, start: usize, n: usize) -> (Vec<Tensor>, Vec<usize>) {
        let mut xs = Vec::with_capacity(n);
        let mut ys = Vec::with_capacity(n);
        for i in 0..n {
            let (x, y) = self.sample(split, start + i);
            xs.push(x);
            ys.push(y);
        }
        (xs, ys)
    }

    /// Flattened batch (NCHW) for the PJRT training path.
    fn batch_flat(&self, split: Split, start: usize, n: usize) -> (Vec<f32>, Vec<i32>) {
        let (xs, ys) = self.batch(split, start, n);
        let mut data = Vec::with_capacity(n * xs[0].len());
        for x in &xs {
            data.extend_from_slice(x.data());
        }
        (data, ys.into_iter().map(|y| y as i32).collect())
    }
}

/// Train/test split.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    /// Training stream.
    Train,
    /// Held-out test stream.
    Test,
}

impl Split {
    fn seed_tag(self) -> u64 {
        match self {
            Split::Train => 0x7261_696e,
            Split::Test => 0x7465_7374,
        }
    }
}

/// 7×5 bitmap digit font (classic seven-segment-ish glyphs).
const DIGIT_FONT: [[u8; 7]; 10] = [
    [0b01110, 0b10001, 0b10011, 0b10101, 0b11001, 0b10001, 0b01110], // 0
    [0b00100, 0b01100, 0b00100, 0b00100, 0b00100, 0b00100, 0b01110], // 1
    [0b01110, 0b10001, 0b00001, 0b00010, 0b00100, 0b01000, 0b11111], // 2
    [0b11111, 0b00010, 0b00100, 0b00010, 0b00001, 0b10001, 0b01110], // 3
    [0b00010, 0b00110, 0b01010, 0b10010, 0b11111, 0b00010, 0b00010], // 4
    [0b11111, 0b10000, 0b11110, 0b00001, 0b00001, 0b10001, 0b01110], // 5
    [0b00110, 0b01000, 0b10000, 0b11110, 0b10001, 0b10001, 0b01110], // 6
    [0b11111, 0b00001, 0b00010, 0b00100, 0b01000, 0b01000, 0b01000], // 7
    [0b01110, 0b10001, 0b10001, 0b01110, 0b10001, 0b10001, 0b01110], // 8
    [0b01110, 0b10001, 0b10001, 0b01111, 0b00001, 0b00010, 0b01100], // 9
];

/// MNIST substitute.
#[derive(Clone, Copy, Debug, Default)]
pub struct SynthDigits {
    /// Additive noise std.
    pub noise: f32,
}

impl SynthDigits {
    /// Standard configuration.
    pub fn new() -> Self {
        Self { noise: 0.15 }
    }
}

impl Dataset for SynthDigits {
    fn shape(&self) -> (usize, usize, usize) {
        (1, 28, 28)
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn sample(&self, split: Split, idx: usize) -> (Tensor, usize) {
        let mut rng = Rng::new(split.seed_tag().wrapping_mul(0x9E37).wrapping_add(idx as u64));
        let label = rng.gen_index(10);
        let glyph = &DIGIT_FONT[label];
        let mut img = Tensor::zeros(&[1, 28, 28]);
        // Scale the 7x5 glyph up 3x and place with jitter.
        let scale = 3;
        let oy = 3 + rng.gen_range_i64(-2, 2) as isize;
        let ox = 6 + rng.gen_range_i64(-3, 3) as isize;
        let intensity = 0.7 + 0.3 * rng.f64() as f32;
        for (gy, row) in glyph.iter().enumerate() {
            for gx in 0..5 {
                if row >> (4 - gx) & 1 == 1 {
                    for dy in 0..scale {
                        for dx in 0..scale {
                            let y = oy + (gy * scale + dy) as isize;
                            let x = ox + (gx * scale + dx) as isize;
                            if (0..28).contains(&y) && (0..28).contains(&x) {
                                img.set3(0, y as usize, x as usize, intensity);
                            }
                        }
                    }
                }
            }
        }
        for v in img.data_mut() {
            *v += self.noise * rng.normal() as f32;
        }
        // Center roughly to zero mean (the chip's input encoder expects
        // a symmetric range).
        let mean: f32 = img.data().iter().sum::<f32>() / img.len() as f32;
        let img = img.map(|v| v - mean);
        (img, label)
    }
}

/// CIFAR substitute: oriented gratings + class-colored blob + noise.
#[derive(Clone, Copy, Debug)]
pub struct SynthCifar {
    /// Number of classes (10 for CIFAR10-like, 20 for CIFAR100-coarse-like).
    pub classes: usize,
    /// Additive noise std.
    pub noise: f32,
    /// Grating amplitude (signal strength).
    pub amp: f32,
    /// Amplitude of a random distractor grating (class-independent).
    pub distractor: f32,
}

impl SynthCifar {
    /// 10-class standard configuration.
    pub fn new(classes: usize) -> Self {
        Self { classes, noise: 0.25, amp: 0.6, distractor: 0.0 }
    }

    /// Harder variant used by the accuracy ablations: weaker signal,
    /// stronger noise, and a class-independent distractor grating, so
    /// low-precision activations measurably hurt (the Table III / Fig 8
    /// regime).
    pub fn hard(classes: usize) -> Self {
        Self { classes, noise: 0.45, amp: 0.45, distractor: 0.25 }
    }
}

impl Dataset for SynthCifar {
    fn shape(&self) -> (usize, usize, usize) {
        (3, 32, 32)
    }

    fn num_classes(&self) -> usize {
        self.classes
    }

    fn sample(&self, split: Split, idx: usize) -> (Tensor, usize) {
        let mut rng =
            Rng::new(split.seed_tag().wrapping_mul(0xC1FA).wrapping_add(idx as u64));
        let label = rng.gen_index(self.classes);
        let mut img = Tensor::zeros(&[3, 32, 32]);
        // Class-dependent grating orientation + frequency.
        let theta = std::f64::consts::PI * label as f64 / self.classes as f64
            + 0.08 * rng.normal();
        let freq = 0.35 + 0.1 * ((label % 3) as f64) + 0.03 * rng.normal();
        let phase = rng.f64() * std::f64::consts::TAU;
        let (s, c) = theta.sin_cos();
        // Class-independent distractor grating (forces the model to be
        // orientation-selective rather than energy-detecting).
        let dtheta = std::f64::consts::PI * rng.f64();
        let (ds, dc) = dtheta.sin_cos();
        let dfreq = 0.3 + 0.25 * rng.f64();
        let dphase = rng.f64() * std::f64::consts::TAU;
        // Class-dependent color balance.
        let col = [
            0.5 + 0.5 * ((label * 37) % 10) as f64 / 10.0,
            0.5 + 0.5 * ((label * 53 + 3) % 10) as f64 / 10.0,
            0.5 + 0.5 * ((label * 71 + 7) % 10) as f64 / 10.0,
        ];
        // Blob position jitters per sample but its size is class-tied.
        let bx = 8.0 + 16.0 * rng.f64();
        let by = 8.0 + 16.0 * rng.f64();
        let br = 3.0 + (label % 5) as f64;
        for y in 0..32 {
            for x in 0..32 {
                let u = x as f64 * c + y as f64 * s;
                let g = (freq * u + phase).sin();
                let du = x as f64 * dc + y as f64 * ds;
                let dg = (dfreq * du + dphase).sin();
                let d2 = ((x as f64 - bx).powi(2) + (y as f64 - by).powi(2)) / (br * br);
                let blob = (-d2).exp();
                for ch in 0..3 {
                    let v = self.amp as f64 * g * col[ch]
                        + self.distractor as f64 * dg
                        + 0.8 * blob * (col[(ch + 1) % 3] - 0.5)
                        + self.noise as f64 * rng.normal();
                    img.set3(ch, y, x, v as f32);
                }
            }
        }
        (img, label)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn digits_deterministic() {
        let d = SynthDigits::new();
        let (a, la) = d.sample(Split::Train, 42);
        let (b, lb) = d.sample(Split::Train, 42);
        assert_eq!(la, lb);
        assert_eq!(a.data(), b.data());
        // Different index -> different image.
        let (c, _) = d.sample(Split::Train, 43);
        assert_ne!(a.data(), c.data());
    }

    #[test]
    fn digits_splits_differ() {
        let d = SynthDigits::new();
        let (a, _) = d.sample(Split::Train, 7);
        let (b, _) = d.sample(Split::Test, 7);
        assert_ne!(a.data(), b.data());
    }

    #[test]
    fn digits_glyph_visible_over_noise() {
        let d = SynthDigits::new();
        let (img, _) = d.sample(Split::Train, 1);
        // Foreground pixels should exceed the noise floor.
        assert!(img.max_abs() > 0.4);
    }

    #[test]
    fn cifar_shapes_and_classes() {
        let d = SynthCifar::new(10);
        assert_eq!(d.shape(), (3, 32, 32));
        let (x, y) = d.sample(Split::Test, 5);
        assert_eq!(x.shape(), &[3, 32, 32]);
        assert!(y < 10);
        let d20 = SynthCifar::new(20);
        let mut seen = vec![false; 20];
        for i in 0..400 {
            let (_, y) = d20.sample(Split::Train, i);
            seen[y] = true;
        }
        assert!(seen.iter().filter(|&&s| s).count() >= 18, "labels should cover classes");
    }

    #[test]
    fn batch_flat_layout() {
        let d = SynthDigits::new();
        let (data, labels) = d.batch_flat(Split::Train, 0, 3);
        assert_eq!(data.len(), 3 * 784);
        assert_eq!(labels.len(), 3);
    }

    #[test]
    fn classes_are_separable_by_simple_statistic() {
        // Nearest-class-mean on raw pixels should beat chance by a wide
        // margin — sanity that the task is learnable.
        let d = SynthDigits::new();
        let k = 10;
        let (c, h, w) = d.shape();
        let dim = c * h * w;
        let mut means = vec![vec![0.0f32; dim]; k];
        let mut counts = vec![0usize; k];
        for i in 0..400 {
            let (x, y) = d.sample(Split::Train, i);
            for (m, v) in means[y].iter_mut().zip(x.data()) {
                *m += v;
            }
            counts[y] += 1;
        }
        for (m, &n) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= n.max(1) as f32;
            }
        }
        let mut hits = 0;
        let total = 200;
        for i in 0..total {
            let (x, y) = d.sample(Split::Test, i);
            let best = (0..k)
                .min_by(|&a, &b| {
                    let da: f32 = means[a]
                        .iter()
                        .zip(x.data())
                        .map(|(m, v)| (m - v).powi(2))
                        .sum();
                    let db: f32 = means[b]
                        .iter()
                        .zip(x.data())
                        .map(|(m, v)| (m - v).powi(2))
                        .sum();
                    da.total_cmp(&db)
                })
                .unwrap();
            if best == y {
                hits += 1;
            }
        }
        let acc = hits as f64 / total as f64;
        assert!(acc > 0.5, "nearest-mean accuracy {acc} too low — task not learnable");
    }
}
