//! # scnn — End-to-End Stochastic-Computing NN Acceleration
//!
//! Reproduction of *"Efficient yet Accurate End-to-End SC Accelerator
//! Design"* (Li, Hu, et al., 2024) as a three-layer Rust + JAX + Pallas
//! stack.
//!
//! The crate contains, at Layer 3 (this Rust library):
//!
//! * [`coding`] — deterministic **thermometer coding** (paper Table II),
//!   2-bit ternary coding, and the stochastic (LFSR/SNG bipolar) coding
//!   substrate used by the FSM baselines.
//! * [`gates`] — gate primitives and netlists with a 28-nm-calibrated
//!   area/delay/energy library.
//! * [`circuits`] — the paper's circuit contributions: the 5-gate ternary
//!   SC multiplier (Fig 3a), the exact bitonic sorting network non-linear
//!   adder (Fig 3b), the selective-interconnect activation synthesizer
//!   (ReLU / tanh / BN-fused ReLU, Fig 7), the residual re-scaling block
//!   (§III.C), the approximate **spatial** BSN (§IV.B, Fig 10b) and the
//!   **spatial-temporal** BSN (Fig 12), plus FSM-based stochastic
//!   activation baselines (Fig 1).
//! * [`cost`] — hardware cost roll-up (area, delay, ADP, energy) and the
//!   voltage/frequency power model behind Fig 4.
//! * [`nn`] — the NN substrate: tensors, conv/BN/linear layers, ternary /
//!   thermometer quantization, a **bit-exact SC executor** that runs
//!   quantized networks through the circuit simulators, a binary
//!   integer baseline executor, the packed **ternary/i8 GEMM core**
//!   every accumulation site shares ([`nn::gemm`]), and the batched,
//!   optionally multi-threaded serving engine ([`nn::ScEngine`]).
//! * [`fault`] — the datapath integrity layer: per-stage fault
//!   injection for the SC and binary datapaths, count-domain integrity
//!   guards with scalar re-execution (`scnn serve --guard`), and the
//!   parallel BER-sweep harness (Fig 5, `scnn exp ber`).
//! * [`data`] — deterministic synthetic datasets standing in for MNIST /
//!   CIFAR (see DESIGN.md §Substitutions).
//! * [`accel`] — the accelerator model: maps network layers onto BSN
//!   configurations, searches the approximate-BSN design space, and rolls
//!   up per-layer ADP/energy (Fig 13, Table V).
//! * [`runtime`] — PJRT client wrapper that loads the AOT-compiled JAX
//!   artifacts (HLO text) and executes them from Rust.
//! * [`coordinator`] — the serving layer: multi-worker inference pool
//!   (sharded request queue, adaptive dynamic batcher with
//!   backpressure/load-shedding, pluggable batch executors), a
//!   multi-model registry with per-tenant admission control, latency
//!   histograms with Prometheus exposition, and a std-only TCP
//!   front-end speaking a length-prefixed binary protocol.
//! * [`exp`] — one runner per paper table/figure (the benchmark harness).
//!
//! Layers 1–2 (Pallas kernel and the SC-friendly JAX model with
//! high-precision residual fusion) live in `python/compile/` and are run
//! once at build time (`make artifacts`); Python is never on the request
//! path.

pub mod accel;
pub mod coding;
// The serving hot path must not grow new panic sites: every lock is
// poison-recovering and every fallible step returns a typed error
// (test modules opt back in locally).
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod coordinator;
pub mod circuits;
pub mod cost;
pub mod data;
// The experiment runners feed CI result artifacts and the fault layer
// sits on the serving path (`--guard`, engine injection): same
// no-new-panic-sites bar as the coordinator.
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod exp;
#[deny(clippy::unwrap_used, clippy::expect_used)]
pub mod fault;
pub mod gates;
pub mod nn;
pub mod runtime;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
