//! PJRT runtime: load AOT-compiled JAX artifacts (HLO text) and execute
//! them from Rust. Python never runs on this path.
//!
//! Pattern from /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO *text* is the interchange format (the crate's xla_extension
//! 0.5.1 rejects jax≥0.5's 64-bit-id serialized protos).

pub mod meta;
pub mod trainer;

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Mutex};

use crate::Result;
use anyhow::{bail, Context};

pub use meta::ModelMeta;
pub use trainer::Trainer;

/// A PJRT runtime holding the CPU client and a compiled-executable
/// cache keyed by artifact path.
pub struct Runtime {
    client: xla::PjRtClient,
    cache: Mutex<HashMap<PathBuf, Arc<xla::PjRtLoadedExecutable>>>,
    artifacts_dir: PathBuf,
}

impl Runtime {
    /// Create a CPU-backed runtime rooted at an artifacts directory.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Self {
            client,
            cache: Mutex::new(HashMap::new()),
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    /// Platform string (diagnostics).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifacts directory.
    pub fn artifacts_dir(&self) -> &Path {
        &self.artifacts_dir
    }

    /// Load (or fetch from cache) an executable from an HLO text file
    /// under the artifacts directory.
    pub fn load(&self, file_name: &str) -> Result<Arc<xla::PjRtLoadedExecutable>> {
        let path = self.artifacts_dir.join(file_name);
        if let Some(exe) = self.cache.lock().unwrap().get(&path) {
            return Ok(exe.clone());
        }
        if !path.exists() {
            bail!(
                "artifact {} not found — run `make artifacts` first",
                path.display()
            );
        }
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Arc::new(
            self.client
                .compile(&comp)
                .with_context(|| format!("compiling {}", path.display()))?,
        );
        self.cache.lock().unwrap().insert(path, exe.clone());
        Ok(exe)
    }

    /// Load the metadata file of a model.
    pub fn load_meta(&self, model: &str) -> Result<ModelMeta> {
        meta::ModelMeta::from_file(self.artifacts_dir.join(format!("{model}_meta.txt")))
    }

    /// Execute an executable whose module returns a tuple (jax lowering
    /// uses `return_tuple=True`): returns the unpacked output literals.
    pub fn run(
        exe: &xla::PjRtLoadedExecutable,
        args: &[xla::Literal],
    ) -> Result<Vec<xla::Literal>> {
        let out = exe.execute::<xla::Literal>(args).context("PJRT execute")?;
        let lit = out[0][0].to_literal_sync().context("device -> host")?;
        Ok(lit.to_tuple().context("unpacking output tuple")?)
    }
}

/// True when the AOT artifacts of `model` are present under `dir`
/// (cheap probe used by the CLI/benches to pick a serving backend
/// without constructing a client).
pub fn artifacts_ready(dir: impl AsRef<Path>, model: &str) -> bool {
    dir.as_ref().join(format!("{model}_meta.txt")).exists()
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "shape {:?} != len {}", dims, data.len());
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// Build an i32 literal of the given shape.
pub fn literal_i32(data: &[i32], dims: &[usize]) -> Result<xla::Literal> {
    let n: usize = dims.iter().product::<usize>().max(1);
    anyhow::ensure!(n == data.len(), "shape {:?} != len {}", dims, data.len());
    if dims.is_empty() {
        return Ok(xla::Literal::scalar(data[0]));
    }
    let d: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&d)?)
}

/// Scalar f32 literal.
pub fn scalar_f32(v: f32) -> xla::Literal {
    xla::Literal::scalar(v)
}
