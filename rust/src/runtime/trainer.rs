//! Rust-driven training and evaluation over the exported HLOs.
//!
//! The end-to-end loop the paper's experiments need (Table III, Fig 2,
//! Fig 8, Table IV): Rust generates synthetic batches, executes the
//! exported `train_step` via PJRT, tracks parameters/momenta as host
//! vectors, and freezes the trained parameters into [`ModelParams`] for
//! the bit-exact SC simulator. One HLO serves every ablation because
//! the quantization knobs are runtime scalars.

use std::sync::Arc;

use crate::data::{Dataset, Split};
use crate::nn::model::ModelParams;
use crate::nn::tensor::Tensor;
use crate::Result;
use anyhow::{ensure, Context};

use super::{literal_f32, literal_i32, scalar_f32, ModelMeta, Runtime};

/// Runtime quantization knobs (mirror of python `QuantKnobs`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Knobs {
    /// Activation clip half-range (`BSL/2`).
    pub act_half: f32,
    /// 1.0 = float activations (ablations).
    pub act_fp: f32,
    /// 1.0 = float weights.
    pub w_fp: f32,
    /// Residual clip half-range.
    pub res_half: f32,
    /// 1.0 = float residual.
    pub res_fp: f32,
    /// 0.0 disables residual adds entirely.
    pub res_on: f32,
    /// N:M pruning: weights kept per group (0 = pruning off). Freeze-
    /// time only — the exported HLO takes the 6 quantization scalars
    /// and never sees pruning; see [`Knobs::flat`].
    pub prune_n: f32,
    /// N:M pruning: group size along the reduction axis (0 = off).
    pub prune_m: f32,
    /// Block pruning: block length along the reduction axis (0 = off).
    pub prune_block: f32,
}

impl Knobs {
    /// Fully-quantized W2-A{bsl}-R16 configuration.
    pub fn quantized(act_bsl: usize) -> Self {
        Self {
            act_half: act_bsl as f32 / 2.0,
            act_fp: 0.0,
            w_fp: 0.0,
            res_half: 8.0,
            res_fp: 0.0,
            res_on: 1.0,
            prune_n: 0.0,
            prune_m: 0.0,
            prune_block: 0.0,
        }
    }

    /// Float baseline.
    pub fn float() -> Self {
        Self {
            act_half: 1.0,
            act_fp: 1.0,
            w_fp: 1.0,
            res_half: 8.0,
            res_fp: 1.0,
            res_on: 1.0,
            prune_n: 0.0,
            prune_m: 0.0,
            prune_block: 0.0,
        }
    }

    /// Freeze-time N:M pruning: keep the `n` largest-magnitude weights
    /// in every aligned group of `m` along the reduction axis.
    pub fn with_pruning(mut self, n: usize, m: usize) -> Self {
        self.prune_n = n as f32;
        self.prune_m = m as f32;
        self.prune_block = 0.0;
        self
    }

    /// Freeze-time block pruning: zero aligned blocks of `size`
    /// consecutive weights whose mean magnitude rounds to zero.
    pub fn with_block_pruning(mut self, size: usize) -> Self {
        self.prune_n = 0.0;
        self.prune_m = 0.0;
        self.prune_block = size as f32;
        self
    }

    /// Residual BSL override (paper Fig 8: residual precision sweep).
    pub fn with_res_bsl(mut self, bsl: Option<usize>) -> Self {
        match bsl {
            Some(b) => {
                self.res_half = b as f32 / 2.0;
                self.res_fp = 0.0;
                self.res_on = 1.0;
            }
            None => self.res_on = 0.0,
        }
        self
    }

    /// Float residual (Fig 8's "floating point residual" point).
    pub fn with_float_res(mut self) -> Self {
        self.res_fp = 1.0;
        self.res_on = 1.0;
        self
    }

    /// As the 6 exported scalars.
    pub fn flat(&self) -> [f32; 6] {
        [self.act_half, self.act_fp, self.w_fp, self.res_half, self.res_fp, self.res_on]
    }
}

/// A PJRT-backed trainer for one exported model.
pub struct Trainer {
    meta: ModelMeta,
    train_exe: Arc<xla::PjRtLoadedExecutable>,
    eval_exe: Arc<xla::PjRtLoadedExecutable>,
    evalq_exe: Arc<xla::PjRtLoadedExecutable>,
    calib_exe: Arc<xla::PjRtLoadedExecutable>,
    params: Vec<Vec<f32>>,
    moms: Vec<Vec<f32>>,
}

impl Trainer {
    /// Load the three executables + metadata for `model` and start from
    /// the exported python init.
    pub fn new(rt: &Runtime, model: &str) -> Result<Self> {
        let meta = rt.load_meta(model)?;
        let train_exe = rt.load(&format!("{model}_train.hlo.txt"))?;
        let eval_exe = rt.load(&format!("{model}_eval.hlo.txt"))?;
        let evalq_exe = rt.load(&format!("{model}_evalq.hlo.txt"))?;
        let calib_exe = rt.load(&format!("{model}_calib.hlo.txt"))?;
        let params = meta.init.clone();
        let moms = meta.params.iter().map(|p| vec![0.0; p.len()]).collect();
        Ok(Self { meta, train_exe, eval_exe, evalq_exe, calib_exe, params, moms })
    }

    /// Model metadata.
    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Current parameters (flat order).
    pub fn params(&self) -> &[Vec<f32>] {
        &self.params
    }

    /// Install trained parameters (flat order; lengths must match).
    pub fn set_params(&mut self, params: Vec<Vec<f32>>) -> Result<()> {
        ensure!(params.len() == self.meta.params.len(), "param count mismatch");
        for (p, m) in params.iter().zip(&self.meta.params) {
            ensure!(p.len() == m.len(), "param {} length mismatch", m.name);
        }
        self.params = params;
        for m in &mut self.moms {
            m.iter_mut().for_each(|v| *v = 0.0);
        }
        Ok(())
    }

    /// Reset parameters/momenta to the exported init.
    pub fn reset(&mut self) {
        self.params = self.meta.init.clone();
        for m in &mut self.moms {
            m.iter_mut().for_each(|v| *v = 0.0);
        }
    }

    /// One SGD+momentum step on a batch; returns the loss.
    pub fn step(&mut self, x: &[f32], y: &[i32], lr: f32, knobs: Knobs) -> Result<f32> {
        let (c, h, w) = self.meta.input;
        let b = self.meta.batch;
        ensure!(x.len() == b * c * h * w, "x batch shape mismatch");
        ensure!(y.len() == b, "y batch shape mismatch");
        let n = self.meta.params.len();
        let mut args: Vec<xla::Literal> = Vec::with_capacity(2 * n + 9);
        for (p, m) in self.params.iter().zip(&self.meta.params) {
            args.push(literal_f32(p, &m.dims)?);
        }
        for (p, m) in self.moms.iter().zip(&self.meta.params) {
            args.push(literal_f32(p, &m.dims)?);
        }
        args.push(literal_f32(x, &[b, c, h, w])?);
        args.push(literal_i32(y, &[b])?);
        args.push(scalar_f32(lr));
        for s in knobs.flat() {
            args.push(scalar_f32(s));
        }
        let out = Runtime::run(&self.train_exe, &args)?;
        ensure!(out.len() == 2 * n + 1, "train outputs {} != {}", out.len(), 2 * n + 1);
        for i in 0..n {
            self.params[i] = out[i].to_vec::<f32>().context("param out")?;
            self.moms[i] = out[n + i].to_vec::<f32>().context("mom out")?;
        }
        let loss = out[2 * n]
            .get_first_element::<f32>()
            .context("loss out")?;
        Ok(loss)
    }

    /// Evaluate logits for a full batch. `serving = true` uses the
    /// integer-code Pallas path; `false` uses the fake-quant path
    /// (required for FP ablation rows).
    pub fn logits(&self, x: &[f32], knobs: Knobs, serving: bool) -> Result<Vec<f32>> {
        let (c, h, w) = self.meta.input;
        let b = self.meta.batch;
        ensure!(x.len() == b * c * h * w, "x batch shape mismatch");
        let mut args: Vec<xla::Literal> = Vec::new();
        for (p, m) in self.params.iter().zip(&self.meta.params) {
            args.push(literal_f32(p, &m.dims)?);
        }
        args.push(literal_f32(x, &[b, c, h, w])?);
        for s in knobs.flat() {
            args.push(scalar_f32(s));
        }
        let exe = if serving { &self.eval_exe } else { &self.evalq_exe };
        let out = Runtime::run(exe, &args)?;
        ensure!(out.len() == 1, "eval outputs {}", out.len());
        Ok(out[0].to_vec::<f32>()?)
    }

    /// Train for `steps` mini-batches drawn from the dataset; returns
    /// the loss curve.
    pub fn train(
        &mut self,
        data: &dyn Dataset,
        steps: usize,
        lr: f32,
        knobs: Knobs,
        mut log: impl FnMut(usize, f32),
    ) -> Result<Vec<f32>> {
        let b = self.meta.batch;
        let mut losses = Vec::with_capacity(steps);
        for s in 0..steps {
            let (x, y) = data.batch_flat(Split::Train, s * b, b);
            // Cosine decay keeps late steps stable for QAT.
            let prog = s as f32 / steps.max(1) as f32;
            let lr_s = lr * 0.5 * (1.0 + (std::f32::consts::PI * prog).cos());
            let loss = self.step(&x, &y, lr_s, knobs)?;
            losses.push(loss);
            log(s, loss);
        }
        Ok(losses)
    }

    /// Test accuracy over `n` examples (rounded up to whole batches).
    pub fn accuracy(
        &self,
        data: &dyn Dataset,
        n: usize,
        knobs: Knobs,
        serving: bool,
    ) -> Result<f64> {
        let b = self.meta.batch;
        let k = self.meta.classes;
        let batches = n.div_ceil(b);
        let mut hits = 0usize;
        let mut total = 0usize;
        for bi in 0..batches {
            let (x, y) = data.batch_flat(Split::Test, bi * b, b);
            let logits = self.logits(&x, knobs, serving)?;
            for (i, &label) in y.iter().enumerate() {
                let row = &logits[i * k..(i + 1) * k];
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(j, _)| j)
                    .unwrap();
                if pred == label as usize {
                    hits += 1;
                }
                total += 1;
            }
        }
        Ok(hits as f64 / total.max(1) as f64)
    }

    /// Activation-statistics calibration pass: runs the float forward
    /// on one batch, then re-seats every quantization scale so the
    /// quantizer's range covers the live activation distribution —
    /// the standard warm-start between float pre-training and QAT
    /// fine-tuning. `alpha = K · mean|y| / half` with `K = 2.5`.
    pub fn calibrate(&mut self, data: &dyn Dataset, knobs: Knobs) -> Result<()> {
        const K: f32 = 2.5;
        let (c, h, w) = self.meta.input;
        let b = self.meta.batch;
        let (x, _) = data.batch_flat(Split::Train, 0, b);
        let mut args: Vec<xla::Literal> = Vec::new();
        for (p, m) in self.params.iter().zip(&self.meta.params) {
            args.push(literal_f32(p, &m.dims)?);
        }
        args.push(literal_f32(&x, &[b, c, h, w])?);
        let out = Runtime::run(&self.calib_exe, &args)?;
        ensure!(out.len() == 1, "calib outputs {}", out.len());
        let stats = out[0].to_vec::<f32>()?;
        // stats[0] = mean|input|; stats[1 + i] = mean|y_i| per conv.
        let meta = self.meta.clone();
        let mut set = |name: &str, value: f32| {
            if let Some(i) = meta.index_of(name) {
                self.params[i] = vec![value.max(1e-6)];
            }
        };
        set("input.alpha", K * stats[0] / knobs.act_half);
        for (ci, s) in stats[1..].iter().enumerate() {
            set(&format!("conv{ci}.alpha_out"), K * s / knobs.act_half);
            set(&format!("conv{ci}.alpha_res"), K * s / knobs.res_half);
        }
        Ok(())
    }

    /// Standard two-phase QAT: float warm-up, scale calibration, then
    /// quantized fine-tuning with the target knobs. Returns the
    /// concatenated loss curve. When the knobs are already float this
    /// is a single full-length float run.
    pub fn train_qat(
        &mut self,
        data: &dyn Dataset,
        steps_fp: usize,
        steps_q: usize,
        lr: f32,
        knobs: Knobs,
        mut log: impl FnMut(usize, f32),
    ) -> Result<Vec<f32>> {
        let is_float = knobs.act_fp >= 0.5 && knobs.w_fp >= 0.5;
        if is_float {
            return self.train(data, steps_fp + steps_q, lr, knobs, log);
        }
        let mut fp = Knobs::float();
        fp.res_on = knobs.res_on;
        let mut losses = self.train(data, steps_fp, lr, fp, |s, l| log(s, l))?;
        self.calibrate(data, knobs)?;
        let tail = self.train(data, steps_q, lr * 0.5, knobs, |s, l| log(steps_fp + s, l))?;
        losses.extend(tail);
        Ok(losses)
    }

    /// Freeze the current parameters into the Rust-side [`ModelParams`]
    /// (for the bit-exact SC executor / fault injection).
    pub fn to_model_params(&self) -> ModelParams {
        let mut mp = ModelParams::new();
        for (vals, m) in self.params.iter().zip(&self.meta.params) {
            let dims = if m.dims.is_empty() { vec![1] } else { m.dims.clone() };
            mp.insert(&m.name, Tensor::from_vec(&dims, vals.clone()));
        }
        mp
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn knobs_flat_order() {
        let k = Knobs::quantized(4);
        assert_eq!(k.flat(), [2.0, 0.0, 0.0, 8.0, 0.0, 1.0]);
        let f = Knobs::float();
        assert_eq!(f.flat()[1], 1.0);
        let no_res = Knobs::quantized(2).with_res_bsl(None);
        assert_eq!(no_res.flat()[5], 0.0);
        let r4 = Knobs::quantized(2).with_res_bsl(Some(4));
        assert_eq!(r4.flat()[3], 2.0);
    }
}
