//! Artifact metadata parser (the line-oriented format written by
//! `python/compile/aot.py`).
//!
//! ```text
//! model <name> classes <k> input <c> <h> <w> batch <b> params <n>
//! P <name> f32 <d0,d1,...>
//! INIT <name> <hex f32 LE>
//! ```

use std::path::Path;

use crate::Result;
use anyhow::{bail, Context};

/// One parameter entry.
#[derive(Clone, Debug, PartialEq)]
pub struct ParamMeta {
    /// Parameter name (e.g. `conv0.w`).
    pub name: String,
    /// Shape.
    pub dims: Vec<usize>,
}

impl ParamMeta {
    /// Element count.
    pub fn len(&self) -> usize {
        self.dims.iter().product::<usize>().max(1)
    }

    /// True when scalar-shaped.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Parsed model metadata.
#[derive(Clone, Debug)]
pub struct ModelMeta {
    /// Model name.
    pub name: String,
    /// Classes.
    pub classes: usize,
    /// Input (C, H, W).
    pub input: (usize, usize, usize),
    /// Exported batch size.
    pub batch: usize,
    /// Parameters in flat-signature order.
    pub params: Vec<ParamMeta>,
    /// Initial values (python init, same order as `params`).
    pub init: Vec<Vec<f32>>,
}

impl ModelMeta {
    /// Parse from a file.
    pub fn from_file(path: impl AsRef<Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path.as_ref())
            .with_context(|| format!("reading {}", path.as_ref().display()))?;
        Self::parse(&text)
    }

    /// Parse from text.
    pub fn parse(text: &str) -> Result<Self> {
        let mut lines = text.lines();
        let head = lines.next().context("empty meta file")?;
        let toks: Vec<&str> = head.split_whitespace().collect();
        let field = |key: &str| -> Result<usize> {
            let i = toks
                .iter()
                .position(|&t| t == key)
                .with_context(|| format!("missing {key} in header"))?;
            Ok(toks[i + 1].parse()?)
        };
        if toks.first() != Some(&"model") {
            bail!("bad meta header: {head}");
        }
        let name = toks[1].to_string();
        let classes = field("classes")?;
        let input_i = toks.iter().position(|&t| t == "input").context("input")?;
        let input = (
            toks[input_i + 1].parse()?,
            toks[input_i + 2].parse()?,
            toks[input_i + 3].parse()?,
        );
        let batch = field("batch")?;
        let n_params = field("params")?;

        let mut params = Vec::new();
        let mut init_map: Vec<(String, Vec<f32>)> = Vec::new();
        for line in lines {
            let mut it = line.split_whitespace();
            match it.next() {
                Some("P") => {
                    let name = it.next().context("P name")?.to_string();
                    let _dtype = it.next().context("P dtype")?;
                    let dims_s = it.next().unwrap_or("");
                    let dims = if dims_s.is_empty() {
                        vec![]
                    } else {
                        dims_s
                            .split(',')
                            .map(|d| d.parse::<usize>().map_err(Into::into))
                            .collect::<Result<Vec<_>>>()?
                    };
                    params.push(ParamMeta { name, dims });
                }
                Some("INIT") => {
                    let name = it.next().context("INIT name")?.to_string();
                    let hexs = it.next().context("INIT hex")?;
                    init_map.push((name, decode_hex_f32(hexs)?));
                }
                _ => {}
            }
        }
        if params.len() != n_params {
            bail!("meta declares {n_params} params, found {}", params.len());
        }
        // Order INIT blobs by the parameter order.
        let mut init = Vec::with_capacity(params.len());
        for p in &params {
            let (_, v) = init_map
                .iter()
                .find(|(n, _)| n == &p.name)
                .with_context(|| format!("missing INIT for {}", p.name))?;
            if v.len() != p.len() {
                bail!("INIT {} has {} values, expected {}", p.name, v.len(), p.len());
            }
            init.push(v.clone());
        }
        Ok(Self { name, classes, input, batch, params, init })
    }

    /// Index of a parameter by name.
    pub fn index_of(&self, name: &str) -> Option<usize> {
        self.params.iter().position(|p| p.name == name)
    }

    /// Total trainable scalars.
    pub fn total_elems(&self) -> usize {
        self.params.iter().map(|p| p.len()).sum()
    }
}

/// Decode a little-endian f32 hex blob.
pub fn decode_hex_f32(hexs: &str) -> Result<Vec<f32>> {
    anyhow::ensure!(hexs.len() % 8 == 0, "hex length {} not multiple of 8", hexs.len());
    let mut out = Vec::with_capacity(hexs.len() / 8);
    let bytes = hexs.as_bytes();
    let nib = |b: u8| -> Result<u8> {
        Ok(match b {
            b'0'..=b'9' => b - b'0',
            b'a'..=b'f' => b - b'a' + 10,
            b'A'..=b'F' => b - b'A' + 10,
            _ => bail!("bad hex char {}", b as char),
        })
    };
    for chunk in bytes.chunks(8) {
        let mut w = [0u8; 4];
        for (i, pair) in chunk.chunks(2).enumerate() {
            w[i] = (nib(pair[0])? << 4) | nib(pair[1])?;
        }
        out.push(f32::from_le_bytes(w));
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
model tiny classes 3 input 1 4 4 batch 8 params 2
P input.alpha f32 1
P fc.w f32 3,4
INIT input.alpha 0000003f
INIT fc.w 0000803f0000803f0000803f0000803f0000803f0000803f0000803f0000803f0000803f0000803f0000803f000080bf
";

    #[test]
    fn parse_sample() {
        let m = ModelMeta::parse(SAMPLE).unwrap();
        assert_eq!(m.name, "tiny");
        assert_eq!(m.classes, 3);
        assert_eq!(m.input, (1, 4, 4));
        assert_eq!(m.batch, 8);
        assert_eq!(m.params.len(), 2);
        assert_eq!(m.params[1].dims, vec![3, 4]);
        assert_eq!(m.init[0], vec![0.5]);
        assert_eq!(m.init[1][11], -1.0);
        assert_eq!(m.index_of("fc.w"), Some(1));
        assert_eq!(m.total_elems(), 13);
    }

    #[test]
    fn decode_hex_roundtrip() {
        let vals = [1.5f32, -2.25, 0.0, 1e-7];
        let hexs: String = vals
            .iter()
            .flat_map(|v| v.to_le_bytes())
            .map(|b| format!("{b:02x}"))
            .collect();
        assert_eq!(decode_hex_f32(&hexs).unwrap(), vals);
    }

    #[test]
    fn bad_header_rejected() {
        assert!(ModelMeta::parse("nonsense").is_err());
    }

    #[test]
    fn param_count_mismatch_rejected() {
        let bad = SAMPLE.replace("params 2", "params 3");
        assert!(ModelMeta::parse(&bad).is_err());
    }
}
