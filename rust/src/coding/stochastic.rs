//! Conventional *stochastic* coding substrate (paper §II.A, Fig 1).
//!
//! The FSM-based designs the paper compares against ([6]–[9]) use
//! stochastic bipolar coding: a value `x in [-1, 1]` is a random
//! bitstream with `P(bit = 1) = (x + 1) / 2`. Bitstreams are produced by
//! stochastic number generators (SNGs): an LFSR pseudo-random source
//! compared against the binary value.
//!
//! This module provides the LFSR, the SNG, and bipolar encode/decode —
//! everything needed to reproduce Fig 1 and the FSM baselines, and
//! nothing more: the paper's own designs are deterministic and never use
//! this path.

use super::BitVec;

/// Maximal-length 16-bit Fibonacci LFSR (taps 16,15,13,4 — polynomial
/// x^16 + x^15 + x^13 + x^4 + 1), the standard SNG random source.
#[derive(Clone, Debug)]
pub struct Lfsr16 {
    state: u16,
}

impl Lfsr16 {
    /// Create with a non-zero seed (0 is mapped to 1: the all-zero state
    /// is the LFSR's single fixed point).
    pub fn new(seed: u16) -> Self {
        Self { state: if seed == 0 { 1 } else { seed } }
    }

    /// Advance one step and return the new state.
    pub fn next_state(&mut self) -> u16 {
        let b = ((self.state >> 15) ^ (self.state >> 14) ^ (self.state >> 12) ^ (self.state >> 3)) & 1;
        self.state = (self.state << 1) | b;
        self.state
    }

    /// Current state.
    pub fn state(&self) -> u16 {
        self.state
    }

    /// Period of the maximal-length sequence.
    pub const PERIOD: usize = 65535;
}

/// Stochastic number generator: compares the LFSR state against a
/// threshold to produce a unipolar bitstream with the given probability.
#[derive(Clone, Debug)]
pub struct Sng {
    lfsr: Lfsr16,
}

impl Sng {
    /// New SNG with the given LFSR seed.
    pub fn new(seed: u16) -> Self {
        Self { lfsr: Lfsr16::new(seed) }
    }

    /// Generate an `n`-bit unipolar stream with `P(1) = p`.
    pub fn unipolar(&mut self, p: f64, n: usize) -> BitVec {
        let thresh = (p.clamp(0.0, 1.0) * 65536.0) as u32;
        let mut out = BitVec::zeros(n);
        for i in 0..n {
            let s = self.lfsr.next_state() as u32;
            out.set(i, s < thresh);
        }
        out
    }

    /// Generate an `n`-bit **bipolar** stream for `x in [-1, 1]`:
    /// `P(1) = (x + 1) / 2`.
    pub fn bipolar(&mut self, x: f64, n: usize) -> BitVec {
        self.unipolar((x.clamp(-1.0, 1.0) + 1.0) / 2.0, n)
    }
}

/// Decode a bipolar stochastic stream: `x = 2 * popcount / n - 1`.
pub fn bipolar_decode(bits: &BitVec) -> f64 {
    if bits.is_empty() {
        return 0.0;
    }
    2.0 * bits.popcount() as f64 / bits.len() as f64 - 1.0
}

/// Decode a unipolar stream: `p = popcount / n`.
pub fn unipolar_decode(bits: &BitVec) -> f64 {
    if bits.is_empty() {
        return 0.0;
    }
    bits.popcount() as f64 / bits.len() as f64
}

/// XNOR bipolar multiplication — the classic stochastic multiplier used
/// by the baselines: `E[xnor(a,b)] = a * b` for independent bipolar
/// streams.
pub fn xnor_mult(a: &BitVec, b: &BitVec) -> BitVec {
    assert_eq!(a.len(), b.len());
    // Word-parallel XNOR: ~(a ^ b) over packed lanes.
    let mut out = a.clone();
    out.xor_with(b);
    out.not_inplace();
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lfsr_is_maximal_length() {
        let mut l = Lfsr16::new(0xACE1);
        let start = l.state();
        let mut count = 0usize;
        loop {
            l.next_state();
            count += 1;
            if l.state() == start {
                break;
            }
            assert!(count <= Lfsr16::PERIOD, "period exceeded");
        }
        assert_eq!(count, Lfsr16::PERIOD);
    }

    #[test]
    fn lfsr_zero_seed_is_fixed() {
        let l = Lfsr16::new(0);
        assert_ne!(l.state(), 0);
    }

    #[test]
    fn bipolar_encode_decode_statistics() {
        let mut sng = Sng::new(0xBEEF);
        for &x in &[-0.9, -0.5, 0.0, 0.3, 0.8] {
            let bits = sng.bipolar(x, 4096);
            let err = (bipolar_decode(&bits) - x).abs();
            assert!(err < 0.05, "x={x} err={err}");
        }
    }

    #[test]
    fn unipolar_statistics() {
        let mut sng = Sng::new(0x1234);
        let bits = sng.unipolar(0.25, 8192);
        assert!((unipolar_decode(&bits) - 0.25).abs() < 0.03);
    }

    #[test]
    fn xnor_mult_expectation() {
        // Independent seeds -> product in expectation.
        let mut sa = Sng::new(0x1111);
        let mut sb = Sng::new(0x7777);
        let (x, y) = (0.6, -0.5);
        let a = sa.bipolar(x, 16384);
        let b = sb.bipolar(y, 16384);
        let p = bipolar_decode(&xnor_mult(&a, &b));
        assert!((p - x * y).abs() < 0.06, "p={p} expect={}", x * y);
    }

    #[test]
    fn decode_empty_is_zero() {
        assert_eq!(bipolar_decode(&BitVec::zeros(0)), 0.0);
        assert_eq!(unipolar_decode(&BitVec::zeros(0)), 0.0);
    }
}
