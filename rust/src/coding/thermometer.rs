//! Deterministic thermometer coding (paper §II.B, Table II).
//!
//! An `L`-bit thermometer code places all 1s at the beginning of the
//! bitstream. A value `x` is represented as
//!
//! ```text
//! x = alpha * x_q,     x_q = sum_i x[i] - L/2   in   [-L/2, L/2]
//! ```
//!
//! so an `L`-bit stream encodes `L + 1` levels centred on zero, and the
//! trained scale factor `alpha` carries the dynamic range. Table II:
//!
//! | BSL | binary precision | range          |
//! |-----|------------------|----------------|
//! | 2   | (ternary)        | -1, 0, 1       |
//! | 4   | 2                | -2 ..= 2       |
//! | 8   | 3                | -4 ..= 4       |
//! | 16  | 4                | -8 ..= 8       |

use super::BitVec;

/// A thermometer-coded value: `L` bits, all 1s first.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct ThermCode {
    bits: BitVec,
}

impl ThermCode {
    /// Encode quantized value `q` (in `[-L/2, L/2]`) as an `L`-bit
    /// thermometer code. `L` must be even. Values outside the range are
    /// saturated, matching the hardware behaviour of the SC datapath.
    pub fn encode(q: i64, bsl: usize) -> Self {
        assert!(bsl >= 2 && bsl % 2 == 0, "BSL must be even, got {bsl}");
        let half = (bsl / 2) as i64;
        let q = q.clamp(-half, half);
        Self::from_count((q + half) as usize, bsl)
    }

    /// Build directly from a count of ones (`0..=L`). Emits whole
    /// packed words (`u64::MAX` runs plus one masked partial), not a
    /// per-bit fill.
    pub fn from_count(ones: usize, bsl: usize) -> Self {
        assert!(ones <= bsl);
        let mut bits = BitVec::zeros(0);
        bits.set_ones_prefix(bsl, ones);
        Self { bits }
    }

    /// Buffer-reuse variant of [`ThermCode::encode`]: overwrite `out`
    /// with the encoding of `q`, reusing its allocation (zero-alloc in
    /// steady state once `out` has reached capacity `bsl`).
    pub fn encode_into(q: i64, bsl: usize, out: &mut ThermCode) {
        assert!(bsl >= 2 && bsl % 2 == 0, "BSL must be even, got {bsl}");
        let half = (bsl / 2) as i64;
        let ones = (q.clamp(-half, half) + half) as usize;
        Self::from_count_into(ones, bsl, out);
    }

    /// Buffer-reuse variant of [`ThermCode::from_count`].
    pub fn from_count_into(ones: usize, bsl: usize, out: &mut ThermCode) {
        assert!(ones <= bsl);
        out.bits.set_ones_prefix(bsl, ones);
    }

    /// Wrap an existing bit vector. Does *not* require the vector to be
    /// sorted — decode only depends on the popcount, which is exactly why
    /// the BSN accumulator is exact (§II.B).
    pub fn from_bits(bits: BitVec) -> Self {
        Self { bits }
    }

    /// The bitstream length (BSL).
    pub fn bsl(&self) -> usize {
        self.bits.len()
    }

    /// Decode to the quantized value `popcount - L/2`.
    pub fn decode(&self) -> i64 {
        self.bits.popcount() as i64 - (self.bits.len() / 2) as i64
    }

    /// Decode to a real value with scale `alpha`.
    pub fn decode_scaled(&self, alpha: f64) -> f64 {
        alpha * self.decode() as f64
    }

    /// Number of ones.
    pub fn count(&self) -> usize {
        self.bits.popcount()
    }

    /// Borrow the bits.
    pub fn bits(&self) -> &BitVec {
        &self.bits
    }

    /// Mutably borrow the bits (fault injection).
    pub fn bits_mut(&mut self) -> &mut BitVec {
        &mut self.bits
    }

    /// Consume into the underlying bits.
    pub fn into_bits(self) -> BitVec {
        self.bits
    }

    /// True iff the representation is canonical (1s first).
    pub fn is_canonical(&self) -> bool {
        self.bits.is_thermometer()
    }

    /// Negation: `-x` flips the count to `L - count`. In hardware this is
    /// a bitwise complement plus reversal; functionally the popcount maps
    /// `c -> L - c`, i.e. `q -> -q`.
    pub fn negate(&self) -> Self {
        // Complement-and-reverse keeps canonical codes canonical; done
        // word-parallel (`reverse_bits` + funnel shift + NOT).
        let mut bits = BitVec::zeros(0);
        bits.complement_reversed_from(&self.bits);
        Self { bits }
    }

    /// The representable range `[-L/2, L/2]` for a given BSL.
    pub fn range(bsl: usize) -> (i64, i64) {
        let half = (bsl / 2) as i64;
        (-half, half)
    }

    /// Equivalent binary precision in bits for a BSL (Table II): an
    /// `L`-bit thermometer code distinguishes `L + 1` levels; the paper
    /// tabulates `log2(L)` for powers of two (BSL 4 -> 2b, 8 -> 3b,
    /// 16 -> 4b).
    pub fn binary_precision(bsl: usize) -> Option<u32> {
        if bsl <= 2 {
            return None; // ternary: the paper lists no binary equivalent
        }
        Some((bsl as f64).log2().floor() as u32)
    }
}

impl std::fmt::Display for ThermCode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.bits)
    }
}

/// Re-quantize a count from one BSL to another, rounding to nearest and
/// saturating — used when the SI output BSL differs from the BSN input
/// BSL (§IV.B, Fig 10a).
pub fn requantize_count(count: usize, from_bsl: usize, to_bsl: usize) -> usize {
    if from_bsl == to_bsl {
        return count;
    }
    let q = count as i64 - (from_bsl / 2) as i64;
    let scaled =
        (q as f64 * to_bsl as f64 / from_bsl as f64).round() as i64;
    let half = (to_bsl / 2) as i64;
    (scaled.clamp(-half, half) + half) as usize
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_bsl2() {
        // BSL 2: -1 -> 00, 0 -> 10, 1 -> 11.
        assert_eq!(ThermCode::encode(-1, 2).to_string(), "00");
        assert_eq!(ThermCode::encode(0, 2).to_string(), "10");
        assert_eq!(ThermCode::encode(1, 2).to_string(), "11");
    }

    #[test]
    fn table2_bsl4() {
        // BSL 4: -2..2 -> 0000, 1000, 1100, 1110, 1111.
        let expect = ["0000", "1000", "1100", "1110", "1111"];
        for (q, e) in (-2..=2).zip(expect) {
            assert_eq!(ThermCode::encode(q, 4).to_string(), e);
        }
    }

    #[test]
    fn table2_bsl8_endpoints() {
        assert_eq!(ThermCode::encode(-4, 8).to_string(), "00000000");
        assert_eq!(ThermCode::encode(-3, 8).to_string(), "10000000");
        assert_eq!(ThermCode::encode(3, 8).to_string(), "11111110");
        assert_eq!(ThermCode::encode(4, 8).to_string(), "11111111");
    }

    #[test]
    fn encode_decode_roundtrip_all_bsl() {
        for bsl in [2usize, 4, 8, 16, 32, 64] {
            let (lo, hi) = ThermCode::range(bsl);
            for q in lo..=hi {
                let c = ThermCode::encode(q, bsl);
                assert_eq!(c.decode(), q, "bsl={bsl} q={q}");
                assert!(c.is_canonical());
            }
        }
    }

    #[test]
    fn into_variants_match_allocating_ones() {
        let mut buf = ThermCode::encode(0, 2);
        for bsl in [2usize, 4, 8, 16] {
            let (lo, hi) = ThermCode::range(bsl);
            for q in lo - 2..=hi + 2 {
                ThermCode::encode_into(q, bsl, &mut buf);
                assert_eq!(buf, ThermCode::encode(q, bsl), "bsl={bsl} q={q}");
            }
            for ones in 0..=bsl {
                ThermCode::from_count_into(ones, bsl, &mut buf);
                assert_eq!(buf, ThermCode::from_count(ones, bsl));
            }
        }
    }

    #[test]
    fn encode_saturates() {
        assert_eq!(ThermCode::encode(100, 8).decode(), 4);
        assert_eq!(ThermCode::encode(-100, 8).decode(), -4);
    }

    #[test]
    fn negate_is_involution() {
        for bsl in [2usize, 4, 8, 16] {
            let (lo, hi) = ThermCode::range(bsl);
            for q in lo..=hi {
                let c = ThermCode::encode(q, bsl);
                assert_eq!(c.negate().decode(), -q);
                assert_eq!(c.negate().negate(), c);
                assert!(c.negate().is_canonical());
            }
        }
    }

    #[test]
    fn decode_depends_only_on_popcount() {
        // A shuffled (non-canonical) code decodes identically — the key
        // property that makes the BSN accumulator exact.
        let c = ThermCode::from_bits(BitVec::from_str01("01010101"));
        assert_eq!(c.decode(), 0); // 4 ones - 4
    }

    #[test]
    fn binary_precision_matches_table2() {
        assert_eq!(ThermCode::binary_precision(2), None);
        assert_eq!(ThermCode::binary_precision(4), Some(2));
        assert_eq!(ThermCode::binary_precision(8), Some(3));
        assert_eq!(ThermCode::binary_precision(16), Some(4));
    }

    #[test]
    fn requantize_identity_and_halving() {
        assert_eq!(requantize_count(5, 8, 8), 5);
        // q=+4 at BSL8 -> q=+8 at BSL16 -> count 16
        assert_eq!(requantize_count(8, 8, 16), 16);
        // q=+4 at BSL8 -> q=+2 at BSL4 (scaled) -> count 4
        assert_eq!(requantize_count(8, 8, 4), 4);
        // center maps to center
        assert_eq!(requantize_count(4, 8, 16), 8);
    }

    #[test]
    fn scaled_decode() {
        let c = ThermCode::encode(3, 8);
        assert!((c.decode_scaled(0.5) - 1.5).abs() < 1e-12);
    }
}
