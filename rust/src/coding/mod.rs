//! Coding schemes for stochastic computing.
//!
//! The paper's central representational choice is **deterministic
//! thermometer coding** (Table II): an `L`-bit stream in which all 1s
//! appear first, representing the quantized value `q = popcount - L/2`
//! with a trained scale factor `alpha`, i.e. `x = alpha * q`.
//!
//! Three sub-modules:
//!
//! * [`thermometer`] — general L-bit thermometer codes and arithmetic.
//! * [`ternary`] — the 2-bit special case (`00 -> -1`, `10 -> 0`,
//!   `11 -> +1`) used for weights and low-precision activations.
//! * [`stochastic`] — conventional *stochastic* bipolar coding with
//!   LFSR-based stochastic number generators; only used by the FSM
//!   baseline designs the paper compares against (Fig 1).

pub mod stochastic;
pub mod ternary;
pub mod thermometer;

pub use ternary::{Ternary, TernaryCode};
pub use thermometer::ThermCode;

/// A plain bit vector, LSB-first in push order. Thermometer streams store
/// their 1s at the *front* (low indices) per the paper's convention.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BitVec {
    bits: Vec<bool>,
}

impl BitVec {
    /// An all-zero bit vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        Self { bits: vec![false; len] }
    }

    /// Build from a bool slice.
    pub fn from_bits(bits: &[bool]) -> Self {
        Self { bits: bits.to_vec() }
    }

    /// Build from a `0`/`1` string, e.g. `"1100"`. Panics on other chars.
    pub fn from_str01(s: &str) -> Self {
        Self { bits: s.chars().map(|c| match c {
            '0' => false,
            '1' => true,
            _ => panic!("BitVec::from_str01: invalid char {c:?}"),
        }).collect() }
    }

    /// Render as a `0`/`1` string (index 0 first).
    pub fn to_str01(&self) -> String {
        self.bits.iter().map(|&b| if b { '1' } else { '0' }).collect()
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.bits.len()
    }

    /// True when the vector holds no bits.
    pub fn is_empty(&self) -> bool {
        self.bits.is_empty()
    }

    /// Bit at `i`.
    pub fn get(&self, i: usize) -> bool {
        self.bits[i]
    }

    /// Set bit `i`.
    pub fn set(&mut self, i: usize, v: bool) {
        self.bits[i] = v;
    }

    /// Flip bit `i` (used by fault injection).
    pub fn flip(&mut self, i: usize) {
        self.bits[i] = !self.bits[i];
    }

    /// Number of 1s.
    pub fn popcount(&self) -> usize {
        self.bits.iter().filter(|&&b| b).count()
    }

    /// Borrow the raw bits.
    pub fn as_slice(&self) -> &[bool] {
        &self.bits
    }

    /// Mutably borrow the raw bits.
    pub fn as_mut_slice(&mut self) -> &mut [bool] {
        &mut self.bits
    }

    /// Re-initialize in place to `len` zero bits, reusing the existing
    /// allocation when capacity allows — the buffer-reuse primitive
    /// behind the `*_into` entry points of [`thermometer`] and
    /// `crate::circuits`.
    pub fn reset(&mut self, len: usize) {
        self.bits.clear();
        self.bits.resize(len, false);
    }

    /// Overwrite with the contents of `other`, reusing the allocation.
    pub fn copy_from(&mut self, other: &BitVec) {
        self.bits.clear();
        self.bits.extend_from_slice(&other.bits);
    }

    /// Append a bit.
    pub fn push(&mut self, b: bool) {
        self.bits.push(b);
    }

    /// Concatenate another vector onto this one.
    pub fn extend_from(&mut self, other: &BitVec) {
        self.bits.extend_from_slice(&other.bits);
    }

    /// Iterate over bits.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        self.bits.iter().copied()
    }

    /// True iff the vector is a valid thermometer code (all 1s before
    /// all 0s).
    pub fn is_thermometer(&self) -> bool {
        let mut seen_zero = false;
        for &b in &self.bits {
            if b && seen_zero {
                return false;
            }
            if !b {
                seen_zero = true;
            }
        }
        true
    }
}

impl std::fmt::Display for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_str01())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitvec_roundtrip_str() {
        let b = BitVec::from_str01("11010");
        assert_eq!(b.to_str01(), "11010");
        assert_eq!(b.len(), 5);
        assert_eq!(b.popcount(), 3);
    }

    #[test]
    fn bitvec_thermometer_check() {
        assert!(BitVec::from_str01("11100").is_thermometer());
        assert!(BitVec::from_str01("00000").is_thermometer());
        assert!(BitVec::from_str01("11111").is_thermometer());
        assert!(!BitVec::from_str01("11011").is_thermometer());
        assert!(!BitVec::from_str01("01").is_thermometer());
    }

    #[test]
    fn bitvec_flip_and_set() {
        let mut b = BitVec::zeros(4);
        b.set(2, true);
        assert_eq!(b.to_str01(), "0010");
        b.flip(2);
        b.flip(0);
        assert_eq!(b.to_str01(), "1000");
    }

    #[test]
    fn bitvec_extend() {
        let mut a = BitVec::from_str01("11");
        a.extend_from(&BitVec::from_str01("00"));
        assert_eq!(a.to_str01(), "1100");
    }

    #[test]
    fn bitvec_reset_and_copy_from() {
        let mut a = BitVec::from_str01("1101");
        a.reset(6);
        assert_eq!(a.to_str01(), "000000");
        a.copy_from(&BitVec::from_str01("101"));
        assert_eq!(a.to_str01(), "101");
    }
}
