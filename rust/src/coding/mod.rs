//! Coding schemes for stochastic computing.
//!
//! The paper's central representational choice is **deterministic
//! thermometer coding** (Table II): an `L`-bit stream in which all 1s
//! appear first, representing the quantized value `q = popcount - L/2`
//! with a trained scale factor `alpha`, i.e. `x = alpha * q`.
//!
//! Three sub-modules:
//!
//! * [`thermometer`] — general L-bit thermometer codes and arithmetic.
//! * [`ternary`] — the 2-bit special case (`00 -> -1`, `10 -> 0`,
//!   `11 -> +1`) used for weights and low-precision activations.
//! * [`stochastic`] — conventional *stochastic* bipolar coding with
//!   LFSR-based stochastic number generators; only used by the FSM
//!   baseline designs the paper compares against (Fig 1).

pub mod stochastic;
pub mod ternary;
pub mod thermometer;

pub use ternary::{Ternary, TernaryCode};
pub use thermometer::ThermCode;

use crate::util::simd::Dispatch;

/// A plain bit vector, LSB-first in push order. Thermometer streams store
/// their 1s at the *front* (low indices) per the paper's convention.
///
/// **Storage is packed**: bit `i` lives in word `i / 64` at bit position
/// `i % 64` of a `Vec<u64>` (LSB-first lane order), with the logical
/// length tracked separately. Every bulk operation — popcount, bitwise
/// combination, concatenation, range copy, complement-reverse, the
/// thermometer ones-prefix fill — runs word-at-a-time, which is what
/// lets the gate-level circuit stages in `crate::circuits` evaluate ~64
/// lanes per instruction without ever transposing to a byte-per-bit
/// form. The word loops themselves route through the runtime-dispatched
/// SIMD table ([`crate::util::simd::Dispatch`]): AVX2/NEON when the CPU
/// has them, the bit-identical scalar kernels otherwise (or always,
/// under `SCNN_NO_SIMD=1`).
///
/// Invariants maintained by every method:
/// * `words.len() == len.div_ceil(64)`;
/// * bits at positions `>= len` in the last word are zero.
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BitVec {
    words: Vec<u64>,
    len: usize,
}

impl BitVec {
    #[inline]
    fn word_count(len: usize) -> usize {
        len.div_ceil(64)
    }

    /// Mask of the valid bits in the last storage word.
    #[inline]
    fn tail_mask(len: usize) -> u64 {
        let r = len % 64;
        if r == 0 {
            u64::MAX
        } else {
            (1u64 << r) - 1
        }
    }

    /// Zero any stale bits past `len` in the last word (the invariant
    /// every word-level producer restores before returning).
    #[inline]
    fn mask_tail(&mut self) {
        if let Some(last) = self.words.last_mut() {
            *last &= Self::tail_mask(self.len);
        }
    }

    /// An all-zero bit vector of length `len`.
    pub fn zeros(len: usize) -> Self {
        Self { words: vec![0; Self::word_count(len)], len }
    }

    /// Build from a bool slice.
    pub fn from_bits(bits: &[bool]) -> Self {
        let mut out = Self::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                out.words[i / 64] |= 1 << (i % 64);
            }
        }
        out
    }

    /// Build from a `0`/`1` string, e.g. `"1100"`. Panics on other chars.
    pub fn from_str01(s: &str) -> Self {
        let mut out = Self::zeros(s.chars().count());
        for (i, c) in s.chars().enumerate() {
            match c {
                '0' => {}
                '1' => out.words[i / 64] |= 1 << (i % 64),
                _ => panic!("BitVec::from_str01: invalid char {c:?}"),
            }
        }
        out
    }

    /// Render as a `0`/`1` string (index 0 first).
    pub fn to_str01(&self) -> String {
        (0..self.len).map(|i| if self.get(i) { '1' } else { '0' }).collect()
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when the vector holds no bits.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Bit at `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        assert!(i < self.len, "BitVec index {i} out of range (len {})", self.len);
        self.words[i / 64] >> (i % 64) & 1 == 1
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize, v: bool) {
        assert!(i < self.len, "BitVec index {i} out of range (len {})", self.len);
        let mask = 1u64 << (i % 64);
        if v {
            self.words[i / 64] |= mask;
        } else {
            self.words[i / 64] &= !mask;
        }
    }

    /// Flip bit `i` (used by fault injection).
    #[inline]
    pub fn flip(&mut self, i: usize) {
        assert!(i < self.len, "BitVec index {i} out of range (len {})", self.len);
        self.words[i / 64] ^= 1 << (i % 64);
    }

    /// Number of 1s — SIMD-dispatched, at worst one `popcnt` per 64
    /// lanes.
    pub fn popcount(&self) -> usize {
        debug_assert!(self.tail_is_zero(), "BitVec: stale bits past len in the last word");
        Dispatch::active().popcount(&self.words) as usize
    }

    /// Number of positions where both this vector and `other` hold a 1
    /// — a fused AND + popcount in one pass over the words, with no
    /// materialized intermediate vector (the SI/count-tap hot path).
    pub fn count_and(&self, other: &BitVec) -> usize {
        assert_eq!(self.len, other.len, "count_and: length mismatch");
        debug_assert!(self.tail_is_zero(), "BitVec: stale bits past len in the last word");
        debug_assert!(other.tail_is_zero(), "BitVec: stale bits past len in the last word");
        Dispatch::active().count_and(&self.words, &other.words) as usize
    }

    /// True when every bit past [`BitVec::len`] in the last storage
    /// word is zero — the invariant each mutating method restores, and
    /// the one [`BitVec::as_mut_words`] callers must uphold. Word-level
    /// consumers (`popcount`, `count_and`, `extend_from`,
    /// `complement_reversed_from`) `debug_assert!` it.
    pub fn tail_is_zero(&self) -> bool {
        match self.words.last() {
            Some(&last) => last & !Self::tail_mask(self.len) == 0,
            None => true,
        }
    }

    /// Borrow the packed storage words (LSB-first lanes; bits past
    /// [`BitVec::len`] in the last word are guaranteed zero).
    pub fn as_words(&self) -> &[u64] {
        &self.words
    }

    /// Mutably borrow the packed storage words. The caller must keep
    /// bits past [`BitVec::len`] in the last word zero — every other
    /// method relies on that invariant, and the word-level consumers
    /// `debug_assert!` [`BitVec::tail_is_zero`] (so a violation fails
    /// fast in debug/test builds instead of corrupting counts).
    pub fn as_mut_words(&mut self) -> &mut [u64] {
        &mut self.words
    }

    /// Overwrite with the first `len` bits of a packed word slice
    /// (stale bits past `len` in the source's last word are masked
    /// off). The word-parallel unpack primitive of the BSN sorter.
    pub fn load_words(&mut self, src: &[u64], len: usize) {
        let nw = Self::word_count(len);
        assert!(nw <= src.len(), "load_words: {len} bits need {nw} words, got {}", src.len());
        self.words.clear();
        self.words.extend_from_slice(&src[..nw]);
        self.len = len;
        self.mask_tail();
    }

    /// Re-initialize in place to `len` zero bits, reusing the existing
    /// allocation when capacity allows — the buffer-reuse primitive
    /// behind the `*_into` entry points of [`thermometer`] and
    /// `crate::circuits`.
    pub fn reset(&mut self, len: usize) {
        self.words.clear();
        self.words.resize(Self::word_count(len), 0);
        self.len = len;
    }

    /// Overwrite with the contents of `other`, reusing the allocation
    /// (a word-level memcpy).
    pub fn copy_from(&mut self, other: &BitVec) {
        self.words.clear();
        self.words.extend_from_slice(&other.words);
        self.len = other.len;
    }

    /// Append a bit.
    pub fn push(&mut self, b: bool) {
        if self.len % 64 == 0 {
            self.words.push(0);
        }
        if b {
            self.words[self.len / 64] |= 1 << (self.len % 64);
        }
        self.len += 1;
    }

    /// Concatenate another vector onto this one — whole source words
    /// are shifted into place (two shifts + two ORs per 64 bits), so
    /// stream concatenation ahead of the BSN never walks single bits.
    pub fn extend_from(&mut self, other: &BitVec) {
        debug_assert!(other.tail_is_zero(), "BitVec: stale bits past len in the last word");
        if other.len == 0 {
            return;
        }
        let off = self.len % 64;
        let new_len = self.len + other.len;
        if off == 0 {
            self.words.extend_from_slice(&other.words);
            self.len = new_len;
            return;
        }
        let base = self.words.len() - 1;
        self.words.resize(Self::word_count(new_len), 0);
        let nw = self.words.len();
        for (k, &w) in other.words.iter().enumerate() {
            self.words[base + k] |= w << off;
            // High spill of this source word; when it would land past
            // the end it is all zeros (tail invariant on `other`).
            if base + k + 1 < nw {
                self.words[base + k + 1] |= w >> (64 - off);
            }
        }
        self.len = new_len;
    }

    /// Overwrite with `len` bits of `src` starting at bit `start` — a
    /// word-parallel funnel shift (the group-extraction primitive of
    /// the approximate/spatial-temporal BSNs).
    pub fn copy_range_from(&mut self, src: &BitVec, start: usize, len: usize) {
        assert!(
            start + len <= src.len,
            "copy_range_from: range {start}..{} out of bounds (src len {})",
            start + len,
            src.len
        );
        self.reset(len);
        if len == 0 {
            return;
        }
        let sw = start / 64;
        let off = start % 64;
        let nw = self.words.len();
        if off == 0 {
            self.words.copy_from_slice(&src.words[sw..sw + nw]);
        } else {
            // `src.words[sw..]` always holds at least `nw` words: the
            // range check above gives sw*64 + off + len <= src words'
            // bit span, and off >= 1.
            Dispatch::active().funnel_shr(&src.words[sw..], off as u32, &mut self.words);
        }
        self.mask_tail();
    }

    /// Overwrite with the ones-prefix pattern: `ones` 1s followed by
    /// zeros, `len` bits total — the canonical thermometer code,
    /// emitted as whole `u64::MAX` words plus one masked partial.
    pub fn set_ones_prefix(&mut self, len: usize, ones: usize) {
        assert!(ones <= len, "ones-prefix {ones} longer than the vector ({len})");
        self.reset(len);
        let full = ones / 64;
        for w in &mut self.words[..full] {
            *w = u64::MAX;
        }
        let r = ones % 64;
        if r > 0 {
            self.words[full] = (1u64 << r) - 1;
        }
    }

    /// Overwrite with the complement of `src` read in reverse bit
    /// order: bit `i` becomes `!src[len-1-i]`. This is thermometer
    /// negation and the ternary multiplier's `w = -1` path, done as one
    /// `reverse_bits` + funnel shift + NOT per word instead of a
    /// per-bit scan.
    pub fn complement_reversed_from(&mut self, src: &BitVec) {
        debug_assert!(src.tail_is_zero(), "BitVec: stale bits past len in the last word");
        let l = src.len;
        self.reset(l);
        if l == 0 {
            return;
        }
        let nw = self.words.len();
        // Reversing the zero-padded width nw*64 and then shifting right
        // by the pad restores the length-l reversal.
        let shift = nw * 64 - l;
        if shift == 0 {
            for j in 0..nw {
                self.words[j] = !src.words[nw - 1 - j].reverse_bits();
            }
        } else {
            for j in 0..nw {
                let cur = src.words[nw - 1 - j].reverse_bits();
                let next = if j + 1 < nw { src.words[nw - 2 - j].reverse_bits() } else { 0 };
                self.words[j] = !((cur >> shift) | (next << (64 - shift)));
            }
        }
        self.mask_tail();
    }

    /// In-place bitwise AND with an equal-length vector.
    pub fn and_with(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len, "and_with: length mismatch");
        Dispatch::active().and_words(&mut self.words, &other.words);
    }

    /// In-place bitwise OR with an equal-length vector.
    pub fn or_with(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len, "or_with: length mismatch");
        Dispatch::active().or_words(&mut self.words, &other.words);
    }

    /// In-place bitwise XOR with an equal-length vector.
    pub fn xor_with(&mut self, other: &BitVec) {
        assert_eq!(self.len, other.len, "xor_with: length mismatch");
        Dispatch::active().xor_words(&mut self.words, &other.words);
    }

    /// In-place bitwise NOT over all `len` lanes.
    pub fn not_inplace(&mut self) {
        for w in &mut self.words {
            *w = !*w;
        }
        self.mask_tail();
    }

    /// Iterate over bits.
    pub fn iter(&self) -> impl Iterator<Item = bool> + '_ {
        (0..self.len).map(move |i| self.words[i / 64] >> (i % 64) & 1 == 1)
    }

    /// True iff the vector is a valid thermometer code (all 1s before
    /// all 0s). Word-level: all-ones words, at most one `2^k - 1`
    /// boundary word, then all-zero words.
    pub fn is_thermometer(&self) -> bool {
        let mut past_boundary = false;
        let last = self.words.len().wrapping_sub(1);
        for (wi, &w) in self.words.iter().enumerate() {
            let valid = if wi == last { Self::tail_mask(self.len) } else { u64::MAX };
            if past_boundary {
                if w != 0 {
                    return false;
                }
            } else if w != valid {
                // Must be a low-ones prefix: 2^k - 1.
                if w & w.wrapping_add(1) != 0 {
                    return false;
                }
                past_boundary = true;
            }
        }
        true
    }
}

impl std::fmt::Display for BitVec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.to_str01())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bitvec_roundtrip_str() {
        let b = BitVec::from_str01("11010");
        assert_eq!(b.to_str01(), "11010");
        assert_eq!(b.len(), 5);
        assert_eq!(b.popcount(), 3);
    }

    #[test]
    fn bitvec_thermometer_check() {
        assert!(BitVec::from_str01("11100").is_thermometer());
        assert!(BitVec::from_str01("00000").is_thermometer());
        assert!(BitVec::from_str01("11111").is_thermometer());
        assert!(!BitVec::from_str01("11011").is_thermometer());
        assert!(!BitVec::from_str01("01").is_thermometer());
    }

    #[test]
    fn bitvec_flip_and_set() {
        let mut b = BitVec::zeros(4);
        b.set(2, true);
        assert_eq!(b.to_str01(), "0010");
        b.flip(2);
        b.flip(0);
        assert_eq!(b.to_str01(), "1000");
    }

    #[test]
    fn bitvec_extend() {
        let mut a = BitVec::from_str01("11");
        a.extend_from(&BitVec::from_str01("00"));
        assert_eq!(a.to_str01(), "1100");
    }

    #[test]
    fn bitvec_reset_and_copy_from() {
        let mut a = BitVec::from_str01("1101");
        a.reset(6);
        assert_eq!(a.to_str01(), "000000");
        a.copy_from(&BitVec::from_str01("101"));
        assert_eq!(a.to_str01(), "101");
    }

    #[test]
    fn word_boundary_extend_and_push() {
        // Concatenate around the 64-bit word boundary at a misaligned
        // offset and check against the string model.
        let mut a = BitVec::from_str01(&"10".repeat(31)); // 62 bits
        let b = BitVec::from_str01("11101");
        a.extend_from(&b);
        let expect = format!("{}{}", "10".repeat(31), "11101");
        assert_eq!(a.to_str01(), expect);
        assert_eq!(a.len(), 67);
        a.push(true);
        assert_eq!(a.to_str01(), format!("{expect}1"));
        assert_eq!(a.popcount(), 31 + 4 + 1);
    }

    #[test]
    fn ones_prefix_matches_thermometer() {
        let mut b = BitVec::zeros(0);
        for len in [1usize, 63, 64, 65, 130] {
            for ones in [0, 1, len / 2, len] {
                b.set_ones_prefix(len, ones);
                assert_eq!(b.len(), len);
                assert_eq!(b.popcount(), ones, "len={len} ones={ones}");
                assert!(b.is_thermometer());
                assert!(ones == len || !b.get(ones));
                assert!(ones == 0 || b.get(ones - 1));
            }
        }
    }

    #[test]
    fn copy_range_unaligned() {
        let s: String =
            (0..200).map(|i| if (i * 7 + 3) % 5 < 2 { '1' } else { '0' }).collect();
        let src = BitVec::from_str01(&s);
        let mut dst = BitVec::zeros(0);
        for (start, len) in [(0, 64), (1, 64), (63, 66), (64, 64), (70, 100), (199, 1), (3, 0)] {
            dst.copy_range_from(&src, start, len);
            assert_eq!(dst.to_str01(), &s[start..start + len], "start={start} len={len}");
        }
    }

    #[test]
    fn complement_reverse_matches_scalar() {
        for len in [1usize, 2, 5, 63, 64, 65, 127, 130] {
            let s: String = (0..len).map(|i| if i % 3 == 0 { '1' } else { '0' }).collect();
            let src = BitVec::from_str01(&s);
            let mut out = BitVec::zeros(0);
            out.complement_reversed_from(&src);
            assert_eq!(out.len(), len);
            for i in 0..len {
                assert_eq!(out.get(i), !src.get(len - 1 - i), "len={len} i={i}");
            }
        }
    }

    #[test]
    fn bitwise_ops_and_not() {
        let a0 = BitVec::from_str01("110101");
        let b0 = BitVec::from_str01("011100");
        let mut a = a0.clone();
        a.and_with(&b0);
        assert_eq!(a.to_str01(), "010100");
        let mut o = a0.clone();
        o.or_with(&b0);
        assert_eq!(o.to_str01(), "111101");
        let mut x = a0.clone();
        x.xor_with(&b0);
        assert_eq!(x.to_str01(), "101001");
        x.not_inplace();
        assert_eq!(x.to_str01(), "010110");
        assert_eq!(x.popcount(), 3);
        // Fused AND+popcount agrees with the two-step path.
        assert_eq!(a0.count_and(&b0), a.popcount());
    }

    #[test]
    fn load_words_masks_tail() {
        let mut b = BitVec::zeros(0);
        b.load_words(&[u64::MAX, u64::MAX], 70);
        assert_eq!(b.len(), 70);
        assert_eq!(b.popcount(), 70);
        assert!(b.is_thermometer());
    }
}
