//! 2-bit ternary thermometer coding (paper §II.B, Fig 3a).
//!
//! The 2-bit special case of thermometer coding represents the ternary
//! set `{-1, 0, +1}` as `{00, 10, 11}`. Ternary is the paper's weight
//! format throughout (weight BSL fixed to 2), and the activation format
//! of the most efficient configurations.

use super::thermometer::ThermCode;

/// A ternary value `{-1, 0, +1}`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Ternary {
    /// `-1`, coded `00`.
    Neg,
    /// `0`, coded `10`.
    Zero,
    /// `+1`, coded `11`.
    Pos,
}

impl Ternary {
    /// All three values, in ascending order.
    pub const ALL: [Ternary; 3] = [Ternary::Neg, Ternary::Zero, Ternary::Pos];

    /// From an integer (saturating outside `{-1,0,1}`).
    pub fn from_i64(v: i64) -> Self {
        match v {
            i64::MIN..=-1 => Ternary::Neg,
            0 => Ternary::Zero,
            1.. => Ternary::Pos,
        }
    }

    /// To an integer in `{-1, 0, 1}`.
    pub fn to_i64(self) -> i64 {
        match self {
            Ternary::Neg => -1,
            Ternary::Zero => 0,
            Ternary::Pos => 1,
        }
    }

    /// Exact ternary product.
    pub fn mul(self, other: Ternary) -> Ternary {
        Ternary::from_i64(self.to_i64() * other.to_i64())
    }
}

/// The 2-bit thermometer encoding of a [`Ternary`], exposing the
/// individual code bits `(t1, t0)` with the convention that the code
/// string is `t1 t0` (so `+1 = 11`, `0 = 10`, `-1 = 00`; `01` is
/// unused/invalid, as in the paper's truth table).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TernaryCode {
    /// First (most significant in stream order) bit.
    pub t1: bool,
    /// Second bit.
    pub t0: bool,
}

impl TernaryCode {
    /// Encode a ternary value.
    pub fn encode(v: Ternary) -> Self {
        match v {
            Ternary::Neg => Self { t1: false, t0: false },
            Ternary::Zero => Self { t1: true, t0: false },
            Ternary::Pos => Self { t1: true, t0: true },
        }
    }

    /// Decode. The invalid code `01` decodes by popcount (`= 0`), which
    /// is what the BSN accumulator would see.
    pub fn decode(self) -> Ternary {
        match (self.t1, self.t0) {
            (false, false) => Ternary::Neg,
            (true, true) => Ternary::Pos,
            _ => Ternary::Zero,
        }
    }

    /// Popcount of the 2-bit code.
    pub fn count(self) -> usize {
        self.t1 as usize + self.t0 as usize
    }

    /// As a [`ThermCode`] of BSL 2.
    pub fn to_therm(self) -> ThermCode {
        ThermCode::from_count(self.count(), 2)
    }
}

/// Multiply an `L`-bit thermometer activation by a ternary weight,
/// functionally (the generalized ternary multiplier):
///
/// * `w = +1` — pass the activation through.
/// * `w = 0`  — output the zero code (`L/2` ones).
/// * `w = -1` — negate (complement-reverse).
///
/// For `L = 2` this is exactly the 5-gate circuit of Fig 3a, which is
/// verified gate-by-gate in [`crate::circuits::multiplier`].
pub fn ternary_mult_therm(act: &ThermCode, w: Ternary) -> ThermCode {
    match w {
        Ternary::Pos => act.clone(),
        Ternary::Zero => ThermCode::from_count(act.bsl() / 2, act.bsl()),
        Ternary::Neg => act.negate(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn codes_match_paper() {
        assert_eq!(TernaryCode::encode(Ternary::Neg).to_therm().to_string(), "00");
        assert_eq!(TernaryCode::encode(Ternary::Zero).to_therm().to_string(), "10");
        assert_eq!(TernaryCode::encode(Ternary::Pos).to_therm().to_string(), "11");
    }

    #[test]
    fn encode_decode_roundtrip() {
        for v in Ternary::ALL {
            assert_eq!(TernaryCode::encode(v).decode(), v);
        }
    }

    #[test]
    fn ternary_mul_table() {
        for a in Ternary::ALL {
            for b in Ternary::ALL {
                assert_eq!(a.mul(b).to_i64(), a.to_i64() * b.to_i64());
            }
        }
    }

    #[test]
    fn therm_mult_matches_integer_product() {
        for bsl in [2usize, 4, 8, 16] {
            let (lo, hi) = ThermCode::range(bsl);
            for q in lo..=hi {
                let act = ThermCode::encode(q, bsl);
                for w in Ternary::ALL {
                    let p = ternary_mult_therm(&act, w);
                    assert_eq!(p.decode(), q * w.to_i64(), "bsl={bsl} q={q} w={w:?}");
                    assert_eq!(p.bsl(), bsl);
                }
            }
        }
    }

    #[test]
    fn from_i64_saturates() {
        assert_eq!(Ternary::from_i64(-7), Ternary::Neg);
        assert_eq!(Ternary::from_i64(9), Ternary::Pos);
        assert_eq!(Ternary::from_i64(0), Ternary::Zero);
    }
}
