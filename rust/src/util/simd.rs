//! Runtime-dispatched SIMD kernels for the two serving hot loops.
//!
//! PR 3 made the bit-level stages word-parallel and PR 4 turned
//! accumulation into a cache-blocked integer GEMM, which leaves serving
//! throughput dominated by two scalar-u64 loop families: the
//! [`TernaryPanel`](crate::nn::gemm::TernaryPanel) /
//! [`I8Panel`](crate::nn::gemm::I8Panel) row dots in `nn::gemm`, and
//! the packed word ops of [`BitVec`](crate::coding::BitVec) (popcount,
//! bitwise combination, funnel-shift range copy, the residual divider's
//! even-bit compress). This module gives each of those kernels an
//! explicit `std::arch` vector path — AVX2 on x86_64, NEON on aarch64 —
//! behind a [`Dispatch`] table of plain `fn` pointers selected **once**
//! at first use by runtime CPU-feature detection, with the portable
//! scalar code kept as the always-available reference arm.
//!
//! Every vector kernel is **bit-identical** to its scalar twin: all
//! accumulation here is exact integer arithmetic in i64 lanes, which is
//! associative, so lane order cannot change a result the way float
//! summation order would (`nn::gemm::dot_f32` deliberately stays
//! scalar-sequential for exactly that reason). The equivalence is
//! enforced by property tests pitting [`Dispatch::active`] against
//! [`Dispatch::scalar`] over ragged lengths and non-word-aligned
//! offsets (`rust/tests/packed_bitvec.rs`, `rust/tests/gemm.rs`), and
//! CI runs the whole suite a second time with `SCNN_NO_SIMD=1` so the
//! scalar arm stays a first-class citizen on any machine
//! (DESIGN.md §Perf "SIMD dispatch").

use std::sync::OnceLock;

/// Which instruction set a [`Dispatch`] table targets.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Level {
    /// Portable scalar u64 code — always available, the reference arm.
    Scalar,
    /// x86_64 AVX2 (detected at runtime; BMI2, when also present,
    /// upgrades the even-bit compress to a hardware `pext`).
    Avx2,
    /// aarch64 NEON (baseline on every aarch64 target).
    Neon,
}

impl Level {
    /// Short label for bench series and log lines.
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }
}

/// The dispatch table: one `fn` pointer per vectorized kernel, filled
/// in once at startup ([`Dispatch::active`]) from runtime CPU-feature
/// detection. Consumers hold `&'static Dispatch` and pay one indirect
/// call per kernel invocation — no per-call feature checks, no
/// monomorphization fan-out.
#[derive(Clone, Copy)]
pub struct Dispatch {
    level: Level,
    popcount: fn(&[u64]) -> u64,
    count_and: fn(&[u64], &[u64]) -> u64,
    and: fn(&mut [u64], &[u64]),
    or: fn(&mut [u64], &[u64]),
    xor: fn(&mut [u64], &[u64]),
    funnel_shr: fn(&[u64], u32, &mut [u64]),
    compress_even: fn(u64) -> u64,
    i8_dot: fn(&[i8], &[i32]) -> i64,
    i8_dot4: fn(&[i8], [&[i32]; 4]) -> [i64; 4],
    gather_sub_i32: fn(&[u32], &[u32], &[i32]) -> i64,
    gather_sub_i64: fn(&[u32], &[u32], &[i64]) -> i64,
    sparse_i8_dot: fn(&[i8], &[i32], &[u32]) -> i64,
}

/// The scalar reference table (also the fallback on unknown ISAs).
static SCALAR: Dispatch = Dispatch {
    level: Level::Scalar,
    popcount: popcount_scalar,
    count_and: count_and_scalar,
    and: and_scalar,
    or: or_scalar,
    xor: xor_scalar,
    funnel_shr: funnel_shr_scalar,
    compress_even: compress_even_scalar,
    i8_dot: i8_dot_scalar,
    i8_dot4: i8_dot4_scalar,
    gather_sub_i32: gather_sub_i32_scalar,
    gather_sub_i64: gather_sub_i64_scalar,
    sparse_i8_dot: sparse_i8_dot_scalar,
};

impl Dispatch {
    /// The table selected for this process: scalar when `SCNN_NO_SIMD`
    /// is set (to anything but `0`), else the best vector arm the CPU
    /// supports — AVX2 on x86_64 (checked with
    /// `is_x86_feature_detected!`), NEON on aarch64 — falling back to
    /// scalar. Detection runs once behind a `OnceLock`.
    pub fn active() -> &'static Dispatch {
        static ACTIVE: OnceLock<Dispatch> = OnceLock::new();
        ACTIVE.get_or_init(|| {
            if std::env::var("SCNN_NO_SIMD").is_ok_and(|v| v != "0") {
                return SCALAR;
            }
            detect_arch()
        })
    }

    /// The always-available scalar reference table — what every vector
    /// path is property-tested against, and the forced-scalar override
    /// for debugging (`SCNN_NO_SIMD=1` makes [`Dispatch::active`]
    /// return the same kernels).
    pub fn scalar() -> &'static Dispatch {
        &SCALAR
    }

    /// Which instruction set this table targets.
    pub fn level(&self) -> Level {
        self.level
    }

    /// Total number of 1 bits across a packed word slice.
    #[inline]
    pub fn popcount(&self, words: &[u64]) -> u64 {
        (self.popcount)(words)
    }

    /// Fused AND + popcount of two equal-length word slices — the
    /// number of positions where both are 1, in one pass with no
    /// materialized temporary.
    #[inline]
    pub fn count_and(&self, a: &[u64], b: &[u64]) -> u64 {
        assert_eq!(a.len(), b.len(), "count_and: word count mismatch");
        (self.count_and)(a, b)
    }

    /// `dst[i] &= src[i]` lane-wise over equal-length word slices.
    #[inline]
    pub fn and_words(&self, dst: &mut [u64], src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "and_words: word count mismatch");
        (self.and)(dst, src)
    }

    /// `dst[i] |= src[i]` lane-wise over equal-length word slices.
    #[inline]
    pub fn or_words(&self, dst: &mut [u64], src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "or_words: word count mismatch");
        (self.or)(dst, src)
    }

    /// `dst[i] ^= src[i]` lane-wise over equal-length word slices.
    #[inline]
    pub fn xor_words(&self, dst: &mut [u64], src: &[u64]) {
        assert_eq!(dst.len(), src.len(), "xor_words: word count mismatch");
        (self.xor)(dst, src)
    }

    /// Word-parallel funnel shift right: for every `k < dst.len()`,
    /// `dst[k] = (src[k] >> off) | (src[k+1] << (64-off))`, where a
    /// high word past `src.len()` reads as zero. `off` must be in
    /// `1..=63` and `src` at least as long as `dst` (the word-misaligned
    /// arm of `BitVec::copy_range_from`).
    #[inline]
    pub fn funnel_shr(&self, src: &[u64], off: u32, dst: &mut [u64]) {
        assert!((1..64u32).contains(&off), "funnel_shr: off {off} outside 1..=63");
        assert!(src.len() >= dst.len(), "funnel_shr: src shorter than dst");
        (self.funnel_shr)(src, off, dst)
    }

    /// Compress the even-index bits of `w` into the low half: output
    /// bit `i` is input bit `2i` (odd-index input bits are dropped).
    /// The residual divider's select-1-of-2 step, generalized to all
    /// 64 lanes.
    #[inline]
    pub fn compress_even(&self, w: u64) -> u64 {
        (self.compress_even)(w)
    }

    /// Exact `Σ x[i] · w[i]` with i8 weights widened into vector
    /// lanes and accumulation in i64 (the dense-panel row dot).
    #[inline]
    pub fn i8_dot(&self, w: &[i8], x: &[i32]) -> i64 {
        assert_eq!(w.len(), x.len(), "i8_dot: length mismatch");
        (self.i8_dot)(w, x)
    }

    /// Four-column variant of [`Dispatch::i8_dot`]: one weight row
    /// against four equal-length pixel columns — the dense GEMM
    /// microkernel (each widened weight chunk feeds four accumulators).
    #[inline]
    pub fn i8_dot4(&self, w: &[i8], x: [&[i32]; 4]) -> [i64; 4] {
        let k = w.len();
        assert!(x.iter().all(|c| c.len() == k), "i8_dot4: length mismatch");
        (self.i8_dot4)(w, x)
    }

    /// `Σ x[plus] − Σ x[minus]` over i32 values via gathered loads
    /// (the ternary-panel row dot: add the `+1` list, subtract the
    /// `−1` list).
    ///
    /// # Safety
    ///
    /// Every index in `plus` and `minus` must be `< x.len()`: the
    /// vector arm issues hardware gathers without per-element bounds
    /// checks. `TernaryPanel::pack` guarantees this for its index
    /// lists (indices are column positions `< k`).
    #[inline]
    pub unsafe fn gather_sub_i32(&self, plus: &[u32], minus: &[u32], x: &[i32]) -> i64 {
        (self.gather_sub_i32)(plus, minus, x)
    }

    /// [`Dispatch::gather_sub_i32`] over i64 values (the classifier
    /// path, where the GAP accumulator is already 64-bit).
    ///
    /// # Safety
    ///
    /// Every index in `plus` and `minus` must be `< x.len()` — same
    /// contract as [`Dispatch::gather_sub_i32`].
    #[inline]
    pub unsafe fn gather_sub_i64(&self, plus: &[u32], minus: &[u32], x: &[i64]) -> i64 {
        (self.gather_sub_i64)(plus, minus, x)
    }

    /// `Σ w[idx[j]] · vals[j]` — one dense i8 weight row against one
    /// compressed activation column (`vals`/`idx` are a CSR column's
    /// nonzero values and their positions). The sparse-GEMM inner
    /// kernel: only the nonzeros are touched, weights reached via
    /// gathered byte loads on the vector arm.
    ///
    /// # Safety
    ///
    /// `idx` must be sorted ascending with every index `< w.len()`:
    /// the vector arm issues hardware gathers without per-element
    /// bounds checks and uses the chunk's last (largest) index as its
    /// in-bounds witness. `SparseCols` columns satisfy both by
    /// construction.
    #[inline]
    pub unsafe fn sparse_i8_dot(&self, w: &[i8], vals: &[i32], idx: &[u32]) -> i64 {
        assert_eq!(vals.len(), idx.len(), "sparse_i8_dot: vals/idx length mismatch");
        (self.sparse_i8_dot)(w, vals, idx)
    }
}

#[cfg(target_arch = "x86_64")]
fn detect_arch() -> Dispatch {
    if std::arch::is_x86_feature_detected!("avx2") {
        x86::table(std::arch::is_x86_feature_detected!("bmi2"))
    } else {
        SCALAR
    }
}

#[cfg(target_arch = "aarch64")]
fn detect_arch() -> Dispatch {
    neon::table()
}

#[cfg(not(any(target_arch = "x86_64", target_arch = "aarch64")))]
fn detect_arch() -> Dispatch {
    SCALAR
}

// ---------------------------------------------------------------------
// Scalar reference kernels (one instruction per 64 lanes; also the
// remainder loops of the vector arms).
// ---------------------------------------------------------------------

fn popcount_scalar(words: &[u64]) -> u64 {
    words.iter().map(|w| w.count_ones() as u64).sum()
}

fn count_and_scalar(a: &[u64], b: &[u64]) -> u64 {
    a.iter().zip(b).map(|(x, y)| (x & y).count_ones() as u64).sum()
}

fn and_scalar(dst: &mut [u64], src: &[u64]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a &= b;
    }
}

fn or_scalar(dst: &mut [u64], src: &[u64]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a |= b;
    }
}

fn xor_scalar(dst: &mut [u64], src: &[u64]) {
    for (a, b) in dst.iter_mut().zip(src) {
        *a ^= b;
    }
}

fn funnel_shr_scalar(src: &[u64], off: u32, dst: &mut [u64]) {
    debug_assert!((1..64u32).contains(&off));
    debug_assert!(src.len() >= dst.len());
    for (k, d) in dst.iter_mut().enumerate() {
        let lo = src[k] >> off;
        let hi = src.get(k + 1).copied().unwrap_or(0) << (64 - off);
        *d = lo | hi;
    }
}

/// SWAR even-bit compress: 6 mask/shift rounds fold bit `2i` down to
/// bit `i` (the 64-lane generalization of the divider's 16-lane
/// version; on x86 with BMI2 this whole function is one `pext`).
fn compress_even_scalar(w: u64) -> u64 {
    let mut x = w & 0x5555_5555_5555_5555;
    x = (x ^ (x >> 1)) & 0x3333_3333_3333_3333;
    x = (x ^ (x >> 2)) & 0x0f0f_0f0f_0f0f_0f0f;
    x = (x ^ (x >> 4)) & 0x00ff_00ff_00ff_00ff;
    x = (x ^ (x >> 8)) & 0x0000_ffff_0000_ffff;
    x = (x ^ (x >> 16)) & 0x0000_0000_ffff_ffff;
    x
}

fn i8_dot_scalar(w: &[i8], x: &[i32]) -> i64 {
    let mut s = 0i64;
    for (&wv, &xv) in w.iter().zip(x) {
        s += xv as i64 * wv as i64;
    }
    s
}

fn i8_dot4_scalar(w: &[i8], x: [&[i32]; 4]) -> [i64; 4] {
    let [x0, x1, x2, x3] = x;
    let (mut a0, mut a1, mut a2, mut a3) = (0i64, 0i64, 0i64, 0i64);
    for (i, &wv) in w.iter().enumerate() {
        let wl = wv as i64;
        a0 += x0[i] as i64 * wl;
        a1 += x1[i] as i64 * wl;
        a2 += x2[i] as i64 * wl;
        a3 += x3[i] as i64 * wl;
    }
    [a0, a1, a2, a3]
}

fn gather_sub_i32_scalar(plus: &[u32], minus: &[u32], x: &[i32]) -> i64 {
    let mut pos = 0i64;
    for &i in plus {
        pos += x[i as usize] as i64;
    }
    let mut neg = 0i64;
    for &i in minus {
        neg += x[i as usize] as i64;
    }
    pos - neg
}

fn gather_sub_i64_scalar(plus: &[u32], minus: &[u32], x: &[i64]) -> i64 {
    let mut pos = 0i64;
    for &i in plus {
        pos += x[i as usize];
    }
    let mut neg = 0i64;
    for &i in minus {
        neg += x[i as usize];
    }
    pos - neg
}

fn sparse_i8_dot_scalar(w: &[i8], vals: &[i32], idx: &[u32]) -> i64 {
    debug_assert_eq!(vals.len(), idx.len());
    let mut s = 0i64;
    for (&v, &i) in vals.iter().zip(idx) {
        s += w[i as usize] as i64 * v as i64;
    }
    s
}

// ---------------------------------------------------------------------
// AVX2 kernels (x86_64). Each `#[target_feature]` kernel is wrapped by
// a safe entry fn; the wrapper's `unsafe` is justified by the dispatch
// selection (the table only installs these after
// `is_x86_feature_detected!("avx2")` succeeded).
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::*;
    use std::arch::x86_64::*;

    pub(super) fn table(bmi2: bool) -> Dispatch {
        Dispatch {
            level: Level::Avx2,
            popcount: popcount_entry,
            count_and: count_and_entry,
            and: and_entry,
            or: or_entry,
            xor: xor_entry,
            funnel_shr: funnel_shr_entry,
            compress_even: if bmi2 {
                compress_even_entry
            } else {
                compress_even_scalar
            },
            i8_dot: i8_dot_entry,
            i8_dot4: i8_dot4_entry,
            gather_sub_i32: gather_sub_i32_entry,
            gather_sub_i64: gather_sub_i64_entry,
            sparse_i8_dot: sparse_i8_dot_entry,
        }
    }

    fn popcount_entry(words: &[u64]) -> u64 {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { popcount_avx2(words) }
    }

    fn count_and_entry(a: &[u64], b: &[u64]) -> u64 {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { count_and_avx2(a, b) }
    }

    fn and_entry(dst: &mut [u64], src: &[u64]) {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { and_avx2(dst, src) }
    }

    fn or_entry(dst: &mut [u64], src: &[u64]) {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { or_avx2(dst, src) }
    }

    fn xor_entry(dst: &mut [u64], src: &[u64]) {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { xor_avx2(dst, src) }
    }

    fn funnel_shr_entry(src: &[u64], off: u32, dst: &mut [u64]) {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { funnel_shr_avx2(src, off, dst) }
    }

    fn compress_even_entry(w: u64) -> u64 {
        // SAFETY: installed only after BMI2 was detected.
        unsafe { compress_even_bmi2(w) }
    }

    fn i8_dot_entry(w: &[i8], x: &[i32]) -> i64 {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { i8_dot_avx2(w, x) }
    }

    fn i8_dot4_entry(w: &[i8], x: [&[i32]; 4]) -> [i64; 4] {
        // SAFETY: installed only after AVX2 was detected.
        unsafe { i8_dot4_avx2(w, x) }
    }

    fn gather_sub_i32_entry(plus: &[u32], minus: &[u32], x: &[i32]) -> i64 {
        if x.len() > i32::MAX as usize {
            // Gather indices are signed 32-bit; beyond that the scalar
            // path is the only correct one.
            return gather_sub_i32_scalar(plus, minus, x);
        }
        // SAFETY: AVX2 detected at init; `Dispatch::gather_sub_i32`'s
        // contract guarantees every index < x.len(), which fits i32.
        unsafe { gather_sum_i32(plus, x) - gather_sum_i32(minus, x) }
    }

    fn gather_sub_i64_entry(plus: &[u32], minus: &[u32], x: &[i64]) -> i64 {
        if x.len() > i32::MAX as usize {
            return gather_sub_i64_scalar(plus, minus, x);
        }
        // SAFETY: AVX2 detected at init; `Dispatch::gather_sub_i64`'s
        // contract guarantees every index < x.len(), which fits i32.
        unsafe { gather_sum_i64(plus, x) - gather_sum_i64(minus, x) }
    }

    fn sparse_i8_dot_entry(w: &[i8], vals: &[i32], idx: &[u32]) -> i64 {
        if w.len() > i32::MAX as usize {
            return sparse_i8_dot_scalar(w, vals, idx);
        }
        // SAFETY: AVX2 detected at init; `Dispatch::sparse_i8_dot`'s
        // contract guarantees ascending indices < w.len().
        unsafe { sparse_i8_dot_avx2(w, vals, idx) }
    }

    /// Horizontal sum of the four i64 lanes.
    #[target_feature(enable = "avx2")]
    unsafe fn hsum_i64(v: __m256i) -> i64 {
        let lo = _mm256_castsi256_si128(v);
        let hi = _mm256_extracti128_si256::<1>(v);
        let s = _mm_add_epi64(lo, hi);
        _mm_cvtsi128_si64(s).wrapping_add(_mm_extract_epi64::<1>(s))
    }

    /// Per-byte popcount of a 256-bit vector (Mula nibble LUT), summed
    /// into the four i64 lanes by `_mm256_sad_epu8`.
    #[target_feature(enable = "avx2")]
    unsafe fn popcnt_lanes_i64(v: __m256i) -> __m256i {
        let lut = _mm256_setr_epi8(
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4, //
            0, 1, 1, 2, 1, 2, 2, 3, 1, 2, 2, 3, 2, 3, 3, 4,
        );
        let low = _mm256_set1_epi8(0x0f);
        let lo = _mm256_shuffle_epi8(lut, _mm256_and_si256(v, low));
        let hi = _mm256_shuffle_epi8(lut, _mm256_and_si256(_mm256_srli_epi16::<4>(v), low));
        _mm256_sad_epu8(_mm256_add_epi8(lo, hi), _mm256_setzero_si256())
    }

    #[target_feature(enable = "avx2")]
    unsafe fn popcount_avx2(words: &[u64]) -> u64 {
        let mut acc = _mm256_setzero_si256();
        for c in words.chunks_exact(4) {
            let v = _mm256_loadu_si256(c.as_ptr().cast());
            acc = _mm256_add_epi64(acc, popcnt_lanes_i64(v));
        }
        let mut total = hsum_i64(acc) as u64;
        for &w in words.chunks_exact(4).remainder() {
            total += w.count_ones() as u64;
        }
        total
    }

    #[target_feature(enable = "avx2")]
    unsafe fn count_and_avx2(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = _mm256_setzero_si256();
        for (ca, cb) in a.chunks_exact(4).zip(b.chunks_exact(4)) {
            let va = _mm256_loadu_si256(ca.as_ptr().cast());
            let vb = _mm256_loadu_si256(cb.as_ptr().cast());
            acc = _mm256_add_epi64(acc, popcnt_lanes_i64(_mm256_and_si256(va, vb)));
        }
        let mut total = hsum_i64(acc) as u64;
        let ra = a.chunks_exact(4).remainder();
        let rb = b.chunks_exact(4).remainder();
        for (x, y) in ra.iter().zip(rb) {
            total += (x & y).count_ones() as u64;
        }
        total
    }

    #[target_feature(enable = "avx2")]
    unsafe fn and_avx2(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut k = 0usize;
        while k + 4 <= n {
            let a = _mm256_loadu_si256(dst.as_ptr().add(k).cast());
            let b = _mm256_loadu_si256(src.as_ptr().add(k).cast());
            _mm256_storeu_si256(dst.as_mut_ptr().add(k).cast(), _mm256_and_si256(a, b));
            k += 4;
        }
        while k < n {
            dst[k] &= src[k];
            k += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn or_avx2(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut k = 0usize;
        while k + 4 <= n {
            let a = _mm256_loadu_si256(dst.as_ptr().add(k).cast());
            let b = _mm256_loadu_si256(src.as_ptr().add(k).cast());
            _mm256_storeu_si256(dst.as_mut_ptr().add(k).cast(), _mm256_or_si256(a, b));
            k += 4;
        }
        while k < n {
            dst[k] |= src[k];
            k += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn xor_avx2(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut k = 0usize;
        while k + 4 <= n {
            let a = _mm256_loadu_si256(dst.as_ptr().add(k).cast());
            let b = _mm256_loadu_si256(src.as_ptr().add(k).cast());
            _mm256_storeu_si256(dst.as_mut_ptr().add(k).cast(), _mm256_xor_si256(a, b));
            k += 4;
        }
        while k < n {
            dst[k] ^= src[k];
            k += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn funnel_shr_avx2(src: &[u64], off: u32, dst: &mut [u64]) {
        debug_assert!((1..64u32).contains(&off));
        debug_assert!(src.len() >= dst.len());
        let n = dst.len();
        let rsh = _mm_cvtsi32_si128(off as i32);
        let lsh = _mm_cvtsi32_si128(64 - off as i32);
        let mut k = 0usize;
        // The vector body reads src[k+1..k+5], so it stops one short
        // of the end; the scalar tail supplies the implicit zero high
        // word past src.len().
        while k + 4 <= n && k + 5 <= src.len() {
            let v0 = _mm256_loadu_si256(src.as_ptr().add(k).cast());
            let v1 = _mm256_loadu_si256(src.as_ptr().add(k + 1).cast());
            let w = _mm256_or_si256(_mm256_srl_epi64(v0, rsh), _mm256_sll_epi64(v1, lsh));
            _mm256_storeu_si256(dst.as_mut_ptr().add(k).cast(), w);
            k += 4;
        }
        while k < n {
            let lo = src[k] >> off;
            let hi = src.get(k + 1).copied().unwrap_or(0) << (64 - off);
            dst[k] = lo | hi;
            k += 1;
        }
    }

    #[target_feature(enable = "bmi2")]
    unsafe fn compress_even_bmi2(w: u64) -> u64 {
        _pext_u64(w, 0x5555_5555_5555_5555)
    }

    /// The eight exact i32×i32→i64 products of two 8-lane vectors,
    /// folded pairwise into four i64 lanes: `_mm256_mul_epi32`
    /// sign-extends the low dword of each qword, so the even lanes
    /// multiply directly and the odd lanes after a 32-bit lane shift.
    #[target_feature(enable = "avx2")]
    unsafe fn mul_i32_pairs(a: __m256i, b: __m256i) -> __m256i {
        let even = _mm256_mul_epi32(a, b);
        let odd = _mm256_mul_epi32(_mm256_srli_epi64::<32>(a), _mm256_srli_epi64::<32>(b));
        _mm256_add_epi64(even, odd)
    }

    #[target_feature(enable = "avx2")]
    unsafe fn i8_dot_avx2(w: &[i8], x: &[i32]) -> i64 {
        debug_assert_eq!(w.len(), x.len());
        let k = w.len();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= k {
            let w32 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(w.as_ptr().add(i).cast()));
            let xv = _mm256_loadu_si256(x.as_ptr().add(i).cast());
            acc = _mm256_add_epi64(acc, mul_i32_pairs(w32, xv));
            i += 8;
        }
        let mut s = hsum_i64(acc);
        while i < k {
            s += x[i] as i64 * w[i] as i64;
            i += 1;
        }
        s
    }

    #[target_feature(enable = "avx2")]
    unsafe fn i8_dot4_avx2(w: &[i8], x: [&[i32]; 4]) -> [i64; 4] {
        let [x0, x1, x2, x3] = x;
        let k = w.len();
        debug_assert!(x0.len() == k && x1.len() == k && x2.len() == k && x3.len() == k);
        let mut a0 = _mm256_setzero_si256();
        let mut a1 = _mm256_setzero_si256();
        let mut a2 = _mm256_setzero_si256();
        let mut a3 = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= k {
            // One widened weight chunk feeds all four accumulators —
            // the same reuse lever as the scalar microkernel.
            let w32 = _mm256_cvtepi8_epi32(_mm_loadl_epi64(w.as_ptr().add(i).cast()));
            let v0 = _mm256_loadu_si256(x0.as_ptr().add(i).cast());
            let v1 = _mm256_loadu_si256(x1.as_ptr().add(i).cast());
            let v2 = _mm256_loadu_si256(x2.as_ptr().add(i).cast());
            let v3 = _mm256_loadu_si256(x3.as_ptr().add(i).cast());
            a0 = _mm256_add_epi64(a0, mul_i32_pairs(w32, v0));
            a1 = _mm256_add_epi64(a1, mul_i32_pairs(w32, v1));
            a2 = _mm256_add_epi64(a2, mul_i32_pairs(w32, v2));
            a3 = _mm256_add_epi64(a3, mul_i32_pairs(w32, v3));
            i += 8;
        }
        let mut out = [hsum_i64(a0), hsum_i64(a1), hsum_i64(a2), hsum_i64(a3)];
        while i < k {
            let wl = w[i] as i64;
            out[0] += x0[i] as i64 * wl;
            out[1] += x1[i] as i64 * wl;
            out[2] += x2[i] as i64 * wl;
            out[3] += x3[i] as i64 * wl;
            i += 1;
        }
        out
    }

    /// `Σ x[idx]` over one index list via 8-wide hardware gathers.
    /// Caller guarantees every index `< x.len() <= i32::MAX` (see the
    /// entry fns and `Dispatch::gather_sub_i32`).
    #[target_feature(enable = "avx2")]
    unsafe fn gather_sum_i32(idx: &[u32], x: &[i32]) -> i64 {
        let base = x.as_ptr();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= idx.len() {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(i).cast());
            let g = _mm256_i32gather_epi32::<4>(base, iv);
            let lo = _mm256_cvtepi32_epi64(_mm256_castsi256_si128(g));
            let hi = _mm256_cvtepi32_epi64(_mm256_extracti128_si256::<1>(g));
            acc = _mm256_add_epi64(acc, _mm256_add_epi64(lo, hi));
            i += 8;
        }
        let mut s = hsum_i64(acc);
        for &j in &idx[i..] {
            s += x[j as usize] as i64;
        }
        s
    }

    /// `Σ w[idx[j]] · vals[j]` with i8 weights fetched through 8-wide
    /// byte gathers (`scale = 1`, low byte of each 4-byte load, sign-
    /// extended by a 24-bit shift pair). A 4-byte gather at index `j`
    /// reads `w[j..j+4]`, so the vector loop only runs while the
    /// chunk's **last** index — the largest, since the contract says
    /// ascending — leaves 4 readable bytes; every later chunk's
    /// indices are at least as large, so one failed witness ends the
    /// vector phase and the scalar tail finishes exactly.
    #[target_feature(enable = "avx2")]
    unsafe fn sparse_i8_dot_avx2(w: &[i8], vals: &[i32], idx: &[u32]) -> i64 {
        debug_assert_eq!(vals.len(), idx.len());
        let base = w.as_ptr().cast::<i32>();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 8 <= idx.len() && idx[i + 7] as usize + 4 <= w.len() {
            let iv = _mm256_loadu_si256(idx.as_ptr().add(i).cast());
            let g = _mm256_i32gather_epi32::<1>(base, iv);
            // Sign-extend the gathered low byte into the full i32 lane.
            let wv = _mm256_srai_epi32::<24>(_mm256_slli_epi32::<24>(g));
            let vv = _mm256_loadu_si256(vals.as_ptr().add(i).cast());
            acc = _mm256_add_epi64(acc, mul_i32_pairs(wv, vv));
            i += 8;
        }
        let mut s = hsum_i64(acc);
        while i < idx.len() {
            s += w[idx[i] as usize] as i64 * vals[i] as i64;
            i += 1;
        }
        s
    }

    /// `Σ x[idx]` over i64 values via 4-wide hardware gathers; same
    /// contract as [`gather_sum_i32`].
    #[target_feature(enable = "avx2")]
    unsafe fn gather_sum_i64(idx: &[u32], x: &[i64]) -> i64 {
        let base = x.as_ptr();
        let mut acc = _mm256_setzero_si256();
        let mut i = 0usize;
        while i + 4 <= idx.len() {
            let iv = _mm_loadu_si128(idx.as_ptr().add(i).cast());
            acc = _mm256_add_epi64(acc, _mm256_i32gather_epi64::<8>(base, iv));
            i += 4;
        }
        let mut s = hsum_i64(acc);
        for &j in &idx[i..] {
            s += x[j as usize];
        }
        s
    }
}

// ---------------------------------------------------------------------
// NEON kernels (aarch64). NEON is baseline on aarch64, so the table
// installs unconditionally; gathers and the even-bit compress have no
// NEON win and stay on the scalar kernels.
// ---------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::*;
    use std::arch::aarch64::*;

    pub(super) fn table() -> Dispatch {
        Dispatch {
            level: Level::Neon,
            popcount: popcount_entry,
            count_and: count_and_entry,
            and: and_entry,
            or: or_entry,
            xor: xor_entry,
            funnel_shr: funnel_shr_entry,
            compress_even: compress_even_scalar,
            i8_dot: i8_dot_entry,
            i8_dot4: i8_dot4_entry,
            gather_sub_i32: gather_sub_i32_scalar,
            gather_sub_i64: gather_sub_i64_scalar,
            sparse_i8_dot: sparse_i8_dot_scalar,
        }
    }

    fn popcount_entry(words: &[u64]) -> u64 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { popcount_neon(words) }
    }

    fn count_and_entry(a: &[u64], b: &[u64]) -> u64 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { count_and_neon(a, b) }
    }

    fn and_entry(dst: &mut [u64], src: &[u64]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { and_neon(dst, src) }
    }

    fn or_entry(dst: &mut [u64], src: &[u64]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { or_neon(dst, src) }
    }

    fn xor_entry(dst: &mut [u64], src: &[u64]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { xor_neon(dst, src) }
    }

    fn funnel_shr_entry(src: &[u64], off: u32, dst: &mut [u64]) {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { funnel_shr_neon(src, off, dst) }
    }

    fn i8_dot_entry(w: &[i8], x: &[i32]) -> i64 {
        // SAFETY: NEON is baseline on aarch64.
        unsafe { i8_dot_neon(w, x) }
    }

    fn i8_dot4_entry(w: &[i8], x: [&[i32]; 4]) -> [i64; 4] {
        let [x0, x1, x2, x3] = x;
        [i8_dot_entry(w, x0), i8_dot_entry(w, x1), i8_dot_entry(w, x2), i8_dot_entry(w, x3)]
    }

    #[target_feature(enable = "neon")]
    unsafe fn popcount_neon(words: &[u64]) -> u64 {
        let mut acc = vdupq_n_u64(0);
        for c in words.chunks_exact(2) {
            let v = vreinterpretq_u8_u64(vld1q_u64(c.as_ptr()));
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(vcntq_u8(v)))));
        }
        let mut total = vaddvq_u64(acc);
        for &w in words.chunks_exact(2).remainder() {
            total += w.count_ones() as u64;
        }
        total
    }

    #[target_feature(enable = "neon")]
    unsafe fn count_and_neon(a: &[u64], b: &[u64]) -> u64 {
        debug_assert_eq!(a.len(), b.len());
        let mut acc = vdupq_n_u64(0);
        for (ca, cb) in a.chunks_exact(2).zip(b.chunks_exact(2)) {
            let v = vandq_u64(vld1q_u64(ca.as_ptr()), vld1q_u64(cb.as_ptr()));
            let bytes = vcntq_u8(vreinterpretq_u8_u64(v));
            acc = vaddq_u64(acc, vpaddlq_u32(vpaddlq_u16(vpaddlq_u8(bytes))));
        }
        let mut total = vaddvq_u64(acc);
        let ra = a.chunks_exact(2).remainder();
        let rb = b.chunks_exact(2).remainder();
        for (x, y) in ra.iter().zip(rb) {
            total += (x & y).count_ones() as u64;
        }
        total
    }

    #[target_feature(enable = "neon")]
    unsafe fn and_neon(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut k = 0usize;
        while k + 2 <= n {
            let a = vld1q_u64(dst.as_ptr().add(k));
            let b = vld1q_u64(src.as_ptr().add(k));
            vst1q_u64(dst.as_mut_ptr().add(k), vandq_u64(a, b));
            k += 2;
        }
        if k < n {
            dst[k] &= src[k];
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn or_neon(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut k = 0usize;
        while k + 2 <= n {
            let a = vld1q_u64(dst.as_ptr().add(k));
            let b = vld1q_u64(src.as_ptr().add(k));
            vst1q_u64(dst.as_mut_ptr().add(k), vorrq_u64(a, b));
            k += 2;
        }
        if k < n {
            dst[k] |= src[k];
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn xor_neon(dst: &mut [u64], src: &[u64]) {
        debug_assert_eq!(dst.len(), src.len());
        let n = dst.len();
        let mut k = 0usize;
        while k + 2 <= n {
            let a = vld1q_u64(dst.as_ptr().add(k));
            let b = vld1q_u64(src.as_ptr().add(k));
            vst1q_u64(dst.as_mut_ptr().add(k), veorq_u64(a, b));
            k += 2;
        }
        if k < n {
            dst[k] ^= src[k];
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn funnel_shr_neon(src: &[u64], off: u32, dst: &mut [u64]) {
        debug_assert!((1..64u32).contains(&off));
        debug_assert!(src.len() >= dst.len());
        let n = dst.len();
        // NEON shifts left by the per-lane signed count; negative
        // counts shift right.
        let rsh = vdupq_n_s64(-(off as i64));
        let lsh = vdupq_n_s64(64 - off as i64);
        let mut k = 0usize;
        while k + 2 <= n && k + 3 <= src.len() {
            let v0 = vld1q_u64(src.as_ptr().add(k));
            let v1 = vld1q_u64(src.as_ptr().add(k + 1));
            let w = vorrq_u64(vshlq_u64(v0, rsh), vshlq_u64(v1, lsh));
            vst1q_u64(dst.as_mut_ptr().add(k), w);
            k += 2;
        }
        while k < n {
            let lo = src[k] >> off;
            let hi = src.get(k + 1).copied().unwrap_or(0) << (64 - off);
            dst[k] = lo | hi;
            k += 1;
        }
    }

    #[target_feature(enable = "neon")]
    unsafe fn i8_dot_neon(w: &[i8], x: &[i32]) -> i64 {
        debug_assert_eq!(w.len(), x.len());
        let k = w.len();
        let mut acc = vdupq_n_s64(0);
        let mut i = 0usize;
        while i + 8 <= k {
            let w16 = vmovl_s8(vld1_s8(w.as_ptr().add(i)));
            let wlo = vmovl_s16(vget_low_s16(w16));
            let whi = vmovl_s16(vget_high_s16(w16));
            let xlo = vld1q_s32(x.as_ptr().add(i));
            let xhi = vld1q_s32(x.as_ptr().add(i + 4));
            acc = vaddq_s64(acc, vmull_s32(vget_low_s32(wlo), vget_low_s32(xlo)));
            acc = vaddq_s64(acc, vmull_s32(vget_high_s32(wlo), vget_high_s32(xlo)));
            acc = vaddq_s64(acc, vmull_s32(vget_low_s32(whi), vget_low_s32(xhi)));
            acc = vaddq_s64(acc, vmull_s32(vget_high_s32(whi), vget_high_s32(xhi)));
            i += 8;
        }
        let mut s = vaddvq_s64(acc);
        while i < k {
            s += x[i] as i64 * w[i] as i64;
            i += 1;
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn words(rng: &mut Rng, n: usize) -> Vec<u64> {
        (0..n).map(|_| rng.next_u64()).collect()
    }

    #[test]
    fn level_names() {
        assert_eq!(Level::Scalar.name(), "scalar");
        assert_eq!(Level::Avx2.name(), "avx2");
        assert_eq!(Level::Neon.name(), "neon");
        assert_eq!(Dispatch::scalar().level(), Level::Scalar);
    }

    #[test]
    fn compress_even_ground_truth() {
        // Bit i of the output must be bit 2i of the input, per table.
        for (w, want) in [
            (0u64, 0u64),
            (0b01, 0b1),
            (0b10, 0b0),
            (0b0101, 0b11),
            (0x5555_5555_5555_5555, 0xffff_ffff),
            (u64::MAX, 0xffff_ffff),
            (0x0f0f, 0b0011_0011),
        ] {
            assert_eq!(compress_even_scalar(w), want, "w={w:#x}");
        }
        // Active arm (pext on BMI2 hardware) agrees everywhere.
        let mut rng = Rng::new(5);
        for _ in 0..200 {
            let w = rng.next_u64();
            assert_eq!(Dispatch::active().compress_even(w), compress_even_scalar(w));
        }
    }

    #[test]
    fn active_matches_scalar_on_word_kernels() {
        let mut rng = Rng::new(77);
        let a5 = Dispatch::active();
        let sc = Dispatch::scalar();
        for n in [0usize, 1, 2, 3, 4, 5, 7, 8, 9, 16, 33, 144] {
            let a = words(&mut rng, n);
            let b = words(&mut rng, n);
            assert_eq!(a5.popcount(&a), sc.popcount(&a), "popcount n={n}");
            assert_eq!(a5.count_and(&a, &b), sc.count_and(&a, &b), "count_and n={n}");
            for off in [1u32, 7, 31, 63] {
                let mut d1 = vec![0u64; n];
                let mut d2 = vec![0u64; n];
                a5.funnel_shr(&a, off, &mut d1);
                sc.funnel_shr(&a, off, &mut d2);
                assert_eq!(d1, d2, "funnel n={n} off={off}");
            }
            let (mut x1, mut x2) = (a.clone(), a.clone());
            a5.and_words(&mut x1, &b);
            sc.and_words(&mut x2, &b);
            assert_eq!(x1, x2, "and n={n}");
            let (mut o1, mut o2) = (a.clone(), a.clone());
            a5.or_words(&mut o1, &b);
            sc.or_words(&mut o2, &b);
            assert_eq!(o1, o2, "or n={n}");
            let (mut e1, mut e2) = (a.clone(), a.clone());
            a5.xor_words(&mut e1, &b);
            sc.xor_words(&mut e2, &b);
            assert_eq!(e1, e2, "xor n={n}");
        }
    }

    #[test]
    fn active_matches_scalar_on_dot_kernels() {
        let mut rng = Rng::new(91);
        let a5 = Dispatch::active();
        let sc = Dispatch::scalar();
        for k in [0usize, 1, 3, 7, 8, 9, 15, 16, 17, 40, 129] {
            let w: Vec<i8> = (0..k).map(|_| rng.gen_range_i64(-128, 127) as i8).collect();
            let cols: Vec<Vec<i32>> = (0..4)
                .map(|_| (0..k).map(|_| rng.gen_range_i64(-1000, 1000) as i32).collect())
                .collect();
            let x = [&cols[0][..], &cols[1][..], &cols[2][..], &cols[3][..]];
            assert_eq!(a5.i8_dot(&w, x[0]), sc.i8_dot(&w, x[0]), "i8_dot k={k}");
            assert_eq!(a5.i8_dot4(&w, x), sc.i8_dot4(&w, x), "i8_dot4 k={k}");
            // Gather lists: every index < k, ragged lengths.
            if k > 0 {
                let plus: Vec<u32> =
                    (0..rng.gen_index(2 * k + 1)).map(|_| rng.gen_index(k) as u32).collect();
                let minus: Vec<u32> =
                    (0..rng.gen_index(2 * k + 1)).map(|_| rng.gen_index(k) as u32).collect();
                let x64: Vec<i64> = x[0].iter().map(|&v| v as i64).collect();
                // SAFETY: indices drawn from 0..k above.
                unsafe {
                    assert_eq!(
                        a5.gather_sub_i32(&plus, &minus, x[0]),
                        sc.gather_sub_i32(&plus, &minus, x[0]),
                        "gather_sub_i32 k={k}"
                    );
                    assert_eq!(
                        a5.gather_sub_i64(&plus, &minus, &x64),
                        sc.gather_sub_i64(&plus, &minus, &x64),
                        "gather_sub_i64 k={k}"
                    );
                }
                // Sparse dot: ascending index list (dupes allowed by
                // the contract), always ending at k-1 so the vector
                // arm's tail-of-row bounds witness is exercised.
                let mut sidx: Vec<u32> =
                    (0..rng.gen_index(2 * k)).map(|_| rng.gen_index(k) as u32).collect();
                sidx.push(k as u32 - 1);
                sidx.sort_unstable();
                let svals: Vec<i32> =
                    (0..sidx.len()).map(|_| rng.gen_range_i64(-1000, 1000) as i32).collect();
                // SAFETY: sorted above, every index < k.
                unsafe {
                    assert_eq!(
                        a5.sparse_i8_dot(&w, &svals, &sidx),
                        sc.sparse_i8_dot(&w, &svals, &sidx),
                        "sparse_i8_dot k={k}"
                    );
                }
            }
        }
    }
}
