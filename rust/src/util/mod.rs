//! In-tree utilities replacing crates unavailable in the offline build
//! environment: a deterministic PRNG ([`rng`]), a micro-benchmark
//! harness ([`bench`]), a tiny property-testing helper ([`prop`]) and
//! the runtime-dispatched SIMD kernel table ([`simd`]).

pub mod bench;
pub mod prop;
pub mod rng;
pub mod simd;

pub use rng::Rng;

/// Simple mean/variance accumulator (Welford).
#[derive(Clone, Copy, Debug, Default)]
pub struct Stats {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Stats {
    /// Empty accumulator.
    pub fn new() -> Self {
        Self { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    /// Add a sample.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Sample count.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Mean (0 when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.mean }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / self.n as f64 }
    }

    /// Standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Minimum sample.
    pub fn min(&self) -> f64 {
        self.min
    }

    /// Maximum sample.
    pub fn max(&self) -> f64 {
        self.max
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_basic() {
        let mut s = Stats::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            s.push(x);
        }
        assert_eq!(s.count(), 4);
        assert!((s.mean() - 2.5).abs() < 1e-12);
        assert!((s.variance() - 1.25).abs() < 1e-12);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 4.0);
    }
}
