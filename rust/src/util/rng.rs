//! Deterministic PRNG (xoshiro256++ seeded via SplitMix64).
//!
//! The offline build has no `rand` crate; every stochastic element of
//! the simulator (SNG seeds, fault injection, synthetic datasets, MSE
//! sampling) draws from this generator, which makes *every experiment in
//! the repository bit-reproducible from its seed*.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed deterministically (SplitMix64 expansion; any seed is fine,
    /// including 0).
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let out = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        out
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Bernoulli with probability `p`.
    pub fn gen_bool(&mut self, p: f64) -> bool {
        if p <= 0.0 {
            return false;
        }
        if p >= 1.0 {
            return true;
        }
        self.f64() < p
    }

    /// Uniform integer in `[lo, hi]` (inclusive).
    pub fn gen_range_i64(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        let span = (hi - lo) as u64 + 1;
        lo + (self.next_u64() % span) as i64
    }

    /// Uniform usize in `[0, n)`.
    pub fn gen_index(&mut self, n: usize) -> usize {
        assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box–Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.gen_index(i + 1);
            v.swap(i, j);
        }
    }

    /// Fork a derived generator (for parallel streams).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn gen_bool_statistics() {
        let mut r = Rng::new(9);
        let hits = (0..10000).filter(|_| r.gen_bool(0.3)).count();
        assert!((hits as f64 / 10000.0 - 0.3).abs() < 0.02);
        assert!(!r.gen_bool(0.0));
        assert!(r.gen_bool(1.0));
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        let mut seen = [false; 5];
        for _ in 0..200 {
            let v = r.gen_range_i64(-2, 2);
            assert!((-2..=2).contains(&v));
            seen[(v + 2) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(13);
        let n = 20000;
        let mut mean = 0.0;
        let mut var = 0.0;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        for &x in &xs {
            mean += x;
        }
        mean /= n as f64;
        for &x in &xs {
            var += (x - mean).powi(2);
        }
        var /= n as f64;
        assert!(mean.abs() < 0.05, "mean={mean}");
        assert!((var - 1.0).abs() < 0.1, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(17);
        let mut v: Vec<u32> = (0..50).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }
}
