//! Tiny property-testing helper (proptest is unavailable offline).
//!
//! [`check`] runs a property over `n` random cases drawn from a
//! generator closure; on failure it greedily shrinks the case via the
//! provided `shrink` closure before panicking with the minimal
//! counterexample. Deterministic: every failure reproduces from the
//! seed embedded in the panic message.

use super::rng::Rng;

/// Run `prop` over `n` cases from `gen`. Panics on the first failing
/// case after shrinking.
pub fn check<T: std::fmt::Debug + Clone>(
    seed: u64,
    n: usize,
    mut gen: impl FnMut(&mut Rng) -> T,
    mut shrink: impl FnMut(&T) -> Vec<T>,
    mut prop: impl FnMut(&T) -> bool,
) {
    let mut rng = Rng::new(seed);
    for i in 0..n {
        let case = gen(&mut rng);
        if !prop(&case) {
            // Greedy shrink.
            let mut cur = case;
            'outer: loop {
                for cand in shrink(&cur) {
                    if !prop(&cand) {
                        cur = cand;
                        continue 'outer;
                    }
                }
                break;
            }
            panic!(
                "property failed (seed={seed}, case #{i}); minimal counterexample: {cur:?}"
            );
        }
    }
}

/// Run a property with no shrinking.
pub fn check_simple<T: std::fmt::Debug + Clone>(
    seed: u64,
    n: usize,
    gen: impl FnMut(&mut Rng) -> T,
    prop: impl FnMut(&T) -> bool,
) {
    check(seed, n, gen, |_| Vec::new(), prop);
}

/// Shrinker for vectors: halves, removes one element, or simplifies one
/// element with `elem_shrink`.
pub fn shrink_vec<T: Clone>(
    v: &[T],
    elem_shrink: impl Fn(&T) -> Vec<T>,
) -> Vec<Vec<T>> {
    let mut out = Vec::new();
    if v.len() > 1 {
        out.push(v[..v.len() / 2].to_vec());
        out.push(v[v.len() / 2..].to_vec());
        for i in 0..v.len() {
            let mut w = v.to_vec();
            w.remove(i);
            out.push(w);
        }
    }
    for i in 0..v.len() {
        for e in elem_shrink(&v[i]) {
            let mut w = v.to_vec();
            w[i] = e;
            out.push(w);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_passes() {
        check_simple(1, 100, |r| r.gen_range_i64(0, 100), |&x| x >= 0);
    }

    #[test]
    #[should_panic(expected = "minimal counterexample")]
    fn failing_property_shrinks() {
        check(
            2,
            100,
            |r| r.gen_range_i64(0, 1000),
            |&x| if x > 0 { vec![x / 2, x - 1] } else { vec![] },
            |&x| x < 500,
        );
    }

    #[test]
    fn shrink_vec_variants() {
        let v = vec![3, 4];
        let shrunk = shrink_vec(&v, |&x| if x > 0 { vec![0] } else { vec![] });
        assert!(shrunk.contains(&vec![3]));
        assert!(shrunk.contains(&vec![4]));
        assert!(shrunk.contains(&vec![0, 4]));
    }
}
