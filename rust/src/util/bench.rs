//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `harness = false` bench binaries under
//! `rust/benches/`, each of which uses [`Bench`] to time closures with
//! warm-up, repetition and simple statistics, printing one aligned row
//! per case. Output format:
//!
//! ```text
//! name                                  median        mean      throughput
//! bsn/gate_level/4608            1.234 ms     1.240 ms     3.73 Mbit/s
//! ```

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark runner.
pub struct Bench {
    /// Minimum measurement time per case.
    pub min_time: Duration,
    /// Maximum iterations per case.
    pub max_iters: u64,
    /// Warm-up iterations.
    pub warmup: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_time: Duration::from_millis(300), max_iters: 100_000, warmup: 3 }
    }
}

/// A single measured result.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Median per-iteration time in seconds.
    pub median_s: f64,
    /// Mean per-iteration time in seconds.
    pub mean_s: f64,
    /// Iterations measured.
    pub iters: u64,
}

impl Bench {
    /// Quick-running configuration for CI / tests.
    pub fn quick() -> Self {
        Self { min_time: Duration::from_millis(50), max_iters: 1000, warmup: 1 }
    }

    /// Time `f`, printing a row labelled `name`. `work_items` (if
    /// non-zero) adds a throughput column in items/s.
    pub fn run<T>(&self, name: &str, work_items: u64, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.min_time && iters < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let median_s = samples[samples.len() / 2];
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        let m = Measurement { median_s, mean_s, iters };
        let tp = if work_items > 0 {
            format!("  {}/s", human(work_items as f64 / median_s))
        } else {
            String::new()
        };
        println!(
            "{name:<48} {:>12}  {:>12}  x{iters}{tp}",
            human_time(median_s),
            human_time(mean_s),
        );
        m
    }
}

/// Human-readable seconds.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-readable count.
pub fn human(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let m = b.run("test/noop", 0, || 1 + 1);
        assert!(m.iters >= 1);
        assert!(m.median_s >= 0.0);
    }

    #[test]
    fn human_units() {
        assert_eq!(human_time(2.0), "2.000 s");
        assert_eq!(human_time(2e-3), "2.000 ms");
        assert_eq!(human_time(2e-6), "2.000 us");
        assert!(human(5e6).starts_with("5.00 M"));
    }
}
