//! Minimal benchmark harness (criterion is unavailable offline).
//!
//! `cargo bench` runs the `harness = false` bench binaries under
//! `rust/benches/`, each of which uses [`Bench`] to time closures with
//! warm-up, repetition and simple statistics, printing one aligned row
//! per case. Output format:
//!
//! ```text
//! name                                  median        mean      throughput
//! bsn/gate_level/4608            1.234 ms     1.240 ms     3.73 Mbit/s
//! ```
//!
//! For machine-readable output, collect results in a [`JsonReport`]
//! and write them to disk (`make bench-json` → `BENCH_sc.json`), so
//! the perf trajectory is tracked across PRs instead of scrolling away
//! in CI logs.

use std::hint::black_box;
use std::time::{Duration, Instant};

/// One benchmark runner.
pub struct Bench {
    /// Minimum measurement time per case.
    pub min_time: Duration,
    /// Maximum iterations per case.
    pub max_iters: u64,
    /// Warm-up iterations.
    pub warmup: u64,
}

impl Default for Bench {
    fn default() -> Self {
        Self { min_time: Duration::from_millis(300), max_iters: 100_000, warmup: 3 }
    }
}

/// A single measured result.
#[derive(Clone, Copy, Debug)]
pub struct Measurement {
    /// Median per-iteration time in seconds.
    pub median_s: f64,
    /// Mean per-iteration time in seconds.
    pub mean_s: f64,
    /// Iterations measured.
    pub iters: u64,
}

impl Bench {
    /// Quick-running configuration for CI / tests.
    pub fn quick() -> Self {
        Self { min_time: Duration::from_millis(50), max_iters: 1000, warmup: 1 }
    }

    /// Time `f`, printing a row labelled `name`. `work_items` (if
    /// non-zero) adds a throughput column in items/s.
    pub fn run<T>(&self, name: &str, work_items: u64, mut f: impl FnMut() -> T) -> Measurement {
        for _ in 0..self.warmup {
            black_box(f());
        }
        let mut samples: Vec<f64> = Vec::new();
        let start = Instant::now();
        let mut iters = 0u64;
        while start.elapsed() < self.min_time && iters < self.max_iters {
            let t0 = Instant::now();
            black_box(f());
            samples.push(t0.elapsed().as_secs_f64());
            iters += 1;
        }
        samples.sort_by(|a, b| a.total_cmp(b));
        let median_s = samples[samples.len() / 2];
        let mean_s = samples.iter().sum::<f64>() / samples.len() as f64;
        let m = Measurement { median_s, mean_s, iters };
        let tp = if work_items > 0 {
            format!("  {}/s", human(work_items as f64 / median_s))
        } else {
            String::new()
        };
        println!(
            "{name:<48} {:>12}  {:>12}  x{iters}{tp}",
            human_time(median_s),
            human_time(mean_s),
        );
        m
    }
}

/// One entry of a [`JsonReport`].
enum JsonEntry {
    /// A timed case (optionally with items/s throughput).
    Measured { name: String, m: Measurement, items_per_s: Option<f64> },
    /// A free-form scalar (e.g. a pool sweep's req/s).
    Scalar { name: String, value: f64, unit: String },
}

/// Machine-readable benchmark collector. Serializes to a small
/// hand-rolled JSON document (no serde offline):
///
/// ```json
/// {
///   "bench": "sc_serve",
///   "entries": [
///     {"name": "engine/scnet_forward", "median_s": 1.2e-3, "mean_s": 1.3e-3,
///      "iters": 250, "items_per_s": 833.0},
///     {"name": "pool/sc/workers=4", "value": 3100.0, "unit": "req/s"}
///   ]
/// }
/// ```
pub struct JsonReport {
    bench: String,
    entries: Vec<JsonEntry>,
}

impl JsonReport {
    /// New empty report for a named bench binary.
    pub fn new(bench: &str) -> Self {
        Self { bench: bench.to_string(), entries: Vec::new() }
    }

    /// Record a timed case. `work_items` > 0 adds an `items_per_s`
    /// field computed from the median.
    pub fn add(&mut self, name: &str, m: &Measurement, work_items: u64) {
        let items_per_s =
            (work_items > 0 && m.median_s > 0.0).then(|| work_items as f64 / m.median_s);
        self.entries.push(JsonEntry::Measured { name: name.to_string(), m: *m, items_per_s });
    }

    /// Record a free-form scalar (e.g. sustained req/s of a pool sweep
    /// point).
    pub fn add_scalar(&mut self, name: &str, value: f64, unit: &str) {
        self.entries.push(JsonEntry::Scalar {
            name: name.to_string(),
            value,
            unit: unit.to_string(),
        });
    }

    /// Number of recorded entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// True when nothing has been recorded.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Serialize to JSON text.
    pub fn to_json(&self) -> String {
        let mut s = String::new();
        s.push_str("{\n");
        s.push_str(&format!("  \"bench\": \"{}\",\n", escape(&self.bench)));
        s.push_str("  \"entries\": [\n");
        for (i, e) in self.entries.iter().enumerate() {
            let row = match e {
                JsonEntry::Measured { name, m, items_per_s } => {
                    let tail = items_per_s
                        .map(|t| format!(", \"items_per_s\": {t}"))
                        .unwrap_or_default();
                    format!(
                        "    {{\"name\": \"{}\", \"median_s\": {}, \"mean_s\": {}, \"iters\": {}{tail}}}",
                        escape(name),
                        m.median_s,
                        m.mean_s,
                        m.iters
                    )
                }
                JsonEntry::Scalar { name, value, unit } => format!(
                    "    {{\"name\": \"{}\", \"value\": {value}, \"unit\": \"{}\"}}",
                    escape(name),
                    escape(unit)
                ),
            };
            s.push_str(&row);
            s.push_str(if i + 1 < self.entries.len() { ",\n" } else { "\n" });
        }
        s.push_str("  ]\n}\n");
        s
    }

    /// Write the JSON document to `path`.
    pub fn write(&self, path: &str) -> std::io::Result<()> {
        std::fs::write(path, self.to_json())
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Human-readable seconds.
pub fn human_time(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.3} s")
    } else if s >= 1e-3 {
        format!("{:.3} ms", s * 1e3)
    } else if s >= 1e-6 {
        format!("{:.3} us", s * 1e6)
    } else {
        format!("{:.1} ns", s * 1e9)
    }
}

/// Human-readable count.
pub fn human(x: f64) -> String {
    if x >= 1e9 {
        format!("{:.2} G", x / 1e9)
    } else if x >= 1e6 {
        format!("{:.2} M", x / 1e6)
    } else if x >= 1e3 {
        format!("{:.2} k", x / 1e3)
    } else {
        format!("{x:.1}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something() {
        let b = Bench::quick();
        let m = b.run("test/noop", 0, || 1 + 1);
        assert!(m.iters >= 1);
        assert!(m.median_s >= 0.0);
    }

    #[test]
    fn human_units() {
        assert_eq!(human_time(2.0), "2.000 s");
        assert_eq!(human_time(2e-3), "2.000 ms");
        assert_eq!(human_time(2e-6), "2.000 us");
        assert!(human(5e6).starts_with("5.00 M"));
    }

    #[test]
    fn json_report_shape() {
        let mut r = JsonReport::new("sc_serve");
        assert!(r.is_empty());
        r.add("engine/forward", &Measurement { median_s: 0.002, mean_s: 0.0021, iters: 10 }, 1);
        r.add("engine/no_items", &Measurement { median_s: 0.5, mean_s: 0.5, iters: 3 }, 0);
        r.add_scalar("pool/sc/workers=4", 3100.5, "req/s");
        assert_eq!(r.len(), 3);
        let json = r.to_json();
        assert!(json.starts_with('{') && json.trim_end().ends_with('}'), "{json}");
        assert!(json.contains("\"bench\": \"sc_serve\""));
        assert!(json.contains("\"items_per_s\": 500"));
        assert!(!json.contains("no_items\", \"median_s\": 0.5, \"mean_s\": 0.5, \"iters\": 3, "));
        assert!(json.contains("\"unit\": \"req/s\""));
        // Every entry row but the last is comma-terminated.
        assert_eq!(json.matches("{\"name\"").count(), 3);
    }

    #[test]
    fn json_escaping() {
        assert_eq!(escape("a\"b\\c"), "a\\\"b\\\\c");
        assert_eq!(escape("x\ny"), "x\\u000ay");
    }
}
