//! BER-sweep harness over the packed engine (`scnn exp ber`).
//!
//! Unlike the PJRT-trained Fig 5 runner ([`super::accuracy_exp::fig5`]),
//! this experiment needs no artifacts and no training: the network is
//! frozen deterministically from the seed ([`ModelParams::init`]) and
//! the reference labels are the *clean engine's own predictions*, so
//! every number measures pure fault-induced disagreement with the
//! fault-free datapath. The sweep itself is the parallel
//! [`fault::ber_sweep_on`] harness — the (BER × repeat) grid sharded
//! across threads, each point's faults a pure function of
//! `(seed, ber, repeat, image index)`.
//!
//! Two tables come out: accuracy vs BER at each activation stream
//! length, and accuracy vs stream length at the harshest BER (longer
//! streams average more flips away — the SC robustness argument).
//! Machine-readable results land in `RESULTS_fault.json`.

use std::sync::Arc;

use anyhow::Context;

use crate::data::{Dataset, Split, SynthDigits};
use crate::fault;
use crate::nn::model::{ModelCfg, ModelParams};
use crate::nn::quant::{Pruning, QuantConfig};
use crate::nn::sc_exec::Prepared;
use crate::nn::ScEngine;
use crate::util::bench::JsonReport;
use crate::util::Rng;
use crate::Result;

use super::{banner, Opts, Report};

/// Output path of the machine-readable sweep results.
pub const RESULTS_PATH: &str = "RESULTS_fault.json";

/// Activation stream lengths swept (the accuracy-vs-stream-length
/// axis).
const ACT_BSLS: [usize; 3] = [2, 4, 8];

/// `scnn exp ber`: accuracy vs BER and vs stream length on the packed
/// engine, no PJRT required.
pub fn ber(opts: &Opts) -> Result<Report> {
    banner("BER sweep — packed-engine fault injection");
    let mut rep = Report::new("ber");
    let data = SynthDigits::new();
    let n_img = if opts.quick { 24 } else { 128 };
    let repeats = if opts.quick { 1 } else { 3 };
    let bers: &[f64] =
        if opts.quick { &[1e-4, 1e-3, 1e-2] } else { &[1e-5, 1e-4, 1e-3, 3e-3, 1e-2, 3e-2] };
    let (images, _) = data.batch(Split::Test, 0, n_img);
    let cfg = ModelCfg::tnn();
    let mut rng = Rng::new(opts.seed);
    let params = ModelParams::init(&cfg, &mut rng);
    let mut json = JsonReport::new("ber");
    let top_ber = bers[bers.len() - 1];
    println!("{n_img} images, {repeats} repeat(s), seed {}", opts.seed);
    for act_bsl in ACT_BSLS {
        let prep = Arc::new(Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(act_bsl),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        ));
        // Self-labels: the clean engine's predictions become ground
        // truth, so soft accuracy is 1.0 by construction and every
        // faulted point reads directly as agreement with the fault-free
        // datapath.
        let labels = ScEngine::new(prep.clone()).predict(&images);
        let sweep = fault::ber_sweep_on(&prep, &images, &labels, bers, repeats, opts.seed);
        println!("--- act BSL {act_bsl} ---");
        println!("{:<10} {:>10} {:>10}", "BER", "acc SC", "acc bin");
        for p in &sweep.points {
            println!("{:<10.0e} {:>10.4} {:>10.4}", p.ber, p.acc_sc, p.acc_binary);
            let row = format!("bsl{act_bsl}/{:.0e}", p.ber);
            rep.push(&row, "acc_sc", p.acc_sc);
            rep.push(&row, "acc_binary", p.acc_binary);
            json.add_scalar(&format!("ber/{row}/acc_sc"), p.acc_sc, "accuracy");
            json.add_scalar(&format!("ber/{row}/acc_binary"), p.acc_binary, "accuracy");
        }
        let red = sweep.avg_loss_reduction();
        rep.push(&format!("bsl{act_bsl}"), "loss_reduction", red);
        json.add_scalar(&format!("ber/bsl{act_bsl}/loss_reduction"), red, "fraction");
    }
    // The stream-length table: SC accuracy at the harshest BER across
    // stream lengths (one flip is 1/L of the signal, so longer streams
    // should hold more accuracy).
    println!("--- SC accuracy at BER {top_ber:.0e} vs stream length ---");
    for act_bsl in ACT_BSLS {
        if let Some(acc) = rep.get(&format!("bsl{act_bsl}/{top_ber:.0e}"), "acc_sc") {
            println!("BSL {act_bsl:<4} {acc:>10.4}");
        }
    }
    json.write(RESULTS_PATH).with_context(|| format!("writing {RESULTS_PATH}"))?;
    println!("wrote {RESULTS_PATH} ({} entries)", json.len());
    Ok(rep)
}
