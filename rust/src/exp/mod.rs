//! Experiment registry: one runner per paper table/figure.
//!
//! `scnn exp <id>` regenerates the table/figure data; `scnn exp all`
//! runs everything. Each runner prints the same rows/series the paper
//! reports and returns them as a [`Report`] so integration tests can
//! assert the *shape* of the results (who wins, by roughly what
//! factor) without depending on absolute numbers.
//!
//! | id    | paper artifact                                   | module |
//! |-------|--------------------------------------------------|--------|
//! | tab2  | Table II thermometer codes                       | [`circuits_exp`] |
//! | fig1  | FSM tanh/ReLU transfer error                     | [`circuits_exp`] |
//! | fig4  | chip current & TOPS/W vs voltage                 | [`circuits_exp`] |
//! | fig7  | BN-fused activation via SI                       | [`circuits_exp`] |
//! | fig9  | BSN cost scaling + big-BSN overhead              | [`circuits_exp`] |
//! | fig10 | SI accuracy vs output BSL + design space         | [`circuits_exp`] |
//! | fig11 | sub-sampling stage input distributions           | [`circuits_exp`] |
//! | fig12 | spatial-temporal BSN cycle trace                 | [`circuits_exp`] |
//! | tab5  | 3×3×512 conv: baseline/spatial/ST                | [`circuits_exp`] |
//! | fig13 | ADP + MSE on 4 ResNet-18 layers                  | [`circuits_exp`] |
//! | fig2  | accuracy vs ADP trade-off (act BSL sweep)        | [`accuracy_exp`] |
//! | fig5  | accuracy loss vs BER, SC vs binary               | [`accuracy_exp`] |
//! | tab3  | quantization ablation                            | [`accuracy_exp`] |
//! | fig8  | high-precision-residual ablation                 | [`accuracy_exp`] |
//! | tab4  | W-A-R configs: area/ADP/accuracy                 | [`accuracy_exp`] |
//! | ber   | engine BER sweep → `RESULTS_fault.json`          | [`fault_exp`] |
//! | prune | pruning frontier → `RESULTS_prune.json`          | [`accuracy_exp`] |

pub mod accuracy_exp;
pub mod circuits_exp;
pub mod fault_exp;

use crate::Result;

/// Options shared by all experiment runners.
#[derive(Clone, Debug)]
pub struct Opts {
    /// Reduced workloads for CI (fewer train steps / trials).
    pub quick: bool,
    /// Artifact directory (PJRT-backed experiments).
    pub artifacts: String,
    /// Deterministic seed.
    pub seed: u64,
}

impl Default for Opts {
    fn default() -> Self {
        Self { quick: true, artifacts: "artifacts".into(), seed: 42 }
    }
}

/// A generated report: named rows of key=value measurements, plus the
/// printed rendering.
#[derive(Clone, Debug, Default)]
pub struct Report {
    /// Experiment id.
    pub id: String,
    /// Metric rows: (row label, metric name, value).
    pub values: Vec<(String, String, f64)>,
}

impl Report {
    /// New report.
    pub fn new(id: &str) -> Self {
        Self { id: id.to_string(), values: Vec::new() }
    }

    /// Record a value (also available to tests).
    pub fn push(&mut self, row: &str, metric: &str, value: f64) {
        self.values.push((row.to_string(), metric.to_string(), value));
    }

    /// Look up a recorded value.
    pub fn get(&self, row: &str, metric: &str) -> Option<f64> {
        self.values
            .iter()
            .find(|(r, m, _)| r == row && m == metric)
            .map(|(_, _, v)| *v)
    }
}

/// All experiment ids in run order.
pub const ALL_IDS: [&str; 17] = [
    "tab2", "fig1", "fig4", "fig7", "fig9", "fig10", "fig11", "fig12", "tab5",
    "fig13", "fig2", "fig5", "tab3", "fig8", "tab4", "ber", "prune",
];

/// Run one experiment by id.
pub fn run(id: &str, opts: &Opts) -> Result<Report> {
    match id {
        "tab2" => circuits_exp::tab2(opts),
        "fig1" => circuits_exp::fig1(opts),
        "fig4" => circuits_exp::fig4(opts),
        "fig7" => circuits_exp::fig7(opts),
        "fig9" => circuits_exp::fig9(opts),
        "fig10" => circuits_exp::fig10(opts),
        "fig11" => circuits_exp::fig11(opts),
        "fig12" => circuits_exp::fig12(opts),
        "tab5" => circuits_exp::tab5(opts),
        "fig13" => circuits_exp::fig13(opts),
        "fig2" => accuracy_exp::fig2(opts),
        "fig5" => accuracy_exp::fig5(opts),
        "tab3" => accuracy_exp::tab3(opts),
        "fig8" => accuracy_exp::fig8(opts),
        "tab4" => accuracy_exp::tab4(opts),
        "ber" => fault_exp::ber(opts),
        "prune" => accuracy_exp::prune(opts),
        other => anyhow::bail!("unknown experiment id {other}; known: {ALL_IDS:?}"),
    }
}

/// Print a horizontal rule + title.
pub(crate) fn banner(title: &str) {
    println!("\n=== {title} ===");
}
