//! Circuit-level experiments (no PJRT required): Tables II & V,
//! Figs 1, 4, 7, 9–13.

use crate::accel::{self, schedule::Schedule, RESNET18_ACC_WIDTHS};
use crate::circuits::bsn::Bsn;
use crate::circuits::fsm::{curve_mse, transfer_curve, ReluFsm, StanhFsm};
use crate::circuits::si::{ActivationFn, SelectiveInterconnect};
use crate::coding::{BitVec, ThermCode};
use crate::cost::power::ChipPowerModel;
use crate::util::{Rng, Stats};
use crate::Result;

use super::{banner, Opts, Report};

/// Table II: thermometer codes and ranges per BSL.
pub fn tab2(_opts: &Opts) -> Result<Report> {
    banner("Table II — thermometer coding");
    let mut rep = Report::new("tab2");
    println!("{:<5} {:>10} {:>14}   example codes", "BSL", "bin prec", "range");
    for bsl in [2usize, 4, 8, 16] {
        let (lo, hi) = ThermCode::range(bsl);
        let prec = ThermCode::binary_precision(bsl)
            .map(|p| p.to_string())
            .unwrap_or_else(|| "-".into());
        let lo_c = ThermCode::encode(lo, bsl);
        let mid_c = ThermCode::encode(0, bsl);
        let hi_c = ThermCode::encode(hi, bsl);
        println!(
            "{bsl:<5} {prec:>10} {:>14}   {lo_c} / {mid_c} / {hi_c}",
            format!("{lo}..{hi}")
        );
        rep.push(&bsl.to_string(), "levels", (bsl + 1) as f64);
    }
    Ok(rep)
}

/// Fig 1: FSM-based tanh/ReLU vs exact — transfer-curve MSE per BSL,
/// with the proposed SI design (exact by construction) as reference.
pub fn fig1(opts: &Opts) -> Result<Report> {
    banner("Fig 1 — FSM activation inaccuracy vs exact");
    let mut rep = Report::new("fig1");
    let xs: Vec<f64> = (0..41).map(|i| -1.0 + i as f64 * 0.05).collect();
    let bsls = if opts.quick { vec![32usize, 128, 1024] } else { vec![16, 32, 64, 128, 256, 1024] };
    println!("{:<8} {:>14} {:>14} {:>14}", "BSL", "tanh MSE", "ReLU MSE", "SI (proposed)");
    for bsl in bsls {
        let tanh_curve = transfer_curve(
            || {
                let mut f = StanhFsm::new(8);
                Box::new(move |b: &BitVec| {
                    f.reset();
                    f.run(b)
                })
            },
            &xs,
            bsl,
            0x5A5A,
        );
        let mse_tanh = curve_mse(&tanh_curve, |x| (4.0 * x).tanh());
        let relu_curve = transfer_curve(
            || {
                let mut f = ReluFsm::new(16);
                Box::new(move |b: &BitVec| {
                    f.reset();
                    f.run(b)
                })
            },
            &xs,
            bsl,
            0x1357,
        );
        let mse_relu = curve_mse(&relu_curve, |x| x.max(0.0));
        // Proposed design: deterministic SI synthesis of the same tanh
        // over a 64-bit accumulation — exact at every representable
        // point, so the only error is quantization.
        let si = SelectiveInterconnect::for_activation(&ActivationFn::Tanh { gain: 0.125 }, 64, 64);
        let mut se = 0.0;
        for c in 0..=64usize {
            let x = (c as f64 - 32.0) / 32.0; // map to [-1, 1]
            let got = (si.apply_count(c) as f64 - 32.0) / 32.0;
            se += (got - (4.0 * x).tanh()).powi(2);
        }
        let mse_si = se / 65.0;
        println!("{bsl:<8} {mse_tanh:>14.6} {mse_relu:>14.6} {mse_si:>14.6}");
        rep.push(&bsl.to_string(), "mse_tanh_fsm", mse_tanh);
        rep.push(&bsl.to_string(), "mse_relu_fsm", mse_relu);
        rep.push(&bsl.to_string(), "mse_si", mse_si);
    }
    Ok(rep)
}

/// Fig 4: chip current and energy efficiency vs supply voltage.
pub fn fig4(_opts: &Opts) -> Result<Report> {
    banner("Fig 4 — current & TOPS/W vs supply voltage");
    let mut rep = Report::new("fig4");
    let freqs = [50.0, 100.0, 200.0, 400.0];
    println!("{:<8} {:>8} {:>12} {:>12} {:>12}", "f (MHz)", "Vdd", "I (mA)", "TOPS/W", "ok");
    for &f in &freqs {
        for i in 0..9 {
            let v = 0.5 + 0.05 * i as f64;
            let p = ChipPowerModel::evaluate(v, f);
            println!(
                "{f:<8} {v:>8.2} {:>12.2} {:>12.1} {:>12}",
                p.current_ma,
                p.tops_per_w,
                if p.functional { "yes" } else { "-" }
            );
            rep.push(&format!("{f}MHz@{v:.2}V"), "tops_per_w", p.tops_per_w);
            rep.push(&format!("{f}MHz@{v:.2}V"), "current_ma", p.current_ma);
        }
    }
    let peak = ChipPowerModel::peak_efficiency(&freqs, 41);
    println!(
        "peak: {:.1} TOPS/W at {:.0} mV / {:.0} MHz  (paper: 198.9 @ 650 mV / 200 MHz)",
        peak.tops_per_w,
        peak.vdd * 1000.0,
        peak.freq_mhz
    );
    rep.push("peak", "tops_per_w", peak.tops_per_w);
    rep.push("peak", "vdd_mv", peak.vdd * 1000.0);
    Ok(rep)
}

/// Fig 7: BN-fused ReLU realized by the SI with 16-bit output BSL.
pub fn fig7(_opts: &Opts) -> Result<Report> {
    banner("Fig 7 — BN-fused activation via selective interconnect");
    let mut rep = Report::new("fig7");
    let in_w = 64usize;
    let out = 16usize;
    println!("{:<24} {:>12} {:>12}", "(gamma, beta)", "max |err|", "mean |err|");
    for (gamma, beta) in [(0.5f64, -4.0f64), (1.0, 0.0), (1.5, 2.0), (2.0, 6.0)] {
        let act = ActivationFn::BnRelu { gamma, beta, ratio: 0.5 };
        let si = SelectiveInterconnect::for_activation(&act, in_w, out);
        let mut stats = Stats::new();
        for c in 0..=in_w {
            let q = c as f64 - in_w as f64 / 2.0;
            let ideal = if q >= beta { gamma * (q - beta) * 0.5 } else { 0.0 };
            let ideal_q = ideal.round().clamp(-(out as f64) / 2.0, out as f64 / 2.0);
            let got = si.apply_count(c) as f64 - out as f64 / 2.0;
            stats.push((got - ideal_q).abs());
        }
        println!("({gamma:>4}, {beta:>5})          {:>12.3} {:>12.4}", stats.max(), stats.mean());
        rep.push(&format!("g{gamma}b{beta}"), "max_err", stats.max());
    }
    println!("(the SI reproduces the BN-fused ReLU exactly at every count)");
    Ok(rep)
}

/// Fig 9: (a) BSN cost vs accumulation width; (b) ADP overhead of the
/// monolithic worst-case BSN on small layers.
pub fn fig9(_opts: &Opts) -> Result<Report> {
    banner("Fig 9 — BSN cost scaling & big-BSN overhead");
    let mut rep = Report::new("fig9");
    let widths = [64usize, 128, 256, 512, 1024, 2304, 4608, 9216];
    println!("{:<8} {:>14} {:>10} {:>14} {:>12}", "width", "area um2", "delay ns", "ADP um2*ns", "area/width");
    let mut per_bit_first = 0.0;
    for (i, &w) in widths.iter().enumerate() {
        let c = Bsn::new(w).cost();
        let per_bit = c.area_um2 / w as f64;
        if i == 0 {
            per_bit_first = per_bit;
        }
        println!(
            "{w:<8} {:>14.0} {:>10.2} {:>14.0} {:>12.3}",
            c.area_um2,
            c.delay_ns,
            c.adp(),
            per_bit
        );
        rep.push(&w.to_string(), "area", c.area_um2);
        rep.push(&w.to_string(), "adp", c.adp());
    }
    let super_linear = (Bsn::new(9216).cost().area_um2 / 9216.0) / per_bit_first;
    println!("per-bit area grows {super_linear:.1}x from 64b to 9216b (super-linear)");
    rep.push("scaling", "per_bit_growth", super_linear);

    println!("\n(b) monolithic 9216-bit BSN serving small widths:");
    let mono = Bsn::new(9216).cost();
    println!("{:<8} {:>14} {:>12}", "width", "right-sized", "overhead x");
    for &w in &widths[..7] {
        let right = Bsn::new(w).cost();
        let overhead = mono.adp() / right.adp();
        println!("{w:<8} {:>14.0} {:>12.1}", right.adp(), overhead);
        rep.push(&w.to_string(), "mono_overhead", overhead);
    }
    Ok(rep)
}

/// Fig 10a: effect of reducing the BSN output BSL on SI accuracy;
/// Fig 10b: the parameterized design space.
pub fn fig10(opts: &Opts) -> Result<Report> {
    banner("Fig 10 — output-BSL reduction & parameterized BSN space");
    let mut rep = Report::new("fig10");
    let in_w = 1152usize;
    let trials = if opts.quick { 2000 } else { 20000 };
    let mut rng = Rng::new(opts.seed);
    println!("{:<10} {:>14} {:>14}", "out BSL", "ReLU MSE", "tanh MSE");
    for out in [64usize, 32, 16, 8, 4] {
        // Random near-Gaussian accumulations (ternary products).
        let relu_si = SelectiveInterconnect::for_activation(
            &ActivationFn::Relu { ratio: out as f64 / 64.0 },
            in_w,
            out,
        );
        let tanh_si = SelectiveInterconnect::for_activation(
            &ActivationFn::Tanh { gain: 0.06 },
            in_w,
            out,
        );
        let (mut se_r, mut se_t) = (0.0f64, 0.0f64);
        for _ in 0..trials {
            let count: usize = (0..in_w).filter(|_| rng.gen_bool(0.5)).count();
            let q = count as f64 - in_w as f64 / 2.0;
            // Reference: full-precision activation normalized to [0,1].
            let ref_r = (q.max(0.0) * (out as f64 / 64.0)).min(out as f64 / 2.0);
            let got_r = relu_si.apply_count(count) as f64 - out as f64 / 2.0;
            se_r += ((got_r - ref_r) / (out as f64 / 2.0)).powi(2);
            let ref_t = (0.06 * q).tanh();
            let got_t = (tanh_si.apply_count(count) as f64 - out as f64 / 2.0) / (out as f64 / 2.0);
            se_t += (got_t - ref_t).powi(2);
        }
        let (mse_r, mse_t) = (se_r / trials as f64, se_t / trials as f64);
        println!("{out:<10} {mse_r:>14.6} {mse_t:>14.6}");
        rep.push(&out.to_string(), "mse_relu", mse_r);
        rep.push(&out.to_string(), "mse_tanh", mse_t);
    }

    println!("\n(b) design space for 2304-bit accumulation:");
    println!("{:<12} {:<10} {:>12} {:>12} {:>10}", "clip_div", "stride", "area um2", "ADP", "MSE");
    for clip_div in [8usize, 4, 3] {
        for stride in [1usize, 2] {
            if let Some(d) = accel::design_spatial_with(2304, 16, clip_div, stride) {
                let c = d.cost();
                let mse = d.mse(0.5, trials / 4, &mut rng);
                println!(
                    "{clip_div:<12} {stride:<10} {:>12.0} {:>12.0} {:>10.2e}",
                    c.area_um2,
                    c.adp(),
                    mse
                );
                rep.push(&format!("c{clip_div}s{stride}"), "adp", c.adp());
            }
        }
    }
    Ok(rep)
}

/// Fig 11: input distributions at the sub-sampling stages.
pub fn fig11(opts: &Opts) -> Result<Report> {
    banner("Fig 11 — per-stage count distributions (clipping opportunity)");
    let mut rep = Report::new("fig11");
    let design = accel::design_spatial(9216, 16);
    let trials = if opts.quick { 400 } else { 4000 };
    let mut rng = Rng::new(opts.seed ^ 0xF16);
    // Track the distribution of group counts entering each stage.
    let m0 = design.stages()[0].m;
    let l0 = design.stages()[0].l;
    for (si, st) in design.stages().iter().enumerate() {
        let mut stats = Stats::new();
        for _ in 0..trials {
            // Simulate fresh leaf inputs and propagate to stage si.
            let mut counts: Vec<usize> =
                (0..m0).map(|_| (0..l0).filter(|_| rng.gen_bool(0.5)).count()).collect();
            let mut bsl;
            for (sj, stj) in design.stages().iter().enumerate() {
                if sj == si {
                    break;
                }
                counts = counts
                    .iter()
                    .map(|&k| stj.sub.apply_count(k, stj.l))
                    .collect();
                bsl = stj.sub.out_bsl(stj.l);
                let per = design.stages()[sj + 1].l / bsl;
                counts = counts.chunks(per).map(|c| c.iter().sum()).collect();
            }
            for &c in &counts {
                stats.push(c as f64);
            }
        }
        let center = st.l as f64 / 2.0;
        let spread = stats.std();
        let clip_sigma = (center - st.sub.clip as f64) / spread.max(1e-9);
        println!(
            "stage {si}: m={} l={} clip={}  count mean={:.1} std={:.1}  clip at {:.1} sigma",
            st.m, st.l, st.sub.clip, stats.mean(), spread, clip_sigma
        );
        rep.push(&format!("stage{si}"), "clip_sigma", clip_sigma);
    }
    println!("(clip boundaries sit many sigma out -> truncation error negligible)");
    Ok(rep)
}

/// Fig 12: spatial-temporal BSN cycle-by-cycle trace.
pub fn fig12(opts: &Opts) -> Result<Report> {
    banner("Fig 12 — 576-bit BSN reused over 9 cycles for 4608b");
    let mut rep = Report::new("fig12");
    let st = accel::design_st(4608, 576, 16, 16);
    println!(
        "inner width = {}b, data cycles = {}, total cycles = {} (paper: 9)",
        st.inner().in_width(),
        st.data_cycles(),
        st.total_cycles()
    );
    let mut rng = Rng::new(opts.seed ^ 0x12);
    let counts: Vec<usize> =
        (0..st.data_cycles()).map(|_| (0..576).filter(|_| rng.gen_bool(0.5)).count()).collect();
    for (cyc, &k) in counts.iter().enumerate() {
        let partial = st.inner().eval_counts(&[k]);
        println!("cycle {cyc}: input count {k:>4} -> partial code count {partial:>3}");
    }
    let out = st.eval_counts(&counts);
    let exact = st.exact_scaled_value(&counts);
    let approx = st.approx_value(&counts);
    println!(
        "merge cycle: output count {out} -> value {approx} (exact {exact:.2}, divisor {})",
        st.scale_divisor()
    );
    rep.push("st", "cycles", st.total_cycles() as f64);
    rep.push("st", "abs_err", (approx - exact).abs());
    Ok(rep)
}

/// Table V: the 3×3×512 convolution — baseline vs spatial vs
/// spatial-temporal approximate BSN.
pub fn tab5(opts: &Opts) -> Result<Report> {
    banner("Table V — 3x3x512 conv accumulator designs");
    let mut rep = Report::new("tab5");
    let width = 4608 * 2; // 4608 ternary products x 2-bit codes
    let trials = if opts.quick { 2000 } else { 50000 };
    let mut rng = Rng::new(opts.seed ^ 0x75);

    let base = Bsn::new(width).cost();
    let spatial = accel::design_spatial(width, 16);
    let sp_cost = spatial.cost();
    let sp_mse = spatial.mse(0.5, trials, &mut rng);
    let st = accel::design_st(width, 1152, 16, 16);
    let st_cost = st.cycle_cost();
    let st_adp = st.adp_throughput_normalized(base.delay_ns);
    let st_mse = st.mse(0.5, trials, &mut rng);

    println!(
        "{:<26} {:>12} {:>10} {:>14} {:>12}",
        "design", "area um2", "delay ns", "ADP um2*ns", "MSE"
    );
    println!(
        "{:<26} {:>12.3e} {:>10.2} {:>14.3e} {:>12}",
        "Baseline BSN", base.area_um2, base.delay_ns, base.adp(), "-"
    );
    println!(
        "{:<26} {:>12.3e} {:>10.2} {:>14.3e} {:>12.2e}",
        "Spatial Appr. BSN", sp_cost.area_um2, sp_cost.delay_ns, sp_cost.adp(), sp_mse
    );
    println!(
        "{:<26} {:>12.3e} {:>10.2} {:>14.3e} {:>12.2e}",
        "Spatial-Temporal Appr. BSN", st_cost.area_um2, st_cost.delay_ns, st_adp, st_mse
    );
    let r_sp = base.adp() / sp_cost.adp();
    let r_st = base.adp() / st_adp;
    println!("ADP reduction: spatial {r_sp:.1}x (paper 2.8x), spatial-temporal {r_st:.1}x (paper 4.1x)");

    rep.push("baseline", "area", base.area_um2);
    rep.push("baseline", "adp", base.adp());
    rep.push("spatial", "adp", sp_cost.adp());
    rep.push("spatial", "mse", sp_mse);
    rep.push("st", "adp_norm", st_adp);
    rep.push("st", "area", st_cost.area_um2);
    rep.push("st", "mse", st_mse);
    rep.push("ratio", "spatial_x", r_sp);
    rep.push("ratio", "st_x", r_st);
    Ok(rep)
}

/// Fig 13: ADP and MSE across the four ResNet-18 conv sizes.
pub fn fig13(opts: &Opts) -> Result<Report> {
    banner("Fig 13 — ADP & MSE across ResNet-18 conv sizes");
    let mut rep = Report::new("fig13");
    let trials = if opts.quick { 1000 } else { 20000 };
    let widths_bits: Vec<usize> = RESNET18_ACC_WIDTHS.iter().map(|w| w * 2).collect();
    let sched = Schedule::new(&widths_bits, 1152);
    println!(
        "{:<10} {:>8} {:>14} {:>14} {:>10} {:>10}",
        "products", "cycles", "mono ADP", "ST ADP", "reduction", "MSE"
    );
    let mut rng = Rng::new(opts.seed ^ 0x13);
    for (i, l) in sched.layers.iter().enumerate() {
        let st = sched.st_for(l.width_bits);
        let mse = st.mse(0.5, trials, &mut rng);
        println!(
            "{:<10} {:>8} {:>14.3e} {:>14.3e} {:>9.1}x {:>10.2e}",
            RESNET18_ACC_WIDTHS[i], l.cycles, l.adp_exact, l.adp_st, l.reduction, mse
        );
        rep.push(&RESNET18_ACC_WIDTHS[i].to_string(), "reduction", l.reduction);
        rep.push(&RESNET18_ACC_WIDTHS[i].to_string(), "mse", mse);
    }
    println!(
        "avg ADP reduction {:.1}x (paper 8.5x, range 8.2-23.3x); datapath area reduction {:.1}x (paper 2.2x)",
        sched.avg_adp_reduction(),
        sched.area_reduction()
    );
    rep.push("avg", "adp_reduction", sched.avg_adp_reduction());
    rep.push("avg", "area_reduction", sched.area_reduction());
    Ok(rep)
}
