//! Accuracy experiments driven through the PJRT training loop
//! (Tables III & IV, Figs 2, 5, 8). Rust generates the synthetic data,
//! executes the exported `train_step` HLO, and evaluates either on the
//! serving path (integer codes through the Pallas kernel) or through
//! the bit-exact SC circuit simulator.

use anyhow::Context;

use crate::circuits::si::ActivationFn;
use crate::circuits::{BsnKind, ConvDatapath, DatapathConfig};
use crate::data::{Dataset, Split, SynthCifar, SynthDigits};
use crate::fault;
use crate::nn::model::{ModelCfg, ModelParams};
use crate::nn::quant::{Pruning, QuantConfig};
use crate::nn::sc_exec::Prepared;
use crate::nn::ScEngine;
use crate::runtime::{trainer::Knobs, Runtime, Trainer};
use crate::util::bench::JsonReport;
use crate::util::Rng;
use crate::Result;

use super::{banner, Opts, Report};

fn steps(opts: &Opts, full: usize) -> usize {
    if opts.quick {
        (full / 3).max(200)
    } else {
        full
    }
}

fn lr_for(model: &str) -> f32 {
    if model == "tnn" {
        0.1
    } else {
        0.05
    }
}

fn eval_n(opts: &Opts) -> usize {
    if opts.quick {
        256
    } else {
        1024
    }
}

/// Train one configuration of a model and return test accuracy.
fn train_and_eval(
    rt: &Runtime,
    model: &str,
    data: &dyn Dataset,
    knobs: Knobs,
    n_steps: usize,
    n_eval: usize,
    serving: bool,
) -> Result<(Trainer, f64)> {
    let mut tr = Trainer::new(rt, model)?;
    // Standard two-phase QAT: float warm-up + calibration + quantized
    // fine-tune (a single float run for FP configurations).
    tr.train_qat(data, n_steps / 2, n_steps / 2, lr_for(model), knobs, |_, _| {})?;
    let acc = tr.accuracy(data, n_eval, knobs, serving)?;
    Ok((tr, acc))
}

/// Total datapath ADP of a model variant (Fig 2 / Table IV cost axis):
/// sum of per-conv-layer datapaths at the given activation/residual
/// BSLs, exact BSN accumulators.
pub fn model_datapath_adp(cfg: &ModelCfg, act_bsl: usize, res_bsl: Option<usize>) -> (f64, f64) {
    let mut area = 0.0;
    let mut adp = 0.0;
    for l in &cfg.layers {
        if let crate::nn::model::LayerCfg::Conv { shape, res_in, .. } = l {
            let dp = ConvDatapath::new(DatapathConfig {
                acc_width: shape.acc_width(),
                act_bsl,
                residual_bsl: if *res_in { res_bsl } else { None },
                out_bsl: act_bsl.max(2),
                bsn: BsnKind::Exact,
                activation: ActivationFn::Relu { ratio: 1.0 },
            });
            let c = dp.cost();
            area += c.area_um2;
            adp += c.adp();
        }
    }
    (area, adp)
}

/// Fig 2: inference accuracy vs ADP as the activation BSL sweeps
/// {2, 4, 8, 16} with 2-bit weights (no residual — the pre-§III model).
pub fn fig2(opts: &Opts) -> Result<Report> {
    banner("Fig 2 — accuracy vs efficiency (activation BSL sweep)");
    let mut rep = Report::new("fig2");
    let rt = Runtime::new(&opts.artifacts)?;
    let data = SynthCifar::hard(10);
    let n_steps = steps(opts, 800);
    println!(
        "{:<8} {:>10} {:>16} {:>14}",
        "act BSL", "accuracy", "datapath ADP", "(um2*ns, sum)"
    );
    let cfg = ModelCfg::scnet(10);
    for bsl in [2usize, 4, 8, 16] {
        let knobs = Knobs::quantized(bsl).with_res_bsl(None);
        let (_tr, acc) =
            train_and_eval(&rt, "scnet10", &data, knobs, n_steps, eval_n(opts), false)?;
        let (_, adp) = model_datapath_adp(&cfg, bsl, None);
        println!("{bsl:<8} {acc:>10.4} {adp:>16.3e}");
        rep.push(&bsl.to_string(), "accuracy", acc);
        rep.push(&bsl.to_string(), "adp", adp);
    }
    println!("(accuracy rises with BSL while ADP grows super-linearly — the paper's trade-off)");
    Ok(rep)
}

/// Fig 5: accuracy loss vs bit-error rate, SC vs conventional binary.
pub fn fig5(opts: &Opts) -> Result<Report> {
    banner("Fig 5 — fault tolerance: accuracy loss vs BER");
    let mut rep = Report::new("fig5");
    let rt = Runtime::new(&opts.artifacts)?;
    let data = SynthDigits::new();
    let knobs = Knobs::quantized(2).with_res_bsl(None);
    // tnn trains at ~100 PJRT steps/s — full-length QAT is cheap and
    // BSL-2 needs it (the soft accuracy anchors the whole sweep).
    let n_steps = if opts.quick { 700 } else { 1400 };
    let (tr, soft) = train_and_eval(&rt, "tnn", &data, knobs, n_steps, eval_n(opts), false)?;
    println!("soft (fault-free, fake-quant eval) accuracy: {soft:.4}");

    // Freeze into the bit-exact SC simulator.
    let params = tr.to_model_params();
    let cfg = ModelCfg::tnn();
    let prep = Prepared::new(
        &cfg,
        &params,
        QuantConfig {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
    );
    let bers = if opts.quick {
        vec![1e-4, 1e-3, 1e-2, 3e-2]
    } else {
        vec![1e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2, 1e-1]
    };
    let n_img = if opts.quick { 60 } else { 200 };
    let repeats = if opts.quick { 1 } else { 3 };
    let sweep = fault::ber_sweep(&prep, &data, &bers, n_img, repeats, opts.seed);
    println!("SC-simulator soft accuracy: {:.4}", sweep.soft_accuracy);
    println!(
        "{:<10} {:>10} {:>10} {:>12} {:>12}",
        "BER", "acc SC", "acc bin", "loss SC", "loss bin"
    );
    for p in &sweep.points {
        println!(
            "{:<10.0e} {:>10.4} {:>10.4} {:>12.4} {:>12.4}",
            p.ber, p.acc_sc, p.acc_binary, p.loss_sc, p.loss_binary
        );
        rep.push(&format!("{:.0e}", p.ber), "loss_sc", p.loss_sc);
        rep.push(&format!("{:.0e}", p.ber), "loss_binary", p.loss_binary);
    }
    let red = sweep.avg_loss_reduction();
    println!("average accuracy-loss reduction of SC vs binary: {:.0}% (paper: ~70%)", red * 100.0);
    rep.push("avg", "loss_reduction", red);
    rep.push("soft", "accuracy", sweep.soft_accuracy);
    Ok(rep)
}

/// Table III: quantization ablation on SynthCIFAR-10.
pub fn tab3(opts: &Opts) -> Result<Report> {
    banner("Table III — quantization ablation");
    let mut rep = Report::new("tab3");
    let rt = Runtime::new(&opts.artifacts)?;
    let data = SynthCifar::hard(10);
    let n_steps = steps(opts, 800);
    let rows: [(&str, Knobs); 4] = [
        ("baseline (FP/FP)", Knobs::float().with_res_bsl(None).with_float_res()),
        ("weight quantized (2/FP)", {
            let mut k = Knobs::float();
            k.w_fp = 0.0;
            k.res_on = 0.0;
            k
        }),
        ("activation quantized (FP/2)", {
            let mut k = Knobs::quantized(2).with_res_bsl(None);
            k.w_fp = 1.0;
            k
        }),
        ("fully quantized (2/2)", Knobs::quantized(2).with_res_bsl(None)),
    ];
    println!("{:<28} {:>12}", "network", "accuracy");
    for (name, knobs) in rows {
        let (_tr, acc) =
            train_and_eval(&rt, "scnet10", &data, knobs, n_steps, eval_n(opts), false)?;
        println!("{name:<28} {acc:>12.4}");
        rep.push(name, "accuracy", acc);
    }
    println!("(activation quantization is the dominant accuracy loss — §III.B)");
    Ok(rep)
}

/// Fig 8: high-precision residual ablation on SynthCIFAR-10/20.
pub fn fig8(opts: &Opts) -> Result<Report> {
    banner("Fig 8 — high-precision residual fusion");
    let mut rep = Report::new("fig8");
    let rt = Runtime::new(&opts.artifacts)?;
    let n_steps = steps(opts, 800);
    for (model, classes) in [("scnet10", 10usize), ("scnet20", 20)] {
        let data = SynthCifar::hard(classes);
        println!("--- {model} ---");
        println!("{:<22} {:>12}", "residual", "accuracy");
        let mut base_acc = 0.0;
        for (name, knobs) in [
            ("none", Knobs::quantized(2).with_res_bsl(None)),
            ("2b", Knobs::quantized(2).with_res_bsl(Some(2))),
            ("4b", Knobs::quantized(2).with_res_bsl(Some(4))),
            ("16b (proposed)", Knobs::quantized(2).with_res_bsl(Some(16))),
            ("float", Knobs::quantized(2).with_float_res()),
        ] {
            let (_tr, acc) =
                train_and_eval(&rt, model, &data, knobs, n_steps, eval_n(opts), false)?;
            if name == "none" {
                base_acc = acc;
            }
            println!("{name:<22} {acc:>12.4}   (+{:.2}%)", (acc - base_acc) * 100.0);
            rep.push(&format!("{model}/{name}"), "accuracy", acc);
        }
    }
    Ok(rep)
}

/// Table IV: W-A-R configurations — area, ADP and accuracy.
pub fn tab4(opts: &Opts) -> Result<Report> {
    banner("Table IV — W-A-R/BSL configurations");
    let mut rep = Report::new("tab4");
    let rt = Runtime::new(&opts.artifacts)?;
    let data = SynthCifar::hard(10);
    let n_steps = steps(opts, 800);
    let cfg = ModelCfg::scnet(10);
    println!(
        "{:<10} {:>14} {:>16} {:>10}",
        "W-A-R", "area um2", "ADP um2*ns", "accuracy"
    );
    for (label, act_bsl, res_bsl) in [
        ("2-2-2", 2usize, Some(2usize)),
        ("2-4-4", 4, Some(4)),
        ("2-2-16", 2, Some(16)),
    ] {
        let knobs = Knobs::quantized(act_bsl).with_res_bsl(res_bsl);
        let (_tr, acc) =
            train_and_eval(&rt, "scnet10", &data, knobs, n_steps, eval_n(opts), false)?;
        let (area, adp) = model_datapath_adp(&cfg, act_bsl, res_bsl);
        println!("{label:<10} {area:>14.1} {adp:>16.2} {acc:>10.4}");
        rep.push(label, "area", area);
        rep.push(label, "adp", adp);
        rep.push(label, "accuracy", acc);
    }
    println!("(2-2-16 ~ the accuracy of 2-4-4 at ~ the cost of 2-2-2 — the paper's point)");
    Ok(rep)
}

/// Output path of the machine-readable pruning-frontier results.
pub const PRUNE_RESULTS_PATH: &str = "RESULTS_prune.json";

/// Fraction of non-zero ternary weight codes across the whole frozen
/// network (convs + classifier).
fn weight_density(prep: &Prepared) -> f64 {
    let mut nnz = 0usize;
    let mut total = 0usize;
    for c in &prep.convs {
        nnz += c.wq.values.iter().filter(|&&v| v != 0).count();
        total += c.wq.values.len();
    }
    nnz += prep.fc.values.iter().filter(|&&v| v != 0).count();
    total += prep.fc.values.len();
    nnz as f64 / total.max(1) as f64
}

/// `scnn exp prune`: the accuracy-vs-speedup frontier over the
/// structured N:M weight-pruning knob, artifact-free on the packed
/// engine. Like [`super::fault_exp::ber`], the network is frozen
/// deterministically from the seed and the reference labels are the
/// *unpruned* engine's own predictions, so accuracy reads directly as
/// agreement with the dense datapath while imgs/s measures what the
/// zero-skipping panels gain from the dropped weights.
pub fn prune(opts: &Opts) -> Result<Report> {
    banner("Pruning frontier — accuracy vs speedup (structured N:M)");
    let mut rep = Report::new("prune");
    let data = SynthDigits::new();
    let n_img = if opts.quick { 48 } else { 256 };
    let (images, _) = data.batch(Split::Test, 0, n_img);
    let cfg = ModelCfg::tnn();
    let mut rng = Rng::new(opts.seed);
    let params = ModelParams::init(&cfg, &mut rng);
    let mut json = JsonReport::new("prune");
    let variants: [(&str, Pruning); 4] = [
        ("dense", Pruning::Off),
        ("3:4", Pruning::Nm { n: 3, m: 4 }),
        ("2:4", Pruning::Nm { n: 2, m: 4 }),
        ("1:4", Pruning::Nm { n: 1, m: 4 }),
    ];
    println!("{n_img} images, seed {}", opts.seed);
    println!(
        "{:<8} {:>12} {:>10} {:>10} {:>10}",
        "prune", "w density", "accuracy", "imgs/s", "speedup"
    );
    let mut dense_rate = 0.0f64;
    let mut labels: Vec<usize> = Vec::new();
    for (name, pruning) in variants {
        let prep = Prepared::new(
            &cfg,
            &params,
            QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None, pruning },
        );
        let density = weight_density(&prep);
        let mut engine = ScEngine::new(prep);
        // Warm-up pass fills the scratch arenas; the timed pass then
        // measures the steady-state request path.
        let _ = engine.predict(&images[..1]);
        let t0 = std::time::Instant::now();
        let preds = engine.predict(&images);
        let dt = t0.elapsed().as_secs_f64().max(1e-9);
        let rate = n_img as f64 / dt;
        if labels.is_empty() {
            // First variant is dense: its predictions are the
            // self-labels every pruned variant is scored against.
            labels = preds.clone();
            dense_rate = rate;
        }
        let hits = preds.iter().zip(labels.iter()).filter(|(a, b)| a == b).count();
        let acc = hits as f64 / n_img.max(1) as f64;
        let speedup = rate / dense_rate.max(1e-9);
        println!("{name:<8} {density:>12.3} {acc:>10.4} {rate:>10.1} {speedup:>10.2}x");
        rep.push(name, "weight_density", density);
        rep.push(name, "accuracy", acc);
        rep.push(name, "speedup", speedup);
        json.add_scalar(&format!("prune/{name}/weight_density"), density, "fraction");
        json.add_scalar(&format!("prune/{name}/accuracy"), acc, "accuracy");
        json.add_scalar(&format!("prune/{name}/speedup"), speedup, "x");
    }
    println!("(the frontier: density falls monotonically; accuracy degrades gracefully)");
    json.write(PRUNE_RESULTS_PATH).with_context(|| format!("writing {PRUNE_RESULTS_PATH}"))?;
    println!("wrote {PRUNE_RESULTS_PATH} ({} entries)", json.len());
    Ok(rep)
}
