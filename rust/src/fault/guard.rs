//! Count-domain integrity guards over the packed GEMM datapath.
//!
//! The SC design computes in exact integers, which buys invariants a
//! float datapath never has: every per-neuron accumulation is bounded
//! by the stream length (`|Σ wᵢxᵢ| ≤ acc_width · bsl/2`), and a row of
//! GEMM counts must sum to the weight row dotted with the column-sum
//! vector — an i64 checksum that any single corrupted count breaks.
//! [`DatapathGuard`] checks both after each `gemm_rows_into` block;
//! on violation it re-executes the affected row through the pinned
//! scalar kernel ([`Dispatch::scalar()`]), rechecks, and counts the
//! outcome in [`GuardCounters`] for the serving metrics
//! (`scnn_integrity_faults_detected_total` /
//! `scnn_integrity_recovered_total`).
//!
//! The chaos knob ([`DatapathGuard::with_chaos`]) deliberately corrupts
//! every Nth row *before* the check — the self-test used by
//! `rust/tests/gemm.rs` to prove detection and recovery are 100% on the
//! live engine path.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::nn::gemm::TernaryPanel;
use crate::util::simd::Dispatch;

/// Shared detection/recovery counters, reported through
/// `coordinator::metrics` when the guard serves behind a pool.
#[derive(Debug, Default)]
pub struct GuardCounters {
    detected: AtomicU64,
    recovered: AtomicU64,
}

impl GuardCounters {
    /// Rows that failed an integrity check.
    pub fn detected(&self) -> u64 {
        self.detected.load(Ordering::Relaxed)
    }

    /// Rows whose scalar re-execution restored a passing check.
    pub fn recovered(&self) -> u64 {
        self.recovered.load(Ordering::Relaxed)
    }
}

/// Integrity guard over GEMM row blocks. One guard (behind an `Arc`)
/// is shared by every engine thread and pool worker; all state is
/// atomic.
#[derive(Debug)]
pub struct DatapathGuard {
    counters: Arc<GuardCounters>,
    /// Chaos knob: corrupt every Nth checked row before verifying.
    corrupt_every: Option<u64>,
    tick: AtomicU64,
}

impl DatapathGuard {
    /// Production guard: verify and recover, never corrupt.
    pub fn new(counters: Arc<GuardCounters>) -> Self {
        Self { counters, corrupt_every: None, tick: AtomicU64::new(0) }
    }

    /// Test/chaos guard: corrupt every `every`-th checked row (1 ⇒
    /// every row) before running the check, so detection and recovery
    /// can be asserted end to end.
    pub fn with_chaos(counters: Arc<GuardCounters>, every: u64) -> Self {
        Self { counters, corrupt_every: Some(every.max(1)), tick: AtomicU64::new(0) }
    }

    /// The shared counters.
    pub fn counters(&self) -> &Arc<GuardCounters> {
        &self.counters
    }

    /// Verify (and on violation re-execute) the GEMM rows
    /// `[r0, r0 + rows)` of `panel`, whose counts occupy
    /// `counts[l · npix ..][..npix]` for local row `l`. `colsum` is the
    /// per-k column-sum vector of `cols` and `base` the per-count
    /// magnitude bound (`acc_width · bsl/2`).
    ///
    /// The checksum oracle and the re-execution both run on the pinned
    /// scalar kernel table, independent of whatever SIMD arm produced
    /// the counts.
    #[allow(clippy::too_many_arguments)]
    pub fn verify_rows(
        &self,
        panel: &TernaryPanel,
        r0: usize,
        rows: usize,
        cols: &[i32],
        npix: usize,
        colsum: &[i64],
        base: i64,
        counts: &mut [i64],
    ) {
        debug_assert_eq!(counts.len(), rows * npix);
        let sc = Dispatch::scalar();
        for l in 0..rows {
            let row = &mut counts[l * npix..(l + 1) * npix];
            if let Some(every) = self.corrupt_every {
                if self.tick.fetch_add(1, Ordering::Relaxed) % every == 0 {
                    // A shift past the count bound: caught by the
                    // magnitude check even when the checksum were
                    // somehow fooled.
                    row[0] = row[0].wrapping_add(4 * base.max(1) + 1);
                }
            }
            let expect = panel.row_dot_i64_with(sc, r0 + l, colsum);
            if row_ok(row, base, expect) {
                continue;
            }
            self.counters.detected.fetch_add(1, Ordering::Relaxed);
            panel.gemm_rows_into_with(sc, r0 + l, r0 + l + 1, cols, npix, row);
            if row_ok(row, base, expect) {
                self.counters.recovered.fetch_add(1, Ordering::Relaxed);
            }
        }
    }
}

/// Both invariants of one GEMM row: every count within the stream-
/// length bound, and the row checksum exact.
fn row_ok(row: &[i64], base: i64, expect: i64) -> bool {
    let mut sum = 0i64;
    for &v in row {
        if v.abs() > base {
            return false;
        }
        sum = sum.wrapping_add(v);
    }
    sum == expect
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::nn::gemm::column_sums;
    use crate::util::Rng;

    fn random_problem(seed: u64) -> (TernaryPanel, Vec<i32>, usize, usize, i64) {
        let mut rng = Rng::new(seed);
        let (rows, k, npix) = (5usize, 36usize, 7usize);
        let w: Vec<i8> = (0..rows * k).map(|_| rng.gen_range_i64(-1, 1) as i8).collect();
        // Activations within the BSL-8 range so `base` is the real
        // per-count bound.
        let cols: Vec<i32> = (0..npix * k).map(|_| rng.gen_range_i64(-4, 4) as i32).collect();
        (TernaryPanel::pack(&w, rows, k), cols, k, npix, (k * 4) as i64)
    }

    #[test]
    fn clean_rows_pass_untouched() {
        let (panel, cols, k, npix, base) = random_problem(3);
        let mut counts = vec![0i64; panel.rows() * npix];
        panel.gemm_into(&cols, npix, &mut counts);
        let before = counts.clone();
        let mut colsum = Vec::new();
        column_sums(&cols, k, &mut colsum);
        let g = DatapathGuard::new(Arc::new(GuardCounters::default()));
        g.verify_rows(&panel, 0, panel.rows(), &cols, npix, &colsum, base, &mut counts);
        assert_eq!(counts, before);
        assert_eq!(g.counters().detected(), 0);
        assert_eq!(g.counters().recovered(), 0);
    }

    #[test]
    fn every_corrupted_row_is_detected_and_recovered() {
        // 100% detection + recovery over many random corruption
        // patterns — the acceptance bar of the guard layer.
        for seed in 0..20u64 {
            let (panel, cols, k, npix, base) = random_problem(seed);
            let mut counts = vec![0i64; panel.rows() * npix];
            panel.gemm_into(&cols, npix, &mut counts);
            let clean = counts.clone();
            let mut colsum = Vec::new();
            column_sums(&cols, k, &mut colsum);
            let mut rng = Rng::new(seed ^ 0xC0FFEE);
            // Corrupt a random set of elements (at least one).
            let n_corrupt = 1 + rng.gen_index(4);
            let mut hit_rows = std::collections::BTreeSet::new();
            for _ in 0..n_corrupt {
                let i = rng.gen_index(counts.len());
                counts[i] = counts[i].wrapping_add(1 + rng.gen_range_i64(0, 1 << 20));
                hit_rows.insert(i / npix);
            }
            let g = DatapathGuard::new(Arc::new(GuardCounters::default()));
            g.verify_rows(&panel, 0, panel.rows(), &cols, npix, &colsum, base, &mut counts);
            assert_eq!(counts, clean, "seed {seed}: recovery must restore exact counts");
            assert_eq!(g.counters().detected(), hit_rows.len() as u64, "seed {seed}");
            assert_eq!(g.counters().recovered(), hit_rows.len() as u64, "seed {seed}");
        }
    }

    #[test]
    fn single_count_offsets_cannot_hide_from_the_checksum() {
        // A ±1 nudge stays inside the magnitude bound but must still
        // trip the row checksum.
        let (panel, cols, k, npix, base) = random_problem(9);
        let mut counts = vec![0i64; panel.rows() * npix];
        panel.gemm_into(&cols, npix, &mut counts);
        let clean = counts.clone();
        counts[2 * npix + 3] += 1;
        let mut colsum = Vec::new();
        column_sums(&cols, k, &mut colsum);
        let g = DatapathGuard::new(Arc::new(GuardCounters::default()));
        g.verify_rows(&panel, 0, panel.rows(), &cols, npix, &colsum, base, &mut counts);
        assert_eq!(counts, clean);
        assert_eq!(g.counters().detected(), 1);
        assert_eq!(g.counters().recovered(), 1);
    }

    #[test]
    fn chaos_guard_corrupts_then_heals_itself() {
        let (panel, cols, k, npix, base) = random_problem(4);
        let mut counts = vec![0i64; panel.rows() * npix];
        panel.gemm_into(&cols, npix, &mut counts);
        let clean = counts.clone();
        let mut colsum = Vec::new();
        column_sums(&cols, k, &mut colsum);
        let g = DatapathGuard::with_chaos(Arc::new(GuardCounters::default()), 2);
        g.verify_rows(&panel, 0, panel.rows(), &cols, npix, &colsum, base, &mut counts);
        // Rows 0, 2, 4 corrupted (every 2nd tick), all recovered.
        assert_eq!(counts, clean);
        assert_eq!(g.counters().detected(), 3);
        assert_eq!(g.counters().recovered(), 3);
    }
}
