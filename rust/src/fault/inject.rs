//! Deterministic word-level fault masks for the packed datapath.
//!
//! The fault model is **stage-output-lane** injection: every circuit
//! stage that produces a bit stream — the ternary multiplier products,
//! the rescale alignment output, the BSN's sorted stream, and each
//! selective interconnect's output lanes — gets an independent sparse
//! bitflip mask drawn at the configured bit-error rate. A mask is a
//! sorted list of lane indices to XOR.
//!
//! Masks are derived from `(seed, image, layer, channel, pixel, stage)`
//! through a SplitMix64-style mixer, so *any* executor draws exactly
//! the same faults for a given site regardless of evaluation order,
//! threading, or batching. This is what lets the packed count-domain
//! [`crate::nn::ScEngine`] and the scalar stream-materializing
//! [`crate::nn::sc_exec::ScExecutor`] produce bit-identical faulted
//! logits (property-tested in `rust/tests/gemm.rs`), and what makes
//! [`crate::fault::ber_sweep`] reproducible under any point order or
//! parallel schedule.
//!
//! Sparse masks keep the faulted path at packed speed: at BER `p` over
//! a `w`-lane stage the expected mask length is `p·w`, and mask
//! generation skips over fault-free gaps geometrically instead of
//! drawing one Bernoulli per lane.

use crate::coding::BitVec;
use crate::util::Rng;

/// The circuit stages whose output lanes take faults, in datapath
/// order. The discriminant feeds the site derivation, so the values
/// are part of the reproducibility contract.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
#[repr(u64)]
pub enum Stage {
    /// Ternary-multiplier product lanes (one mask over the
    /// `acc_width · act_bsl` concatenated product streams).
    Mult = 0,
    /// Aligned residual stream out of the rescale block.
    Rescale = 1,
    /// The BSN's sorted stream (shared by both SIs reading it).
    Bsn = 2,
    /// Main-path SI output lanes.
    SiMain = 3,
    /// Residual-path SI output lanes.
    SiRes = 4,
}

/// SplitMix64 finalizer — the avalanche step that decorrelates the
/// site coordinates.
#[inline]
fn mix64(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Deterministic seed for one fault site. Each coordinate passes
/// through the mixer before combining, so neighbouring sites (pixel
/// `p` vs `p+1`, stage `k` vs `k+1`) get unrelated streams.
#[must_use]
pub fn site_seed(
    seed: u64,
    image: u64,
    layer: usize,
    channel: usize,
    pixel: usize,
    stage: Stage,
) -> u64 {
    let mut h = mix64(seed);
    h = mix64(h ^ image);
    h = mix64(h ^ (layer as u64));
    h = mix64(h ^ (channel as u64));
    h = mix64(h ^ (pixel as u64));
    mix64(h ^ stage as u64)
}

/// RNG for one fault site (see [`site_seed`]).
#[must_use]
pub fn site_rng(
    seed: u64,
    image: u64,
    layer: usize,
    channel: usize,
    pixel: usize,
    stage: Stage,
) -> Rng {
    Rng::new(site_seed(seed, image, layer, channel, pixel, stage))
}

/// Per-image seed for executors that keep a single fault stream per
/// forward pass (the binary baseline): decorrelates images without a
/// shared sequential draw order.
#[must_use]
pub fn image_seed(seed: u64, image: u64) -> u64 {
    mix64(mix64(seed) ^ image)
}

/// Per-sweep-point seed for [`crate::fault::ber_sweep`]: a pure
/// function of `(seed, ber, repeat)`, so reordering or parallelizing
/// the (BER × repeat) grid cannot change any point's draws.
#[must_use]
pub fn point_seed(seed: u64, ber: f64, repeat: u64) -> u64 {
    mix64(mix64(mix64(seed) ^ ber.to_bits()) ^ repeat)
}

/// Fill `out` with the sorted fault-lane indices of one `width`-lane
/// stage at bit-error rate `ber`.
///
/// Gap-skipping sampler: the distance to the next faulted lane is
/// geometric, `skip = ⌊ln(1−u) / ln(1−ber)⌋` with `u ∈ [0, 1)`, so the
/// cost is proportional to the number of faults, not the width.
/// `ber ≤ 0` (or zero width) yields an empty mask without consuming a
/// draw; `ber ≥ 1` faults every lane.
pub fn fill_mask(rng: &mut Rng, ber: f64, width: usize, out: &mut Vec<u32>) {
    out.clear();
    if width == 0 || ber <= 0.0 {
        return;
    }
    debug_assert!(width <= u32::MAX as usize, "stage width {width} exceeds mask range");
    if ber >= 1.0 {
        out.extend(0..width as u32);
        return;
    }
    // ln(1 − ber) < 0 for 0 < ber < 1.
    let denom = (1.0 - ber).ln();
    let mut pos = 0usize;
    loop {
        // u ∈ [0, 1) ⇒ 1 − u ∈ (0, 1] ⇒ ln(1 − u) ∈ (−∞, 0] ⇒ skip ≥ 0.
        let u = rng.f64();
        // Saturating cast: a tiny BER can produce a skip beyond any
        // representable width, which simply means "no fault here".
        let skip = ((1.0 - u).ln() / denom).floor() as usize;
        pos = pos.saturating_add(skip);
        if pos >= width {
            return;
        }
        out.push(pos as u32);
        pos += 1;
    }
}

/// XOR the mask into a packed stream, word-level. Every index must be
/// `< bits.len()`, which also preserves the `BitVec` tail-bits-zero
/// invariant its word-wise consumers depend on.
pub fn apply_mask(mask: &[u32], bits: &mut BitVec) {
    let len = bits.len();
    let words = bits.as_mut_words();
    for &g in mask {
        let g = g as usize;
        assert!(g < len, "mask index {g} out of range for stream of {len} lanes");
        words[g / 64] ^= 1u64 << (g % 64);
    }
}

/// Apply the sub-range `[lo, hi)` of a sorted mask to a stream,
/// rebasing indices to `g − lo` — the per-product view of the one
/// `Mult` mask spanning all `acc_width` concatenated product streams.
pub fn apply_mask_range(mask: &[u32], lo: usize, hi: usize, bits: &mut BitVec) {
    debug_assert!(is_sorted(mask), "mask must be sorted");
    let a = mask.partition_point(|&g| (g as usize) < lo);
    let b = mask.partition_point(|&g| (g as usize) < hi);
    let len = bits.len();
    let words = bits.as_mut_words();
    for &g in &mask[a..b] {
        let i = g as usize - lo;
        assert!(i < len, "mask index {i} out of range for stream of {len} lanes");
        words[i / 64] ^= 1u64 << (i % 64);
    }
}

/// Popcount delta from XOR-ing a sorted mask into a canonical
/// ones-prefix stream with `count` leading ones: each faulted lane
/// below `count` clears a one (−1), each at or above sets a zero (+1).
#[must_use]
pub fn prefix_flip_delta(mask: &[u32], count: usize) -> i64 {
    debug_assert!(is_sorted(mask), "mask must be sorted");
    let k = mask.partition_point(|&g| (g as usize) < count);
    (mask.len() - k) as i64 - k as i64
}

/// Whether a sorted mask faults lane `g` (binary search).
#[must_use]
pub fn contains(mask: &[u32], g: usize) -> bool {
    g <= u32::MAX as usize && mask.binary_search(&(g as u32)).is_ok()
}

fn is_sorted(mask: &[u32]) -> bool {
    mask.windows(2).all(|w| w[0] < w[1])
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;

    #[test]
    fn site_seeds_are_distinct_across_every_coordinate() {
        let base = site_seed(1, 2, 3, 4, 5, Stage::Bsn);
        assert_ne!(base, site_seed(9, 2, 3, 4, 5, Stage::Bsn));
        assert_ne!(base, site_seed(1, 9, 3, 4, 5, Stage::Bsn));
        assert_ne!(base, site_seed(1, 2, 9, 4, 5, Stage::Bsn));
        assert_ne!(base, site_seed(1, 2, 3, 9, 5, Stage::Bsn));
        assert_ne!(base, site_seed(1, 2, 3, 4, 9, Stage::Bsn));
        assert_ne!(base, site_seed(1, 2, 3, 4, 5, Stage::SiMain));
        // Swapping values between coordinates must not collide either
        // (each passes the mixer before combining).
        assert_ne!(site_seed(1, 2, 3, 4, 5, Stage::Mult), site_seed(2, 1, 3, 4, 5, Stage::Mult));
        assert_ne!(site_seed(1, 2, 3, 4, 5, Stage::Mult), site_seed(1, 2, 4, 3, 5, Stage::Mult));
    }

    #[test]
    fn point_seed_depends_only_on_its_coordinates() {
        assert_eq!(point_seed(42, 1e-3, 2), point_seed(42, 1e-3, 2));
        assert_ne!(point_seed(42, 1e-3, 2), point_seed(42, 1e-2, 2));
        assert_ne!(point_seed(42, 1e-3, 2), point_seed(42, 1e-3, 3));
        assert_ne!(point_seed(42, 1e-3, 2), point_seed(43, 1e-3, 2));
    }

    #[test]
    fn fill_mask_edges() {
        let mut rng = Rng::new(7);
        let mut m = Vec::new();
        fill_mask(&mut rng, 0.0, 128, &mut m);
        assert!(m.is_empty());
        fill_mask(&mut rng, -1.0, 128, &mut m);
        assert!(m.is_empty());
        fill_mask(&mut rng, 0.5, 0, &mut m);
        assert!(m.is_empty());
        fill_mask(&mut rng, 1.0, 5, &mut m);
        assert_eq!(m, vec![0, 1, 2, 3, 4]);
        fill_mask(&mut rng, 2.0, 3, &mut m);
        assert_eq!(m, vec![0, 1, 2]);
    }

    #[test]
    fn fill_mask_is_sorted_in_range_and_rate_accurate() {
        let mut rng = Rng::new(11);
        let mut m = Vec::new();
        let (width, ber, trials) = (1000usize, 0.05f64, 200usize);
        let mut total = 0usize;
        for _ in 0..trials {
            fill_mask(&mut rng, ber, width, &mut m);
            assert!(is_sorted(&m));
            assert!(m.iter().all(|&g| (g as usize) < width));
            total += m.len();
        }
        let rate = total as f64 / (width * trials) as f64;
        assert!(
            (rate - ber).abs() < 0.01,
            "observed fault rate {rate} far from requested {ber}"
        );
    }

    #[test]
    fn fill_mask_is_deterministic_in_the_rng_seed() {
        let (mut a, mut b) = (Rng::new(3), Rng::new(3));
        let (mut ma, mut mb) = (Vec::new(), Vec::new());
        for _ in 0..10 {
            fill_mask(&mut a, 0.03, 500, &mut ma);
            fill_mask(&mut b, 0.03, 500, &mut mb);
            assert_eq!(ma, mb);
        }
    }

    #[test]
    fn apply_mask_flips_exactly_the_masked_lanes() {
        let mut bits = BitVec::zeros(130);
        bits.set(0, true);
        bits.set(64, true);
        apply_mask(&[0, 63, 64, 129], &mut bits);
        assert!(!bits.get(0)); // 1 → 0
        assert!(bits.get(63)); // 0 → 1
        assert!(!bits.get(64)); // 1 → 0
        assert!(bits.get(129)); // 0 → 1
        assert!(bits.tail_is_zero());
        assert_eq!(bits.popcount(), 2);
    }

    #[test]
    fn apply_mask_range_rebases_indices() {
        // One concatenated mask over 2 products of 64 lanes each; the
        // second product's slice lands at bit g − 64.
        let mask = [3u32, 64, 70, 127];
        let mut prod = BitVec::zeros(64);
        apply_mask_range(&mask, 64, 128, &mut prod);
        assert!(prod.get(0) && prod.get(6) && prod.get(63));
        assert_eq!(prod.popcount(), 3);
        let mut first = BitVec::zeros(64);
        apply_mask_range(&mask, 0, 64, &mut first);
        assert!(first.get(3));
        assert_eq!(first.popcount(), 1);
    }

    #[test]
    fn prefix_flip_delta_matches_materialized_popcount() {
        let mut rng = Rng::new(19);
        for width in [63usize, 64, 65, 127, 128, 130] {
            for _ in 0..20 {
                let count = rng.gen_index(width + 1);
                let mut m = Vec::new();
                fill_mask(&mut rng, 0.2, width, &mut m);
                let mut bits = BitVec::zeros(0);
                bits.set_ones_prefix(width, count);
                apply_mask(&m, &mut bits);
                assert_eq!(
                    bits.popcount() as i64,
                    count as i64 + prefix_flip_delta(&m, count),
                    "width {width} count {count} mask {m:?}"
                );
            }
        }
    }

    #[test]
    fn contains_agrees_with_linear_scan() {
        let m = [1u32, 5, 64, 65, 200];
        for g in 0..256usize {
            assert_eq!(contains(&m, g), m.iter().any(|&x| x as usize == g), "lane {g}");
        }
        assert!(!contains(&m, usize::MAX));
    }
}
