//! Fault-tolerance experiment harness (paper Fig 5).
//!
//! Sweeps bit-error rate over both executors on the same frozen network
//! and reports accuracy loss relative to the fault-free ("soft")
//! accuracy. The paper's claim: SC reduces average accuracy loss by
//! ~70% versus the conventional binary design, because an SC bit flip
//! perturbs the result by one quantization step while a binary MSB flip
//! perturbs it by half the range.

use crate::data::{Dataset, Split};
use crate::nn::binary_exec::BinaryExecutor;
use crate::nn::sc_exec::{FaultCfg, Prepared, ScExecutor};
use crate::nn::tensor::Tensor;

/// One row of the Fig 5 sweep.
#[derive(Clone, Copy, Debug)]
pub struct BerPoint {
    /// Bit-error rate.
    pub ber: f64,
    /// SC accuracy at this BER.
    pub acc_sc: f64,
    /// Binary accuracy at this BER.
    pub acc_binary: f64,
    /// Accuracy loss (soft − faulty) of the SC design.
    pub loss_sc: f64,
    /// Accuracy loss of the binary design.
    pub loss_binary: f64,
}

/// Full sweep result.
#[derive(Clone, Debug)]
pub struct BerSweep {
    /// Fault-free accuracy (both executors agree fault-free).
    pub soft_accuracy: f64,
    /// Points in BER order.
    pub points: Vec<BerPoint>,
}

impl BerSweep {
    /// Average accuracy-loss reduction of SC vs binary (the paper's
    /// "70%"): `1 - mean(loss_sc) / mean(loss_binary)`.
    pub fn avg_loss_reduction(&self) -> f64 {
        let (mut ls, mut lb) = (0.0, 0.0);
        for p in &self.points {
            ls += p.loss_sc.max(0.0);
            lb += p.loss_binary.max(0.0);
        }
        if lb <= 0.0 {
            return 0.0;
        }
        1.0 - ls / lb
    }
}

/// Run the Fig-5 sweep: evaluate `n_eval` test images at each BER with
/// `repeats` fault seeds and average.
pub fn ber_sweep(
    prep: &Prepared,
    data: &dyn Dataset,
    bers: &[f64],
    n_eval: usize,
    repeats: usize,
    seed: u64,
) -> BerSweep {
    let (images, labels) = data.batch(Split::Test, 0, n_eval);
    // One frozen model shared by every executor in the sweep (the Arc
    // clone is a refcount bump, not a copy of the weights/SI tables).
    let prep = std::sync::Arc::new(prep.clone());
    let clean = ScExecutor::new(prep.clone());
    let soft = clean.accuracy(&images, &labels);
    let mut points = Vec::with_capacity(bers.len());
    for (bi, &ber) in bers.iter().enumerate() {
        let mut acc_sc = 0.0;
        let mut acc_bin = 0.0;
        for r in 0..repeats {
            let fc = FaultCfg { ber, seed: seed ^ ((bi as u64) << 32) ^ r as u64 };
            acc_sc += ScExecutor::with_faults(prep.clone(), fc).accuracy(&images, &labels);
            acc_bin +=
                BinaryExecutor::with_faults(prep.clone(), fc).accuracy(&images, &labels);
        }
        acc_sc /= repeats as f64;
        acc_bin /= repeats as f64;
        points.push(BerPoint {
            ber,
            acc_sc,
            acc_binary: acc_bin,
            loss_sc: soft - acc_sc,
            loss_binary: soft - acc_bin,
        });
    }
    BerSweep { soft_accuracy: soft, points }
}

/// Flip bits across a whole image's worth of activation codes — utility
/// for targeted robustness tests.
pub fn perturb_image(img: &Tensor, flip_fraction: f64, rng: &mut crate::util::Rng) -> Tensor {
    let mut out = img.clone();
    for v in out.data_mut() {
        if rng.gen_bool(flip_fraction) {
            *v = -*v;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;
    use crate::nn::model::{ModelCfg, ModelParams};
    use crate::nn::quant::QuantConfig;
    use crate::util::Rng;

    #[test]
    fn sweep_structure_and_monotonicity() {
        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(8);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Prepared::new(
            &cfg,
            &params,
            QuantConfig { act_bsl: Some(2), weight_ternary: true, residual_bsl: None },
        );
        let data = SynthDigits::new();
        let sweep = ber_sweep(&prep, &data, &[1e-4, 1e-2], 12, 1, 42);
        assert_eq!(sweep.points.len(), 2);
        // Low BER should hurt no more than high BER (within noise we
        // allow equality).
        assert!(sweep.points[0].loss_sc <= sweep.points[1].loss_sc + 0.2);
        for p in &sweep.points {
            assert!((0.0..=1.0).contains(&p.acc_sc));
            assert!((0.0..=1.0).contains(&p.acc_binary));
        }
    }

    #[test]
    fn perturb_fraction_zero_is_identity() {
        let mut rng = Rng::new(1);
        let img = Tensor::from_vec(&[4], vec![1.0, -2.0, 3.0, -4.0]);
        let same = perturb_image(&img, 0.0, &mut rng);
        assert_eq!(img.data(), same.data());
    }
}
