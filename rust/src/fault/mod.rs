//! Datapath fault model: injection, integrity guards, and the BER
//! sweep experiment (paper Fig 5).
//!
//! * [`inject`] — deterministic word-level bitflip masks for every
//!   circuit stage, derived per `(seed, image, layer, channel, pixel,
//!   stage)` site so the packed [`ScEngine`] and the scalar
//!   stream-materializing executor draw identical faults.
//! * [`guard`] — count-domain integrity checks over the GEMM
//!   accumulation with scalar re-execution on violation, serving
//!   behind `scnn serve --guard`.
//! * [`ber_sweep`] / [`ber_sweep_on`] — the Fig 5 experiment: sweep
//!   bit-error rate over the SC and binary designs on the same frozen
//!   network and report accuracy loss relative to the fault-free
//!   ("soft") accuracy. The paper's claim: SC reduces average accuracy
//!   loss by ~70% versus the conventional binary design, because an SC
//!   bit flip perturbs the result by one quantization step while a
//!   binary MSB flip perturbs it by half the range.
//!
//! The sweep shards its (BER × repeat) grid across threads, each
//! worker running the packed engine; every point's RNG is a pure
//! function of `(seed, ber, repeat)` and every image's masks of its
//! index, so results are identical under any sweep order or degree of
//! parallelism.
//!
//! Injection sites, the count-domain folding algebra, and the
//! output-lane-vs-internal-wire modelling deviation are documented in
//! DESIGN.md §Fault model.

pub mod guard;
pub mod inject;

use std::sync::Arc;

use crate::data::{Dataset, Split};
use crate::nn::binary_exec::BinaryExecutor;
use crate::nn::sc_exec::{FaultCfg, Prepared};
use crate::nn::tensor::Tensor;
use crate::nn::ScEngine;

/// One row of the Fig 5 sweep.
#[derive(Clone, Copy, Debug)]
pub struct BerPoint {
    /// Bit-error rate.
    pub ber: f64,
    /// SC accuracy at this BER.
    pub acc_sc: f64,
    /// Binary accuracy at this BER.
    pub acc_binary: f64,
    /// Accuracy loss (soft − faulty) of the SC design.
    pub loss_sc: f64,
    /// Accuracy loss of the binary design.
    pub loss_binary: f64,
}

/// Full sweep result.
#[derive(Clone, Debug)]
pub struct BerSweep {
    /// Fault-free accuracy (both executors agree fault-free).
    pub soft_accuracy: f64,
    /// Points in BER order.
    pub points: Vec<BerPoint>,
}

impl BerSweep {
    /// Average accuracy-loss reduction of SC vs binary (the paper's
    /// "70%"): `1 - mean(loss_sc) / mean(loss_binary)`.
    pub fn avg_loss_reduction(&self) -> f64 {
        let (mut ls, mut lb) = (0.0, 0.0);
        for p in &self.points {
            ls += p.loss_sc.max(0.0);
            lb += p.loss_binary.max(0.0);
        }
        if lb <= 0.0 {
            return 0.0;
        }
        1.0 - ls / lb
    }
}

/// Run the Fig-5 sweep: evaluate `n_eval` test images at each BER with
/// `repeats` fault seeds and average. Convenience wrapper over
/// [`ber_sweep_on`].
pub fn ber_sweep(
    prep: &Prepared,
    data: &dyn Dataset,
    bers: &[f64],
    n_eval: usize,
    repeats: usize,
    seed: u64,
) -> BerSweep {
    let (images, labels) = data.batch(Split::Test, 0, n_eval);
    // One frozen model shared by every executor in the sweep (the Arc
    // clone is a refcount bump, not a copy of the weights/SI tables).
    let prep = Arc::new(prep.clone());
    ber_sweep_on(&prep, &images, &labels, bers, repeats, seed)
}

/// The BER sweep over an explicit image/label set.
///
/// The (BER × repeat) grid is sharded across `available_parallelism`
/// scoped worker threads, each owning one packed [`ScEngine`] (the
/// production datapath, re-seeded per point via
/// [`inject::point_seed`]) and the binary baseline. Every worker
/// writes a disjoint chunk of the result grid and every point's draws
/// are pure functions of `(seed, ber, repeat, image index)`, so the
/// result is bit-identical under any worker count or point order.
pub fn ber_sweep_on(
    prep: &Arc<Prepared>,
    images: &[Tensor],
    labels: &[usize],
    bers: &[f64],
    repeats: usize,
    seed: u64,
) -> BerSweep {
    let repeats = repeats.max(1);
    // Fault-free ("soft") accuracy, measured on the same packed engine
    // the faulted points run on.
    let soft = engine_accuracy(&mut ScEngine::new(prep.clone()), images, labels);
    let npts = bers.len() * repeats;
    let mut grid = vec![(0.0f64, 0.0f64); npts];
    let workers = std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
        .min(npts.max(1));
    let per = npts.div_ceil(workers.max(1)).max(1);
    std::thread::scope(|sc| {
        for (w, chunk) in grid.chunks_mut(per).enumerate() {
            sc.spawn(move || {
                let mut engine = ScEngine::new(prep.clone());
                for (k, slot) in chunk.iter_mut().enumerate() {
                    let idx = w * per + k;
                    let (bi, r) = (idx / repeats, idx % repeats);
                    let ber = bers[bi];
                    let fc = FaultCfg { ber, seed: inject::point_seed(seed, ber, r as u64) };
                    engine.set_fault(Some(fc));
                    let acc_sc = engine_accuracy(&mut engine, images, labels);
                    let acc_bin =
                        BinaryExecutor::with_faults(prep.clone(), fc).accuracy(images, labels);
                    *slot = (acc_sc, acc_bin);
                }
            });
        }
    });
    let points = bers
        .iter()
        .enumerate()
        .map(|(bi, &ber)| {
            let (mut acc_sc, mut acc_bin) = (0.0, 0.0);
            for &(s, b) in &grid[bi * repeats..(bi + 1) * repeats] {
                acc_sc += s;
                acc_bin += b;
            }
            acc_sc /= repeats as f64;
            acc_bin /= repeats as f64;
            BerPoint {
                ber,
                acc_sc,
                acc_binary: acc_bin,
                loss_sc: soft - acc_sc,
                loss_binary: soft - acc_bin,
            }
        })
        .collect();
    BerSweep { soft_accuracy: soft, points }
}

/// Accuracy of one engine over a labelled set (predict tags images by
/// index, so faulted accuracy is schedule-independent).
fn engine_accuracy(engine: &mut ScEngine, images: &[Tensor], labels: &[usize]) -> f64 {
    let preds = engine.predict(images);
    preds.iter().zip(labels).filter(|(p, l)| p == l).count() as f64
        / labels.len().max(1) as f64
}

/// Flip bits across a whole image's worth of activation codes — utility
/// for targeted robustness tests.
pub fn perturb_image(img: &Tensor, flip_fraction: f64, rng: &mut crate::util::Rng) -> Tensor {
    let mut out = img.clone();
    for v in out.data_mut() {
        if rng.gen_bool(flip_fraction) {
            *v = -*v;
        }
    }
    out
}

#[cfg(test)]
#[allow(clippy::unwrap_used, clippy::expect_used)]
mod tests {
    use super::*;
    use crate::data::SynthDigits;
    use crate::nn::model::{ModelCfg, ModelParams};
    use crate::nn::quant::{Pruning, QuantConfig};
    use crate::util::Rng;

    #[test]
    fn sweep_structure_and_monotonicity() {
        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(8);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        );
        let data = SynthDigits::new();
        let sweep = ber_sweep(&prep, &data, &[1e-4, 1e-2], 12, 1, 42);
        assert_eq!(sweep.points.len(), 2);
        // Low BER should hurt no more than high BER (within noise we
        // allow equality).
        assert!(sweep.points[0].loss_sc <= sweep.points[1].loss_sc + 0.2);
        for p in &sweep.points {
            assert!((0.0..=1.0).contains(&p.acc_sc));
            assert!((0.0..=1.0).contains(&p.acc_binary));
        }
    }

    #[test]
    fn sweep_is_invariant_to_point_order() {
        // Satellite contract: per-point seeds are pure functions of
        // (seed, ber, repeat), so reversing the BER grid (and with it
        // the parallel schedule) changes nothing per point.
        let cfg = ModelCfg::tnn();
        let mut rng = Rng::new(8);
        let params = ModelParams::init(&cfg, &mut rng);
        let prep = std::sync::Arc::new(Prepared::new(
            &cfg,
            &params,
            QuantConfig {
                act_bsl: Some(2),
                weight_ternary: true,
                residual_bsl: None,
                pruning: Pruning::Off,
            },
        ));
        let data = SynthDigits::new();
        let (images, labels) = data.batch(Split::Test, 0, 8);
        let fwd = ber_sweep_on(&prep, &images, &labels, &[1e-3, 1e-2], 2, 7);
        let rev = ber_sweep_on(&prep, &images, &labels, &[1e-2, 1e-3], 2, 7);
        for (a, b) in fwd.points.iter().zip(rev.points.iter().rev()) {
            assert_eq!(a.ber, b.ber);
            assert_eq!(a.acc_sc, b.acc_sc, "ber {}", a.ber);
            assert_eq!(a.acc_binary, b.acc_binary, "ber {}", a.ber);
        }
    }

    #[test]
    fn perturb_fraction_zero_is_identity() {
        let mut rng = Rng::new(1);
        let img = Tensor::from_vec(&[4], vec![1.0, -2.0, 3.0, -4.0]);
        let same = perturb_image(&img, 0.0, &mut rng);
        assert_eq!(img.data(), same.data());
    }
}
