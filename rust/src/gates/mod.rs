//! Gate primitives and circuit cost accounting.
//!
//! The paper reports hardware cost from a 28-nm CMOS implementation
//! (Table V, Figs 2/9/13). We reproduce those numbers with an analytical
//! gate-level model: every circuit in [`crate::circuits`] reports its
//! composition as a [`GateCount`], which the 28-nm library in
//! [`crate::cost`] converts to area (µm²), delay (ns) and energy (fJ).
//!
//! Calibration (see DESIGN.md §Substitutions): the per-gate area and
//! delay constants are chosen so the *baseline* BSN for the paper's
//! 3×3×512 convolution (4608 inputs × 2-bit BSL → 9216 bits, padded to
//! 16384) lands on Table V's reported 2.95e5 µm² / 4.33 ns. All other
//! results are then *predictions* of the model, and the paper's claims
//! we verify are ratios, which are insensitive to the calibration point.

/// Two-input (or unary) gate classes tracked by the cost model.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum GateKind {
    /// 2-input AND.
    And2,
    /// 2-input OR.
    Or2,
    /// 2-input XOR (FSM baselines, binary adders).
    Xor2,
    /// Inverter.
    Not,
    /// 2:1 multiplexer (selective interconnect, sampling).
    Mux2,
    /// D flip-flop (temporal folding registers, FSM state).
    Dff,
}

impl GateKind {
    /// All kinds, for iteration.
    pub const ALL: [GateKind; 6] = [
        GateKind::And2,
        GateKind::Or2,
        GateKind::Xor2,
        GateKind::Not,
        GateKind::Mux2,
        GateKind::Dff,
    ];

    /// Area in NAND2-equivalents (standard-cell folklore ratios).
    pub fn nand2_eq(self) -> f64 {
        match self {
            GateKind::And2 => 1.0,
            GateKind::Or2 => 1.0,
            GateKind::Xor2 => 2.5,
            GateKind::Not => 0.5,
            GateKind::Mux2 => 2.0,
            GateKind::Dff => 4.5,
        }
    }

    /// Delay in units of one nominal 2-input gate delay.
    pub fn delay_eq(self) -> f64 {
        match self {
            GateKind::And2 => 1.0,
            GateKind::Or2 => 1.0,
            GateKind::Xor2 => 1.4,
            GateKind::Not => 0.4,
            GateKind::Mux2 => 1.2,
            GateKind::Dff => 2.0, // clk-to-q + setup, folded into one unit
        }
    }

    /// Switching energy in units of one nominal gate toggle.
    pub fn energy_eq(self) -> f64 {
        match self {
            GateKind::And2 => 1.0,
            GateKind::Or2 => 1.0,
            GateKind::Xor2 => 2.0,
            GateKind::Not => 0.4,
            GateKind::Mux2 => 1.6,
            GateKind::Dff => 3.0,
        }
    }
}

/// A multiset of gates plus the combinational depth along the critical
/// path — the raw "netlist summary" every circuit module reports.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct GateCount {
    /// Gate counts, indexed by [`GateKind::ALL`] order.
    counts: [u64; 6],
    /// Critical-path depth in nominal gate-delay units.
    pub depth: f64,
}

impl GateCount {
    /// The empty circuit.
    pub fn new() -> Self {
        Self::default()
    }

    /// Add `n` gates of a kind (does not touch depth).
    pub fn add(&mut self, kind: GateKind, n: u64) {
        let i = GateKind::ALL.iter().position(|&k| k == kind).unwrap();
        self.counts[i] += n;
    }

    /// Builder-style [`GateCount::add`].
    pub fn with(mut self, kind: GateKind, n: u64) -> Self {
        self.add(kind, n);
        self
    }

    /// Count of a kind.
    pub fn get(&self, kind: GateKind) -> u64 {
        let i = GateKind::ALL.iter().position(|&k| k == kind).unwrap();
        self.counts[i]
    }

    /// Total gates of all kinds.
    pub fn total(&self) -> u64 {
        self.counts.iter().sum()
    }

    /// Total area in NAND2 equivalents.
    pub fn nand2_eq(&self) -> f64 {
        GateKind::ALL
            .iter()
            .map(|&k| self.get(k) as f64 * k.nand2_eq())
            .sum()
    }

    /// Total switching energy in nominal toggle units (assumes every gate
    /// toggles once per operation — a standard activity=1 upper-bound
    /// model; the cost library applies an activity factor).
    pub fn energy_eq(&self) -> f64 {
        GateKind::ALL
            .iter()
            .map(|&k| self.get(k) as f64 * k.energy_eq())
            .sum()
    }

    /// Compose two blocks in **series** (depths add, gates add).
    pub fn series(&self, other: &GateCount) -> GateCount {
        let mut out = self.clone();
        for (i, c) in other.counts.iter().enumerate() {
            out.counts[i] += c;
        }
        out.depth = self.depth + other.depth;
        out
    }

    /// Compose two blocks in **parallel** (gates add, depth is the max).
    pub fn parallel(&self, other: &GateCount) -> GateCount {
        let mut out = self.clone();
        for (i, c) in other.counts.iter().enumerate() {
            out.counts[i] += c;
        }
        out.depth = self.depth.max(other.depth);
        out
    }

    /// Replicate this block `n` times in parallel.
    pub fn replicate(&self, n: u64) -> GateCount {
        let mut out = self.clone();
        for c in out.counts.iter_mut() {
            *c *= n;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn add_and_get() {
        let mut g = GateCount::new();
        g.add(GateKind::And2, 3);
        g.add(GateKind::Or2, 2);
        g.add(GateKind::And2, 1);
        assert_eq!(g.get(GateKind::And2), 4);
        assert_eq!(g.get(GateKind::Or2), 2);
        assert_eq!(g.total(), 6);
    }

    #[test]
    fn series_adds_depth() {
        let a = GateCount { counts: [1, 0, 0, 0, 0, 0], depth: 2.0 };
        let b = GateCount { counts: [0, 1, 0, 0, 0, 0], depth: 3.0 };
        let s = a.series(&b);
        assert_eq!(s.depth, 5.0);
        assert_eq!(s.total(), 2);
    }

    #[test]
    fn parallel_takes_max_depth() {
        let a = GateCount { counts: [1, 0, 0, 0, 0, 0], depth: 2.0 };
        let b = GateCount { counts: [0, 1, 0, 0, 0, 0], depth: 3.0 };
        let p = a.parallel(&b);
        assert_eq!(p.depth, 3.0);
        assert_eq!(p.total(), 2);
    }

    #[test]
    fn replicate_scales_gates_not_depth() {
        let a = GateCount { counts: [2, 1, 0, 0, 0, 0], depth: 4.0 };
        let r = a.replicate(8);
        assert_eq!(r.get(GateKind::And2), 16);
        assert_eq!(r.get(GateKind::Or2), 8);
        assert_eq!(r.depth, 4.0);
    }

    #[test]
    fn nand2_eq_weights() {
        let g = GateCount::new().with(GateKind::Dff, 2).with(GateKind::Not, 2);
        assert!((g.nand2_eq() - (2.0 * 4.5 + 2.0 * 0.5)).abs() < 1e-12);
    }
}
