//! Chip-level voltage/frequency power model (paper Fig 4).
//!
//! The fabricated 28-nm chip [4] reports current and energy efficiency
//! versus supply voltage at several clock frequencies, peaking at
//! **198.9 TOPS/W at 200 MHz / 650 mV**. We reproduce the measurement
//! with a standard alpha-power-law model:
//!
//! * gate delay `d(V) ∝ V / (V - Vth)^alpha` bounds the maximum
//!   frequency at each voltage (the chip only *works* above `Vmin(f)`);
//! * dynamic energy per op scales as `V²`;
//! * leakage power scales super-linearly with `V` and is amortized over
//!   fewer ops at low frequency — producing the efficiency roll-off that
//!   makes (650 mV, 200 MHz) the sweet spot.
//!
//! Calibrated so the peak is 198.9 TOPS/W at exactly that point.

/// Threshold voltage of the alpha-power delay model (V).
pub const VTH: f64 = 0.35;
/// Velocity-saturation exponent.
pub const ALPHA: f64 = 1.7;
/// Nominal supply (V).
pub const VDD_NOM: f64 = 0.9;
/// Maximum clock at nominal supply (MHz).
pub const FMAX_NOM_MHZ: f64 = 405.0;

/// Dynamic energy per operation at nominal supply (fJ/op). Calibrated —
/// see [`ChipPowerModel::efficiency_tops_w`] docs.
pub const E_OP_NOM_FJ: f64 = 8.39;
/// Leakage power at nominal supply (mW).
pub const P_LEAK_NOM_MW: f64 = 5.97;
/// Leakage voltage sensitivity: `P_leak ∝ (V/0.9) · 10^((V-0.9)/S)`.
pub const LEAK_S: f64 = 0.45;

/// Operations per cycle of the modeled chip: 4608 MACs × 2 ops — the
/// fully-parallel 3×3×512 SC conv engine.
pub const OPS_PER_CYCLE: f64 = 9216.0;

/// Minimum functional supply regardless of frequency (logic/SRAM
/// retention floor — why the measured peak sits at 650 mV / 200 MHz
/// rather than at ever-lower voltage).
pub const VMIN_FUNC: f64 = 0.63;

/// One (voltage, frequency) operating point evaluation.
#[derive(Clone, Copy, Debug)]
pub struct OperatingPoint {
    /// Supply voltage (V).
    pub vdd: f64,
    /// Clock (MHz).
    pub freq_mhz: f64,
    /// Whether timing closes at this voltage.
    pub functional: bool,
    /// Total power (mW).
    pub power_mw: f64,
    /// Supply current (mA).
    pub current_ma: f64,
    /// Energy efficiency (TOPS/W); 0 when not functional.
    pub tops_per_w: f64,
}

/// Alpha-power chip model.
#[derive(Clone, Copy, Debug, Default)]
pub struct ChipPowerModel;

impl ChipPowerModel {
    /// Relative gate-delay factor versus nominal supply.
    pub fn delay_factor(vdd: f64) -> f64 {
        let d = |v: f64| v / (v - VTH).max(1e-3).powf(ALPHA);
        d(vdd) / d(VDD_NOM)
    }

    /// Maximum functional frequency at a supply voltage (MHz).
    pub fn fmax_mhz(vdd: f64) -> f64 {
        if vdd <= VTH {
            return 0.0;
        }
        FMAX_NOM_MHZ / Self::delay_factor(vdd)
    }

    /// Dynamic energy per op at a supply (fJ).
    pub fn e_op_fj(vdd: f64) -> f64 {
        E_OP_NOM_FJ * (vdd / VDD_NOM).powi(2)
    }

    /// Leakage power at a supply (mW).
    pub fn p_leak_mw(vdd: f64) -> f64 {
        P_LEAK_NOM_MW * (vdd / VDD_NOM) * 10f64.powf((vdd - VDD_NOM) / LEAK_S)
    }

    /// Evaluate an operating point.
    pub fn evaluate(vdd: f64, freq_mhz: f64) -> OperatingPoint {
        let functional = vdd >= VMIN_FUNC && freq_mhz <= Self::fmax_mhz(vdd) + 1e-9;
        let ops_per_s = OPS_PER_CYCLE * freq_mhz * 1e6;
        // fJ/op * ops/s = 1e-15 J/op * ops/s W -> mW factor 1e-12
        let p_dyn_mw = Self::e_op_fj(vdd) * ops_per_s * 1e-12;
        let power_mw = p_dyn_mw + Self::p_leak_mw(vdd);
        let current_ma = power_mw / vdd;
        let tops = ops_per_s / 1e12;
        let tops_per_w = if functional { tops / (power_mw / 1000.0) } else { 0.0 };
        OperatingPoint { vdd, freq_mhz, functional, power_mw, current_ma, tops_per_w }
    }

    /// Sweep the Fig-4 grid: voltages 0.5–0.9 V at the given frequencies.
    pub fn sweep(freqs_mhz: &[f64], v_steps: usize) -> Vec<OperatingPoint> {
        let mut out = Vec::new();
        for &f in freqs_mhz {
            for i in 0..v_steps {
                let vdd = 0.5 + 0.4 * i as f64 / (v_steps - 1) as f64;
                out.push(Self::evaluate(vdd, f));
            }
        }
        out
    }

    /// The peak efficiency over a sweep (the paper's headline number).
    pub fn peak_efficiency(freqs_mhz: &[f64], v_steps: usize) -> OperatingPoint {
        Self::sweep(freqs_mhz, v_steps)
            .into_iter()
            .filter(|p| p.functional)
            .max_by(|a, b| a.tops_per_w.total_cmp(&b.tops_per_w))
            .expect("no functional operating point")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn peak_is_198_9_tops_w_at_650mv_200mhz() {
        let p = ChipPowerModel::evaluate(0.65, 200.0);
        assert!(p.functional, "200 MHz must close timing at 650 mV");
        assert!(
            (p.tops_per_w - 198.9).abs() < 6.0,
            "calibration drifted: {} TOPS/W",
            p.tops_per_w
        );
    }

    #[test]
    fn fmax_monotone_in_vdd() {
        let mut prev = 0.0;
        for i in 0..20 {
            let v = 0.45 + i as f64 * 0.025;
            let f = ChipPowerModel::fmax_mhz(v);
            assert!(f >= prev, "fmax must grow with vdd");
            prev = f;
        }
    }

    #[test]
    fn not_functional_below_vmin() {
        // 400 MHz can't run at 0.5 V in this model.
        let p = ChipPowerModel::evaluate(0.5, 400.0);
        assert!(!p.functional);
        assert_eq!(p.tops_per_w, 0.0);
        // ...and nothing runs below the functional floor.
        assert!(!ChipPowerModel::evaluate(0.6, 50.0).functional);
    }

    #[test]
    fn global_peak_is_at_650mv_200mhz() {
        let peak = ChipPowerModel::peak_efficiency(&[50.0, 100.0, 200.0, 400.0], 41);
        assert!((peak.vdd - 0.65).abs() < 0.011, "peak vdd {}", peak.vdd);
        assert_eq!(peak.freq_mhz, 200.0);
        assert!((peak.tops_per_w - 198.9).abs() < 3.0, "peak {}", peak.tops_per_w);
    }

    #[test]
    fn current_grows_with_voltage_at_fixed_freq() {
        let lo = ChipPowerModel::evaluate(0.7, 100.0);
        let hi = ChipPowerModel::evaluate(0.9, 100.0);
        assert!(hi.current_ma > lo.current_ma);
    }

    #[test]
    fn efficiency_drops_at_high_voltage() {
        let lo = ChipPowerModel::evaluate(0.65, 200.0);
        let hi = ChipPowerModel::evaluate(0.9, 200.0);
        assert!(lo.tops_per_w > hi.tops_per_w);
    }

    #[test]
    fn sweep_covers_grid() {
        let pts = ChipPowerModel::sweep(&[50.0, 100.0, 200.0, 400.0], 9);
        assert_eq!(pts.len(), 36);
        let peak = ChipPowerModel::peak_efficiency(&[50.0, 100.0, 200.0, 400.0], 41);
        assert!(peak.tops_per_w > 150.0);
    }
}
