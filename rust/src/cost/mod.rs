//! 28-nm-calibrated hardware cost library.
//!
//! Converts [`GateCount`] netlist summaries into physical area / delay /
//! energy, and defines the **ADP** (area-delay product) figure of merit
//! the paper reports throughout (Fig 2, Table IV, Table V, Fig 13).
//!
//! ## Calibration
//!
//! Two constants anchor the model to the paper's silicon:
//!
//! * `AREA_NAND2_UM2` — chosen so the baseline 16384-bit BSN (the
//!   padded 3×3×512-conv accumulator of Table V) reports ≈ 2.95e5 µm².
//! * `DELAY_GATE_NS` — chosen so the same BSN's 105-stage critical path
//!   reports ≈ 4.33 ns.
//!
//! Everything else (energy scaling, leakage) is a textbook alpha-power
//! model calibrated against the chip's reported 198.9 TOPS/W peak at
//! 0.65 V / 200 MHz (Fig 4) — see [`power`].

pub mod power;

use crate::gates::GateCount;

/// NAND2-equivalent cell area in µm² (28-nm high-density calibration;
/// see module docs).
pub const AREA_NAND2_UM2: f64 = 0.3101;

/// Nominal 2-input gate delay in ns at 0.9 V.
pub const DELAY_GATE_NS: f64 = 0.04124;

/// Nominal gate switching energy in fJ at 0.9 V (per toggle).
pub const ENERGY_GATE_FJ: f64 = 0.18;

/// Average switching-activity factor applied to the activity=1 energy
/// upper bound of [`GateCount::energy_eq`].
pub const ACTIVITY_FACTOR: f64 = 0.22;

/// Physical cost of a circuit block.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct Cost {
    /// Silicon area in µm².
    pub area_um2: f64,
    /// Combinational / total latency in ns.
    pub delay_ns: f64,
    /// Energy per operation in fJ.
    pub energy_fj: f64,
}

impl Cost {
    /// Area-delay product in µm²·ns — the paper's primary efficiency
    /// metric (Table V uses µm²·ns; Table IV and Fig 2 use µm²·µs for
    /// full-layer latencies).
    pub fn adp(&self) -> f64 {
        self.area_um2 * self.delay_ns
    }

    /// ADP expressed in µm²·µs.
    pub fn adp_um2_us(&self) -> f64 {
        self.adp() / 1000.0
    }

    /// Series composition: areas and energies add, delays add.
    pub fn series(&self, other: &Cost) -> Cost {
        Cost {
            area_um2: self.area_um2 + other.area_um2,
            delay_ns: self.delay_ns + other.delay_ns,
            energy_fj: self.energy_fj + other.energy_fj,
        }
    }

    /// Parallel composition: areas and energies add, delay is the max.
    pub fn parallel(&self, other: &Cost) -> Cost {
        Cost {
            area_um2: self.area_um2 + other.area_um2,
            delay_ns: self.delay_ns.max(other.delay_ns),
            energy_fj: self.energy_fj + other.energy_fj,
        }
    }

    /// A multi-cycle block: same area, `cycles ×` delay and energy (the
    /// spatial-temporal BSN's reuse model, §IV.B).
    pub fn over_cycles(&self, cycles: u64) -> Cost {
        Cost {
            area_um2: self.area_um2,
            delay_ns: self.delay_ns * cycles as f64,
            energy_fj: self.energy_fj * cycles as f64,
        }
    }
}

/// Convert a gate-count summary into physical cost at nominal voltage.
pub fn cost_of(gates: &GateCount) -> Cost {
    Cost {
        area_um2: gates.nand2_eq() * AREA_NAND2_UM2,
        delay_ns: gates.depth * DELAY_GATE_NS,
        energy_fj: gates.energy_eq() * ENERGY_GATE_FJ * ACTIVITY_FACTOR,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gates::GateKind;

    #[test]
    fn cost_of_simple_block() {
        let g = GateCount::new().with(GateKind::And2, 100);
        let c = cost_of(&g);
        assert!((c.area_um2 - 100.0 * AREA_NAND2_UM2).abs() < 1e-9);
        assert_eq!(c.delay_ns, 0.0); // depth not set
    }

    #[test]
    fn adp_units() {
        let c = Cost { area_um2: 1000.0, delay_ns: 2.0, energy_fj: 0.0 };
        assert!((c.adp() - 2000.0).abs() < 1e-12);
        assert!((c.adp_um2_us() - 2.0).abs() < 1e-12);
    }

    #[test]
    fn series_parallel_cycles() {
        let a = Cost { area_um2: 10.0, delay_ns: 1.0, energy_fj: 5.0 };
        let b = Cost { area_um2: 20.0, delay_ns: 3.0, energy_fj: 1.0 };
        let s = a.series(&b);
        assert_eq!((s.area_um2, s.delay_ns, s.energy_fj), (30.0, 4.0, 6.0));
        let p = a.parallel(&b);
        assert_eq!((p.area_um2, p.delay_ns, p.energy_fj), (30.0, 3.0, 6.0));
        let m = a.over_cycles(4);
        assert_eq!((m.area_um2, m.delay_ns, m.energy_fj), (10.0, 4.0, 20.0));
    }
}
