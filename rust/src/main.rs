//! `scnn` — CLI for the end-to-end SC accelerator reproduction.
//!
//! ```text
//! scnn exp <id>|all [--full] [--artifacts DIR] [--seed N]
//! scnn train --model NAME [--steps N] [--act-bsl B] [--artifacts DIR]
//! scnn serve --model NAME [--workers N] [--clients N] [--requests N]
//!            [--backend auto|pjrt|synthetic|sc|binary] [--batch N]
//!            [--threads N] [--seed N] [--shed] [--restart-budget N] [--guard]
//!            [--prune N:M] [--prune-block S]
//!            [--artifacts DIR] [--listen ADDR] [--models a,b|all]
//!            [--tenant-quota N] [--duration SECS]
//! scnn client --addr HOST:PORT [--model NAME] [--requests N]
//!             [--tenant ID] [--priority high|normal|low]
//!             [--deadline-ms N] [--retries N] [--metrics]
//! scnn info
//! ```
//!
//! (The offline environment has no clap; arguments are parsed by hand.)

use std::collections::HashMap;
use std::sync::Arc;

use scnn::coordinator::backend::MODEL_NAMES;
use scnn::coordinator::{
    Backend, Coordinator, ModelRegistry, NetClient, NetServer, OverloadPolicy, Priority,
    ServeConfig, Status, TenantPolicy,
};
use scnn::data::{Dataset, Split, SynthCifar, SynthDigits};
use scnn::exp;
use scnn::runtime::{trainer::Knobs, Runtime, Trainer};
use scnn::Result;

fn parse_flags(args: &[String]) -> (Vec<String>, HashMap<String, String>) {
    let mut pos = Vec::new();
    let mut flags = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(name) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                flags.insert(name.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                flags.insert(name.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            pos.push(a.clone());
            i += 1;
        }
    }
    (pos, flags)
}

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let (pos, flags) = parse_flags(&args);
    let cmd = pos.first().map(String::as_str).unwrap_or("help");
    let artifacts = flags.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
    let seed: u64 = flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    match cmd {
        "exp" => {
            let id = pos.get(1).map(String::as_str).unwrap_or("all");
            let opts = exp::Opts {
                quick: !flags.contains_key("full"),
                artifacts,
                seed,
            };
            if id == "all" {
                for id in exp::ALL_IDS {
                    exp::run(id, &opts)?;
                }
            } else {
                exp::run(id, &opts)?;
            }
            Ok(())
        }
        "train" => cmd_train(&flags, &artifacts),
        "serve" => cmd_serve(&flags, &artifacts),
        "client" => cmd_client(&flags),
        "info" => cmd_info(&artifacts),
        _ => {
            println!(
                "usage: scnn <exp|train|serve|client|info> [...]\n\
                 \n  exp <id>|all [--full] [--artifacts DIR] [--seed N]\n\
                 \n      ids: {}\n\
                 \n  train --model tnn|scnet10|scnet20 [--steps N] [--act-bsl B] [--res-bsl B]\n\
                 \n  serve --model NAME [--workers N] [--clients N] [--requests N] [--steps N]\n\
                 \n        [--backend auto|pjrt|synthetic|sc|binary] [--batch N] [--threads N]\n\
                 \n        [--seed N] [--shed] [--restart-budget N] [--guard]\n\
                 \n        [--prune N:M] [--prune-block S]\n\
                 \n        (--seed pins the sc/binary backends' deterministic model freeze;\n\
                 \n         --threads shards each sc-backend batch across N engine threads;\n\
                 \n         --restart-budget caps worker respawns after panics, default 3;\n\
                 \n         --guard arms the sc backend's count-domain integrity checks;\n\
                 \n         --prune keeps the N largest weights per aligned group of M,\n\
                 \n         --prune-block drops whole weak weight blocks at freeze time)\n\
                 \n        [--listen ADDR] serve over TCP instead of an in-process loop:\n\
                 \n        [--models a,b|all] [--tenant-quota N] [--duration SECS]\n\
                 \n  client --addr HOST:PORT [--model NAME] [--requests N] [--tenant ID]\n\
                 \n        [--priority high|normal|low] [--deadline-ms N] [--retries N] [--metrics]\n\
                 \n  info   print runtime/artifact status",
                exp::ALL_IDS.join(" ")
            );
            Ok(())
        }
    }
}

fn dataset_for(model: &str) -> Box<dyn Dataset> {
    if model == "tnn" {
        Box::new(SynthDigits::new())
    } else if model == "scnet20" {
        Box::new(SynthCifar::new(20))
    } else {
        Box::new(SynthCifar::new(10))
    }
}

fn knobs_from_flags(flags: &HashMap<String, String>) -> Knobs {
    let act_bsl: usize = flags.get("act-bsl").and_then(|s| s.parse().ok()).unwrap_or(2);
    let res_bsl: Option<usize> = match flags.get("res-bsl").map(String::as_str) {
        Some("none") => None,
        Some(s) => s.parse().ok(),
        None => Some(16),
    };
    let mut knobs = Knobs::quantized(act_bsl).with_res_bsl(res_bsl);
    // `--prune N:M` (magnitude N-of-M weight pruning at freeze time)
    // and `--prune-block S` (whole-block pruning) are mutually
    // exclusive; the backend validates and reports bad combinations.
    if let Some((n, m)) = flags.get("prune").and_then(|s| {
        let (n, m) = s.split_once(':')?;
        Some((n.trim().parse::<f32>().ok()?, m.trim().parse::<f32>().ok()?))
    }) {
        knobs = knobs.with_pruning(n, m);
    }
    if let Some(b) = flags.get("prune-block").and_then(|s| s.parse::<f32>().ok()) {
        knobs = knobs.with_block_pruning(b);
    }
    knobs
}

fn cmd_train(flags: &HashMap<String, String>, artifacts: &str) -> Result<()> {
    let model = flags.get("model").cloned().unwrap_or_else(|| "scnet10".into());
    let steps: usize = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(200);
    let lr: f32 = flags.get("lr").and_then(|s| s.parse().ok()).unwrap_or(0.02);
    let knobs = knobs_from_flags(flags);
    let rt = Runtime::new(artifacts)?;
    println!("platform: {}", rt.platform());
    let data = dataset_for(&model);
    let mut tr = Trainer::new(&rt, &model)?;
    println!(
        "training {model}: {} params, batch {}, {} steps, knobs {:?}",
        tr.meta().total_elems(),
        tr.meta().batch,
        steps,
        knobs
    );
    let t0 = std::time::Instant::now();
    tr.train_qat(data.as_ref(), steps / 2, steps / 2, lr, knobs, |s, loss| {
        if s % 20 == 0 {
            println!("step {s:>5}  loss {loss:.4}");
        }
    })?;
    let dt = t0.elapsed();
    let acc_q = tr.accuracy(data.as_ref(), 512, knobs, false)?;
    let acc_s = tr.accuracy(data.as_ref(), 512, knobs, true)?;
    println!(
        "done in {:.1}s ({:.1} steps/s); accuracy fake-quant {acc_q:.4}, serving (Pallas) {acc_s:.4}",
        dt.as_secs_f64(),
        steps as f64 / dt.as_secs_f64()
    );
    Ok(())
}

/// Build one model's [`ServeConfig`] from the shared serve flags.
fn serve_cfg(flags: &HashMap<String, String>, artifacts: &str, model: &str) -> ServeConfig {
    let workers: usize = flags.get("workers").and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let threads: usize = flags.get("threads").and_then(|s| s.parse().ok()).unwrap_or(1).max(1);
    let seed: u64 = flags.get("seed").and_then(|s| s.parse().ok()).unwrap_or(42);
    let mut policy = scnn::coordinator::BatchPolicy::default();
    if flags.contains_key("shed") {
        policy.overload = OverloadPolicy::Shed;
    }
    let mut cfg = ServeConfig::new(artifacts, model);
    cfg.knobs = knobs_from_flags(flags);
    cfg.workers = workers;
    cfg.threads = threads;
    cfg.policy = policy;
    cfg.seed = seed;
    if let Some(b) = flags.get("batch").and_then(|s| s.parse().ok()) {
        cfg.batch = b;
    }
    if let Some(r) = flags.get("restart-budget").and_then(|s| s.parse().ok()) {
        cfg.restart_budget = r;
    }
    cfg.guard = flags.contains_key("guard");
    cfg
}

fn cmd_serve(flags: &HashMap<String, String>, artifacts: &str) -> Result<()> {
    if let Some(listen) = flags.get("listen") {
        return cmd_serve_net(flags, artifacts, listen);
    }
    let model = flags.get("model").cloned().unwrap_or_else(|| "scnet10".into());
    let requests: usize = flags.get("requests").and_then(|s| s.parse().ok()).unwrap_or(512);
    let steps: usize = flags.get("steps").and_then(|s| s.parse().ok()).unwrap_or(0);
    let clients: usize = flags.get("clients").and_then(|s| s.parse().ok()).unwrap_or(4).max(1);
    let backend = Backend::parse(flags.get("backend").map(String::as_str).unwrap_or("auto"))?;
    let data = dataset_for(&model);
    let mut cfg = serve_cfg(flags, artifacts, &model);
    let (workers, threads, knobs) = (cfg.workers, cfg.threads, cfg.knobs);
    let resolved = backend.resolve(artifacts, &model);
    println!("backend: {resolved}");
    if resolved == Backend::Pjrt && steps > 0 {
        println!("warm-up training for {steps} steps...");
        let rt = Runtime::new(artifacts)?;
        let mut tr = Trainer::new(&rt, &model)?;
        tr.train_qat(data.as_ref(), steps / 2, steps / 2, 0.05, knobs, |_, _| {})?;
        cfg.params = Some(tr.params().to_vec());
    }
    let coord = Coordinator::start_backend(resolved, cfg)?;
    let client = coord.client();
    let (c, h, w) = data.shape();
    println!(
        "serving {model} ({c}x{h}x{w}); {workers} workers x {threads} engine threads; \
         issuing {requests} requests from {clients} client threads"
    );
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for t in 0..clients {
        let client = client.clone();
        let data = dataset_for(&model);
        let n = requests / clients;
        handles.push(std::thread::spawn(move || -> Result<(usize, usize)> {
            let mut hits = 0usize;
            let mut shed = 0usize;
            for i in 0..n {
                let (x, y) = data.sample(Split::Test, t * 100_000 + i);
                match client.classify(x.into_vec()) {
                    Ok(pred) if pred == y => hits += 1,
                    Ok(_) => {}
                    Err(e) if scnn::coordinator::is_shed_error(&e) => shed += 1,
                    Err(e) => return Err(e),
                }
            }
            Ok((hits, shed))
        }));
    }
    let (mut hits, mut shed) = (0usize, 0usize);
    for h in handles {
        let (h_hits, h_shed) = h.join().unwrap()?;
        hits += h_hits;
        shed += h_shed;
    }
    let dt = t0.elapsed();
    let m = coord.shutdown();
    println!(
        "served {} requests in {:.2}s -> {:.0} req/s; accuracy {:.4}",
        m.requests,
        dt.as_secs_f64(),
        m.requests as f64 / dt.as_secs_f64(),
        // Accuracy over *served* requests: shed ones never produced a
        // prediction and must not deflate the number.
        hits as f64 / m.requests.max(1) as f64
    );
    println!(
        "batches {} (occupancy {:.2}), latency p50 {:?} p99 {:?}, shed {} (client-observed {})",
        m.batches, m.occupancy, m.p50, m.p99, m.shed, shed
    );
    for w in &m.per_worker {
        println!(
            "  worker {}: {} requests in {} batches ({} errors)",
            w.worker, w.requests, w.batches, w.errors
        );
    }
    Ok(())
}

/// `scnn serve --listen ADDR`: the TCP front-end over a multi-model
/// registry, serving until `--duration SECS` elapses (forever when
/// the flag is absent).
fn cmd_serve_net(flags: &HashMap<String, String>, artifacts: &str, listen: &str) -> Result<()> {
    let models: Vec<String> = match flags.get("models").map(String::as_str) {
        Some("all") => MODEL_NAMES.iter().map(|s| s.to_string()).collect(),
        Some(list) => {
            list.split(',').map(str::trim).filter(|s| !s.is_empty()).map(String::from).collect()
        }
        None => vec![flags.get("model").cloned().unwrap_or_else(|| "scnet10".into())],
    };
    anyhow::ensure!(!models.is_empty(), "--models expanded to an empty list");
    let quota: usize = flags.get("tenant-quota").and_then(|s| s.parse().ok()).unwrap_or(0);
    let backend = Backend::parse(flags.get("backend").map(String::as_str).unwrap_or("auto"))?;
    let registry = Arc::new(ModelRegistry::new(TenantPolicy { max_inflight: quota }));
    for name in &models {
        let cfg = serve_cfg(flags, artifacts, name);
        let resolved = backend.resolve(artifacts, name);
        println!("model {name}: backend {resolved}");
        let _ = registry.register_backend(resolved, cfg)?;
    }
    let server = NetServer::bind(listen, registry.clone())?;
    println!(
        "listening on {} ({} models: {}; tenant quota {})",
        server.local_addr(),
        registry.len(),
        registry.names().join(", "),
        if quota == 0 { "off".to_string() } else { quota.to_string() }
    );
    match flags.get("duration").and_then(|s| s.parse::<f64>().ok()) {
        Some(secs) => std::thread::sleep(std::time::Duration::from_secs_f64(secs)),
        None => loop {
            std::thread::park();
        },
    }
    server.shutdown();
    for (name, m) in registry.shutdown_all() {
        println!(
            "{name}: {} requests in {} batches, p50 {:?} p99 {:?}, shed {}",
            m.requests, m.batches, m.p50, m.p99, m.shed
        );
    }
    Ok(())
}

/// `scnn client`: smoke traffic (or a metrics scrape) against a
/// running `scnn serve --listen` front-end.
fn cmd_client(flags: &HashMap<String, String>) -> Result<()> {
    let addr = flags
        .get("addr")
        .ok_or_else(|| anyhow::anyhow!("client requires --addr HOST:PORT"))?;
    let model = flags.get("model").cloned().unwrap_or_else(|| "scnet10".into());
    let requests: usize = flags.get("requests").and_then(|s| s.parse().ok()).unwrap_or(16);
    let tenant = flags.get("tenant").cloned().unwrap_or_else(|| "default".into());
    let priority = Priority::parse(flags.get("priority").map(String::as_str).unwrap_or("normal"))?;
    let deadline = flags
        .get("deadline-ms")
        .and_then(|s| s.parse::<u64>().ok())
        .filter(|&ms| ms > 0)
        .map(std::time::Duration::from_millis);
    let mut client =
        NetClient::connect(addr.as_str())?.with_tenant(&tenant).with_priority(priority);
    client = client.with_deadline(deadline);
    if let Some(r) = flags.get("retries").and_then(|s| s.parse().ok()) {
        client = client.with_retries(r);
    }
    if flags.contains_key("metrics") {
        print!("{}", client.metrics_text()?);
        return Ok(());
    }
    let data = dataset_for(&model);
    let (mut ok, mut shed, mut expired, mut hits) = (0usize, 0usize, 0usize, 0usize);
    let t0 = std::time::Instant::now();
    for i in 0..requests {
        let (x, y) = data.sample(Split::Test, i);
        let resp = client.request(&model, &x.into_vec())?;
        match resp.status {
            Status::Ok => {
                ok += 1;
                let pred = resp
                    .logits
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap_or(0);
                if pred == y {
                    hits += 1;
                }
            }
            Status::Shed => shed += 1,
            Status::Expired => expired += 1,
            s => anyhow::bail!("server rejected request ({s:?}): {}", resp.message),
        }
    }
    let dt = t0.elapsed();
    println!(
        "{ok}/{requests} ok ({shed} shed, {expired} expired) in {:.2}s -> {:.0} req/s; accuracy {:.4}",
        dt.as_secs_f64(),
        requests as f64 / dt.as_secs_f64().max(1e-9),
        hits as f64 / ok.max(1) as f64
    );
    Ok(())
}

fn cmd_info(artifacts: &str) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    println!("PJRT platform: {}", rt.platform());
    for model in ["tnn", "scnet10", "scnet20"] {
        match rt.load_meta(model) {
            Ok(m) => println!(
                "{model}: {} classes, input {:?}, batch {}, {} params ({} scalars)",
                m.classes,
                m.input,
                m.batch,
                m.params.len(),
                m.total_elems()
            ),
            Err(e) => println!("{model}: unavailable ({e})"),
        }
    }
    Ok(())
}
