//! Cross-module integration tests (no PJRT; see `runtime_hlo.rs` for
//! the artifact-backed path).

use scnn::accel::{self, schedule::Schedule, RESNET18_ACC_WIDTHS};
use scnn::circuits::multiplier::TernaryMultiplier;
use scnn::circuits::si::{ActivationFn, SelectiveInterconnect};
use scnn::circuits::{Bsn, RescaleBlock};
use scnn::coding::{Ternary, ThermCode};
use scnn::cost::power::ChipPowerModel;
use scnn::data::{Dataset, Split, SynthCifar, SynthDigits};
use scnn::exp::{self, Opts};
use scnn::nn::binary_exec::{accuracy_float, BinaryExecutor};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::{Pruning, QuantConfig};
use scnn::nn::sc_exec::{FaultCfg, Prepared, ScExecutor};
use scnn::util::Rng;

/// §II micro-pipeline: encode → 5-gate multiply → gate-level BSN → SI,
/// against integer arithmetic, across widths and BSLs.
#[test]
fn sc_dot_product_pipeline_exact() {
    let mut rng = Rng::new(1);
    for bsl in [2usize, 4, 8] {
        for n in [4usize, 9, 16, 27] {
            let half = (bsl / 2) as i64;
            let acts: Vec<i64> = (0..n).map(|_| rng.gen_range_i64(-half, half)).collect();
            let ws: Vec<Ternary> =
                (0..n).map(|_| Ternary::from_i64(rng.gen_range_i64(-1, 1))).collect();
            let products: Vec<ThermCode> = acts
                .iter()
                .zip(&ws)
                .map(|(&a, &w)| TernaryMultiplier::mult_therm(&ThermCode::encode(a, bsl), w))
                .collect();
            let bsn = Bsn::new(n * bsl);
            let sorted = bsn.sort_gate_level(&Bsn::concat(&products));
            let acc = ThermCode::from_bits(sorted.clone());
            let expect: i64 = acts.iter().zip(&ws).map(|(&a, w)| a * w.to_i64()).sum();
            assert_eq!(acc.decode(), expect, "bsl={bsl} n={n}");

            // ReLU via SI on the sorted stream.
            let si = SelectiveInterconnect::for_activation(
                &ActivationFn::Relu { ratio: 1.0 },
                n * bsl,
                16,
            );
            let out = ThermCode::from_bits(si.apply_bits(&sorted));
            assert_eq!(out.decode(), expect.max(0).min(8), "relu bsl={bsl} n={n}");
        }
    }
}

/// §III residual path: rescale block + BSN accumulation of residual +
/// conv products at mismatched scales.
#[test]
fn residual_rescale_alignment() {
    let block = RescaleBlock::new(16);
    // Residual q=6 at alpha 2^0; conv products at alpha 2^-2: the
    // residual count must be multiplied by 4.
    let res = ThermCode::encode(6, 16);
    let (aligned, cycles) = block.align(&res, 0, -2);
    assert_eq!(cycles, 1);
    assert_eq!(aligned.decode(), 24);
    // And with alpha 2^1 target: divide by 2 over 1 cycle, BSL kept.
    let (divided, cycles) = block.align(&res, 0, 1);
    assert_eq!(cycles, 1);
    assert_eq!(divided.bsl(), 16);
    assert_eq!(divided.decode(), 3);
}

/// The full SC executor equals the binary executor on every config that
/// both support (fault-free) — across models and BSLs.
#[test]
fn executors_agree_across_configs() {
    let mut rng = Rng::new(33);
    for (cfg, c, h, w) in [
        (ModelCfg::tnn(), 1usize, 28usize, 28usize),
        (ModelCfg::scnet(10), 3, 32, 32),
    ] {
        let params = ModelParams::init(&cfg, &mut rng);
        for act_bsl in [2usize, 4] {
            let has_res = cfg.name == "scnet";
            let quant = QuantConfig {
                act_bsl: Some(act_bsl),
                weight_ternary: true,
                residual_bsl: if has_res { Some(16) } else { None },
                pruning: Pruning::Off,
            };
            let prep = Prepared::new(&cfg, &params, quant);
            let sc = ScExecutor::new(prep.clone());
            let bin = BinaryExecutor::new(prep);
            for s in 0..2 {
                let mut r = Rng::new(1000 + s);
                let img = scnn::nn::tensor::Tensor::from_vec(
                    &[c, h, w],
                    (0..c * h * w).map(|_| r.normal() as f32 * 0.5).collect(),
                );
                assert_eq!(
                    sc.forward(&img),
                    bin.forward(&img),
                    "{} bsl={act_bsl} seed={s}",
                    cfg.name
                );
            }
        }
    }
}

/// Fault injection preserves determinism per seed and zero-BER equals
/// clean, through the full network.
#[test]
fn fault_injection_determinism() {
    let cfg = ModelCfg::tnn();
    let mut rng = Rng::new(5);
    let params = ModelParams::init(&cfg, &mut rng);
    let prep = Prepared::new(
        &cfg,
        &params,
        QuantConfig {
            act_bsl: Some(2),
            weight_ternary: true,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
    );
    let data = SynthDigits::new();
    let (imgs, _) = data.batch(Split::Test, 0, 4);
    let a = ScExecutor::with_faults(prep.clone(), FaultCfg { ber: 0.01, seed: 9 });
    let b = ScExecutor::with_faults(prep.clone(), FaultCfg { ber: 0.01, seed: 9 });
    for img in &imgs {
        assert_eq!(a.forward(img), b.forward(img));
    }
    let clean = ScExecutor::new(prep.clone());
    let zero = ScExecutor::with_faults(prep, FaultCfg { ber: 0.0, seed: 1 });
    for img in &imgs {
        assert_eq!(clean.forward(img), zero.forward(img));
    }
}

/// Float-reference executor runs every ablation row of Table III.
#[test]
fn float_reference_all_quant_configs() {
    let cfg = ModelCfg::scnet(10);
    let mut rng = Rng::new(8);
    let params = ModelParams::init(&cfg, &mut rng);
    let data = SynthCifar::new(10);
    let (imgs, labels) = data.batch(Split::Test, 0, 8);
    for quant in [
        QuantConfig::float(),
        QuantConfig {
            act_bsl: None,
            weight_ternary: true,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
        QuantConfig {
            act_bsl: Some(2),
            weight_ternary: false,
            residual_bsl: None,
            pruning: Pruning::Off,
        },
        QuantConfig::w2a2r16(),
    ] {
        let acc = accuracy_float(&cfg, &params, quant, &imgs, &labels);
        assert!((0.0..=1.0).contains(&acc), "{quant:?}");
    }
}

/// The accelerator schedule covers every ResNet-18 layer and the
/// paper's headline ratios hold in *shape* (all reductions > 1, small
/// layers win more than large ones).
#[test]
fn schedule_shape_matches_paper() {
    let widths: Vec<usize> = RESNET18_ACC_WIDTHS.iter().map(|w| w * 2).collect();
    let s = Schedule::new(&widths, 1152);
    let reductions: Vec<f64> = s.layers.iter().map(|l| l.reduction).collect();
    for w in reductions.windows(2) {
        assert!(w[0] >= w[1], "smaller layers must win more: {reductions:?}");
    }
    assert!(s.avg_adp_reduction() > 3.0);
    assert!(s.area_reduction() > 2.0);
}

/// Spatial/ST designs stay within an MSE budget across all paper
/// widths (the Table V / Fig 13 quality gate).
#[test]
fn approx_designs_quality_gate() {
    let mut rng = Rng::new(77);
    for &wprod in &RESNET18_ACC_WIDTHS {
        let bits = wprod * 2;
        let sp = accel::design_spatial(bits, 16);
        assert!(sp.mse(0.5, 400, &mut rng) < 5e-3, "spatial {wprod}");
        let st = accel::design_st(bits, 1152.min(bits), 16, 16);
        assert!(st.mse(0.5, 400, &mut rng) < 5e-3, "st {wprod}");
    }
}

/// The chip power model hits the paper's headline at the paper's
/// operating point and degrades away from it.
#[test]
fn power_model_headline() {
    let p = ChipPowerModel::evaluate(0.65, 200.0);
    assert!(p.functional);
    assert!((p.tops_per_w - 198.9).abs() < 6.0);
    assert!(ChipPowerModel::evaluate(0.9, 200.0).tops_per_w < p.tops_per_w);
}

/// Circuit-level experiments run end-to-end in quick mode and report
/// paper-shaped results.
#[test]
fn circuit_experiments_quick() {
    let opts = Opts { quick: true, artifacts: "artifacts".into(), seed: 3 };
    // tab5: spatial and ST must beat the baseline.
    let r = exp::run("tab5", &opts).unwrap();
    assert!(r.get("ratio", "spatial_x").unwrap() > 1.5);
    assert!(r.get("ratio", "st_x").unwrap() > 1.5);
    // fig9: super-linear per-bit growth.
    let r = exp::run("fig9", &opts).unwrap();
    assert!(r.get("scaling", "per_bit_growth").unwrap() > 1.5);
    // fig1: FSM error decreases with BSL but never reaches the SI.
    let r = exp::run("fig1", &opts).unwrap();
    let long = r.get("1024", "mse_relu_fsm").unwrap();
    let short = r.get("32", "mse_relu_fsm").unwrap();
    assert!(short > long);
    // fig13: avg ADP reduction > 3x.
    let r = exp::run("fig13", &opts).unwrap();
    assert!(r.get("avg", "adp_reduction").unwrap() > 3.0);
    // fig4: peak close to the paper's headline.
    let r = exp::run("fig4", &opts).unwrap();
    assert!((r.get("peak", "tops_per_w").unwrap() - 198.9).abs() < 10.0);
    // fig7: SI reproduces BN-ReLU exactly.
    let r = exp::run("fig7", &opts).unwrap();
    assert_eq!(r.get("g1b0", "max_err").unwrap(), 0.0);
}
