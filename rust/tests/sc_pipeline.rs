//! The central cross-layer correctness gate: trained parameters flow
//! from the PJRT/JAX world into the bit-exact SC hardware simulator,
//! and every representation along the way agrees.

use scnn::data::{Dataset, Split, SynthCifar};
use scnn::nn::binary_exec::{forward_float, BinaryExecutor};
use scnn::nn::model::{ModelCfg, ModelParams};
use scnn::nn::quant::QuantConfig;
use scnn::nn::sc_exec::{Prepared, ScExecutor};
use scnn::util::Rng;

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/scnet10_meta.txt").exists()
}

/// SC executor == binary executor on the residual network, fault-free
/// (random params — no PJRT needed).
#[test]
fn sc_equals_binary_on_residual_network() {
    let cfg = ModelCfg::scnet(10);
    let mut rng = Rng::new(2024);
    let params = ModelParams::init(&cfg, &mut rng);
    let prep = Prepared::new(&cfg, &params, QuantConfig::w2a2r16());
    let sc = ScExecutor::new(prep.clone());
    let bin = BinaryExecutor::new(prep);
    let data = SynthCifar::new(10);
    let (imgs, _) = data.batch(Split::Test, 0, 6);
    for (i, img) in imgs.iter().enumerate() {
        assert_eq!(sc.forward(img), bin.forward(img), "image {i}");
    }
}

/// The integer executors track the float fake-quant reference: the
/// predicted class agrees on a clear majority of inputs (rounding
/// differences at quantization boundaries may flip ties).
#[test]
fn integer_executors_track_float_reference() {
    let cfg = ModelCfg::scnet(10);
    let mut rng = Rng::new(7);
    let params = ModelParams::init(&cfg, &mut rng);
    let quant = QuantConfig::w2a2r16();
    let prep = Prepared::new(&cfg, &params, quant);
    let sc = ScExecutor::new(prep);
    let data = SynthCifar::new(10);
    let (imgs, _) = data.batch(Split::Test, 0, 24);
    let mut agree = 0;
    for img in &imgs {
        let fl = forward_float(&cfg, &params, quant, img);
        let f_pred = fl
            .iter()
            .enumerate()
            .max_by(|a, b| a.1.total_cmp(b.1))
            .map(|(i, _)| i)
            .unwrap();
        let s_pred = sc.predict(std::slice::from_ref(img))[0];
        if f_pred == s_pred {
            agree += 1;
        }
    }
    assert!(agree >= 16, "only {agree}/24 predictions agree with the float reference");
}

/// PJRT-trained scnet parameters survive the freeze into the SC
/// simulator with sensible accuracy (requires artifacts).
#[test]
fn pjrt_trained_scnet_freezes_into_simulator() {
    if !artifacts_available() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    use scnn::runtime::{trainer::Knobs, Runtime, Trainer};
    let rt = Runtime::new("artifacts").unwrap();
    let data = SynthCifar::new(10);
    let mut tr = Trainer::new(&rt, "scnet10").unwrap();
    let knobs = Knobs::quantized(2).with_res_bsl(Some(16));
    tr.train_qat(&data, 150, 150, 0.05, knobs, |_, _| {}).unwrap();
    let acc_jax = tr.accuracy(&data, 128, knobs, false).unwrap();

    let prep = Prepared::new(&ModelCfg::scnet(10), &tr.to_model_params(), QuantConfig::w2a2r16());
    let sc = ScExecutor::new(prep);
    let (imgs, labels) = data.batch(Split::Test, 0, 64);
    let acc_sc = sc.accuracy(&imgs, &labels);
    // The SC-sim accuracy should be in the same regime as the JAX eval
    // (they differ in residual pow2 alignment and GAP details).
    assert!(
        (acc_sc - acc_jax).abs() < 0.25,
        "JAX {acc_jax} vs SC-sim {acc_sc} diverged"
    );
    assert!(acc_sc > 0.15, "trained SC-sim accuracy stuck at chance: {acc_sc}");
}

/// Residual taps materially change the computation (the §III feature is
/// actually wired through the executors).
#[test]
fn residual_path_changes_outputs() {
    let cfg = ModelCfg::scnet(10);
    let mut rng = Rng::new(99);
    let params = ModelParams::init(&cfg, &mut rng);
    let with_res = Prepared::new(&cfg, &params, QuantConfig::w2a2r16());
    let data = SynthCifar::new(10);
    let (imgs, _) = data.batch(Split::Test, 0, 6);
    let sc = ScExecutor::new(with_res);
    // Zeroing the residual scales (alpha_res -> tiny) should change
    // logits on at least one image.
    let mut params2 = params.clone();
    for i in 0..6 {
        let name = format!("conv{i}.alpha_res");
        if params2.get(&name).is_some() {
            params2.insert(&name, scnn::nn::tensor::Tensor::from_vec(&[1], vec![1e6]));
        }
    }
    let sc2 = ScExecutor::new(Prepared::new(&cfg, &params2, QuantConfig::w2a2r16()));
    let changed = imgs.iter().any(|im| sc.forward(im) != sc2.forward(im));
    assert!(changed, "residual path appears disconnected");
}
