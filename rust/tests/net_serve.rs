//! End-to-end tests of the network serving front-end over real
//! loopback sockets: an ephemeral listener (`127.0.0.1:0`), concurrent
//! `NetClient` threads against the deterministic synthetic backend,
//! logits checked bit-for-bit against the in-process oracle, exact
//! shed accounting under overload, tenant admission, hot model swap,
//! Prometheus scrapes over the wire, drain-on-shutdown — plus
//! socket-free property tests of the frame codec (ragged lengths,
//! 1-byte trickle delivery, malformed-input rejection).

use std::io::{Read, Write};
use std::net::TcpStream;
use std::sync::Arc;
use std::time::Duration;

use scnn::coordinator::net::{decode_body, encode_frame, MAX_FRAME};
use scnn::coordinator::{
    Coordinator, ExecutorSpec, Frame, FrameReader, InferRequest, InferResponse, ModelRegistry,
    NetClient, NetServer, PoolConfig, Priority, Status, SyntheticExecutor, TenantPolicy,
};
use scnn::util::Rng;

const SPEC: ExecutorSpec = ExecutorSpec { image_len: 12, batch: 4, classes: 5 };

/// A deterministic fake "image" for request index `i`.
fn image(i: usize) -> Vec<f32> {
    (0..SPEC.image_len).map(|p| ((i * 31 + p * 7) % 17) as f32 * 0.125 - 1.0).collect()
}

fn pool_with(spec: ExecutorSpec, workers: usize, latency: Duration) -> Coordinator {
    Coordinator::start_with(
        SyntheticExecutor::factory(spec, latency),
        PoolConfig { workers, ..PoolConfig::default() },
    )
    .expect("start pool")
}

/// One-model registry + bound server on an ephemeral loopback port.
fn serve_toy(
    workers: usize,
    latency: Duration,
    policy: TenantPolicy,
) -> (Arc<ModelRegistry>, NetServer) {
    let registry = Arc::new(ModelRegistry::new(policy));
    assert!(registry.register("toy", pool_with(SPEC, workers, latency)).is_none());
    let server = NetServer::bind("127.0.0.1:0", registry.clone()).expect("bind loopback");
    (registry, server)
}

/// Scrape until `pred` holds (metrics are recorded just after the
/// response is written, so a scrape can trail the last answer by one
/// batch for a moment).
fn scrape_until(addr: std::net::SocketAddr, pred: impl Fn(&str) -> bool) -> String {
    let mut last = String::new();
    for _ in 0..200 {
        last = NetClient::connect(addr).unwrap().metrics_text().expect("scrape");
        if pred(&last) {
            return last;
        }
        std::thread::sleep(Duration::from_millis(5));
    }
    panic!("metrics never converged; last scrape:\n{last}");
}

#[test]
fn loopback_logits_match_in_process_oracle() {
    let (registry, server) = serve_toy(2, Duration::ZERO, TenantPolicy::default());
    let addr = server.local_addr();
    let clients = 6usize;
    let per_client = 16usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        handles.push(std::thread::spawn(move || -> Vec<(usize, Vec<f32>)> {
            let mut client = NetClient::connect(addr).expect("connect");
            (0..per_client)
                .map(|i| {
                    let idx = t * per_client + i;
                    (idx, client.infer("toy", &image(idx)).expect("infer over socket"))
                })
                .collect()
        }));
    }
    let oracle = SyntheticExecutor::new(SPEC);
    let mut total = 0usize;
    for h in handles {
        for (idx, logits) in h.join().unwrap() {
            // Socket round-trip must be bit-identical to the
            // in-process ground truth (f32 LE survives the wire).
            assert_eq!(logits, oracle.reference_logits(&image(idx)), "request {idx}");
            total += 1;
        }
    }
    assert_eq!(total, clients * per_client);
    assert!(server.connections_accepted() >= clients as u64);
    server.shutdown();
    let finals = registry.shutdown_all();
    assert_eq!(finals.len(), 1);
    let (name, m) = &finals[0];
    assert_eq!(name, "toy");
    assert_eq!(m.requests, total as u64);
    assert_eq!(m.errors, 0);
    assert_eq!(m.shed, 0);
}

#[test]
fn routes_between_models_on_one_connection() {
    let wide = ExecutorSpec { image_len: 6, batch: 2, classes: 3 };
    let registry = Arc::new(ModelRegistry::new(TenantPolicy::default()));
    assert!(registry.register("toy", pool_with(SPEC, 1, Duration::ZERO)).is_none());
    assert!(registry.register("wide", pool_with(wide, 1, Duration::ZERO)).is_none());
    let server = NetServer::bind("127.0.0.1:0", registry.clone()).unwrap();
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    let toy_oracle = SyntheticExecutor::new(SPEC);
    let wide_oracle = SyntheticExecutor::new(wide);
    for i in 0..8 {
        let x = image(i);
        assert_eq!(client.infer("toy", &x).unwrap(), toy_oracle.reference_logits(&x));
        let y: Vec<f32> = x[..wide.image_len].to_vec();
        assert_eq!(client.infer("wide", &y).unwrap(), wide_oracle.reference_logits(&y));
    }
    server.shutdown();
    let finals = registry.shutdown_all();
    assert_eq!(finals.len(), 2);
    assert!(finals.iter().all(|(_, m)| m.requests == 8));
}

#[test]
fn shed_accounting_is_exact_under_overload() {
    // One slow worker, two queue slots, Shed policy: a burst of
    // instant clients cannot all be admitted. Tenant admission is off,
    // so every rejection is the pool's own shedding.
    let policy = scnn::coordinator::BatchPolicy {
        overload: scnn::coordinator::OverloadPolicy::Shed,
        ..Default::default()
    };
    let registry = Arc::new(ModelRegistry::new(TenantPolicy::default()));
    let coord = Coordinator::start_with(
        SyntheticExecutor::factory(SPEC, Duration::from_millis(25)),
        PoolConfig { workers: 1, policy, queue_depth: 2, ..PoolConfig::default() },
    )
    .unwrap();
    assert!(registry.register("toy", coord).is_none());
    let server = NetServer::bind("127.0.0.1:0", registry.clone()).unwrap();
    let addr = server.local_addr();
    let clients = 12usize;
    let mut handles = Vec::new();
    for t in 0..clients {
        handles.push(std::thread::spawn(move || -> (usize, usize) {
            let mut client = NetClient::connect(addr).expect("connect");
            let resp = client.request("toy", &image(t)).expect("transport must not fail");
            match resp.status {
                Status::Ok => {
                    assert_eq!(resp.logits.len(), SPEC.classes);
                    (1, 0)
                }
                Status::Shed => {
                    assert!(
                        resp.message.starts_with(scnn::coordinator::SHED_ERROR),
                        "shed response must carry the shed marker: {}",
                        resp.message
                    );
                    (0, 1)
                }
                s => panic!("unexpected status {s:?}: {}", resp.message),
            }
        }));
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for h in handles {
        let (o, s) = h.join().unwrap();
        ok += o;
        shed += s;
    }
    assert_eq!(ok + shed, clients);
    assert!(shed > 0, "expected at least one shed under a 12-client burst");
    server.shutdown();
    let m = registry.shutdown_all().remove(0).1;
    // Exact accounting: the pool's counters equal what the clients
    // observed through their sockets — nothing lost on the wire.
    assert_eq!(m.requests, ok as u64);
    assert_eq!(m.shed, shed as u64);
}

#[test]
fn tenant_admission_sheds_noisy_tenant_without_starving_quiet() {
    let (registry, server) =
        serve_toy(1, Duration::from_millis(30), TenantPolicy { max_inflight: 1 });
    let addr = server.local_addr();
    // Six concurrent requests from one noisy tenant: quota 1 admits
    // them one at a time, the overlap is shed at admission.
    let mut handles = Vec::new();
    for t in 0..6usize {
        handles.push(std::thread::spawn(move || -> (usize, usize) {
            let c = NetClient::connect(addr).unwrap();
            let mut client = c.with_tenant("noisy").with_priority(Priority::Low);
            match client.request("toy", &image(t)).unwrap().status {
                Status::Ok => (1, 0),
                Status::Shed => (0, 1),
                s => panic!("unexpected status {s:?}"),
            }
        }));
    }
    // A quiet tenant issuing sequential requests never holds more than
    // one slot, so its traffic is admitted even while noisy saturates.
    let mut quiet = NetClient::connect(addr).unwrap().with_tenant("quiet");
    for i in 0..3 {
        let resp = quiet.request("toy", &image(100 + i)).unwrap();
        assert_eq!(resp.status, Status::Ok, "quiet tenant was starved: {}", resp.message);
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for h in handles {
        let (o, s) = h.join().unwrap();
        ok += o;
        shed += s;
    }
    assert_eq!(ok + shed, 6);
    assert!(shed > 0, "six overlapping requests under quota 1 must shed");
    // Admission counters match what the noisy tenant observed, and the
    // scrape exposes them per tenant.
    let counters = registry.admission().counters();
    let noisy = counters.iter().find(|c| c.tenant == "noisy").unwrap();
    assert_eq!(noisy.shed, shed as u64);
    assert_eq!(noisy.admitted, ok as u64);
    let text = scrape_until(addr, |t| t.contains("scnn_tenant_shed_total{tenant=\"noisy\"}"));
    assert!(text.contains(&format!("scnn_tenant_shed_total{{tenant=\"noisy\"}} {shed}")), "{text}");
    assert!(text.contains("scnn_tenant_shed_total{tenant=\"quiet\"} 0"), "{text}");
    server.shutdown();
    registry.shutdown_all();
}

#[test]
fn unknown_model_and_bad_shape_get_clean_errors_on_a_live_connection() {
    let (registry, server) = serve_toy(1, Duration::ZERO, TenantPolicy::default());
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    // Unknown model: clean status, connection stays usable.
    let r = client.request("nope", &image(0)).unwrap();
    assert_eq!(r.status, Status::UnknownModel);
    assert!(r.message.contains("toy"), "error should list known models: {}", r.message);
    // Wrong payload shape: rejected before reaching any pool.
    let long = vec![0.0f32; SPEC.image_len + 1];
    let r = client.request("toy", &long).unwrap();
    assert_eq!(r.status, Status::BadRequest);
    assert!(r.message.contains("length"), "{}", r.message);
    // The same connection still serves well-formed requests.
    let logits = client.infer("toy", &image(1)).unwrap();
    assert_eq!(logits, SyntheticExecutor::new(SPEC).reference_logits(&image(1)));
    server.shutdown();
    let m = registry.shutdown_all().remove(0).1;
    assert_eq!(m.requests, 1, "rejected requests never reach the pool");
}

#[test]
fn malformed_frames_are_answered_and_do_not_kill_the_server() {
    let (registry, server) = serve_toy(1, Duration::ZERO, TenantPolicy::default());
    let addr = server.local_addr();
    // Bad magic: the server answers BadRequest and closes this
    // connection (a corrupt stream cannot be resynchronized).
    let mut raw = TcpStream::connect(addr).unwrap();
    let mut junk = vec![0u8; 12];
    junk[0..4].copy_from_slice(&8u32.to_le_bytes()); // length 8, garbage body
    raw.write_all(&junk).unwrap();
    let mut reader = FrameReader::new();
    let mut buf = [0u8; 1024];
    let reply = loop {
        let n = raw.read(&mut buf).unwrap();
        assert!(n > 0, "server closed before answering the malformed frame");
        reader.feed(&buf[..n]);
        if let Some(f) = reader.try_next().unwrap() {
            break f;
        }
    };
    match reply {
        Frame::Response(r) => {
            assert_eq!(r.status, Status::BadRequest);
            assert!(r.message.contains("magic"), "{}", r.message);
        }
        other => panic!("expected an error response, got {other:?}"),
    }
    // The connection is then closed by the server.
    assert_eq!(raw.read(&mut buf).unwrap(), 0);
    // An oversized declared length is rejected before buffering.
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(&((MAX_FRAME as u32) + 1).to_le_bytes()).unwrap();
    let mut reader = FrameReader::new();
    let reply = loop {
        let n = raw.read(&mut buf).unwrap();
        assert!(n > 0, "server closed before answering the oversized frame");
        reader.feed(&buf[..n]);
        if let Some(f) = reader.try_next().unwrap() {
            break f;
        }
    };
    match reply {
        Frame::Response(r) => assert_eq!(r.status, Status::BadRequest),
        other => panic!("expected an error response, got {other:?}"),
    }
    // A half-frame followed by a client hangup must not wedge anything.
    let mut raw = TcpStream::connect(addr).unwrap();
    raw.write_all(&100u32.to_le_bytes()).unwrap();
    raw.write_all(&[1, 2, 3]).unwrap();
    drop(raw);
    // After all that abuse, a well-formed client still gets served.
    let mut client = NetClient::connect(addr).unwrap();
    let logits = client.infer("toy", &image(7)).unwrap();
    assert_eq!(logits, SyntheticExecutor::new(SPEC).reference_logits(&image(7)));
    let text = client.metrics_text().unwrap();
    assert!(text.contains("scnn_frames_malformed_total 2"), "{text}");
    server.shutdown();
    registry.shutdown_all();
}

#[test]
fn drain_on_shutdown_completes_inflight_requests() {
    let (registry, server) = serve_toy(1, Duration::from_millis(50), TenantPolicy::default());
    let addr = server.local_addr();
    let inflight = std::thread::spawn(move || {
        let mut client = NetClient::connect(addr).expect("connect");
        client.infer("toy", &image(3))
    });
    // Let the request reach the pool, then shut the front-end down
    // while the batch is still executing.
    std::thread::sleep(Duration::from_millis(15));
    server.shutdown();
    // Drain invariant: the in-flight request got its response before
    // its socket closed.
    let logits = inflight.join().unwrap().expect("in-flight request must complete");
    assert_eq!(logits, SyntheticExecutor::new(SPEC).reference_logits(&image(3)));
    // New connections are refused once the listener is gone.
    let late = NetClient::connect(addr).and_then(|mut c| c.request("toy", &image(4)));
    assert!(late.is_err(), "the server must not accept work after shutdown");
    let m = registry.shutdown_all().remove(0).1;
    assert_eq!(m.requests, 1);
}

#[test]
fn metrics_scrape_over_socket_is_structurally_sound() {
    let (registry, server) = serve_toy(1, Duration::ZERO, TenantPolicy::default());
    let addr = server.local_addr();
    let mut client = NetClient::connect(addr).unwrap();
    let total = 10usize;
    for i in 0..total {
        client.infer("toy", &image(i)).unwrap();
    }
    let text = scrape_until(addr, |t| {
        t.contains(&format!("scnn_requests_total{{model=\"toy\"}} {total}"))
    });
    // _count agrees with the request counter over the socket.
    assert!(
        text.contains(&format!("scnn_request_latency_seconds_count{{model=\"toy\"}} {total}")),
        "{text}"
    );
    // The bucket series is cumulative and monotone, ends at +Inf with
    // the full count.
    let buckets: Vec<u64> = text
        .lines()
        .filter(|l| l.starts_with("scnn_request_latency_seconds_bucket{model=\"toy\""))
        .map(|l| l.rsplit(' ').next().unwrap().parse().unwrap())
        .collect();
    assert!(!buckets.is_empty(), "{text}");
    assert!(buckets.windows(2).all(|w| w[0] <= w[1]), "monotone buckets: {buckets:?}");
    assert_eq!(*buckets.last().unwrap(), total as u64);
    for q in ["0.5", "0.95", "0.99"] {
        let needle =
            format!("scnn_request_latency_quantile_seconds{{model=\"toy\",quantile=\"{q}\"}}");
        assert!(text.contains(&needle), "{text}");
    }
    assert!(text.contains("scnn_connections_accepted_total"), "{text}");
    server.shutdown();
    registry.shutdown_all();
}

#[test]
fn hot_swap_serves_new_pool_on_a_live_connection() {
    let (registry, server) = serve_toy(1, Duration::ZERO, TenantPolicy::default());
    let mut client = NetClient::connect(server.local_addr()).unwrap();
    client.infer("toy", &image(0)).unwrap();
    // Swap the model under the live connection: the old pool drains
    // and reports its traffic; the same socket reaches the new pool.
    let old = registry.register("toy", pool_with(SPEC, 2, Duration::ZERO));
    let old = old.expect("swap returns the old pool's final snapshot");
    assert_eq!(old.requests, 1);
    for i in 1..5 {
        let logits = client.infer("toy", &image(i)).unwrap();
        assert_eq!(logits, SyntheticExecutor::new(SPEC).reference_logits(&image(i)));
    }
    server.shutdown();
    let m = registry.shutdown_all().remove(0).1;
    assert_eq!(m.requests, 4);
    assert_eq!(m.workers, 2);
}

// ---------------------------------------------------------------------------
// Frame-codec property tests (no sockets): ragged sizes, split reads,
// malformed rejection. Deterministic via the crate's own Rng.
// ---------------------------------------------------------------------------

fn random_request(rng: &mut Rng, payload_len: usize) -> Frame {
    let model_len = (rng.next_u64() % 16) as usize;
    let tenant_len = (rng.next_u64() % 16) as usize;
    let model: String = (0..model_len).map(|i| (b'a' + (i % 26) as u8) as char).collect();
    let tenant: String = (0..tenant_len).map(|i| (b'A' + (i % 26) as u8) as char).collect();
    let priority = Priority::from_u8((rng.next_u64() % 3) as u8).unwrap();
    let payload: Vec<f32> = (0..payload_len).map(|_| (rng.f64() * 4.0 - 2.0) as f32).collect();
    Frame::Infer(InferRequest {
        id: rng.next_u64(),
        priority,
        deadline_ms: 0,
        model,
        tenant,
        payload,
    })
}

#[test]
fn codec_roundtrips_ragged_payloads_across_random_split_reads() {
    let mut rng = Rng::new(0xC0DEC);
    // Ragged payload lengths, including the empty payload.
    let lens = [0usize, 1, 2, 3, 5, 8, 13, 64, 257, 1000];
    let mut frames = Vec::new();
    let mut bytes = Vec::new();
    for &n in &lens {
        let f = random_request(&mut rng, n);
        encode_frame(&f, &mut bytes).unwrap();
        frames.push(f);
        let r = Frame::Response(InferResponse::ok(
            rng.next_u64(),
            (0..n).map(|_| rng.f64() as f32).collect(),
        ));
        encode_frame(&r, &mut bytes).unwrap();
        frames.push(r);
    }
    // Deliver the whole stream in random chunks (1..=7 bytes) and
    // check every frame comes out intact and in order.
    let mut reader = FrameReader::new();
    let mut got = Vec::new();
    let mut pos = 0usize;
    while pos < bytes.len() {
        let chunk = 1 + (rng.next_u64() % 7) as usize;
        let end = (pos + chunk).min(bytes.len());
        reader.feed(&bytes[pos..end]);
        pos = end;
        while let Some(f) = reader.try_next().expect("well-formed stream") {
            got.push(f);
        }
    }
    assert_eq!(got, frames);
    assert_eq!(reader.buffered(), 0);
}

#[test]
fn codec_reports_incomplete_frames_as_none_never_panics() {
    let mut rng = Rng::new(7);
    let mut bytes = Vec::new();
    encode_frame(&random_request(&mut rng, 100), &mut bytes).unwrap();
    // Every possible truncation point of a valid frame is simply
    // "incomplete", never an error or a panic.
    for cut in 0..bytes.len() {
        let mut reader = FrameReader::new();
        reader.feed(&bytes[..cut]);
        assert!(reader.try_next().expect("prefix is not malformed").is_none(), "cut {cut}");
        assert_eq!(reader.buffered(), cut);
    }
}

#[test]
fn codec_rejects_bitflips_in_the_header_cleanly() {
    let mut rng = Rng::new(99);
    let mut bytes = Vec::new();
    encode_frame(&random_request(&mut rng, 9), &mut bytes).unwrap();
    // Flipping any single bit of magic/version/kind must yield a clean
    // decode error (or, for kind 1, a different valid kind whose body
    // then fails) — never a panic.
    for byte in 4..10 {
        for bit in 0..8 {
            let mut bad = bytes.clone();
            bad[byte] ^= 1 << bit;
            let mut reader = FrameReader::new();
            reader.feed(&bad);
            match reader.try_next() {
                Err(_) => {}
                Ok(f) => {
                    // A kind byte that flipped to another valid kind can
                    // only decode if the body happens to parse; either
                    // way the reader must stay consistent.
                    assert!(f.is_some() || reader.buffered() > 0);
                }
            }
        }
    }
    // decode_body on random garbage never panics.
    for _ in 0..500 {
        let n = (rng.next_u64() % 64) as usize;
        let garbage: Vec<u8> = (0..n).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = decode_body(&garbage);
    }
}
