//! Property tests for the packed `u64` `BitVec` representation.
//!
//! Two layers of evidence that the word-packed datapath is bit-exact:
//!
//! 1. every bulk `BitVec` operation (popcount, concat, range copy at
//!    non-word-aligned offsets, ones-prefix fill, complement-reverse,
//!    bitwise combinators, str01 round-trip) is pitted against a naive
//!    `Vec<bool>` reference model over lengths straddling the 64-bit
//!    word boundary;
//! 2. every gate-level circuit stage (ternary multiplier, BSN sort,
//!    selective interconnect, rescale divider, approximate and
//!    spatial-temporal BSNs) is checked packed-vs-scalar on random —
//!    including non-canonical — streams;
//! 3. every SIMD word kernel behind the runtime [`Dispatch`] table is
//!    pitted against the always-available scalar arm over ragged word
//!    counts and non-word-aligned funnel offsets — on this machine's
//!    dispatched table AND under the `SCNN_NO_SIMD=1` forced-scalar
//!    override (CI runs the suite both ways);
//! 4. the fault-injection mask primitives (`fault::inject`) are pitted
//!    against per-bit references at word-crossing widths: sorted/unique
//!    mask sampling, XOR application, the prefix-flip count delta, and
//!    windowed mask rebasing.

use scnn::circuits::approx_bsn::{ApproxBsn, ApproxStage, SubSample};
use scnn::fault::inject;
use scnn::circuits::multiplier::TernaryMultiplier;
use scnn::circuits::rescale::{RescaleBlock, DIV_PAD};
use scnn::circuits::si::{SelTap, SelectiveInterconnect};
use scnn::circuits::st_bsn::SpatialTemporalBsn;
use scnn::circuits::Bsn;
use scnn::coding::{BitVec, Ternary, ThermCode};
use scnn::util::prop::check_simple;
use scnn::util::simd::{Dispatch, Level};
use scnn::util::Rng;

/// Naive byte-per-bit reference model.
fn rand_bools(rng: &mut Rng, n: usize, p: f64) -> Vec<bool> {
    (0..n).map(|_| rng.gen_bool(p)).collect()
}

fn to_bitvec(bits: &[bool]) -> BitVec {
    BitVec::from_bits(bits)
}

fn assert_matches_ref(bv: &BitVec, reference: &[bool], ctx: &str) {
    assert_eq!(bv.len(), reference.len(), "{ctx}: length");
    assert_eq!(
        bv.popcount(),
        reference.iter().filter(|&&b| b).count(),
        "{ctx}: popcount"
    );
    for (i, &b) in reference.iter().enumerate() {
        assert_eq!(bv.get(i), b, "{ctx}: bit {i}");
    }
}

/// Round-trip and per-bit access across word boundaries.
#[test]
fn prop_packed_roundtrip_matches_reference() {
    check_simple(
        101,
        150,
        |rng| {
            let n = 1 + rng.gen_index(300);
            rand_bools(rng, n, rng.f64())
        },
        |bits| {
            let bv = to_bitvec(bits);
            assert_matches_ref(&bv, bits, "from_bits");
            let s: String = bits.iter().map(|&b| if b { '1' } else { '0' }).collect();
            bv.to_str01() == s
                && BitVec::from_str01(&s) == bv
                && bv.iter().collect::<Vec<_>>() == *bits
        },
    );
}

/// Concatenation at arbitrary (mostly non-word-aligned) offsets.
#[test]
fn prop_packed_concat_matches_reference() {
    check_simple(
        103,
        150,
        |rng| {
            let a = rand_bools(rng, rng.gen_index(200), 0.5);
            let b = rand_bools(rng, rng.gen_index(200), 0.5);
            (a, b)
        },
        |(a, b)| {
            let mut packed = to_bitvec(a);
            packed.extend_from(&to_bitvec(b));
            let mut reference = a.clone();
            reference.extend_from_slice(b);
            assert_matches_ref(&packed, &reference, "extend_from");
            // push keeps working after a misaligned concat.
            packed.push(true);
            reference.push(true);
            assert_matches_ref(&packed, &reference, "push after extend");
            true
        },
    );
}

/// Range copy (the BSN group-extraction primitive) at random offsets.
#[test]
fn prop_packed_copy_range_matches_reference() {
    check_simple(
        107,
        200,
        |rng| {
            let src = rand_bools(rng, 1 + rng.gen_index(300), 0.5);
            let start = rng.gen_index(src.len());
            let len = rng.gen_index(src.len() - start + 1);
            (src, start, len)
        },
        |(src, start, len)| {
            let mut out = BitVec::zeros(0);
            out.copy_range_from(&to_bitvec(src), *start, *len);
            assert_matches_ref(&out, &src[*start..start + len], "copy_range_from");
            true
        },
    );
}

/// Ones-prefix fill (thermometer encode) and complement-reverse
/// (negation / `w = -1` multiplier path).
#[test]
fn prop_packed_prefix_and_reverse_match_reference() {
    check_simple(
        109,
        200,
        |rng| {
            let n = 1 + rng.gen_index(300);
            (rand_bools(rng, n, 0.5), rng.gen_index(n + 1))
        },
        |(bits, ones)| {
            let n = bits.len();
            let mut prefix = BitVec::zeros(0);
            prefix.set_ones_prefix(n, *ones);
            let ref_prefix: Vec<bool> = (0..n).map(|i| i < *ones).collect();
            assert_matches_ref(&prefix, &ref_prefix, "set_ones_prefix");
            assert!(prefix.is_thermometer());

            let mut rev = BitVec::zeros(0);
            rev.complement_reversed_from(&to_bitvec(bits));
            let ref_rev: Vec<bool> = (0..n).map(|i| !bits[n - 1 - i]).collect();
            assert_matches_ref(&rev, &ref_rev, "complement_reversed_from");
            true
        },
    );
}

/// Bitwise combinators and the thermometer-validity check.
#[test]
fn prop_packed_bitwise_ops_match_reference() {
    check_simple(
        113,
        200,
        |rng| {
            let n = 1 + rng.gen_index(300);
            (rand_bools(rng, n, 0.5), rand_bools(rng, n, 0.5))
        },
        |(a, b)| {
            let (pa, pb) = (to_bitvec(a), to_bitvec(b));
            for (name, f, g) in [
                (
                    "and",
                    BitVec::and_with as fn(&mut BitVec, &BitVec),
                    (|x, y| x && y) as fn(bool, bool) -> bool,
                ),
                ("or", BitVec::or_with, |x, y| x || y),
                ("xor", BitVec::xor_with, |x, y| x != y),
            ] {
                let mut out = pa.clone();
                f(&mut out, &pb);
                let reference: Vec<bool> =
                    a.iter().zip(b).map(|(&x, &y)| g(x, y)).collect();
                assert_matches_ref(&out, &reference, name);
            }
            let mut not = pa.clone();
            not.not_inplace();
            let ref_not: Vec<bool> = a.iter().map(|&x| !x).collect();
            assert_matches_ref(&not, &ref_not, "not");

            // is_thermometer agrees with the scalar definition.
            let mut seen_zero = false;
            let mut ref_therm = true;
            for &bit in a {
                if bit && seen_zero {
                    ref_therm = false;
                    break;
                }
                if !bit {
                    seen_zero = true;
                }
            }
            pa.is_thermometer() == ref_therm
        },
    );
}

/// The packed 64-lane BSN equals the scalar compare-exchange network
/// (reached through the public fault API with a zero BER) on every
/// width class.
#[test]
fn prop_packed_sort_equals_scalar_network() {
    check_simple(
        127,
        60,
        |rng| {
            let width = 1 + rng.gen_index(260);
            rand_bools(rng, width, rng.f64())
        },
        |bits| {
            let bv = to_bitvec(bits);
            let bsn = Bsn::new(bits.len());
            let packed = bsn.sort_gate_level(&bv);
            let scalar = bsn.sort_with_faults(&bv, 0.0, &mut Rng::new(1));
            packed == scalar
                && packed.popcount() == bv.popcount()
                && packed.is_thermometer()
        },
    );
}

/// Word-wise ternary multiplier vs the per-bit mux reference, on
/// non-canonical streams (as occur under fault injection).
#[test]
fn prop_multiplier_packed_equals_scalar() {
    check_simple(
        131,
        200,
        |rng| {
            let bsl = 2 * (1 + rng.gen_index(80));
            (rand_bools(rng, bsl, 0.5), rng.gen_range_i64(-1, 1))
        },
        |(act_bits, w)| {
            let act = to_bitvec(act_bits);
            let w = Ternary::from_i64(*w);
            let got = TernaryMultiplier::mult_bits(&act, w);
            let l = act_bits.len();
            let reference: Vec<bool> = match w {
                Ternary::Pos => act_bits.clone(),
                Ternary::Zero => (0..l).map(|i| i < l / 2).collect(),
                Ternary::Neg => (0..l).map(|i| !act_bits[l - 1 - i]).collect(),
            };
            assert_matches_ref(&got, &reference, "mult_bits");
            true
        },
    );
}

/// Word-assembling SI tap gather vs a per-tap scalar reference, on
/// arbitrary (non-sorted) streams, with buffer reuse across calls.
#[test]
fn prop_si_apply_bits_packed_equals_scalar() {
    check_simple(
        137,
        100,
        |rng| {
            let in_w = 4 + rng.gen_index(150);
            let out = 2 + rng.gen_index(20);
            // Random monotone count table -> a valid SI.
            let mut table = Vec::with_capacity(in_w + 1);
            let mut cur = 0usize;
            for _ in 0..=in_w {
                if rng.gen_bool(0.3) && cur < out {
                    cur += 1;
                }
                table.push(cur);
            }
            let stream = rand_bools(rng, in_w, rng.f64());
            (in_w, out, table, stream)
        },
        |(in_w, out, table, stream)| {
            let t = table.clone();
            let si = SelectiveInterconnect::synthesize(|c| t[c], *in_w, *out);
            let sorted = to_bitvec(stream);
            let mut reused = BitVec::zeros(0);
            si.apply_bits_into(&sorted, &mut reused);
            let reference: Vec<bool> = si
                .taps()
                .iter()
                .map(|t| match t {
                    SelTap::Zero => false,
                    SelTap::One => true,
                    SelTap::Bit(p) => stream[*p],
                })
                .collect();
            assert_matches_ref(&reused, &reference, "apply_bits_into");
            si.apply_bits(&sorted) == reused
        },
    );
}

/// SWAR even-bit divider vs the per-bit select-and-pad reference, on
/// arbitrary 16-lane streams.
#[test]
fn prop_rescale_div2_packed_equals_scalar() {
    check_simple(
        139,
        300,
        |rng| rand_bools(rng, 16, rng.f64()),
        |bits| {
            let r = RescaleBlock::new(16);
            let code = ThermCode::from_bits(to_bitvec(bits));
            let got = r.div2_cycle(&code);
            let mut reference: Vec<bool> = (0..16).step_by(2).map(|i| bits[i]).collect();
            reference.extend(DIV_PAD.chars().map(|c| c == '1'));
            assert_matches_ref(got.bits(), &reference, "div2_cycle");
            true
        },
    );
}

/// Thermometer encode/negate through the packed fills equal the
/// definitional reference at word-boundary BSLs.
#[test]
fn prop_thermometer_packed_encoding() {
    for bsl in [2usize, 62, 64, 66, 128, 190] {
        let (lo, hi) = ThermCode::range(bsl);
        let mut buf = ThermCode::from_count(0, 2);
        for q in lo..=hi {
            let c = ThermCode::encode(q, bsl);
            let ones = (q + (bsl / 2) as i64) as usize;
            let reference: Vec<bool> = (0..bsl).map(|i| i < ones).collect();
            assert_matches_ref(c.bits(), &reference, "encode");
            assert!(c.is_canonical());
            assert_eq!(c.negate().decode(), -q, "bsl={bsl} q={q}");
            ThermCode::encode_into(q, bsl, &mut buf);
            assert_eq!(buf, c, "encode_into bsl={bsl} q={q}");
        }
    }
}

/// Approximate-BSN bit path (packed sorts + word-extracted groups)
/// equals the count path on groups that straddle word boundaries.
#[test]
fn prop_approx_bsn_packed_bits_equal_counts() {
    // 2 groups of 96 bits (crossing the u64 boundary) -> 40-bit codes
    // -> one 80-bit merge.
    let a = ApproxBsn::new(vec![
        ApproxStage { m: 2, l: 96, sub: SubSample { clip: 8, stride: 2 } },
        ApproxStage { m: 1, l: 80, sub: SubSample { clip: 8, stride: 1 } },
    ]);
    let mut rng = Rng::new(149);
    for _ in 0..25 {
        let bits = rand_bools(&mut rng, 192, 0.5);
        let bv = to_bitvec(&bits);
        let counts: Vec<usize> = (0..2)
            .map(|g| bits[g * 96..(g + 1) * 96].iter().filter(|&&b| b).count())
            .collect();
        assert_eq!(a.eval_bits(&bv).popcount(), a.eval_counts(&counts));
    }
}

/// Every dispatched word kernel is bit-identical to the scalar arm on
/// ragged word counts and every funnel offset class. When the process
/// runs under `SCNN_NO_SIMD=1` the two tables are the same functions
/// and this degenerates to a self-check — CI runs it both ways.
#[test]
fn prop_simd_word_kernels_match_scalar() {
    check_simple(
        157,
        150,
        |rng| {
            let n = rng.gen_index(40);
            let a: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let b: Vec<u64> = (0..n).map(|_| rng.next_u64()).collect();
            let off = 1 + rng.gen_index(63) as u32;
            (a, b, off)
        },
        |(a, b, off)| {
            let act = Dispatch::active();
            let sc = Dispatch::scalar();
            assert_eq!(act.popcount(a), sc.popcount(a), "popcount");
            assert_eq!(act.count_and(a, b), sc.count_and(a, b), "count_and");
            let (mut d1, mut d2) = (vec![0u64; a.len()], vec![0u64; a.len()]);
            act.funnel_shr(a, *off, &mut d1);
            sc.funnel_shr(a, *off, &mut d2);
            assert_eq!(d1, d2, "funnel_shr off={off}");
            for (name, f) in [
                ("and", Dispatch::and_words as fn(&Dispatch, &mut [u64], &[u64])),
                ("or", Dispatch::or_words),
                ("xor", Dispatch::xor_words),
            ] {
                let (mut x1, mut x2) = (a.clone(), a.clone());
                f(act, &mut x1, b);
                f(sc, &mut x2, b);
                assert_eq!(x1, x2, "{name}");
            }
            for &w in a.iter() {
                assert_eq!(act.compress_even(w), sc.compress_even(w), "compress_even");
            }
            true
        },
    );
}

/// The fused AND+popcount equals the two-step path on the `BitVec`
/// level, including lengths with a partial tail word.
#[test]
fn prop_count_and_matches_two_step() {
    check_simple(
        163,
        200,
        |rng| {
            let n = 1 + rng.gen_index(300);
            (rand_bools(rng, n, 0.5), rand_bools(rng, n, 0.5))
        },
        |(a, b)| {
            let (pa, pb) = (to_bitvec(a), to_bitvec(b));
            let mut anded = pa.clone();
            anded.and_with(&pb);
            let reference = a.iter().zip(b).filter(|&(&x, &y)| x && y).count();
            pa.count_and(&pb) == anded.popcount() && pa.count_and(&pb) == reference
        },
    );
}

/// `Dispatch::scalar()` is always the scalar table, and when the
/// forced-scalar override is set the dispatched table collapses onto
/// it. (The override assertion only bites in the CI lane that exports
/// `SCNN_NO_SIMD=1` — detection runs once per process, so the default
/// lane can't probe it in-process.)
#[test]
fn forced_scalar_override() {
    assert_eq!(Dispatch::scalar().level(), Level::Scalar);
    if std::env::var("SCNN_NO_SIMD").is_ok_and(|v| v != "0") {
        assert_eq!(Dispatch::active().level(), Level::Scalar);
    }
}

/// Violating the tail-bits-zero invariant through `as_mut_words` is
/// caught by the `debug_assert!` in the word-level consumers instead
/// of silently corrupting counts — the SIMD kernels depend on it.
#[test]
#[cfg(debug_assertions)]
#[should_panic(expected = "stale bits")]
fn tail_invariant_violation_is_caught() {
    let mut b = BitVec::zeros(70);
    assert!(b.tail_is_zero());
    // Plant a bit at position 74 — past len, inside the last word.
    b.as_mut_words()[1] |= 1 << 10;
    assert!(!b.tail_is_zero());
    let _ = b.popcount();
}

/// Fault-mask sampling: sorted, unique, in range, deterministic in the
/// RNG, with the BER edge cases pinned (0 ⇒ empty, 1 ⇒ every lane).
#[test]
fn prop_fault_mask_fill_is_sorted_unique_in_range() {
    check_simple(
        167,
        200,
        |rng| {
            let width = rng.gen_index(200);
            let ber = match rng.gen_index(4) {
                0 => 0.0,
                1 => 1.0,
                2 => rng.f64(),
                _ => 0.02,
            };
            (width, ber, rng.next_u64())
        },
        |(width, ber, seed)| {
            let mut mask = Vec::new();
            inject::fill_mask(&mut Rng::new(*seed), *ber, *width, &mut mask);
            assert!(mask.windows(2).all(|w| w[0] < w[1]), "sorted and unique");
            assert!(mask.iter().all(|&g| (g as usize) < *width), "in range");
            if *ber >= 1.0 {
                assert_eq!(mask.len(), *width, "BER 1 faults every lane");
            }
            if *ber <= 0.0 {
                assert!(mask.is_empty(), "BER 0 faults nothing");
            }
            for &g in &mask {
                assert!(inject::contains(&mask, g as usize), "contains its own lanes");
            }
            assert!(!inject::contains(&mask, *width), "never past the width");
            // Same RNG state ⇒ same mask (the determinism the whole
            // fault layer is built on).
            let mut again = Vec::new();
            inject::fill_mask(&mut Rng::new(*seed), *ber, *width, &mut again);
            mask == again
        },
    );
}

/// Packed mask application equals the per-bit XOR reference at
/// word-crossing widths.
#[test]
fn apply_mask_equals_per_bit_xor_reference() {
    for width in [63usize, 64, 65, 127, 128, 130] {
        let mut rng = Rng::new(width as u64 ^ 0xFA17);
        let bits = rand_bools(&mut rng, width, 0.5);
        let mut mask = Vec::new();
        inject::fill_mask(&mut rng, 0.15, width, &mut mask);
        let mut packed = to_bitvec(&bits);
        inject::apply_mask(&mask, &mut packed);
        let reference: Vec<bool> = bits
            .iter()
            .enumerate()
            .map(|(i, &b)| b != inject::contains(&mask, i))
            .collect();
        assert_matches_ref(&packed, &reference, &format!("apply_mask width={width}"));
        assert!(packed.tail_is_zero(), "width={width}: tail invariant survives masking");
    }
}

/// The count-domain prefix-flip delta equals materializing the
/// canonical stream, XOR-ing the mask in, and re-counting — the
/// identity the engine's packed fault path rests on.
#[test]
fn prop_prefix_flip_delta_matches_materialized_stream() {
    check_simple(
        173,
        200,
        |rng| {
            let width = 1 + rng.gen_index(200);
            (width, rng.gen_index(width + 1), rng.next_u64())
        },
        |(width, count, seed)| {
            let mut rng = Rng::new(*seed);
            let mut mask = Vec::new();
            inject::fill_mask(&mut rng, 0.1, *width, &mut mask);
            let mut stream = BitVec::zeros(0);
            stream.set_ones_prefix(*width, *count);
            inject::apply_mask(&mask, &mut stream);
            stream.popcount() as i64 - *count as i64 == inject::prefix_flip_delta(&mask, *count)
        },
    );
}

/// Applying the `[lo, hi)` window of a concatenated-stage mask equals
/// filtering and rebasing the lane indices by hand — how per-product
/// faults are carved out of one multiplier-stage mask.
#[test]
fn prop_apply_mask_range_is_a_rebased_sub_mask() {
    check_simple(
        179,
        150,
        |rng| {
            let lanes = 1 + rng.gen_index(6);
            let l = 1 + rng.gen_index(120);
            (lanes, l, rng.gen_index(lanes), rng.next_u64())
        },
        |(lanes, l, which, seed)| {
            let mut rng = Rng::new(*seed);
            let mut mask = Vec::new();
            inject::fill_mask(&mut rng, 0.1, lanes * l, &mut mask);
            let bits = rand_bools(&mut rng, *l, 0.5);
            let (lo, hi) = (which * l, (which + 1) * l);
            let mut ranged = to_bitvec(&bits);
            inject::apply_mask_range(&mask, lo, hi, &mut ranged);
            let rebased: Vec<u32> = mask
                .iter()
                .copied()
                .filter(|&g| (g as usize) >= lo && (g as usize) < hi)
                .map(|g| g - lo as u32)
                .collect();
            let mut direct = to_bitvec(&bits);
            inject::apply_mask(&rebased, &mut direct);
            ranged == direct
        },
    );
}

/// Spatial-temporal BSN bit path with word-parallel chunk extraction
/// equals the count path.
#[test]
fn prop_st_bsn_packed_bits_equal_counts() {
    let inner = ApproxBsn::new(vec![ApproxStage {
        m: 1,
        l: 96,
        sub: SubSample { clip: 16, stride: 2 },
    }]);
    let st = SpatialTemporalBsn::new(inner, 288, SubSample { clip: 12, stride: 1 });
    let mut rng = Rng::new(151);
    for _ in 0..15 {
        let bits = rand_bools(&mut rng, 288, 0.5);
        let bv = to_bitvec(&bits);
        let counts: Vec<usize> = (0..3)
            .map(|c| bits[c * 96..(c + 1) * 96].iter().filter(|&&b| b).count())
            .collect();
        assert_eq!(st.eval_bits(&bv).popcount(), st.eval_counts(&counts));
    }
}
