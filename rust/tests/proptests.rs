//! Property-based tests over the circuit invariants (in-tree shrinking
//! property harness — proptest is unavailable offline).

use scnn::circuits::approx_bsn::{ApproxBsn, ApproxStage, SubSample};
use scnn::circuits::multiplier::TernaryMultiplier;
use scnn::circuits::si::SelectiveInterconnect;
use scnn::circuits::Bsn;
use scnn::coding::{BitVec, Ternary, ThermCode};
use scnn::nn::quant::{QuantTensor, TernaryTensor};
use scnn::nn::tensor::Tensor;
use scnn::util::prop::{check, check_simple, shrink_vec};
use scnn::util::Rng;

fn random_bits(rng: &mut Rng, n: usize, p: f64) -> Vec<bool> {
    (0..n).map(|_| rng.gen_bool(p)).collect()
}

/// Sorting any bit vector preserves popcount and yields a thermometer
/// code — the invariant that makes BSN accumulation exact.
#[test]
fn prop_bsn_sort_invariants() {
    check(
        11,
        200,
        |rng| {
            let n = 1 + rng.gen_index(96);
            let p_one = rng.f64();
            random_bits(rng, n, p_one)
        },
        |v| shrink_vec(v, |&b| if b { vec![false] } else { vec![] }),
        |bits| {
            let bv = BitVec::from_bits(bits);
            let sorted = Bsn::new(bits.len()).sort_gate_level(&bv);
            sorted.popcount() == bv.popcount() && sorted.is_thermometer()
        },
    );
}

/// Gate-level sort == functional accumulate for arbitrary product
/// mixes.
#[test]
fn prop_gate_equals_functional() {
    check_simple(
        13,
        100,
        |rng| {
            let n = 1 + rng.gen_index(24);
            let bsl = [2usize, 4, 8][rng.gen_index(3)];
            (0..n)
                .map(|_| {
                    let half = (bsl / 2) as i64;
                    rng.gen_range_i64(-half, half)
                })
                .map(|q| ThermCode::encode(q, bsl))
                .collect::<Vec<_>>()
        },
        |codes| {
            let w: usize = codes.iter().map(|c| c.bsl()).sum();
            let bsn = Bsn::new(w);
            let gate = bsn.sort_gate_level(&Bsn::concat(codes)).popcount();
            let func = bsn.accumulate(codes).count();
            gate == func
        },
    );
}

/// Ternary multiplication: code path == integer path for every BSL.
#[test]
fn prop_multiplier_exact() {
    check_simple(
        17,
        300,
        |rng| {
            let bsl = [2usize, 4, 8, 16][rng.gen_index(4)];
            let half = (bsl / 2) as i64;
            (bsl, rng.gen_range_i64(-half, half), rng.gen_range_i64(-1, 1))
        },
        |&(bsl, a, w)| {
            let code = TernaryMultiplier::mult_therm(
                &ThermCode::encode(a, bsl),
                Ternary::from_i64(w),
            );
            code.decode() == a * w && code.bsl() == bsl
        },
    );
}

/// SI synthesis is exact for any random monotone step function.
#[test]
fn prop_si_synthesizes_any_monotone_fn() {
    check_simple(
        19,
        100,
        |rng| {
            let in_w = 4 + rng.gen_index(60);
            let out = 2 + rng.gen_index(16);
            // Random monotone table 0..=out over 0..=in_w.
            let mut table = Vec::with_capacity(in_w + 1);
            let mut cur = 0usize;
            for _ in 0..=in_w {
                if rng.gen_bool(0.3) && cur < out {
                    cur += 1;
                }
                table.push(cur);
            }
            (in_w, out, table)
        },
        |(in_w, out, table)| {
            let t = table.clone();
            let si = SelectiveInterconnect::synthesize(|c| t[c], *in_w, *out);
            (0..=*in_w).all(|c| si.apply_count(c) == table[c])
        },
    );
}

/// Sub-sampling: count path == bit path on sorted streams; output is
/// monotone in the input count.
#[test]
fn prop_subsample_consistency() {
    check_simple(
        23,
        200,
        |rng| {
            let stride = 1 + rng.gen_index(4);
            let out = 2 + rng.gen_index(16);
            let clip = rng.gen_index(16);
            let l = out * stride + 2 * clip;
            (l, SubSample { clip, stride })
        },
        |&(l, sub)| {
            let mut prev = 0usize;
            for k in 0..=l {
                let via_count = sub.apply_count(k, l);
                let via_bits = sub.apply_bits(ThermCode::from_count(k, l).bits()).popcount();
                if via_count != via_bits || via_count < prev {
                    return false;
                }
                prev = via_count;
            }
            true
        },
    );
}

/// Approximate BSN never *increases* the represented error beyond the
/// quantization step bound when inputs stay within the clip window.
#[test]
fn prop_approx_bsn_error_bound() {
    check_simple(
        29,
        60,
        |rng| {
            let m = 2 + rng.gen_index(6);
            let counts: Vec<usize> = (0..m).map(|_| 8 + rng.gen_index(17)).collect();
            (m, counts)
        },
        |(m, counts)| {
            // One stage: groups of 32, clip 4, stride 2 -> 12-bit codes,
            // then exact merge.
            let a = ApproxBsn::new(vec![
                ApproxStage { m: *m, l: 32, sub: SubSample { clip: 4, stride: 2 } },
                ApproxStage { m: 1, l: m * 12, sub: SubSample::IDENTITY },
            ]);
            let exact = a.exact_scaled_value(counts);
            let approx = a.approx_value(counts);
            // Each group quantizes by stride 2 with rounding: error
            // <= 0.5 per group (in divided units) plus merge exactness.
            (approx - exact).abs() <= 0.5 * *m as f64 + 1e-9
        },
    );
}

/// Quantize→dequantize is idempotent (a fixed point) for both weight
/// and activation quantizers.
#[test]
fn prop_quantizers_idempotent() {
    check_simple(
        31,
        100,
        |rng| {
            let n = 1 + rng.gen_index(64);
            (0..n).map(|_| rng.normal() as f32).collect::<Vec<f32>>()
        },
        |vals| {
            let t = Tensor::from_vec(&[vals.len()], vals.clone());
            // Ternarization preserves the sign/zero pattern under
            // re-quantization (the scale renormalizes, the symbols
            // cannot change sign).
            let t1 = TernaryTensor::quantize(&t);
            let t2 = TernaryTensor::quantize(&t1.dequantize());
            let aw = t1
                .values
                .iter()
                .zip(&t2.values)
                .all(|(a, b)| a.signum() == b.signum());

            // Activation fake-quant at a fixed alpha is idempotent.
            let q1 = QuantTensor::quantize(&t, 0.5, 8).dequantize();
            let q2 = QuantTensor::quantize(&q1, 0.5, 8).dequantize();
            let aq = q1.data().iter().zip(q2.data()).all(|(a, b)| (a - b).abs() < 1e-5);
            aw && aq
        },
    );
}

/// Thermometer negate/encode/decode laws under composition.
#[test]
fn prop_thermometer_algebra() {
    check_simple(
        37,
        300,
        |rng| {
            let bsl = 2 * (1 + rng.gen_index(16));
            let half = (bsl / 2) as i64;
            (bsl, rng.gen_range_i64(-half, half))
        },
        |&(bsl, q)| {
            let c = ThermCode::encode(q, bsl);
            c.decode() == q
                && c.negate().decode() == -q
                && c.negate().negate() == c
                && c.is_canonical()
        },
    );
}
